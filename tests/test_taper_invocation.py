"""End-to-end TAPER invocation tests: ipt must actually go down."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.core.taper import Taper, TaperConfig
from repro.graphs.generators import provgen_like, musicbrainz_like
from repro.graphs.metrics import partition_balance
from repro.graphs.partition import hash_partition, metis_like_partition
from repro.workload.executor import QueryExecutor

PROV_QUERIES = [
    parse_rpq("Entity.Entity.Entity"),
    parse_rpq("Agent.Activity.Entity"),
    parse_rpq("Entity.Activity.Agent"),
]


@pytest.fixture(scope="module")
def prov_graph():
    return provgen_like(2500, avg_degree=5.0, seed=7)


@pytest.fixture(scope="module")
def prov_workload():
    return [(q, f) for q, f in zip(PROV_QUERIES, (0.5, 0.3, 0.2))]


def test_invocation_reduces_objective_and_ipt(prov_graph, prov_workload):
    g = prov_graph
    k = 4
    part0 = hash_partition(g.n, k, seed=1)
    taper = Taper(g, k, TaperConfig(max_iterations=8, candidates_per_part=96, seed=0))
    report = taper.invoke(part0, prov_workload)

    # objective (total extroversion mass) strictly improves
    assert report.objective[-1] < report.objective[0]
    assert report.improvement > 0.3  # expect large gains from hash start

    # measured ipt improves too
    ex = QueryExecutor(g)
    ipt0 = ex.workload_ipt(prov_workload, part0)
    ipt1 = ex.workload_ipt(prov_workload, report.final_part)
    assert ipt1 < 0.8 * ipt0

    # balance constraint respected (5%)
    assert partition_balance(report.final_part, k) <= 1.05 + 1e-9

    # converges within the paper's 8 iterations
    assert report.iterations <= 8


def test_invocation_improves_metis_start(prov_graph, prov_workload):
    g = prov_graph
    k = 4
    part0 = metis_like_partition(g, k, seed=0)
    taper = Taper(g, k, TaperConfig(max_iterations=8, candidates_per_part=96, seed=0))
    report = taper.invoke(part0, prov_workload)
    ex = QueryExecutor(g)
    ipt0 = ex.workload_ipt(prov_workload, part0)
    ipt1 = ex.workload_ipt(prov_workload, report.final_part)
    assert ipt1 <= ipt0  # never worse; usually better (Fig. 8 shows ~30%)


def test_partition_vector_stays_valid(prov_graph, prov_workload):
    g = prov_graph
    k = 4
    taper = Taper(g, k, TaperConfig(max_iterations=3, seed=0))
    report = taper.invoke(hash_partition(g.n, k), prov_workload)
    p = report.final_part
    assert p.shape == (g.n,)
    assert p.min() >= 0 and p.max() < k


def test_workload_sensitivity(prov_graph):
    """Different workloads should lead to different refined partitionings."""
    g = prov_graph
    k = 4
    part0 = hash_partition(g.n, k, seed=1)
    w1 = [(parse_rpq("Entity.Entity"), 1.0)]
    w2 = [(parse_rpq("Activity.Agent"), 1.0)]
    t = Taper(g, k, TaperConfig(max_iterations=4, seed=0))
    p1 = t.invoke(part0, w1).final_part
    p2 = t.invoke(part0, w2).final_part
    assert (p1 != p2).any()
    ex = QueryExecutor(g)
    # each partitioning is better for its own workload than the other's
    assert ex.workload_ipt(w1, p1) <= ex.workload_ipt(w1, p2)
