"""Parity suite: the vectorised swap engine and Pallas field vs their seeds.

The frontier-batched ``swap_iteration`` must produce *bit-identical*
partitions and stats to the seed per-vertex implementation
(``repro.core.swap_ref``) — same candidate order, same families, same
offer/receive decisions, same rejected-offer counts — across random labelled
graphs, both ``ext_to`` modes, and chained iterations.

The Pallas-backed extroversion field is held to numerical (not bit) parity
with the fused jnp oracle: same DP, different op order.
"""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.core.swap import SwapConfig, swap_iteration
from repro.core.swap_ref import swap_iteration_reference
from repro.core.taper import Taper, TaperConfig
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import musicbrainz_like, provgen_like
from repro.graphs.partition import hash_partition

CASES = [
    # (seed, generator, queries, k)
    (7, provgen_like, ["Entity.Entity.Entity", "Agent.Activity.Entity"], 4),
    (3, musicbrainz_like, ["Area.Artist.(Artist|Label).Area"], 8),
    (11, provgen_like, ["Entity.Activity.Agent", "Entity.(Entity)*.Entity"], 3),
]


def _setup(seed, gen, queries, k, n=1200):
    g = gen(n, seed=seed)
    w = [(parse_rpq(q), 1.0 / len(queries)) for q in queries]
    arrays = TPSTry.from_workload(w).compile(g.label_names)
    part = hash_partition(g.n, k, seed=seed)
    return g, arrays, part


@pytest.mark.parametrize("case", CASES, ids=[f"seed{c[0]}" for c in CASES])
@pytest.mark.parametrize("dense", [True, False], ids=["dense", "two-phase"])
def test_swap_iteration_bit_identical(case, dense):
    seed, gen, queries, k = case
    g, arrays, part = _setup(seed, gen, queries, k)
    # chain three iterations so later ones start from swapped state
    for it in range(3):
        fld = extroversion_field(g, arrays, part, k, dense_ext_to=dense)
        cfg = SwapConfig()
        p_new, s_new = swap_iteration(
            g, part, fld, k, cfg, np.random.default_rng(0))
        p_ref, s_ref = swap_iteration_reference(
            g, part, fld, k, cfg, np.random.default_rng(0))
        assert (p_new == p_ref).all(), f"partition mismatch at iteration {it}"
        assert s_new == s_ref, f"stats mismatch at iteration {it}"
        if s_new.moves == 0:
            break
        part = p_new


def test_swap_iteration_bit_identical_nondefault_config():
    """Capped queues, tighter balance, small families, mass ranking."""
    g, arrays, part = _setup(5, provgen_like, ["Entity.Activity.Agent"], 5)
    fld = extroversion_field(g, arrays, part, 5, dense_ext_to=True)
    cfg = SwapConfig(candidates_per_part=40, balance_eps=0.02,
                     family_max_size=4, min_gain=1e-6, rank_by="mass",
                     max_scan_neighbors=8)
    p_new, s_new = swap_iteration(g, part, fld, 5, cfg, np.random.default_rng(0))
    p_ref, s_ref = swap_iteration_reference(
        g, part, fld, 5, cfg, np.random.default_rng(0))
    assert (p_new == p_ref).all()
    assert s_new == s_ref


def test_reverse_edge_index_is_involution():
    g = musicbrainz_like(2000, seed=1)
    rev = g.reverse_edge_index
    assert rev.shape == (g.m,)
    assert (rev >= 0).all()  # symmetric graph: every edge has its reverse
    assert (g.src[rev] == g.dst).all()
    assert (g.dst[rev] == g.src).all()
    assert (rev[rev] == np.arange(g.m)).all()


@pytest.mark.parametrize("dense", [True, False], ids=["dense", "two-phase"])
def test_pallas_field_matches_jnp(dense):
    g, arrays, part = _setup(9, provgen_like,
                             ["Entity.Entity.Entity", "Agent.Activity.Entity"],
                             4, n=800)
    f_jnp = extroversion_field(g, arrays, part, 4, dense_ext_to=dense,
                               backend="jnp")
    f_pal = extroversion_field(g, arrays, part, 4, dense_ext_to=dense,
                               backend="pallas")
    np.testing.assert_allclose(f_pal.alpha, f_jnp.alpha, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(f_pal.edge_mass, f_jnp.edge_mass,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(f_pal.pr, f_jnp.pr, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(f_pal.extro_mass, f_jnp.extro_mass,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(f_pal.extroversion, f_jnp.extroversion,
                               rtol=1e-3, atol=1e-6)
    if dense:
        np.testing.assert_allclose(f_pal.ext_to, f_jnp.ext_to,
                                   rtol=1e-4, atol=1e-6)
    else:
        assert f_pal.ext_to is None and f_jnp.ext_to is None
    assert f_pal.total_extroversion == pytest.approx(
        f_jnp.total_extroversion, rel=1e-4, abs=1e-6)


def test_pallas_field_depth_cap():
    g, arrays, part = _setup(2, provgen_like, ["Entity.Entity.Entity"], 3,
                             n=500)
    f_jnp = extroversion_field(g, arrays, part, 3, depth_cap=2, backend="jnp")
    f_pal = extroversion_field(g, arrays, part, 3, depth_cap=2,
                               backend="pallas")
    np.testing.assert_allclose(f_pal.edge_mass, f_jnp.edge_mass,
                               rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(f_pal.pr, f_jnp.pr, rtol=1e-4, atol=1e-6)


def test_taper_invoke_pallas_backend():
    """A full invocation through the Pallas field backend still improves the
    objective and keeps balance."""
    g = provgen_like(800, avg_degree=4.0, seed=4)
    k = 3
    w = [(parse_rpq("Entity.Entity.Entity"), 0.6),
         (parse_rpq("Entity.Activity.Agent"), 0.4)]
    part0 = hash_partition(g.n, k, seed=1)
    taper = Taper(g, k, TaperConfig(max_iterations=3, seed=0,
                                    field_backend="pallas"))
    report = taper.invoke(part0, w)
    assert report.objective[-1] <= report.objective[0]
    p = report.final_part
    assert p.shape == (g.n,) and p.min() >= 0 and p.max() < k


def test_taper_field_lazy_reuse_on_unchanged_trie():
    """§4.2: unchanged trie probabilities + unchanged partition -> the field
    is reused, not recomputed."""
    g = provgen_like(400, seed=8)
    k = 2
    w = [(parse_rpq("Entity.Entity"), 1.0)]
    trie = TPSTry.from_workload(w)
    taper = Taper(g, k, TaperConfig(max_iterations=1, seed=0))
    part = hash_partition(g.n, k, seed=3)
    r1 = taper.invoke(part, trie)
    calls = {"n": 0}
    import repro.core.taper as taper_mod
    orig = taper_mod.extroversion_field

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    taper_mod.extroversion_field = counting
    try:
        r2 = taper.invoke(part, trie)
    finally:
        taper_mod.extroversion_field = orig
    # first field evaluation of the repeat invocation hits the memo
    assert r2.objective[0] == r1.objective[0]
    assert calls["n"] < max(r2.iterations + 1, 1) + 1
