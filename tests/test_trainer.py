"""Fault-tolerance tests: checkpoint/restart bitwise resume, failure
injection, straggler detection, gradient compression convergence, elastic
resharding."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.lm import TokenPipeline
from repro.distributed.compression import compress_grads, init_residuals
from repro.models import transformer as tf
from repro.optim import AdamW
from repro.train.checkpoint import CheckpointManager
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture()
def tiny_setup(tmp_path):
    cfg = get_config("qwen3-4b").reduced()
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(learning_rate=1e-3)
    ostate = opt.init(params)
    step = jax.jit(tf.make_train_step(cfg, opt, remat=False))
    data = TokenPipeline(cfg.vocab, batch=4, seq_len=32, seed=0)

    def loss_and_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: tf.loss_fn(p, batch, cfg), has_aux=True)(params)
        return grads, metrics

    def apply(params, grads, ostate):
        return opt.update(params, grads, ostate)

    return cfg, params, ostate, step, data, loss_and_grads, apply


def _leaves_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_checkpoint_roundtrip(tmp_path, tiny_setup):
    cfg, params, ostate, step, data, *_ = tiny_setup
    mgr = CheckpointManager(tmp_path / "ck", keep=2)
    mgr.save(7, {"params": params, "opt_state": ostate}, {"note": "x"})
    restored = mgr.restore({"params": params, "opt_state": ostate})
    assert _leaves_equal(restored["params"], params)
    assert mgr.latest_step() == 7
    assert mgr.metadata() == {"note": "x"}


def test_checkpoint_gc_keeps_latest(tmp_path, tiny_setup):
    cfg, params, ostate, *_ = tiny_setup
    mgr = CheckpointManager(tmp_path / "ck", keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": params})
    assert mgr.all_steps() == [3, 4]


def test_crash_restart_bitwise_resume(tmp_path, tiny_setup):
    """Train 10 steps straight vs crash-at-6 + restart: identical params.

    Data is keyed by step so the restarted run replays the same batches."""
    cfg, params0, ostate0, step, _, *_ = tiny_setup

    def data_from(step_idx):
        # deterministic per-step batches
        def gen():
            i = step_idx
            while True:
                pipe = TokenPipeline(cfg.vocab, batch=4, seq_len=32, seed=100 + i)
                yield next(pipe)
                i += 1
        return gen()

    def make_trainer(fail_at, ckdir, start_params, start_opt):
        t = Trainer(
            TrainerConfig(total_steps=10, checkpoint_every=3,
                          checkpoint_dir=str(ckdir), fail_at_step=fail_at,
                          log_every=100),
            step, start_params, start_opt, data_from(0))
        return t

    # uninterrupted run
    t_ref = make_trainer(None, tmp_path / "a", params0, ostate0)
    t_ref.run()

    # crashing run
    t_crash = make_trainer(6, tmp_path / "b", params0, ostate0)
    with pytest.raises(RuntimeError, match="injected failure"):
        t_crash.run()
    # restart: fresh trainer, resume from latest checkpoint (step 6)
    t_resume = Trainer(
        TrainerConfig(total_steps=10, checkpoint_every=3,
                      checkpoint_dir=str(tmp_path / "b"), log_every=100),
        step, params0, ostate0, None)
    assert t_resume.try_resume()
    assert t_resume.step == 6
    t_resume.data = data_from(t_resume.step)
    t_resume.run()

    assert _leaves_equal(t_ref.params, t_resume.params)


def test_straggler_detection(tmp_path, tiny_setup):
    import time

    cfg, params, ostate, step, data, *_ = tiny_setup

    def hook(s):
        if s == 5:
            time.sleep(1.0)  # inject a straggler step

    t = Trainer(
        TrainerConfig(total_steps=8, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path / "ck"),
                      straggler_factor=4.0, log_every=100),
        step, params, ostate, data, step_hook=hook)
    out = t.run()
    assert 6 in out["stragglers"]  # step numbering is post-increment
    assert len(out["stragglers"]) <= 2


def test_gradient_compression_convergence(tmp_path, tiny_setup):
    cfg, params, ostate, step, data, loss_and_grads, apply = tiny_setup
    t_plain = Trainer(
        TrainerConfig(total_steps=15, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path / "p"), log_every=100),
        step, params, ostate, TokenPipeline(cfg.vocab, 4, 32, seed=5))
    out_plain = t_plain.run()

    t_comp = Trainer(
        TrainerConfig(total_steps=15, checkpoint_every=100,
                      checkpoint_dir=str(tmp_path / "c"),
                      compress_grads=True, log_every=100),
        step, params, ostate, TokenPipeline(cfg.vocab, 4, 32, seed=5),
        grad_step_fn=jax.jit(loss_and_grads), apply_fn=jax.jit(apply))
    out_comp = t_comp.run()

    l_plain = out_plain["metrics"][-1]["loss"]
    l_comp = out_comp["metrics"][-1]["loss"]
    l_start = out_plain["metrics"][0]["loss"]
    assert l_comp < l_start              # compressed run still learns
    assert abs(l_comp - l_plain) < 0.25 * l_start  # and stays close


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated quantisation error stays bounded
    and the mean dequantised gradient tracks the true mean."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(256,)).astype(np.float32) * 1e-3)
    params = {"w": jnp.zeros((256,))}
    res = init_residuals(params)
    acc = jnp.zeros((256,))
    for _ in range(50):
        deq, res = compress_grads({"w": g_true}, res)
        acc = acc + deq["w"]
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(g_true),
                               atol=1e-6)


def test_elastic_reshard_roundtrip(tmp_path, tiny_setup):
    from repro.launch.mesh import make_smoke_mesh
    from repro.train.elastic import plan_reshard, reshard_restore
    from repro.models import transformer as tfm

    cfg, params, ostate, *_ = tiny_setup
    _, logical = tfm.init(jax.random.PRNGKey(0), cfg)
    mgr = CheckpointManager(tmp_path / "ck")
    mgr.save(1, {"params": params})

    mesh = make_smoke_mesh()
    restored = reshard_restore(mgr, {"params": params}, {"params": logical}, mesh)
    assert _leaves_equal(restored["params"], params)

    plan = plan_reshard(params, logical, mesh, mesh)
    assert plan["total_state_bytes"] > 0
    assert plan["bytes_per_new_chip"] == plan["total_state_bytes"] / mesh.devices.size


def test_checkpoint_async_saves_serialize_and_close_flushes(tmp_path,
                                                            tiny_setup):
    """Regression: back-to-back async saves used to race — the second
    save() could overwrite the writer-thread handle while the first was
    mid-publish, interleaving its write with keep-pruning.  Saves must
    serialize (join-then-spawn under the lock) and close() must flush the
    in-flight writer so every step is durably on disk."""
    cfg, params, ostate, *_ = tiny_setup
    mgr = CheckpointManager(tmp_path / "ck", keep=2, async_save=True)
    for s in (1, 2, 3, 4):     # no wait() between: exercises the join path
        mgr.save(s, {"params": params, "opt_state": ostate}, {"s": s})
    mgr.close()
    assert mgr.all_steps() == [3, 4]
    restored = mgr.restore({"params": params, "opt_state": ostate})
    assert _leaves_equal(restored["params"], params)
    assert mgr.metadata() == {"s": 4}
    # the manager stays usable after close(): a later save spawns fresh
    mgr.save(5, {"params": params, "opt_state": ostate})
    mgr.close()
    assert mgr.all_steps() == [4, 5]
