"""OnlineTaper driver, GraphMutationStream scenarios, frontier-seeded swaps."""
import numpy as np
import pytest

from repro.core.online import OnlinePolicy, OnlineTaper
from repro.core.rpq import parse_rpq
from repro.core.swap import SwapConfig, swap_iteration
from repro.core.taper import Taper, TaperConfig
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import musicbrainz_like, power_law_labelled
from repro.graphs.graph import MutationBatch
from repro.graphs.metrics import partition_balance
from repro.graphs.partition import hash_partition
from repro.workload.executor import QueryExecutor
from repro.workload.stream import GraphMutationStream, WorkloadStream

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


def _workload():
    return [(MQ1, 0.5), (MQ3, 0.5)]


# ---------------------------------------------------------------------------
# GraphMutationStream
# ---------------------------------------------------------------------------


def test_mutation_stream_grow():
    g = musicbrainz_like(1000, seed=1)
    s = GraphMutationStream(mode="grow", vertices_per_tick=5, seed=0)
    n0, m0 = g.n, g.m
    g.apply_mutations(s.next_batch(g))
    assert g.n == n0 + 5
    assert g.m > m0


def test_mutation_stream_churn_keeps_size():
    g = musicbrainz_like(1000, seed=1)
    s = GraphMutationStream(mode="churn", edges_per_tick=10, seed=0)
    n0 = g.n
    g.apply_mutations(s.next_batch(g))
    assert g.n == n0  # churn never grows the vertex set


def test_mutation_stream_burst_quiet_then_spike():
    g = musicbrainz_like(800, seed=2)
    s = GraphMutationStream(mode="burst", burst_every=3, seed=0)
    assert s.next_batch(g).is_empty
    assert s.next_batch(g).is_empty
    spike = s.next_batch(g)
    assert not spike.is_empty
    assert len(spike.add_vertex_labels) > 0


def test_mutation_stream_deterministic():
    g1 = musicbrainz_like(800, seed=3)
    g2 = musicbrainz_like(800, seed=3)
    s1 = GraphMutationStream(mode="mixed", seed=9)
    s2 = GraphMutationStream(mode="mixed", seed=9)
    b1, b2 = s1.next_batch(g1), s2.next_batch(g2)
    assert np.array_equal(np.asarray(b1.add_edges), np.asarray(b2.add_edges))
    assert np.array_equal(
        np.asarray(b1.remove_edges), np.asarray(b2.remove_edges))


# ---------------------------------------------------------------------------
# frontier-seeded swap queue
# ---------------------------------------------------------------------------


def test_candidate_mask_restricts_moves():
    g = power_law_labelled(300, n_labels=4, avg_degree=5.0, seed=7)
    k = 3
    part = hash_partition(g.n, k, seed=1)
    trie = TPSTry.from_workload(
        [(parse_rpq("L0.(L1|L2).L3"), 1.0)]).compile(g.label_names)
    fld = extroversion_field(g, trie, part, k)
    allowed = np.zeros(g.n, dtype=bool)
    allowed[: g.n // 10] = True
    new_part, stats = swap_iteration(
        g, part, fld, k, SwapConfig(), np.random.default_rng(0),
        candidate_mask=allowed)
    moved = np.nonzero(new_part != part)[0]
    # singleton moves come only from the mask; families may drag 1-hop
    # members along, so every move is within one hop of the mask
    for v in moved:
        assert allowed[v] or allowed[g.neighbors(v)].any()


def test_taper_invoke_frontier_smoke():
    g = musicbrainz_like(1500, seed=4)
    taper = Taper(g, 4, TaperConfig(max_iterations=3))
    part = hash_partition(g.n, 4, seed=1)
    frontier = np.arange(50)
    rep = taper.invoke(part, _workload(), frontier=frontier)
    assert rep.final_part.shape == (g.n,)
    assert partition_balance(rep.final_part, 4) <= 1.06


# ---------------------------------------------------------------------------
# OnlineTaper
# ---------------------------------------------------------------------------


def test_online_taper_places_new_vertices_and_invokes():
    g = musicbrainz_like(1200, seed=5)
    ot = OnlineTaper(
        g, 4, policy=OnlinePolicy(cadence=2, dirty_fraction=0.01))
    ws = WorkloadStream([MQ1, MQ3], period=6.0, seed=2)
    ms = GraphMutationStream(
        mode="mixed", seed=3, vertices_per_tick=3, edges_per_tick=8)
    for _ in range(4):
        ws.advance(1.0)
        ot.observe(ws.sample(60))
        ot.apply_mutations(ms.next_batch(g))
        ot.step()
    assert ot.part.shape == (g.n,)
    assert (ot.part >= 0).all() and (ot.part < 4).all()
    assert ot.invocations >= 1
    assert partition_balance(ot.part, 4) <= 1.10


def test_online_ingest_rejects_stale_or_skipped_records():
    g = musicbrainz_like(600, seed=10)
    ot = OnlineTaper(g, 4)
    ms = GraphMutationStream(mode="grow", vertices_per_tick=2, seed=1)
    a1 = g.apply_mutations(ms.next_batch(g))
    a2 = g.apply_mutations(ms.next_batch(g))  # a1 skipped by the caller
    with pytest.raises(ValueError, match="stale"):
        ot.ingest(a1)
    with pytest.raises(ValueError, match="non-contiguous"):
        ot.ingest(a2)  # part still at the pre-a1 length


def test_online_taper_no_workload_no_invoke():
    g = musicbrainz_like(800, seed=6)
    ot = OnlineTaper(g, 4, policy=OnlinePolicy(cadence=1, min_interval=0))
    rep = ot.step()
    assert not rep.invoked  # nothing observed yet -> nothing to fit


def test_online_policy_workload_drift_trigger():
    g = musicbrainz_like(800, seed=7)
    ot = OnlineTaper(
        g, 4,
        policy=OnlinePolicy(cadence=100, dirty_fraction=1.0, drift_l1=0.3))
    ot.observe([MQ1] * 50)
    assert not ot.step().invoked      # no baseline yet: drift undefined
    ot.invoke(reason="manual")        # establish the baseline
    ot.observe([MQ1] * 50)
    assert not ot.step().invoked      # same workload: no drift
    for _ in range(6):
        ot.observe([MQ3] * 50)        # decisive swing to MQ3
    rep = ot.step()
    assert rep.invoked and rep.reason == "workload"


def test_online_policy_topology_trigger_is_frontier_local():
    g = musicbrainz_like(1000, seed=8)
    ot = OnlineTaper(
        g, 4,
        policy=OnlinePolicy(cadence=100, dirty_fraction=0.005, drift_l1=9.9))
    ot.observe([MQ1, MQ3] * 30)
    ot.invoke(reason="manual")        # establish baseline freqs
    ms = GraphMutationStream(mode="churn", edges_per_tick=20, seed=4)
    ot.apply_mutations(ms.next_batch(g))
    rep = ot.step()
    assert rep.invoked and rep.reason == "topology"
    assert int(ot._dirty.sum()) == 0  # frontier consumed by the invocation


def test_online_ipt_under_drift_beats_hash():
    """End-to-end: combined topology+workload drift, OnlineTaper holds ipt
    below the drifting hash baseline."""
    g = musicbrainz_like(2000, seed=9)
    k = 4
    ws = WorkloadStream([MQ1, MQ3], period=8.0, seed=3)
    ms = GraphMutationStream(
        mode="mixed", seed=5, vertices_per_tick=2, edges_per_tick=6)
    ex = QueryExecutor(g)
    taper = Taper(g, k, TaperConfig(max_iterations=4))
    part0 = taper.invoke(
        hash_partition(g.n, k, seed=1), ws.workload()).final_part
    ot = OnlineTaper(
        g, k, part=part0,
        policy=OnlinePolicy(cadence=3, dirty_fraction=0.01))
    wins = 0
    ticks = 5
    for _ in range(ticks):
        ws.advance(1.0)
        ot.observe(ws.sample(80))
        ot.apply_mutations(ms.next_batch(g))
        w = ws.workload()
        ot.step(measured_ipt=ex.workload_ipt(w, ot.part))
        ipt_online = ex.workload_ipt(w, ot.part)
        ipt_hash = ex.workload_ipt(w, hash_partition(g.n, k, seed=1))
        wins += ipt_online < ipt_hash
    assert wins >= ticks - 1  # at most one transient tick above baseline


# ---------------------------------------------------------------------------
# migration-cost gating of the ipt-regression trigger
# ---------------------------------------------------------------------------


def _regressed_online_taper(**policy_overrides):
    """OnlineTaper with an established ipt baseline of 100.0 and every
    trigger except ipt-regression disabled."""
    g = musicbrainz_like(800, seed=10)
    pol = OnlinePolicy(cadence=1000, min_interval=0, dirty_fraction=1.0,
                       drift_l1=9e9, ipt_regression=1.2, **policy_overrides)
    ot = OnlineTaper(g, 4, policy=pol)
    ot.observe([MQ1, MQ3] * 30)
    ot.invoke(reason="manual")
    rep = ot.step(measured_ipt=100.0)   # first measurement -> baseline
    assert not rep.invoked
    return ot


def test_ipt_regression_trigger_fires_without_gate():
    ot = _regressed_online_taper()      # min_ipt_gain_per_mb=0: gate off
    rep = ot.step(measured_ipt=200.0)   # 2x regression >= 1.2
    assert rep.invoked and rep.reason == "ipt"


def test_ipt_regression_gated_by_migration_cost():
    ot = _regressed_online_taper(min_ipt_gain_per_mb=1e12)
    rep = ot.step(measured_ipt=200.0)   # regressed, but gain/MB too small
    assert not rep.invoked
    # a drastic regression clears even a demanding threshold
    mb = ot.estimated_migration_bytes() / 2**20
    ot.policy.min_ipt_gain_per_mb = 50.0 / mb  # needs gain >= 50
    rep = ot.step(measured_ipt=200.0)          # projected gain = 100
    assert rep.invoked and rep.reason == "ipt"


def test_estimated_migration_bytes_degree_proportional():
    g = musicbrainz_like(600, seed=11)
    ot = OnlineTaper(g, 4, policy=OnlinePolicy(migration_bytes_per_edge=64.0))
    base = ot.estimated_migration_bytes()
    assert base > 0
    ot.policy.migration_bytes_per_edge = 128.0
    assert ot.estimated_migration_bytes() == pytest.approx(2 * base)
    # after an invocation the estimate follows the actual move count
    ot.observe([MQ1, MQ3] * 30)
    ot.invoke(reason="manual")
    moves = ot._last_total_moves
    assert moves is not None
    avg_deg = g.m / g.n
    assert ot.estimated_migration_bytes() == pytest.approx(
        max(moves, 0) * avg_deg * 128.0)
