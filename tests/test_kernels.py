"""Pallas kernel validation (interpret mode) against pure-jnp oracles.

Per assignment: for each kernel, sweep shapes/dtypes and assert_allclose
against the ref.py oracle (hypothesis-driven sweeps + fixed edge cases).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.kernels.embedding_bag.ops import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_reference
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.segment_spmm.ops import pack_edges, pack_weights, segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_reference
from repro.kernels.vm_step.ops import pack_vm_inputs, vm_step
from repro.kernels.vm_step.ref import build_transition, vm_step_reference

SET = settings(max_examples=10, deadline=None,
               suppress_health_check=list(HealthCheck))


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@given(
    b=st.integers(1, 3),
    sq=st.integers(1, 300),
    skv=st.integers(1, 300),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([32, 64]),
    causal=st.booleans(),
    window=st.sampled_from([None, 17, 64]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
@SET
def test_flash_attention_sweep(b, sq, skv, h, g, d, causal, window, dtype):
    if causal and sq > skv:
        sq = skv  # decode-style causal assumes q suffix aligns; keep simple
    rng = np.random.default_rng(abs(hash((b, sq, skv, h, g, d))) % 2**31)
    kv = h
    H = h * g
    q = jnp.asarray(rng.normal(size=(b, sq, H, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, skv, kv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, skv, kv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    kf, vf = jnp.repeat(k, g, 2), jnp.repeat(v, g, 2)
    ref = attention_reference(q, kf, vf, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_long_and_blocks():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, 1024, 2, 64)).astype(np.float32))
    ref = attention_reference(q, k, v, causal=True)
    for bq, bk in [(128, 128), (256, 64), (64, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# segment spmm
# ---------------------------------------------------------------------------


@given(
    n=st.integers(5, 400),
    e=st.integers(1, 1500),
    f=st.sampled_from([8, 32, 64]),
    block_n=st.sampled_from([32, 128]),
    block_e=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**16),
)
@SET
def test_segment_spmm_sweep(n, e, f, block_n, block_e, seed):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    w = rng.normal(size=e).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))

    packed = pack_edges(src, dst, n, block_n, block_e)
    w_packed = pack_weights(packed, w)
    out = segment_spmm(x, packed, w_packed, n)
    ref = segment_spmm_reference(x, jnp.asarray(src), jnp.asarray(dst),
                                 jnp.asarray(w), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_segment_spmm_fallback_matches():
    rng = np.random.default_rng(1)
    n, e, f = 100, 400, 16
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    w = rng.normal(size=e).astype(np.float32)
    x = jnp.asarray(rng.normal(size=(n, f)).astype(np.float32))
    packed = pack_edges(src, dst, n, 32, 64)
    wp = pack_weights(packed, w)
    out_k = segment_spmm(x, packed, wp, n, use_pallas=True)
    out_f = segment_spmm(x, packed, wp, n, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_f),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# vm step (TAPER DP)
# ---------------------------------------------------------------------------


def _random_trie(rng, n_labels, depth=3, branching=2):
    from repro.core.tpstry import synthetic_trie

    return synthetic_trie(n_labels, depth, branching,
                          n_first=min(3, n_labels), seed=int(rng.integers(1e6)))


@given(
    n=st.integers(10, 300),
    e=st.integers(5, 1200),
    n_labels=st.sampled_from([3, 6, 12]),
    seed=st.integers(0, 2**16),
)
@SET
def test_vm_step_sweep(n, e, n_labels, seed):
    rng = np.random.default_rng(seed)
    trie = _random_trie(rng, n_labels)
    N = trie.n_nodes
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    labels = rng.integers(0, n_labels, n).astype(np.int32)
    cnt = rng.integers(1, 5, (n, n_labels)).astype(np.int32)
    alpha = jnp.asarray(rng.random((n, N)).astype(np.float32))
    T = jnp.asarray(build_transition(trie.parent, trie.label, trie.cond_p,
                                     n_labels))

    packed, dst_label, inv_cnt = pack_vm_inputs(src, dst, labels, cnt, n,
                                                block_n=64, block_e=128)
    out = vm_step(alpha, T, packed, dst_label, inv_cnt, n)
    inv_ref = 1.0 / np.maximum(cnt[src, labels[dst]], 1.0)
    ref = vm_step_reference(alpha, T, jnp.asarray(src), jnp.asarray(dst),
                            jnp.asarray(inv_ref.astype(np.float32)),
                            jnp.asarray(labels[dst]), n)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_vm_step_matches_visitor_dp(paper_graph, paper_trie, paper_partition):
    """The kernel advances alpha exactly like the visitor-field DP: applying
    it to the paper graph's depth-1 priors must reproduce the depth-2 alpha
    states of the §5.4 worked example (restricted to local edges)."""
    from repro.core.visitor import extroversion_field

    g = paper_graph
    arrays = paper_trie.compile(g.label_names)
    fld = extroversion_field(g, arrays, paper_partition, k=2)

    # build alpha0 with only depth-1 states
    N = arrays.n_nodes
    alpha0 = np.zeros((g.n, N), np.float32)
    for i in range(N):
        if arrays.depth[i] == 1:
            alpha0[:, i] = np.asarray(fld.alpha[:, i])
    # only local edges advance the DP
    local = paper_partition[g.src] == paper_partition[g.dst]
    src, dst = g.src[local], g.dst[local]
    cnt = g.neighbor_label_counts()
    T = jnp.asarray(build_transition(arrays.parent, arrays.label,
                                     arrays.cond_p, arrays.n_labels))
    packed, dst_label, inv_cnt = pack_vm_inputs(src, dst, g.labels, cnt, g.n,
                                                block_n=8, block_e=8)
    out = np.asarray(vm_step(jnp.asarray(alpha0), T, packed, dst_label,
                             inv_cnt, g.n))
    for i in range(N):
        if arrays.depth[i] == 2:
            np.testing.assert_allclose(out[:, i], np.asarray(fld.alpha[:, i]),
                                       rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# embedding bag
# ---------------------------------------------------------------------------


@given(
    v=st.integers(10, 3000),
    d=st.sampled_from([8, 32, 64]),
    b=st.integers(1, 300),
    h=st.sampled_from([1, 2, 8]),
    combiner=st.sampled_from(["sum", "mean"]),
    seed=st.integers(0, 2**16),
)
@SET
def test_embedding_bag_sweep(v, d, b, h, combiner, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, v, (b, h)).astype(np.int32))
    out = embedding_bag_pallas(table, ids, combiner=combiner,
                               block_b=64, block_v=256)
    ref = embedding_bag_reference(table, ids, combiner)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_embedding_bag_repeated_ids():
    # a bag hitting the same row multiple times must count it multiple times
    table = jnp.asarray(np.eye(8, 4, dtype=np.float32))
    ids = jnp.asarray([[2, 2, 2, 0]], dtype=jnp.int32)
    out = embedding_bag_pallas(table, ids, block_b=8, block_v=8)
    ref = embedding_bag_reference(table, ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))
