"""Async serving subsystem: queue semantics, ingest coalescing, atomic
partition swap under mid-invocation mutations, batched enumeration parity,
and the threaded serving loop end to end."""
import numpy as np
import pytest

from repro.core.online import OnlinePolicy, OnlineTaper
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like, power_law_labelled
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.graphs.partition import hash_partition
from repro.serve import (
    GraphQueryEngine,
    IngestQueue,
    RequestQueue,
    ServeConfig,
    ServeLoopConfig,
    ServingLoop,
    coalesce_mutations,
)
from repro.workload.executor import QueryExecutor

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


# ---------------------------------------------------------------------------
# request queue: bounded admission, backpressure, micro-batching
# ---------------------------------------------------------------------------


def test_request_queue_backpressure_rejects_with_retry_hint():
    q = RequestQueue(max_depth=4)
    tickets = [q.submit(MQ1) for _ in range(4)]
    assert all(t.accepted for t in tickets)
    rej = q.submit(MQ1)
    assert not rej.accepted
    assert rej.reason == "queue_full"
    assert rej.queue_depth == 4
    assert rej.retry_after_s > 0
    assert q.rejected == 1
    # the hint scales with the measured service rate
    q.record_service_time(1.0)
    slow = q.submit(MQ1)
    assert slow.retry_after_s > rej.retry_after_s
    # draining frees capacity
    q.take_batch(2)
    assert q.submit(MQ1).accepted


def test_admission_classes_reserve_admits_hot_ahead_of_cold():
    # hot MQ1 dominates the observed stream, cold MQ3 trickles
    freqs = {MQ1.qhash: 0.9, MQ3.qhash: 0.02}
    q = RequestQueue(max_depth=8, hot_reserve_frac=0.5,
                     admission_weight=lambda rpq: freqs[rpq.qhash])
    # warm the admitted-weight EWMA below the reserve zone
    for _ in range(4):
        assert q.submit(MQ1).accepted
    # reserve zone (depth >= 4): cold queries are refused, hot admitted
    cold = q.submit(MQ3)
    assert not cold.accepted
    assert cold.reason == "cold_backpressure"
    assert q.rejected_cold == 1
    hot = q.submit(MQ1)
    assert hot.accepted
    # a genuinely full queue rejects both, but the hint is graded by heat:
    # hot queries are told to retry sooner than cold ones
    for _ in range(3):
        q.submit(MQ1)
    hot_rej = q.submit(MQ1)
    cold_rej = q.submit(MQ3)
    assert not hot_rej.accepted and not cold_rej.accepted
    assert hot_rej.reason == "queue_full"
    assert hot_rej.retry_after_s < cold_rej.retry_after_s


def test_admission_classes_inactive_without_weight_hook_or_signal():
    # no hook: PR-4 behaviour byte for byte
    q = RequestQueue(max_depth=2)
    assert q.submit(MQ1).accepted and q.submit(MQ3).accepted
    assert q.submit(MQ1).reason == "queue_full"
    # hook present but sketch unwarmed (all weights 0): everything is hot,
    # so the reserve never rejects and hints stay unscaled
    q2 = RequestQueue(max_depth=2, admission_weight=lambda rpq: 0.0)
    assert q2.submit(MQ1).accepted and q2.submit(MQ3).accepted
    rej = q2.submit(MQ3)
    assert rej.reason == "queue_full"
    assert q2.rejected_cold == 0


def test_serving_loop_grades_backpressure_by_sketch_frequency():
    g = musicbrainz_like(400, seed=3)
    loop = ServingLoop(
        g, 4, config=ServeLoopConfig(micro_batch=8, max_queue_depth=8))
    # serve a hot-heavy stream inline to warm the sketch snapshot
    for _ in range(6):
        for q in [MQ1] * 7 + [MQ3]:
            loop.submit(q)
        loop.pump()
    assert loop._adm_freqs[MQ1.qhash] > loop._adm_freqs.get(MQ3.qhash, 0.0)
    # fill into the reserve zone with hot traffic; cold is now refused
    # ahead of hot under pressure
    while loop.requests.depth() < loop.cfg.max_queue_depth - 1:
        assert loop.submit(MQ1).accepted
    cold = loop.submit(MQ3)
    hot = loop.submit(MQ1)
    assert not cold.accepted and cold.reason == "cold_backpressure"
    assert hot.accepted
    stats = loop.stop()
    assert stats["rejected_cold_requests"] >= 1


def test_request_queue_micro_batch_is_fifo():
    q = RequestQueue(max_depth=16)
    t1, t2, t3 = q.submit(MQ1), q.submit(MQ3), q.submit(MQ1)
    batch = q.take_batch(2)
    assert batch == [t1, t2]
    assert q.take_batch(2) == [t3]
    assert q.take_batch(2, timeout=0) == []


def test_ingest_queue_backpressure():
    iq = IngestQueue(max_depth=2)
    assert iq.submit(MutationBatch(add_edges=[(0, 1)])) is True
    assert iq.submit(MutationBatch(add_edges=[(1, 2)])) is True
    rej = iq.submit(MutationBatch(add_edges=[(2, 3)]))
    assert not rej.accepted and rej.reason == "ingest_full"
    assert iq.rejected == 1


# ---------------------------------------------------------------------------
# ingest coalescing: order-aware fold == sequential apply, bitwise
# ---------------------------------------------------------------------------


def _apply_all(g: LabelledGraph, batches):
    for b in batches:
        g.apply_mutations(b)


def _assert_graphs_equal(g1: LabelledGraph, g2: LabelledGraph):
    assert g1.n == g2.n
    assert np.array_equal(g1.labels, g2.labels)
    assert np.array_equal(g1.src, g2.src)
    assert np.array_equal(g1.dst, g2.dst)
    assert np.array_equal(g1.row_ptr, g2.row_ptr)


def test_coalesce_order_add_then_remove_is_absent():
    g1 = power_law_labelled(60, n_labels=3, avg_degree=4.0, seed=1)
    g2 = g1.copy()
    batches = [
        MutationBatch(add_edges=[(0, 9)]),
        MutationBatch(remove_edges=[(0, 9)]),
    ]
    merged = coalesce_mutations(batches)
    assert len(merged) == 1  # no conflict: one batch
    _apply_all(g1, batches)
    _apply_all(g2, merged)
    assert 9 not in g1.neighbors(0)
    _assert_graphs_equal(g1, g2)


def test_coalesce_order_remove_then_add_is_present():
    g1 = power_law_labelled(60, n_labels=3, avg_degree=4.0, seed=2)
    # pick an existing edge so the removal is effective
    u, w = int(g1.src[0]), int(g1.dst[0])
    g2 = g1.copy()
    batches = [
        MutationBatch(remove_edges=[(u, w)]),
        MutationBatch(add_edges=[(u, w)]),
    ]
    merged = coalesce_mutations(batches)
    assert len(merged) == 1
    _apply_all(g1, batches)
    _apply_all(g2, merged)
    assert w in g1.neighbors(u)
    _assert_graphs_equal(g1, g2)


def test_coalesce_splits_on_add_after_vertex_removal():
    g1 = power_law_labelled(60, n_labels=3, avg_degree=4.0, seed=3)
    g2 = g1.copy()
    batches = [
        MutationBatch(remove_vertices=[5]),
        MutationBatch(add_edges=[(5, 11)]),  # re-attach the tombstone
    ]
    merged = coalesce_mutations(batches)
    assert len(merged) == 2  # one batch would drop the re-attachment
    _apply_all(g1, batches)
    _apply_all(g2, merged)
    assert 11 in g1.neighbors(5)
    _assert_graphs_equal(g1, g2)


def test_coalesce_relabel_last_wins_and_new_vertices_align():
    g1 = power_law_labelled(60, n_labels=4, avg_degree=4.0, seed=4)
    g2 = g1.copy()
    batches = [
        MutationBatch(add_vertex_labels=[1], add_edges=[(60, 2)],
                      relabel=[(7, 0)]),
        MutationBatch(add_vertex_labels=[2], add_edges=[(61, 60)],
                      relabel=[(7, 3), (60, 0)]),
    ]
    merged = coalesce_mutations(batches)
    assert len(merged) == 1
    _apply_all(g1, batches)
    _apply_all(g2, merged)
    assert int(g1.labels[7]) == 3 and int(g1.labels[60]) == 0
    _assert_graphs_equal(g1, g2)


@pytest.mark.parametrize("seed", range(4))
def test_coalesce_random_stream_parity(seed):
    rng = np.random.default_rng(seed)
    g1 = power_law_labelled(80, n_labels=4, avg_degree=5.0, seed=seed)
    g2 = g1.copy()
    batches = []
    n_virtual = g1.n
    for _ in range(6):
        nv = int(rng.integers(0, 3))
        hi = n_virtual + nv
        batches.append(MutationBatch(
            add_vertex_labels=rng.integers(0, 4, nv),
            add_edges=np.stack([rng.integers(0, hi, 6),
                                rng.integers(0, hi, 6)], 1),
            remove_edges=np.stack([rng.integers(0, n_virtual, 4),
                                   rng.integers(0, n_virtual, 4)], 1),
            remove_vertices=(
                [int(rng.integers(0, n_virtual))]
                if rng.random() < 0.4 else []),
            relabel=(
                [(int(rng.integers(0, n_virtual)), int(rng.integers(0, 4)))]
                if rng.random() < 0.5 else []),
        ))
        n_virtual = hi
    _apply_all(g1, batches)
    _apply_all(g2, coalesce_mutations(batches))
    _assert_graphs_equal(g1, g2)


# ---------------------------------------------------------------------------
# atomic partition swap (double buffering) under a mid-invocation mutation
# ---------------------------------------------------------------------------


def test_commit_grafts_snapshot_onto_grown_partition():
    g = musicbrainz_like(900, seed=3)
    ot = OnlineTaper(g, 4, policy=OnlinePolicy(),
                     config=TaperConfig(max_iterations=2))
    ot.observe([MQ1, MQ3] * 30)
    n0 = g.n
    pending = ot.begin_invocation("manual")
    assert pending is not None and pending.n_snapshot == n0
    old_part = ot.part
    rep = ot.run_invocation(pending)
    # a mutation lands after the run finished but before the commit: two
    # new vertices (greedily placed) and fresh topology dirt
    applied = ot.apply_mutations(MutationBatch(
        add_vertex_labels=[0, 1], add_edges=[(n0, 0), (n0 + 1, 2), (3, 4)]))
    assert ot.part.shape == (n0 + 2,)
    tail = ot.part[n0:].copy()
    ot.commit_invocation(pending)
    # the swap covers the full live length: enhanced prefix + live tail
    assert ot.part.shape == (n0 + 2,)
    assert np.array_equal(ot.part[:n0], rep.final_part[:n0])
    assert np.array_equal(ot.part[n0:], tail)
    assert ot.invocations == 1
    # the old vector object is untouched (readers holding it see a
    # consistent pre-swap view — double buffering, not in-place writes)
    assert old_part.shape == (n0,)
    # mid-invocation dirt survives the commit for the next invocation
    dirty = applied.dirty_vertices()
    assert ot._dirty[dirty[dirty < ot._dirty.shape[0]]].any()


def test_overlapped_loop_defers_ingest_until_commit():
    g = musicbrainz_like(700, seed=5)
    loop = ServingLoop(
        g, 4,
        taper_config=TaperConfig(max_iterations=2),
        policy=OnlinePolicy(bootstrap_after_ticks=0, cadence=10 ** 9,
                            dirty_fraction=2.0, drift_l1=9e9),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=True))
    for _ in range(8):
        loop.submit(MQ1)
    n0 = g.n
    # inline pump: serves one micro-batch and launches the (overlapped)
    # bootstrap invocation on its thread
    loop.pump()
    assert loop.invocation_in_flight
    # a mutation submitted mid-invocation is queued, not applied
    loop.submit_mutations(MutationBatch(add_vertex_labels=[0],
                                        add_edges=[(n0, 1)]))
    assert g.n == n0  # graph untouched while the field eval runs
    loop._finish_inflight()          # wait + commit
    assert not loop.invocation_in_flight
    assert loop.ot.invocations == 1
    assert g.n == n0                 # ingest still deferred until a pump
    loop.pump()
    assert g.n == n0 + 1             # applied after the commit
    assert loop.part.shape == (n0 + 1,)
    loop.stop()


# ---------------------------------------------------------------------------
# batched enumeration parity
# ---------------------------------------------------------------------------


def test_enumerate_paths_many_matches_per_query():
    g = musicbrainz_like(800, seed=7)
    part = hash_partition(g.n, 4, seed=1)
    ex = QueryExecutor(g)
    queries = [MQ1, MQ3, MQ1, MQ1, MQ3]  # duplicates share one enumeration
    many = ex.enumerate_paths_many(queries, max_results=16, part=part)
    assert len(many) == len(queries)
    for q, (paths, ipt) in zip(queries, many):
        ref_paths, ref_ipt = ex.enumerate_paths(q, max_results=16, part=part)
        assert paths == ref_paths
        assert ipt == ref_ipt


def test_enumeration_plan_survives_mutations():
    g = musicbrainz_like(500, seed=8)
    ex = QueryExecutor(g)
    ex.enumerate_paths(MQ1, max_results=8)   # warm the plan cache
    ex.enumerate_paths(MQ3, max_results=8)
    new_lab = (int(g.labels[0]) + 1) % g.n_labels
    g.apply_mutations(MutationBatch(relabel=[(0, new_lab)],
                                    add_edges=[(0, 7)]))
    fresh = QueryExecutor(g)
    for q in (MQ1, MQ3):
        # cached plan is label-id based: still valid across graph versions
        assert ex.enumerate_paths(q, max_results=8) == \
            fresh.enumerate_paths(q, max_results=8)


# ---------------------------------------------------------------------------
# threaded serving loop end to end
# ---------------------------------------------------------------------------


def test_threaded_loop_serves_and_invokes():
    g = musicbrainz_like(900, seed=9)
    loop = ServingLoop(
        g, 4,
        taper_config=TaperConfig(max_iterations=2),
        config=ServeLoopConfig(micro_batch=8, max_queue_depth=512,
                               batch_wait_s=0.002)).start()
    tickets = []
    for i in range(60):
        t = loop.submit(MQ1 if i % 3 else MQ3)
        assert t.accepted
        tickets.append(t)
    loop.submit_mutations(MutationBatch(add_vertex_labels=[1],
                                        add_edges=[(g.n, 0), (g.n, 5)]))
    for t in tickets:
        assert t.wait(timeout=30.0)
    stats = loop.stop()
    assert stats["completed"] == 60
    assert loop.ot.invocations >= 1
    assert loop.part.shape == (g.n,)
    assert (loop.part >= 0).all() and (loop.part < 4).all()
    for key in ("latency_p50_s", "latency_p99_s", "ipt_p99",
                "ipt_per_request", "queue_depth", "invocation_overlap_s",
                "invocation_stall_s", "partition_swaps"):
        assert key in stats
    assert stats["latency_p99_s"] >= stats["latency_p50_s"]


def test_malformed_ingest_batch_does_not_kill_the_loop():
    g = musicbrainz_like(500, seed=15)
    loop = ServingLoop(
        g, 4,
        taper_config=TaperConfig(max_iterations=2),
        config=ServeLoopConfig(micro_batch=8)).start()
    m0 = g.m
    # a valid batch and a malformed one (out-of-range id) coalesce into one
    # fold; the loop must drop only the bad member and keep the good one
    w = next(v for v in range(1, g.n)
             if v not in set(g.neighbors(0).tolist()))
    loop.submit_mutations(MutationBatch(add_edges=[(0, w)]))
    loop.submit_mutations(MutationBatch(relabel=[(g.n + 5, 0)]))
    tickets = [loop.submit(MQ1) for _ in range(10)]
    for t in tickets:
        assert t.wait(timeout=30.0)   # worker survived and kept serving
    stats = loop.stop()
    assert stats["completed"] == 10
    assert stats["failed_mutations"] == 1
    assert g.m == m0 + 2              # the valid member still landed


def test_stop_the_world_mode_records_stalls():
    g = musicbrainz_like(600, seed=11)
    loop = ServingLoop(
        g, 4,
        taper_config=TaperConfig(max_iterations=2),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False))
    tickets = [loop.submit(MQ1) for _ in range(10)]
    while not all(t.done.is_set() for t in tickets):
        loop.pump()
    stats = loop.stop()
    assert loop.ot.invocations >= 1
    assert stats["invocation_stall_s"] > 0      # serving blocked
    assert stats["invocation_overlap_s"] == 0.0


def test_sharded_warm_path_uploads_only_dirty_shards():
    jax = pytest.importorskip("jax")
    g = musicbrainz_like(700, seed=12)
    loop = ServingLoop(
        g, 4,
        taper_config=TaperConfig(max_iterations=2,
                                 field_backend="pallas_sharded"),
        policy=OnlinePolicy(bootstrap_after_ticks=0, cadence=10 ** 9,
                            dirty_fraction=2.0, drift_l1=9e9),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False))
    tickets = [loop.submit(MQ1) for _ in range(10)]
    while not all(t.done.is_set() for t in tickets):
        loop.pump()
    assert loop.ot.invocations == 1     # bootstrap ran the sharded field
    pre = loop.ot.taper._pre
    ups = pre["_shard_uploads"]
    n_shards = len(jax.devices())
    total0 = ups["total_shards"]
    # a mutation localized to the first shard's vertex range: the warm path
    # re-uploads only the dirty shard slice(s), not the whole packing
    loop.submit_mutations(MutationBatch(add_edges=[(0, 2), (1, 3)]))
    loop.pump()
    assert ups["rebuilds"] == 1         # patched in place, never re-packed
    uploaded = ups["total_shards"] - total0
    assert uploaded >= 1
    assert n_shards == 1 or uploaded < n_shards
    loop.stop()


def test_first_invocation_after_gates_bootstrap():
    g = musicbrainz_like(600, seed=14)
    eng = GraphQueryEngine(
        g, hash_partition(g.n, 4, seed=1), 4,
        ServeConfig(first_invocation_after=15, max_results_per_query=4))
    eng.serve_batch([MQ1] * 10)
    assert eng.invocations == 0      # below the configured request floor
    eng.serve_batch([MQ1] * 10)
    assert eng.invocations == 1      # bootstrap fires once past it


def test_facade_engine_routes_mutations_and_stats():
    g = musicbrainz_like(600, seed=13)
    eng = GraphQueryEngine(
        g, hash_partition(g.n, 4, seed=1), 4,
        ServeConfig(min_requests_between_invocations=20,
                    max_results_per_query=4))
    out = eng.serve_batch([MQ1] * 10)
    assert len(out) == 10
    n0 = g.n
    eng.apply_mutations(MutationBatch(add_vertex_labels=[2],
                                      add_edges=[(n0, 1)]))
    eng.serve_batch([MQ3] * 10)
    assert g.n == n0 + 1
    assert eng.part.shape == (n0 + 1,)
    s = eng.stats()
    assert s["requests"] == 20
    assert s["invocations"] >= 1
    assert "ipt_p99" in s and "latency_p99_s" in s


# ---------------------------------------------------------------------------
# retry hints under sustained overload
# ---------------------------------------------------------------------------


def test_retry_hints_monotone_in_backlog_depth():
    freqs = {MQ1.qhash: 0.9, MQ3.qhash: 0.02}
    q = RequestQueue(max_depth=16, hot_reserve_frac=0.75,
                     admission_weight=lambda rpq: freqs[rpq.qhash])
    for _ in range(4):            # warm the watershed into the reserve zone
        assert q.submit(MQ1).accepted
    hints = []
    while q.depth() < q.max_depth:
        rej = q.submit(MQ3)       # cold probe: rejected, depth unchanged
        assert rej.reason == "cold_backpressure"
        hints.append(rej.retry_after_s)
        assert q.submit(MQ1).accepted
    # a deeper backlog always quotes an equal-or-later comeback time
    assert all(b >= a for a, b in zip(hints, hints[1:]))
    assert hints[-1] > hints[0]
    # ingest hints follow the same rule: the hint scales with the backlog
    # the producer would be waiting behind
    shallow, deep = IngestQueue(max_depth=4), IngestQueue(max_depth=32)
    for iq in (shallow, deep):
        while iq.submit(MutationBatch(add_edges=[(0, 1)])) is True:
            pass
    assert (deep.submit(MutationBatch(add_edges=[(0, 1)])).retry_after_s
            > shallow.submit(MutationBatch(add_edges=[(0, 1)])).retry_after_s)


def test_hot_hint_never_later_than_cold_under_sustained_overload():
    freqs = {MQ1.qhash: 0.8, MQ3.qhash: 0.05}
    q = RequestQueue(max_depth=8,
                     admission_weight=lambda rpq: freqs[rpq.qhash])
    while q.depth() < q.max_depth:
        assert q.submit(MQ1).accepted
    # rounds of overload with a drifting service-time estimate: every
    # paired rejection tells the hot client to come back no later than the
    # cold one, so retry traffic re-arrives pre-sorted by priority
    for round_service_s in (1e-3, 5e-3, 2e-2, 1e-1):
        q.record_service_time(round_service_s)
        hot = q.submit(MQ1)
        cold = q.submit(MQ3)
        assert not hot.accepted and not cold.accepted
        assert hot.retry_after_s <= cold.retry_after_s
    assert q.rejected == 8


# ---------------------------------------------------------------------------
# loop-level split-group apply (add-after-vertex-removal conflict)
# ---------------------------------------------------------------------------


def test_loop_applies_split_groups_and_journals_both(tmp_path):
    """The add-after-vertex-removal conflict must split into two groups all
    the way through the serving loop: two journaled groups, two version
    bumps, and arrays bitwise equal to the sequential apply."""
    from repro.serve.snapshot import WAL_NAME, MutationJournal

    g = musicbrainz_like(300, seed=31)
    ref = g.copy()
    loop = ServingLoop(
        g, 4, config=ServeLoopConfig(micro_batch=8,
                                     overlap_invocations=False,
                                     snapshot_dir=str(tmp_path)))
    v0 = g.version
    batches = [
        MutationBatch(remove_vertices=[5]),
        MutationBatch(add_edges=[(5, 11)]),  # re-attach the tombstone
    ]
    for b in batches:
        assert loop.submit_mutations(b) is True
    loop.pump()
    # two groups applied (a single fold would drop the re-attachment)
    assert g.version == v0 + 2
    assert loop.ingest.applied_batches == 2
    assert 11 in g.neighbors(5)
    for b in batches:
        ref.apply_mutations(b)
    _assert_graphs_equal(g, ref)
    # ...and the WAL framed them as two groups, each with a merged outcome
    out = MutationJournal(tmp_path / WAL_NAME).replay()
    assert [seq for seq, _, _ in out] == [1, 2]
    assert all(o["mode"] == "merged" for _, _, o in out)
    assert len(out[0][1]) == 1 and len(out[1][1]) == 1
    loop.stop()
