"""Online serving engine tests: batched requests, drift-triggered TAPER."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.graphs.generators import provgen_like
from repro.graphs.partition import hash_partition
from repro.serve.engine import GraphQueryEngine, ServeConfig
from repro.workload.stream import WorkloadStream


@pytest.fixture(scope="module")
def engine():
    g = provgen_like(2000, seed=4)
    return GraphQueryEngine(
        g, hash_partition(g.n, 4, seed=1), 4,
        ServeConfig(min_requests_between_invocations=50, drift_threshold=0.2,
                    max_results_per_query=8),
    )


def test_serve_batch_returns_results(engine):
    q = parse_rpq("Entity.Activity")
    out = engine.serve_batch([q, q, q])
    assert len(out) == 3
    for r in out:
        assert r.n_results >= 0
        assert r.ipt >= 0
        assert r.latency_s >= 0


def test_drift_triggers_invocation(engine):
    qa = parse_rpq("Entity.Entity")
    qb = parse_rpq("Agent.Activity")
    # phase 1: all Qa -> first fit
    for _ in range(3):
        engine.serve_batch([qa] * 30)
    inv1 = engine.invocations
    assert inv1 >= 1
    part1 = engine.part.copy()
    # phase 2: workload flips to Qb -> drift must trigger a re-fit
    for _ in range(4):
        engine.serve_batch([qb] * 30)
    assert engine.invocations > inv1
    assert (engine.part != part1).any()
    # partition stays valid
    assert engine.part.min() >= 0 and engine.part.max() < 4


def test_stats_accounting(engine):
    s = engine.stats()
    assert s["requests"] > 0
    assert s["ipt_per_request"] >= 0
