"""Observability subsystem: tracer sampling and span trees, the flight
recorder ring + triggered JSONL dumps (at every fault site), the unified
metrics registry (collect protocol, Prometheus round-trip), and the
serving loop's request/invocation/ingest trace integration."""
import json

import numpy as np
import pytest

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.obs import (
    NOOP_SPAN,
    NOOP_TRACE,
    FlightRecorder,
    Observability,
    Registry,
    Tracer,
    flatten_numeric,
    parse_prometheus_text,
)
from repro.serve import ServeLoopConfig, ServingLoop
from repro.serve.faults import (
    FaultInjector,
    InjectedFault,
    SITE_INGEST_GROUP,
    SITE_INVOCATION,
    SITE_LINK_PARTITION,
    SITE_REPLICA_APPLY,
    SITE_REPLICA_SERVE,
    SITE_SHARD_UPLOAD,
    SITE_SHIP_DELAY,
    SITE_SHIP_DROP,
    SITE_SHIP_REORDER,
)
from repro.serve.metrics import ServeMetrics, SlidingWindow
from repro.utils.timing import Timer

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")

ALL_FAULT_SITES = [
    SITE_INVOCATION, SITE_SHARD_UPLOAD, SITE_INGEST_GROUP, SITE_SHIP_DROP,
    SITE_SHIP_DELAY, SITE_SHIP_REORDER, SITE_LINK_PARTITION,
    SITE_REPLICA_APPLY, SITE_REPLICA_SERVE,
]


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_tracer_span_tree_and_ring():
    tr = Tracer(node="a")
    ctx = tr.new_trace()
    assert ctx.sampled and ctx.trace_id.startswith("t-a-")
    with tr.start("root", ctx, kind="test") as root:
        child = tr.start("child", root.context())
        child.end(ok=True)
        tr.event("mark", root.context(), depth=2)
    spans = tr.spans(ctx.trace_id)
    by_name = {s["name"]: s for s in spans}
    assert set(by_name) == {"root", "child", "mark"}
    assert by_name["child"]["parent_id"] == by_name["root"]["span_id"]
    assert by_name["mark"]["parent_id"] == by_name["root"]["span_id"]
    assert by_name["child"]["attrs"] == {"ok": True}
    assert by_name["mark"]["duration_s"] == 0.0  # instant span
    assert by_name["root"]["duration_s"] >= 0.0
    assert by_name["root"]["wall"] > 0


def test_tracer_sampling_is_deterministic_counting():
    tr = Tracer(sample_rate=0.5)
    sampled = [tr.new_trace().sampled for _ in range(10)]
    assert sampled == [True, False] * 5
    assert tr.sampled_traces == 5 and tr.unsampled_traces == 5
    # unsampled traces produce only the shared no-op span
    assert tr.start("x", NOOP_TRACE) is NOOP_SPAN


def test_tracer_rate_zero_and_force():
    tr = Tracer(sample_rate=0.0)
    assert not tr.new_trace().sampled
    assert tr.new_trace(force=True).sampled  # forced: invocations, failover
    off = Tracer(enabled=False)
    assert off.new_trace(force=True) is NOOP_TRACE  # off beats force


def test_tracer_join_adopts_foreign_trace():
    a, b = Tracer(node="a"), Tracer(node="b")
    ctx = a.new_trace()
    a.start("origin", ctx).end()
    joined = b.join(ctx.trace_id)
    b.start("remote", joined).end()
    assert [s["name"] for s in b.spans(ctx.trace_id)] == ["remote"]
    assert b.join(None) is NOOP_TRACE


def test_tracer_ring_eviction_and_jsonl_export(tmp_path):
    tr = Tracer(capacity=4)
    ctx = tr.new_trace()
    for i in range(10):
        tr.start(f"s{i}", ctx).end()
    spans = tr.spans()
    assert [s["name"] for s in spans] == ["s6", "s7", "s8", "s9"]
    p = tmp_path / "spans.jsonl"
    assert tr.export_jsonl(p) == 4
    rows = [json.loads(line) for line in p.read_text().splitlines()]
    assert [r["name"] for r in rows] == ["s6", "s7", "s8", "s9"]


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------


def test_recorder_ring_order_and_filter():
    rec = FlightRecorder(capacity=4)
    for i in range(6):
        rec.record("tick" if i % 2 else "tock", i=i)
    evs = rec.events()
    assert [e["i"] for e in evs] == [2, 3, 4, 5]  # oldest evicted
    assert [e["seq"] for e in evs] == sorted(e["seq"] for e in evs)
    assert [e["i"] for e in rec.events("tick")] == [3, 5]
    assert all(e["node"] == "n0" for e in evs)


def test_recorder_trigger_dumps_jsonl(tmp_path):
    rec = FlightRecorder(dump_dir=tmp_path, node="p")
    rec.record("admission_reject", reason="queue_full")
    path = rec.trigger("failover")
    assert path is not None and path.exists()
    assert rec.dumps == [path]
    rows = FlightRecorder.load_jsonl(path)
    assert rows[0]["kind"] == "admission_reject"
    assert rows[-1]["kind"] == "dump_trigger"
    assert rows[-1]["reason"] == "failover"


def test_recorder_env_dump_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_FLIGHT_DIR", str(tmp_path / "flight"))
    rec = FlightRecorder()
    rec.record("x")
    assert rec.trigger("t").exists()


def test_recorder_disabled_is_inert(tmp_path):
    rec = FlightRecorder(dump_dir=tmp_path, enabled=False)
    rec.record("x")
    assert rec.trigger("t") is None
    assert rec.events() == [] and rec.dumps == []


def test_every_fault_site_triggers_a_flight_dump(tmp_path):
    """Each armed fault site (including scoped per-replica arms) records a
    ``fault_fired`` event and auto-dumps the ring."""
    for i, site in enumerate(ALL_FAULT_SITES):
        fi = FaultInjector()
        fi.recorder = FlightRecorder(dump_dir=tmp_path / site, node=site)
        scoped = site if i % 2 == 0 else f"{site}:replica-1"
        fi.arm(scoped, mode="raise", times=1)
        with pytest.raises(InjectedFault):
            fi.fire(scoped)
        ev = fi.recorder.events("fault_fired")
        assert len(ev) == 1 and ev[0]["site"] == scoped
        trig = fi.recorder.events("dump_trigger")
        assert trig[0]["reason"] == f"fault:{scoped}"
        assert len(fi.recorder.dumps) == 1
        rows = FlightRecorder.load_jsonl(fi.recorder.dumps[0])
        assert any(r["kind"] == "fault_fired" for r in rows)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_instruments():
    reg = Registry()
    c = reg.counter("requests_total", cls="hot")
    c.inc()
    c.inc(2)
    assert reg.counter("requests_total", cls="hot") is c  # get-or-create
    g = reg.gauge("queue_depth")
    g.set(7)
    h = reg.histogram("latency_s")
    for v in (0.001, 0.003, 0.2):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["requests_total_cls_hot"] == 3
    assert snap["queue_depth"] == 7
    assert snap["latency_s_count"] == 3
    assert snap["latency_s_sum"] == pytest.approx(0.204)
    assert 0 < snap["latency_s_p50"] <= snap["latency_s_p99"]
    with pytest.raises(TypeError):
        reg.gauge("requests_total", cls="hot")  # kind mismatch


def test_registry_prometheus_round_trip():
    reg = Registry()
    reg.counter("reqs_total", cls="hot").inc(5)
    reg.counter("reqs_total", cls="cold").inc(1)
    reg.gauge("depth").set(3.5)
    h = reg.histogram("lat_s", cls="hot")
    for v in (0.0001, 0.004, 0.09, 30.0):
        h.observe(v)
    text = reg.to_prometheus_text(include_collected=False)
    again = parse_prometheus_text(text)
    # byte-identical round trip: every metric, label set and bucket survives
    assert again.to_prometheus_text(include_collected=False) == text
    assert again.snapshot() == reg.snapshot()


def test_registry_collect_protocol():
    reg = Registry()
    reg.register_collector("serve", lambda: {
        "completed": 10, "nested": {"a": 1, "b": 2.5}, "name": "skip-me",
        "flag": True})
    got = reg.collected()
    assert got == {"serve_completed": 10, "serve_nested_a": 1,
                   "serve_nested_b": 2.5, "serve_flag": 1}
    # re-registering the same prefix replaces (promotion takes over slots)
    reg.register_collector("serve", lambda: {"completed": 11})
    assert reg.collected() == {"serve_completed": 11}
    # a raising collector is dropped, not fatal
    reg.register_collector("bad", lambda: 1 / 0)
    assert reg.collected() == {"serve_completed": 11}
    reg.unregister_collector("serve")
    reg.unregister_collector("bad")
    assert reg.collected() == {}


def test_flatten_numeric():
    assert flatten_numeric({"a": 1, "b": {"c": 2.0, "d": {"e": 3}},
                            "s": "x", "t": True, "l": [1]}) == {
        "a": 1, "b_c": 2.0, "b_d_e": 3, "t": 1}


# ---------------------------------------------------------------------------
# serve metrics satellites
# ---------------------------------------------------------------------------


def test_sliding_window_percentile_cache_matches_fresh_sort():
    """The sort cache must be invisible: every percentile read, at every
    interleaving of records, equals the from-scratch sorted answer."""
    rng = np.random.default_rng(0)
    w = SlidingWindow(window=64)
    for i, v in enumerate(rng.random(200)):
        w.record(float(v))
        if i % 7 == 0:
            for p in (0.0, 50.0, 90.0, 99.0, 100.0):
                fresh = sorted(w._buf)
                idx = min(len(fresh) - 1,
                          max(0, int(round(p / 100.0 * (len(fresh) - 1)))))
                # read twice: the second hits the cache and must agree
                assert w.percentile(p) == fresh[idx]
                assert w.percentile(p) == fresh[idx]


def test_serve_metrics_snapshot_is_flat_scalars():
    m = ServeMetrics(window=16)
    m.record_batch([0.01, 0.02], [1, 2], False, worker_id=0)
    m.record_batch([0.03], [3], True, worker_id=2)
    snap = m.snapshot(field_stats={"halo_ratio": 0.25})
    for k, v in snap.items():
        assert not isinstance(v, (dict, list, tuple)), \
            f"{k} is nested ({type(v).__name__}); the contract is flat"
    assert snap["completed_by_worker_0"] == 2
    assert snap["completed_by_worker_2"] == 1
    assert snap["workers_reporting"] == 2
    assert snap["halo_ratio"] == 0.25
    assert "completed_by_worker" not in snap  # the nested dict is gone


def test_timer_shim_backed_by_registry():
    t = Timer()
    with t.section("load"):
        pass
    with t.section("load"):
        pass
    with t.section("fit"):
        pass
    assert t.counts == {"load": 2, "fit": 1}
    assert set(t.totals) == {"load", "fit"}
    assert all(v >= 0 for v in t.totals.values())
    s = t.summary()
    assert "load" in s and "fit" in s
    # the accumulation is registry histograms, not bespoke dicts
    assert t.registry.histogram("timer_load").count == 2


# ---------------------------------------------------------------------------
# serving loop integration
# ---------------------------------------------------------------------------


def _loop(tmp=None, obs=None, **pol):
    g = musicbrainz_like(300, seed=7)
    pol.setdefault("bootstrap_after_ticks", 0)
    pol.setdefault("cadence", 6)
    pol.setdefault("min_interval", 0)
    pol.setdefault("dirty_fraction", 0.02)
    pol.setdefault("drift_l1", 9e9)
    pol.setdefault("ipt_regression", 9e9)
    return ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=OnlinePolicy(**pol),
        config=ServeLoopConfig(
            micro_batch=8, overlap_invocations=False, obs=obs,
            snapshot_dir=None if tmp is None else str(tmp)))


def _drive(loop, rounds, mutate_every=3):
    tickets = []
    for i in range(rounds):
        t = loop.submit(MQ1 if i % 3 else MQ3)
        assert t.accepted
        tickets.append(t)
        if mutate_every and i % mutate_every == 0:
            loop.submit_mutations(MutationBatch(add_edges=[(i % 200,
                                                            (i * 7) % 200)]))
        loop.pump()
    while not all(t.done.is_set() for t in tickets):
        loop.pump()


def test_loop_request_and_invocation_traces(tmp_path):
    obs = Observability(trace_sample_rate=1.0, node="primary")
    loop = _loop(tmp_path, obs=obs)
    _drive(loop, rounds=14)
    assert loop.ot.invocations >= 1
    tr = obs.tracer

    # every admitted request opened a "request" trace and closed it with
    # the serve outcome
    reqs = tr.spans(name="request")
    assert len(reqs) == 14
    assert all(r["attrs"]["latency_s"] > 0 for r in reqs)
    assert all("n_paths" in r["attrs"] for r in reqs)
    # micro-batch drain spans join the admission-opened traces
    batches = tr.spans(name="request.batch")
    assert batches and all(b["trace_id"].startswith("t-primary-")
                           for b in batches)
    assert {b["trace_id"] for b in batches} <= {r["trace_id"] for r in reqs}

    # the invocation lifecycle is one forced trace: snapshot → field →
    # swap → commit, all under the same root
    inv = [s for s in tr.spans(name="invocation")
           if s["attrs"].get("committed")]
    assert inv
    tid = inv[0]["trace_id"]
    names = [s["name"] for s in tr.spans(tid)]
    for stage in ("invocation.snapshot", "invocation.field",
                  "invocation.swap", "invocation.commit"):
        assert stage in names, f"{stage} missing from {names}"
    assert names.index("invocation.snapshot") \
        < names.index("invocation.commit")

    # ingest groups trace too (journal append → apply → publish)
    assert tr.spans(name="ingest.group")
    loop.stop()


def test_loop_trace_sample_rate_config_path():
    loop = _loop(obs=None)
    assert not loop.obs.enabled  # default: the disabled singleton
    loop.stop()
    g = musicbrainz_like(300, seed=7)
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=OnlinePolicy(bootstrap_after_ticks=0, cadence=6,
                            min_interval=0, dirty_fraction=0.02,
                            drift_l1=9e9, ipt_regression=9e9),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                               trace_sample_rate=0.25))
    assert loop.obs.enabled
    assert loop.obs.tracer.sample_rate == 0.25
    loop.stop()


def test_loop_registers_collectors_and_prom_export(tmp_path):
    obs = Observability(trace_sample_rate=1.0)
    loop = _loop(tmp_path, obs=obs)
    _drive(loop, rounds=8)
    got = obs.registry.collected()
    assert any(k.startswith("serve_") for k in got)
    assert got["executor_enum_calls"] > 0
    assert got["executor_plans_compiled"] > 0
    text = obs.registry.to_prometheus_text()
    assert parse_prometheus_text(
        obs.registry.to_prometheus_text(include_collected=False)
    ).to_prometheus_text(include_collected=False) \
        == obs.registry.to_prometheus_text(include_collected=False)
    # collected values ride along as untyped gauges in the full export
    assert "executor_enum_calls" in text
    loop.stop()


def test_loop_fault_site_dump_through_serving_path(tmp_path):
    """The integration variant of the per-site dump test: a fault fired by
    the loop's own ingest path dumps the ring with the serving events that
    led up to it."""
    fi = FaultInjector()
    obs = Observability(trace_sample_rate=1.0,
                        dump_dir=str(tmp_path / "flight"))
    g = musicbrainz_like(300, seed=7)
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=OnlinePolicy(bootstrap_after_ticks=10 ** 9, cadence=10 ** 9,
                            min_interval=0, dirty_fraction=2.0,
                            drift_l1=9e9, ipt_regression=9e9),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                               obs=obs, faults=fi,
                               snapshot_dir=str(tmp_path / "snap")))
    fi.arm(SITE_INGEST_GROUP, mode="raise", times=1)
    loop.submit_mutations(MutationBatch(add_edges=[(1, 2)]))
    # the loop survives the poisoned group (falls back to per-member
    # application) — but the recorder captured the firing and dumped
    loop.pump()
    assert fi.recorder is obs.recorder  # the loop wired it
    assert [e["site"] for e in obs.recorder.events("fault_fired")] \
        == [SITE_INGEST_GROUP]
    assert len(obs.recorder.dumps) == 1
    rows = FlightRecorder.load_jsonl(obs.recorder.dumps[0])
    assert any(r["kind"] == "fault_fired" for r in rows)
    loop.stop()


def test_obs_disabled_leaves_no_trace_state(tmp_path):
    loop = _loop(tmp_path, obs=None)
    _drive(loop, rounds=6)
    assert loop.obs.tracer.spans() == []
    assert loop.obs.recorder.events() == []
    loop.stop()


# ---------------------------------------------------------------------------
# PR 10 satellites: quantile round-trip + recorder wraparound under soak
# ---------------------------------------------------------------------------


def test_histogram_quantile_round_trips_sliding_window():
    """The registry histogram's bucket quantile and the serving metrics'
    exact SlidingWindow percentile agree to bucket resolution on the same
    samples — the brownout controller may trust either signal."""
    import bisect

    rng = np.random.default_rng(42)
    reg = Registry()
    h = reg.histogram("lat", cls="hot")
    sw = SlidingWindow(window=4096)
    for x in rng.uniform(0.0008, 1.2, size=600):
        h.observe(float(x))
        sw.record(float(x))
    for q in (0.5, 0.9, 0.95, 0.99):
        exact = sw.percentile(q * 100.0)
        est = h.quantile(q)
        # the estimate must land in the exact value's bucket (one bucket
        # of slack either side for the rank-rounding difference)
        i = bisect.bisect_left(h.bounds, exact)
        lo = h.bounds[i - 2] if i >= 2 else 0.0
        hi = h.bounds[min(i + 1, len(h.bounds) - 1)]
        assert lo <= est <= hi, (q, exact, est)


def test_recorder_wraparound_retains_exactly_the_window(tmp_path):
    """Soak past capacity: the ring evicts oldest-first, seq stays
    monotone, and a trigger dumps exactly the surviving window."""
    rec = FlightRecorder(capacity=8, dump_dir=tmp_path, node="soak")
    for i in range(50):
        rec.record("tick", i=i)
    assert rec.recorded == 50
    evs = rec.events()
    assert len(evs) == 8
    assert [e["i"] for e in evs] == list(range(42, 50))  # newest 8 survive
    assert [e["seq"] for e in evs] == sorted(e["seq"] for e in evs)
    path = rec.trigger("soak-check")
    rows = FlightRecorder.load_jsonl(path)
    # the dump_trigger event itself evicted the oldest retained tick
    assert len(rows) == 8
    assert [r["i"] for r in rows[:-1]] == list(range(43, 50))
    assert rows[-1]["kind"] == "dump_trigger"
    assert rows[-1]["reason"] == "soak-check"
