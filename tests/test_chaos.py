"""Chaos-soak scenarios (PR 10): compound fault storms on a virtual
clock, checked for the robustness invariants and bit-reproducibility."""
import pytest

from repro.serve.chaos import (
    SCENARIOS,
    ChaosEvent,
    ChaosHarness,
    Scenario,
    scenario,
)


def _run(tmp_path, name, sub="a"):
    d = tmp_path / sub
    d.mkdir(exist_ok=True)
    return ChaosHarness(d, scenario(name)).run()


def _assert_green(r):
    assert r.invariant_errors == []
    assert r.staleness_violations == []
    assert r.ok


# ---------------------------------------------------------------------------
# the four canonical storms: invariants green, the expected signals fired
# ---------------------------------------------------------------------------


def test_crash_storm_survives_and_converges(tmp_path):
    r = _run(tmp_path, "crash_storm")
    _assert_green(r)
    assert r.failovers == 1 and r.rejoins >= 1
    assert r.epoch == 2
    assert r.faults_fired.get("replica_apply:replica-2") == 1
    assert r.final_seq >= r.watermark_seq


def test_slow_follower_breaker_routes_around(tmp_path):
    r = _run(tmp_path, "slow_follower")
    _assert_green(r)
    # the permanently failing replica tripped its serve breaker, and the
    # cooldown (virtual clock) re-admitted it after the fault cleared
    assert r.breaker_trips >= 1
    assert r.faults_fired.get("replica_serve:replica-1", 0) >= 1
    assert r.stats["breaker_trips"] >= 1
    assert r.stats["breakers_open"] == 0  # closed again by quiesce


def test_flash_crowd_sheds_and_recovers(tmp_path):
    r = _run(tmp_path, "flash_crowd")
    _assert_green(r)
    assert r.shed_raises >= 1  # brownout engaged under the 4x surge
    assert r.stats["rejected_brownout"] > 0  # cold traffic actually shed
    assert r.stats["shed_level"] == 0  # admission re-opened at quiesce


def test_partition_heal_fences_and_rejoins(tmp_path):
    r = _run(tmp_path, "partition_heal")
    _assert_green(r)
    assert r.failovers == 1 and r.rejoins == 1
    assert r.epoch == 2
    assert r.final_seq >= r.watermark_seq


# ---------------------------------------------------------------------------
# determinism: same scenario, same seed -> identical state digest
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenarios_are_bit_reproducible(tmp_path, name):
    a = _run(tmp_path, name, "a")
    b = _run(tmp_path, name, "b")
    _assert_green(a)
    _assert_green(b)
    assert a.digest == b.digest


def test_different_seeds_diverge(tmp_path):
    sc = scenario("crash_storm")
    a = ChaosHarness(tmp_path / "a", sc).run()
    sc2 = scenario("crash_storm")
    sc2.seed = sc.seed + 1
    b = ChaosHarness(tmp_path / "b", sc2).run()
    assert a.digest != b.digest  # the digest actually covers the workload


# ---------------------------------------------------------------------------
# evidence: the flight recorder tells the whole story
# ---------------------------------------------------------------------------


def test_chaos_leaves_flight_recorder_evidence(tmp_path):
    h = ChaosHarness(tmp_path, scenario("crash_storm"))
    r = h.run()
    _assert_green(r)
    rec = h.obs.recorder
    assert len(rec.events("promotion")) == r.failovers
    assert len(rec.events("rejoin")) == r.rejoins
    assert rec.events("fault_fired")
    assert rec.events("heartbeat_lapse")  # the forced-failover path
    # run() triggered a dump: the black box is on disk
    assert rec.dumps and rec.dumps[-1].exists()


def test_harness_rejects_unknown_action(tmp_path):
    sc = Scenario(name="bad", steps=1,
                  events=[ChaosEvent(0, "explode", {})])
    h = ChaosHarness(tmp_path, sc)
    with pytest.raises(ValueError, match="unknown chaos action"):
        h.run()
    h.coord.stop()


def test_unknown_scenario_name():
    with pytest.raises(ValueError, match="unknown scenario"):
        scenario("nope")
