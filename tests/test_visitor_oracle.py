"""Visitor-Matrix / extroversion oracle tests.

Every expected number below appears verbatim in the paper (§4.2 example,
§5.2.1 safe-vertex example, §5.4 partial-extroversion example).  These pin
the vectorised DP to the paper's corecursive Alg. 1 semantics.
"""
import numpy as np
import pytest

from repro.core.visitor import extroversion_field, vm_cell

V1, V2, V3, V4, V5, V6 = 0, 1, 2, 3, 4, 5  # paper vertex ids 1..6


@pytest.fixture(scope="module")
def arrays(paper_trie, paper_graph):
    return paper_trie.compile(paper_graph.label_names)


@pytest.fixture(scope="module")
def field(paper_graph, arrays, paper_partition):
    return extroversion_field(paper_graph, arrays, paper_partition, k=2)


def test_vm_cell_paper_4_2(paper_graph, arrays):
    """§4.2: VM^(3)[1,2,*] = (0, 0, 0.25, 0.5, 0.25, 0)."""
    row = vm_cell(paper_graph, arrays, [V1, V2])
    np.testing.assert_allclose(row, [0, 0, 0.25, 0.5, 0.25, 0], atol=1e-7)


def test_vm_cell_unmatched_path(paper_graph, arrays):
    # a path whose label string is not a trie prefix has no transitions
    row = vm_cell(paper_graph, arrays, [V2])  # label 'b' is not a prefix
    np.testing.assert_allclose(row, np.zeros(6), atol=0)


def test_alpha_states_vertex3(paper_graph, arrays, field, paper_trie):
    """§5.2.1/§5.4 intermediate values for vertex 3, partition B={3,5,6}:
    alpha[(3)->'c']=0.125, alpha[(5,3)->'cc']=0.125, alpha[(6,3)->'ac']=0.25."""
    name_to = {
        tuple(): 0,
    }
    # locate trie nodes by path
    def node_of(path):
        cur = 0
        lbl = {s: i for i, s in enumerate(paper_graph.label_names)}
        for sym in path:
            cur = int(arrays.child_index[cur, lbl[sym]])
            assert cur >= 0
        return cur

    assert field.alpha[V3, node_of(["c"])] == pytest.approx(0.125, abs=1e-7)
    assert field.alpha[V3, node_of(["c", "c"])] == pytest.approx(0.125, abs=1e-7)
    assert field.alpha[V3, node_of(["a", "c"])] == pytest.approx(0.25, abs=1e-7)


def test_pr_vertex3(field):
    """§5.2.1: total traversal probability through v3, Pr(v3) = 0.5."""
    assert field.pr[V3] == pytest.approx(0.5, abs=1e-7)


def test_extroversion_vertex3(field):
    """§5.4: external transition probability 0.0625 ('0.06'); extroversion
    0.0625/0.5 = 0.125 ('0.12')."""
    assert field.extro_mass[V3] == pytest.approx(0.0625, abs=1e-7)
    assert field.extroversion[V3] == pytest.approx(0.125, abs=1e-7)


def test_introversion_vertex3(field):
    """§5.2.1: intra-partition traversal probability 0.44 (exactly 0.4375),
    introversion 0.4375/0.5 = 0.875 ('0.88') — v3 is 'safe' for any
    threshold below 0.875."""
    assert field.introversion[V3] == pytest.approx(0.875, abs=1e-7)


def test_ext_to_decomposition(field, paper_partition):
    """ext_to sums to extro_mass; v3's external mass all flows to A."""
    np.testing.assert_allclose(field.ext_to.sum(axis=1), field.extro_mass, atol=1e-6)
    assert field.ext_to[V3, 0] == pytest.approx(0.0625, abs=1e-7)
    assert field.ext_to[V3, 1] == pytest.approx(0.0, abs=1e-9)


def test_no_external_neighbours_is_safe(paper_graph, arrays, paper_partition):
    """§5.2.2: vertices without external neighbours have no extroversion."""
    fld = extroversion_field(paper_graph, arrays, paper_partition, k=2)
    # vertex 6's only neighbour is 3 (same partition B)
    assert fld.extro_mass[V6] == pytest.approx(0.0, abs=1e-9)
    assert fld.extroversion[V6] == pytest.approx(0.0, abs=1e-9)


def test_depth_cap_heuristic(paper_graph, arrays, paper_partition):
    """§5.2.2 time heuristic: capping path length k < t changes (only
    truncates) the field; with cap=1 there are no transitions at all."""
    fld_full = extroversion_field(paper_graph, arrays, paper_partition, k=2)
    fld_cap = extroversion_field(paper_graph, arrays, paper_partition, k=2, depth_cap=2)
    # with cap 2, only priors transition; v3 extroversion shrinks to the
    # depth-2 contribution (paths of length 1)
    assert fld_cap.extro_mass[V3] <= fld_full.extro_mass[V3] + 1e-9


def test_mass_conservation(paper_graph, arrays, paper_partition, field):
    """Per-vertex: edge mass out + termination mass == Pr(v)."""
    out_mass = np.zeros(paper_graph.n)
    np.add.at(out_mass, paper_graph.src, field.edge_mass)
    assert (out_mass <= field.pr + 1e-6).all()
