"""Fault injection and graceful degradation: the serving loop must keep
answering queries under every injected fault class — invocation crashes
and stalls (watchdog abort-and-retry with backoff), shard-upload failures,
poisoned coalesced ingest groups — degrading the field backend down the
``pallas_sharded -> pallas -> jnp`` ladder and probing back up."""
import time

import numpy as np
import pytest

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.serve import ServeLoopConfig, ServingLoop
from repro.serve.faults import (
    SITE_INGEST_GROUP,
    SITE_INVOCATION,
    SITE_SHARD_UPLOAD,
    FaultInjector,
    FaultSpec,
    InjectedFault,
)

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")


def _eager_policy():
    """Invoke on every tick (cadence 1), decisions from durable state."""
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=1, min_interval=0,
                        dirty_fraction=2.0, drift_l1=9e9, ipt_regression=9e9)


def _quiet_policy():
    """Never invoke: isolates ingest/upload paths from the swap engine."""
    return OnlinePolicy(bootstrap_after_ticks=None, cadence=10 ** 9,
                        min_interval=0, dirty_fraction=2.0, drift_l1=9e9,
                        ipt_regression=9e9)


def _topology_policy():
    """Invoke only on topology dirt (any dirty vertex trips it)."""
    return OnlinePolicy(bootstrap_after_ticks=None, cadence=10 ** 9,
                        min_interval=0, dirty_fraction=1e-9, drift_l1=9e9,
                        ipt_regression=9e9)


# ---------------------------------------------------------------------------
# the injector itself
# ---------------------------------------------------------------------------


def test_fault_injector_arm_fire_exhaust_disarm():
    fi = FaultInjector()
    fi.fire("invocation")                  # unarmed site: no-op
    fi.arm("invocation", times=2)
    with pytest.raises(InjectedFault):
        fi.fire("invocation")
    with pytest.raises(InjectedFault):
        fi.fire("invocation")
    fi.fire("invocation")                  # exhausted after ``times`` shots
    assert fi.fired_total() == 2
    fi.arm("shard_upload", times=-1)       # <=0: fires forever
    for _ in range(3):
        with pytest.raises(InjectedFault):
            fi.fire("shard_upload")
    fi.disarm("shard_upload")
    fi.fire("shard_upload")
    assert fi.fired_total() == 5
    with pytest.raises(ValueError):
        FaultSpec(mode="explode")


def test_fault_injector_rejects_unknown_site():
    """A typo'd site must fail at arm time, not pass vacuously by never
    firing (PR 10 satellite)."""
    fi = FaultInjector()
    with pytest.raises(ValueError, match="unknown fault site 'invocatoin'"):
        fi.arm("invocatoin")
    with pytest.raises(ValueError, match="valid sites: "):
        fi.arm("not_a_site:replica-1")
    # qualified arms of known sites still work
    fi.arm("replica_serve:replica-1")
    assert fi.armed("replica_serve:replica-1")


def test_fault_injector_stall_mode_sleeps_not_raises():
    fi = FaultInjector()
    fi.arm("invocation", mode="stall", delay_s=0.05)
    t0 = time.perf_counter()
    fi.fire("invocation")                  # stall: delay, no exception
    assert time.perf_counter() - t0 >= 0.04
    assert fi.fired_total() == 1


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------


def test_backend_fallback_and_probe_recovery():
    pytest.importorskip("jax")
    g = musicbrainz_like(300, seed=21)
    fi = FaultInjector()
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2,
                                       field_backend="pallas"),
        policy=_eager_policy(),
        config=ServeLoopConfig(
            micro_batch=4, overlap_invocations=False, faults=fi,
            invocation_retry_backoff_s=0.0, backend_fallback_after=2,
            backend_probe_after=1))
    fi.arm(SITE_INVOCATION, times=4)
    while fi.fired_total() < 4:
        loop.submit(MQ1)
        try:
            loop.pump()
        except InjectedFault:
            # the inline drive re-raises the invocation fault, but only
            # after the micro-batch was served — queries never stall
            pass
    served_during_faults = loop.metrics.completed
    assert served_during_faults >= 4
    # 4 consecutive failures at threshold 2: pallas -> jnp, then pinned at
    # the bottom rung (no further fallback to record)
    s = loop.stats()
    assert s["field_backend"] == "jnp"
    assert s["backend_fallbacks"] == 1
    assert s["degraded"] == 1 and s["healthy"] == 0
    assert loop.metrics.invocation_failures == 4
    # healthy commits at probe_after=1 walk back up: jnp -> pallas
    while loop.stats()["backend_recoveries"] < 1:
        loop.submit(MQ1)
        loop.pump()
    s = loop.stats()
    assert s["field_backend"] == "pallas"
    assert s["degraded"] == 0 and s["healthy"] == 1
    assert s["completed"] >= served_during_faults + 1


def test_invocation_failure_sets_retry_backoff():
    g = musicbrainz_like(300, seed=22)
    fi = FaultInjector()
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=_eager_policy(),
        config=ServeLoopConfig(
            micro_batch=4, overlap_invocations=False, faults=fi,
            invocation_retry_backoff_s=30.0, backend_fallback_after=99))
    fi.arm(SITE_INVOCATION, times=1)
    loop.submit(MQ1)
    with pytest.raises(InjectedFault):
        loop.pump()
    assert loop._backoff_until > time.monotonic() + 10
    inv = loop.ot.invocations
    loop.submit(MQ1)
    assert loop.pump() == 1                # still serving inside the backoff
    assert loop.ot.invocations == inv      # ...but no retry until it expires
    loop._backoff_until = 0.0
    loop.submit(MQ1)
    loop.pump()
    assert loop.ot.invocations == inv + 1  # retried once the backoff passed


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------


def test_watchdog_aborts_stalled_invocation_and_gates_ingest():
    g = musicbrainz_like(300, seed=23)
    fi = FaultInjector()
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=_eager_policy(),
        config=ServeLoopConfig(
            micro_batch=4, overlap_invocations=True, faults=fi,
            invocation_timeout_s=0.05, invocation_retry_backoff_s=0.0))
    fi.arm(SITE_INVOCATION, mode="stall", delay_s=0.6)
    loop.submit(MQ1)
    loop.pump()                            # spawns the stalled run
    assert loop.invocation_in_flight
    time.sleep(0.1)                        # blow the 50ms watchdog budget
    loop.pump()                            # watchdog: abort + abandon
    s = loop.stats()
    assert s["watchdog_aborts"] == 1
    assert "TimeoutError" in s["invocation_error"]
    assert s["healthy"] == 0
    assert not loop.invocation_in_flight
    assert loop._zombies_active()
    # the zombie still reads the graph: ingest (and new invocations) wait,
    # but queries keep being answered on the old partition
    v0, n0 = g.version, g.n
    assert loop.submit_mutations(MutationBatch(
        add_vertex_labels=[0], add_edges=[(0, n0)])) is True
    loop.submit(MQ1)
    assert loop.pump() == 1
    assert g.version == v0                 # mutation deferred, not lost
    for _ in range(100):                   # zombie exits at its abort check
        if not loop._zombies_active():
            break
        time.sleep(0.02)
    assert not loop._zombies_active()
    loop.pump()                            # deferred ingest now applies
    assert g.version == v0 + 1
    # drive one clean invocation so the abort was a blip, not an outage
    inv = loop.ot.invocations
    while loop.ot.invocations == inv:
        loop.submit(MQ1)
        loop.pump()
        loop._finish_inflight()
    assert loop.stats()["invocation_error"] == ""
    loop.stop()


def test_failed_invocation_leaves_dirty_bits_for_retry():
    """Satellite: an invocation that dies mid-run must not consume the
    dirty bits that triggered it — the next (clean) run retries them."""
    g = musicbrainz_like(300, seed=24)
    fi = FaultInjector()
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=_topology_policy(),
        config=ServeLoopConfig(
            micro_batch=4, overlap_invocations=True, faults=fi,
            invocation_retry_backoff_s=0.0))
    # seed some workload so begin_invocation has something to fit
    loop.submit(MQ1)
    loop.pump()
    assert loop.submit_mutations(MutationBatch(
        add_vertex_labels=[0], add_edges=[(0, g.n)])) is True
    fi.arm(SITE_INVOCATION, times=1)
    loop.submit(MQ1)
    loop.pump()                            # applies ingest, spawns the run
    dirty_before = int(loop.ot._dirty.sum())
    assert dirty_before > 0
    assert loop._invocation_done.wait(5.0)
    loop.pump()                            # reaps the failed run
    s = loop.stats()
    assert "InjectedFault" in s["invocation_error"]
    assert s["healthy"] == 0
    assert int(loop.ot._dirty.sum()) == dirty_before   # unconsumed: retry
    inv = loop.ot.invocations
    while loop.ot.invocations == inv:      # clean retry consumes them
        loop.submit(MQ1)
        loop.pump()
        loop._finish_inflight()
    assert int(loop.ot._dirty.sum()) == 0
    assert loop.stats()["invocation_error"] == ""
    loop.stop()


# ---------------------------------------------------------------------------
# poisoned ingest group
# ---------------------------------------------------------------------------


def test_poisoned_ingest_group_falls_back_to_member_batches(tmp_path):
    g = musicbrainz_like(300, seed=25)
    fi = FaultInjector()
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2),
        policy=_quiet_policy(),
        config=ServeLoopConfig(micro_batch=4, overlap_invocations=False,
                               faults=fi, snapshot_dir=str(tmp_path)))
    loop.snapshot(sync=True)
    v0, n0 = g.version, g.n
    fi.arm(SITE_INGEST_GROUP, times=1)
    for i in range(3):
        assert loop.submit_mutations(MutationBatch(
            add_vertex_labels=[i], add_edges=[(i, n0 + i)])) is True
    loop.pump()
    # the poisoned merged fold fell back to per-member application: every
    # batch landed (3 version bumps instead of 1), none were dropped
    assert g.version == v0 + 3
    assert loop.ingest.failed == 0
    assert fi.fired_total() == 1
    assert loop.stats()["failed_mutations"] == 0
    # recovery parity across the poisoned group: the outcome record makes
    # replay reproduce the per-member bumps (the fault is not re-raised)
    restored = ServingLoop.restore(
        tmp_path, taper_config=TaperConfig(max_iterations=2),
        policy=_quiet_policy(),
        config=ServeLoopConfig(micro_batch=4, overlap_invocations=False))
    assert restored.restore_result.replayed == 3
    assert restored.g.version == g.version
    assert restored.g.n == g.n
    assert np.array_equal(restored.g.src, g.src)
    assert np.array_equal(restored.ot._dirty, loop.ot._dirty)
    log_live = g.mutation_log
    log_back = restored.g.mutation_log
    assert [r.version for r in log_back] == [r.version for r in log_live]
    loop.stop()


# ---------------------------------------------------------------------------
# shard-upload failure
# ---------------------------------------------------------------------------


def test_shard_upload_fault_survivable_then_degrades():
    pytest.importorskip("jax")
    g = musicbrainz_like(300, seed=26)
    fi = FaultInjector()
    loop = ServingLoop(
        g, 4, taper_config=TaperConfig(max_iterations=2,
                                       field_backend="pallas_sharded"),
        policy=_quiet_policy(),
        config=ServeLoopConfig(
            micro_batch=4, overlap_invocations=False, faults=fi,
            invocation_retry_backoff_s=0.0, backend_fallback_after=2))
    fi.arm(SITE_SHARD_UPLOAD, times=1)
    v0, n0 = g.version, g.n
    assert loop.submit_mutations(MutationBatch(
        add_vertex_labels=[0], add_edges=[(1, n0)])) is True
    loop.pump()
    s = loop.stats()
    # the upload died but the mutation applied and serving continues on the
    # previous device buffers — survivable, one failure below the threshold
    assert g.version == v0 + 1
    assert s["upload_failures"] == 1
    assert s["degraded"] == 0
    loop.submit(MQ1)
    assert loop.pump() == 1
    # a second consecutive upload failure crosses the ladder threshold
    fi.arm(SITE_SHARD_UPLOAD, times=1)
    assert loop.submit_mutations(MutationBatch(
        add_vertex_labels=[0], add_edges=[(2, n0 + 1)])) is True
    loop.pump()
    s = loop.stats()
    assert s["upload_failures"] == 2
    assert s["backend_fallbacks"] == 1
    assert s["field_backend"] == "pallas" and s["degraded"] == 1
    loop.submit(MQ1)
    assert loop.pump() == 1                # still answering queries
    loop.stop()
