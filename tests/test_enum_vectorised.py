"""Parity suite for the batched frontier path enumerator (PR 7).

``QueryExecutor.enumerate_paths`` / ``enumerate_paths_many`` must be
bit-identical — paths, emission order, ipt — to the recursive DFS oracle
``enumerate_paths_ref`` on every graph, query and truncation boundary, and
the multi-worker serving loop must return the same per-request results as
the single-worker one.
"""
import threading

import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.graphs.generators import (
    musicbrainz_like,
    paper_example_graph,
    power_law_labelled,
    provgen_like,
)
from repro.graphs.graph import MutationBatch
from repro.workload.executor import QueryExecutor

MB_QUERIES = [
    "Area.Artist.(Artist|Label).Area",
    "Artist.Credit.(Track|Recording).Credit.Artist",
    "Artist.Credit.Track.Medium",
]
PG_QUERIES = [
    "Entity.(Entity)*.Entity",
    "Agent.Activity.Entity.Entity.Activity.Agent",
    "(Entity)*.Activity.Entity",
    "Entity.Activity.(Agent)*",
]
# generic shapes over the L0..L{k-1} alphabet of power_law_labelled
PL_QUERIES = [
    "L0.L1",
    "L1.(L0|L2).L1",
    "(L0)*.L1",
    "L2.(L1)*",
    "L0.(L1|L2|L3).(L0|L1).L2",
    "(L3)*",
]


def _assert_parity(ex, q, max_results, part):
    ref = ex.enumerate_paths_ref(q, max_results, part)
    got = ex.enumerate_paths(q, max_results, part)
    assert got == ref, (q.to_text(), max_results)


@pytest.mark.parametrize("gname", ["mb", "pg", "pl"])
def test_parity_random_graphs(gname):
    rng = np.random.default_rng(0)
    if gname == "mb":
        g, texts = musicbrainz_like(1500, seed=5), MB_QUERIES
    elif gname == "pg":
        g, texts = provgen_like(1500, seed=5), PG_QUERIES
    else:
        g, texts = power_law_labelled(800, n_labels=4, seed=5), PL_QUERIES
    ex = QueryExecutor(g)
    part = rng.integers(0, 8, g.n)
    for text in texts:
        q = parse_rpq(text)
        for mr in (1, 7, 32, 10 ** 9):
            _assert_parity(ex, q, mr, part)


def test_parity_paper_graph():
    g = paper_example_graph()
    ex = QueryExecutor(g)
    part = np.zeros(g.n, dtype=np.int64)
    part[g.n // 2:] = 1
    for text in ("a.(b|c).(c|d)", "(c|a).c.a"):
        _assert_parity(ex, parse_rpq(text), 100, part)


def test_truncation_boundaries():
    g = power_law_labelled(600, n_labels=3, seed=1)
    ex = QueryExecutor(g)
    part = np.random.default_rng(1).integers(0, 4, g.n)
    q = parse_rpq("L0.(L1|L2).L0")
    full, _ = ex.enumerate_paths_ref(q, 10 ** 9, part)
    total = len(full)
    assert total > 2, "fixture query must have several matches"
    for mr in (0, 1, 2, total - 1, total, total + 1,
               QueryExecutor.ENUM_CHUNK0 - 1, QueryExecutor.ENUM_CHUNK0,
               QueryExecutor.ENUM_CHUNK0 + 1):
        _assert_parity(ex, q, mr, part)
    # a truncated result is exactly the prefix of the full enumeration
    got, _ = ex.enumerate_paths(q, min(5, total), part)
    assert got == full[:min(5, total)]


def test_kleene_star_at_star_max():
    g = power_law_labelled(400, n_labels=3, seed=2)
    for star_max in (1, 2, 3, 4):
        ex = QueryExecutor(g, star_max=star_max)
        part = np.random.default_rng(2).integers(0, 4, g.n)
        for text in ("(L0)*.L1", "L1.(L2)*", "(L0)*"):
            q = parse_rpq(text)
            _assert_parity(ex, q, 10 ** 9, part)
            paths, _ = ex.enumerate_paths(q, 10 ** 9, part)
            # star bounded at star_max: no match may exceed the plan width
            max_len = max((len(t) for t in ex._enum_plan(q).targets),
                          default=0)
            assert all(len(p) <= max_len for p in paths)


def test_many_matches_per_query_and_order():
    g = musicbrainz_like(1200, seed=7)
    ex = QueryExecutor(g)
    part = np.random.default_rng(7).integers(0, 8, g.n)
    queries = [parse_rpq(t) for t in MB_QUERIES]
    outs = ex.enumerate_paths_many(queries, 32, part)
    for q, out in zip(queries, outs):
        assert out == ex.enumerate_paths_ref(q, 32, part)


def test_duplicate_query_fanout_does_not_alias():
    g = musicbrainz_like(800, seed=3)
    ex = QueryExecutor(g)
    part = np.random.default_rng(3).integers(0, 4, g.n)
    q = parse_rpq(MB_QUERIES[0])
    batch = [q, parse_rpq(MB_QUERIES[1]), q, q]
    outs = ex.enumerate_paths_many(batch, 16, part)
    assert outs[0] == outs[2] == outs[3]
    # each duplicate position owns its list: serving tickets may consume
    # (mutate) their result without corrupting their siblings'
    ref = list(outs[2][0])
    outs[0][0].append(("sentinel",))
    assert outs[2][0] == ref and outs[3][0] == ref


def test_enum_counters_surface():
    g = musicbrainz_like(800, seed=4)
    ex = QueryExecutor(g)
    stats = {}
    ex.enumerate_paths_many([parse_rpq(t) for t in MB_QUERIES], 32,
                            np.zeros(g.n, np.int64), stats=stats)
    assert stats["enum_sweeps"] > 0
    assert stats["frontier_rows"] > 0
    assert ex.last_enum_stats == stats


def test_parity_survives_mutations():
    """The per-graph-version caches (starts, traversal DP) must follow
    topology and label mutations."""
    g = power_law_labelled(500, n_labels=3, seed=6)
    ex = QueryExecutor(g)
    part = np.random.default_rng(6).integers(0, 4, g.n)
    q = parse_rpq("L0.(L1|L2).L0")
    _assert_parity(ex, q, 10 ** 9, part)
    before = ex.enumerate_paths(q, 10 ** 9, part)
    rng = np.random.default_rng(8)
    edges = np.stack([rng.integers(0, g.n, 12), rng.integers(0, g.n, 12)],
                     axis=1)
    g.apply_mutations(MutationBatch(
        add_edges=edges, relabel=[(int(rng.integers(0, g.n)), 0)]))
    _assert_parity(ex, q, 10 ** 9, part)
    _assert_parity(ex, q, 5, part)


def test_plan_cache_is_lru():
    """A repeatedly-hit plan outlives PLAN_CACHE_LIMIT cold insertions."""
    g = power_law_labelled(200, n_labels=4, seed=9)
    ex = QueryExecutor(g)
    hot = parse_rpq("L0.L1")
    hot_plan = ex._enum_plan(hot)
    for i in range(ex.PLAN_CACHE_LIMIT + 16):
        # alternate cold inserts with hot hits: FIFO would evict the hot
        # plan once PLAN_CACHE_LIMIT cold queries passed through, LRU keeps
        # renewing it
        ex._enum_plan(parse_rpq("L0." * (i // 4 + 1) + f"L{i % 4}"))
        assert ex._enum_plan(hot) is hot_plan
    assert len(ex._plan_cache) <= ex.PLAN_CACHE_LIMIT


def test_plan_cache_evicts_cold():
    g = power_law_labelled(200, n_labels=4, seed=9)
    ex = QueryExecutor(g)
    cold = parse_rpq("L3.L3")
    cold_plan = ex._enum_plan(cold)
    for i in range(ex.PLAN_CACHE_LIMIT + 1):
        ex._enum_plan(parse_rpq("L0." * (i // 4 + 1) + f"L{i % 4}"))
    assert ex._enum_plan(cold) is not cold_plan


def test_executor_thread_safety_smoke():
    """Concurrent enumerate_paths_many over one executor: the plan cache is
    locked, the sweeps read-only — results must equal the serial oracle."""
    g = musicbrainz_like(800, seed=11)
    ex = QueryExecutor(g)
    part = np.random.default_rng(11).integers(0, 4, g.n)
    queries = [parse_rpq(t) for t in MB_QUERIES]
    expected = [ex.enumerate_paths_ref(q, 16, part) for q in queries]
    errors = []

    def worker():
        try:
            for _ in range(20):
                outs = ex.enumerate_paths_many(queries, 16, part)
                assert outs == expected
        except BaseException as exc:  # pragma: no cover
            errors.append(exc)

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors


def test_multi_worker_determinism():
    """Same request stream, no invocations/mutations: per-ticket results
    are identical whatever the worker count."""
    from repro.core.online import OnlinePolicy
    from repro.serve.loop import ServeLoopConfig, ServingLoop

    queries = [parse_rpq(MB_QUERIES[i % 3]) for i in range(120)]
    ref = None
    for n_workers in (1, 4):
        loop = ServingLoop(
            musicbrainz_like(1000, seed=13), k=4,
            policy=OnlinePolicy(cadence=10 ** 9,
                                bootstrap_after_ticks=10 ** 9),
            config=ServeLoopConfig(n_workers=n_workers, micro_batch=8),
        ).start()
        tickets = [loop.submit(q) for q in queries]
        assert all(t.accepted for t in tickets)
        for t in tickets:
            assert t.wait(30)
        stats = loop.stop()
        results = [(t.paths, t.ipt) for t in tickets]
        if ref is None:
            ref = results
        else:
            assert results == ref
        assert stats["completed"] == len(queries)
        if n_workers > 1:
            assert stats["workers_reporting"] >= 1
        assert stats["enum_sweeps"] > 0


def test_multi_worker_with_mutations_and_commit():
    """Secondaries keep serving across ingest patches and an invocation
    commit; every ticket completes and the loop stays healthy."""
    from repro.core.online import OnlinePolicy
    from repro.serve.loop import ServeLoopConfig, ServingLoop

    g = musicbrainz_like(800, seed=17)
    loop = ServingLoop(
        g, k=4,
        policy=OnlinePolicy(cadence=5, min_interval=0,
                            bootstrap_after_ticks=0),
        config=ServeLoopConfig(n_workers=3, micro_batch=8),
    ).start()
    rng = np.random.default_rng(17)
    tickets = []
    for i in range(300):
        t = loop.submit(parse_rpq(MB_QUERIES[int(rng.integers(0, 3))]))
        if t.accepted:
            tickets.append(t)
        if i % 40 == 0:
            u, v = int(rng.integers(0, g.n)), int(rng.integers(0, g.n))
            loop.submit_mutations(MutationBatch(add_edges=[(u, v)]))
    for t in tickets:
        assert t.wait(60)
    stats = loop.stop()
    assert stats["invocations"] >= 1
    assert stats["healthy"] == 1
    assert stats["completed"] == len(tickets)


# -- hypothesis twin ----------------------------------------------------------
# Guarded (not importorskip at module level) so the deterministic parity
# suite above still runs where hypothesis is absent.
try:
    from hypothesis import HealthCheck, given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @st.composite
    def _graph_and_query(draw):
        n_labels = draw(st.integers(min_value=1, max_value=5))
        n = draw(st.integers(min_value=2, max_value=60))
        seed = draw(st.integers(min_value=0, max_value=2 ** 16))
        depth = draw(st.integers(min_value=1, max_value=4))
        parts = []
        for _ in range(depth):
            kind = draw(st.sampled_from(["label", "union", "star"]))
            a = draw(st.integers(min_value=0, max_value=n_labels - 1))
            b = draw(st.integers(min_value=0, max_value=n_labels - 1))
            if kind == "label":
                parts.append(f"L{a}")
            elif kind == "union":
                parts.append(f"(L{a}|L{b})")
            else:
                parts.append(f"(L{a})*")
        return n, n_labels, seed, ".".join(parts)

    @settings(max_examples=40, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(_graph_and_query(), st.integers(min_value=0, max_value=64))
    def test_hypothesis_parity_random_alphabets(gq, max_results):
        n, n_labels, seed, text = gq
        g = power_law_labelled(n, n_labels=n_labels, avg_degree=4.0,
                               seed=seed)
        ex = QueryExecutor(g)
        part = np.random.default_rng(seed).integers(0, 3, g.n)
        q = parse_rpq(text)
        assert ex.enumerate_paths(q, max_results, part) == \
            ex.enumerate_paths_ref(q, max_results, part)
