"""TPSTry construction and probability tests against the paper's §4.1
worked example (Fig. 3 / Fig. 4)."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.core.tpstry import TPSTry


def test_paper_trie_probabilities(paper_trie):
    """Exact numbers from §4.1 and Fig. 4(right)."""
    t = paper_trie
    assert t.prob_of_path(["a"]) == pytest.approx(0.75)        # Pr(E->a) worked example
    assert t.prob_of_path(["c"]) == pytest.approx(0.25)
    assert t.prob_of_path(["a", "b"]) == pytest.approx(0.25)   # Pr(E->a->b)=.25...

    # Fig. 4: p(ab)=0.25? §4.1 computes Pr(E->a->b) = 0.25
    assert t.prob_of_path(["a", "c"]) == pytest.approx(0.5)
    assert t.prob_of_path(["c", "c"]) == pytest.approx(0.25)
    assert t.prob_of_path(["a", "b", "c"]) == pytest.approx(0.125)
    assert t.prob_of_path(["a", "b", "d"]) == pytest.approx(0.125)
    assert t.prob_of_path(["a", "c", "c"]) == pytest.approx(0.125)
    assert t.prob_of_path(["a", "c", "d"]) == pytest.approx(0.125)
    assert t.prob_of_path(["a", "c", "a"]) == pytest.approx(0.25)
    assert t.prob_of_path(["c", "c", "a"]) == pytest.approx(0.25)


def test_trie_structure(paper_trie):
    # Fig 3(b): merged trie with nodes for both queries
    t = paper_trie
    assert t.node_by_path(["a"]) is not None
    assert t.node_by_path(["c", "c", "a"]) is not None
    assert t.node_by_path(["b"]) is None
    assert t.max_depth == 3
    # node 'a' and 'ac' are labelled with both queries (paper fn. 4)
    q1, q2 = parse_rpq("a.(b|c).(c|d)"), parse_rpq("(c|a).c.a")
    assert t.node_by_path(["a"]).queries == {q1.qhash, q2.qhash}
    assert t.node_by_path(["a", "c"]).queries == {q1.qhash, q2.qhash}
    assert t.node_by_path(["a", "b"]).queries == {q1.qhash}


def test_frequency_zero_removes_query(paper_workload):
    """§4: an expression with frequency 0 has its labels (and orphaned
    nodes) removed and is treated as new in future."""
    trie = TPSTry.from_workload(paper_workload)
    n_before = trie.n_nodes
    (q1, _), (q2, _) = paper_workload
    trie.set_frequencies({q1.qhash: 1.0, q2.qhash: 0.0})
    assert trie.node_by_path(["c", "c"]) is None        # only Q2 used cc
    assert trie.node_by_path(["a", "c", "a"]) is None   # only Q2 used aca
    assert trie.node_by_path(["a", "b"]) is not None
    assert trie.n_nodes < n_before
    # with Q1 alone its conditionals renormalise
    assert trie.prob_of_path(["a"]) == pytest.approx(1.0)
    assert trie.prob_of_path(["a", "b"]) == pytest.approx(0.5)


def test_right_stochastic_children(paper_trie):
    """Children of any node sum to at most the node's probability (the
    shortfall is termination mass)."""
    t = paper_trie
    for node in t.nodes:
        p_children = sum(t.nodes[c].p for c in node.children.values())
        p_self = node.p if node.node_id != 0 else 1.0
        assert p_children <= p_self + 1e-9


def test_compile_arrays(paper_trie, paper_graph):
    arrays = paper_trie.compile(paper_graph.label_names)
    assert arrays.n_nodes == paper_trie.n_nodes
    assert arrays.max_depth == 3
    # depth ordering: parents precede children
    assert all(arrays.parent[i] < i for i in range(1, arrays.n_nodes))
    # cond_p of depth-1 node == p
    d1 = [i for i in range(arrays.n_nodes) if arrays.depth[i] == 1]
    np.testing.assert_allclose(arrays.cond_p[d1], arrays.p[d1], rtol=1e-6)


def test_compile_drops_unknown_symbols(paper_workload):
    trie = TPSTry.from_workload(paper_workload)
    arrays = trie.compile(["a", "b", "c"])  # no 'd' in this graph
    # abd / acd subtrees dropped
    assert arrays.n_nodes == trie.n_nodes - 2


def test_snapshot_change_detection(paper_workload):
    trie = TPSTry.from_workload(paper_workload)
    trie.snapshot()
    assert not trie.changed_since_snapshot().any()
    (q1, _), (q2, _) = paper_workload
    trie.set_frequencies({q1.qhash: 0.9, q2.qhash: 0.1})
    changed = trie.changed_since_snapshot()
    assert changed.any()
