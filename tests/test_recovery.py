"""Crash-safe serving: WAL framing/torn tails, atomic snapshots with
corruption fallback, kill-and-restore bitwise parity over a mixed
request+mutation stream, and elastic restore onto a different shard count."""
import numpy as np
import pytest

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.graphs.sharded_packing import partition_shard_order, shard_assignment
from repro.serve import ServeLoopConfig, ServingLoop
from repro.serve.faults import corrupt_latest_snapshot
from repro.serve.snapshot import (
    MutationJournal,
    ServingSnapshotter,
    capture_serving_state,
    load_serving_snapshot,
    plan_elastic_restore,
    restore_serving_state,
)
from repro.workload.sketch import FrequencySketch

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


def _policy():
    # triggers driven only by persisted state (tick cadence + dirty
    # fraction), so a restored node re-decides invocations exactly like the
    # uninterrupted one — ipt regression depends on an unreplayed EWMA
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=6, min_interval=0,
                        dirty_fraction=0.02, drift_l1=9e9,
                        ipt_regression=9e9)


def _loop(g, tmp=None, **cfg_kw):
    cfg = ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                          snapshot_dir=None if tmp is None else str(tmp),
                          **cfg_kw)
    return ServingLoop(g, 4, taper_config=TaperConfig(max_iterations=2),
                       policy=_policy(), config=cfg)


def _stream(n0, steps=30, seed=0):
    """Deterministic mixed request+mutation op stream."""
    rng = np.random.default_rng(seed)
    ops, n = [], n0
    for i in range(steps):
        ops.append(("req", MQ1 if i % 3 else MQ3))
        r = rng.random()
        if r < 0.3:
            ops.append(("mut", MutationBatch(
                add_vertex_labels=[int(rng.integers(0, 4))],
                add_edges=[(int(rng.integers(0, n)), n)])))
            n += 1
        elif r < 0.5:
            ops.append(("mut", MutationBatch(
                add_edges=[(int(rng.integers(0, n0)),
                            int(rng.integers(0, n0)))])))
        ops.append(("pump",))
    return ops


def _drive(loop, ops):
    for op in ops:
        if op[0] == "req":
            loop.submit(op[1])
        elif op[0] == "mut":
            assert loop.submit_mutations(op[1]) is True
        else:
            loop.pump()


def _assert_durable_parity(a, b):
    """Bitwise equality of everything snapshot+WAL-replay guarantees at an
    *arbitrary* kill point: graph arrays and version spans, partition,
    dirty bits, swap-RNG state, invocation counters (every commit
    snapshots), executor-DP results and the sharded-packing fold.  Request
    side-state (tick, sketch) has snapshot granularity — see
    :func:`_assert_full_parity`."""
    assert a.g.n == b.g.n and a.g.version == b.g.version
    for x, y in [(a.g.labels, b.g.labels), (a.g.src, b.g.src),
                 (a.g.dst, b.g.dst), (a.g.row_ptr, b.g.row_ptr),
                 (a.part, b.part), (a.ot._dirty, b.ot._dirty)]:
        assert np.array_equal(x, y)
    la, lb = a.g.mutation_log, b.g.mutation_log
    assert len(la) == len(lb)
    for ra, rb in zip(la, lb):
        assert (ra.version, ra.version_base, ra.n_before, ra.n_after) == \
            (rb.version, rb.version_base, rb.n_before, rb.n_after)
        assert np.array_equal(ra.added_src, rb.added_src)
        assert np.array_equal(ra.old2new, rb.old2new)
    assert a.ot.invocations == b.ot.invocations
    assert a.ot._freqs_at_invoke == b.ot._freqs_at_invoke
    assert a.ot.taper._rng.bit_generator.state == \
        b.ot.taper._rng.bit_generator.state
    # executor-DP state: identical enumeration (paths AND ipt accounting)
    for q in (MQ1, MQ3):
        ra = a.executor.enumerate_paths(q, max_results=16, part=a.part)
        rb = b.executor.enumerate_paths(q, max_results=16, part=b.part)
        assert ra == rb
    # sharded-packing state: the same fold from the same partition
    cnt_a = a.g.cached_neighbor_label_counts()
    cnt_b = b.g.cached_neighbor_label_counts()
    assert np.array_equal(cnt_a, cnt_b)
    order_a = partition_shard_order(a.part, 2)
    order_b = partition_shard_order(b.part, 2)
    assert np.array_equal(order_a, order_b)
    pa = a.g.vm_packing_sharded(2, cnt=cnt_a, order=order_a, order_token="t")
    pb = b.g.vm_packing_sharded(2, cnt=cnt_b, order=order_b, order_token="t")
    for fa, fb in [(pa.pos_of, pb.pos_of), (pa.src_global, pb.src_global),
                   (pa.dst_global, pb.dst_global), (pa.meta, pb.meta)]:
        assert np.array_equal(fa, fb)


def _assert_full_parity(a, b):
    """Durable parity plus the request-side state (policy tick clock and
    decayed workload sketch) — holds when the kill lands on a snapshot."""
    _assert_durable_parity(a, b)
    assert a.ot.tick == b.ot.tick
    assert a.ot.sketch.counts == b.ot.sketch.counts
    assert a.ot.sketch._stamp == b.ot.sketch._stamp
    assert a.ot.sketch._ticks == b.ot.sketch._ticks


# ---------------------------------------------------------------------------
# WAL framing
# ---------------------------------------------------------------------------


def test_journal_group_roundtrip_and_outcomes(tmp_path):
    j = MutationJournal(tmp_path / "wal.log")
    m1 = [MutationBatch(add_edges=[(0, 1)]),
          MutationBatch(add_vertex_labels=[2], add_edges=[(3, 10)])]
    m2 = [MutationBatch(remove_vertices=[5], relabel=[(1, 3)])]
    s1 = j.append_group(m1)
    j.append_outcome(s1, "merged", [True, True])
    s2 = j.append_group(m2)
    j.append_outcome(s2, "members", [True])
    assert (s1, s2) == (1, 2)
    out = j.replay()
    assert [seq for seq, _, _ in out] == [1, 2]
    seq, members, outcome = out[0]
    assert len(members) == 2
    assert np.array_equal(members[0].add_edges, [[0, 1]])
    assert np.array_equal(members[1].add_vertex_labels, [2])
    assert outcome == {"mode": "merged", "applied": [True, True]}
    assert out[1][2]["mode"] == "members"
    # after_seq filters whole groups
    assert [seq for seq, _, _ in j.replay(after_seq=1)] == [2]
    j.close()
    # persistence across re-open, and last_seq continues monotone
    j2 = MutationJournal(tmp_path / "wal.log")
    assert j2.last_seq == 2
    assert len(j2.replay()) == 2


def test_journal_torn_tail_is_truncated_and_replay_survives(tmp_path):
    path = tmp_path / "wal.log"
    j = MutationJournal(path)
    j.append_group([MutationBatch(add_edges=[(0, 1)])])
    j.append_group([MutationBatch(add_edges=[(1, 2)])])
    j.close()
    size = path.stat().st_size
    with open(path, "r+b") as fh:          # crash mid-append: half a frame
        fh.truncate(size - 7)
    j2 = MutationJournal(path)             # re-open truncates the torn tail
    assert path.stat().st_size < size - 7 or j2.last_seq == 1
    out = j2.replay()
    assert [seq for seq, _, _ in out] == [1]
    # appends after the truncation stay readable
    j2.append_group([MutationBatch(add_edges=[(2, 3)])])
    assert [seq for seq, _, _ in j2.replay()] == [1, 2]


def test_journal_compaction_drops_covered_groups(tmp_path):
    j = MutationJournal(tmp_path / "wal.log")
    for i in range(4):
        s = j.append_group([MutationBatch(add_edges=[(i, i + 1)])])
        j.append_outcome(s, "merged", [True])
    dropped = j.compact(2)
    assert dropped == 4                    # 2 groups + their 2 outcomes
    assert [seq for seq, _, _ in j.replay()] == [3, 4]
    assert j.last_seq == 4                 # seq numbering never rewinds
    assert j.append_group([MutationBatch(add_edges=[(9, 10)])]) == 5


# ---------------------------------------------------------------------------
# snapshotter
# ---------------------------------------------------------------------------


def test_snapshotter_keep_n_and_async_serialization(tmp_path):
    g = musicbrainz_like(300, seed=1)
    loop = _loop(g)
    snap = ServingSnapshotter(tmp_path, keep=2)
    for _ in range(4):
        # async saves back to back: each save joins the previous writer, so
        # pruning never interleaves with an in-flight publish
        snap.save(capture_serving_state(loop.ot, 0), sync=False)
    snap.close()
    assert snap.saved == 4 and snap.failures == 0
    assert snap.all_ids() == [3, 4]
    manifest, arrays = load_serving_snapshot(tmp_path)
    assert manifest["snap_id"] == 4
    assert arrays["part"].shape == (g.n,)


def test_corrupt_snapshot_falls_back_to_older(tmp_path):
    g = musicbrainz_like(300, seed=2)
    loop = _loop(g)
    snap = ServingSnapshotter(tmp_path, keep=3)
    snap.save(capture_serving_state(loop.ot, 0))
    g.apply_mutations(MutationBatch(add_edges=[(0, 5)]))
    snap.save(capture_serving_state(loop.ot, 1))
    corrupt_latest_snapshot(tmp_path)
    manifest, _ = load_serving_snapshot(tmp_path)
    assert manifest["snap_id"] == 1        # checksum caught the damage
    assert manifest["journal_seq"] == 0
    with pytest.raises(FileNotFoundError):
        load_serving_snapshot(tmp_path, snap_id=2)


# ---------------------------------------------------------------------------
# kill-and-restore parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cut", [17, 41])
def test_kill_and_restore_bitwise_parity(tmp_path, cut):
    """Kill at an arbitrary point in a mixed request+mutation stream: the
    restored node must be bitwise-identical to the crashed one on every
    durable component — graph, partition, executor-DP, sharded-packing
    fold — via latest snapshot + WAL replay."""
    g = musicbrainz_like(400, seed=7)
    ops = _stream(g.n, steps=25, seed=3)

    crash = _loop(g, tmp=tmp_path)
    crash.snapshot(sync=True)              # a snapshot exists from t=0
    _drive(crash, ops[:cut])
    crash._snapshotter.wait()              # "kill": no stop(), no drain

    restored = ServingLoop.restore(
        tmp_path, taper_config=TaperConfig(max_iterations=2),
        policy=_policy(),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False))
    _assert_durable_parity(restored, crash)
    assert restored.restore_result.replay_failed == 0
    assert restored.stats()["journal_seq"] == crash.stats()["journal_seq"]


def test_kill_on_snapshot_full_parity_and_continuation(tmp_path):
    """When the kill lands on a snapshot boundary the *entire* serving
    state (including the policy tick clock and workload sketch) comes
    back, so continuing the stream lands bitwise exactly where the
    never-crashed node does."""
    g_ref = musicbrainz_like(400, seed=7)
    g_crash = g_ref.copy()
    ops = _stream(g_ref.n, steps=25, seed=3)
    # cut right after a pump: in-queue requests are deliberately NOT
    # durable, so a boundary where both queues are drained is the point
    # where full-state continuation parity is the contract
    cut = [i + 1 for i, op in enumerate(ops) if op[0] == "pump"][10]

    ref = _loop(g_ref)
    _drive(ref, ops)

    crash = _loop(g_crash, tmp=tmp_path)
    _drive(crash, ops[:cut])
    crash.snapshot(sync=True)              # the last durable point == kill
    crash._snapshotter.wait()

    restored = ServingLoop.restore(
        tmp_path, taper_config=TaperConfig(max_iterations=2),
        policy=_policy(),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False))
    _assert_full_parity(restored, crash)

    _drive(restored, ops[cut:])
    _assert_full_parity(restored, ref)


def test_restore_after_corruption_replays_longer_tail(tmp_path):
    """Corrupting the newest snapshot degrades recovery to the previous one
    plus a longer WAL replay — same final state."""
    g = musicbrainz_like(400, seed=9)
    ops = _stream(g.n, steps=20, seed=5)
    live = _loop(g, tmp=tmp_path)
    live.snapshot(sync=True)
    _drive(live, ops)
    live.snapshot(sync=True)
    newest = live._snapshotter.latest_id()
    corrupt_latest_snapshot(tmp_path)
    restored = ServingLoop.restore(
        tmp_path, taper_config=TaperConfig(max_iterations=2),
        policy=_policy(),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False))
    assert restored.restore_result.snap_id < newest
    _assert_durable_parity(restored, live)


# ---------------------------------------------------------------------------
# elastic restore
# ---------------------------------------------------------------------------


def test_elastic_restore_onto_different_shard_count(tmp_path):
    pytest.importorskip("jax")
    # >= 10 blocks of 128, so the block-padded per-shard spans (and hence
    # the shard assignments) genuinely differ between the old and new S
    g = musicbrainz_like(1200, seed=11)
    loop = ServingLoop(
        g, 4,
        taper_config=TaperConfig(max_iterations=2,
                                 field_backend="pallas_sharded",
                                 shard_map_source="partition"),
        policy=_policy(),
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                               snapshot_dir=str(tmp_path)))
    for _ in range(10):
        loop.submit(MQ1)
        loop.pump()
    assert loop.ot.invocations >= 1
    assert "_shard_order" in loop.ot.taper._pre
    loop.snapshot(sync=True)
    live_part = loop.part.copy()
    # the live shard count follows the device mesh (1 in plain tier-1,
    # 8 in the forced-host CI matrix entry) — restore onto a different S
    live_shards = loop.ot.taper._mesh_shards()
    new_s = 3 if live_shards == 4 else 4

    res = restore_serving_state(
        tmp_path, n_shards=new_s,
        taper_config=TaperConfig(max_iterations=2, field_backend="jnp",
                                 shard_map_source="partition"))
    # the shard map was re-folded with the movement-aware k->S fold
    token, pos = res.ot.taper._pre["_shard_order"]
    assert "restore" in token
    assert np.array_equal(pos, partition_shard_order(live_part, new_s))
    # byte-movement budget follows train.elastic's reshard-plan schema
    plan = res.elastic_plan
    assert plan is not None
    assert plan["old_chips"] == live_shards and plan["new_chips"] == new_s
    assert plan["total_state_bytes"] > 0
    assert 0 < plan["est_transfer_bytes"] <= plan["total_state_bytes"]
    assert 0.0 < plan["moved_frac"] <= 1.0
    # the restored packing at the new S is bitwise the scratch packing
    cnt = res.ot.g.cached_neighbor_label_counts()
    restored_sp = res.ot.g.vm_packing_sharded(
        new_s, cnt=cnt, order=pos, order_token=token)
    scratch_sp = g.vm_packing_sharded(
        new_s, cnt=g.cached_neighbor_label_counts(),
        order=partition_shard_order(live_part, new_s), order_token="scratch")
    for fa, fb in [(restored_sp.pos_of, scratch_sp.pos_of),
                   (restored_sp.src_global, scratch_sp.src_global),
                   (restored_sp.dst_global, scratch_sp.dst_global),
                   (restored_sp.meta, scratch_sp.meta)]:
        assert np.array_equal(fa, fb)
    loop.stop()


def test_plan_elastic_restore_counts_moved_state():
    g = musicbrainz_like(400, seed=13)
    part = np.arange(g.n, dtype=np.int32) % 4
    plan = plan_elastic_restore(g, part, old_shards=2, new_shards=4)
    moved = shard_assignment(part, 2) != shard_assignment(part, 4)
    assert plan["moved_vertices"] == int(moved.sum())
    assert plan["bytes_per_new_chip"] * 4 >= plan["total_state_bytes"]
    # same S: nothing moves, transfer estimate collapses to zero
    same = plan_elastic_restore(g, part, old_shards=2, new_shards=2)
    assert same["moved_vertices"] == 0 and same["est_transfer_bytes"] == 0


# ---------------------------------------------------------------------------
# sketch persistence
# ---------------------------------------------------------------------------


def test_sketch_state_roundtrip_preserves_decay_clock():
    sk = FrequencySketch(half_life=8.0)
    for i in range(6):
        sk.observe_batch([MQ1] * 3 + [MQ3] * (i % 2))
    state = sk.state_dict()
    back = FrequencySketch.from_state(state)
    assert back._ticks == sk._ticks
    assert back.counts == sk.counts
    assert back._stamp == sk._stamp
    assert back.frequencies() == sk.frequencies()
    # the query ASTs survive via text round-trip: hashes still line up
    for qh, q in back.queries.items():
        assert q.qhash == qh
    assert [q for q, _ in back.workload()] and \
        {q.qhash for q, _ in back.workload()} == \
        {q.qhash for q, _ in sk.workload()}
