"""Cell-plan construction smoke: every (arch x shape) build plan resolves
specs/shardings on a local mesh (the 512-device compile matrix itself is
exercised by launch/dryrun.py; this guards the plan-building layer in CI)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import list_archs, shapes_for
from repro.launch.mesh import make_smoke_mesh
from repro.launch.specs import all_cells, build_cell


@pytest.fixture(scope="module")
def mesh():
    return make_smoke_mesh()


@pytest.mark.parametrize("arch,shape", all_cells())
def test_build_cell(arch, shape, mesh):
    plan = build_cell(arch, shape, mesh)
    assert plan.step_fn is not None
    assert plan.meta.get("model_flops", 0) > 0
    # args and shardings are structurally consistent
    flat_args = jax.tree.leaves(plan.args)
    assert all(isinstance(a, jax.ShapeDtypeStruct) for a in flat_args)
    flat_shard = jax.tree.leaves(
        plan.in_shardings,
        is_leaf=lambda x: hasattr(x, "spec"))
    assert len(flat_shard) >= 1


def test_all_cells_count():
    cells = all_cells()
    # 5 LM archs (4 shapes each, minus 4 long_500k skips) + 4 GNN x 4
    # + dlrm x 4 + taper_paper x 1 = 16 + 16 + 4 + 1 = 37
    assert len(cells) == 37


def test_long_context_only_for_hybrid():
    assert ("gemma3-4b", "long_500k") in all_cells()
    for arch in ("qwen2.5-14b", "qwen3-4b", "olmoe-1b-7b", "kimi-k2-1t-a32b"):
        assert (arch, "long_500k") not in all_cells()
