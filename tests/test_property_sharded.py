"""Hypothesis property suite for topology-aware shard maps (PR 5).

Three families of randomized invariants:

* **random shard maps** — the sharded extroversion field matches the jnp
  oracle bit-for-tolerance under *arbitrary* vertex permutations, on both
  exchange backends (the permutation threads through packing, frontier,
  slot tables and the inverse gather);
* **mutations against a permuted packing** — random ``MutationBatch``
  sequences patch a permuted packing to exactly the state a scratch
  rebuild (same shard map) produces, and both source maps keep decoding to
  the true global source of every slot;
* **k != S partition folding** — ``partition_shard_order`` stays a
  permutation that keeps every partition's positions contiguous for any
  (k, n_shards) combination.

The deterministic seeded twins live in tests/test_sharded_field.py.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.rpq import parse_rpq
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import power_law_labelled
from repro.graphs.graph import MutationBatch
from repro.graphs.sharded_packing import (
    build_sharded_vm_packing,
    partition_shard_order,
)
from test_dynamic_graph import _random_batch  # sibling (pytest sys.path)

SET = settings(
    max_examples=10,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

FIELDS = ("alpha", "pr", "edge_mass", "extro_mass", "extroversion", "ext_to")


def _decode_checks(sp, g):
    """Both source maps of every shard decode to the true global source."""
    raw = sp.slot_raw.reshape(-1)
    real = raw >= 0
    assert int(real.sum()) == g.m
    assert np.array_equal(np.sort(raw[real]), np.arange(g.m))
    hot2pos = np.zeros(max(sp.n_hot, 1), np.int64)
    live_hot = sp.fr_hot_pos[: sp.n_frontier]
    hot2pos[live_hot[live_hot >= 0]] = \
        sp.frontier[: sp.n_frontier][live_hot >= 0]
    rb = sp.round_base
    for s in range(sp.n_shards):
        r = sp.slot_raw[s] >= 0
        truth = sp.src_global[s][r]
        # destinations are wholly shard-owned in position space
        assert (sp.pos_of[sp.dst_global[s][r]] // sp.n_local_pad == s).all()
        # psum map: [local | union frontier]
        m_ = sp.src_map[s][r]
        own = m_ < sp.n_local_pad
        fidx = np.maximum(m_ - sp.n_local_pad, 0)
        dec = np.where(own, m_ + s * sp.n_local_pad, sp.frontier[fidx])
        assert np.array_equal(sp.vtx_at[dec], truth)
        # sliced map: [local | hot union | ring round slices]
        msl = sp.src_map_sliced[s][r]
        assert np.array_equal(own, msl < sp.n_local_pad)
        rel = np.maximum(msl - sp.n_local_pad, 0)
        is_hot = rel < sp.hot_pad
        cold = np.maximum(rel - sp.hot_pad, 0)
        rnd = np.minimum(np.searchsorted(rb[1:], cold, side="right"),
                         sp.n_shards - 1)
        slot = cold - rb[rnd]
        owner = (s - rnd) % sp.n_shards
        dec_cold = (sp.send_local[owner, s, np.minimum(slot, sp.pair_cap - 1)]
                    + owner * sp.n_local_pad)
        dec_hot = hot2pos[np.minimum(rel, max(sp.n_hot - 1, 0))]
        dec_sl = np.where(own, dec, np.where(is_hot, dec_hot, dec_cold))
        assert np.array_equal(sp.vtx_at[dec_sl], truth)


@st.composite
def graph_and_map(draw):
    n = draw(st.integers(80, 300))
    seed = draw(st.integers(0, 2**16))
    n_shards = draw(st.sampled_from([1, 2, 3, 5, 8]))
    kind = draw(st.sampled_from(["identity", "random", "partition"]))
    return n, seed, n_shards, kind


def _order_for(kind, g, n_shards, rng):
    if kind == "identity":
        return None
    if kind == "random":
        return rng.permutation(g.n).astype(np.int64)
    part = rng.integers(0, rng.integers(2, 13), g.n)
    return partition_shard_order(part, n_shards)


@given(graph_and_map())
@SET
def test_sharded_field_parity_random_shard_maps(scenario):
    n, seed, n_shards, kind = scenario
    g = power_law_labelled(n, n_labels=5, avg_degree=5.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    arrays = TPSTry.from_workload(
        [(parse_rpq("L0.L1.(L2|L3).L1"), 0.6),
         (parse_rpq("L1.L2.L0"), 0.4)]).compile(g.label_names)
    k = int(rng.integers(2, 7))
    part = rng.integers(0, k, g.n).astype(np.int32)
    ref = extroversion_field(g, arrays, part, k, backend="jnp")
    order = _order_for(kind, g, n_shards, rng)
    for exchange in ("sliced", "psum"):
        pre = ({} if order is None
               else {"_shard_order": (f"{kind}:0", order)})
        sh = extroversion_field(g, arrays, part, k, _precomputed=pre,
                                backend="pallas_sharded",
                                halo_exchange=exchange)
        for f in FIELDS:
            np.testing.assert_allclose(
                getattr(ref, f), getattr(sh, f), atol=2e-5, rtol=1e-4,
                err_msg=f"{kind}/{exchange}:{f}")


@st.composite
def mutation_scenario(draw):
    n = draw(st.integers(60, 220))
    seed = draw(st.integers(0, 2**16))
    n_shards = draw(st.sampled_from([2, 4, 8]))
    kind = draw(st.sampled_from(["random", "partition"]))
    specs = draw(st.lists(
        st.tuples(st.integers(0, 3), st.integers(0, 10), st.integers(0, 10),
                  st.booleans(), st.integers(0, 2)),
        min_size=1, max_size=3))
    return n, seed, n_shards, kind, specs


@given(mutation_scenario())
@SET
def test_random_mutations_against_permuted_packing(scenario):
    n, seed, n_shards, kind, specs = scenario
    g = power_law_labelled(n, n_labels=4, avg_degree=5.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    order = _order_for(kind, g, n_shards, rng)
    token = f"{kind}:0"
    sp = g.vm_packing_sharded(n_shards, block_n=32, block_e=64,
                              order=order, order_token=token)
    for nv, na, nr, drop_vertex, nrl in specs:
        rem_v = [int(rng.integers(0, g.n))] if drop_vertex else []
        g.apply_mutations(_random_batch(g, rng, nv, na, nr, rem_v, nrl=nrl))
        g.validate()
        sp2 = g.vm_packing_sharded(n_shards, block_n=32, block_e=64,
                                   order=order, order_token=token)
        assert sp2.version == g.version
        _decode_checks(sp2, g)
        # patched (when capacity held) or rebuilt — either way it must
        # agree with a scratch rebuild along the same (extended) shard map
        scratch = build_sharded_vm_packing(
            g, n_shards, g.cached_neighbor_label_counts(),
            block_n=32, block_e=64, order=sp2.pos_of, order_token=token)
        raw_a, raw_b = sp2.slot_raw.reshape(-1), scratch.slot_raw.reshape(-1)
        ok_a, ok_b = raw_a >= 0, raw_b >= 0
        oa, ob = np.argsort(raw_a[ok_a]), np.argsort(raw_b[ok_b])
        for nm in ("src_global", "dst_global", "dst_label", "inv_cnt"):
            va = getattr(sp2, nm).reshape(-1)[ok_a][oa]
            vb = getattr(scratch, nm).reshape(-1)[ok_b][ob]
            assert np.array_equal(va, vb), nm
        assert np.array_equal(sp2.vlabels, scratch.vlabels)
        # the patched frontier may keep stale (harmless) entries but must
        # cover every halo position the scratch packing needs
        assert set(scratch.frontier[: scratch.n_frontier]) <= set(
            sp2.frontier[: sp2.n_frontier])


@given(st.integers(1, 16), st.integers(1, 12), st.integers(0, 2**16),
       st.integers(50, 400))
@SET
def test_partition_fold_properties(k, n_shards, seed, n):
    rng = np.random.default_rng(seed)
    part = rng.integers(0, k, n)
    pos = partition_shard_order(part, n_shards)
    assert np.array_equal(np.sort(pos), np.arange(n))
    for p in range(k):
        ps = np.sort(pos[part == p])
        if ps.size:
            assert ps[-1] - ps[0] == ps.size - 1
