"""Cross-node observability: the failover drill must leave ONE trace
telling the whole story (primary-crash → fence → promotion under epoch 2 →
first answer) in causal order, follower applies must join shipped trace
ids, and the failover must auto-dump the flight recorder with the drill's
event sequence."""
import time

import numpy as np

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.graph import MutationBatch
from repro.graphs.generators import musicbrainz_like
from repro.obs import FlightRecorder, Observability
from repro.serve import (
    ClusterConfig,
    ClusterCoordinator,
    ServeLoopConfig,
    ServingLoop,
)

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


def _policy():
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=6, min_interval=0,
                        dirty_fraction=0.02, drift_l1=9e9,
                        ipt_regression=9e9)


def _cluster(tmp, obs, n_followers=2, **ck):
    g = musicbrainz_like(400, seed=7)
    cfg = ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                          snapshot_dir=str(tmp / "snap"), obs=obs)
    primary = ServingLoop(g, 4, taper_config=TaperConfig(max_iterations=2),
                          policy=_policy(), config=cfg)
    ck.setdefault("heartbeat_timeout_s", 9e9)
    ccfg = ClusterConfig(n_followers=n_followers, obs=obs, **ck)
    return ClusterCoordinator(primary, config=ccfg, policy=_policy(),
                              taper_config=TaperConfig(max_iterations=2))


def _drive(coord, rounds, seed=0):
    rng = np.random.default_rng(seed)
    n = coord.primary.g.n
    for i in range(rounds):
        coord.serve([MQ1 if i % 3 else MQ3], cls="hot")
        r = rng.random()
        if r < 0.4:
            coord.submit_mutations(MutationBatch(
                add_vertex_labels=[int(rng.integers(0, 4))],
                add_edges=[(int(rng.integers(0, n)), n)]))
            n += 1
        elif r < 0.6:
            coord.submit_mutations(MutationBatch(
                add_edges=[(int(rng.integers(0, 400)),
                            int(rng.integers(0, 400)))]))
        coord.pump()


def test_failover_drill_single_cross_node_trace(tmp_path):
    """The PR-8 drill, traced: crash the primary, promote, answer a read —
    and the tracer holds exactly one failover trace whose spans tell that
    story in causal order, including the follower-side commit apply that
    joined via the shipped frame's trace id."""
    obs = Observability(trace_sample_rate=1.0, node="cluster",
                        dump_dir=str(tmp_path / "flight"))
    coord = _cluster(tmp_path, obs, heartbeat_timeout_s=0.05)
    _drive(coord, rounds=18, seed=3)
    assert coord.primary.ot.invocations > 0  # the drill spans commits

    coord.crash_primary()
    time.sleep(0.06)
    coord.pump()
    assert coord.failovers == 1 and coord.hub.current_epoch == 2
    coord.serve([MQ3], cls="hot")  # the first post-failover answer

    roots = obs.tracer.spans(name="failover")
    assert len(roots) == 1, "the drill must open exactly ONE failover trace"
    tid = roots[0]["trace_id"]
    spans = obs.tracer.spans(tid)  # sorted by start time = causal order
    names = [s["name"] for s in spans]
    by_name = {s["name"]: s for s in spans}

    for expected in ("failover.primary-crash", "failover.fence",
                     "failover.promotion", "replica.commit",
                     "failover.first-answer"):
        assert expected in names, f"{expected} missing from {names}"
    assert names.index("failover.primary-crash") \
        < names.index("failover.fence") \
        < names.index("failover.promotion") \
        < names.index("failover.first-answer")
    # the promotion happened under the advanced epoch
    assert by_name["failover.fence"]["attrs"]["epoch"] == 2
    assert by_name["failover.promotion"]["attrs"]["epoch"] == 2
    assert by_name["failover.promotion"]["attrs"]["slot"] \
        == coord.primary_slot
    # every span is parented inside the one trace (no orphans)
    ids = {s["span_id"] for s in spans}
    root_id = roots[0]["span_id"]
    for s in spans:
        assert s["parent_id"] == 0 or s["parent_id"] in ids \
            or s["parent_id"] == root_id
    # a second serve does NOT open another first-answer span
    coord.serve([MQ1], cls="hot")
    assert len(obs.tracer.spans(tid, name="failover.first-answer")) == 1
    coord.stop()


def test_failover_auto_dumps_flight_recorder(tmp_path):
    """Failover triggers a flight-recorder dump whose event sequence
    matches the drill: heartbeat lapse, then promotion, then the dump."""
    obs = Observability(trace_sample_rate=1.0, node="cluster",
                        dump_dir=str(tmp_path / "flight"))
    coord = _cluster(tmp_path, obs, heartbeat_timeout_s=0.05)
    _drive(coord, rounds=6, seed=5)
    coord.crash_primary()
    time.sleep(0.06)
    coord.pump()
    assert coord.failovers == 1

    assert len(obs.recorder.dumps) == 1
    rows = FlightRecorder.load_jsonl(obs.recorder.dumps[0])
    kinds = [r["kind"] for r in rows]
    assert "heartbeat_lapse" in kinds and "promotion" in kinds
    assert kinds.index("heartbeat_lapse") < kinds.index("promotion")
    assert kinds[-1] == "dump_trigger" and rows[-1]["reason"] == "failover"
    lapse = next(r for r in rows if r["kind"] == "heartbeat_lapse")
    assert lapse["silent_s"] >= 0.05 and lapse["slot"] == 0
    promo = next(r for r in rows if r["kind"] == "promotion")
    assert promo["epoch"] == 2 and promo["slot"] == coord.primary_slot
    assert promo["demoted_slot"] == 0
    coord.stop()


def test_follower_applies_join_shipped_group_traces(tmp_path):
    """Every shipped ingest-group frame carries the originating trace id;
    the follower's apply span lands in the SAME trace as the primary's
    ingest.group span — one cross-node causal story per group."""
    obs = Observability(trace_sample_rate=1.0, node="cluster")
    coord = _cluster(tmp_path, obs, n_followers=1)
    for i in range(4):
        coord.submit_mutations(MutationBatch(add_edges=[(i, i + 1)]))
        coord.pump()
    groups = obs.tracer.spans(name="ingest.group")
    assert groups
    applies = obs.tracer.spans(name="replica.apply")
    assert applies
    group_tids = {s["trace_id"] for s in groups}
    for a in applies:
        assert a["trace_id"] in group_tids
        assert a["attrs"]["replica"] == "replica-1"
    # seq attrs line up: the follower applied the seqs the primary shipped
    assert {a["attrs"]["seq"] for a in applies} \
        <= {g["attrs"]["seq"] for g in groups}
    coord.stop()


def test_cluster_registry_collects_every_component(tmp_path):
    """One registry pull sees the loop, executor, hub, each follower, the
    router and the coordinator — and the export parses back."""
    from repro.obs import parse_prometheus_text

    obs = Observability(trace_sample_rate=1.0, node="cluster")
    coord = _cluster(tmp_path, obs, n_followers=2)
    _drive(coord, rounds=8, seed=1)
    got = obs.registry.collected()
    for prefix in ("serve_", "executor_", "hub_", "follower_1_",
                   "follower_2_", "router_", "cluster_"):
        assert any(k.startswith(prefix) for k in got), \
            f"no {prefix} keys in collected()"
    assert got["cluster_n_replicas"] == 3
    assert got["router_routed"] >= 8
    # per-SLO-class latency histograms populated by the router
    hot = obs.registry.histogram("router_latency_s", cls="hot")
    assert hot.count >= 8
    text = obs.registry.to_prometheus_text(include_collected=False)
    assert parse_prometheus_text(text).to_prometheus_text(
        include_collected=False) == text
    coord.stop()


def test_promoted_loop_takes_over_collector_slots(tmp_path):
    """After failover the promoted loop replaces the dead primary's
    ``serve``/``executor`` collectors and the promoted slot's follower
    collector is retired — the registry keeps exporting live numbers."""
    obs = Observability(trace_sample_rate=1.0, node="cluster")
    coord = _cluster(tmp_path, obs, heartbeat_timeout_s=0.05)
    _drive(coord, rounds=6, seed=2)
    coord.crash_primary()
    time.sleep(0.06)
    coord.pump()
    assert coord.failovers == 1
    promoted_slot = coord.primary_slot
    got = obs.registry.collected()
    assert not any(k.startswith(f"follower_{promoted_slot}_") for k in got)
    before = got["serve_completed"]
    coord.serve([MQ3], cls="hot")
    assert obs.registry.collected()["serve_completed"] > before
    coord.stop()
