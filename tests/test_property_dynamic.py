"""Hypothesis property test: after any random MutationBatch sequence the
incrementally-patched caches (reverse_edge_index, neighbour-label counts,
vm_packing, executor traversal counts) are bit-identical to rebuilding from
scratch.  The seeded numpy twin (always runnable) lives in
tests/test_dynamic_graph.py."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.rpq import parse_rpq
from repro.graphs.generators import power_law_labelled
from repro.graphs.graph import MutationBatch
from repro.workload.executor import QueryExecutor
from test_dynamic_graph import (  # same-directory sibling (pytest sys.path)
    _assert_full_parity,
    _random_batch,
    _seed_caches,
)

SET = settings(
    max_examples=15,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)


@st.composite
def mutation_scenario(draw):
    n = draw(st.integers(40, 250))
    seed = draw(st.integers(0, 2**16))
    specs = draw(st.lists(
        st.tuples(st.integers(0, 4), st.integers(0, 12), st.integers(0, 12),
                  st.booleans(), st.integers(0, 3)),
        min_size=1, max_size=3))
    return n, seed, specs


@given(mutation_scenario())
@SET
def test_random_mutation_batches_bitwise_parity(scenario):
    n, seed, specs = scenario
    g = power_law_labelled(n, n_labels=4, avg_degree=5.0, seed=seed)
    rng = np.random.default_rng(seed + 1)
    q = parse_rpq("L0.(L1|L2).L3")
    _seed_caches(g)
    ex = QueryExecutor(g)
    ex.traversals(q)
    for nv, na, nr, drop_vertex, nrl in specs:
        rem_v = [int(rng.integers(0, g.n))] if drop_vertex else []
        g.apply_mutations(_random_batch(g, rng, nv, na, nr, rem_v, nrl=nrl))
        g.validate()
        _assert_full_parity(g, queries=[(ex, q)])
