"""Dynamic-graph subsystem: mutation semantics + incremental-maintenance
parity (patched caches must be bit-identical to rebuild-from-scratch)."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.graphs.generators import musicbrainz_like, power_law_labelled
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.workload.executor import QueryExecutor


def _rebuilt(g: LabelledGraph) -> LabelledGraph:
    """Fresh graph constructed from g's raw arrays (full re-sort path)."""
    return LabelledGraph(
        n=g.n, labels=g.labels.copy(), label_names=list(g.label_names),
        src=g.src.copy(), dst=g.dst.copy())


def _assert_full_parity(g: LabelledGraph, queries=()):
    """Every incrementally-maintained structure == scratch rebuild, bitwise."""
    g2 = _rebuilt(g)
    assert np.array_equal(g.src, g2.src)
    assert np.array_equal(g.dst, g2.dst)
    assert np.array_equal(g.row_ptr, g2.row_ptr)
    assert np.array_equal(g.reverse_edge_index, g2.reverse_edge_index)
    assert np.array_equal(
        g.cached_neighbor_label_counts(), g2.neighbor_label_counts())
    p1, dl1, ic1, dg1 = g.vm_packing()
    p2, dl2, ic2, dg2 = g2.vm_packing()
    assert p1.n_blocks_out == p2.n_blocks_out
    for a, b in [
        (p1.src, p2.src), (p1.dst_local, p2.dst_local), (p1.meta, p2.meta),
        (p1.pad_mask, p2.pad_mask), (p1.order, p2.order),
        (np.asarray(dl1), np.asarray(dl2)),
        (np.asarray(ic1), np.asarray(ic2)), (dg1, dg2),
    ]:
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for ex, q in queries:
        assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def _seed_caches(g: LabelledGraph):
    g.reverse_edge_index
    g.cached_neighbor_label_counts()
    g.vm_packing()


# ---------------------------------------------------------------------------
# mutation semantics
# ---------------------------------------------------------------------------


def test_add_and_remove_edges(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))  # private copy
    _seed_caches(g)
    m0, v0 = g.m, g.version
    applied = g.apply_mutations(MutationBatch(
        add_edges=[(0, 5)], remove_edges=[(1, 2)]))
    assert g.version == v0 + 1
    assert g.m == m0  # one undirected edge in, one out
    assert 5 in g.neighbors(0) and 2 not in g.neighbors(1)
    assert applied.added_src.size == 2 and applied.removed_src.size == 2
    _assert_full_parity(g)


def test_add_vertices_with_edges(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    applied = g.apply_mutations(MutationBatch(
        add_vertex_labels=[2, 0], add_edges=[(6, 0), (6, 7), (7, 3)]))
    assert g.n == 8 and applied.n_after == 8
    assert sorted(g.neighbors(6).tolist()) == [0, 7]
    assert g.labels[6] == 2 and g.labels[7] == 0
    assert np.isin(np.arange(6, 8), applied.dirty_vertices()).all()
    _assert_full_parity(g)


def test_remove_vertex_isolates_tombstone(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    lab = int(g.labels[1])
    g.apply_mutations(MutationBatch(remove_vertices=[1]))
    assert g.n == 6                       # slot remains
    assert g.neighbors(1).size == 0       # but isolated
    assert int(g.labels[1]) == lab        # label kept
    assert not np.isin(1, g.dst).any()
    _assert_full_parity(g)


def test_remove_vertex_drops_one_directional_in_arcs():
    """Asymmetric storage: a tombstoned vertex must lose in-arcs that have
    no stored reverse, not just its out-edges."""
    g = LabelledGraph(
        n=4, labels=[0, 0, 1, 1], label_names=["a", "b"],
        src=np.array([0, 1, 2], dtype=np.int32),
        dst=np.array([1, 2, 3], dtype=np.int32))
    g.apply_mutations(MutationBatch(remove_vertices=[1]))
    assert not np.isin(1, g.src).any() and not np.isin(1, g.dst).any()
    assert g.m == 1  # only (2, 3) survives


def test_noop_batch_does_not_bump_version(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    v0 = g.version
    applied = g.apply_mutations(MutationBatch(
        add_edges=[(0, 1)],          # already present
        remove_edges=[(0, 5)]))      # absent
    assert applied.is_noop and g.version == v0
    assert len(g.mutation_log) == 0


def test_out_of_range_add_edge_raises(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    with pytest.raises(ValueError, match="out of range"):
        # references vertex 6 without a matching add_vertex_labels entry
        g.apply_mutations(MutationBatch(add_edges=[(0, 6)]))


def test_duplicate_and_self_loop_additions_dropped(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    m0 = g.m
    g.apply_mutations(MutationBatch(add_edges=[(0, 0), (0, 5), (5, 0)]))
    assert g.m == m0 + 2  # one undirected edge, stored twice
    _assert_full_parity(g)


def test_stale_vm_packing_not_served(paper_graph):
    """Stale derived caches must be refreshed, not silently reused."""
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    before = g.vm_packing()
    g.apply_mutations(MutationBatch(add_edges=[(0, 5)]))
    after = g.vm_packing()
    assert after[0].src.shape != before[0].src.shape or not np.array_equal(
        np.asarray(after[0].src), np.asarray(before[0].src))


# ---------------------------------------------------------------------------
# vertex re-labelling
# ---------------------------------------------------------------------------


def test_relabel_patches_caches(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    v0 = g.version
    old = int(g.labels[2])
    new = (old + 1) % g.n_labels
    applied = g.apply_mutations(MutationBatch(relabel=[(2, new)]))
    assert g.version == v0 + 1
    assert int(g.labels[2]) == new
    assert np.array_equal(applied.relabel_v, [2])
    assert applied.relabel_old[0] == old and applied.relabel_new[0] == new
    assert 2 in applied.dirty_vertices()
    _assert_full_parity(g)


def test_relabel_same_label_is_noop(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    v0 = g.version
    applied = g.apply_mutations(
        MutationBatch(relabel=[(3, int(g.labels[3]))]))
    assert applied.is_noop and g.version == v0
    assert len(g.mutation_log) == 0


def test_relabel_last_entry_wins_and_validates(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    old = int(g.labels[1])
    new = (old + 1) % g.n_labels
    g.apply_mutations(MutationBatch(relabel=[(1, old), (1, new)]))
    assert int(g.labels[1]) == new
    with pytest.raises(ValueError, match="label range"):
        g.apply_mutations(MutationBatch(relabel=[(1, g.n_labels)]))
    with pytest.raises(ValueError, match="vertex id"):
        g.apply_mutations(MutationBatch(relabel=[(g.n, 0)]))


def test_relabel_mixed_with_structural_same_batch(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    # add a vertex, rewire, and relabel both an old vertex and the new one
    g.apply_mutations(MutationBatch(
        add_vertex_labels=[0],
        add_edges=[(6, 1), (6, 4)],
        remove_edges=[(1, 2)],
        relabel=[(0, (int(g.labels[0]) + 1) % g.n_labels), (6, 1)]))
    assert int(g.labels[6]) == 1
    _assert_full_parity(g)


def test_relabel_executor_patch_matches_rebuild():
    g = musicbrainz_like(1200, seed=21)
    q = parse_rpq("Artist.Credit.Track.Medium")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(0)
    for _ in range(3):
        vs = rng.choice(g.n, size=5, replace=False)
        g.apply_mutations(MutationBatch(
            relabel=[(int(v), int(rng.integers(0, g.n_labels)))
                     for v in vs]))
        assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def test_relabel_executor_patch_across_compacted_log():
    """Relabels compose across ring compaction like structural deltas."""
    g = musicbrainz_like(600, seed=22)
    q = parse_rpq("Area.Artist.(Artist|Label).Area")
    ex = QueryExecutor(g)
    ex.traversals(q)     # snapshot at version 0
    rng = np.random.default_rng(1)
    for _ in range(g.MUTATION_LOG_LIMIT + 4):
        v = int(rng.integers(0, g.n))
        g.apply_mutations(MutationBatch(
            relabel=[(v, int(rng.integers(0, g.n_labels)))],
            add_edges=[(int(rng.integers(0, g.n)),
                        int(rng.integers(0, g.n)))]))
    assert len(g.mutation_log) == g.MUTATION_LOG_LIMIT
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


# ---------------------------------------------------------------------------
# executor delta-aware cache
# ---------------------------------------------------------------------------


def test_executor_patch_matches_rebuild():
    g = musicbrainz_like(2000, seed=3)
    q = parse_rpq("Artist.Credit.Track.Medium")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(0)
    und = np.stack([g.src, g.dst], 1)
    und = und[und[:, 0] < und[:, 1]]
    g.apply_mutations(MutationBatch(
        add_vertex_labels=rng.integers(0, g.n_labels, 4),
        add_edges=np.stack([rng.integers(0, g.n + 4, 30),
                            rng.integers(0, g.n + 4, 30)], 1),
        remove_edges=und[rng.choice(len(und), 20, replace=False)]))
    patched = ex.traversals(q)
    scratch = QueryExecutor(g).traversals(q)
    assert np.array_equal(patched, scratch)


def test_executor_patch_across_multiple_batches():
    g = musicbrainz_like(1500, seed=4)
    q = parse_rpq("Area.Artist.(Artist|Label).Area")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(1)
    for _ in range(3):  # gap of 3 versions, patched in one composed hop
        g.apply_mutations(MutationBatch(
            add_edges=np.stack([rng.integers(0, g.n, 15),
                                rng.integers(0, g.n, 15)], 1)))
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def test_executor_patches_across_compacted_log():
    """Overflowing the ring no longer strands a slow consumer: the two
    oldest records compose (``compose_mutations``), the log keeps reaching
    back to version 0, and the patch stays bit-identical to a rebuild."""
    g = musicbrainz_like(1000, seed=5)
    q = parse_rpq("Artist.Credit.Track.Medium")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(2)
    for _ in range(g.MUTATION_LOG_LIMIT + 2):  # overflow the ring
        g.apply_mutations(MutationBatch(
            add_edges=np.stack([rng.integers(0, g.n, 4),
                                rng.integers(0, g.n, 4)], 1)))
    assert len(g.mutation_log) == g.MUTATION_LOG_LIMIT
    assert g.mutation_log[0].version_base == 0  # history still rooted
    state = ex._cache[q.qhash]
    assert state.version == 0                   # consumer genuinely stale
    assert ex._covering_mutations(0) is not None
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def test_mutation_log_compaction_ring_and_spans():
    g = musicbrainz_like(600, seed=6)
    rng = np.random.default_rng(3)
    total = g.MUTATION_LOG_LIMIT + 7
    for _ in range(total):
        g.apply_mutations(MutationBatch(
            add_edges=np.stack([rng.integers(0, g.n, 3),
                                rng.integers(0, g.n, 3)], 1)))
    log = g.mutation_log
    assert len(log) == g.MUTATION_LOG_LIMIT
    # spans are contiguous and cover version 0 .. current
    assert log[0].version_base == 0
    for a, b in zip(log, log[1:]):
        assert b.version_base == a.version
    assert log[-1].version == g.version == total
    # the head record absorbed all the overflow
    assert log[0].version - log[0].version_base == total - (
        g.MUTATION_LOG_LIMIT - 1)


def test_executor_rebuilds_when_inside_compacted_span():
    """A snapshot strictly inside a compacted span cannot be patched; the
    executor falls back to rebuild and still returns exact counts."""
    g = musicbrainz_like(800, seed=7)
    q = parse_rpq("Artist.Credit.Track.Medium")
    rng = np.random.default_rng(4)
    g.apply_mutations(MutationBatch(
        add_edges=np.stack([rng.integers(0, g.n, 3),
                            rng.integers(0, g.n, 3)], 1)))
    ex = QueryExecutor(g)
    ex.traversals(q)                           # snapshot at version 1
    for _ in range(g.MUTATION_LOG_LIMIT + 3):  # version 1 gets compacted over
        g.apply_mutations(MutationBatch(
            add_edges=np.stack([rng.integers(0, g.n, 3),
                                rng.integers(0, g.n, 3)], 1)))
    assert g.mutation_log[0].version_base == 0
    assert g.mutation_log[0].version > 1
    assert ex._covering_mutations(1) is None
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def test_compacted_record_bounded_under_same_edge_churn():
    """Churning the same edges forever must not grow the head record: the
    compose step prunes span-transient edges, so list sizes are bounded by
    the distinct edge universe, not lifetime batch count."""
    g = musicbrainz_like(1000, seed=9)
    q = parse_rpq("Artist.Credit.Track.Medium")
    ex = QueryExecutor(g)
    ex.traversals(q)
    und = np.stack([g.src, g.dst], 1)
    fixed = und[und[:, 0] < und[:, 1]][:5]
    sizes = []
    for _ in range(3 * g.MUTATION_LOG_LIMIT):
        g.apply_mutations(MutationBatch(add_edges=fixed, remove_edges=fixed))
        sizes.append(int(g.mutation_log[0].removed_src.size))
    assert sizes[-1] == sizes[2 * g.MUTATION_LOG_LIMIT]  # plateaued
    assert sizes[-1] <= 2 * len(fixed) * 2
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def test_compose_mutations_exact_roundtrip():
    """Composed old2new/new_edge_pos must agree with composing by hand."""
    from repro.graphs.graph import compose_mutations

    g = musicbrainz_like(500, seed=8)
    rng = np.random.default_rng(5)
    batches = []
    for _ in range(2):
        und = np.stack([g.src, g.dst], 1)
        und = und[und[:, 0] < und[:, 1]]
        batches.append(g.apply_mutations(MutationBatch(
            add_vertex_labels=rng.integers(0, g.n_labels, 2),
            add_edges=np.stack([rng.integers(0, g.n + 2, 8),
                                rng.integers(0, g.n + 2, 8)], 1),
            remove_edges=und[rng.choice(len(und), 5, replace=False)])))
    a, b = batches
    c = compose_mutations(a, b)
    assert c.version_base == a.version_base and c.version == b.version
    assert c.n_before == a.n_before and c.n_after == b.n_after
    valid = a.old2new >= 0
    expect = np.full(a.old2new.shape[0], -1, np.int64)
    expect[valid] = b.old2new[a.old2new[valid]]
    assert np.array_equal(c.old2new, expect)
    # every current edge is either mapped from the base or listed as added
    covered = np.zeros(g.m, bool)
    covered[c.old2new[c.old2new >= 0]] = True
    covered[c.new_edge_pos] = True
    assert covered.all()
    assert np.array_equal(g.src[c.new_edge_pos], c.added_src)
    assert np.array_equal(g.dst[c.new_edge_pos], c.added_dst)


# ---------------------------------------------------------------------------
# randomized MutationBatch parity (the acceptance gate); the hypothesis
# twin with minimisation lives in tests/test_property_dynamic.py
# ---------------------------------------------------------------------------


def _random_batch(g, rng, nv, na, nr, rem_v, nrl=0):
    und = np.stack([g.src, g.dst], 1)
    und = und[und[:, 0] < und[:, 1]]
    nr = min(nr, len(und))
    remove = (und[rng.choice(len(und), nr, replace=False)]
              if nr else np.zeros((0, 2), np.int64))
    hi = g.n + nv
    add = (np.stack([rng.integers(0, hi, na), rng.integers(0, hi, na)], 1)
           if na else np.zeros((0, 2), np.int64))
    relabel = (np.stack([rng.integers(0, hi, nrl),
                         rng.integers(0, g.n_labels, nrl)], 1)
               if nrl else np.zeros((0, 2), np.int64))
    return MutationBatch(
        add_vertex_labels=rng.integers(0, g.n_labels, nv),
        add_edges=add, remove_edges=remove, remove_vertices=rem_v,
        relabel=relabel)


@pytest.mark.parametrize("seed", range(8))
def test_random_mutation_batches_bitwise_parity(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 250))
    g = power_law_labelled(n, n_labels=4, avg_degree=5.0, seed=seed)
    q = parse_rpq("L0.(L1|L2).L3")
    _seed_caches(g)
    ex = QueryExecutor(g)
    ex.traversals(q)
    for _ in range(int(rng.integers(1, 4))):
        rem_v = [int(rng.integers(0, g.n))] if rng.random() < 0.5 else []
        g.apply_mutations(_random_batch(
            g, rng,
            nv=int(rng.integers(0, 5)), na=int(rng.integers(0, 13)),
            nr=int(rng.integers(0, 13)), rem_v=rem_v,
            nrl=int(rng.integers(0, 4))))
        g.validate()
        _assert_full_parity(g, queries=[(ex, q)])
