"""Dynamic-graph subsystem: mutation semantics + incremental-maintenance
parity (patched caches must be bit-identical to rebuild-from-scratch)."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.graphs.generators import musicbrainz_like, power_law_labelled
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.workload.executor import QueryExecutor


def _rebuilt(g: LabelledGraph) -> LabelledGraph:
    """Fresh graph constructed from g's raw arrays (full re-sort path)."""
    return LabelledGraph(
        n=g.n, labels=g.labels.copy(), label_names=list(g.label_names),
        src=g.src.copy(), dst=g.dst.copy())


def _assert_full_parity(g: LabelledGraph, queries=()):
    """Every incrementally-maintained structure == scratch rebuild, bitwise."""
    g2 = _rebuilt(g)
    assert np.array_equal(g.src, g2.src)
    assert np.array_equal(g.dst, g2.dst)
    assert np.array_equal(g.row_ptr, g2.row_ptr)
    assert np.array_equal(g.reverse_edge_index, g2.reverse_edge_index)
    assert np.array_equal(
        g.cached_neighbor_label_counts(), g2.neighbor_label_counts())
    p1, dl1, ic1, dg1 = g.vm_packing()
    p2, dl2, ic2, dg2 = g2.vm_packing()
    assert p1.n_blocks_out == p2.n_blocks_out
    for a, b in [
        (p1.src, p2.src), (p1.dst_local, p2.dst_local), (p1.meta, p2.meta),
        (p1.pad_mask, p2.pad_mask), (p1.order, p2.order),
        (np.asarray(dl1), np.asarray(dl2)),
        (np.asarray(ic1), np.asarray(ic2)), (dg1, dg2),
    ]:
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for ex, q in queries:
        assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def _seed_caches(g: LabelledGraph):
    g.reverse_edge_index
    g.cached_neighbor_label_counts()
    g.vm_packing()


# ---------------------------------------------------------------------------
# mutation semantics
# ---------------------------------------------------------------------------


def test_add_and_remove_edges(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))  # private copy
    _seed_caches(g)
    m0, v0 = g.m, g.version
    applied = g.apply_mutations(MutationBatch(
        add_edges=[(0, 5)], remove_edges=[(1, 2)]))
    assert g.version == v0 + 1
    assert g.m == m0  # one undirected edge in, one out
    assert 5 in g.neighbors(0) and 2 not in g.neighbors(1)
    assert applied.added_src.size == 2 and applied.removed_src.size == 2
    _assert_full_parity(g)


def test_add_vertices_with_edges(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    applied = g.apply_mutations(MutationBatch(
        add_vertex_labels=[2, 0], add_edges=[(6, 0), (6, 7), (7, 3)]))
    assert g.n == 8 and applied.n_after == 8
    assert sorted(g.neighbors(6).tolist()) == [0, 7]
    assert g.labels[6] == 2 and g.labels[7] == 0
    assert np.isin(np.arange(6, 8), applied.dirty_vertices()).all()
    _assert_full_parity(g)


def test_remove_vertex_isolates_tombstone(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    lab = int(g.labels[1])
    g.apply_mutations(MutationBatch(remove_vertices=[1]))
    assert g.n == 6                       # slot remains
    assert g.neighbors(1).size == 0       # but isolated
    assert int(g.labels[1]) == lab        # label kept
    assert not np.isin(1, g.dst).any()
    _assert_full_parity(g)


def test_remove_vertex_drops_one_directional_in_arcs():
    """Asymmetric storage: a tombstoned vertex must lose in-arcs that have
    no stored reverse, not just its out-edges."""
    g = LabelledGraph(
        n=4, labels=[0, 0, 1, 1], label_names=["a", "b"],
        src=np.array([0, 1, 2], dtype=np.int32),
        dst=np.array([1, 2, 3], dtype=np.int32))
    g.apply_mutations(MutationBatch(remove_vertices=[1]))
    assert not np.isin(1, g.src).any() and not np.isin(1, g.dst).any()
    assert g.m == 1  # only (2, 3) survives


def test_noop_batch_does_not_bump_version(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    v0 = g.version
    applied = g.apply_mutations(MutationBatch(
        add_edges=[(0, 1)],          # already present
        remove_edges=[(0, 5)]))      # absent
    assert applied.is_noop and g.version == v0
    assert len(g.mutation_log) == 0


def test_out_of_range_add_edge_raises(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    with pytest.raises(ValueError, match="out of range"):
        # references vertex 6 without a matching add_vertex_labels entry
        g.apply_mutations(MutationBatch(add_edges=[(0, 6)]))


def test_duplicate_and_self_loop_additions_dropped(paper_graph):
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    m0 = g.m
    g.apply_mutations(MutationBatch(add_edges=[(0, 0), (0, 5), (5, 0)]))
    assert g.m == m0 + 2  # one undirected edge, stored twice
    _assert_full_parity(g)


def test_stale_vm_packing_not_served(paper_graph):
    """Stale derived caches must be refreshed, not silently reused."""
    g = paper_graph.subgraph_mask(np.ones(6, bool))
    _seed_caches(g)
    before = g.vm_packing()
    g.apply_mutations(MutationBatch(add_edges=[(0, 5)]))
    after = g.vm_packing()
    assert after[0].src.shape != before[0].src.shape or not np.array_equal(
        np.asarray(after[0].src), np.asarray(before[0].src))


# ---------------------------------------------------------------------------
# executor delta-aware cache
# ---------------------------------------------------------------------------


def test_executor_patch_matches_rebuild():
    g = musicbrainz_like(2000, seed=3)
    q = parse_rpq("Artist.Credit.Track.Medium")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(0)
    und = np.stack([g.src, g.dst], 1)
    und = und[und[:, 0] < und[:, 1]]
    g.apply_mutations(MutationBatch(
        add_vertex_labels=rng.integers(0, g.n_labels, 4),
        add_edges=np.stack([rng.integers(0, g.n + 4, 30),
                            rng.integers(0, g.n + 4, 30)], 1),
        remove_edges=und[rng.choice(len(und), 20, replace=False)]))
    patched = ex.traversals(q)
    scratch = QueryExecutor(g).traversals(q)
    assert np.array_equal(patched, scratch)


def test_executor_patch_across_multiple_batches():
    g = musicbrainz_like(1500, seed=4)
    q = parse_rpq("Area.Artist.(Artist|Label).Area")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(1)
    for _ in range(3):  # gap of 3 versions, patched in one composed hop
        g.apply_mutations(MutationBatch(
            add_edges=np.stack([rng.integers(0, g.n, 15),
                                rng.integers(0, g.n, 15)], 1)))
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


def test_executor_rebuilds_when_log_expired():
    g = musicbrainz_like(1000, seed=5)
    q = parse_rpq("Artist.Credit.Track.Medium")
    ex = QueryExecutor(g)
    ex.traversals(q)
    rng = np.random.default_rng(2)
    for _ in range(g.MUTATION_LOG_LIMIT + 2):  # overflow the log
        g.apply_mutations(MutationBatch(
            add_edges=np.stack([rng.integers(0, g.n, 4),
                                rng.integers(0, g.n, 4)], 1)))
    assert np.array_equal(ex.traversals(q), QueryExecutor(g).traversals(q))


# ---------------------------------------------------------------------------
# randomized MutationBatch parity (the acceptance gate); the hypothesis
# twin with minimisation lives in tests/test_property_dynamic.py
# ---------------------------------------------------------------------------


def _random_batch(g, rng, nv, na, nr, rem_v):
    und = np.stack([g.src, g.dst], 1)
    und = und[und[:, 0] < und[:, 1]]
    nr = min(nr, len(und))
    remove = (und[rng.choice(len(und), nr, replace=False)]
              if nr else np.zeros((0, 2), np.int64))
    hi = g.n + nv
    add = (np.stack([rng.integers(0, hi, na), rng.integers(0, hi, na)], 1)
           if na else np.zeros((0, 2), np.int64))
    return MutationBatch(
        add_vertex_labels=rng.integers(0, g.n_labels, nv),
        add_edges=add, remove_edges=remove, remove_vertices=rem_v)


@pytest.mark.parametrize("seed", range(8))
def test_random_mutation_batches_bitwise_parity(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 250))
    g = power_law_labelled(n, n_labels=4, avg_degree=5.0, seed=seed)
    q = parse_rpq("L0.(L1|L2).L3")
    _seed_caches(g)
    ex = QueryExecutor(g)
    ex.traversals(q)
    for _ in range(int(rng.integers(1, 4))):
        rem_v = [int(rng.integers(0, g.n))] if rng.random() < 0.5 else []
        g.apply_mutations(_random_batch(
            g, rng,
            nv=int(rng.integers(0, 5)), na=int(rng.integers(0, 13)),
            nr=int(rng.integers(0, 13)), rem_v=rem_v))
        g.validate()
        _assert_full_parity(g, queries=[(ex, q)])
