import os
import sys

# Smoke tests and benches must see exactly 1 CPU device (the dry-run sets its
# own 512-device flag in its first two lines, in a separate process).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def paper_graph():
    from repro.graphs.generators import paper_example_graph

    return paper_example_graph()


@pytest.fixture(scope="session")
def paper_workload():
    from repro.core.rpq import parse_rpq

    q1 = parse_rpq("a.(b|c).(c|d)")
    q2 = parse_rpq("(c|a).c.a")
    return [(q1, 0.5), (q2, 0.5)]


@pytest.fixture(scope="session")
def paper_trie(paper_graph, paper_workload):
    from repro.core.tpstry import TPSTry

    trie = TPSTry.from_workload(paper_workload)
    return trie


@pytest.fixture(scope="session")
def paper_partition():
    from repro.graphs.generators import paper_example_partition

    return paper_example_partition()
