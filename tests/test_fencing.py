"""Epoch fencing and durable-write integrity: the partitioned zombie's
post-failover invocation commit AND snapshot publish are rejected with a
stale epoch (surfaced in stats()), torn-tail WAL truncation at every byte
offset of the final frame, and the snapshot manifest's wall-time /
capture-duration split."""
import json
import time

import numpy as np

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.serve import (
    ClusterConfig,
    ClusterCoordinator,
    ServeLoopConfig,
    ServingLoop,
)
from repro.serve.replication import FencedWrite
from repro.serve.snapshot import MutationJournal

MQ3 = parse_rpq("Artist.Credit.Track.Medium")


def _policy():
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=6, min_interval=0,
                        dirty_fraction=0.02, drift_l1=9e9,
                        ipt_regression=9e9)


def _cluster(tmp, **ck):
    g = musicbrainz_like(400, seed=7)
    cfg = ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                          snapshot_dir=str(tmp))
    primary = ServingLoop(g, 4, taper_config=TaperConfig(max_iterations=2),
                          policy=_policy(), config=cfg)
    ck.setdefault("n_followers", 1)
    ck.setdefault("heartbeat_timeout_s", 0.05)
    return ClusterCoordinator(primary, config=ClusterConfig(**ck),
                              policy=_policy(),
                              taper_config=TaperConfig(max_iterations=2))


# ---------------------------------------------------------------------------
# zombie fencing
# ---------------------------------------------------------------------------


def test_zombie_commit_and_snapshot_fenced_with_stale_epoch(tmp_path):
    """After a partition-driven failover the deposed primary keeps
    running.  Its next invocation commit and its snapshot publish both
    carry epoch 1 against a cluster at epoch 2 — rejected at the fence,
    visible in stats(), and *not* charged as invocation failures (a
    fenced commit must not walk the backend-fallback ladder)."""
    coord = _cluster(tmp_path)
    for i in range(8):
        coord.serve([MQ3], cls="hot")
        coord.submit_mutations(MutationBatch(
            add_edges=[(i % 7, (3 * i) % 11)]))
        coord.pump()
    old = coord.primary
    coord.partition_primary()
    time.sleep(0.06)
    coord.pump()
    assert coord.primary is not old
    assert coord.stats()["cluster_epoch"] == 2

    # the zombie serves its own request stream: the only durable writes it
    # will attempt are invocation commits (requests only, no mutations)
    before = old.stats()
    for _ in range(14):
        old.submit(MQ3)
        old.pump()
    zst = old.stats()
    assert zst["fenced_writes"] > before["fenced_writes"]
    assert zst["invocation_failures"] == before["invocation_failures"]
    assert zst["invocations"] == before["invocations"]  # commit never ran
    assert zst["epoch"] == 1 and zst["cluster_epoch"] == 2
    assert zst["fenced"] == 1
    assert zst["last_stale_epoch"] == 1
    assert "stale epoch 1" in zst["fence_error"]

    # the zombie's snapshot publish is fenced the same way
    fw0, sf0 = zst["fenced_writes"], zst["snapshot_failures"]
    old.snapshot(sync=True)
    zst = old.stats()
    assert zst["fenced_writes"] == fw0 + 1
    assert zst["snapshot_failures"] == sf0 + 1

    # cluster-side accounting saw the rejections too
    cst = coord.stats()
    assert cst["fencing_rejections"] > 0
    assert cst["last_stale_epoch"] == 1
    assert cst["stale_heartbeats"] > 0
    coord.stop()


def test_authorize_raises_fenced_write(tmp_path):
    """The fence primitive itself: stale epoch vs lapsed (partitioned)
    lease are distinguishable on the raised error."""
    coord = _cluster(tmp_path)
    hub = coord.hub
    hub.partition_primary(True)
    try:
        hub.authorize(1, "ingest group")
        raise AssertionError("partitioned write not fenced")
    except FencedWrite as e:
        assert e.partitioned and e.what == "ingest group"
    hub.partition_primary(False)
    hub.advance_epoch()
    try:
        hub.authorize(1, "snapshot publish")
        raise AssertionError("stale-epoch write not fenced")
    except FencedWrite as e:
        assert not e.partitioned
        assert e.stale_epoch == 1 and e.current_epoch == 2
    coord.stop()


# ---------------------------------------------------------------------------
# WAL torn tails
# ---------------------------------------------------------------------------


def test_torn_tail_truncation_at_every_offset(tmp_path):
    """Kill the writer mid-frame at *every* byte offset of the final
    frame of a 3-record journal: reopening always recovers the intact
    prefix, truncates the torn bytes, and stays appendable."""
    src = tmp_path / "wal.log"
    j = MutationJournal(src)
    s1 = j.append_group([MutationBatch(add_edges=[(0, 1)])])
    j.append_outcome(s1, "merged", [True])
    size2 = src.stat().st_size
    j.append_group([MutationBatch(add_vertex_labels=[1],
                                  add_edges=[(1, 2)]),
                    MutationBatch(add_edges=[(2, 3)])])
    size3 = src.stat().st_size
    j.close()
    blob = src.read_bytes()
    assert size3 > size2 + 16  # the final frame spans many offsets
    for off in range(size2, size3):
        d = tmp_path / f"torn_{off}"
        d.mkdir()
        p = d / "wal.log"
        p.write_bytes(blob[:off])
        jj = MutationJournal(p)
        assert p.stat().st_size == size2  # torn bytes gone
        groups = jj.replay()
        assert [g[0] for g in groups] == [1]
        _, members, outcome = groups[0]
        assert len(members) == 1
        assert outcome == {"mode": "merged", "applied": [True]}
        # appends after recovery continue the sequence and stay readable
        assert jj.append_group([MutationBatch(add_edges=[(4, 5)])]) == 2
        jj.close()
        assert [g[0] for g in MutationJournal(p).replay()] == [1, 2]


# ---------------------------------------------------------------------------
# snapshot manifest timing (satellite: wall time vs capture duration)
# ---------------------------------------------------------------------------


def test_snapshot_manifest_wall_time_and_capture_duration(tmp_path):
    """The manifest's ``time`` is wall-clock (not a monotonic counter),
    the capture cost is measured separately on the monotonic clock, and
    both halves of the snapshot cost surface in stats()."""
    g = musicbrainz_like(300, seed=3)
    cfg = ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                          snapshot_dir=str(tmp_path))
    loop = ServingLoop(g, 4, taper_config=TaperConfig(max_iterations=2),
                       policy=_policy(), config=cfg)
    loop.snapshot(sync=True)
    snaps = sorted(tmp_path.glob("snap_*"))
    man = json.loads((snaps[-1] / "manifest.json").read_text())
    now = time.time()
    # wall clock: epoch seconds, not a small monotonic-counter value
    assert man["time"] > 1e9 and abs(man["time"] - now) < 300
    assert abs(man["wall_time_s"] - man["time"]) < 5.0
    assert 0 < man["capture_duration_s"] < 60
    st = loop.stats()
    assert st["snapshot_capture_s"] == man["capture_duration_s"]
    assert st["snapshot_publish_s"] > 0
    assert st["snapshots_taken"] >= 1
    loop.stop()
