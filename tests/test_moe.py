"""MoE layer tests: routing invariants + shard_map path equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.distributed.sharding import activation_sharding, rules_for
from repro.models import moe


@pytest.fixture(scope="module")
def setup():
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert_ff=16, capacity_factor=2.0)
    d = 32
    params, logical = moe.init(jax.random.PRNGKey(0), d, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, d), jnp.float32)
    return cfg, params, x


def test_moe_output_finite_and_shaped(setup):
    cfg, params, x = setup
    out, aux = moe.apply(params, x, cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux["moe_aux_loss"]) > 0
    assert 0.0 <= float(aux["moe_dropped_frac"]) <= 1.0


def test_moe_capacity_drops(setup):
    cfg, params, x = setup
    # capacity 1 must drop most assignments
    out, aux = moe.apply(params, x, cfg, capacity=1)
    assert float(aux["moe_dropped_frac"]) > 0.5


def test_moe_high_capacity_keeps_everything(setup):
    cfg, params, x = setup
    out, aux = moe.apply(params, x, cfg, capacity=x.shape[0] * cfg.top_k)
    assert float(aux["moe_dropped_frac"]) == 0.0


def test_sharded_path_matches_plain(setup):
    """shard_map expert parallelism (§Perf-K1) must be numerically identical
    to the plain scatter/gather path (here on a 1x1 mesh; the math is
    rank-agnostic)."""
    cfg, params, x = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out_plain, aux_plain = moe.apply(params, x, cfg)

    with mesh:
        out_sh, aux_sh = jax.jit(
            lambda p, xx: moe.apply_sharded(p, xx, cfg, mesh, rules_for(mesh))
        )(params, x)
    np.testing.assert_allclose(np.asarray(out_sh), np.asarray(out_plain),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(aux_sh["moe_aux_loss"]),
                               float(aux_plain["moe_aux_loss"]), rtol=1e-4)
    np.testing.assert_allclose(float(aux_sh["moe_dropped_frac"]),
                               float(aux_plain["moe_dropped_frac"]), atol=1e-6)


def test_apply_auto_uses_ctx(setup):
    cfg, params, x = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    out_plain, _ = moe.apply(params, x, cfg)
    with mesh:
        with activation_sharding(mesh):
            out_auto, _ = jax.jit(
                lambda p, xx: moe.apply_auto(p, xx, cfg))(params, x)
    np.testing.assert_allclose(np.asarray(out_auto), np.asarray(out_plain),
                               rtol=2e-5, atol=2e-5)


def test_grad_flows_through_sharded(setup):
    cfg, params, x = setup
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def loss(p):
        out, aux = moe.apply_sharded(p, x, cfg, mesh, rules_for(mesh))
        return jnp.sum(out ** 2) + aux["moe_aux_loss"]

    with mesh:
        g = jax.jit(jax.grad(loss))(params)
    assert all(np.isfinite(np.asarray(v, np.float32)).all()
               for v in jax.tree.leaves(g))
    assert float(jnp.abs(g["gate"]).max()) > 0
