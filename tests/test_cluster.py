"""Replicated cluster serving: owner routing, cross-replica ipt
accounting, bounded-staleness reads, deadline hedging, and the
deterministic failover drill (crash -> promote under a new epoch ->
bitwise-identical answers vs an uninterrupted run at the same applied
seq -> fenced zombie -> rejoin by catch-up replay)."""
import time

import numpy as np

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.graphs.sharded_packing import majority_owner, shard_assignment
from repro.serve import (
    ClusterConfig,
    ClusterCoordinator,
    ServeLoopConfig,
    ServingLoop,
)
from repro.serve.faults import FaultInjector, SITE_REPLICA_SERVE

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


def _policy():
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=6, min_interval=0,
                        dirty_fraction=0.02, drift_l1=9e9,
                        ipt_regression=9e9)


def _cluster(tmp, n_followers=2, faults=None, **ck):
    g = musicbrainz_like(400, seed=7)
    cfg = ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                          snapshot_dir=str(tmp), faults=faults)
    primary = ServingLoop(g, 4, taper_config=TaperConfig(max_iterations=2),
                          policy=_policy(), config=cfg)
    ck.setdefault("heartbeat_timeout_s", 9e9)
    ccfg = ClusterConfig(n_followers=n_followers, faults=faults, **ck)
    return ClusterCoordinator(primary, config=ccfg, policy=_policy(),
                              taper_config=TaperConfig(max_iterations=2))


def _drive(coord, rounds, seed=0):
    rng = np.random.default_rng(seed)
    n = coord.primary.g.n
    for i in range(rounds):
        coord.serve([MQ1 if i % 3 else MQ3], cls="hot")
        r = rng.random()
        if r < 0.4:
            coord.submit_mutations(MutationBatch(
                add_vertex_labels=[int(rng.integers(0, 4))],
                add_edges=[(int(rng.integers(0, n)), n)]))
            n += 1
        elif r < 0.6:
            coord.submit_mutations(MutationBatch(
                add_edges=[(int(rng.integers(0, 400)),
                            int(rng.integers(0, 400)))]))
        coord.pump()


# ---------------------------------------------------------------------------
# routing + ipt accounting
# ---------------------------------------------------------------------------


def test_majority_owner_fold():
    owner_of = np.array([0, 0, 1, 1, 2], np.int32)
    assert majority_owner(owner_of, np.array([0, 1, 2])) == 0
    assert majority_owner(owner_of, np.array([2, 3, 4])) == 1
    assert majority_owner(owner_of, np.array([], np.int64)) == 0


def test_owner_routing_matches_shard_assignment(tmp_path):
    """Each query routes to the majority owner of its start vertices under
    the same block-dealt fold the device packing uses."""
    coord = _cluster(tmp_path, n_followers=2)
    r = coord.router
    owners = r.owners()
    assert np.array_equal(
        owners, shard_assignment(coord.primary.ot.part, coord.n_replicas,
                                 block_n=coord.cfg.block_n))
    for q in (MQ1, MQ3):
        plan = coord.primary.executor._enum_plan(q)
        starts = np.nonzero(
            np.isin(coord.primary.g.labels, plan.first_labels))[0]
        assert r.route(q) == majority_owner(owners, starts)
    coord.serve([MQ1, MQ3, MQ3], cls="hot")
    st = r.stats()
    assert st["routed"] == 3
    assert sum(st["routed_by_slot"].values()) == 3
    coord.stop()


def test_cross_replica_ipt_accounting(tmp_path):
    """Served paths are charged for owner-boundary crossings — the
    serving-level ipt the partition enhancement is minimising."""
    coord = _cluster(tmp_path, n_followers=2)
    # capture the owner fold first: observe_served may trigger an
    # invocation right after the ipt accounting, swapping the partition
    owners = coord.router.owners().copy()
    res = coord.serve([MQ3] * 4, cls="hot")
    expect = 0.0
    for paths, _ in res:
        for p in paths:
            if len(p) > 1:
                ov = owners[np.asarray(p, dtype=np.int64)]
                expect += float((ov[1:] != ov[:-1]).sum())
    assert coord.router.stats()["cross_replica_ipt"] == expect
    assert expect > 0  # 4-hop paths across a 3-way block deal must cross
    coord.stop()


# ---------------------------------------------------------------------------
# bounded staleness + hedging
# ---------------------------------------------------------------------------


def test_bounded_staleness_gate(tmp_path):
    """A follower beyond the class staleness bound first catches up; when
    it cannot (blackholed link), the read falls back to the primary and is
    counted.  A dead follower redirects immediately."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi,
                     max_staleness_versions={"hot": 0, "cold": 16})
    f = coord.followers[1]
    fi.arm("link_partition:replica-1")
    for _ in range(3):
        coord.submit_mutations(MutationBatch(add_edges=[(1, 2)]))
        coord.pump()
    assert f.version_lag > 0
    assert coord.router._usable(1, "hot") == coord.primary_slot
    assert coord.router.stats()["staleness_fallbacks"] == 1
    # a cold read tolerates the lag and still lands on the follower
    assert coord.router._usable(1, "cold") == 1
    # heal: catch-up brings it back inside the hot bound
    fi.disarm("link_partition:replica-1")
    assert coord.router._usable(1, "hot") == 1
    assert coord.router.stats()["staleness_fallbacks"] == 1
    # a dead follower is redirected without a catch-up attempt
    f.crash()
    assert coord.router._usable(1, "hot") == coord.primary_slot
    assert coord.router.stats()["dead_redirects"] == 1
    coord.stop()


def test_deadline_hedging_past_slo_budget(tmp_path):
    """A read stalling past the class SLO budget re-issues to an alternate
    replica; the faster answer wins and the hedge is counted."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi,
                     slo_budget_s={"hot": 0.01, "cold": 0.5})
    coord.router.route = lambda q: 1  # pin the read to the follower
    fi.arm(f"{SITE_REPLICA_SERVE}:replica-1", mode="stall", times=1,
           delay_s=0.1)
    # reference answer before serving: the observation fold after the read
    # may trigger an invocation and swap the partition
    direct = coord.primary.executor.enumerate_paths_many(
        [MQ3], max_results=coord.cfg.max_results_per_query,
        part=coord.primary.ot.part)
    res = coord.serve([MQ3], cls="hot")
    st = coord.router.stats()
    assert st["hedged_requests"] == 1
    assert st["hedged_rate"] > 0
    # the hedged answer is bitwise the replica-parity answer
    assert res == direct
    coord.stop()


def test_replica_serve_fault_fails_over_to_primary(tmp_path):
    """A raising replica read (not just a slow one) retries on the
    primary transparently."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi)
    coord.router.route = lambda q: 1
    fi.arm(f"{SITE_REPLICA_SERVE}:replica-1", mode="raise", times=1)
    direct = coord.primary.executor.enumerate_paths_many(
        [MQ3], max_results=coord.cfg.max_results_per_query,
        part=coord.primary.ot.part)
    res = coord.serve([MQ3], cls="hot")
    assert coord.router.stats()["read_failovers"] == 1
    assert res == direct
    coord.stop()


# ---------------------------------------------------------------------------
# the failover drill
# ---------------------------------------------------------------------------


def _assert_loop_parity(a, b):
    """Bitwise parity between two serving loops' durable-replicated state."""
    assert a.ot.g.n == b.ot.g.n and a.ot.g.version == b.ot.g.version
    for x, y in [(a.ot.g.labels, b.ot.g.labels), (a.ot.g.src, b.ot.g.src),
                 (a.ot.g.dst, b.ot.g.dst), (a.ot.g.row_ptr, b.ot.g.row_ptr),
                 (a.ot.part, b.ot.part), (a.ot._dirty, b.ot._dirty)]:
        assert np.array_equal(x, y)
    assert a.ot.invocations == b.ot.invocations
    assert a.ot.taper._rng.bit_generator.state == \
        b.ot.taper._rng.bit_generator.state


def test_failover_drill_bitwise_parity(tmp_path):
    """The acceptance drill: run two identical clusters; crash one
    primary (losing its unshipped ingest); the best follower promotes
    under a higher epoch and serves *bitwise-identical* results to the
    uninterrupted cluster at the same applied seq; the zombie's late
    writes fence; the demoted node rejoins by pure catch-up replay."""
    A = _cluster(tmp_path / "a", n_followers=2, heartbeat_timeout_s=0.05)
    B = _cluster(tmp_path / "b", n_followers=2)
    _drive(A, rounds=18, seed=3)
    _drive(B, rounds=18, seed=3)
    assert A.primary._applied_seq == B.primary._applied_seq
    assert A.primary.ot.invocations > 0  # the drill spans commits

    # crash mid-stream: the submitted-but-unpumped mutation below is the
    # primary's unacknowledged write — it dies with the process
    A.submit_mutations(MutationBatch(add_edges=[(1, 2)]))
    old_primary, old_slot = A.primary, A.primary_slot
    A.crash_primary()
    time.sleep(0.06)
    A.pump()

    st = A.stats()
    assert A.primary is not old_primary
    assert st["cluster_epoch"] == 2 and st["failovers"] == 1
    assert A.primary_slot != old_slot
    assert A.primary._epoch == 2
    # promoted at the same applied seq as the uninterrupted run
    assert A.primary._applied_seq == B.primary._applied_seq
    _assert_loop_parity(A.primary, B.primary)
    for q in (MQ1, MQ3):
        ra = A.primary.executor.enumerate_paths(
            q, max_results=16, part=A.primary.ot.part)
        rb = B.primary.executor.enumerate_paths(
            q, max_results=16, part=B.primary.ot.part)
        assert ra == rb
    # the routed read path agrees too (followers re-converged on the
    # promoted node's epoch-opening commit frame)
    assert A.serve([MQ3], cls="hot") == B.serve([MQ3], cls="hot")

    # the zombie's late snapshot publish carries the stale epoch
    fw0 = old_primary.stats()["fenced_writes"]
    old_primary.snapshot(sync=True)
    zst = old_primary.stats()
    assert zst["fenced_writes"] > fw0
    assert zst["fenced"] == 1 and zst["epoch"] == 1
    assert zst["cluster_epoch"] == 2

    # demoted node rejoins as a follower by catch-up replay alone
    f = A.rejoin_demoted(slot=old_slot, reuse_state=True)
    _drive(A, rounds=6, seed=4)
    f.catch_up()
    st = f.stats()
    assert st["seq_lag"] == 0 and st["full_resyncs"] == 0
    assert np.array_equal(f.ot.part, A.primary.ot.part)
    assert np.array_equal(f.ot.g.src, A.primary.ot.g.src)
    assert f.ot.invocations == A.primary.ot.invocations
    assert f.ot.taper._rng.bit_generator.state == \
        A.primary.ot.taper._rng.bit_generator.state
    assert A.stats()["rejoins"] == 1
    A.stop()
    B.stop()


def test_cluster_stats_surface_replication_health(tmp_path):
    """stats() exports the replication picture: per-follower seq/version
    lag, the staleness bound, the epoch, and failover/fencing counters."""
    coord = _cluster(tmp_path, n_followers=2, heartbeat_timeout_s=0.05)
    _drive(coord, rounds=8, seed=5)
    st = coord.stats()
    assert st["n_replicas"] == 3 and st["primary_slot"] == 0
    assert st["cluster_epoch"] == 1 and st["failovers"] == 0
    assert st["staleness_bound_versions"] == {"hot": 4, "cold": 16}
    assert set(st["followers"]) == {"replica-1", "replica-2"}
    for fs in st["followers"].values():
        assert {"applied_seq", "shipped_seq", "seq_lag", "version_lag",
                "applied_commits", "tail_resyncs"} <= set(fs)
    assert st["max_seq_lag"] >= 0 and st["hedged_rate"] >= 0
    coord.crash_primary()
    time.sleep(0.06)
    coord.pump()
    st = coord.stats()
    assert st["failovers"] == 1 and st["cluster_epoch"] == 2
    assert st["epoch"] == 2  # the stats now come from the promoted node
    coord.stop()
