"""FrequencySketch lazy-decay regression tests (vs the eager formulation)."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.workload.sketch import FrequencySketch

Q = [parse_rpq(s) for s in ("a.b", "b.c", "c.(a|b)", "a.(b)*.c")]


def _eager_frequencies(observations, half_life, min_freq=1e-4):
    """Reference: decay *every* counter on every observation (the old
    O(#distinct) implementation)."""
    d = 0.5 ** (1.0 / half_life)
    counts = {}
    for q, w in observations:
        for k in counts:
            counts[k] *= d
        counts[q.qhash] = counts.get(q.qhash, 0.0) + w
    total = sum(counts.values())
    if total <= 0:
        return {}
    out = {k: v / total for k, v in counts.items()}
    return {k: (v if v >= min_freq else 0.0) for k, v in out.items()}


def test_lazy_observe_matches_eager():
    rng = np.random.default_rng(0)
    obs = [(Q[int(i)], float(w))
           for i, w in zip(rng.integers(0, len(Q), 200),
                           rng.uniform(0.5, 2.0, 200))]
    sk = FrequencySketch(half_life=17.0)
    for q, w in obs:
        sk.observe(q, w)
    expect = _eager_frequencies(obs, 17.0)
    got = sk.frequencies()
    assert set(got) == set(expect)
    for k in expect:
        assert got[k] == pytest.approx(expect[k], rel=1e-9)


def test_observe_is_o1_touches_only_observed_counter():
    sk = FrequencySketch(half_life=10.0)
    sk.observe(Q[0])
    stored_before = sk.counts[Q[0].qhash]
    for _ in range(50):
        sk.observe(Q[1])
    # lazy: Q[0]'s stored value untouched; decay only materialises on read
    assert sk.counts[Q[0].qhash] == stored_before
    freqs = sk.frequencies(min_freq=0.0)
    assert freqs[Q[0].qhash] < freqs[Q[1].qhash]
    expect0 = sk.decay ** 50 / (sk.decay ** 50 + sum(
        sk.decay ** i for i in range(50)))
    assert freqs[Q[0].qhash] == pytest.approx(expect0, rel=1e-9)


def test_observe_batch_decays_once_per_batch():
    sk = FrequencySketch(half_life=4.0)
    sk.observe_batch([Q[0]] * 10)
    w0 = sk.frequencies(min_freq=0.0)[Q[0].qhash]
    assert w0 == pytest.approx(1.0)
    # a big batch of Q1 advances the clock exactly one tick: Q0's counter
    # decays by d once regardless of the batch size
    sk.observe_batch([Q[1]] * 1000)
    vals = sk._decayed()
    assert vals[Q[0].qhash] == pytest.approx(10 * sk.decay, rel=1e-12)
    assert vals[Q[1].qhash] == pytest.approx(1000.0)


def test_preseeded_counts_survive():
    """Counts seeded through the dataclass init (stamp 0) must not crash
    reads or subsequent observes."""
    sk = FrequencySketch(
        half_life=10.0,
        counts={Q[0].qhash: 2.0}, queries={Q[0].qhash: Q[0]})
    assert sk.frequencies(min_freq=0.0)[Q[0].qhash] == pytest.approx(1.0)
    sk.observe(Q[0])
    vals = sk._decayed()
    assert vals[Q[0].qhash] == pytest.approx(2.0 * sk.decay + 1.0, rel=1e-12)


def test_empty_batch_is_noop():
    sk = FrequencySketch()
    sk.observe(Q[0])
    t = sk._ticks
    sk.observe_batch([])
    assert sk._ticks == t


def test_workload_snapshot_roundtrip():
    sk = FrequencySketch(half_life=100.0)
    sk.observe_batch([Q[0]] * 3 + [Q[1]])
    wl = dict((q.qhash, f) for q, f in sk.workload())
    assert wl[Q[0].qhash] == pytest.approx(0.75)
    assert wl[Q[1].qhash] == pytest.approx(0.25)
