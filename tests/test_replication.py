"""WAL-shipping replication: transport faults (drop/delay/reorder/link
partition, per-follower qualified sites), follower bootstrap + bitwise
parity at every shipped seq, gap-driven tail resync, the compaction
retention floor, and replica crash/rejoin in both modes."""
import numpy as np

from repro.core.online import OnlinePolicy
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.serve import (
    ClusterConfig,
    ClusterCoordinator,
    ServeLoopConfig,
    ServingLoop,
)
from repro.serve.faults import (
    FaultInjector,
    SITE_LINK_PARTITION,
    SITE_REPLICA_APPLY,
    SITE_SHIP_DELAY,
    SITE_SHIP_DROP,
    SITE_SHIP_REORDER,
)

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


def _policy():
    # durable-state-only triggers (see test_recovery): a replica that
    # adopted the shipped commit stream re-decides invocations identically
    return OnlinePolicy(bootstrap_after_ticks=0, cadence=6, min_interval=0,
                        dirty_fraction=0.02, drift_l1=9e9,
                        ipt_regression=9e9)


def _cluster(tmp, n_followers=1, faults=None, snapshot_keep=3, **ck):
    g = musicbrainz_like(400, seed=7)
    cfg = ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                          snapshot_dir=str(tmp), snapshot_keep=snapshot_keep,
                          faults=faults)
    primary = ServingLoop(g, 4, taper_config=TaperConfig(max_iterations=2),
                          policy=_policy(), config=cfg)
    ccfg = ClusterConfig(n_followers=n_followers, faults=faults,
                         heartbeat_timeout_s=9e9, **ck)
    return ClusterCoordinator(primary, config=ccfg, policy=_policy(),
                              taper_config=TaperConfig(max_iterations=2))


def _drive(coord, rounds, seed=0, serve=True):
    """Deterministic serve+mutate+pump rounds against the coordinator."""
    rng = np.random.default_rng(seed)
    n = coord.primary.g.n
    for i in range(rounds):
        if serve:
            coord.serve([MQ1 if i % 3 else MQ3], cls="hot")
        r = rng.random()
        if r < 0.4:
            coord.submit_mutations(MutationBatch(
                add_vertex_labels=[int(rng.integers(0, 4))],
                add_edges=[(int(rng.integers(0, n)), n)]))
            n += 1
        elif r < 0.6:
            coord.submit_mutations(MutationBatch(
                add_edges=[(int(rng.integers(0, 400)),
                            int(rng.integers(0, 400)))]))
        coord.pump()


def _assert_replica_parity(f, loop):
    """Bitwise parity of a follower against a serving loop: graph arrays,
    version, partition, dirty bits, invocation counters, swap-RNG state,
    and the enumeration results both would serve."""
    a, b = f.ot, loop.ot
    assert a.g.n == b.g.n and a.g.version == b.g.version
    for x, y in [(a.g.labels, b.g.labels), (a.g.src, b.g.src),
                 (a.g.dst, b.g.dst), (a.g.row_ptr, b.g.row_ptr),
                 (a.part, b.part), (a._dirty, b._dirty)]:
        assert np.array_equal(x, y)
    assert a.invocations == b.invocations
    assert a.taper._rng.bit_generator.state == \
        b.taper._rng.bit_generator.state
    for q in (MQ1, MQ3):
        ra = f.executor.enumerate_paths(q, max_results=16, part=a.part)
        rb = loop.executor.enumerate_paths(q, max_results=16, part=b.part)
        assert ra == rb


# ---------------------------------------------------------------------------
# steady-state shipping
# ---------------------------------------------------------------------------


def test_follower_bootstrap_and_shipped_parity(tmp_path):
    """Followers bootstrap like a restarted node, then stay bitwise-equal
    to the primary through shipped groups AND shipped invocation commits
    (RNG state is the commit-frame litmus test)."""
    coord = _cluster(tmp_path, n_followers=2)
    _drive(coord, rounds=30, seed=0)
    for f in coord.followers.values():
        f.catch_up()
        st = f.stats()
        assert st["seq_lag"] == 0 and st["full_resyncs"] == 0
        assert st["applied_groups"] > 0
        _assert_replica_parity(f, coord.primary)
    assert coord.primary.ot.invocations > 0
    assert coord.followers[1].stats()["applied_commits"] > 0
    coord.stop()


def test_ship_drop_recovers_via_tail_resync(tmp_path):
    """A dropped group frame leaves a seq gap; the follower detects it
    (newer frames keep arriving) and tail-resyncs from the journal —
    never a full snapshot re-fetch."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi)
    f = coord.followers[1]
    _drive(coord, rounds=4, seed=1)
    # next heartbeat + the group for this mutation both drop
    fi.arm(f"{SITE_SHIP_DROP}:replica-1", times=2)
    coord.submit_mutations(MutationBatch(add_edges=[(1, 2)]))
    coord.pump()
    assert f.stats()["channel_dropped"] >= 1
    for _ in range(4):  # gap persists resync_after_polls -> tail resync
        coord.pump()
    st = f.stats()
    assert st["seq_lag"] == 0
    assert st["tail_resyncs"] >= 1 and st["full_resyncs"] == 0
    _assert_replica_parity(f, coord.primary)
    coord.stop()


def test_ship_delay_and_reorder_are_absorbed(tmp_path):
    """Delayed (late, out-of-order) and swapped frames are buffered by seq
    and applied strictly in order — parity holds without re-bootstrap."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi)
    f = coord.followers[1]
    fi.arm(f"{SITE_SHIP_DELAY}:replica-1", times=2)
    coord.submit_mutations(MutationBatch(add_edges=[(3, 4)]))
    coord.pump()
    coord.submit_mutations(MutationBatch(add_edges=[(5, 6)]))
    coord.pump()
    fi.arm(f"{SITE_SHIP_REORDER}:replica-1", times=1)
    coord.submit_mutations(MutationBatch(add_edges=[(7, 8)]))
    for _ in range(5):
        coord.pump()
    st = f.stats()
    assert st["channel_delayed"] >= 1
    assert st["channel_reordered"] >= 1
    assert st["seq_lag"] == 0 and st["full_resyncs"] == 0
    _assert_replica_parity(f, coord.primary)
    coord.stop()


def test_qualified_site_targets_one_follower(tmp_path):
    """``site:name`` qualification faults one link; the other follower's
    transport stays clean and both converge."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=2, faults=fi)
    fi.arm(f"{SITE_SHIP_DROP}:replica-1", times=3)
    _drive(coord, rounds=10, seed=2, serve=False)
    for _ in range(4):
        coord.pump()
    s1 = coord.followers[1].stats()
    s2 = coord.followers[2].stats()
    assert s1["channel_dropped"] >= 1
    assert s2["channel_dropped"] == 0
    for f in coord.followers.values():
        _assert_replica_parity(f, coord.primary)
    coord.stop()


# ---------------------------------------------------------------------------
# partition + retention floor
# ---------------------------------------------------------------------------


def test_link_partition_blackholes_then_heals_by_tail_replay(tmp_path):
    """A partitioned link loses frames in flight and stops acks; healing
    goes through tail resync because the retention floor (min acked across
    live followers) kept the journal window alive."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi)
    f = coord.followers[1]
    _drive(coord, rounds=4, seed=3, serve=False)
    f.catch_up()
    acked0 = coord.hub.acked()["replica-1"]
    fi.arm(f"{SITE_LINK_PARTITION}:replica-1")
    _drive(coord, rounds=8, seed=4, serve=False)
    st = f.stats()
    assert st["channel_blocked"] >= 1
    assert st["seq_lag"] > 0
    # no acks across the blackhole: the floor pins at the pre-partition seq
    assert coord.hub.acked()["replica-1"] == acked0
    assert coord.primary._journal.retain_floor == acked0
    fi.disarm(f"{SITE_LINK_PARTITION}:replica-1")
    for _ in range(4):
        coord.pump()
    st = f.stats()
    assert st["seq_lag"] == 0
    assert st["tail_resyncs"] >= 1 and st["full_resyncs"] == 0
    _assert_replica_parity(f, coord.primary)
    coord.stop()


def test_retention_floor_slow_follower_survives_keep_1(tmp_path):
    """``snapshot_keep=1`` compacts the WAL aggressively after every
    commit snapshot; a live-but-partitioned follower's unacked tail must
    survive that pruning so it can catch up without a full re-fetch."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi, snapshot_keep=1)
    f = coord.followers[1]
    _drive(coord, rounds=4, seed=5)
    f.catch_up()
    fi.arm(f"{SITE_LINK_PARTITION}:replica-1")
    # serve-driven rounds so invocation commits fire -> snapshots -> compaction
    _drive(coord, rounds=24, seed=6)
    assert coord.primary.stats()["snapshots_taken"] >= 2
    # the journal still reaches back to the follower's acked position
    acked = coord.hub.acked()["replica-1"]
    tail = coord.primary._journal.replay(after_seq=acked)
    assert [s for s, _, _ in tail][:1] == [acked + 1] or not tail
    fi.disarm(f"{SITE_LINK_PARTITION}:replica-1")
    for _ in range(4):
        coord.pump()
    st = f.stats()
    assert st["seq_lag"] == 0
    assert st["full_resyncs"] == 0  # tail replay sufficed
    _assert_replica_parity(f, coord.primary)
    coord.stop()


def test_dead_replica_does_not_pin_the_wal(tmp_path):
    """A crashed follower is excluded from the retention floor, so the
    journal compacts past it; its rejoin then needs the full re-bootstrap
    path (JournalGap -> snapshot re-fetch) and still reaches parity."""
    coord = _cluster(tmp_path, n_followers=1, snapshot_keep=1)
    f = coord.followers[1]
    _drive(coord, rounds=4, seed=7)
    f.catch_up()
    f.crash()
    _drive(coord, rounds=24, seed=8)
    # compaction ran unclamped: the tail no longer reaches the dead replica
    assert coord.primary._journal.retain_floor is None
    f.rejoin(reuse_state=True)
    for _ in range(4):
        coord.pump()
    st = f.stats()
    assert st["full_resyncs"] >= 1
    assert st["seq_lag"] == 0
    _assert_replica_parity(f, coord.primary)
    coord.stop()


# ---------------------------------------------------------------------------
# replica crash / rejoin
# ---------------------------------------------------------------------------


def test_replica_crash_and_rejoin_both_modes(tmp_path):
    """An injected apply fault crashes the replica (it stops applying,
    serving and acking); rejoin with kept memory is pure catch-up replay,
    rejoin without is a fresh bootstrap — both end at bitwise parity."""
    fi = FaultInjector()
    coord = _cluster(tmp_path, n_followers=1, faults=fi)
    f = coord.followers[1]
    _drive(coord, rounds=4, seed=9, serve=False)
    fi.arm(f"{SITE_REPLICA_APPLY}:replica-1", times=1)
    coord.submit_mutations(MutationBatch(add_edges=[(9, 10)]))
    coord.pump()
    assert not f.alive and f.crash_error is not None
    _drive(coord, rounds=6, seed=10, serve=False)
    f.rejoin(reuse_state=True)
    assert f.alive
    st = f.stats()
    assert st["seq_lag"] == 0 and st["full_resyncs"] == 0
    _assert_replica_parity(f, coord.primary)
    # crash again; this time the process is "lost" -> full bootstrap
    f.crash()
    _drive(coord, rounds=6, seed=11, serve=False)
    f.rejoin(reuse_state=False)
    for _ in range(2):
        coord.pump()
    assert f.stats()["seq_lag"] == 0
    _assert_replica_parity(f, coord.primary)
    coord.stop()
