"""Hypothesis property tests for TAPER core invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.rpq import RPQ, concat, label, parse_rpq, star, union
from repro.core.swap import SwapConfig, swap_iteration
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import power_law_labelled
from repro.graphs.partition import hash_partition
from repro.workload.executor import QueryExecutor

SET = settings(
    max_examples=20,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)

LABELS = ["L0", "L1", "L2", "L3"]


@st.composite
def rpq_expr(draw, depth=0):
    if depth >= 2:
        return label(draw(st.sampled_from(LABELS)))
    kind = draw(st.sampled_from(["label", "concat", "union", "star"]))
    if kind == "label":
        return label(draw(st.sampled_from(LABELS)))
    if kind == "star":
        return star(draw(rpq_expr(depth + 1)))
    a = draw(rpq_expr(depth + 1))
    b = draw(rpq_expr(depth + 1))
    return concat(a, b) if kind == "concat" else union(a, b)


@st.composite
def graph_workload(draw):
    n = draw(st.integers(30, 300))
    seed = draw(st.integers(0, 2**16))
    g = power_law_labelled(n, n_labels=4, avg_degree=5.0, seed=seed)
    n_q = draw(st.integers(1, 3))
    queries = [draw(rpq_expr()) for _ in range(n_q)]
    freqs = [draw(st.floats(0.1, 1.0)) for _ in range(n_q)]
    k = draw(st.integers(2, 5))
    return g, list(zip(queries, freqs)), k, seed


def _trie_or_none(workload):
    try:
        return TPSTry.from_workload(workload, max_len=4)
    except ValueError:
        return None  # all queries expanded empty — fine


@given(graph_workload())
@SET
def test_extroversion_bounds_and_decomposition(gwk):
    g, workload, k, seed = gwk
    trie = _trie_or_none(workload)
    if trie is None:
        return
    arrays = trie.compile(g.label_names)
    part = hash_partition(g.n, k, seed)
    fld = extroversion_field(g, arrays, part, k)

    assert np.isfinite(fld.extroversion).all()
    assert (fld.extroversion >= -1e-6).all()
    assert (fld.extroversion <= 1.0 + 1e-5).all()
    assert (fld.pr >= -1e-7).all()
    assert (fld.edge_mass >= -1e-7).all()
    # per-destination decomposition sums to total external mass
    np.testing.assert_allclose(
        fld.ext_to.sum(axis=1), fld.extro_mass, rtol=1e-4, atol=1e-6
    )
    # out-flowing mass never exceeds the probability of being at the vertex
    out_mass = np.zeros(g.n)
    np.add.at(out_mass, g.src, fld.edge_mass)
    assert (out_mass <= fld.pr * (1 + 1e-4) + 1e-6).all()


@given(graph_workload())
@SET
def test_single_partition_has_no_extroversion(gwk):
    g, workload, k, seed = gwk
    trie = _trie_or_none(workload)
    if trie is None:
        return
    arrays = trie.compile(g.label_names)
    part = np.zeros(g.n, dtype=np.int32)
    fld = extroversion_field(g, arrays, part, 1)
    np.testing.assert_allclose(fld.extro_mass, 0.0, atol=1e-7)


@given(graph_workload())
@SET
def test_swap_iteration_invariants(gwk):
    g, workload, k, seed = gwk
    trie = _trie_or_none(workload)
    if trie is None:
        return
    arrays = trie.compile(g.label_names)
    part = hash_partition(g.n, k, seed)
    fld = extroversion_field(g, arrays, part, k)
    cfg = SwapConfig(balance_eps=0.2)  # loose for tiny graphs
    rng = np.random.default_rng(0)
    new_part, stats = swap_iteration(g, part, fld, k, cfg, rng)
    # validity
    assert new_part.shape == part.shape
    assert new_part.min() >= 0 and new_part.max() < k
    assert stats.moves == int((new_part != part).sum())


@given(graph_workload())
@SET
def test_ipt_bounded_by_total_traversals(gwk):
    g, workload, k, seed = gwk
    ex = QueryExecutor(g, max_len=4)
    part = hash_partition(g.n, k, seed)
    for q, f in workload:
        try:
            total = ex.total_traversals(q)
        except ValueError:
            continue
        ipt = ex.ipt(q, part)
        assert 0.0 <= ipt <= total + 1e-6
        assert ex.ipt(q, np.zeros(g.n, dtype=np.int32)) == 0.0


@given(rpq_expr())
@SET
def test_trie_probability_monotone(q):
    try:
        trie = TPSTry.from_workload([(q, 1.0)], max_len=4)
    except ValueError:
        return
    for node in trie.nodes:
        p_self = node.p if node.node_id != 0 else 1.0
        kids = sum(trie.nodes[c].p for c in node.children.values())
        assert kids <= p_self + 1e-9
        for c in node.children.values():
            assert trie.nodes[c].p <= p_self + 1e-9
