"""RPQ expression language tests (paper §2, §4)."""
import pytest

from repro.core.rpq import concat, label, parse_rpq, star, union


def test_parse_roundtrip():
    q = parse_rpq("a.(b|c).(c|d)")
    assert q.op == "concat"
    assert q.to_text() == "a.(b|c).(c|d)"


def test_strings_expansion_paper_q1():
    # str(a.(b|c).(c|d)) = {abc, abd, acc, acd}   (paper §4 example)
    q = parse_rpq("a.(b|c).(c|d)")
    got = {"".join(s) for s in q.strings(max_len=5)}
    assert got == {"abc", "abd", "acc", "acd"}


def test_strings_expansion_paper_q2():
    q = parse_rpq("(c|a).c.a")
    got = {"".join(s) for s in q.strings(max_len=5)}
    assert got == {"cca", "aca"}


def test_union_plus_equivalent():
    assert parse_rpq("a+b").strings(3) == parse_rpq("a|b").strings(3)


def test_star_bounded_expansion():
    # str(e*) bounded by star_max and max_len (paper §4: e^N expansion)
    q = parse_rpq("Entity.(Entity)*.Activity")
    got = {"".join(f"{sym[0]}" for sym in s) for s in q.strings(max_len=4, star_max=3)}
    # E A, E E A, E E E A  (strings longer than max_len dropped)
    assert got == {"EA", "EEA", "EEEA"}


def test_star_zero_reps_allowed():
    q = parse_rpq("a.(b)*")
    got = {"".join(s) for s in q.strings(max_len=3)}
    assert "a" in got and "ab" in got and "abb" in got


def test_qhash_unique_and_stable():
    q1, q2 = parse_rpq("a.b"), parse_rpq("a.c")
    assert q1.qhash != q2.qhash
    assert q1.qhash == parse_rpq("a.b").qhash


def test_operator_sugar():
    q = label("a") * (label("b") | label("c"))
    assert {"".join(s) for s in q.strings(3)} == {"ab", "ac"}


def test_parse_errors():
    with pytest.raises(ValueError):
        parse_rpq("a..b")
    with pytest.raises(ValueError):
        parse_rpq("(a.b")
    with pytest.raises(ValueError):
        parse_rpq("a.b)")


def test_middle_dot_accepted():
    assert parse_rpq("a·b").to_text() == "a.b"
