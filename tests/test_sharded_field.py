"""Sharded multi-device extroversion field: parity vs the single-device
backends, packing invariants, and post-mutation dirty-shard patching.

The suite adapts to however many devices exist: under plain tier-1 it runs
with the single CPU device (a 1-shard mesh still exercises the shard_map +
halo-exchange code path end to end); CI additionally runs it under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the frontier
exchange genuinely crosses devices.
"""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.core.taper import Taper, TaperConfig
from repro.core.tpstry import TPSTry
from repro.core.visitor import extroversion_field
from repro.graphs.generators import musicbrainz_like, power_law_labelled
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.graphs.partition import hash_partition, metis_like_partition
from repro.graphs.sharded_packing import (
    bfs_shard_order,
    build_sharded_vm_packing,
    partition_shard_order,
)

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")

FIELDS = ("alpha", "pr", "edge_mass", "extro_mass", "extroversion", "ext_to")


def _n_devices() -> int:
    import jax

    return len(jax.devices())


def _trie(g, workload=None):
    w = workload or [(MQ1, 0.5), (MQ3, 0.5)]
    return TPSTry.from_workload(w).compile(g.label_names)


def _assert_field_parity(ref, sh, atol=2e-5):
    for f in FIELDS:
        a, b = getattr(ref, f), getattr(sh, f)
        if a is None:
            assert b is None
            continue
        np.testing.assert_allclose(a, b, atol=atol, rtol=1e-4, err_msg=f)
    assert abs(ref.total_extroversion - sh.total_extroversion) <= max(
        1e-4, 1e-3 * abs(ref.total_extroversion))


# ---------------------------------------------------------------------------
# packing invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_shards", [1, 3, 8])
def test_sharded_packing_invariants(n_shards):
    rng = np.random.default_rng(7)
    n = 500
    g = LabelledGraph.from_undirected_edges(
        n, rng.integers(0, 5, n), rng.integers(0, n, (1400, 2)))
    sp = g.vm_packing_sharded(n_shards, block_n=64, block_e=128)

    # every real directed edge lands in exactly one slot, with its raw id
    raw = sp.slot_raw.reshape(-1)
    real = raw >= 0
    assert int(real.sum()) == g.m
    assert np.array_equal(np.sort(raw[real]), np.arange(g.m))
    flat_src = sp.src_global.reshape(-1)[real]
    flat_dst = sp.dst_global.reshape(-1)[real]
    assert np.array_equal(flat_src, g.src[raw[real]])
    assert np.array_equal(flat_dst, g.dst[raw[real]])

    for s in range(n_shards):
        r = sp.slot_raw[s] >= 0
        # destinations are wholly shard-owned (output rows never cross)
        assert (sp.dst_global[s][r] // sp.n_local_pad == s).all()
        # src_map decodes back to the global source through local | frontier
        m_ = sp.src_map[s][r]
        own = m_ < sp.n_local_pad
        fidx = np.maximum(m_ - sp.n_local_pad, 0)
        dec = np.where(own, m_ + s * sp.n_local_pad, sp.frontier[fidx])
        assert np.array_equal(dec, sp.src_global[s][r])
        # padding slots are inert for the kernel
        assert (sp.inv_cnt[s][~r] == 0.0).all()

    # each frontier entry has exactly one owner
    if sp.n_frontier:
        assert (sp.fr_owned[:, : sp.n_frontier].sum(axis=0) == 1.0).all()
    # the frontier never includes shard-interior or isolated vertices
    assert sp.n_frontier < g.n


def test_halo_traffic_smaller_than_full_field():
    g = musicbrainz_like(8000, seed=5)
    sp = g.vm_packing_sharded(8)
    assert sp.halo_bytes_per_depth(24) < sp.full_field_bytes_per_depth(
        g.n, 24)


def test_sharded_packing_cached_and_version_keyed():
    g = musicbrainz_like(600, seed=2)
    sp1 = g.vm_packing_sharded(2)
    assert g.vm_packing_sharded(2) is sp1
    g.apply_mutations(MutationBatch(add_edges=[(0, 1), (1, 2), (2, 3)]))
    sp2 = g.vm_packing_sharded(2)
    assert sp2 is sp1               # patched in place, not rebuilt
    assert sp2.version == g.version


# ---------------------------------------------------------------------------
# field parity vs the numpy/jnp backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(4))
def test_sharded_field_parity_random_graphs(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(200, 900))
    g = power_law_labelled(n, n_labels=6, seed=seed)
    arrays = _trie(g, [(parse_rpq("L0.L1.(L2|L3).L1"), 0.6),
                       (parse_rpq("L1.L2.L0"), 0.4)])
    k = int(rng.integers(2, 9))
    part = hash_partition(g.n, k, seed=seed)
    ref = extroversion_field(g, arrays, part, k, backend="jnp")
    sh = extroversion_field(g, arrays, part, k, backend="pallas_sharded")
    _assert_field_parity(ref, sh)


@pytest.mark.parametrize("dense_ext_to", [True, False])
def test_sharded_field_parity_dense_and_lazy(dense_ext_to):
    g = musicbrainz_like(1200, seed=11)
    arrays = _trie(g)
    part = hash_partition(g.n, 8, seed=1)
    ref = extroversion_field(g, arrays, part, 8, backend="jnp",
                             dense_ext_to=dense_ext_to)
    sh = extroversion_field(g, arrays, part, 8, backend="pallas_sharded",
                            dense_ext_to=dense_ext_to)
    _assert_field_parity(ref, sh)


def test_sharded_field_parity_depth_cap():
    g = musicbrainz_like(800, seed=12)
    arrays = _trie(g)
    part = hash_partition(g.n, 4, seed=2)
    for cap in (1, 2, 3):
        ref = extroversion_field(g, arrays, part, 4, depth_cap=cap,
                                 backend="jnp")
        sh = extroversion_field(g, arrays, part, 4, depth_cap=cap,
                                backend="pallas_sharded")
        _assert_field_parity(ref, sh)


def test_sharded_field_parity_vs_pallas_single_device():
    g = musicbrainz_like(900, seed=13)
    arrays = _trie(g)
    part = hash_partition(g.n, 8, seed=3)
    ref = extroversion_field(g, arrays, part, 8, backend="pallas")
    sh = extroversion_field(g, arrays, part, 8, backend="pallas_sharded")
    _assert_field_parity(ref, sh)


# ---------------------------------------------------------------------------
# topology-aware shard maps + exchange backends (PR 5)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("source", ["stripe", "partition", "bfs"])
@pytest.mark.parametrize("exchange", ["psum", "sliced"])
def test_sharded_field_parity_shard_maps_and_exchanges(source, exchange):
    g = musicbrainz_like(900, seed=41)
    arrays = _trie(g)
    part = metis_like_partition(g, 4, seed=0)
    ref = extroversion_field(g, arrays, part, 4, backend="jnp")
    pre = {}
    sh = extroversion_field(g, arrays, part, 4, _precomputed=pre,
                            backend="pallas_sharded",
                            shard_map_source=source, halo_exchange=exchange)
    _assert_field_parity(ref, sh)
    hs = pre["_halo_stats"]
    assert hs["shard_map_source"] == source
    assert hs["halo_exchange"] == exchange
    assert hs["halo_bytes_per_depth"] < hs["full_field_bytes_per_depth"]


def test_partition_shard_order_k_equals_s():
    part = np.repeat(np.arange(4), 25)
    pos = partition_shard_order(part, 4)
    # bijection, and co-partitioned vertices occupy contiguous positions
    assert np.array_equal(np.sort(pos), np.arange(100))
    for p in range(4):
        ps = np.sort(pos[part == p])
        assert ps[-1] - ps[0] == ps.size - 1


@pytest.mark.parametrize("k,s", [(5, 3), (12, 8), (3, 8), (2, 1)])
def test_partition_shard_order_folds_k_to_s(k, s):
    rng = np.random.default_rng(k * 31 + s)
    part = rng.integers(0, k, 400)
    pos = partition_shard_order(part, s)
    assert np.array_equal(np.sort(pos), np.arange(400))
    # partitions stay whole: each partition's positions are contiguous
    for p in range(k):
        ps = np.sort(pos[part == p])
        if ps.size:
            assert ps[-1] - ps[0] == ps.size - 1
    # greedy largest-first folding keeps the position groups balanced:
    # no fold group exceeds the LPT bound of ~(4/3) * ideal + max part
    span = -(-400 // s)
    sizes = np.bincount(part, minlength=k)
    group_of = pos // span
    loads = np.bincount(np.minimum(group_of, s - 1), minlength=s)
    assert loads.max() <= 400 / s + sizes.max()


def test_bfs_shard_order_is_permutation_and_groups_neighbours():
    g = musicbrainz_like(800, seed=7)
    pos = bfs_shard_order(g)
    assert np.array_equal(np.sort(pos), np.arange(g.n))
    # locality: the mean positional distance across edges must beat a
    # random permutation's (~n/3) by a wide margin
    rng = np.random.default_rng(0)
    rand = rng.permutation(g.n)
    d_bfs = np.abs(pos[g.src] - pos[g.dst]).mean()
    d_rand = np.abs(rand[g.src].astype(np.int64) - rand[g.dst]).mean()
    assert d_bfs < 0.6 * d_rand


def test_partition_map_sliced_exchange_compresses_halo():
    """The PR-5 headline at test scale: partition-dealt shards + the
    two-tier sliced exchange move >= 2x fewer bytes per depth step than
    the PR-3 stripe + psum'd-union baseline (packing-level, exact)."""
    g = musicbrainz_like(2000, seed=13)
    n_trie = 16
    sp_stripe = g.vm_packing_sharded(8)
    order = partition_shard_order(metis_like_partition(g, 8, seed=0), 8)
    sp_part = g.vm_packing_sharded(8, order=order, order_token="partition:0")
    base = sp_stripe.halo_bytes_per_depth(n_trie, exchange="psum")
    sliced = sp_part.halo_bytes_per_depth(n_trie, exchange="sliced")
    assert sliced * 2 <= base
    # the two-tier scan never loses to the union on the same shard map
    assert sp_part.halo_bytes_per_depth(n_trie, exchange="sliced") <= \
        sp_part.halo_bytes_per_depth(n_trie, exchange="psum")


def test_online_taper_redeals_shards_on_commit():
    g = musicbrainz_like(1000, seed=33)
    from repro.core.online import OnlinePolicy, OnlineTaper

    ot = OnlineTaper(
        g, 4,
        config=TaperConfig(max_iterations=2,
                           field_backend="pallas_sharded",
                           shard_map_source="partition"),
        policy=OnlinePolicy(cadence=2, min_interval=0))
    ot.observe([MQ1] * 40)
    pre = ot.taper._pre
    assert ot.invoke(reason="manual") is not None
    token, order = pre["_shard_order"]
    assert token.startswith("partition:")
    assert np.array_equal(np.sort(order), np.arange(g.n))
    # the installed layout is what the next field evaluation packs by
    fld_pre_stats = pre["_halo_stats"]
    assert fld_pre_stats["shard_map_source"] == "partition"
    # an unchanged partition skips the re-deal (no repacking churn)
    assert not ot.taper.maybe_redeal_shards(ot.part)
    # a genuinely regrouped partition re-deals under a fresh token (pinned
    # to a 4-way layout so the check is meaningful on a 1-device tier-1 run)
    regrouped = np.random.default_rng(0).integers(0, 4, g.n).astype(np.int32)
    assert ot.taper.maybe_redeal_shards(regrouped, n_shards=4)
    assert pre["_shard_order"][0] != token


def test_taper_config_psum_fallback_matches_sliced():
    g = musicbrainz_like(700, seed=44)
    w = [(MQ1, 0.5), (MQ3, 0.5)]
    part0 = hash_partition(g.n, 4, seed=1)
    objs = []
    for exchange in ("sliced", "psum"):
        rep = Taper(g, 4, TaperConfig(
            max_iterations=2, seed=0, field_backend="pallas_sharded",
            halo_exchange=exchange)).invoke(part0, w)
        objs.append(rep.objective[0])
    assert objs[0] == pytest.approx(objs[1], rel=1e-5)


# ---------------------------------------------------------------------------
# dirty-shard patching after mutations
# ---------------------------------------------------------------------------


def test_patched_packing_matches_scratch_repack():
    g = musicbrainz_like(2500, seed=21)
    g2 = musicbrainz_like(2500, seed=21)
    sp = g.vm_packing_sharded(4)
    rng = np.random.default_rng(0)
    for _ in range(4):
        batch = MutationBatch(
            add_vertex_labels=rng.integers(0, g.n_labels, 2),
            add_edges=np.stack([rng.integers(0, g.n + 2, 12),
                                rng.integers(0, g.n + 2, 12)], 1),
            remove_edges=[(int(g.src[i]), int(g.dst[i]))
                          for i in rng.integers(0, g.m, 6)])
        g.apply_mutations(batch)
        g2.apply_mutations(batch)
    assert g.vm_packing_sharded(4) is sp
    scratch = build_sharded_vm_packing(
        g2, 4, g2.cached_neighbor_label_counts())

    def canon(p):
        raw = p.slot_raw.reshape(-1)
        ok = raw >= 0
        o = np.argsort(raw[ok])
        return [raw[ok][o]] + [
            getattr(p, nm).reshape(-1)[ok][o]
            for nm in ("src_global", "dst_global", "dst_label", "inv_cnt")]

    for a, b in zip(canon(sp), canon(scratch)):
        assert np.array_equal(a, b)
    assert np.array_equal(sp.vlabels, scratch.vlabels)
    # patched frontier may keep stale (harmless) entries but must cover
    # every halo the scratch packing needs
    assert set(scratch.frontier[: scratch.n_frontier]) <= set(
        sp.frontier[: sp.n_frontier])


def test_localized_mutation_dirties_few_shards():
    g = musicbrainz_like(4000, seed=22)
    sp = g.vm_packing_sharded(8, block_n=64)
    epochs = sp.shard_epoch.copy()
    # all endpoints inside the first shard's vertex range
    lim = sp.n_local_pad
    g.apply_mutations(MutationBatch(
        add_edges=[(1, 5), (2, 9), (3, lim - 1)]))
    assert g.vm_packing_sharded(8, block_n=64) is sp
    dirty = np.nonzero(sp.shard_epoch != epochs)[0]
    assert dirty.size >= 1
    assert dirty.size < sp.n_shards  # the point: not a global re-pack


def test_sharded_field_parity_after_mutation_batches():
    g = musicbrainz_like(1500, seed=23)
    arrays = _trie(g)
    part = hash_partition(g.n, 4, seed=4)
    pre = {}
    extroversion_field(g, arrays, part, 4, _precomputed=pre,
                       backend="pallas_sharded")
    rebuilds0 = pre["_shard_uploads"]["rebuilds"]
    rng = np.random.default_rng(1)
    for _ in range(3):
        g.apply_mutations(MutationBatch(
            add_vertex_labels=[int(rng.integers(0, g.n_labels))],
            add_edges=np.stack([rng.integers(0, g.n, 8),
                                rng.integers(0, g.n, 8)], 1),
            remove_edges=[(int(g.src[i]), int(g.dst[i]))
                          for i in rng.integers(0, g.m, 4)]))
        part = np.concatenate([part, [0]]).astype(np.int32)
        ref = extroversion_field(g, arrays, part, 4, backend="jnp")
        sh = extroversion_field(g, arrays, part, 4, _precomputed=pre,
                                backend="pallas_sharded")
        _assert_field_parity(ref, sh)
    # the cached packing was patched, never rebuilt from scratch
    assert pre["_shard_uploads"]["rebuilds"] == rebuilds0


def test_capacity_overflow_falls_back_to_rebuild():
    g = musicbrainz_like(400, seed=24)
    sp = g.vm_packing_sharded(2, block_n=64)
    # add far more vertices than the packing's block capacity can absorb
    grow = sp.n_shards * sp.n_local_pad  # guarantees nb_new > S * bps
    g.apply_mutations(MutationBatch(
        add_vertex_labels=np.zeros(grow, np.int64)))
    sp2 = g.vm_packing_sharded(2, block_n=64)
    assert sp2 is not sp
    assert sp2.version == g.version
    assert sp2.n_shards * sp2.n_local_pad >= g.n


# ---------------------------------------------------------------------------
# driver integration
# ---------------------------------------------------------------------------


def test_taper_invocation_with_sharded_backend():
    g = musicbrainz_like(1200, seed=31)
    k = 4
    w = [(MQ1, 0.5), (MQ3, 0.5)]
    part0 = hash_partition(g.n, k, seed=1)
    ref = Taper(g, k, TaperConfig(max_iterations=3, seed=0)).invoke(part0, w)
    sh = Taper(g, k, TaperConfig(max_iterations=3, seed=0,
                                 field_backend="pallas_sharded")
               ).invoke(part0, w)
    assert sh.objective[0] == pytest.approx(ref.objective[0], rel=1e-4)
    # both must enhance; trajectories may diverge after float-tied swaps
    assert sh.objective[-1] <= sh.objective[0]
    assert sh.objective[-1] == pytest.approx(ref.objective[-1], rel=0.05)


def test_online_taper_with_sharded_backend():
    from repro.core.online import OnlinePolicy, OnlineTaper

    g = musicbrainz_like(1000, seed=32)
    ot = OnlineTaper(
        g, 4, config=TaperConfig(max_iterations=2,
                                 field_backend="pallas_sharded"),
        policy=OnlinePolicy(cadence=2, min_interval=0))
    ot.observe([MQ1] * 40)
    assert ot.invoke(reason="manual") is not None
    rng = np.random.default_rng(2)
    ot.apply_mutations(MutationBatch(
        add_vertex_labels=[1, 2],
        add_edges=np.stack([rng.integers(0, g.n + 2, 10),
                            rng.integers(0, g.n + 2, 10)], 1)))
    ot.observe([MQ3] * 40)
    rep = ot.step()
    assert ot.part.shape[0] == g.n
    assert (ot.part >= 0).all() and (ot.part < 4).all()
    if rep.invoked:
        assert rep.report is not None


def test_smoke_mesh_matches_device_count():
    import jax

    from repro.launch.mesh import make_smoke_mesh

    mesh = make_smoke_mesh()
    assert int(mesh.shape["model"]) == _n_devices()
