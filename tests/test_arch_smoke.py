"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeSpec
from repro.configs.registry import get_config, list_archs, shapes_for
from repro.data.graphs import random_graph_batch
from repro.models import dlrm as dlrm_lib
from repro.models import transformer as tf
from repro.models.gnn import api as gnn_api
from repro.optim import AdamW

LM_ARCHS = ["olmoe-1b-7b", "kimi-k2-1t-a32b", "gemma3-4b", "qwen2.5-14b", "qwen3-4b"]
GNN_ARCHS = ["gcn-cora", "gin-tu", "nequip", "equiformer-v2"]


def _finite(tree):
    return all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke(arch):
    cfg = get_config(arch).reduced()
    params, logical = tf.init(jax.random.PRNGKey(0), cfg)
    assert jax.tree.structure(params) == jax.tree.structure(
        logical, is_leaf=lambda x: isinstance(x, tuple))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    logits, aux = tf.forward(params, toks, cfg)
    assert logits.shape == (2, 32, cfg.vocab)
    assert _finite(logits)

    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(tf.make_train_step(cfg, opt, remat=False))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    p2, o2, m = step(params, opt.init(params), batch)
    assert _finite(m["loss"]) and float(m["loss"]) > 0
    assert _finite(p2)


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_decode_smoke(arch):
    cfg = get_config(arch).reduced()
    params, _ = tf.init(jax.random.PRNGKey(0), cfg)
    cache = tf.init_cache(cfg, batch=2, max_len=16)
    tok = jnp.zeros((2, 1), jnp.int32)
    step = jax.jit(lambda p, c, t: tf.decode_step(p, c, t, cfg))
    for i in range(3):
        logits, cache = step(params, cache, tok)
        tok = logits[:, :, :].argmax(-1).astype(jnp.int32)
    assert logits.shape == (2, 1, cfg.vocab)
    assert int(cache["pos"]) == 3
    assert _finite(logits)


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("shape_name", ["full_graph_sm", "molecule", "minibatch_lg"])
def test_gnn_smoke(arch, shape_name):
    cfg = get_config(arch).reduced()
    shape = next(s for s in shapes_for(arch) if s.name == shape_name)
    batch_np = random_graph_batch(cfg, shape, seed=0, scale=0.02)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params, logical = gnn_api.init(jax.random.PRNGKey(0), cfg, shape)

    opt = AdamW(learning_rate=1e-3, weight_decay=0.0)
    step = jax.jit(gnn_api.make_train_step(cfg, shape, opt))
    p2, o2, m = step(params, opt.init(params), batch)
    assert _finite(m["loss"])
    assert _finite(p2)
    # loss decreases over a few steps on the same batch
    l0 = float(m["loss"])
    for _ in range(5):
        p2, o2, m = step(p2, o2, batch)
    assert float(m["loss"]) < l0


def test_dlrm_smoke():
    from repro.data.recsys import ClickLogPipeline

    cfg = get_config("dlrm-rm2").reduced()
    pipe = ClickLogPipeline(cfg, batch=64, seed=0)
    batch_np = next(pipe)
    batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
    params, logical = dlrm_lib.init(jax.random.PRNGKey(0), cfg)
    opt = AdamW(learning_rate=1e-3, weight_decay=0.0)
    step = jax.jit(dlrm_lib.make_train_step(cfg, opt))
    p2, o2, m = step(params, opt.init(params), batch)
    l0 = float(m["loss"])
    for _ in range(5):
        p2, o2, m = step(p2, o2, batch)
    assert float(m["loss"]) < l0
    assert _finite(p2)

    # serving + retrieval paths
    probs = jax.jit(lambda p, b: dlrm_lib.serve_step(p, b, cfg))(p2, batch)
    assert probs.shape == (64,)
    assert float(probs.min()) >= 0 and float(probs.max()) <= 1
    cands = jax.random.normal(jax.random.PRNGKey(2), (1000, cfg.bot_mlp[-1]))
    scores, idx = dlrm_lib.retrieval_step(
        p2, {"dense": batch["dense"][:1]}, cands, top_k=10)
    assert scores.shape == (10,) and idx.shape == (10,)


def test_taper_paper_arch_registered():
    cfg = get_config("taper_paper")
    assert cfg.family == "taper"
    red = cfg.reduced()
    assert red.n_vertices == 2000


def test_all_archs_listed():
    assert len(list_archs()) == 11  # 10 assigned + taper_paper
    for arch in list_archs():
        cfg = get_config(arch)
        assert cfg.name.replace(".", "") or True
        assert len(shapes_for(arch)) >= 1


def test_param_counts_match_assignment():
    # olmoe ~6.9B total / ~1.3B active; kimi ~1T total / ~32B active
    olmoe = get_config("olmoe-1b-7b")
    assert 5e9 < olmoe.n_params() < 9e9
    assert 0.8e9 < olmoe.n_active_params() < 2e9
    kimi = get_config("kimi-k2-1t-a32b")
    assert 0.8e12 < kimi.n_params() < 1.3e12
    assert 20e9 < kimi.n_active_params() < 45e9
