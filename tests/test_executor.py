"""Exact ipt-counting executor tests."""
import numpy as np
import pytest

from repro.core.rpq import parse_rpq
from repro.graphs.generators import paper_example_graph, provgen_like
from repro.graphs.partition import hash_partition
from repro.workload.executor import QueryExecutor, ipt_of_partition


def test_paper_intro_query(paper_graph):
    """§1: query c.(b|d) evaluates to paths (3,2),(3,4),(5,2),(5,4); under
    partitioning A={1,2,4}, B={3,5,6} every path crosses once -> ipt=4; under
    V1={1,3,6}, V2={2,4,5} only (3,2),(3,4),(5,... wait — (3,2) and (3,4)
    cross (3 in V1; 2,4 in V2) and (5,2),(5,4) don't (5,2,4 all in V2)
    -> ipt=2 (paper: 'only paths (3,2),(5,4) require traversing a
    boundary' under its analogous argument)."""
    ex = QueryExecutor(paper_graph)
    q = parse_rpq("c.(b|d)")
    assert ex.total_traversals(q) == pytest.approx(4.0)

    part_ab = np.array([0, 0, 1, 0, 1, 1], dtype=np.int32)   # A/B of Fig.1
    assert ex.ipt(q, part_ab) == pytest.approx(4.0)

    part_alt = np.array([0, 1, 0, 1, 1, 0], dtype=np.int32)  # V1={1,3,6}
    assert ex.ipt(q, part_alt) == pytest.approx(2.0)


def test_traversal_counts_longer_pattern(paper_graph):
    """abc paths: 1->2->{3,5}; traversals: edge (1,2) once... the DP counts
    per-prefix extensions: (1,2) traversed once for prefix 'a'->'ab', then
    (2,3) and (2,5) once each for 'ab'->'abc'. Total 3."""
    ex = QueryExecutor(paper_graph)
    q = parse_rpq("a.b.c")
    assert ex.total_traversals(q) == pytest.approx(3.0)


def test_workload_ipt_weighting(paper_graph):
    ex = QueryExecutor(paper_graph)
    q1, q2 = parse_rpq("c.(b|d)"), parse_rpq("a.b")
    part = np.array([0, 0, 1, 0, 1, 1], dtype=np.int32)
    w = [(q1, 0.25), (q2, 0.75)]
    expect = 0.25 * ex.ipt(q1, part) + 0.75 * ex.ipt(q2, part)
    assert ex.workload_ipt(w, part) == pytest.approx(expect)
    assert ipt_of_partition(paper_graph, w, part, ex) == pytest.approx(expect)


def test_enumerate_paths(paper_graph):
    ex = QueryExecutor(paper_graph)
    q = parse_rpq("c.(b|d)")
    paths, crossings = ex.enumerate_paths(
        q, part=np.array([0, 0, 1, 0, 1, 1], dtype=np.int32)
    )
    assert sorted(paths) == [(2, 1), (2, 3), (4, 1), (4, 3)]
    assert crossings == 4


def test_executor_cache(paper_graph):
    ex = QueryExecutor(paper_graph)
    q = parse_rpq("a.b")
    t1 = ex.traversals(q)
    t2 = ex.traversals(q)
    assert t1 is t2  # cached


def test_ipt_scales_with_cut():
    """More cut edges -> more ipt, on a random heterogeneous graph."""
    g = provgen_like(1500, seed=5)
    ex = QueryExecutor(g)
    q = parse_rpq("Entity.Activity.Agent")
    part1 = hash_partition(g.n, 2)
    part_all_same = np.zeros(g.n, dtype=np.int32)
    assert ex.ipt(q, part_all_same) == 0.0
    assert ex.ipt(q, part1) > 0.0
    assert ex.ipt(q, part1) <= ex.total_traversals(q)
