"""Equivariance property tests for the SO(3) machinery.

The key identities:
  Y(R r) = D(R) Y(r)                       (sph_harm x wigner_d_real)
  CG contraction transforms as l3          (clebsch_gordan_real)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gnn import so3

RNG = np.random.default_rng(0)


def random_rotation(n):
    """Random z-y-z Euler angles."""
    alpha = RNG.uniform(-np.pi, np.pi, n)
    beta = RNG.uniform(0, np.pi, n)
    gamma = RNG.uniform(-np.pi, np.pi, n)
    return jnp.asarray(alpha), jnp.asarray(beta), jnp.asarray(gamma)


def rot_matrix(alpha, beta, gamma):
    ca, sa = jnp.cos(alpha), jnp.sin(alpha)
    cb, sb = jnp.cos(beta), jnp.sin(beta)
    cg, sg = jnp.cos(gamma), jnp.sin(gamma)
    Rz1 = jnp.stack([jnp.stack([ca, -sa, 0 * ca], -1),
                     jnp.stack([sa, ca, 0 * ca], -1),
                     jnp.stack([0 * ca, 0 * ca, 1 + 0 * ca], -1)], -2)
    Ry = jnp.stack([jnp.stack([cb, 0 * cb, sb], -1),
                    jnp.stack([0 * cb, 1 + 0 * cb, 0 * cb], -1),
                    jnp.stack([-sb, 0 * cb, cb], -1)], -2)
    Rz2 = jnp.stack([jnp.stack([cg, -sg, 0 * cg], -1),
                     jnp.stack([sg, cg, 0 * cg], -1),
                     jnp.stack([0 * cg, 0 * cg, 1 + 0 * cg], -1)], -2)
    return Rz1 @ Ry @ Rz2


def test_sph_harm_l0_l1_closed_form():
    v = jnp.asarray(RNG.normal(size=(64, 3)))
    v = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
    Y = so3.sph_harm(v, 1)
    c0 = 1.0 / np.sqrt(4 * np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    np.testing.assert_allclose(Y[:, 0], c0, rtol=1e-5)
    # ordering: (l=1, m=-1)=y, (m=0)=z, (m=1)=x
    np.testing.assert_allclose(Y[:, 1], c1 * v[:, 1], rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(Y[:, 2], c1 * v[:, 2], rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(Y[:, 3], c1 * v[:, 0], rtol=2e-5, atol=1e-6)


@pytest.mark.parametrize("l_max", [1, 2, 3, 6])
def test_wigner_rotation_identity(l_max):
    """Y(R r) == D(R) Y(r) for random rotations and directions."""
    n = 16
    a, b, g = random_rotation(n)
    R = rot_matrix(a, b, g)
    v = jnp.asarray(RNG.normal(size=(n, 3)))
    v = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
    Rv = jnp.einsum("nij,nj->ni", R, v)
    Y = so3.sph_harm(v, l_max)
    YR = so3.sph_harm(Rv, l_max)
    for l in range(l_max + 1):
        D = so3.wigner_d_real(a, b, g, l)
        lo, hi = l * l, (l + 1) ** 2
        got = jnp.einsum("nij,nj->ni", D, Y[:, lo:hi])
        np.testing.assert_allclose(np.asarray(got), np.asarray(YR[:, lo:hi]),
                                   rtol=1e-3, atol=2e-4)


@pytest.mark.parametrize("l_max", [2, 4, 6])
def test_wigner_orthogonality(l_max):
    n = 8
    a, b, g = random_rotation(n)
    for l in range(l_max + 1):
        D = so3.wigner_d_real(a, b, g, l)
        eye = jnp.einsum("nij,nkj->nik", D, D)
        np.testing.assert_allclose(
            np.asarray(eye), np.broadcast_to(np.eye(2 * l + 1), eye.shape),
            atol=2e-4,
        )


def test_align_to_z():
    v = jnp.asarray(RNG.normal(size=(32, 3)))
    v = v / jnp.linalg.norm(v, axis=-1, keepdims=True)
    a, b, g = so3.align_to_z_angles(v)
    R = rot_matrix(a, b, g)
    z = jnp.einsum("nij,nj->ni", R, v)
    np.testing.assert_allclose(np.asarray(z[:, 2]), 1.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(z[:, :2]), 0.0, atol=1e-5)


@pytest.mark.parametrize("l1,l2,l3", [(1, 1, 0), (1, 1, 1), (1, 1, 2), (2, 1, 1), (2, 2, 2)])
def test_cg_equivariance(l1, l2, l3):
    """(D1 a) x (D2 b) contracted with CG transforms as D3."""
    C = jnp.asarray(so3.clebsch_gordan_real(l1, l2, l3))
    assert float(jnp.abs(C).max()) > 0  # non-trivial path
    n = 8
    a_, b_, g_ = random_rotation(n)
    D1 = so3.wigner_d_real(a_, b_, g_, l1)
    D2 = so3.wigner_d_real(a_, b_, g_, l2)
    D3 = so3.wigner_d_real(a_, b_, g_, l3)
    x = jnp.asarray(RNG.normal(size=(n, 2 * l1 + 1)))
    y = jnp.asarray(RNG.normal(size=(n, 2 * l2 + 1)))
    lhs = jnp.einsum("ijk,ni,nj->nk",
                     C,
                     jnp.einsum("nij,nj->ni", D1, x),
                     jnp.einsum("nij,nj->ni", D2, y))
    rhs = jnp.einsum("nij,nj->ni", D3, jnp.einsum("ijk,ni,nj->nk", C, x, y))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3, atol=2e-4)


def test_rotate_coeffs_roundtrip():
    l_max = 3
    n, c = 10, 4
    a, b, g = random_rotation(n)
    Ds = so3.rotation_block_diag(a, b, g, l_max)
    x = jnp.asarray(RNG.normal(size=(n, c, so3.n_sph(l_max))).astype(np.float32))
    y = so3.rotate_coeffs(x, Ds, l_max)
    back = so3.rotate_coeffs(y, Ds, l_max, transpose=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)
