"""GNN model property tests: E(3)/SO(3) equivariance end-to-end, permutation
invariance, sampler correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, shapes_for
from repro.data.graphs import NeighborSampler, build_csr, random_graph_batch
from repro.models.gnn import api as gnn_api
from repro.models.gnn import equiformer, nequip

RNG = np.random.default_rng(3)


def _mol_batch(cfg, n_nodes=12, n_edges=40, seed=0):
    rng = np.random.default_rng(seed)
    d = gnn_api.N_SPECIES
    feat = np.zeros((n_nodes, d), np.float32)
    feat[np.arange(n_nodes), rng.integers(0, d, n_nodes)] = 1.0
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    pos = rng.normal(size=(n_nodes, 3)).astype(np.float32)
    return {
        "node_feat": jnp.asarray(feat),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "node_mask": jnp.ones(n_nodes, bool),
        "edge_mask": jnp.asarray(src != dst),
        "positions": jnp.asarray(pos),
        "graph_id": jnp.zeros(n_nodes, jnp.int32),
        "targets": jnp.zeros((1,), jnp.float32),
    }


def _random_rot():
    a = RNG.uniform(-np.pi, np.pi)
    b = RNG.uniform(0, np.pi)
    g = RNG.uniform(-np.pi, np.pi)
    ca, sa, cb, sb, cg, sg = np.cos(a), np.sin(a), np.cos(b), np.sin(b), np.cos(g), np.sin(g)
    Rz1 = np.array([[ca, -sa, 0], [sa, ca, 0], [0, 0, 1]])
    Ry = np.array([[cb, 0, sb], [0, 1, 0], [-sb, 0, cb]])
    Rz2 = np.array([[cg, -sg, 0], [sg, cg, 0], [0, 0, 1]])
    return (Rz1 @ Ry @ Rz2).astype(np.float32)


@pytest.mark.parametrize("model,arch", [(nequip, "nequip"),
                                        (equiformer, "equiformer-v2")])
def test_energy_invariance_under_rotation_translation(model, arch):
    """Predicted energies must be invariant to global rotation+translation —
    the defining property of both assigned equivariant architectures."""
    cfg = get_config(arch).reduced()
    batch = _mol_batch(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg, gnn_api.N_SPECIES)
    e0 = model.forward(params, batch, cfg, 1)

    R = jnp.asarray(_random_rot())
    t = jnp.asarray(RNG.normal(size=(1, 3)).astype(np.float32))
    batch_rot = dict(batch)
    batch_rot["positions"] = batch["positions"] @ R.T + t
    e1 = model.forward(params, batch_rot, cfg, 1)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("model,arch", [(nequip, "nequip"),
                                        (equiformer, "equiformer-v2")])
def test_energy_changes_with_geometry(model, arch):
    """Sanity: the model is not constant — perturbing geometry changes E."""
    cfg = get_config(arch).reduced()
    batch = _mol_batch(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), cfg, gnn_api.N_SPECIES)
    e0 = model.forward(params, batch, cfg, 1)
    batch2 = dict(batch)
    batch2["positions"] = batch["positions"] * 1.3
    e1 = model.forward(params, batch2, cfg, 1)
    assert abs(float(e0[0]) - float(e1[0])) > 1e-6


def test_gcn_permutation_equivariance():
    from repro.models.gnn import gcn

    cfg = get_config("gcn-cora").reduced()
    shape = shapes_for("gcn-cora")[0]
    b = random_graph_batch(cfg, shape, seed=1, scale=0.05)
    batch = {k: jnp.asarray(v) for k, v in b.items()}
    params, _ = gcn.init(jax.random.PRNGKey(0), cfg, b["node_feat"].shape[1])
    out = gcn.forward(params, batch, cfg)

    n = b["node_feat"].shape[0]
    perm = RNG.permutation(n)
    inv = np.argsort(perm)
    pb = dict(batch)
    pb["node_feat"] = batch["node_feat"][perm]
    pb["node_mask"] = batch["node_mask"][perm]
    pb["edge_src"] = jnp.asarray(inv)[batch["edge_src"]]
    pb["edge_dst"] = jnp.asarray(inv)[batch["edge_dst"]]
    out_p = gcn.forward(params, pb, cfg)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out)[perm],
                               rtol=1e-4, atol=1e-5)


def test_neighbor_sampler_shapes_and_validity():
    g = build_csr(5000, 80000, seed=0)
    sampler = NeighborSampler(g, (15, 10))
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.n, 64)
    sub = sampler.sample(seeds, rng)
    assert len(sub.nodes) == sampler.max_nodes(64) == 64 * (1 + 15 + 15 * 10)
    assert sub.edge_src.shape == sub.edge_dst.shape
    # all masked edges reference valid local nodes
    n_valid = int(sub.node_mask.sum())
    assert sub.edge_src[sub.edge_mask].max() < n_valid
    assert sub.edge_dst[sub.edge_mask].max() < n_valid
    # every sampled edge exists in the base graph
    for s, d in zip(sub.edge_src[sub.edge_mask][:100], sub.edge_dst[sub.edge_mask][:100]):
        u, w = sub.nodes[s], sub.nodes[d]
        row = g.col[g.row_ptr[w]: g.row_ptr[w + 1]]
        assert u in row


def test_sampler_respects_fanout_distribution():
    g = build_csr(2000, 60000, seed=1)
    sampler = NeighborSampler(g, (5,))
    rng = np.random.default_rng(1)
    sub = sampler.sample(np.arange(32), rng)
    # seeds with degree > 0 contribute exactly fanout edges
    deg = g.row_ptr[1:] - g.row_ptr[:-1]
    expect = sum(5 for s in range(32) if deg[s] > 0)
    assert int(sub.edge_mask.sum()) == expect
