"""Graph container, generators and partitioner tests."""
import numpy as np
import pytest

from repro.graphs.generators import (
    musicbrainz_like,
    paper_example_graph,
    power_law_labelled,
    provgen_like,
)
from repro.graphs.metrics import edge_cut, partition_balance, partition_sizes
from repro.graphs.partition import (
    fennel_stream_partition,
    hash_partition,
    metis_like_partition,
)


def test_paper_graph_structure(paper_graph):
    g = paper_graph
    assert g.n == 6
    assert g.undirected_edge_count() == 8
    assert sorted(g.neighbors(1).tolist()) == [0, 2, 3, 4]   # §4.2: nbrs of v2
    assert sorted(g.neighbors(2).tolist()) == [1, 3, 4, 5]   # §5.4: nbrs of v3
    assert g.neighbors(5).tolist() == [2]                    # v6 - v3 only
    cnt = g.neighbor_label_counts()
    assert cnt[4, 2] == 1  # v5 has exactly one c-neighbour (v3)
    assert cnt[5, 2] == 1  # v6 has exactly one c-neighbour (v3)
    assert g.label_counts().tolist() == [2, 1, 2, 1]


def test_generators_valid():
    for g in (musicbrainz_like(2000, seed=1), provgen_like(2000, seed=1),
              power_law_labelled(1000, seed=1)):
        g.validate()
        assert g.n >= 1000
        assert g.m > 0
        # symmetric edge list
        fwd = set(zip(g.src.tolist(), g.dst.tolist()))
        assert all((d, s) in fwd for s, d in list(fwd)[:200])


def test_generator_heterogeneity():
    g = musicbrainz_like(5000, seed=0)
    assert g.n_labels == 12
    assert (g.label_counts() > 0).all()
    g2 = provgen_like(5000, seed=0)
    assert g2.n_labels == 3


def test_hash_partition_balanced():
    part = hash_partition(10_000, 8, seed=3)
    assert part.shape == (10_000,)
    assert partition_balance(part, 8) < 1.05
    assert set(np.unique(part)) == set(range(8))


def test_metis_like_beats_hash():
    g = provgen_like(3000, seed=2)
    hash_p = hash_partition(g.n, 8)
    metis_p = metis_like_partition(g, 8, seed=0)
    assert partition_balance(metis_p, 8) <= 1.06
    assert edge_cut(g, metis_p) < 0.7 * edge_cut(g, hash_p)


def test_fennel_beats_hash():
    g = provgen_like(3000, seed=2)
    hash_p = hash_partition(g.n, 8)
    fennel_p = fennel_stream_partition(g, 8, seed=0)
    assert partition_balance(fennel_p, 8) <= 1.15
    assert edge_cut(g, fennel_p) < edge_cut(g, hash_p)


def test_edge_cut_undirected_vs_directed(paper_graph):
    part = np.array([0, 0, 1, 0, 1, 1], dtype=np.int32)  # A/B of Fig.1
    und = edge_cut(paper_graph, part)                # symmetric storage
    dir_ = edge_cut(paper_graph, part, directed=True)
    assert dir_ == 2 * und                           # every pair stored twice
    cut_pairs = {(1, 2), (1, 4), (2, 3), (3, 4)}     # by hand from Fig. 1
    assert und == len(cut_pairs)


def test_edge_cut_one_directional_arcs_not_halved():
    """A directed graph stored one-direction-per-edge: the old ``// 2``
    silently halved the cut; both modes must count each arc once."""
    from repro.graphs.graph import LabelledGraph

    g = LabelledGraph(
        n=4, labels=[0, 0, 1, 1], label_names=["a", "b"],
        src=np.array([0, 1, 2], dtype=np.int32),
        dst=np.array([1, 2, 3], dtype=np.int32))
    part = np.array([0, 1, 0, 1], dtype=np.int32)    # all three arcs cut
    assert edge_cut(g, part, directed=True) == 3
    assert edge_cut(g, part) == 3                    # no reverse arcs stored


def test_subgraph_mask(paper_graph):
    sub = paper_graph.subgraph_mask(np.array([0, 0, 1, 0, 1, 1], dtype=bool))
    assert sub.n == 3
    assert sub.undirected_edge_count() == 2  # 3-5, 3-6
