"""Control loops (PR 10): breakers, windowed quantiles, brownout
admission, adaptive hedging, pressure-aware invocation cadence."""
import numpy as np
import pytest

from repro.core.online import OnlinePolicy, OnlineTaper
from repro.core.rpq import parse_rpq
from repro.core.taper import TaperConfig
from repro.graphs.generators import musicbrainz_like
from repro.graphs.graph import MutationBatch
from repro.obs.registry import Registry
from repro.serve.control import (
    Breaker,
    BrownoutController,
    ControlConfig,
    HedgeController,
    WindowedQuantile,
    serve_pressure,
)
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.serve.queueing import Rejection, RequestQueue
from repro.serve.replication import Frame, ShipChannel

MQ1 = parse_rpq("Area.Artist.(Artist|Label).Area")
MQ3 = parse_rpq("Artist.Credit.Track.Medium")


class FakeClock:
    def __init__(self, t0=0.0):
        self.t = float(t0)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


class ListRecorder:
    def __init__(self):
        self.events = []

    def record(self, kind, **fields):
        self.events.append({"kind": kind, **fields})

    def of(self, kind):
        return [e for e in self.events if e["kind"] == kind]


# ---------------------------------------------------------------------------
# Breaker
# ---------------------------------------------------------------------------


def test_breaker_trips_on_error_rate_not_just_streak():
    # 2 failures in 3 calls: not consecutive, but rate 2/3 >= 0.5 trips —
    # the upgrade over the old bare consecutive-strike count
    b = Breaker("x", window=8, min_failures=2, error_rate=0.5)
    assert b.record_failure() is False
    b.record_success()
    assert b.record_failure() is True  # the tripping edge
    assert b.state == "open" and b.trips == 1
    assert b.record_failure() is False  # already open: no second edge


def test_breaker_consecutive_tail_trips_below_rate():
    # 10 successes then 3 straight failures: rate 3/13 < 0.9 but the
    # trailing strike count preserves the historic ladder behaviour
    b = Breaker("x", window=16, min_failures=3, error_rate=0.9)
    for _ in range(10):
        b.record_success()
    b.record_failure()
    b.record_failure()
    assert b.state == "closed"
    assert b.record_failure() is True
    assert b.state == "open"


def test_breaker_no_trip_below_min_failures():
    b = Breaker("x", window=8, min_failures=3, error_rate=0.1)
    b.record_failure()
    b.record_failure()
    assert b.state == "closed"


def test_breaker_cooldown_halfopen_probe_and_close():
    clk = FakeClock()
    rec = ListRecorder()
    b = Breaker("dep", window=4, min_failures=2, error_rate=0.5,
                cooldown_s=1.0, recorder=rec, clock=clk)
    b.record_failure()
    b.record_failure()
    assert b.state == "open"
    assert not b.allow() and not b.allow()
    assert b.fast_failures == 2
    clk.advance(1.01)
    assert b.allow()  # cooldown elapsed: half-open probe flows
    assert b.state == "half_open"
    b.record_success()
    assert b.state == "closed" and b.closes == 1
    # window was cleared at close: one failure alone cannot re-trip
    assert b.record_failure() is False
    frames = [(e["frm"], e["to"]) for e in rec.of("breaker_transition")]
    assert frames == [("closed", "open"), ("open", "half_open"),
                      ("half_open", "closed")]


def test_breaker_failed_probe_doubles_cooldown_up_to_max():
    clk = FakeClock()
    b = Breaker("dep", window=4, min_failures=1, error_rate=1.0,
                cooldown_s=1.0, cooldown_max_s=3.0, clock=clk)
    b.record_failure()
    for expected in (2.0, 3.0, 3.0):  # doubled, then capped
        clk.advance(b._cooldown_s + 0.01)
        assert b.allow() and b.state == "half_open"
        b.record_failure()  # failed probe
        assert b.state == "open"
        assert b._cooldown_s == expected
    # a successful probe resets the cooldown ladder
    clk.advance(b._cooldown_s + 0.01)
    assert b.allow()
    b.record_success()
    assert b.state == "closed" and b._cooldown_s == 1.0


def test_breaker_open_ignores_straggler_success():
    b = Breaker("x", window=4, min_failures=1, error_rate=1.0,
                cooldown_s=99.0, clock=FakeClock())
    b.record_failure()
    b.record_success()  # a call that was in flight at the trip
    assert b.state == "open"


def test_breaker_reset_reopens_fresh():
    b = Breaker("x", window=4, min_failures=1, error_rate=1.0)
    b.record_failure()
    assert b.state == "open"
    b.reset()
    assert b.state == "closed"
    assert b.allow()


# ---------------------------------------------------------------------------
# WindowedQuantile
# ---------------------------------------------------------------------------


def test_windowed_quantile_sees_only_the_window():
    reg = Registry()
    h = reg.histogram("lat", cls="hot")
    for _ in range(100):
        h.observe(0.001)  # old history: fast
    w = WindowedQuantile(h)
    w.advance()  # window starts after the fast history
    for _ in range(10):
        h.observe(0.9)  # the live window is slow
    assert w.count == 10
    # lifetime quantile still reads fast; the window reads slow
    assert h.quantile(0.5) < 0.01
    assert w.quantile(0.5) > 0.1


def test_windowed_quantile_empty_window_is_none():
    reg = Registry()
    h = reg.histogram("lat", cls="hot")
    h.observe(0.5)
    w = WindowedQuantile(h)
    w.advance()
    assert w.count == 0
    assert w.quantile(0.99) is None


def test_windowed_quantile_interpolates_within_bucket():
    reg = Registry()
    h = reg.histogram("lat", cls="hot")
    w = WindowedQuantile(h)
    for _ in range(8):
        h.observe(0.3)
    q = w.quantile(0.5)
    lo = max(b for b in h.bounds if b < 0.3)
    hi = min(b for b in h.bounds if b >= 0.3)
    assert lo <= q <= hi


# ---------------------------------------------------------------------------
# serve_pressure
# ---------------------------------------------------------------------------


def test_serve_pressure_weights_and_clamp():
    cfg = ControlConfig()
    assert serve_pressure(0.0, 0.0, 0.0, cfg) == 0.0
    assert serve_pressure(9.0, 9.0, 9.0, cfg) == 1.0  # inputs clamp too
    p = serve_pressure(0.5, 0.0, 0.0, cfg)
    assert p == pytest.approx(cfg.pressure_depth_weight * 0.5)
    assert 0.0 <= serve_pressure(-1.0, 0.2, 0.1, cfg) <= 1.0


# ---------------------------------------------------------------------------
# RequestQueue brownout shedding
# ---------------------------------------------------------------------------


def test_queue_shed_level_rejects_cold_keeps_hot():
    q = RequestQueue(max_depth=16)
    q.max_shed_level = 4
    q.shed_classes = ("cold",)
    q.set_shed_level(4)  # max level: shed classes rejected outright
    r = q.submit(MQ1, cls="cold")
    assert isinstance(r, Rejection) and r.reason == "brownout"
    assert q.rejected_brownout == 1
    assert not isinstance(q.submit(MQ1, cls="hot"), Rejection)


def test_queue_partial_shed_shrinks_admission_zone():
    q = RequestQueue(max_depth=8)
    q.max_shed_level = 4
    q.shed_classes = ("cold",)
    q.set_shed_level(2)  # admission zone shrinks to half depth
    admitted = 0
    for _ in range(8):
        if not isinstance(q.submit(MQ1, cls="cold"), Rejection):
            admitted += 1
    assert 0 < admitted < 8
    # retry hint scales with the shed level
    q2 = RequestQueue(max_depth=8)
    q2.set_shed_level(q2.max_shed_level)
    rej = q2.submit(MQ1, cls="cold")
    base = RequestQueue(max_depth=1)
    base.submit(MQ1, cls="hot")
    full = base.submit(MQ1, cls="hot")
    assert rej.retry_after_s > full.retry_after_s


def test_queue_set_shed_level_clamps():
    q = RequestQueue(max_depth=8)
    q.max_shed_level = 3
    q.set_shed_level(99)
    assert q.shed_level == 3
    q.set_shed_level(-4)
    assert q.shed_level == 0


# ---------------------------------------------------------------------------
# BrownoutController
# ---------------------------------------------------------------------------


def _brownout(clk, **over):
    kw = dict(slo_budget_s={"hot": 0.05}, window_s=1.0,
              min_window_samples=4, shed_levels=3, clear_ratio=0.5,
              clear_windows=2, clock=clk)
    kw.update(over)
    cfg = ControlConfig(**kw)
    reg = Registry()
    q = RequestQueue(max_depth=32)
    rec = ListRecorder()
    return BrownoutController(q, reg, cfg, recorder=rec), reg, q, rec


def _feed(reg, value, n=8, cls="hot"):
    h = reg.histogram("request_latency_s", cls=cls)
    for _ in range(n):
        h.observe(value)


def test_brownout_breach_raises_one_level_per_window():
    clk = FakeClock()
    bo, reg, q, rec = _brownout(clk)
    _feed(reg, 0.4)  # p99 far over the 50ms budget
    assert bo.tick() == 1
    assert q.shed_level == 1 and bo.shed_raises == 1
    _feed(reg, 0.4)
    assert bo.tick() == 2
    _feed(reg, 0.4)
    assert bo.tick() == 3
    _feed(reg, 0.4)
    assert bo.tick() is None  # ladder tops out at shed_levels
    assert q.shed_level == 3
    evs = rec.of("shed_level")
    assert [e["level"] for e in evs] == [1, 2, 3]
    assert all(e["raised"] for e in evs)
    assert evs[0]["cls"] == "hot" and evs[0]["budget_s"] == 0.05


def test_brownout_recovery_is_hysteretic():
    clk = FakeClock()
    bo, reg, q, rec = _brownout(clk)
    _feed(reg, 0.4)
    bo.tick()
    assert q.shed_level == 1
    # one clear window is not enough (clear_windows=2)
    _feed(reg, 0.001)
    assert bo.tick() is None and q.shed_level == 1
    _feed(reg, 0.001)
    assert bo.tick() == 0
    assert q.shed_level == 0 and bo.shed_drops == 1


def test_brownout_idle_window_is_not_recovery():
    clk = FakeClock()
    bo, reg, q, rec = _brownout(clk)
    _feed(reg, 0.4)
    bo.tick()
    assert q.shed_level == 1
    # windows with no samples must not walk the level back down
    for _ in range(5):
        assert bo.tick() is None
    assert q.shed_level == 1


def test_brownout_near_budget_resets_clear_streak():
    clk = FakeClock()
    bo, reg, q, rec = _brownout(clk)
    _feed(reg, 0.4)
    bo.tick()
    _feed(reg, 0.001)
    bo.tick()  # streak 1/2
    _feed(reg, 0.04)  # below budget but above clear_ratio * budget
    assert bo.tick() is None
    _feed(reg, 0.001)
    assert bo.tick() is None  # streak restarted: 1/2 again
    assert q.shed_level == 1


def test_brownout_maybe_tick_respects_window_cadence():
    clk = FakeClock()
    bo, reg, q, rec = _brownout(clk)
    _feed(reg, 0.4)
    assert bo.maybe_tick() is None  # window not yet elapsed
    assert bo.ticks == 0
    clk.advance(1.01)
    assert bo.maybe_tick() == 1
    assert bo.ticks == 1


def test_brownout_set_budget_live():
    clk = FakeClock()
    bo, reg, q, rec = _brownout(clk)
    _feed(reg, 0.01)  # fine under the default 50ms budget
    assert bo.tick() is None
    bo.set_budget("hot", 1e-6)
    _feed(reg, 0.01)  # same traffic now breaches
    assert bo.tick() == 1


# ---------------------------------------------------------------------------
# HedgeController
# ---------------------------------------------------------------------------


def test_hedge_deadline_defaults_to_budget_without_estimate():
    clk = FakeClock()
    cfg = ControlConfig(window_s=1.0, min_window_samples=4, clock=clk)
    hc = HedgeController(Registry(), cfg)
    assert hc.deadline("hot", 0.05) == 0.05
    assert hc.deadline("hot", None) is None


def test_hedge_deadline_tracks_quantile_clamped_to_budget():
    clk = FakeClock()
    cfg = ControlConfig(window_s=1.0, min_window_samples=4,
                        hedge_factor=1.5, hedge_floor_s=1e-3, clock=clk)
    reg = Registry()
    hc = HedgeController(reg, cfg)
    hc.deadline("hot", 0.5)  # registers the class
    h = reg.histogram("router_latency_s", cls="hot")
    for _ in range(8):
        h.observe(0.01)
    clk.advance(1.01)
    d = hc.deadline("hot", 0.5)
    assert d < 0.5  # adaptive: hedges far earlier than the static budget
    assert d >= cfg.hedge_floor_s
    # the static budget is the worst case: a slow window clamps to it
    for _ in range(8):
        h.observe(30.0)
    clk.advance(1.01)
    assert hc.deadline("hot", 0.5) == 0.5


def test_hedge_floor_clamp():
    clk = FakeClock()
    cfg = ControlConfig(window_s=1.0, min_window_samples=2,
                        hedge_floor_s=0.25, clock=clk)
    reg = Registry()
    hc = HedgeController(reg, cfg)
    hc.deadline("hot", 0.5)
    h = reg.histogram("router_latency_s", cls="hot")
    for _ in range(4):
        h.observe(1e-5)
    clk.advance(1.01)
    assert hc.deadline("hot", 0.5) == 0.25


# ---------------------------------------------------------------------------
# pressure-aware invocation cadence
# ---------------------------------------------------------------------------


def _dirty_taper(policy):
    g = musicbrainz_like(200, seed=3)
    ot = OnlineTaper(g, 4, policy=policy,
                     config=TaperConfig(max_iterations=2))
    # enough dirt to arm the topology trigger
    for i in range(40):
        ot.apply_mutations(MutationBatch(add_edges=[(i % 50, (i * 3) % 50)]))
    return ot


def test_policy_defers_invocation_under_pressure():
    pol = OnlinePolicy(bootstrap_after_ticks=None, cadence=10**9,
                       min_interval=0, dirty_fraction=0.01,
                       drift_l1=9e9, ipt_regression=9e9,
                       defer_above_pressure=0.5)
    ot = _dirty_taper(pol)
    assert ot.poll(pressure=0.9) is None  # trigger armed but deferred
    assert ot.pressure_deferrals == 1
    assert ot.poll(pressure=0.1) is not None  # fires once pressure drops
    assert ot.pressure_deferrals == 1


def test_policy_accelerates_at_idle():
    # regression 1.12x: below the 1.2 threshold, but the idle-relaxed
    # threshold 1 + (1.2 - 1) * 0.5 = 1.1 catches it
    base = OnlinePolicy(bootstrap_after_ticks=None, cadence=10**9,
                        min_interval=0, dirty_fraction=2.0,
                        drift_l1=9e9, ipt_regression=1.2,
                        accelerate_below_pressure=0.2, accel_factor=0.5)
    g = musicbrainz_like(200, seed=3)
    ot = OnlineTaper(g, 4, policy=base,
                     config=TaperConfig(max_iterations=2))
    ot.poll()  # no baseline yet; establish one
    ot.invoke("seed")
    ot._ipt_at_invoke = 1.0
    assert ot.poll(measured_ipt=1.12, pressure=0.5) is None
    assert ot.poll(measured_ipt=1.12, pressure=0.1) is not None


# ---------------------------------------------------------------------------
# ship-channel breaker
# ---------------------------------------------------------------------------


def test_ship_channel_breaker_fast_fails_open_link():
    clk = FakeClock()
    ch = ShipChannel("replica-1")
    ch.breaker = Breaker("ship-replica-1", window=4, min_failures=1,
                         error_rate=1.0, cooldown_s=5.0, clock=clk)
    ch.breaker.record_failure()  # link declared dead
    assert not ch.send(Frame(kind="commit", epoch=1, seq=1))
    assert ch.breaker_fastfail == 1 and ch.blocked == 1
    # half-open probe after cooldown actually attempts the transport
    clk.advance(5.01)
    assert ch.send(Frame(kind="commit", epoch=1, seq=2))
    assert ch.breaker.state == "closed"


# ---------------------------------------------------------------------------
# ServingLoop integration: brownout + pressure surface in stats()
# ---------------------------------------------------------------------------


def test_serving_loop_brownout_end_to_end(tmp_path):
    clk = FakeClock()
    ctl = ControlConfig(slo_budget_s={"hot": 1e-6}, window_s=0.5,
                        min_window_samples=2, shed_levels=2,
                        clear_windows=1, clock=clk)
    pol = OnlinePolicy(bootstrap_after_ticks=0, cadence=10**9,
                       min_interval=0, dirty_fraction=2.0,
                       drift_l1=9e9, ipt_regression=9e9)
    loop = ServingLoop(
        musicbrainz_like(300, seed=7), 4,
        taper_config=TaperConfig(max_iterations=2), policy=pol,
        config=ServeLoopConfig(micro_batch=8, overlap_invocations=False,
                               snapshot_dir=str(tmp_path), control=ctl))
    try:
        for _ in range(4):
            loop.submit(MQ1, cls="hot")
            loop.submit(MQ3, cls="cold")
        loop.pump()
        clk.advance(0.51)
        loop.submit(MQ1, cls="hot")
        loop.pump()  # controller window: the 1µs budget is breached
        st = loop.stats()
        assert st["shed_level"] == 1
        assert 0.0 <= st["serve_pressure"] <= 1.0
        assert st["backend_breaker_state"] == "closed"
        # a second breached window tops the ladder out: cold traffic is
        # rejected outright, not just depth-limited
        for _ in range(4):
            loop.submit(MQ1, cls="hot")
        loop.pump()
        clk.advance(0.51)
        loop.submit(MQ1, cls="hot")
        loop.pump()
        assert loop.stats()["shed_level"] == 2
        rej = 0
        for _ in range(12):
            if isinstance(loop.submit(MQ3, cls="cold"), Rejection):
                rej += 1
        assert rej > 0
        assert loop.stats()["rejected_brownout"] == rej
        # recovery: budget restored, traffic clears, admission re-opens
        loop._brownout.set_budget("hot", 1e9)
        for _ in range(4):  # one clear window per level step-down
            for _ in range(4):
                loop.submit(MQ1, cls="hot")
            loop.pump()
            clk.advance(0.51)
            loop.submit(MQ1, cls="hot")
            loop.pump()
            if loop.stats()["shed_level"] == 0:
                break
        assert loop.stats()["shed_level"] == 0
    finally:
        loop.stop()
