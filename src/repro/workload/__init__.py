from repro.workload.sketch import FrequencySketch
from repro.workload.stream import (
    GraphMutationStream,
    WorkloadStream,
    periodic_frequencies,
    linear_drift,
)
from repro.workload.executor import QueryExecutor, ipt_of_partition

__all__ = [
    "FrequencySketch",
    "GraphMutationStream",
    "WorkloadStream",
    "periodic_frequencies",
    "linear_drift",
    "QueryExecutor",
    "ipt_of_partition",
]
