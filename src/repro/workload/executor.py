"""Vectorised RPQ executor and exact inter-partition-traversal (ipt) counting.

This is the evaluation oracle for partition quality (paper §6.1: "we measure
this experimentally by executing snapshots of query workloads over
partitioned graphs and counting the number of inter-partition traversals").

The executor enumerates (by counting, not materialising) every traversal a
pattern-matching engine would perform: a path instance `v_1 ... v_j` whose
label string is a prefix of some string in str(Q) causes one traversal per
extension edge.  Counting is a DP over (vertex, trie-node) states — the
integer twin of the Visitor-Matrix probability DP — run in float64 numpy so
results are deterministic (bit-identical across full rebuild and the
incremental path below).

Because per-edge traversal counts depend only on (graph, query) — not on the
partitioning — they are computed once and cached; `ipt` for any partitioning
is then a masked sum over cut edges.  Under topology mutations
(``LabelledGraph.apply_mutations``) the cache is *delta-aware*: the DP state
(per-(vertex, trie-node) path counts plus per-edge traversal counts) is
patched by re-deriving only the states and edges whose (src-state,
dst-label) contributions changed — the dirty set is propagated depth by
depth from the mutated endpoints, so a small mutation batch costs
O(affected neighbourhood), not a full DP over the graph.  Path
materialisation (for the serving engine) is a separate bounded enumeration.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ
from repro.core.tpstry import TPSTry, TrieArrays
from repro.graphs.graph import AppliedMutation, LabelledGraph
from repro.utils import get_logger

log = get_logger("workload.executor")


@dataclass
class _CountState:
    """Cached DP state for one (graph version, query)."""

    version: int
    trav: np.ndarray          # (m,) float64 per-edge traversal counts
    cnt: np.ndarray           # (n, N) float64 per-(vertex, trie-node) counts
    depth1: List[Tuple[int, int]]   # (node, label) for depth-1 nodes
    steps: List[Tuple[int, int, int]]  # (node, parent, label), depth order


@dataclass
class _EnumPlan:
    """Graph-independent enumeration plan for one query: the label-id
    target strings, their prefix closure and the admissible first labels.
    Depends only on (query, label_names) — label ids are stable across
    topology mutations and relabels — so plans are shared across the
    requests of a serving micro-batch and across graph versions."""

    targets: frozenset       # of tuple(label_id, ...)
    prefixes: frozenset
    first_labels: np.ndarray  # unique admissible first label ids
    max_len: int


def _count_full(g: LabelledGraph, depth1, steps, n_trie: int) -> Tuple[np.ndarray, np.ndarray]:
    """Full traversal-count DP over the whole edge list (the rebuild path)."""
    n, m = g.n, g.m
    cnt = np.zeros((n, n_trie), dtype=np.float64)
    for i, li in depth1:
        cnt[:, i] = (g.labels == li).astype(np.float64)
    trav = np.zeros(m, dtype=np.float64)
    src, dst = g.src, g.dst
    lab_dst = g.labels[dst]
    for c, par, lc in steps:
        contrib = cnt[src, par] * (lab_dst == lc)
        trav += contrib
        if m:
            cnt[:, c] += np.bincount(dst, weights=contrib, minlength=n)[:n]
    return trav, cnt


class QueryExecutor:
    """Caches per-query per-edge traversal counts for a graph.

    The cache follows the graph's mutation ``version``: a stale entry is
    patched incrementally from ``LabelledGraph.mutation_log`` when the log
    still covers the gap (and the graph is symmetric, so in-edges can be
    enumerated through ``reverse_edge_index``), and rebuilt from scratch
    otherwise.  Both paths produce bit-identical counts.
    """

    #: bound on the per-query enumeration-plan cache (each plan is a few
    #: small python sets; the bound only guards pathological workloads)
    PLAN_CACHE_LIMIT = 256

    def __init__(self, g: LabelledGraph, star_max: int = 3, max_len: Optional[int] = None):
        self.g = g
        self.star_max = star_max
        self.max_len = max_len
        self._cache: Dict[str, _CountState] = {}
        self._plan_cache: Dict[str, "_EnumPlan"] = {}

    def traversals(self, q: RPQ) -> np.ndarray:
        """(m,) float64 — number of times each directed edge is traversed
        when fully evaluating ``q`` over the graph."""
        qh = q.qhash
        state = self._cache.get(qh)
        if state is not None and state.version == self.g.version:
            return state.trav
        if state is not None:
            patched = self._patch(state)
            if patched is not None:
                self._cache[qh] = patched
                return patched.trav
        self._cache[qh] = self._build(q)
        return self._cache[qh].trav

    def _compile(self, q: RPQ) -> TrieArrays:
        return TPSTry.from_workload(
            [(q, 1.0)], max_len=self.max_len, star_max=self.star_max
        ).compile(self.g.label_names)

    def _build(self, q: RPQ) -> _CountState:
        trie = self._compile(q)
        depth1 = [
            (int(i), int(trie.label[i]))
            for i in range(trie.n_nodes)
            if trie.depth[i] == 1
        ]
        steps = [
            (int(i), int(trie.parent[i]), int(trie.label[i]))
            for i in range(trie.n_nodes)
            if trie.depth[i] >= 2
        ]
        trav, cnt = _count_full(self.g, depth1, steps, trie.n_nodes)
        return _CountState(self.g.version, trav, cnt, depth1, steps)

    # -- incremental maintenance ----------------------------------------------
    def _covering_mutations(self, version: int) -> Optional[List[AppliedMutation]]:
        """The contiguous mutation-log chain taking ``version`` to the
        graph's current version, or None if the log no longer covers it.

        Log compaction composes old records into wider spans
        (``version_base -> version``), so the walk chains on spans rather
        than assuming one version per record; a snapshot that falls
        *strictly inside* a compacted span can no longer be patched."""
        entries = sorted(
            (e for e in self.g.mutation_log if e.version > version),
            key=lambda e: e.version)
        chain: List[AppliedMutation] = []
        cur = version
        for e in entries:
            if e.version_base == cur:
                chain.append(e)
                cur = e.version
            elif e.version_base > cur:
                return None  # gap: the log lost the span starting at cur
        if not chain or cur != self.g.version:
            return None
        return chain

    def _patch(self, state: _CountState) -> Optional[_CountState]:
        """Patch a stale DP state across the mutation gap, or None to force
        a rebuild.

        The patch never needs the intermediate graph snapshots: the per-edge
        index maps of the covered mutations compose into one old->new map,
        the structural endpoints union into one dirty seed set, and every
        affected quantity is then re-derived against the *final* arrays —
        per trie node, the (vertex, node) counts of affected destinations
        are recomputed from their in-edges (through ``reverse_edge_index``,
        in ascending edge order, matching ``np.bincount``'s accumulation
        order so the result is bit-identical to a full rebuild), and dirty
        destinations propagate to the next depth only when the recomputed
        value actually changed.
        """
        g = self.g
        entries = self._covering_mutations(state.version)
        if entries is None:
            return None
        if not g.is_symmetric():
            return None  # need total rev index to enumerate in-edges
        n_new, m_new = g.n, g.m
        n_before = entries[0].n_before

        # compose old->new edge index maps across the gap
        old2new = entries[0].old2new
        for e in entries[1:]:
            valid = old2new >= 0
            nxt = np.full(old2new.shape[0], -1, dtype=np.int64)
            nxt[valid] = e.old2new[old2new[valid]]
            old2new = nxt
        surv_old = np.nonzero(old2new >= 0)[0]
        surv_new = old2new[surv_old]
        # edges with no pre-gap ancestor are "added" w.r.t. the cached state
        is_mapped = np.zeros(m_new, dtype=bool)
        is_mapped[surv_new] = True
        added_pos = np.nonzero(~is_mapped)[0]

        # net re-labellings across the gap: earliest old, latest new; a
        # round-trip flip nets out (consumers re-derive vs final labels)
        rl_net: Dict[int, Tuple[int, int]] = {}
        for e in entries:
            for v, o, nw in zip(e.relabel_v.tolist(), e.relabel_old.tolist(),
                                e.relabel_new.tolist()):
                rl_net[v] = (rl_net[v][0], nw) if v in rl_net else (o, nw)
        rl_items = sorted(
            (v, o) for v, (o, nw) in rl_net.items()
            if o != nw and v < n_before)  # >= n_before: already conservative
        rl_v = np.asarray([v for v, _ in rl_items], dtype=np.int64)
        rl_old = np.asarray([o for _, o in rl_items], dtype=np.int64)

        # structural dirty endpoints (vertex ids are stable across versions)
        seed_dst: List[np.ndarray] = [g.dst[added_pos].astype(np.int64), rl_v]
        for e in entries:
            seed_dst.append(e.removed_dst.astype(np.int64))
        seed_dst_all = np.unique(np.concatenate(seed_dst)) if seed_dst else \
            np.empty(0, np.int64)
        seed_dst_all = seed_dst_all[seed_dst_all < n_new]

        N = state.cnt.shape[1]
        trav = np.zeros(m_new, dtype=np.float64)
        trav[surv_new] = state.trav[surv_old]
        cnt = np.zeros((n_new, N), dtype=np.float64)
        cnt[:n_before] = state.cnt
        changed = np.zeros((n_new, N), dtype=bool)
        labels = g.labels
        for i, li in state.depth1:
            cnt[n_before:, i] = (labels[n_before:] == li).astype(np.float64)
        changed[n_before:, :] = True  # brand-new vertices: conservative

        rev = g.reverse_edge_index
        src, dst = g.src, g.dst
        touched: List[np.ndarray] = [added_pos]
        if rl_v.size:
            # depth-1 base case of every re-labelled vertex follows its
            # final label directly
            for i, li in state.depth1:
                newv = (labels[rl_v] == li).astype(np.float64)
                diff = newv != cnt[rl_v, i]
                changed[rl_v[diff], i] = True
                cnt[rl_v, i] = newv
            # deeper nodes gated on the *old* label go to zero now (the
            # vertex no longer matches); nodes gated on the new label are
            # re-derived by the seeded step loop below.  Marking `changed`
            # up front is safe: the loop only ever adds marks, and a zeroed
            # count is the vertex's final value for that node.
            for c, par, lc in state.steps:
                vs = rl_v[(rl_old == lc) & (labels[rl_v] != lc)]
                if vs.size:
                    stale = cnt[vs, c] != 0.0
                    changed[vs[stale], c] = True
                    cnt[vs, c] = 0.0
            # every in-edge of a re-labelled vertex carries a (src-state,
            # dst-label) contribution whose label test flipped
            touched.append(rev[g.edge_indices_of(rl_v)])
        for c, par, lc in state.steps:
            dirty_src = np.nonzero(changed[:, par])[0]
            eidx = g.edge_indices_of(dirty_src) if dirty_src.size else \
                np.empty(0, np.int64)
            if eidx.size:
                eidx = eidx[labels[dst[eidx]] == lc]
            if eidx.size:
                touched.append(eidx)
            aff_v = np.unique(np.concatenate([
                dst[eidx].astype(np.int64),
                seed_dst_all[labels[seed_dst_all] == lc],
            ]))
            if aff_v.size == 0:
                continue
            in_pos = rev[g.edge_indices_of(aff_v)]
            # per-destination in-edge sums, ascending edge order per bin
            # (identical accumulation order to the full DP's bincount)
            newvals = np.bincount(
                dst[in_pos], weights=cnt[src[in_pos], par], minlength=n_new
            )[aff_v] if in_pos.size else np.zeros(aff_v.size)
            upd = newvals != cnt[aff_v, c]
            changed[aff_v[upd], c] = True
            cnt[aff_v, c] = newvals

        # re-derive full traversal counts for every touched edge, summing
        # node contributions in the same (depth) order as the full DP
        eall = np.unique(np.concatenate(touched)) if touched else \
            np.empty(0, np.int64)
        if eall.size:
            t = np.zeros(eall.size, dtype=np.float64)
            s_e, lab_e = src[eall], labels[dst[eall]]
            for c, par, lc in state.steps:
                t += cnt[s_e, par] * (lab_e == lc)
            trav[eall] = t
        return _CountState(g.version, trav, cnt, state.depth1, state.steps)

    # -- metrics ---------------------------------------------------------------
    def ipt(self, q: RPQ, part: np.ndarray) -> float:
        """Inter-partition traversals for query ``q`` under ``part``."""
        trav = self.traversals(q)
        cut = part[self.g.src] != part[self.g.dst]
        return float(trav[cut].sum())

    def total_traversals(self, q: RPQ) -> float:
        return float(self.traversals(q).sum())

    def workload_ipt(
        self, workload: Sequence[Tuple[RPQ, float]], part: np.ndarray
    ) -> float:
        """Frequency-weighted expected ipt per query execution."""
        return sum(f * self.ipt(q, part) for q, f in workload)

    # -- path materialisation (serving) ---------------------------------------
    def _enum_plan(self, q: RPQ) -> _EnumPlan:
        """Cached enumeration plan (see :class:`_EnumPlan`)."""
        qh = q.qhash
        plan = self._plan_cache.get(qh)
        if plan is None:
            strings = q.strings(self.max_len or 32, self.star_max)
            name_to_id = {s: i for i, s in enumerate(self.g.label_names)}
            targets = frozenset(
                tuple(name_to_id[s] for s in st)
                for st in strings if all(x in name_to_id for x in st))
            prefixes = frozenset(
                tuple(t[:i]) for t in targets for i in range(1, len(t) + 1))
            plan = _EnumPlan(
                targets=targets,
                prefixes=prefixes,
                first_labels=np.asarray(
                    sorted({t[0] for t in targets}), dtype=np.int64),
                max_len=max((len(t) for t in targets), default=0))
            while len(self._plan_cache) >= self.PLAN_CACHE_LIMIT:
                self._plan_cache.pop(next(iter(self._plan_cache)))
            self._plan_cache[qh] = plan
        return plan

    def enumerate_paths(
        self, q: RPQ, max_results: int = 100, part: Optional[np.ndarray] = None
    ) -> Tuple[List[Tuple[int, ...]], int]:
        """Materialise up to ``max_results`` full matches of ``q``.

        Returns (paths, ipt_incurred). A full match is a path whose label
        string is in str(Q). ipt counts boundary crossings on the returned
        paths only (the serving engine's per-request accounting).
        """
        g = self.g
        plan = self._enum_plan(q)
        targets, prefixes = plan.targets, plan.prefixes
        max_len = plan.max_len
        results: List[Tuple[int, ...]] = []
        crossings = 0

        # DFS from every vertex matching a first label (ascending id order,
        # so the LIFO exploration order matches the per-vertex scan)
        starts = np.nonzero(np.isin(g.labels, plan.first_labels))[0]
        stack: List[Tuple[Tuple[int, ...], Tuple[int, ...]]] = [
            ((int(v),), (int(g.labels[v]),)) for v in starts
        ]
        while stack and len(results) < max_results:
            path, labs = stack.pop()
            if labs in targets:
                results.append(path)
                if part is not None:
                    crossings += int(
                        sum(part[a] != part[b] for a, b in zip(path, path[1:]))
                    )
                continue
            if len(labs) >= max_len:
                continue
            v = path[-1]
            for u in g.neighbors(v):
                nl = labs + (int(g.labels[u]),)
                if nl in prefixes:
                    stack.append((path + (int(u),), nl))
        return results, crossings

    def enumerate_paths_many(
        self,
        queries: Sequence[RPQ],
        max_results: int = 100,
        part: Optional[np.ndarray] = None,
    ) -> List[Tuple[List[Tuple[int, ...]], int]]:
        """Batched :meth:`enumerate_paths` over one serving micro-batch.

        The trie-expansion/plan work (``str(Q)`` strings, prefix closure,
        start-vertex scan, DFS) is shared across the batch: each *distinct*
        query is enumerated once and its result fanned out to every request
        position that asked for it — the common serving case of a hot query
        repeated within a micro-batch pays one enumeration.  Results are
        positionally aligned with ``queries`` and identical to calling
        :meth:`enumerate_paths` per query.
        """
        out: List[Optional[Tuple[List[Tuple[int, ...]], int]]] = \
            [None] * len(queries)
        by_hash: Dict[str, List[int]] = {}
        for i, q in enumerate(queries):
            by_hash.setdefault(q.qhash, []).append(i)
        for idxs in by_hash.values():
            paths, ipt = self.enumerate_paths(
                queries[idxs[0]], max_results=max_results, part=part)
            out[idxs[0]] = (paths, ipt)
            for i in idxs[1:]:
                # fresh list per position: duplicate requests must not
                # alias one mutable result (the path tuples are immutable)
                out[i] = (list(paths), ipt)
        return out


def ipt_of_partition(
    g: LabelledGraph,
    workload: Sequence[Tuple[RPQ, float]],
    part: np.ndarray,
    executor: Optional[QueryExecutor] = None,
) -> float:
    """Convenience wrapper: expected ipt of a partitioning under a workload."""
    ex = executor or QueryExecutor(g)
    return ex.workload_ipt(workload, part)
