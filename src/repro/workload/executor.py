"""Vectorised RPQ executor and exact inter-partition-traversal (ipt) counting.

This is the evaluation oracle for partition quality (paper §6.1: "we measure
this experimentally by executing snapshots of query workloads over
partitioned graphs and counting the number of inter-partition traversals").

The executor enumerates (by counting, not materialising) every traversal a
pattern-matching engine would perform: a path instance `v_1 ... v_j` whose
label string is a prefix of some string in str(Q) causes one traversal per
extension edge.  Counting is a DP over (vertex, trie-node) states — the
integer twin of the Visitor-Matrix probability DP — run in float64 numpy so
results are deterministic (bit-identical across full rebuild and the
incremental path below).

Because per-edge traversal counts depend only on (graph, query) — not on the
partitioning — they are computed once and cached; `ipt` for any partitioning
is then a masked sum over cut edges.  Under topology mutations
(``LabelledGraph.apply_mutations``) the cache is *delta-aware*: the DP state
(per-(vertex, trie-node) path counts plus per-edge traversal counts) is
patched by re-deriving only the states and edges whose (src-state,
dst-label) contributions changed — the dirty set is propagated depth by
depth from the mutated endpoints, so a small mutation batch costs
O(affected neighbourhood), not a full DP over the graph.

Path materialisation (the serving request path) is a *batched
frontier enumeration*: instead of a per-query recursive DFS, the whole
micro-batch's prefix tree is expanded depth by depth as vectorised segment
gather sweeps over the CSR arrays (``row_ptr``/``dst`` — the same idiom as
``swap_iteration`` and ``segment_spmm``).

**Frontier-row layout.**  A frontier at depth ``d`` is a struct-of-arrays of
live prefix rows ``(qid, state, tail)``: ``qid`` indexes the micro-batch's
*distinct* queries, ``state`` is a node of that query's compiled prefix trie
(``_EnumPlan.trans``/``is_target``; state 0 = the empty root), and ``tail``
is the path's last vertex.  One sweep expands every row's out-edges at once
(``np.repeat`` over CSR degree counts), advances states through the stacked
``trans[qid, state, label]`` table (label-mask pruning against the shared
prefix closure: a ``-1`` transition kills the row), and splits the survivors
into *emitted* rows (target states — recorded, never extended, exactly like
the DFS's emit-and-continue) and the next depth's frontier.  Per-depth
``(vertex, parent_row, qid)`` level arrays make path reconstruction a
backward gather.

**DFS-order-reproducing truncation.**  The reference DFS
(:meth:`QueryExecutor.enumerate_paths_ref`) seeds its stack with the start
vertices in ascending id order and pushes neighbours ascending, so it pops —
and therefore *emits* — matches in **descending lexicographic order of their
vertex tuples** (emitted matches form an antichain under prefix order, so
the first differing vertex always decides).  The batched engine reproduces
that order exactly: start vertices are processed descending in
geometrically growing chunks, each chunk's emissions are lexsorted
descending on the padded vertex matrix, and chunks stop as soon as a
query's ``max_results`` is reached — bit-identical paths, emission order
and ipt to the DFS at any truncation point, while a hot truncated query
only pays for the chunks it consumed.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ
from repro.core.tpstry import TPSTry, TrieArrays
from repro.graphs.graph import AppliedMutation, LabelledGraph
from repro.utils import get_logger

log = get_logger("workload.executor")


@dataclass
class _CountState:
    """Cached DP state for one (graph version, query)."""

    version: int
    trav: np.ndarray          # (m,) float64 per-edge traversal counts
    cnt: np.ndarray           # (n, N) float64 per-(vertex, trie-node) counts
    depth1: List[Tuple[int, int]]   # (node, label) for depth-1 nodes
    steps: List[Tuple[int, int, int]]  # (node, parent, label), depth order


@dataclass
class _EnumPlan:
    """Graph-independent enumeration plan for one query: the label-id
    target strings, their prefix closure and the admissible first labels,
    plus the prefix closure *compiled* to a trie transition table for the
    batched frontier engine.  Depends only on (query, label_names) — label
    ids are stable across topology mutations and relabels — so plans are
    shared across the requests of a serving micro-batch and across graph
    versions."""

    targets: frozenset       # of tuple(label_id, ...)
    prefixes: frozenset
    first_labels: np.ndarray  # unique admissible first label ids
    max_len: int
    # -- compiled trie (batched enumeration) --------------------------------
    #: state count incl. the root (state 0 = empty prefix)
    n_states: int = 1
    #: label-alphabet width the table was compiled against
    n_labels: int = 0
    #: (n_states, n_labels) int32 state transitions; -1 = dead (the label
    #: string leaves the prefix closure)
    trans: np.ndarray = field(default_factory=lambda: np.full((1, 0), -1, np.int32))
    #: (n_states,) bool — state's prefix is a full match (emit, never extend)
    is_target: np.ndarray = field(default_factory=lambda: np.zeros(1, bool))
    #: owning query's qhash (keys the per-graph-version starts cache)
    qh: str = ""


def _count_full(g: LabelledGraph, depth1, steps, n_trie: int) -> Tuple[np.ndarray, np.ndarray]:
    """Full traversal-count DP over the whole edge list (the rebuild path)."""
    n, m = g.n, g.m
    cnt = np.zeros((n, n_trie), dtype=np.float64)
    for i, li in depth1:
        cnt[:, i] = (g.labels == li).astype(np.float64)
    trav = np.zeros(m, dtype=np.float64)
    src, dst = g.src, g.dst
    lab_dst = g.labels[dst]
    for c, par, lc in steps:
        contrib = cnt[src, par] * (lab_dst == lc)
        trav += contrib
        if m:
            cnt[:, c] += np.bincount(dst, weights=contrib, minlength=n)[:n]
    return trav, cnt


class QueryExecutor:
    """Caches per-query per-edge traversal counts for a graph.

    The cache follows the graph's mutation ``version``: a stale entry is
    patched incrementally from ``LabelledGraph.mutation_log`` when the log
    still covers the gap (and the graph is symmetric, so in-edges can be
    enumerated through ``reverse_edge_index``), and rebuilt from scratch
    otherwise.  Both paths produce bit-identical counts.
    """

    #: bound on the per-query enumeration-plan cache (each plan is a few
    #: small python sets plus the compiled trie arrays; the bound only
    #: guards pathological workloads).  Eviction is LRU: a hit moves the
    #: plan to the back, so hot serving queries survive cache pressure.
    PLAN_CACHE_LIMIT = 256

    #: start-vertex chunking of the batched enumeration: the first round
    #: expands this many start subtrees per query, growing geometrically —
    #: a truncated (max_results-bounded) query stops scheduling chunks as
    #: soon as its results are in, like the DFS stops popping
    ENUM_CHUNK0 = 32
    ENUM_CHUNK_GROWTH = 4

    def __init__(self, g: LabelledGraph, star_max: int = 3, max_len: Optional[int] = None):
        self.g = g
        self.star_max = star_max
        self.max_len = max_len
        self._cache: Dict[str, _CountState] = {}
        self._plan_cache: "OrderedDict[str, _EnumPlan]" = OrderedDict()
        #: serialises plan-cache access so multi-worker serving loops can
        #: share one executor (the enumeration sweeps themselves only read
        #: graph arrays and are lock-free)
        self._plan_lock = threading.Lock()
        #: counters of the most recent batched enumeration (sweeps = depth
        #: expansions executed, frontier_rows = total live prefix rows) —
        #: per-call copies go to the ``stats=`` out-param for callers that
        #: share the executor across threads
        self.last_enum_stats: Dict[str, int] = {
            "enum_sweeps": 0, "frontier_rows": 0}
        #: lifetime counters behind the metrics registry's ``collect()``
        #: protocol (cumulative across every batched enumeration; benign
        #: GIL-atomic increments under concurrent workers)
        self.total_enum_calls = 0
        self.total_enum_sweeps = 0
        self.total_frontier_rows = 0
        self.plans_compiled = 0
        self.plan_cache_hits = 0
        #: descending start-vertex lists keyed qhash -> (graph version,
        #: starts); a benign data race under concurrent workers at worst
        #: recomputes one entry
        self._starts_cache: Dict[str, Tuple[int, np.ndarray]] = {}

    def traversals(self, q: RPQ) -> np.ndarray:
        """(m,) float64 — number of times each directed edge is traversed
        when fully evaluating ``q`` over the graph."""
        qh = q.qhash
        state = self._cache.get(qh)
        if state is not None and state.version == self.g.version:
            return state.trav
        if state is not None:
            patched = self._patch(state)
            if patched is not None:
                self._cache[qh] = patched
                return patched.trav
        self._cache[qh] = self._build(q)
        return self._cache[qh].trav

    def _compile(self, q: RPQ) -> TrieArrays:
        return TPSTry.from_workload(
            [(q, 1.0)], max_len=self.max_len, star_max=self.star_max
        ).compile(self.g.label_names)

    def _build(self, q: RPQ) -> _CountState:
        trie = self._compile(q)
        depth1 = [
            (int(i), int(trie.label[i]))
            for i in range(trie.n_nodes)
            if trie.depth[i] == 1
        ]
        steps = [
            (int(i), int(trie.parent[i]), int(trie.label[i]))
            for i in range(trie.n_nodes)
            if trie.depth[i] >= 2
        ]
        trav, cnt = _count_full(self.g, depth1, steps, trie.n_nodes)
        return _CountState(self.g.version, trav, cnt, depth1, steps)

    # -- incremental maintenance ----------------------------------------------
    def _covering_mutations(self, version: int) -> Optional[List[AppliedMutation]]:
        """The contiguous mutation-log chain taking ``version`` to the
        graph's current version, or None if the log no longer covers it.

        Log compaction composes old records into wider spans
        (``version_base -> version``), so the walk chains on spans rather
        than assuming one version per record; a snapshot that falls
        *strictly inside* a compacted span can no longer be patched."""
        entries = sorted(
            (e for e in self.g.mutation_log if e.version > version),
            key=lambda e: e.version)
        chain: List[AppliedMutation] = []
        cur = version
        for e in entries:
            if e.version_base == cur:
                chain.append(e)
                cur = e.version
            elif e.version_base > cur:
                return None  # gap: the log lost the span starting at cur
        if not chain or cur != self.g.version:
            return None
        return chain

    def _patch(self, state: _CountState) -> Optional[_CountState]:
        """Patch a stale DP state across the mutation gap, or None to force
        a rebuild.

        The patch never needs the intermediate graph snapshots: the per-edge
        index maps of the covered mutations compose into one old->new map,
        the structural endpoints union into one dirty seed set, and every
        affected quantity is then re-derived against the *final* arrays —
        per trie node, the (vertex, node) counts of affected destinations
        are recomputed from their in-edges (through ``reverse_edge_index``,
        in ascending edge order, matching ``np.bincount``'s accumulation
        order so the result is bit-identical to a full rebuild), and dirty
        destinations propagate to the next depth only when the recomputed
        value actually changed.
        """
        g = self.g
        entries = self._covering_mutations(state.version)
        if entries is None:
            return None
        if not g.is_symmetric():
            return None  # need total rev index to enumerate in-edges
        n_new, m_new = g.n, g.m
        n_before = entries[0].n_before

        # compose old->new edge index maps across the gap
        old2new = entries[0].old2new
        for e in entries[1:]:
            valid = old2new >= 0
            nxt = np.full(old2new.shape[0], -1, dtype=np.int64)
            nxt[valid] = e.old2new[old2new[valid]]
            old2new = nxt
        surv_old = np.nonzero(old2new >= 0)[0]
        surv_new = old2new[surv_old]
        # edges with no pre-gap ancestor are "added" w.r.t. the cached state
        is_mapped = np.zeros(m_new, dtype=bool)
        is_mapped[surv_new] = True
        added_pos = np.nonzero(~is_mapped)[0]

        # net re-labellings across the gap: earliest old, latest new; a
        # round-trip flip nets out (consumers re-derive vs final labels)
        rl_net: Dict[int, Tuple[int, int]] = {}
        for e in entries:
            for v, o, nw in zip(e.relabel_v.tolist(), e.relabel_old.tolist(),
                                e.relabel_new.tolist()):
                rl_net[v] = (rl_net[v][0], nw) if v in rl_net else (o, nw)
        rl_items = sorted(
            (v, o) for v, (o, nw) in rl_net.items()
            if o != nw and v < n_before)  # >= n_before: already conservative
        rl_v = np.asarray([v for v, _ in rl_items], dtype=np.int64)
        rl_old = np.asarray([o for _, o in rl_items], dtype=np.int64)

        # structural dirty endpoints (vertex ids are stable across versions)
        seed_dst: List[np.ndarray] = [g.dst[added_pos].astype(np.int64), rl_v]
        for e in entries:
            seed_dst.append(e.removed_dst.astype(np.int64))
        seed_dst_all = np.unique(np.concatenate(seed_dst)) if seed_dst else \
            np.empty(0, np.int64)
        seed_dst_all = seed_dst_all[seed_dst_all < n_new]

        N = state.cnt.shape[1]
        trav = np.zeros(m_new, dtype=np.float64)
        trav[surv_new] = state.trav[surv_old]
        cnt = np.zeros((n_new, N), dtype=np.float64)
        cnt[:n_before] = state.cnt
        changed = np.zeros((n_new, N), dtype=bool)
        labels = g.labels
        for i, li in state.depth1:
            cnt[n_before:, i] = (labels[n_before:] == li).astype(np.float64)
        changed[n_before:, :] = True  # brand-new vertices: conservative

        rev = g.reverse_edge_index
        src, dst = g.src, g.dst
        touched: List[np.ndarray] = [added_pos]
        if rl_v.size:
            # depth-1 base case of every re-labelled vertex follows its
            # final label directly
            for i, li in state.depth1:
                newv = (labels[rl_v] == li).astype(np.float64)
                diff = newv != cnt[rl_v, i]
                changed[rl_v[diff], i] = True
                cnt[rl_v, i] = newv
            # deeper nodes gated on the *old* label go to zero now (the
            # vertex no longer matches); nodes gated on the new label are
            # re-derived by the seeded step loop below.  Marking `changed`
            # up front is safe: the loop only ever adds marks, and a zeroed
            # count is the vertex's final value for that node.
            for c, par, lc in state.steps:
                vs = rl_v[(rl_old == lc) & (labels[rl_v] != lc)]
                if vs.size:
                    stale = cnt[vs, c] != 0.0
                    changed[vs[stale], c] = True
                    cnt[vs, c] = 0.0
            # every in-edge of a re-labelled vertex carries a (src-state,
            # dst-label) contribution whose label test flipped
            touched.append(rev[g.edge_indices_of(rl_v)])
        for c, par, lc in state.steps:
            dirty_src = np.nonzero(changed[:, par])[0]
            eidx = g.edge_indices_of(dirty_src) if dirty_src.size else \
                np.empty(0, np.int64)
            if eidx.size:
                eidx = eidx[labels[dst[eidx]] == lc]
            if eidx.size:
                touched.append(eidx)
            aff_v = np.unique(np.concatenate([
                dst[eidx].astype(np.int64),
                seed_dst_all[labels[seed_dst_all] == lc],
            ]))
            if aff_v.size == 0:
                continue
            in_pos = rev[g.edge_indices_of(aff_v)]
            # per-destination in-edge sums, ascending edge order per bin
            # (identical accumulation order to the full DP's bincount)
            newvals = np.bincount(
                dst[in_pos], weights=cnt[src[in_pos], par], minlength=n_new
            )[aff_v] if in_pos.size else np.zeros(aff_v.size)
            upd = newvals != cnt[aff_v, c]
            changed[aff_v[upd], c] = True
            cnt[aff_v, c] = newvals

        # re-derive full traversal counts for every touched edge, summing
        # node contributions in the same (depth) order as the full DP
        eall = np.unique(np.concatenate(touched)) if touched else \
            np.empty(0, np.int64)
        if eall.size:
            t = np.zeros(eall.size, dtype=np.float64)
            s_e, lab_e = src[eall], labels[dst[eall]]
            for c, par, lc in state.steps:
                t += cnt[s_e, par] * (lab_e == lc)
            trav[eall] = t
        return _CountState(g.version, trav, cnt, state.depth1, state.steps)

    # -- metrics ---------------------------------------------------------------
    def ipt(self, q: RPQ, part: np.ndarray) -> float:
        """Inter-partition traversals for query ``q`` under ``part``."""
        trav = self.traversals(q)
        cut = part[self.g.src] != part[self.g.dst]
        return float(trav[cut].sum())

    def total_traversals(self, q: RPQ) -> float:
        return float(self.traversals(q).sum())

    def workload_ipt(
        self, workload: Sequence[Tuple[RPQ, float]], part: np.ndarray
    ) -> float:
        """Frequency-weighted expected ipt per query execution."""
        return sum(f * self.ipt(q, part) for q, f in workload)

    # -- path materialisation (serving) ---------------------------------------
    def _enum_plan(self, q: RPQ) -> _EnumPlan:
        """Cached enumeration plan (see :class:`_EnumPlan`), LRU-evicted."""
        qh = q.qhash
        with self._plan_lock:
            plan = self._plan_cache.get(qh)
            if plan is not None:
                # LRU, not FIFO: a hit renews the plan, so a hot serving
                # query outlives any number of cold insertions
                self._plan_cache.move_to_end(qh)
                self.plan_cache_hits += 1
                return plan
            strings = q.strings(self.max_len or 32, self.star_max)
            name_to_id = {s: i for i, s in enumerate(self.g.label_names)}
            targets = frozenset(
                tuple(name_to_id[s] for s in st)
                for st in strings if all(x in name_to_id for x in st))
            prefixes = frozenset(
                tuple(t[:i]) for t in targets for i in range(1, len(t) + 1))
            # compile the prefix closure into a trie: state 0 is the empty
            # root, states 1.. the prefixes; a -1 transition is a dead row
            states = sorted(prefixes)
            sid = {p: i + 1 for i, p in enumerate(states)}
            n_labels = len(name_to_id)
            trans = np.full((len(states) + 1, max(n_labels, 1)), -1,
                            dtype=np.int32)
            is_target = np.zeros(len(states) + 1, dtype=bool)
            for p in states:
                parent = sid[p[:-1]] if len(p) > 1 else 0
                trans[parent, p[-1]] = sid[p]
                if p in targets:
                    is_target[sid[p]] = True
            plan = _EnumPlan(
                qh=qh,
                targets=targets,
                prefixes=prefixes,
                first_labels=np.asarray(
                    sorted({t[0] for t in targets}), dtype=np.int64),
                max_len=max((len(t) for t in targets), default=0),
                n_states=len(states) + 1,
                n_labels=n_labels,
                trans=trans,
                is_target=is_target)
            while len(self._plan_cache) >= self.PLAN_CACHE_LIMIT:
                self._plan_cache.popitem(last=False)
            self._plan_cache[qh] = plan
            self.plans_compiled += 1
            return plan

    def _starts_desc(self, plan: _EnumPlan) -> np.ndarray:
        """Descending start vertices of ``plan`` (= the DFS pop order),
        cached per graph version; serving re-enumerates the same hot
        queries between mutations, so the ``isin`` scan amortises away."""
        ent = self._starts_cache.get(plan.qh)
        if ent is not None and ent[0] == self.g.version:
            return ent[1]
        s = np.nonzero(np.isin(self.g.labels, plan.first_labels))[0]
        s = s[::-1].astype(np.int64)
        if len(self._starts_cache) >= 4 * self.PLAN_CACHE_LIMIT:
            self._starts_cache.clear()
        self._starts_cache[plan.qh] = (self.g.version, s)
        return s

    def enumerate_paths_ref(
        self, q: RPQ, max_results: int = 100, part: Optional[np.ndarray] = None
    ) -> Tuple[List[Tuple[int, ...]], int]:
        """Reference DFS enumeration — the parity oracle for the batched
        engine (see the module docstring for the emission-order argument).

        Materialises up to ``max_results`` full matches of ``q``; returns
        (paths, ipt_incurred).  A full match is a path whose label string is
        in str(Q); ipt counts boundary crossings on the returned paths only
        (the serving engine's per-request accounting).
        """
        g = self.g
        plan = self._enum_plan(q)
        targets, prefixes = plan.targets, plan.prefixes
        max_len = plan.max_len
        results: List[Tuple[int, ...]] = []
        crossings = 0

        # DFS from every vertex matching a first label (ascending id order,
        # so the LIFO exploration order matches the per-vertex scan)
        starts = np.nonzero(np.isin(g.labels, plan.first_labels))[0]
        stack: List[Tuple[Tuple[int, ...], Tuple[int, ...]]] = [
            ((int(v),), (int(g.labels[v]),)) for v in starts
        ]
        while stack and len(results) < max_results:
            path, labs = stack.pop()
            if labs in targets:
                results.append(path)
                if part is not None and len(path) > 1:
                    # one gather + compare per emitted path, not a python
                    # sum over consecutive pairs
                    pv = np.take(part, path)
                    crossings += int(np.sum(pv[1:] != pv[:-1]))
                continue
            if len(labs) >= max_len:
                continue
            v = path[-1]
            for u in g.neighbors(v):
                nl = labs + (int(g.labels[u]),)
                if nl in prefixes:
                    stack.append((path + (int(u),), nl))
        return results, crossings

    def enumerate_paths(
        self, q: RPQ, max_results: int = 100, part: Optional[np.ndarray] = None
    ) -> Tuple[List[Tuple[int, ...]], int]:
        """Materialise up to ``max_results`` full matches of ``q`` via the
        batched frontier engine — bit-identical (paths, emission order,
        ipt) to :meth:`enumerate_paths_ref`."""
        return self._enumerate_batch([self._enum_plan(q)], max_results,
                                     part)[0]

    def enumerate_paths_many(
        self,
        queries: Sequence[RPQ],
        max_results: int = 100,
        part: Optional[np.ndarray] = None,
        stats: Optional[Dict[str, int]] = None,
    ) -> List[Tuple[List[Tuple[int, ...]], int]]:
        """Batched :meth:`enumerate_paths` over one serving micro-batch.

        Every *distinct* query contributes rows to one shared frontier, so
        a single sweep per depth advances every live prefix of every query
        in the batch; duplicates of a hot query pay one enumeration and fan
        out to their request positions.  Results are positionally aligned
        with ``queries`` and bit-identical to calling
        :meth:`enumerate_paths_ref` per query.  ``stats``, when given, is
        filled with this call's ``enum_sweeps``/``frontier_rows``.
        """
        out: List[Optional[Tuple[List[Tuple[int, ...]], int]]] = \
            [None] * len(queries)
        by_hash: Dict[str, List[int]] = {}
        for i, q in enumerate(queries):
            by_hash.setdefault(q.qhash, []).append(i)
        distinct = [queries[idxs[0]] for idxs in by_hash.values()]
        plans = [self._enum_plan(q) for q in distinct]
        results = self._enumerate_batch(plans, max_results, part, stats)
        for idxs, (paths, ipt) in zip(by_hash.values(), results):
            out[idxs[0]] = (paths, ipt)
            for i in idxs[1:]:
                # fresh list per position: duplicate requests must not
                # alias one mutable result (the path tuples are immutable)
                out[i] = (list(paths), ipt)
        return out

    def _enumerate_batch(
        self,
        plans: List[_EnumPlan],
        max_results: int,
        part: Optional[np.ndarray],
        stats: Optional[Dict[str, int]] = None,
    ) -> List[Tuple[List[Tuple[int, ...]], int]]:
        """Frontier-batched enumeration over distinct plans (module doc:
        frontier-row layout, truncation rule)."""
        g = self.g
        nq = len(plans)
        out: List[Optional[Tuple[List[Tuple[int, ...]], int]]] = [None] * nq
        sweeps = 0
        frontier_rows = 0
        live = [i for i, p in enumerate(plans)
                if max_results > 0 and p.max_len > 0]
        for i in range(nq):
            if i not in live:
                out[i] = ([], 0)
        if live:
            S = max(plans[i].n_states for i in live)
            L = max(plans[i].trans.shape[1] for i in live)
            trans = np.full((nq, S, L), -1, dtype=np.int32)
            is_tgt = np.zeros((nq, S), dtype=bool)
            for i in live:
                p = plans[i]
                trans[i, :p.n_states, :p.trans.shape[1]] = p.trans
                is_tgt[i, :p.n_states] = p.is_target
            labels = np.ascontiguousarray(g.labels, dtype=np.int64)
            row_ptr = np.ascontiguousarray(g.row_ptr, dtype=np.int64)
            dst = np.ascontiguousarray(g.dst, dtype=np.int64)
            # start vertices per query, descending (= the DFS pop order)
            starts = {i: self._starts_desc(plans[i]) for i in live}
            cursor = {i: 0 for i in live}
            acc: Dict[int, List[Tuple[np.ndarray, np.ndarray]]] = \
                {i: [] for i in live}
            acc_n = {i: 0 for i in live}
            done: set = set()
            chunk = self.ENUM_CHUNK0
            while len(done) < len(live):
                qid_parts, v_parts, round_q = [], [], []
                for i in live:
                    if i in done:
                        continue
                    s = starts[i][cursor[i]: cursor[i] + chunk]
                    cursor[i] += s.size
                    if cursor[i] >= starts[i].size:
                        done.add(i)  # last chunk; results land this round
                    if s.size:
                        qid_parts.append(np.full(s.size, i, dtype=np.int64))
                        v_parts.append(s)
                        round_q.append(i)
                if not v_parts:
                    break
                qid0 = np.concatenate(qid_parts)
                v0 = np.concatenate(v_parts)
                lab0 = labels[v0]
                st0 = trans[qid0, 0, np.minimum(lab0, L - 1)].astype(np.int64)
                st0[lab0 >= L] = -1
                keep0 = st0 >= 0
                f_qid, f_state, f_tail = qid0[keep0], st0[keep0], v0[keep0]
                sweeps += 1
                frontier_rows += f_tail.size
                # per-depth levels: (vertex, parent row at prev depth, qid)
                levels = [(f_tail, np.full(f_tail.size, -1, np.int64), f_qid)]
                emits: List[Tuple[int, np.ndarray]] = []
                tgt = is_tgt[f_qid, f_state]
                if tgt.any():
                    emits.append((1, np.nonzero(tgt)[0]))
                ext = ~tgt
                f_row = np.nonzero(ext)[0]
                f_qid, f_state, f_tail = f_qid[ext], f_state[ext], f_tail[ext]
                depth = 1
                max_depth = max(plans[i].max_len for i in round_q)
                while f_tail.size and depth < max_depth:
                    base = row_ptr[f_tail]
                    cnts = row_ptr[f_tail + 1] - base
                    total = int(cnts.sum())
                    if total == 0:
                        break
                    rep = np.repeat(np.arange(f_tail.size), cnts)
                    # edge index = per-parent CSR base + within-parent
                    # offset, folded into one gather over parent rows
                    adj = base + cnts - np.cumsum(cnts)
                    eidx = np.arange(total, dtype=np.int64) + adj[rep]
                    nbr = dst[eidx]
                    nlab = labels[nbr]
                    nstate = trans[f_qid[rep], f_state[rep],
                                   np.minimum(nlab, L - 1)].astype(np.int64)
                    nstate[nlab >= L] = -1
                    keep = nstate >= 0
                    rep, nbr, nstate = rep[keep], nbr[keep], nstate[keep]
                    nqid = f_qid[rep]
                    nprev = f_row[rep]
                    depth += 1
                    sweeps += 1
                    frontier_rows += nbr.size
                    levels.append((nbr, nprev, nqid))
                    tgt = is_tgt[nqid, nstate]
                    if tgt.any():
                        emits.append((depth, np.nonzero(tgt)[0]))
                    ext = ~tgt
                    f_row = np.nonzero(ext)[0]
                    f_qid, f_state, f_tail = nqid[ext], nstate[ext], nbr[ext]
                # materialise this round's emissions: backward gather per
                # depth, then per-query descending lexsort = DFS order
                per_q: Dict[int, List[Tuple[np.ndarray, int]]] = {}
                for d, rows in emits:
                    mat = np.empty((rows.size, d), dtype=np.int64)
                    cur = rows
                    for col in range(d - 1, -1, -1):
                        verts, prev, _ = levels[col]
                        mat[:, col] = verts[cur]
                        cur = prev[cur]
                    qv = levels[d - 1][2][rows]
                    for i in np.unique(qv):
                        sel = qv == i
                        per_q.setdefault(int(i), []).append((mat[sel], d))
                for i, pieces in per_q.items():
                    W = plans[i].max_len
                    tot = sum(m.shape[0] for m, _ in pieces)
                    padded = np.full((tot, W), -1, dtype=np.int64)
                    lens = np.empty(tot, dtype=np.int64)
                    o = 0
                    for m, d in pieces:
                        padded[o:o + m.shape[0], :d] = m
                        lens[o:o + m.shape[0]] = d
                        o += m.shape[0]
                    # emitted matches are an antichain under prefix order,
                    # so the -1 padding never decides a comparison
                    order = np.lexsort(
                        [-padded[:, c] for c in range(W - 1, -1, -1)])
                    acc[i].append((padded[order], lens[order]))
                    acc_n[i] += tot
                    if acc_n[i] >= max_results:
                        done.add(i)
                chunk *= self.ENUM_CHUNK_GROWTH
            for i in live:
                if not acc[i]:
                    out[i] = ([], 0)
                    continue
                padded = np.concatenate([m for m, _ in acc[i]], axis=0)
                lens = np.concatenate([l for _, l in acc[i]])
                if padded.shape[0] > max_results:
                    padded, lens = padded[:max_results], lens[:max_results]
                crossings = 0
                if part is not None and padded.shape[1] >= 2:
                    pv = np.asarray(part)[np.clip(padded, 0, None)]
                    valid = (np.arange(1, padded.shape[1])[None, :]
                             <= (lens - 1)[:, None])
                    crossings = int(((pv[:, 1:] != pv[:, :-1]) & valid).sum())
                paths = [tuple(map(int, padded[r, :lens[r]]))
                         for r in range(padded.shape[0])]
                out[i] = (paths, crossings)
        self.last_enum_stats = {"enum_sweeps": sweeps,
                                "frontier_rows": frontier_rows}
        self.total_enum_calls += 1
        self.total_enum_sweeps += sweeps
        self.total_frontier_rows += frontier_rows
        if stats is not None:
            stats.update(self.last_enum_stats)
        return out

    def collect(self) -> Dict[str, int]:
        """Metrics-registry collector: lifetime enumeration counters and
        cache occupancy (flat numeric dict)."""
        return {
            "enum_calls": self.total_enum_calls,
            "enum_sweeps": self.total_enum_sweeps,
            "frontier_rows": self.total_frontier_rows,
            "plans_compiled": self.plans_compiled,
            "plan_cache_hits": self.plan_cache_hits,
            "plan_cache_size": len(self._plan_cache),
            "count_cache_size": len(self._cache),
        }


def ipt_of_partition(
    g: LabelledGraph,
    workload: Sequence[Tuple[RPQ, float]],
    part: np.ndarray,
    executor: Optional[QueryExecutor] = None,
) -> float:
    """Convenience wrapper: expected ipt of a partitioning under a workload."""
    ex = executor or QueryExecutor(g)
    return ex.workload_ipt(workload, part)
