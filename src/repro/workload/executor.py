"""Vectorised RPQ executor and exact inter-partition-traversal (ipt) counting.

This is the evaluation oracle for partition quality (paper §6.1: "we measure
this experimentally by executing snapshots of query workloads over
partitioned graphs and counting the number of inter-partition traversals").

The executor enumerates (by counting, not materialising) every traversal a
pattern-matching engine would perform: a path instance `v_1 ... v_j` whose
label string is a prefix of some string in str(Q) causes one traversal per
extension edge.  Counting is a DP over (vertex, trie-node) states — the
integer twin of the Visitor-Matrix probability DP.

Because per-edge traversal counts depend only on (graph, query) — not on the
partitioning — they are computed once and cached; `ipt` for any partitioning
is then a masked sum over cut edges.  Path materialisation (for the serving
engine) is a separate bounded enumeration.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rpq import RPQ
from repro.core.tpstry import TPSTry, TrieArrays
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("workload.executor")


@partial(jax.jit, static_argnames=("n", "m", "n_trie", "depth1_key", "steps_key"))
def _traversal_counts(
    src, dst, vlabels, *, n: int, m: int, n_trie: int, depth1_key, steps_key
):
    """Per-edge traversal counts for one compiled trie.

    depth1_key: tuple of (node_id, label_id) for depth-1 nodes;
    steps_key: tuple of (node_id, parent_id, label_id) for depth>=2 nodes in
    depth order.  Both static, baked into the trace.
    """
    dst_lab = vlabels[dst]
    depth1 = dict(depth1_key)
    counts = []
    for i in range(n_trie):
        if i in depth1:
            counts.append((vlabels == depth1[i]).astype(jnp.float32))
        else:
            counts.append(jnp.zeros((n,), jnp.float32))
    cnt = jnp.stack(counts, axis=1) if n_trie else jnp.zeros((n, 0), jnp.float32)

    trav = jnp.zeros((m,), jnp.float32)
    for (c, par, lc) in steps_key:
        contrib = cnt[src, par] * (dst_lab == lc).astype(jnp.float32)
        trav = trav + contrib
        cnt = cnt.at[:, c].add(jax.ops.segment_sum(contrib, dst, num_segments=n))
    return trav


class QueryExecutor:
    """Caches per-query per-edge traversal counts for a graph."""

    def __init__(self, g: LabelledGraph, star_max: int = 3, max_len: Optional[int] = None):
        self.g = g
        self.star_max = star_max
        self.max_len = max_len
        self._cache: Dict[str, np.ndarray] = {}

    def traversals(self, q: RPQ) -> np.ndarray:
        """(m,) float64 — number of times each directed edge is traversed
        when fully evaluating ``q`` over the graph."""
        qh = q.qhash
        if qh not in self._cache:
            trie = TPSTry.from_workload(
                [(q, 1.0)], max_len=self.max_len, star_max=self.star_max
            ).compile(self.g.label_names)
            self._cache[qh] = self._count(trie)
        return self._cache[qh]

    def _count(self, trie: TrieArrays) -> np.ndarray:
        steps_key = tuple(
            (int(i), int(trie.parent[i]), int(trie.label[i]))
            for i in range(trie.n_nodes)
            if trie.depth[i] >= 2
        )
        depth1_key = tuple(
            (int(i), int(trie.label[i]))
            for i in range(trie.n_nodes)
            if trie.depth[i] == 1
        )
        trav = _traversal_counts(
            jnp.asarray(self.g.src),
            jnp.asarray(self.g.dst),
            jnp.asarray(self.g.labels),
            n=self.g.n,
            m=self.g.m,
            n_trie=trie.n_nodes,
            depth1_key=depth1_key,
            steps_key=steps_key,
        )
        return np.asarray(trav, dtype=np.float64)

    # -- metrics ---------------------------------------------------------------
    def ipt(self, q: RPQ, part: np.ndarray) -> float:
        """Inter-partition traversals for query ``q`` under ``part``."""
        trav = self.traversals(q)
        cut = part[self.g.src] != part[self.g.dst]
        return float(trav[cut].sum())

    def total_traversals(self, q: RPQ) -> float:
        return float(self.traversals(q).sum())

    def workload_ipt(
        self, workload: Sequence[Tuple[RPQ, float]], part: np.ndarray
    ) -> float:
        """Frequency-weighted expected ipt per query execution."""
        return sum(f * self.ipt(q, part) for q, f in workload)

    # -- path materialisation (serving) ---------------------------------------
    def enumerate_paths(
        self, q: RPQ, max_results: int = 100, part: Optional[np.ndarray] = None
    ) -> Tuple[List[Tuple[int, ...]], int]:
        """Materialise up to ``max_results`` full matches of ``q``.

        Returns (paths, ipt_incurred). A full match is a path whose label
        string is in str(Q). ipt counts boundary crossings on the returned
        paths only (the serving engine's per-request accounting).
        """
        g = self.g
        trie = TPSTry.from_workload(
            [(q, 1.0)], max_len=self.max_len, star_max=self.star_max
        ).compile(g.label_names)
        # terminal nodes: label strings in str(Q) == nodes whose path is a
        # complete string; conservatively: leaves, plus any node marked by
        # string set membership
        strings = q.strings(self.max_len or 32, self.star_max)
        results: List[Tuple[int, ...]] = []
        crossings = 0

        name_to_id = {s: i for i, s in enumerate(g.label_names)}
        targets = {tuple(name_to_id[s] for s in st) for st in strings if all(x in name_to_id for x in st)}
        max_len = max((len(t) for t in targets), default=0)

        # DFS from every vertex matching a first label
        first_labels = {t[0] for t in targets}
        prefixes = {tuple(t[:i]) for t in targets for i in range(1, len(t) + 1)}
        stack: List[Tuple[Tuple[int, ...], Tuple[int, ...]]] = []
        for v in range(g.n):
            if g.labels[v] in first_labels:
                stack.append(((int(v),), (int(g.labels[v]),)))
        while stack and len(results) < max_results:
            path, labs = stack.pop()
            if labs in targets:
                results.append(path)
                if part is not None:
                    crossings += int(
                        sum(part[a] != part[b] for a, b in zip(path, path[1:]))
                    )
                continue
            if len(labs) >= max_len:
                continue
            v = path[-1]
            for u in g.neighbors(v):
                nl = labs + (int(g.labels[u]),)
                if nl in prefixes:
                    stack.append((path + (int(u),), nl))
        return results, crossings


def ipt_of_partition(
    g: LabelledGraph,
    workload: Sequence[Tuple[RPQ, float]],
    part: np.ndarray,
    executor: Optional[QueryExecutor] = None,
) -> float:
    """Convenience wrapper: expected ipt of a partitioning under a workload."""
    ex = executor or QueryExecutor(g)
    return ex.workload_ipt(workload, part)
