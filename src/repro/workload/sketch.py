"""Query-frequency tracking over a sliding window (paper §5.3: "frequencies
are approximated using a sketch datastructure which samples the occurrences
of each query within a sliding window of time t").

We use an exponential-decay counter — O(#distinct queries) space — with
*lazy* timestamp-based decay: ``observe`` touches only the observed query's
counter (O(1)); every counter remembers the tick it was last updated at and
the pending decay ``d^(now - then)`` is applied when the counter is next
touched or read.  This matches the eager formulation (decay every counter on
every observation) exactly up to float rounding, without the
O(#distinct-queries) scan per observation the eager version needs.

``observe_batch`` advances the clock once for the whole batch: a batch is
one time step of the sliding window, so its queries land with equal weight
and the decay horizon is measured in batches (the online driver's tick)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable

from repro.core.rpq import RPQ


@dataclass
class FrequencySketch:
    """Exponentially decayed query counts -> relative frequencies."""

    half_life: float = 100.0           # ticks until weight halves
    counts: Dict[str, float] = field(default_factory=dict)
    queries: Dict[str, RPQ] = field(default_factory=dict)
    _ticks: int = 0
    _stamp: Dict[str, int] = field(default_factory=dict)

    @property
    def decay(self) -> float:
        return 0.5 ** (1.0 / self.half_life)

    def _bump(self, qh: str, q: RPQ, weight: float) -> None:
        """Bring one counter up to the current tick, then add ``weight``."""
        prev = self.counts.get(qh, 0.0)
        if prev:
            # .get: counts seeded through the dataclass init carry stamp 0
            prev *= self.decay ** (self._ticks - self._stamp.get(qh, 0))
        self.counts[qh] = prev + weight
        self._stamp[qh] = self._ticks
        self.queries[qh] = q

    def observe(self, q: RPQ, weight: float = 1.0) -> None:
        """O(1): advance the clock one tick and credit ``q``; other counters
        decay lazily (their pending ``d^dt`` is applied on next touch/read)."""
        self._ticks += 1
        self._bump(q.qhash, q, weight)

    def observe_batch(self, batch: Iterable[RPQ]) -> None:
        """Credit a whole batch under a *single* decay tick (one batch = one
        time step of the sliding window), touching each distinct query once."""
        weights: Dict[str, float] = {}
        qs: Dict[str, RPQ] = {}
        for q in batch:
            qh = q.qhash
            weights[qh] = weights.get(qh, 0.0) + 1.0
            qs[qh] = q
        if not weights:
            return
        self._ticks += 1
        for qh, w in weights.items():
            self._bump(qh, qs[qh], w)

    def _decayed(self) -> Dict[str, float]:
        d, now = self.decay, self._ticks
        return {
            k: v * d ** (now - self._stamp.get(k, 0))
            for k, v in self.counts.items()
        }

    def frequencies(self, min_freq: float = 1e-4) -> Dict[str, float]:
        vals = self._decayed()
        total = sum(vals.values())
        if total <= 0:
            return {}
        out = {k: v / total for k, v in vals.items()}
        return {k: (v if v >= min_freq else 0.0) for k, v in out.items()}

    def workload(self, min_freq: float = 1e-4):
        """[(RPQ, freq)] snapshot for TAPER invocation."""
        freqs = self.frequencies(min_freq)
        return [(self.queries[k], f) for k, f in freqs.items() if f > 0]

    # -- persistence ---------------------------------------------------------
    def state_dict(self) -> Dict:
        """JSON-serializable state: counters plus the query expressions as
        text (``parse_rpq(to_text(q))`` round-trips the AST, and ``qhash``
        is derived from the text, so keys survive the round trip)."""
        order = list(self.counts)
        return {
            "half_life": self.half_life,
            "ticks": self._ticks,
            "qhashes": order,
            "counts": [self.counts[k] for k in order],
            "stamps": [int(self._stamp.get(k, 0)) for k in order],
            "queries": [self.queries[k].to_text() for k in order],
        }

    @classmethod
    def from_state(cls, state: Dict) -> "FrequencySketch":
        from repro.core.rpq import parse_rpq

        sk = cls(half_life=float(state["half_life"]))
        sk._ticks = int(state["ticks"])
        for qh, c, st, text in zip(state["qhashes"], state["counts"],
                                   state["stamps"], state["queries"]):
            sk.counts[qh] = float(c)
            sk._stamp[qh] = int(st)
            sk.queries[qh] = parse_rpq(text)
        return sk
