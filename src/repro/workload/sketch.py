"""Query-frequency tracking over a sliding window (paper §5.3: "frequencies
are approximated using a sketch datastructure which samples the occurrences
of each query within a sliding window of time t").

We use an exponential-decay counter — O(#distinct queries) space, constant
time per observation, and the decay horizon plays the role of the window."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from repro.core.rpq import RPQ


@dataclass
class FrequencySketch:
    """Exponentially decayed query counts -> relative frequencies."""

    half_life: float = 100.0           # observations until weight halves
    counts: Dict[str, float] = field(default_factory=dict)
    queries: Dict[str, RPQ] = field(default_factory=dict)
    _ticks: int = 0

    @property
    def decay(self) -> float:
        return 0.5 ** (1.0 / self.half_life)

    def observe(self, q: RPQ, weight: float = 1.0) -> None:
        d = self.decay
        for k in self.counts:
            self.counts[k] *= d
        qh = q.qhash
        self.counts[qh] = self.counts.get(qh, 0.0) + weight
        self.queries[qh] = q
        self._ticks += 1

    def observe_batch(self, batch) -> None:
        for q in batch:
            self.observe(q)

    def frequencies(self, min_freq: float = 1e-4) -> Dict[str, float]:
        total = sum(self.counts.values())
        if total <= 0:
            return {}
        out = {k: v / total for k, v in self.counts.items()}
        return {k: (v if v >= min_freq else 0.0) for k, v in out.items()}

    def workload(self, min_freq: float = 1e-4):
        """[(RPQ, freq)] snapshot for TAPER invocation."""
        freqs = self.frequencies(min_freq)
        return [(self.queries[k], f) for k, f in freqs.items() if f > 0]
