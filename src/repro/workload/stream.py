"""Query workload and graph-topology streams (paper §6.1.2 + online TAPER).

The paper's experiments use a periodic model where each query pattern's
frequency grows and shrinks "similar to a sin wave", complementary so the
total is always 1; plus (Fig. 10) a linear drift between two queries.

:class:`GraphMutationStream` is the topology twin: it emits per-tick
:class:`repro.graphs.graph.MutationBatch` batches under grow / churn /
burst / mixed scenarios, driving the "changes in the graph topology" half
of the paper's adaptivity claim."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ
from repro.graphs.graph import LabelledGraph, MutationBatch


def periodic_frequencies(
    n_queries: int, t: float, period: float = 1.0, floor: float = 0.02
) -> np.ndarray:
    """Relative frequencies at time ``t``: phase-shifted raised sines,
    normalised to sum to 1 (paper §6.1.2)."""
    phases = 2 * np.pi * (np.arange(n_queries) / n_queries)
    raw = 1.0 + np.sin(2 * np.pi * t / period + phases)
    raw = np.maximum(raw, floor)
    return raw / raw.sum()


def linear_drift(t: float) -> np.ndarray:
    """Fig. 10 model: two queries, Q_a 100%->0% linearly, Q_b 0%->100%."""
    a = float(np.clip(1.0 - t, 0.0, 1.0))
    return np.array([a, 1.0 - a])


@dataclass
class WorkloadStream:
    """Infinite stream of query instances with time-varying frequencies."""

    queries: Sequence[RPQ]
    period: float = 1.0
    mode: str = "periodic"            # "periodic" | "linear" | "static"
    static_freqs: Sequence[float] = ()
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.t = 0.0

    def frequencies(self) -> np.ndarray:
        if self.mode == "periodic":
            return periodic_frequencies(len(self.queries), self.t, self.period)
        if self.mode == "linear":
            assert len(self.queries) == 2
            return linear_drift(self.t)
        freqs = np.asarray(self.static_freqs, dtype=np.float64)
        return freqs / freqs.sum()

    def workload(self) -> List[Tuple[RPQ, float]]:
        """Exact current workload snapshot [(query, frequency)]."""
        return list(zip(self.queries, self.frequencies().tolist()))

    def sample(self, batch_size: int) -> List[RPQ]:
        """Sample a batch of query instances at the current time."""
        idx = self._rng.choice(len(self.queries), size=batch_size, p=self.frequencies())
        return [self.queries[i] for i in idx]

    def advance(self, dt: float) -> None:
        self.t += dt


@dataclass
class GraphMutationStream:
    """Stream of per-tick topology mutation batches.

    Scenarios (``mode``):

    * ``"grow"``  — ``vertices_per_tick`` new vertices arrive each tick,
      labels drawn from the current label distribution, each attaching
      ``attach_degree`` edges to existing vertices by preferential
      attachment (degree-proportional).
    * ``"churn"`` — constant size: ``edges_per_tick`` random existing
      undirected edges are removed and the same number of fresh random
      edges inserted.
    * ``"burst"`` — quiet ticks punctuated every ``burst_every`` ticks by a
      ``burst_scale``-times mixed batch (arrival spike).
    * ``"mixed"`` — grow + churn combined in one batch per tick (the
      combined topology-drift scenario; one batch keeps downstream
      incremental caches patchable in a single hop).

    ``next_batch(g)`` samples against the *current* graph, so apply the
    returned batch before requesting the next one.
    """

    mode: str = "mixed"              # "grow" | "churn" | "burst" | "mixed"
    vertices_per_tick: int = 4
    edges_per_tick: int = 16
    attach_degree: int = 3
    burst_every: int = 5
    burst_scale: int = 8
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.tick = 0

    # -- scenario pieces ----------------------------------------------------
    def _grow_parts(self, g: LabelledGraph, nv: int):
        if nv <= 0:
            return [], np.zeros((0, 2), np.int64)
        lab_freq = np.bincount(g.labels, minlength=g.n_labels).astype(np.float64)
        lab_freq = lab_freq / max(lab_freq.sum(), 1.0)
        labels = self._rng.choice(g.n_labels, size=nv, p=lab_freq)
        deg = (g.row_ptr[1:] - g.row_ptr[:-1]).astype(np.float64) + 1.0
        p = deg / deg.sum()
        edges = []
        for i in range(nv):
            targets = self._rng.choice(
                g.n, size=min(self.attach_degree, g.n), replace=False, p=p)
            edges.extend((g.n + i, int(t)) for t in targets)
        return labels.tolist(), np.asarray(edges, np.int64).reshape(-1, 2)

    def _churn_parts(self, g: LabelledGraph, ne: int):
        if ne <= 0 or g.m == 0:
            z = np.zeros((0, 2), np.int64)
            return z, z
        fwd = np.nonzero(g.src < g.dst)[0]
        take = min(ne, fwd.size)
        rem_idx = self._rng.choice(fwd.size, size=take, replace=False)
        remove = np.stack(
            [g.src[fwd[rem_idx]], g.dst[fwd[rem_idx]]], axis=1).astype(np.int64)
        add = np.stack([
            self._rng.integers(0, g.n, size=ne),
            self._rng.integers(0, g.n, size=ne),
        ], axis=1).astype(np.int64)
        return remove, add

    def next_batch(self, g: LabelledGraph) -> MutationBatch:
        self.tick += 1
        scale = 1
        mode = self.mode
        if mode == "burst":
            if self.tick % self.burst_every:
                return MutationBatch()
            scale, mode = self.burst_scale, "mixed"
        nv = self.vertices_per_tick * scale if mode in ("grow", "mixed") else 0
        ne = self.edges_per_tick * scale if mode in ("churn", "mixed") else 0
        labels, grow_edges = self._grow_parts(g, nv)
        remove, churn_add = self._churn_parts(g, ne)
        add = (np.concatenate([grow_edges, churn_add], axis=0)
               if grow_edges.size or churn_add.size
               else np.zeros((0, 2), np.int64))
        return MutationBatch(
            add_vertex_labels=labels, add_edges=add, remove_edges=remove)
