"""Query workload streams with drifting frequencies (paper §6.1.2).

The paper's experiments use a periodic model where each query pattern's
frequency grows and shrinks "similar to a sin wave", complementary so the
total is always 1; plus (Fig. 10) a linear drift between two queries."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ


def periodic_frequencies(
    n_queries: int, t: float, period: float = 1.0, floor: float = 0.02
) -> np.ndarray:
    """Relative frequencies at time ``t``: phase-shifted raised sines,
    normalised to sum to 1 (paper §6.1.2)."""
    phases = 2 * np.pi * (np.arange(n_queries) / n_queries)
    raw = 1.0 + np.sin(2 * np.pi * t / period + phases)
    raw = np.maximum(raw, floor)
    return raw / raw.sum()


def linear_drift(t: float) -> np.ndarray:
    """Fig. 10 model: two queries, Q_a 100%->0% linearly, Q_b 0%->100%."""
    a = float(np.clip(1.0 - t, 0.0, 1.0))
    return np.array([a, 1.0 - a])


@dataclass
class WorkloadStream:
    """Infinite stream of query instances with time-varying frequencies."""

    queries: Sequence[RPQ]
    period: float = 1.0
    mode: str = "periodic"            # "periodic" | "linear" | "static"
    static_freqs: Sequence[float] = ()
    seed: int = 0

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)
        self.t = 0.0

    def frequencies(self) -> np.ndarray:
        if self.mode == "periodic":
            return periodic_frequencies(len(self.queries), self.t, self.period)
        if self.mode == "linear":
            assert len(self.queries) == 2
            return linear_drift(self.t)
        freqs = np.asarray(self.static_freqs, dtype=np.float64)
        return freqs / freqs.sum()

    def workload(self) -> List[Tuple[RPQ, float]]:
        """Exact current workload snapshot [(query, frequency)]."""
        return list(zip(self.queries, self.frequencies().tolist()))

    def sample(self, batch_size: int) -> List[RPQ]:
        """Sample a batch of query instances at the current time."""
        idx = self._rng.choice(len(self.queries), size=batch_size, p=self.frequencies())
        return [self.queries[i] for i in idx]

    def advance(self, dt: float) -> None:
        self.t += dt
