"""Regular Path Queries over vertex labels (paper §2, expression language (3)).

    E ::= tau | (E . E) | (E + E) | (E | E) | E*

``+`` (union) and ``|`` (exclusive disjunction) expand identically to a set of
label strings (paper §4: ``str(e1 | e2) = str(e1) ∪ str(e2)``); the Kleene
closure is expanded to a bounded number of repetitions
(``str(e^N) = str(e.e...e) N times``, paper §4) — the bound is the workload's
maximum pattern length ``t``.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import FrozenSet, List, Sequence, Tuple, Union


# ---------------------------------------------------------------------------
# AST
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RPQ:
    """Node of an RPQ expression tree."""

    op: str  # "label" | "concat" | "union" | "star"
    children: Tuple["RPQ", ...] = ()
    symbol: str = ""

    # -- constructors ------------------------------------------------------
    def __mul__(self, other: "RPQ") -> "RPQ":  # q1 * q2 == concat
        return concat(self, other)

    def __or__(self, other: "RPQ") -> "RPQ":
        return union(self, other)

    # -- expansion ----------------------------------------------------------
    def strings(self, max_len: int, star_max: int = 3) -> FrozenSet[Tuple[str, ...]]:
        """``str(Q)``: the set of label strings described by the expression,
        with Kleene stars bounded to ``star_max`` repetitions and results
        truncated to ``max_len`` symbols."""
        out = {s for s in self._strings(star_max) if 0 < len(s) <= max_len}
        return frozenset(out)

    def _strings(self, star_max: int) -> FrozenSet[Tuple[str, ...]]:
        if self.op == "label":
            return frozenset({(self.symbol,)})
        if self.op == "union":
            acc: FrozenSet[Tuple[str, ...]] = frozenset()
            for c in self.children:
                acc = acc | c._strings(star_max)
            return acc
        if self.op == "concat":
            acc = frozenset({()})
            for c in self.children:
                nxt = c._strings(star_max)
                acc = frozenset(a + b for a in acc for b in nxt)
            return acc
        if self.op == "star":
            base = self.children[0]._strings(star_max)
            acc = frozenset({()})
            reps: FrozenSet[Tuple[str, ...]] = frozenset({()})
            for _ in range(star_max):
                reps = frozenset(a + b for a in reps for b in base)
                acc = acc | reps
            return acc
        raise ValueError(f"unknown op {self.op}")

    # -- identity ------------------------------------------------------------
    def to_text(self) -> str:
        if self.op == "label":
            return self.symbol
        if self.op == "union":
            return "(" + "|".join(c.to_text() for c in self.children) + ")"
        if self.op == "concat":
            return ".".join(
                c.to_text() if c.op in ("label", "star", "union") else f"({c.to_text()})"
                for c in self.children
            )
        if self.op == "star":
            inner = self.children[0].to_text()
            return f"({inner})*"
        raise ValueError(self.op)

    @property
    def qhash(self) -> str:
        """Unique query label (paper §4: 'hashes of the expressions')."""
        return hashlib.sha1(self.to_text().encode()).hexdigest()[:12]

    def __repr__(self) -> str:  # pragma: no cover
        return f"RPQ({self.to_text()})"


def label(symbol: str) -> RPQ:
    return RPQ("label", symbol=symbol)


def concat(*qs: RPQ) -> RPQ:
    flat: List[RPQ] = []
    for q in qs:
        flat.extend(q.children if q.op == "concat" else (q,))
    return RPQ("concat", tuple(flat))


def union(*qs: RPQ) -> RPQ:
    flat: List[RPQ] = []
    for q in qs:
        flat.extend(q.children if q.op == "union" else (q,))
    return RPQ("union", tuple(flat))


def star(q: RPQ) -> RPQ:
    return RPQ("star", (q,))


# ---------------------------------------------------------------------------
# Parser  (tokens: identifiers, '.', '|', '+', '*', parentheses)
# ---------------------------------------------------------------------------


def parse_rpq(text: str) -> RPQ:
    """Parse an RPQ expression, e.g. ``"Artist.Credit.(Track|Recording)"``
    or ``"Entity.(Entity)*.Activity"`` (paper's MQ/PQ notation; the middle
    dot ``·`` is accepted as ``.``)."""
    toks = _tokenize(text)
    pos = [0]

    def peek() -> str:
        return toks[pos[0]] if pos[0] < len(toks) else ""

    def eat(tok: str = "") -> str:
        cur = peek()
        if tok and cur != tok:
            raise ValueError(f"expected {tok!r}, got {cur!r} in {text!r}")
        pos[0] += 1
        return cur

    def parse_union() -> RPQ:
        terms = [parse_concat()]
        while peek() in ("|", "+"):
            eat()
            terms.append(parse_concat())
        return terms[0] if len(terms) == 1 else union(*terms)

    def parse_concat() -> RPQ:
        factors = [parse_postfix()]
        while True:
            if peek() == ".":
                eat()
                factors.append(parse_postfix())
            elif peek() and peek() not in (")", "|", "+"):
                factors.append(parse_postfix())
            else:
                break
        return factors[0] if len(factors) == 1 else concat(*factors)

    def parse_postfix() -> RPQ:
        node = parse_atom()
        while peek() == "*":
            eat()
            node = star(node)
        return node

    def parse_atom() -> RPQ:
        if peek() == "(":
            eat("(")
            node = parse_union()
            eat(")")
            return node
        tok = eat()
        if not tok or not (tok[0].isalpha() or tok[0] == "_"):
            raise ValueError(f"unexpected token {tok!r} in {text!r}")
        return label(tok)

    node = parse_union()
    if pos[0] != len(toks):
        raise ValueError(f"trailing tokens in {text!r}")
    return node


def _tokenize(text: str) -> List[str]:
    text = text.replace("·", ".")  # middle dot
    toks: List[str] = []
    i = 0
    while i < len(text):
        c = text[i]
        if c.isspace():
            i += 1
        elif c in ".|+*()":
            toks.append(c)
            i += 1
        elif c.isalnum() or c == "_":
            j = i
            while j < len(text) and (text[j].isalnum() or text[j] == "_"):
                j += 1
            toks.append(text[i:j])
            i = j
        else:
            raise ValueError(f"bad character {c!r} in {text!r}")
    return toks
