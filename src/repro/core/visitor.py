"""Vectorised Visitor-Matrix extroversion field (paper §2.3, §3.2, §5.4).

The paper's Alg. 1 builds Visitor-Matrix rows corecursively per vertex.  We
reformulate it as a depth-stratified sparse recurrence over the edge list —
the TPU-native adaptation (DESIGN.md §2):

  state    alpha[v, n]  = total probability of workload-legal *intra-partition*
                          paths ending at v whose label string is trie node n
  base     alpha[v, n1] = p(n1) / |{u : l(u) = label(n1)}|        (depth-1 n1)
  step     alpha[w, n'] += alpha[u, parent(n')] * cond_p(n')
                           / cnt[u, l(w)]          over local edges (u, w)
  masses   mass[u→w]    = sum_n alpha[u, parent(c)] * cond_p(c) / cnt[u, l(w)]
                          for c = child(n, l(w))   over ALL edges
  outputs  Pr(v)        = sum_{n non-leaf} alpha[v, n]
           extroversion = (sum of mass over cut edges out of v) / Pr(v)
           introversion = 1 - extroversion  (termination mass is intra, §4.2)

Everything is `segment_sum` over edge blocks — the same kernel regime as GNN
message passing; `repro.kernels.vm_step` provides the Pallas TPU kernel for
the inner step, and this module is its jnp oracle.

One jit cache entry exists per (trie topology, graph/partition shapes); trie
*probabilities* are runtime arguments so workload-frequency drift never
recompiles.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tpstry import TrieArrays
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("core.visitor")

_EPS = 1e-30


@dataclass
class ExtroversionResult:
    """Per-vertex/per-edge extroversion field for one partitioning."""

    alpha: np.ndarray         # (n, N) path-state probabilities
    pr: np.ndarray            # (n,)  total traversal probability through v
    edge_mass: np.ndarray     # (m,)  traversal probability mass per directed edge
    extro_mass: np.ndarray    # (n,)  external mass out of v
    extroversion: np.ndarray  # (n,)  extro_mass / pr  (0 where pr == 0)
    ext_to: Optional[np.ndarray]  # (n, k) external mass per destination part
                                  # (None under the two-phase §Perf-T2 path:
                                  # swap computes candidate rows lazily)
    total_extroversion: float  # sum of extro_mass — TAPER's objective

    @property
    def introversion(self) -> np.ndarray:
        return np.where(self.pr > 0, 1.0 - self.extroversion, 1.0)


# ---------------------------------------------------------------------------
# jit core (cached per trie topology + shapes)
# ---------------------------------------------------------------------------

_FIELD_CACHE: Dict[Tuple, object] = {}


def _build_field_fn(topology: Tuple, trie: TrieArrays, k: int, depth_cap: int,
                    fused: bool = True, dense_ext_to: bool = True):
    """Build the jitted field function for a fixed trie *topology*.

    Topology (parent/label/leaf structure) is baked in as Python-level loop
    structure; probabilities arrive as runtime arrays.

    Two implementations (numerically identical; tested against each other):

    * naive  — one gather + segment_sum pass over the edge list per trie
      node (the direct transcription of the recurrence);
    * fused  — all trie nodes of one depth advance in a single batched
      gather / elementwise / segment_sum pass (§Perf iteration T1: the
      naive variant launches ~N_trie scatter passes whose intermediates
      cannot fuse, and its HBM term is ~5x the fused one).
    """
    parent = trie.parent.copy()
    labels_n = trie.label.copy()
    depth = trie.depth.copy()
    is_leaf = trie.is_leaf.copy()
    N = trie.n_nodes
    max_depth = min(trie.max_depth, depth_cap)

    step_nodes = [
        i for i in range(N) if 2 <= depth[i] <= max_depth
    ]  # in depth order already (compile() sorts by depth)
    # states that still have outgoing transitions (non-leaf, depth in [1, t))
    counted_nodes = [
        i for i in range(N)
        if 1 <= depth[i] < max_depth and not is_leaf[i]
    ]

    def _priors(vlabels, lab_vcount, p, n):
        cols = []
        for i in range(N):
            if depth[i] == 1:
                li = int(labels_n[i])
                prior = p[i] / jnp.maximum(lab_vcount[li].astype(jnp.float32), 1.0)
                cols.append(jnp.where(vlabels == li, prior, 0.0))
            else:
                cols.append(jnp.zeros((n,), dtype=jnp.float32))
        return jnp.stack(cols, axis=1) if N else jnp.zeros((n, 0), jnp.float32)

    def _aggregates(alpha, mass, src, dst, part, local, n, m):
        pr = jnp.zeros((n,), dtype=jnp.float32)
        for i in counted_nodes:
            pr = pr + alpha[:, i]
        is_ext = 1.0 - local
        extro_mass = jax.ops.segment_sum(mass * is_ext, src, num_segments=n)
        extroversion = jnp.where(pr > _EPS, extro_mass / jnp.maximum(pr, _EPS), 0.0)
        if dense_ext_to:
            seg = src.astype(jnp.int32) * k + part[dst]
            ext_to = jax.ops.segment_sum(mass * is_ext, seg, num_segments=n * k)
            return alpha, pr, mass, extro_mass, extroversion, ext_to.reshape(n, k)
        return alpha, pr, mass, extro_mass, extroversion

    @partial(jax.jit, static_argnames=("n", "m"))
    def field_fn_naive(
        src, dst, vlabels, cnt, lab_vcount, part, p, cond_p, *, n: int, m: int
    ):
        inv_cnt = 1.0 / jnp.maximum(cnt.astype(jnp.float32), 1.0)  # (n, L)
        local = (part[src] == part[dst]).astype(jnp.float32)       # (m,)
        dst_lab = vlabels[dst]                                     # (m,)
        alpha = _priors(vlabels, lab_vcount, p, n)

        # --- DP steps + edge masses, one pass per depth>=2 node ---
        mass = jnp.zeros((m,), dtype=jnp.float32)
        for c in step_nodes:
            par, lc = int(parent[c]), int(labels_n[c])
            contrib = (
                alpha[src, par]
                * cond_p[c]
                * inv_cnt[src, lc]
                * (dst_lab == lc).astype(jnp.float32)
            )
            mass = mass + contrib
            # only local (intra-partition) extensions continue the path
            alpha = alpha.at[:, c].add(
                jax.ops.segment_sum(contrib * local, dst, num_segments=n)
            )
        return _aggregates(alpha, mass, src, dst, part, local, n, m)

    @partial(jax.jit, static_argnames=("n", "m"))
    def field_fn_fused(
        src, dst, vlabels, cnt, lab_vcount, part, p, cond_p, *, n: int, m: int
    ):
        inv_cnt = 1.0 / jnp.maximum(cnt.astype(jnp.float32), 1.0)  # (n, L)
        local = (part[src] == part[dst]).astype(jnp.float32)       # (m,)
        dst_lab = vlabels[dst]                                     # (m,)
        alpha = _priors(vlabels, lab_vcount, p, n)

        mass = jnp.zeros((m,), dtype=jnp.float32)
        for d in range(2, max_depth + 1):
            nodes_d = [c for c in step_nodes if depth[c] == d]
            if not nodes_d:
                continue
            pars = np.asarray([parent[c] for c in nodes_d])
            labs = np.asarray([labels_n[c] for c in nodes_d])
            # one batched gather of the needed parent columns: (m, n_d)
            # (column-slice first so the row gather moves n_d floats/edge,
            # not the full trie row)
            a_par = alpha[:, pars][src]
            coef = cond_p[jnp.asarray(np.asarray(nodes_d))][None, :]
            lab_mask = (dst_lab[:, None] == jnp.asarray(labs)[None, :])
            ic = inv_cnt[:, labs][src]
            contrib = a_par * coef * ic * lab_mask.astype(jnp.float32)
            mass = mass + contrib.sum(axis=1)
            # single segment_sum for the whole depth: (n, n_d)
            upd = jax.ops.segment_sum(contrib * local[:, None], dst,
                                      num_segments=n)
            alpha = alpha.at[:, jnp.asarray(np.asarray(nodes_d))].add(upd)
        return _aggregates(alpha, mass, src, dst, part, local, n, m)

    return field_fn_fused if fused else field_fn_naive


def extroversion_field(
    g: LabelledGraph,
    trie: TrieArrays,
    part: np.ndarray,
    k: int,
    depth_cap: Optional[int] = None,
    _precomputed: Optional[Dict] = None,
    fused: bool = True,
    dense_ext_to: bool = True,
) -> ExtroversionResult:
    """Compute the extroversion field of ``part`` under the workload trie.

    ``depth_cap`` implements the paper's §5.2.2 time heuristic (stop VM row
    expansion at path length < t, trading accuracy for time).
    """
    depth_cap = depth_cap or trie.max_depth
    key = (trie.topology_signature(), k, depth_cap, g.n, g.m, fused, dense_ext_to)
    fn = _FIELD_CACHE.get(key)
    if fn is None:
        fn = _build_field_fn(key, trie, k, depth_cap, fused=fused,
                             dense_ext_to=dense_ext_to)
        _FIELD_CACHE[key] = fn

    pre = _precomputed or {}
    cnt = pre.get("cnt")
    if cnt is None:
        cnt = g.neighbor_label_counts()
    lab_vcount = pre.get("lab_vcount")
    if lab_vcount is None:
        lab_vcount = g.label_counts()

    out = fn(
        jnp.asarray(g.src),
        jnp.asarray(g.dst),
        jnp.asarray(g.labels),
        jnp.asarray(cnt),
        jnp.asarray(lab_vcount),
        jnp.asarray(part.astype(np.int32)),
        jnp.asarray(trie.p),
        jnp.asarray(trie.cond_p),
        n=g.n,
        m=g.m,
    )
    if dense_ext_to:
        alpha, pr, mass, extro_mass, extroversion, ext_to = out
        ext_to = np.asarray(ext_to)
    else:
        alpha, pr, mass, extro_mass, extroversion = out
        ext_to = None
    return ExtroversionResult(
        alpha=np.asarray(alpha),
        pr=np.asarray(pr),
        edge_mass=np.asarray(mass),
        extro_mass=np.asarray(extro_mass),
        extroversion=np.asarray(extroversion),
        ext_to=ext_to,
        total_extroversion=float(np.asarray(extro_mass).sum()),
    )


# ---------------------------------------------------------------------------
# Reference single-cell evaluation (paper §4.2) — used by tests/examples
# ---------------------------------------------------------------------------


def vm_cell(
    g: LabelledGraph, trie: TrieArrays, path_vertices, label_names=None
) -> np.ndarray:
    """``VM^(t)[p_1, ..., p_{t-1}, *]``: the distribution over next vertices
    given the path ``path_vertices`` (paper §4.2 worked example).

    Returns an ``(n,)`` vector of transition probabilities (rows need not sum
    to 1; the shortfall is the 'no subsequent traversal' mass, §4.2 fn. 6).
    """
    path = list(path_vertices)
    # find trie node for the label string of the path
    node = 0
    for v in path:
        child = trie.child_index[node, g.labels[v]]
        if child < 0:
            return np.zeros(g.n, dtype=np.float64)
        node = int(child)
    last = path[-1]
    nbrs = g.neighbors(last)
    nbr_labels = g.labels[nbrs]
    out = np.zeros(g.n, dtype=np.float64)
    for lab_id in range(trie.n_labels):
        child = trie.child_index[node, lab_id]
        if child < 0:
            continue
        cond = float(trie.cond_p[child])
        same = nbrs[nbr_labels == lab_id]
        if same.size:
            out[same] += cond / same.size
    return out
