"""Vectorised Visitor-Matrix extroversion field (paper §2.3, §3.2, §5.4).

The paper's Alg. 1 builds Visitor-Matrix rows corecursively per vertex.  We
reformulate it as a depth-stratified sparse recurrence over the edge list —
the TPU-native adaptation (DESIGN.md §2):

  state    alpha[v, n]  = total probability of workload-legal *intra-partition*
                          paths ending at v whose label string is trie node n
  base     alpha[v, n1] = p(n1) / |{u : l(u) = label(n1)}|        (depth-1 n1)
  step     alpha[w, n'] += alpha[u, parent(n')] * cond_p(n')
                           / cnt[u, l(w)]          over local edges (u, w)
  masses   mass[u→w]    = sum_n alpha[u, parent(c)] * cond_p(c) / cnt[u, l(w)]
                          for c = child(n, l(w))   over ALL edges
  outputs  Pr(v)        = sum_{n non-leaf} alpha[v, n]
           extroversion = (sum of mass over cut edges out of v) / Pr(v)
           introversion = 1 - extroversion  (termination mass is intra, §4.2)

Everything is `segment_sum` over edge blocks — the same kernel regime as GNN
message passing; `repro.kernels.vm_step` provides the Pallas TPU kernel for
the inner step, and this module is its jnp oracle.

One jit cache entry exists per (trie topology, graph/partition shapes); trie
*probabilities* are runtime arguments so workload-frequency drift never
recompiles.

Multi-device (``backend="pallas_sharded"``): the packed edge blocks are
dealt across the mesh's ``model`` axis (``LabelledGraph.vm_packing_sharded``,
along a pluggable topology-aware *shard map* — see
``repro.graphs.sharded_packing``) and the depth loop runs under
``shard_map`` as a **halo-exchange recurrence** with two exchange backends:

* ``halo_exchange="sliced"`` (default) — two-tier per-shard-pair slice
  exchange: hub rows read by many shards travel once in a small psum'd
  *hot union*, and the cold tail moves as a ragged all-to-all decomposed
  into ``S - 1`` ring ``ppermute`` rounds, each padded only to that
  round's largest pair (the packing's precomputed ``send_local`` tables
  and ``round_cap``).  Per-depth traffic is ``(hot_pad + sum(round_cap))
  * N`` floats per shard — it scales with what each shard *reads*, not
  with the global union, so a topology-aware shard map (e.g.
  ``"partition"``) compresses it directly;
* ``halo_exchange="psum"`` — the PR-3 union exchange, kept as a fallback
  for latency-bound meshes where ``S - 1`` collective rounds lose to one
  ``psum`` (and for layouts whose pairwise halos approach the union
  anyway): every shard scatters its owned slice of the union frontier
  into an ``(H_pad, N)`` buffer and one ``psum`` completes it (each
  frontier row has exactly one owner).

Either way each shard then advances its local destination blocks with the
``vm_step`` kernel, gathering sources from ``concat([beta_local,
exchanged])`` via the packing's mode-matched source map (``src_map`` /
``src_map_sliced``), and per-slot edge masses accumulate shard-locally
(over *all* edges, cut and local) and scatter back to raw edge order on
the host at the end.

Because destination blocks never cross shards, the kernel's output rows
are wholly shard-local and ``alpha`` assembles by concatenation — in
*position* space; the shard map's inverse permutation restores vertex
order (a no-op gather under the identity stripe map).  After graph
mutations, stale device buffers re-upload per *dirty shard* (the packing's
``shard_epoch`` counters), not wholesale.  Each sharded evaluation records
its measured exchange footprint in ``pre["_halo_stats"]`` (bytes per depth
step, halo ratio vs the full field, shard-map source, exchange backend)
for serving metrics and benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tpstry import TrieArrays
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("core.visitor")

_EPS = 1e-30


@dataclass
class ExtroversionResult:
    """Per-vertex/per-edge extroversion field for one partitioning."""

    alpha: np.ndarray         # (n, N) path-state probabilities
    pr: np.ndarray            # (n,)  total traversal probability through v
    edge_mass: np.ndarray     # (m,)  traversal probability mass per directed edge
    extro_mass: np.ndarray    # (n,)  external mass out of v
    extroversion: np.ndarray  # (n,)  extro_mass / pr  (0 where pr == 0)
    ext_to: Optional[np.ndarray]  # (n, k) external mass per destination part
                                  # (None under the two-phase §Perf-T2 path:
                                  # swap computes candidate rows lazily)
    total_extroversion: float  # sum of extro_mass — TAPER's objective

    @property
    def introversion(self) -> np.ndarray:
        return np.where(self.pr > 0, 1.0 - self.extroversion, 1.0)


# ---------------------------------------------------------------------------
# jit core (cached per trie topology + shapes)
# ---------------------------------------------------------------------------

_FIELD_CACHE: Dict[Tuple, object] = {}


def _prior_columns(depth, labels_n, N, vlabels, lab_vcount, p, n):
    """Depth-1 prior columns ``alpha[v, n1] = p(n1) / |{u : l(u)=label(n1)}|``.

    Shared by the jnp and Pallas backends so the base case is arithmetically
    identical (float32 division on-device) in both."""
    cols = []
    for i in range(N):
        if depth[i] == 1:
            li = int(labels_n[i])
            prior = p[i] / jnp.maximum(lab_vcount[li].astype(jnp.float32), 1.0)
            cols.append(jnp.where(vlabels == li, prior, 0.0))
        else:
            cols.append(jnp.zeros((n,), dtype=jnp.float32))
    return jnp.stack(cols, axis=1) if N else jnp.zeros((n, 0), jnp.float32)


def _field_aggregates(counted_nodes, k, dense_ext_to,
                      alpha, mass, src, dst, part, local, n):
    """Pr / extroversion / (optional) ext_to tail, shared by both backends."""
    pr = jnp.zeros((n,), dtype=jnp.float32)
    for i in counted_nodes:
        pr = pr + alpha[:, i]
    is_ext = 1.0 - local
    extro_mass = jax.ops.segment_sum(mass * is_ext, src, num_segments=n)
    extroversion = jnp.where(pr > _EPS, extro_mass / jnp.maximum(pr, _EPS), 0.0)
    if dense_ext_to:
        seg = src.astype(jnp.int32) * k + part[dst]
        ext_to = jax.ops.segment_sum(mass * is_ext, seg, num_segments=n * k)
        return alpha, pr, mass, extro_mass, extroversion, ext_to.reshape(n, k)
    return alpha, pr, mass, extro_mass, extroversion


def _build_field_fn(topology: Tuple, trie: TrieArrays, k: int, depth_cap: int,
                    fused: bool = True, dense_ext_to: bool = True):
    """Build the jitted field function for a fixed trie *topology*.

    Topology (parent/label/leaf structure) is baked in as Python-level loop
    structure; probabilities arrive as runtime arrays.

    Two implementations (numerically identical; tested against each other):

    * naive  — one gather + segment_sum pass over the edge list per trie
      node (the direct transcription of the recurrence);
    * fused  — all trie nodes of one depth advance in a single batched
      gather / elementwise / segment_sum pass (§Perf iteration T1: the
      naive variant launches ~N_trie scatter passes whose intermediates
      cannot fuse, and its HBM term is ~5x the fused one).
    """
    parent = trie.parent.copy()
    labels_n = trie.label.copy()
    depth = trie.depth.copy()
    is_leaf = trie.is_leaf.copy()
    N = trie.n_nodes
    max_depth = min(trie.max_depth, depth_cap)

    step_nodes = [
        i for i in range(N) if 2 <= depth[i] <= max_depth
    ]  # in depth order already (compile() sorts by depth)
    # states that still have outgoing transitions (non-leaf, depth in [1, t))
    counted_nodes = [
        i for i in range(N)
        if 1 <= depth[i] < max_depth and not is_leaf[i]
    ]

    def _priors(vlabels, lab_vcount, p, n):
        return _prior_columns(depth, labels_n, N, vlabels, lab_vcount, p, n)

    def _aggregates(alpha, mass, src, dst, part, local, n, m):
        return _field_aggregates(counted_nodes, k, dense_ext_to,
                                 alpha, mass, src, dst, part, local, n)

    @partial(jax.jit, static_argnames=("n", "m"))
    def field_fn_naive(
        src, dst, vlabels, cnt, lab_vcount, part, p, cond_p, *, n: int, m: int
    ):
        inv_cnt = 1.0 / jnp.maximum(cnt.astype(jnp.float32), 1.0)  # (n, L)
        local = (part[src] == part[dst]).astype(jnp.float32)       # (m,)
        dst_lab = vlabels[dst]                                     # (m,)
        alpha = _priors(vlabels, lab_vcount, p, n)

        # --- DP steps + edge masses, one pass per depth>=2 node ---
        mass = jnp.zeros((m,), dtype=jnp.float32)
        for c in step_nodes:
            par, lc = int(parent[c]), int(labels_n[c])
            contrib = (
                alpha[src, par]
                * cond_p[c]
                * inv_cnt[src, lc]
                * (dst_lab == lc).astype(jnp.float32)
            )
            mass = mass + contrib
            # only local (intra-partition) extensions continue the path
            alpha = alpha.at[:, c].add(
                jax.ops.segment_sum(contrib * local, dst, num_segments=n)
            )
        return _aggregates(alpha, mass, src, dst, part, local, n, m)

    @partial(jax.jit, static_argnames=("n", "m"))
    def field_fn_fused(
        src, dst, vlabels, cnt, lab_vcount, part, p, cond_p, *, n: int, m: int
    ):
        inv_cnt = 1.0 / jnp.maximum(cnt.astype(jnp.float32), 1.0)  # (n, L)
        local = (part[src] == part[dst]).astype(jnp.float32)       # (m,)
        dst_lab = vlabels[dst]                                     # (m,)
        alpha = _priors(vlabels, lab_vcount, p, n)

        mass = jnp.zeros((m,), dtype=jnp.float32)
        for d in range(2, max_depth + 1):
            nodes_d = [c for c in step_nodes if depth[c] == d]
            if not nodes_d:
                continue
            pars = np.asarray([parent[c] for c in nodes_d])
            labs = np.asarray([labels_n[c] for c in nodes_d])
            # one batched gather of the needed parent columns: (m, n_d)
            # (column-slice first so the row gather moves n_d floats/edge,
            # not the full trie row)
            a_par = alpha[:, pars][src]
            coef = cond_p[jnp.asarray(np.asarray(nodes_d))][None, :]
            lab_mask = (dst_lab[:, None] == jnp.asarray(labs)[None, :])
            ic = inv_cnt[:, labs][src]
            contrib = a_par * coef * ic * lab_mask.astype(jnp.float32)
            mass = mass + contrib.sum(axis=1)
            # single segment_sum for the whole depth: (n, n_d)
            upd = jax.ops.segment_sum(contrib * local[:, None], dst,
                                      num_segments=n)
            alpha = alpha.at[:, jnp.asarray(np.asarray(nodes_d))].add(upd)
        return _aggregates(alpha, mass, src, dst, part, local, n, m)

    return field_fn_fused if fused else field_fn_naive


def _device_inputs(g: LabelledGraph, pre: Dict, cnt, lab_vcount) -> Dict:
    """Device-resident copies of the partition-independent field inputs.

    Cached inside the caller's ``_precomputed`` dict (Taper keeps one per
    graph), so repeated ``invoke`` iterations re-use the same device buffers
    instead of re-uploading the edge list every call.  Only the partition
    vector crosses host->device per iteration.  The graph's mutation
    ``version`` is recorded alongside the buffers: after
    ``LabelledGraph.apply_mutations`` the stale device-resident edge arrays
    are detected and re-uploaded rather than silently reused.
    """
    dev = pre.get("_dev")
    if dev is not None and pre.get("_dev_version") != g.version:
        dev = None
    if dev is None:
        dev = {
            "src": jnp.asarray(g.src),
            "dst": jnp.asarray(g.dst),
            "labels": jnp.asarray(g.labels),
            "cnt": jnp.asarray(cnt),
            "lab_vcount": jnp.asarray(lab_vcount),
        }
        pre["_dev"] = dev
        pre["_dev_version"] = g.version
    return dev


_TRANSITION_CACHE: Dict[Tuple, np.ndarray] = {}


def _capped_transition(trie: TrieArrays, depth_cap: int) -> np.ndarray:
    """(L, N, N) trie transition tensor with children beyond ``depth_cap``
    zeroed (§5.2.2 time heuristic).  Cached per (topology, probabilities);
    bounded so drifting workload frequencies (a fresh ``cond_p`` per
    invocation) cannot grow the cache without limit."""
    from repro.kernels.vm_step.ref import build_transition

    key = (trie.topology_signature(), int(depth_cap), trie.cond_p.tobytes())
    T = _TRANSITION_CACHE.get(key)
    if T is None:
        T = build_transition(trie.parent, trie.label, trie.cond_p,
                             trie.n_labels)
        if depth_cap < trie.max_depth:
            T[:, :, trie.depth > depth_cap] = 0.0
        while len(_TRANSITION_CACHE) >= 8:
            _TRANSITION_CACHE.pop(next(iter(_TRANSITION_CACHE)))
        _TRANSITION_CACHE[key] = T
    return T


def _pallas_field(
    g: LabelledGraph,
    trie: TrieArrays,
    part: np.ndarray,
    k: int,
    depth_cap: int,
    pre: Dict,
    dense_ext_to: bool,
    interpret: Optional[bool] = None,
):
    """Pallas-backed extroversion field: the depth-advancing DP step runs as
    the ``vm_step`` TPU kernel over the graph's cached edge packing.

    The depth recurrence is expressed as a chain of *delta* states: ``beta_d``
    holds only the depth-``d`` trie columns, so applying the full transition
    tensor once per depth advances every state without double counting:

        beta_1 = priors;  beta_d = vm_step(beta_{d-1}, T | local edges)
        alpha  = sum_d beta_d
        mass  += rowsum over children of the beta_{d-1} messages (ALL edges)

    The packing (src/dst/label/1-cnt channels) is partition-independent and
    cached on the graph; per iteration only the partition vector and the
    derived local-edge mask move to the device.  ``interpret`` defaults to
    auto: off when running on a real TPU, on elsewhere.
    """
    from repro.kernels.vm_step.ops import vm_step

    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    n, m = g.n, g.m
    N = trie.n_nodes
    cnt = pre.get("cnt")
    if cnt is None:
        cnt = g.neighbor_label_counts()
    lab_vcount = pre.get("lab_vcount")
    if lab_vcount is None:
        lab_vcount = g.label_counts()
    dev = _device_inputs(g, pre, cnt, lab_vcount)
    src, dst, vlabels = dev["src"], dev["dst"], dev["labels"]

    packed, dst_label, inv_cnt_packed, dst_global = g.vm_packing(cnt=cnt)
    pdev = pre.get("_vm_dev")
    if pdev is not None and pre.get("_vm_dev_version") != g.version:
        pdev = None  # stale device packing from a pre-mutation graph
    if pdev is None:
        inv_cnt_edge = 1.0 / np.maximum(
            np.asarray(cnt)[g.src, g.labels[g.dst]], 1.0)
        pdev = {
            "packed_src": jnp.asarray(packed.src),
            "dst_global": jnp.asarray(dst_global),
            "inv_cnt_edge": jnp.asarray(inv_cnt_edge.astype(np.float32)),
        }
        pre["_vm_dev"] = pdev
        pre["_vm_dev_version"] = g.version

    # device-resident transition tensor, re-uploaded only when the trie
    # probabilities (or depth cap) change — not per iteration
    T_key = (trie.topology_signature(), int(depth_cap), trie.cond_p.tobytes())
    t_hit = pre.get("_T_dev")
    if t_hit is None or t_hit[0] != T_key:
        T = jnp.asarray(_capped_transition(trie, depth_cap))
        Tsum = T.sum(axis=2)                   # (L, N) mass per (label, parent)
        pre["_T_dev"] = (T_key, T, Tsum)
    else:
        _, T, Tsum = t_hit
    part_dev = jnp.asarray(part.astype(np.int32))
    local = (part_dev[src] == part_dev[dst]).astype(jnp.float32)   # (m,)
    local_packed = (part_dev[pdev["packed_src"]]
                    == part_dev[pdev["dst_global"]]).astype(jnp.float32)
    inv_local = inv_cnt_packed * local_packed  # 0 on padding (inv_cnt is 0)
    dst_lab = vlabels[dst]
    inv_cnt_edge = pdev["inv_cnt_edge"]

    # depth-1 priors — same device arithmetic as the jnp backend
    alpha = _prior_columns(trie.depth, trie.label, N, vlabels,
                           dev["lab_vcount"], jnp.asarray(trie.p), n)
    beta = alpha
    mass = jnp.zeros((m,), dtype=jnp.float32)
    max_depth = min(trie.max_depth, depth_cap)
    for _ in range(2, max_depth + 1):
        # per-edge mass of the depth step over ALL edges (cut + local)
        mass = mass + (beta[src] * Tsum[dst_lab]).sum(axis=1) * inv_cnt_edge
        # the DP itself advances over local edges only — vm_step kernel
        beta = vm_step(beta, T, packed, dst_label, inv_local, n,
                       interpret=interpret, use_pallas=True)
        alpha = alpha + beta

    counted = [
        i for i in range(N)
        if 1 <= int(trie.depth[i]) < max_depth and not bool(trie.is_leaf[i])
    ]
    return _field_aggregates(counted, k, dense_ext_to,
                             alpha, mass, src, dst, part_dev, local, n)


def _build_sharded_fn(mesh, trie: TrieArrays, depth_cap: int,
                      bps: int, block_n: int, block_e: int,
                      n_local_pad: int, h_pad: int, interpret: bool,
                      exchange: str = "psum", n_shards: int = 1,
                      round_cap: Tuple[int, ...] = ()):
    """shard_map'd halo-exchange depth loop (see module docstring §sharded).

    Static per (mesh, trie topology, packing shapes, exchange backend): the
    trie topology and depth count bake into the loop; probabilities, the
    partition vector and the packed shard arrays arrive as runtime inputs.
    The ``exchange`` backend decides the per-depth collective: one ``psum``
    of the union frontier (``fr_a``/``fr_b`` = the union owner maps), or
    the two-tier sliced exchange — a small ``psum`` of the hot broadcast
    rows (``fr_a``/``fr_b`` = the hot owner maps) plus ``S - 1`` ring
    ``ppermute`` rounds of the cold per-shard-pair slices (``send`` = the
    ``send_local`` tables, round ``r`` padded to the static
    ``round_cap[r]``; ``src_map`` is then the packing's sliced variant).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.kernels.vm_step.kernel import vm_step_packed

    depth = trie.depth.copy()
    labels_n = trie.label.copy()
    N = trie.n_nodes
    max_depth = min(trie.max_depth, depth_cap)
    sliced = exchange == "sliced"

    def body(meta, src_map, dst_local, dst_label, inv_full, src_g, dst_g,
             vlab, fr_a, fr_b, send, part, p, lab_vcount, T, Tsum):
        # sharded inputs arrive with their leading shard axis (size 1)
        (meta, src_map, dst_local, dst_label, inv_full, src_g, dst_g,
         vlab, fr_a, fr_b, send) = (
            x[0] for x in (meta, src_map, dst_local, dst_label, inv_full,
                           src_g, dst_g, vlab, fr_a, fr_b, send))
        local = (part[src_g] == part[dst_g]).astype(jnp.float32)
        inv_local = inv_full * local
        alpha = _prior_columns(depth, labels_n, N, vlab, lab_vcount, p,
                               n_local_pad)
        beta = alpha
        slot_mass = jnp.zeros(inv_full.shape, dtype=jnp.float32)
        for _ in range(2, max_depth + 1):
            if sliced:
                # two-tier exchange: psum the (small) hot broadcast rows,
                # then ring-exchange the cold per-pair slices — round r
                # ships each shard's slice for the reader r hops ahead,
                # padded to that round's own largest pair
                hot = jax.lax.psum(beta[fr_a] * fr_b[:, None], "model")
                me = jax.lax.axis_index("model")
                parts = [hot]
                for r in range(1, n_shards):
                    reader = jax.lax.rem(me + r, n_shards)
                    rows = jax.lax.dynamic_index_in_dim(
                        send, reader, axis=0, keepdims=False)
                    payload = beta[rows[: round_cap[r]]]
                    parts.append(jax.lax.ppermute(
                        payload, "model",
                        perm=[(i, (i + r) % n_shards)
                              for i in range(n_shards)]))
                fr = jnp.concatenate(parts, axis=0)
            else:
                # union exchange: each shard contributes its owned frontier
                # rows (fr_a = fr_local_idx, fr_b = fr_owned); psum
                # completes the union (each row has exactly one owner)
                fr = jax.lax.psum(beta[fr_a] * fr_b[:, None], "model")
            a_in = jnp.concatenate([beta, fr], axis=0)
            # per-slot mass over ALL edges (cut + local) at this depth
            slot_mass = slot_mass + (
                a_in[src_map] * Tsum[dst_label]).sum(axis=1) * inv_full
            # the DP advances over intra-partition edges only
            beta = vm_step_packed(
                a_in, T, src_map, dst_local, dst_label, inv_local, meta,
                bps, block_n, block_e, interpret=interpret)
            alpha = alpha + beta
        return alpha[None], slot_mass[None]

    sharded = (P("model"),) * 11
    fn = shard_map(
        body, mesh=mesh,
        in_specs=sharded + (P(), P(), P(), P(), P()),
        out_specs=(P("model"), P("model")),
        check_rep=False,
    )
    return jax.jit(fn)


def _sharded_device_arrays(sp, pre: Dict) -> Dict:
    """Device-resident stacked shard arrays, re-uploaded per dirty shard.

    The packing's ``shard_epoch`` counters say which shard slices changed
    since this cache last uploaded them; only those rows move to the device
    (plus the small frontier maps when ``fr_epoch`` moved).  Upload counts
    accumulate in ``pre["_shard_uploads"]`` for benchmarks/tests.
    """
    stats = pre.setdefault(
        "_shard_uploads", {"last_shards": 0, "total_shards": 0, "rebuilds": 0})
    names = ("meta", "src_map", "src_map_sliced", "dst_local", "dst_label",
             "inv_cnt", "src_global", "dst_global", "vlabels", "send_local")
    sdev = pre.get("_shard_dev")
    if sdev is not None and sdev["sp"] is not sp:
        sdev = None  # packing was rebuilt from scratch (capacity overflow)
    if sdev is None:
        sdev = {"sp": sp,
                "epochs": sp.shard_epoch.copy(),
                "fr_epoch": sp.fr_epoch,
                "arrays": {nm: jnp.asarray(getattr(sp, nm)) for nm in names},
                "fr": (jnp.asarray(sp.fr_local_idx),
                       jnp.asarray(sp.fr_owned)),
                "hot": (jnp.asarray(sp.hot_local_idx),
                        jnp.asarray(sp.hot_owned)),
                "n_pos": sp.pos_of.shape[0],
                "pos": (None if sp.identity
                        else jnp.asarray(sp.pos_of.astype(np.int32)))}
        pre["_shard_dev"] = sdev
        stats["last_shards"] = sp.n_shards
        stats["total_shards"] += sp.n_shards
        stats["rebuilds"] += 1
        return sdev
    dirty = np.nonzero(sp.shard_epoch != sdev["epochs"])[0]
    for s in dirty.tolist():
        for nm in names:
            sdev["arrays"][nm] = sdev["arrays"][nm].at[s].set(
                jnp.asarray(getattr(sp, nm)[s]))
    if sp.fr_epoch != sdev["fr_epoch"]:
        sdev["fr"] = (jnp.asarray(sp.fr_local_idx), jnp.asarray(sp.fr_owned))
        sdev["fr_epoch"] = sp.fr_epoch
    if sp.pos_of.shape[0] != sdev["n_pos"]:
        # vertex growth extended the shard map's identity tail
        sdev["n_pos"] = sp.pos_of.shape[0]
        sdev["pos"] = (None if sp.identity
                       else jnp.asarray(sp.pos_of.astype(np.int32)))
    sdev["epochs"] = sp.shard_epoch.copy()
    stats["last_shards"] = int(dirty.size)
    stats["total_shards"] += int(dirty.size)
    return sdev


def _pallas_sharded_field(
    g: LabelledGraph,
    trie: TrieArrays,
    part: np.ndarray,
    k: int,
    depth_cap: int,
    pre: Dict,
    dense_ext_to: bool,
    interpret: Optional[bool] = None,
    mesh=None,
    shard_map_source: str = "stripe",
    halo_exchange: str = "sliced",
):
    """Multi-device extroversion field: ``vm_step`` per shard over the
    graph's sharded packing, halo-exchanging only the ``beta`` rows other
    shards read between depth steps (module docstring §sharded).

    The mesh defaults to ``repro.launch.mesh.make_smoke_mesh()`` over every
    visible device and is cached in ``pre["_mesh"]``; callers may seed
    ``pre["_mesh"]`` (e.g. a production mesh's ``model`` axis) instead.

    The shard map is sticky: the first sharded evaluation resolves
    ``shard_map_source`` (``"stripe"`` | ``"partition"`` — dealt along this
    call's partition vector — | ``"bfs"``) into a vertex permutation cached
    in ``pre["_shard_order"]``; subsequent calls reuse it so the packing is
    never re-dealt mid-invocation.  ``Taper.maybe_redeal_shards`` (called
    by ``OnlineTaper.commit_invocation``) refreshes it off the critical
    path.  Callers may seed ``pre["_shard_order"] = (token, pos_of)``
    directly (tests use random permutations).
    """
    from repro.graphs.sharded_packing import compute_shard_order

    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if mesh is None:
        mesh = pre.get("_mesh")
    if mesh is None:
        from repro.launch.mesh import make_smoke_mesh

        mesh = make_smoke_mesh()
        pre["_mesh"] = mesh
    S = int(mesh.shape["model"])

    n, m = g.n, g.m
    N = trie.n_nodes
    cnt = pre.get("cnt")
    if cnt is None:
        # the graph's own (incrementally patched) matrix, so the cached
        # sharded packing stays patchable across mutations
        cnt = g.cached_neighbor_label_counts()
    lab_vcount = pre.get("lab_vcount")
    if lab_vcount is None:
        lab_vcount = g.label_counts()
    dev = _device_inputs(g, pre, cnt, lab_vcount)

    order_entry = pre.get("_shard_order")
    if order_entry is None and shard_map_source != "stripe":
        order_entry = (f"{shard_map_source}:0",
                       compute_shard_order(g, shard_map_source, S, part=part))
        pre["_shard_order"] = order_entry
    token, order = order_entry if order_entry is not None else ("stripe", None)
    sp = g.vm_packing_sharded(S, cnt=cnt, order=order, order_token=token)
    sdev = _sharded_device_arrays(sp, pre)
    arr = sdev["arrays"]
    frloc, frown = sdev["fr"]

    T_key = (trie.topology_signature(), int(depth_cap), trie.cond_p.tobytes())
    t_hit = pre.get("_T_dev")
    if t_hit is None or t_hit[0] != T_key:
        T = jnp.asarray(_capped_transition(trie, depth_cap))
        Tsum = T.sum(axis=2)
        pre["_T_dev"] = (T_key, T, Tsum)
    else:
        _, T, Tsum = t_hit

    round_cap = tuple(int(c) for c in sp.round_cap)
    key = ("sharded", trie.topology_signature(), int(depth_cap), S,
           sp.blocks_per_shard, sp.block_n, sp.block_e, sp.eb_cap,
           sp.n_local_pad, sp.h_pad, sp.hot_pad, round_cap, halo_exchange,
           bool(interpret), id(mesh))
    fn = _FIELD_CACHE.get(key)
    if fn is None:
        fn = _build_sharded_fn(
            mesh, trie, depth_cap, sp.blocks_per_shard, sp.block_n,
            sp.block_e, sp.n_local_pad, sp.h_pad, interpret,
            exchange=halo_exchange, n_shards=S, round_cap=round_cap)
        while len(_FIELD_CACHE) >= 64:
            _FIELD_CACHE.pop(next(iter(_FIELD_CACHE)))
        _FIELD_CACHE[key] = fn

    if halo_exchange == "sliced":
        src_map_in = arr["src_map_sliced"]
        fr_a, fr_b = sdev["hot"]
    else:
        src_map_in, fr_a, fr_b = arr["src_map"], frloc, frown
    part_dev = jnp.asarray(part.astype(np.int32))
    alpha_sh, slot_mass = fn(
        arr["meta"], src_map_in, arr["dst_local"], arr["dst_label"],
        arr["inv_cnt"], arr["src_global"], arr["dst_global"], arr["vlabels"],
        fr_a, fr_b, arr["send_local"],
        part_dev, jnp.asarray(trie.p),
        dev["lab_vcount"], T, Tsum)

    alpha_pos = jnp.reshape(alpha_sh, (S * sp.n_local_pad, N))
    # kernel rows are positions; the shard map's inverse restores vertex
    # order (no-op slice under the identity stripe map)
    alpha = (alpha_pos[:n] if sdev["pos"] is None
             else alpha_pos[sdev["pos"]])
    mass = jnp.asarray(sp.scatter_slot_values(np.asarray(slot_mass), m))
    src, dst = dev["src"], dev["dst"]
    local = (part_dev[src] == part_dev[dst]).astype(jnp.float32)

    full = sp.full_field_bytes_per_depth(n, N)
    halo = sp.halo_bytes_per_depth(N, exchange=halo_exchange)
    max_depth = min(trie.max_depth, depth_cap)
    pre["_halo_stats"] = {
        "halo_bytes_per_depth": halo,
        "full_field_bytes_per_depth": full,
        "halo_ratio": halo / max(full, 1),
        "shard_map_source": token.split(":")[0],
        "halo_exchange": halo_exchange,
        "n_shards": S,
        "n_frontier": sp.n_frontier,
        "hot_rows": sp.hot_pad,
        "sliced_rows": sp.hot_pad + int(sp.round_cap[1:].sum()),
        # DP depth steps the kernel ran (each one is a halo exchange) —
        # the invocation trace emits one field.depth event per step
        "depth_steps": max(int(max_depth) - 1, 0),
    }

    counted = [
        i for i in range(N)
        if 1 <= int(trie.depth[i]) < max_depth and not bool(trie.is_leaf[i])
    ]
    return _field_aggregates(counted, k, dense_ext_to,
                             alpha, mass, src, dst, part_dev, local, n)


def extroversion_field(
    g: LabelledGraph,
    trie: TrieArrays,
    part: np.ndarray,
    k: int,
    depth_cap: Optional[int] = None,
    _precomputed: Optional[Dict] = None,
    fused: bool = True,
    dense_ext_to: bool = True,
    backend: str = "jnp",
    shard_map_source: str = "stripe",
    halo_exchange: str = "sliced",
) -> ExtroversionResult:
    """Compute the extroversion field of ``part`` under the workload trie.

    ``depth_cap`` implements the paper's §5.2.2 time heuristic (stop VM row
    expansion at path length < t, trading accuracy for time).

    ``dense_ext_to=True`` (the default, matching ``TaperConfig``) also
    returns the dense ``(n, k)`` per-destination external-mass matrix in one
    fused pass — one extra ``segment_sum`` and ``n*k`` floats of memory.
    ``dense_ext_to=False`` selects the two-phase §Perf-T2 trade-off: the
    field pass skips the matrix and the swap engine derives each
    *candidate's* destination preferences lazily from its own cut edges —
    cheaper when ``k`` is large or candidate queues are short, at the cost
    of a little host work per candidate.

    ``backend`` selects the DP engine: ``"jnp"`` (the fused XLA
    transcription), ``"pallas"`` (the ``vm_step`` TPU kernel over the
    graph's cached edge packing; interpret mode auto-disables on TPU) or
    ``"pallas_sharded"`` (the same kernel per shard over every visible
    device, halo-exchanging only the cross-shard ``beta`` rows between
    depth steps — see the module docstring; seed ``_precomputed["_mesh"]``
    to pin a specific mesh).  ``shard_map_source`` / ``halo_exchange``
    apply to the sharded backend only: how vertices are dealt to shards
    (``"stripe"`` | ``"partition"`` | ``"bfs"``) and whether the exchange
    moves per-shard-pair slices (``"sliced"``: a psum'd hot union plus
    ``S - 1`` ring ``ppermute`` rounds, padded per round) or the psum'd
    union frontier (``"psum"``).
    """
    depth_cap = depth_cap or trie.max_depth
    pre = _precomputed if _precomputed is not None else {}
    if backend == "pallas":
        out = _pallas_field(g, trie, part, k, depth_cap, pre, dense_ext_to)
    elif backend == "pallas_sharded":
        out = _pallas_sharded_field(g, trie, part, k, depth_cap, pre,
                                    dense_ext_to,
                                    shard_map_source=shard_map_source,
                                    halo_exchange=halo_exchange)
    elif backend == "jnp":
        key = (trie.topology_signature(), k, depth_cap, g.n, g.m, fused,
               dense_ext_to)
        fn = _FIELD_CACHE.get(key)
        if fn is None:
            fn = _build_field_fn(key, trie, k, depth_cap, fused=fused,
                                 dense_ext_to=dense_ext_to)
            _FIELD_CACHE[key] = fn

        cnt = pre.get("cnt")
        if cnt is None:
            cnt = g.neighbor_label_counts()
        lab_vcount = pre.get("lab_vcount")
        if lab_vcount is None:
            lab_vcount = g.label_counts()
        dev = _device_inputs(g, pre, cnt, lab_vcount)

        out = fn(
            dev["src"],
            dev["dst"],
            dev["labels"],
            dev["cnt"],
            dev["lab_vcount"],
            jnp.asarray(part.astype(np.int32)),
            jnp.asarray(trie.p),
            jnp.asarray(trie.cond_p),
            n=g.n,
            m=g.m,
        )
    else:
        raise ValueError(f"unknown field backend {backend!r}")
    if dense_ext_to:
        alpha, pr, mass, extro_mass, extroversion, ext_to = out
        ext_to = np.asarray(ext_to)
    else:
        alpha, pr, mass, extro_mass, extroversion = out
        ext_to = None
    return ExtroversionResult(
        alpha=np.asarray(alpha),
        pr=np.asarray(pr),
        edge_mass=np.asarray(mass),
        extro_mass=np.asarray(extro_mass),
        extroversion=np.asarray(extroversion),
        ext_to=ext_to,
        total_extroversion=float(np.asarray(extro_mass).sum()),
    )


# ---------------------------------------------------------------------------
# Reference single-cell evaluation (paper §4.2) — used by tests/examples
# ---------------------------------------------------------------------------


def vm_cell(
    g: LabelledGraph, trie: TrieArrays, path_vertices, label_names=None
) -> np.ndarray:
    """``VM^(t)[p_1, ..., p_{t-1}, *]``: the distribution over next vertices
    given the path ``path_vertices`` (paper §4.2 worked example).

    Returns an ``(n,)`` vector of transition probabilities (rows need not sum
    to 1; the shortfall is the 'no subsequent traversal' mass, §4.2 fn. 6).
    """
    path = list(path_vertices)
    # find trie node for the label string of the path
    node = 0
    for v in path:
        child = trie.child_index[node, g.labels[v]]
        if child < 0:
            return np.zeros(g.n, dtype=np.float64)
        node = int(child)
    last = path[-1]
    nbrs = g.neighbors(last)
    nbr_labels = g.labels[nbrs]
    out = np.zeros(g.n, dtype=np.float64)
    for lab_id in range(trie.n_labels):
        child = trie.child_index[node, lab_id]
        if child < 0:
            continue
        cond = float(trie.cond_p[child])
        same = nbrs[nbr_labels == lab_id]
        if same.size:
            out[same] += cond / same.size
    return out
