"""TAPER invocation driver (paper §1.1 def. 1, §3, §5).

One *invocation* enhances an existing partitioning for a workload snapshot by
running internal iterations of (extroversion field -> vertex swapping) until
convergence (paper: 6-8 iterations).  Repeated invocations against a drifting
workload implement eqn. (2):

    P_k^0(G) --Q1--> P_k^1(G, Q1) --Q2--> P_k^2(G, Q2) ...
"""
from __future__ import annotations

from dataclasses import dataclass, field as dfield
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.rpq import RPQ
from repro.core.swap import SwapConfig, SwapStats, swap_iteration
from repro.core.tpstry import TPSTry, TrieArrays
from repro.core.visitor import ExtroversionResult, extroversion_field
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("core.taper")

Workload = Sequence[Tuple[RPQ, float]]

#: extroversion-field DP backends ordered by capability: serving loops
#: degrade left-to-right on repeated device failure (losing scale, keeping
#: availability) and probe back right-to-left once the fault clears
FIELD_BACKEND_LADDER = ("pallas_sharded", "pallas", "jnp")


class InvocationAborted(RuntimeError):
    """Raised inside :meth:`Taper.invoke` when the caller's ``should_abort``
    hook fires — a watchdog cancelling a stalled/abandoned run.  The
    partition is untouched (enhancement only publishes via the report)."""


@dataclass
class TaperConfig:
    max_iterations: int = 8          # paper: converges within 6-8
    converge_rel_tol: float = 0.01   # stop when objective improves < 1%
    candidates_per_part: Optional[int] = None  # None = full queue (§5.5)
    rank_by: str = "extroversion"    # "extroversion" (paper) | "mass"
    family_threshold: float = 0.5
    family_max_size: int = 12
    balance_eps: float = 0.05
    min_gain: float = 0.0
    safe_introversion: float = 0.95  # §5.2.1 space heuristic
    depth_cap: Optional[int] = None  # §5.2.2 time heuristic (k < t)
    fused_field: bool = True         # §Perf-T1 batched DP passes
    #: Dense per-destination external-mass matrix (matches the
    #: ``extroversion_field`` default).  True computes the (n, k) ``ext_to``
    #: in the fused device pass — one extra segment_sum, n*k floats — and the
    #: swap engine batch-gathers preference rows from it.  False selects the
    #: two-phase §Perf-T2 trade-off: the field pass skips the matrix and swap
    #: derives each candidate's preferences lazily from its own cut edges
    #: (cheaper for large k / short candidate queues).
    dense_ext_to: bool = True
    #: extroversion-field DP engine: "jnp" (fused XLA), "pallas" (vm_step
    #: kernel, single device) or "pallas_sharded" (vm_step per mesh shard
    #: with frontier halo exchange — scales the field with device count)
    field_backend: str = "jnp"
    #: sharded backend only — how vertices are dealt to mesh shards:
    #: "stripe" (contiguous id ranges), "partition" (dealt along the live
    #: TAPER partition vector, k -> S folded; OnlineTaper re-deals on
    #: commit) or "bfs" (locality order for graphs with no partition yet)
    shard_map_source: str = "stripe"
    #: sharded backend only — per-depth halo collective: "sliced" (hot
    #: broadcast rows + per-shard-pair ring slices; bytes scale with what
    #: each shard reads) or "psum" (the union-frontier fallback for meshes
    #: where the ring rounds lose)
    halo_exchange: str = "sliced"
    #: skip a commit-time shard re-deal when fewer than this fraction of
    #: vertices would change shard (avoids repacking churn on converged
    #: partitions)
    redeal_min_moved_frac: float = 0.01
    star_max: int = 3
    trie_max_len: Optional[int] = None
    seed: int = 0

    def swap_config(self) -> SwapConfig:
        return SwapConfig(
            candidates_per_part=self.candidates_per_part,
            family_threshold=self.family_threshold,
            family_max_size=self.family_max_size,
            balance_eps=self.balance_eps,
            min_gain=self.min_gain,
            safe_introversion=self.safe_introversion,
            rank_by=self.rank_by,
        )


@dataclass
class TaperReport:
    """Trace of one TAPER invocation."""

    parts: List[np.ndarray] = dfield(default_factory=list)   # per iteration
    objective: List[float] = dfield(default_factory=list)    # total extroversion
    moves: List[int] = dfield(default_factory=list)
    stats: List[SwapStats] = dfield(default_factory=list)
    iterations: int = 0
    total_moves: int = 0

    @property
    def final_part(self) -> np.ndarray:
        return self.parts[-1]

    @property
    def improvement(self) -> float:
        if not self.objective or self.objective[0] <= 0:
            return 0.0
        return 1.0 - self.objective[-1] / self.objective[0]


class Taper:
    """Workload-aware partition enhancer over a fixed graph."""

    def __init__(self, g: LabelledGraph, k: int, config: Optional[TaperConfig] = None):
        self.g = g
        self.k = k
        self.config = config or TaperConfig()
        # partition-independent precomputes shared across invocations; the
        # field functions also cache device-resident edge arrays in here, so
        # only the partition vector is re-uploaded per iteration.  All of it
        # is keyed to the graph's mutation version: after
        # ``g.apply_mutations`` the host counts are re-fetched (the graph
        # patches them incrementally) and the visitor drops its stale
        # device buffers.
        self._pre = {
            "cnt": g.cached_neighbor_label_counts(),
            "lab_vcount": g.label_counts(),
        }
        self._g_version = g.version
        self._rng = np.random.default_rng(self.config.seed)
        # §4.2 lazy re-evaluation state: compiled trie + memoised fields are
        # reused across invocations while the TPSTry is unchanged.  The
        # per-instance signature (not just the trie's shared snapshot, which
        # any other Taper or caller may refresh) guards cache validity.
        self._trie_ref: Optional[TPSTry] = None
        self._trie_sig: Optional[Tuple] = None
        self._snapshot_key = f"taper:{id(self):x}"
        self._arrays_cache: Optional[TrieArrays] = None
        # single-entry memo: only a repeat evaluation of the latest
        # (trie, partition) pair can hit, and one ExtroversionResult is
        # O(n*N + m + n*k) floats — don't pin more than one
        self._field_memo: Optional[Tuple[Tuple, ExtroversionResult]] = None
        self._redeal_counter = 0
        # observability (optional; wired by the serving loop): when a
        # tracer is attached and ``trace_ctx`` is a sampled invocation
        # trace, field evaluations / swap iterations / shard re-deals emit
        # spans under it.  Both default to off — a bare Taper pays nothing.
        self.tracer = None
        self.trace_ctx = None

    def _span(self, name: str, **attrs):
        """Open a span on the attached invocation trace (no-op span when
        no tracer/context is wired)."""
        if self.tracer is None or self.trace_ctx is None:
            from repro.obs.trace import NOOP_SPAN

            return NOOP_SPAN
        return self.tracer.start(name, self.trace_ctx, **attrs)

    def __del__(self):
        # release this instance's snapshot slot on a shared, long-lived trie
        trie = getattr(self, "_trie_ref", None)
        if trie is not None:
            try:
                trie.drop_snapshot(self._snapshot_key)
            except Exception:
                pass

    @staticmethod
    def _tpstry_signature(trie: TPSTry) -> Tuple:
        """Cheap per-instance identity of a TPSTry's topology+probabilities."""
        return (
            tuple(nd.parent for nd in trie.nodes),
            tuple(nd.symbol for nd in trie.nodes),
            np.array([nd.p for nd in trie.nodes], dtype=np.float64).tobytes(),
        )

    def _sync_graph(self) -> None:
        """Refresh graph-derived host state after topology mutations.

        Device-buffer refresh happens inside ``repro.core.visitor`` (it
        compares the version recorded next to the buffers); here we re-fetch
        the incrementally-patched host count arrays and drop the field memo,
        which was computed against the old topology."""
        if self._g_version != self.g.version:
            self._pre["cnt"] = self.g.cached_neighbor_label_counts()
            self._pre["lab_vcount"] = self.g.label_counts()
            self._field_memo = None
            self._g_version = self.g.version

    def _frontier_mask(self, frontier: np.ndarray) -> np.ndarray:
        """Dirty-frontier candidate mask: the mutated vertices plus their
        1-hop neighbourhood (a mutation changes the extroversion of both
        endpoints' neighbourhoods)."""
        g = self.g
        mask = np.zeros(g.n, dtype=bool)
        vs = np.asarray(frontier, dtype=np.int64).reshape(-1)
        vs = vs[(vs >= 0) & (vs < g.n)]
        mask[vs] = True
        if vs.size:
            mask[g.dst[g.edge_indices_of(vs)].astype(np.int64)] = True
        return mask

    def _mesh_shards(self) -> int:
        """Shard count of the field mesh (``model`` axis; defaults to every
        visible device, matching ``_pallas_sharded_field``)."""
        mesh = self._pre.get("_mesh")
        if mesh is not None:
            return int(mesh.shape["model"])
        import jax

        return len(jax.devices())

    def _seed_shard_order(self, part: np.ndarray) -> None:
        """Resolve the sticky shard map now (idempotent) so the field memo
        key is stable from the first evaluation on."""
        cfg = self.config
        if (cfg.field_backend != "pallas_sharded"
                or cfg.shard_map_source == "stripe"
                or "_shard_order" in self._pre):
            return
        from repro.graphs.sharded_packing import compute_shard_order

        self._pre["_shard_order"] = (
            f"{cfg.shard_map_source}:0",
            compute_shard_order(self.g, cfg.shard_map_source,
                                self._mesh_shards(), part=part))

    def maybe_redeal_shards(self, part: np.ndarray,
                            n_shards: Optional[int] = None) -> bool:
        """Refresh the sharded field's shard map along ``part``.

        Applies only under ``field_backend="pallas_sharded"`` with
        ``shard_map_source="partition"``.  Computes the fresh
        partition-dealt vertex order and installs it in the precompute
        dict; the next field evaluation re-packs (and re-uploads) along it
        — callers invoke this *off the invocation's critical path*
        (``OnlineTaper.commit_invocation`` does, right after the partition
        swap).  Skipped (returns ``False``) when fewer than
        ``redeal_min_moved_frac`` of vertices would change shard, so a
        converged partitioning never thrashes the packing."""
        cfg = self.config
        if (cfg.field_backend != "pallas_sharded"
                or cfg.shard_map_source != "partition"):
            return False
        if n_shards is None:
            n_shards = self._mesh_shards()
        from repro.graphs.sharded_packing import partition_shard_order

        new_pos = partition_shard_order(part, n_shards)
        cur = self._pre.get("_shard_order")
        if cur is not None:
            _, cur_pos = cur
            n0 = min(cur_pos.shape[0], new_pos.shape[0])
            # the packing's true per-shard span (block-padded); the live
            # packing knows it exactly, else reconstruct from the default
            # block_n the field path uses
            sdev = self._pre.get("_shard_dev")
            if sdev is not None and sdev["sp"].n_shards == n_shards:
                span = sdev["sp"].n_local_pad
            else:
                nb = max(1, -(-new_pos.shape[0] // 128))
                span = -(-nb // n_shards) * 128
            moved = (float(np.mean(
                new_pos[:n0] // span != cur_pos[:n0] // span)) if n0 else 1.0)
            if moved < cfg.redeal_min_moved_frac:
                return False
        self._redeal_counter += 1
        self._pre["_shard_order"] = (
            f"partition:{self._redeal_counter}", new_pos)
        self._field_memo = None     # memoed field keyed on the old layout
        if self.tracer is not None and self.trace_ctx is not None:
            self.tracer.event("invocation.redeal", self.trace_ctx,
                              redeal_epoch=self._redeal_counter,
                              n_shards=int(n_shards))
        log.info("re-dealt shard map along partition (epoch %d)",
                 self._redeal_counter)
        return True

    def set_field_backend(self, backend: str) -> None:
        """Switch the extroversion-field DP engine in place.

        The serving loop's graceful-degradation ladder calls this to fall
        from ``pallas_sharded`` toward ``jnp`` on repeated device failure
        (and to probe back up).  Device-resident caches in ``_pre`` are
        keyed per backend so they survive the round trip; only the field
        memo (keyed on the old backend) is dropped."""
        if backend not in FIELD_BACKEND_LADDER:
            raise ValueError(f"unknown field backend {backend!r}")
        if backend == self.config.field_backend:
            return
        self.config.field_backend = backend
        self._field_memo = None

    # -- workload handling ---------------------------------------------------
    def build_trie(self, workload: Workload) -> TPSTry:
        return TPSTry.from_workload(
            workload, max_len=self.config.trie_max_len, star_max=self.config.star_max
        )

    # -- the core API ----------------------------------------------------------
    def field(
        self, part: np.ndarray, trie: Union[TPSTry, TrieArrays]
    ) -> ExtroversionResult:
        self._sync_graph()
        arrays = (
            trie if isinstance(trie, TrieArrays) else trie.compile(self.g.label_names)
        )
        cfg = self.config
        # resolve the sticky shard map before keying the memo, so the first
        # sharded evaluation doesn't memoize under a pre-install key
        self._seed_shard_order(np.asarray(part))
        # §4.2 lazy re-evaluation: if neither the trie probabilities nor the
        # partition changed since the last evaluation, the field is reused
        # verbatim instead of recomputed (workload drift without frequency
        # change, repeated invocations on a converged partitioning, ...)
        memo_key = (
            arrays.topology_signature(),
            arrays.p.tobytes(),
            arrays.cond_p.tobytes(),
            np.asarray(part, dtype=np.int32).tobytes(),
            cfg.depth_cap, cfg.fused_field, cfg.dense_ext_to,
            cfg.field_backend, cfg.halo_exchange,
            self._pre.get("_shard_order", (None,))[0],
            self.k, self.g.version,
        )
        if self._field_memo is not None and self._field_memo[0] == memo_key:
            return self._field_memo[1]
        with self._span("invocation.field",
                        backend=cfg.field_backend) as sp:
            fld = extroversion_field(
                self.g,
                arrays,
                part,
                self.k,
                depth_cap=cfg.depth_cap,
                _precomputed=self._pre,
                fused=cfg.fused_field,
                dense_ext_to=cfg.dense_ext_to,
                backend=cfg.field_backend,
                shard_map_source=cfg.shard_map_source,
                halo_exchange=cfg.halo_exchange,
            )
            hs = self._pre.get("_halo_stats")
            if hs:
                sp.set(halo_bytes_per_depth=hs.get("halo_bytes_per_depth", 0),
                       halo_ratio=hs.get("halo_ratio", 0.0),
                       depth_steps=hs.get("depth_steps", 0),
                       n_shards=hs.get("n_shards", 0))
                if self.tracer is not None and self.trace_ctx is not None:
                    # per-depth accounting: one instant marker per DP depth
                    # step, each carrying the bytes its halo exchange moved
                    for d in range(int(hs.get("depth_steps", 0))):
                        self.tracer.event(
                            "field.depth", self.trace_ctx, depth=d + 1,
                            halo_bytes=hs.get("halo_bytes_per_depth", 0))
        self._field_memo = (memo_key, fld)
        return fld

    def invoke(
        self,
        part: np.ndarray,
        workload: Union[Workload, TPSTry, TrieArrays],
        max_iterations: Optional[int] = None,
        frontier: Optional[np.ndarray] = None,
        should_abort=None,
    ) -> TaperReport:
        """One TAPER invocation (def. 1): enhance ``part`` for the workload.

        ``frontier`` (optional vertex-id array) runs a *mutation-local*
        invocation: the swap candidate queue is seeded only from the dirty
        frontier (the given vertices plus their 1-hop neighbourhood), and
        grows with each iteration's moved vertices so improvements can
        propagate outward — paper §5.5's queue pruning generalised to
        topology deltas.

        ``should_abort`` (optional zero-arg callable) is polled at iteration
        boundaries; returning True raises :class:`InvocationAborted` — the
        cooperative cancel a serving watchdog uses on an abandoned run, so
        the thread releases the graph-immutability window promptly instead
        of finishing work nobody will commit.
        """
        self._sync_graph()
        if should_abort is not None and should_abort():
            raise InvocationAborted("invocation aborted before start")
        if isinstance(workload, TrieArrays):
            arrays = workload
        elif isinstance(workload, TPSTry):
            # §4.2 lazy re-evaluation: skip recompiling (and, via the field
            # memo, recomputing) when the trie is unchanged.  The shared
            # snapshot is a fast pre-check only — another Taper (or caller)
            # may have re-snapshotted after a drift, so validity rests on
            # this instance's own signature of what it compiled.
            sig = None
            if (
                self._trie_ref is workload
                and self._arrays_cache is not None
            ):
                if not workload.changed_since_snapshot(
                        key=self._snapshot_key).any():
                    sig = self._tpstry_signature(workload)
            if sig is not None and sig == self._trie_sig:
                arrays = self._arrays_cache
            else:
                if self._trie_ref is not None and self._trie_ref is not workload:
                    # leaving a trie behind: release our slot on it
                    self._trie_ref.drop_snapshot(self._snapshot_key)
                arrays = workload.compile(self.g.label_names)
                self._trie_ref = workload
                self._trie_sig = self._tpstry_signature(workload)
                self._arrays_cache = arrays
            # private snapshot slot: never clobbers the default-slot snapshot
            # a caller may be polling for its own drift detection
            workload.snapshot(key=self._snapshot_key)
        else:
            arrays = self.build_trie(workload).compile(self.g.label_names)

        cfg = self.config
        part = np.asarray(part, dtype=np.int32).copy()
        report = TaperReport()
        report.parts.append(part.copy())

        fld = self.field(part, arrays)
        report.objective.append(fld.total_extroversion)
        log.info(
            "taper invoke: n=%d k=%d trie_nodes=%d objective0=%.4f",
            self.g.n, self.k, arrays.n_nodes, fld.total_extroversion,
        )

        cand_mask = None
        if frontier is not None:
            cand_mask = self._frontier_mask(frontier)

        iters = max_iterations or cfg.max_iterations
        for it in range(iters):
            if should_abort is not None and should_abort():
                raise InvocationAborted(
                    f"invocation aborted at iteration {it + 1}")
            with self._span("invocation.swap", iteration=it + 1) as swap_sp:
                new_part, stats = swap_iteration(
                    self.g, part, fld, self.k, cfg.swap_config(), self._rng,
                    candidate_mask=cand_mask,
                )
                swap_sp.set(moves=stats.moves)
            if stats.moves == 0:
                log.info("iteration %d: no moves, converged", it + 1)
                break
            if cand_mask is not None:
                # let the frontier follow the moves: moved vertices and
                # their neighbourhoods become candidates next iteration
                moved_now = np.nonzero(new_part != part)[0]
                cand_mask |= self._frontier_mask(moved_now)
            part = new_part
            fld = self.field(part, arrays)
            report.parts.append(part.copy())
            report.objective.append(fld.total_extroversion)
            report.moves.append(stats.moves)
            report.stats.append(stats)
            report.iterations = it + 1
            report.total_moves += stats.moves
            log.info(
                "iteration %d: moves=%d objective=%.4f (%.1f%% of start)",
                it + 1, stats.moves, fld.total_extroversion,
                100.0 * fld.total_extroversion / max(report.objective[0], 1e-30),
            )
            prev, cur = report.objective[-2], report.objective[-1]
            if prev > 0 and (prev - cur) / prev < cfg.converge_rel_tol and it >= 1:
                log.info("objective improvement < %.2f%%, stopping", 100 * cfg.converge_rel_tol)
                break
        return report
