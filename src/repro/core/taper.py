"""TAPER invocation driver (paper §1.1 def. 1, §3, §5).

One *invocation* enhances an existing partitioning for a workload snapshot by
running internal iterations of (extroversion field -> vertex swapping) until
convergence (paper: 6-8 iterations).  Repeated invocations against a drifting
workload implement eqn. (2):

    P_k^0(G) --Q1--> P_k^1(G, Q1) --Q2--> P_k^2(G, Q2) ...
"""
from __future__ import annotations

from dataclasses import dataclass, field as dfield
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.rpq import RPQ
from repro.core.swap import SwapConfig, SwapStats, swap_iteration
from repro.core.tpstry import TPSTry, TrieArrays
from repro.core.visitor import ExtroversionResult, extroversion_field
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("core.taper")

Workload = Sequence[Tuple[RPQ, float]]


@dataclass
class TaperConfig:
    max_iterations: int = 8          # paper: converges within 6-8
    converge_rel_tol: float = 0.01   # stop when objective improves < 1%
    candidates_per_part: Optional[int] = None  # None = full queue (§5.5)
    rank_by: str = "extroversion"    # "extroversion" (paper) | "mass"
    family_threshold: float = 0.5
    family_max_size: int = 12
    balance_eps: float = 0.05
    min_gain: float = 0.0
    safe_introversion: float = 0.95  # §5.2.1 space heuristic
    depth_cap: Optional[int] = None  # §5.2.2 time heuristic (k < t)
    fused_field: bool = True         # §Perf-T1 batched DP passes
    dense_ext_to: bool = False       # §Perf-T2 two-phase destination prefs
    star_max: int = 3
    trie_max_len: Optional[int] = None
    seed: int = 0

    def swap_config(self) -> SwapConfig:
        return SwapConfig(
            candidates_per_part=self.candidates_per_part,
            family_threshold=self.family_threshold,
            family_max_size=self.family_max_size,
            balance_eps=self.balance_eps,
            min_gain=self.min_gain,
            safe_introversion=self.safe_introversion,
            rank_by=self.rank_by,
        )


@dataclass
class TaperReport:
    """Trace of one TAPER invocation."""

    parts: List[np.ndarray] = dfield(default_factory=list)   # per iteration
    objective: List[float] = dfield(default_factory=list)    # total extroversion
    moves: List[int] = dfield(default_factory=list)
    stats: List[SwapStats] = dfield(default_factory=list)
    iterations: int = 0
    total_moves: int = 0

    @property
    def final_part(self) -> np.ndarray:
        return self.parts[-1]

    @property
    def improvement(self) -> float:
        if not self.objective or self.objective[0] <= 0:
            return 0.0
        return 1.0 - self.objective[-1] / self.objective[0]


class Taper:
    """Workload-aware partition enhancer over a fixed graph."""

    def __init__(self, g: LabelledGraph, k: int, config: Optional[TaperConfig] = None):
        self.g = g
        self.k = k
        self.config = config or TaperConfig()
        # partition-independent precomputes shared across invocations
        self._pre = {
            "cnt": g.neighbor_label_counts(),
            "lab_vcount": g.label_counts(),
        }
        self._rng = np.random.default_rng(self.config.seed)

    # -- workload handling ---------------------------------------------------
    def build_trie(self, workload: Workload) -> TPSTry:
        return TPSTry.from_workload(
            workload, max_len=self.config.trie_max_len, star_max=self.config.star_max
        )

    # -- the core API ----------------------------------------------------------
    def field(
        self, part: np.ndarray, trie: Union[TPSTry, TrieArrays]
    ) -> ExtroversionResult:
        arrays = (
            trie if isinstance(trie, TrieArrays) else trie.compile(self.g.label_names)
        )
        return extroversion_field(
            self.g,
            arrays,
            part,
            self.k,
            depth_cap=self.config.depth_cap,
            _precomputed=self._pre,
            fused=self.config.fused_field,
            dense_ext_to=self.config.dense_ext_to,
        )

    def invoke(
        self,
        part: np.ndarray,
        workload: Union[Workload, TPSTry, TrieArrays],
        max_iterations: Optional[int] = None,
    ) -> TaperReport:
        """One TAPER invocation (def. 1): enhance ``part`` for the workload."""
        if isinstance(workload, TrieArrays):
            arrays = workload
        elif isinstance(workload, TPSTry):
            arrays = workload.compile(self.g.label_names)
        else:
            arrays = self.build_trie(workload).compile(self.g.label_names)

        cfg = self.config
        part = np.asarray(part, dtype=np.int32).copy()
        report = TaperReport()
        report.parts.append(part.copy())

        fld = self.field(part, arrays)
        report.objective.append(fld.total_extroversion)
        log.info(
            "taper invoke: n=%d k=%d trie_nodes=%d objective0=%.4f",
            self.g.n, self.k, arrays.n_nodes, fld.total_extroversion,
        )

        iters = max_iterations or cfg.max_iterations
        for it in range(iters):
            new_part, stats = swap_iteration(
                self.g, part, fld, self.k, cfg.swap_config(), self._rng
            )
            if stats.moves == 0:
                log.info("iteration %d: no moves, converged", it + 1)
                break
            part = new_part
            fld = self.field(part, arrays)
            report.parts.append(part.copy())
            report.objective.append(fld.total_extroversion)
            report.moves.append(stats.moves)
            report.stats.append(stats)
            report.iterations = it + 1
            report.total_moves += stats.moves
            log.info(
                "iteration %d: moves=%d objective=%.4f (%.1f%% of start)",
                it + 1, stats.moves, fld.total_extroversion,
                100.0 * fld.total_extroversion / max(report.objective[0], 1e-30),
            )
            prev, cur = report.objective[-2], report.objective[-1]
            if prev > 0 and (prev - cur) / prev < cfg.converge_rel_tol and it >= 1:
                log.info("objective improvement < %.2f%%, stopping", 100 * cfg.converge_rel_tol)
                break
        return report
