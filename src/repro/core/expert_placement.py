"""TAPER-style MoE expert placement (DESIGN.md §4, integration point 3).

Tokens flow expert-to-expert across consecutive MoE layers; when two
experts that frequently co-serve the same tokens sit on different devices,
the all-to-all between those layers carries that token twice across the
ICI.  The expert *co-routing* graph (vertices = (layer, expert), labels =
layer ids, edges weighted by co-routing counts) is exactly a heterogeneous
labelled graph with a 2-step path workload ``layer_l . layer_{l+1}`` — so
TAPER applies unchanged.

``plan_expert_placement`` builds the graph from routing statistics and runs
a TAPER invocation on a hash placement; the benchmark reports the reduction
in cross-device co-routing mass (the all-to-all skew proxy).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.rpq import concat, label
from repro.core.taper import Taper, TaperConfig
from repro.graphs.graph import LabelledGraph
from repro.graphs.partition import hash_partition


def co_routing_graph(expert_ids: np.ndarray, n_experts: int) -> LabelledGraph:
    """expert_ids: (T, L, K) — per token, per MoE layer, the routed experts.

    Vertex (l, e) has label "L<l>"; an edge connects (l, e) to (l+1, e')
    whenever some token is routed to e at layer l and e' at layer l+1.
    """
    T, L, K = expert_ids.shape
    edges = []
    for l in range(L - 1):
        a = expert_ids[:, l, :]          # (T, K)
        b = expert_ids[:, l + 1, :]
        for i in range(K):
            for j in range(K):
                u = l * n_experts + a[:, i]
                v = (l + 1) * n_experts + b[:, j]
                edges.append(np.stack([u, v], axis=1))
    edges = np.concatenate(edges, axis=0)
    labels = np.repeat(np.arange(L), n_experts).astype(np.int32)
    return LabelledGraph.from_undirected_edges(
        L * n_experts, labels, edges, [f"L{l}" for l in range(L)],
        dedup=False,
    )


def layer_flow_workload(n_layers: int):
    """RPQ workload: one 2-step pattern per consecutive layer pair."""
    qs = [concat(label(f"L{l}"), label(f"L{l + 1}")) for l in range(n_layers - 1)]
    f = 1.0 / max(len(qs), 1)
    return [(q, f) for q in qs]


def cross_device_mass(g: LabelledGraph, part: np.ndarray) -> float:
    """Co-routing edge mass crossing devices (all-to-all skew proxy)."""
    return float((part[g.src] != part[g.dst]).sum()) / 2.0


def plan_expert_placement(
    expert_ids: np.ndarray, n_experts: int, n_devices: int,
    seed: int = 0, max_iterations: int = 6,
) -> Dict:
    g = co_routing_graph(expert_ids, n_experts)
    L = expert_ids.shape[1]
    workload = layer_flow_workload(L)
    part0 = hash_partition(g.n, n_devices, seed)
    taper = Taper(g, n_devices, TaperConfig(
        max_iterations=max_iterations, balance_eps=0.1, seed=seed))
    report = taper.invoke(part0, workload)
    return {
        "graph": g,
        "placement0": part0,
        "placement": report.final_part,
        "cross_mass_before": cross_device_mass(g, part0),
        "cross_mass_after": cross_device_mass(g, report.final_part),
        "moves": report.total_moves,
        "iterations": report.iterations,
    }
