"""TPSTry — the Traversal Pattern Summary Trie (paper §4).

Encodes the label strings expanded from every RPQ in the workload as a prefix
trie.  Each node carries the set of queries that can traverse a path with that
label prefix, and a probability ``p(n)`` (paper §4.1):

    p(n) = sum_Q Pr(root -> ... -> n | Q) * Pr(Q)

where, *within* a query, the next-label distribution at a prefix is uniform
over the distinct next symbols the query admits at that prefix (paper §4.1's
worked example: "initially Q2 can match both a and c, with equal
probability").

The trie grows with ``|L_V|^t`` (not ``|V|^t``) — it is the *intensional*
representation that makes TAPER tractable.

``TrieArrays`` is the array compilation consumed by the vectorised
Visitor-Matrix DP (repro.core.visitor): static topology (numpy int arrays,
hashable signature → one jit cache entry per topology) + dynamic
probabilities (updated as workload frequencies drift, no recompilation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ
from repro.utils import get_logger

log = get_logger("core.tpstry")


@dataclass
class _Node:
    node_id: int
    symbol: str            # label symbol on the incoming edge ("" for root)
    parent: int            # -1 for root
    depth: int
    children: Dict[str, int] = field(default_factory=dict)
    queries: set = field(default_factory=set)   # qhashes whose strings pass here
    p: float = 0.0


class TPSTry:
    """Mutable trie multimap + query frequency table (paper §5.3)."""

    def __init__(self, max_len: int = 5, star_max: int = 3):
        self.max_len = max_len
        self.star_max = star_max
        self.nodes: List[_Node] = [_Node(0, "", -1, 0)]
        self._queries: Dict[str, RPQ] = {}          # qhash -> expression
        self._freqs: Dict[str, float] = {}          # qhash -> relative frequency
        self._strings: Dict[str, FrozenSet[Tuple[str, ...]]] = {}
        self._snapshots: Dict[Optional[str], np.ndarray] = {}

    # -- construction -------------------------------------------------------
    @classmethod
    def from_workload(
        cls,
        workload: Sequence[Tuple[RPQ, float]],
        max_len: Optional[int] = None,
        star_max: int = 3,
    ) -> "TPSTry":
        if max_len is None:
            max_len = 1
            for q, _ in workload:
                longest = max((len(s) for s in q.strings(32, star_max)), default=1)
                max_len = max(max_len, longest)
        trie = cls(max_len=max_len, star_max=star_max)
        for q, f in workload:
            trie.add_query(q)
        trie.set_frequencies({q.qhash: f for q, f in workload})
        return trie

    def add_query(self, q: RPQ) -> None:
        """Standard trie insertion of str(Q); label every prefix node (§4)."""
        qh = q.qhash
        if qh in self._queries:
            return
        strings = q.strings(self.max_len, self.star_max)
        if not strings:
            raise ValueError(f"query {q.to_text()} expands to no strings <= {self.max_len}")
        self._queries[qh] = q
        self._strings[qh] = strings
        for s in strings:
            cur = 0
            for sym in s:
                node = self.nodes[cur]
                nxt = node.children.get(sym)
                if nxt is None:
                    nxt = len(self.nodes)
                    self.nodes.append(_Node(nxt, sym, cur, node.depth + 1))
                    node.children[sym] = nxt
                self.nodes[nxt].queries.add(qh)
                cur = nxt
        self._freqs.setdefault(qh, 0.0)

    def set_frequencies(self, freqs: Dict[str, float]) -> None:
        """Update relative frequencies; drop queries at frequency 0 (§4:
        'if an expression is not seen ... its label is removed from nodes in
        the trie; any node without any query labels is also removed')."""
        total = sum(max(f, 0.0) for f in freqs.values())
        norm = {qh: max(f, 0.0) / total for qh, f in freqs.items()} if total > 0 else {}
        dead = [qh for qh in self._queries if norm.get(qh, 0.0) <= 0.0]
        for qh in dead:
            self._remove_query(qh)
        self._freqs = {qh: norm[qh] for qh in self._queries}
        self._recompute_probabilities()

    def _remove_query(self, qh: str) -> None:
        self._queries.pop(qh, None)
        self._strings.pop(qh, None)
        self._freqs.pop(qh, None)
        for node in self.nodes:
            node.queries.discard(qh)
        self._prune_unlabelled()

    def _prune_unlabelled(self) -> None:
        keep = [True] * len(self.nodes)
        for node in self.nodes[1:]:
            if not node.queries:
                keep[node.node_id] = False
        if all(keep):
            return
        remap = {}
        new_nodes: List[_Node] = []
        for node in self.nodes:
            if keep[node.node_id]:
                remap[node.node_id] = len(new_nodes)
                new_nodes.append(node)
        for node in new_nodes:
            node.node_id = remap[node.node_id]
            node.parent = remap.get(node.parent, -1) if node.parent >= 0 else -1
            node.children = {
                sym: remap[cid] for sym, cid in node.children.items() if cid in remap
            }
        self.nodes = new_nodes

    # -- probabilities (§4.1) -------------------------------------------------
    def _recompute_probabilities(self) -> None:
        for node in self.nodes:
            node.p = 0.0
        self.nodes[0].p = 1.0
        for qh, fq in self._freqs.items():
            if fq <= 0.0:
                continue
            # BFS over nodes labelled with this query; per-query conditional
            # is uniform over the distinct next symbols the query admits.
            pr_given_q = {0: 1.0}
            frontier = [0]
            while frontier:
                nxt_frontier = []
                for nid in frontier:
                    node = self.nodes[nid]
                    kids = [
                        cid for cid in node.children.values()
                        if qh in self.nodes[cid].queries
                    ]
                    if not kids:
                        continue
                    share = pr_given_q[nid] / len(kids)
                    for cid in kids:
                        pr_given_q[cid] = pr_given_q.get(cid, 0.0) + share
                        nxt_frontier.append(cid)
                frontier = nxt_frontier
            for nid, pr in pr_given_q.items():
                if nid != 0:
                    self.nodes[nid].p += fq * pr

    # -- queries --------------------------------------------------------------
    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def max_depth(self) -> int:
        return max((n.depth for n in self.nodes), default=0)

    def node_by_path(self, symbols: Sequence[str]) -> Optional[_Node]:
        cur = 0
        for sym in symbols:
            cur = self.nodes[cur].children.get(sym)
            if cur is None:
                return None
            cur = int(cur)
        return self.nodes[cur]

    def prob_of_path(self, symbols: Sequence[str]) -> float:
        node = self.node_by_path(symbols)
        return 0.0 if node is None else node.p

    def frequencies(self) -> Dict[str, float]:
        return dict(self._freqs)

    # -- snapshotting (§4.2: lazy VM re-evaluation between iterations) --------
    def snapshot(self, key: Optional[str] = None) -> None:
        """Record the current node probabilities.  ``key`` namespaces the
        snapshot so independent observers (e.g. each Taper instance, plus an
        online driver polling for drift) can track changes without clobbering
        one another; ``None`` is the default shared slot."""
        self._snapshots[key] = np.array([n.p for n in self.nodes],
                                        dtype=np.float64)

    def drop_snapshot(self, key: Optional[str] = None) -> None:
        """Discard the snapshot stored under ``key`` (used by observers —
        e.g. a Taper being garbage-collected — so per-observer slots don't
        accumulate on a long-lived trie)."""
        self._snapshots.pop(key, None)

    def changed_since_snapshot(
        self, atol: float = 1e-12, key: Optional[str] = None
    ) -> np.ndarray:
        """Boolean mask over node ids whose probability changed since the
        last snapshot under ``key`` (nodes added since count as changed)."""
        cur = np.array([n.p for n in self.nodes], dtype=np.float64)
        prev = self._snapshots.get(key)
        if prev is None:
            return np.ones(len(cur), dtype=bool)
        if len(prev) < len(cur):
            prev = np.concatenate([prev, np.full(len(cur) - len(prev), np.nan)])
        return ~np.isclose(cur, prev[: len(cur)], atol=atol, equal_nan=False)

    # -- compilation ------------------------------------------------------------
    def compile(self, label_names: Sequence[str]) -> "TrieArrays":
        """Compile to arrays against a graph's label vocabulary.

        Trie symbols missing from the vocabulary make their subtree
        unreachable on that graph; they are dropped with a warning.
        """
        name_to_id = {s: i for i, s in enumerate(label_names)}
        keep: List[int] = []
        old_to_new: Dict[int, int] = {}
        for node in self.nodes:  # BFS order guaranteed: parents precede children
            if node.node_id == 0:
                old_to_new[0] = 0
                keep.append(0)
                continue
            if node.symbol not in name_to_id:
                log.warning("trie symbol %r not in graph labels; dropped", node.symbol)
                continue
            if node.parent not in old_to_new:
                continue  # ancestor dropped
            old_to_new[node.node_id] = len(keep)
            keep.append(node.node_id)

        order = sorted(keep, key=lambda nid: (self.nodes[nid].depth, nid))
        old_to_new = {nid: i for i, nid in enumerate(order)}
        N = len(order)
        parent = np.full(N, -1, dtype=np.int32)
        lab = np.full(N, -1, dtype=np.int32)
        depth = np.zeros(N, dtype=np.int32)
        p = np.zeros(N, dtype=np.float32)
        child_index = np.full((N, len(label_names)), -1, dtype=np.int32)
        for nid in order:
            node = self.nodes[nid]
            i = old_to_new[nid]
            depth[i] = node.depth
            p[i] = node.p
            if nid != 0:
                parent[i] = old_to_new[node.parent]
                lab[i] = name_to_id[node.symbol]
            for sym, cid in node.children.items():
                if cid in old_to_new:
                    child_index[i, name_to_id[sym]] = old_to_new[cid]
        is_leaf = (child_index < 0).all(axis=1)
        with np.errstate(divide="ignore", invalid="ignore"):
            cond_p = np.where(
                parent >= 0, p / np.maximum(p[np.maximum(parent, 0)], 1e-30), 0.0
            ).astype(np.float32)
        return TrieArrays(
            parent=parent,
            label=lab,
            depth=depth,
            p=p,
            cond_p=cond_p,
            child_index=child_index,
            is_leaf=is_leaf,
            n_labels=len(label_names),
        )


def synthetic_trie(n_labels: int = 12, depth: int = 4, branching: int = 2,
                   n_first: int = 3, seed: int = 0) -> "TrieArrays":
    """Deterministic synthetic TrieArrays for dry-runs/benchmarks at
    production scale (a plausible workload summary without real queries)."""
    rng = np.random.default_rng(seed)
    parent, label, depth_arr, p = [-1], [-1], [0], [1.0]
    frontier = []
    for i in range(min(n_first, n_labels)):
        parent.append(0)
        label.append(i)
        depth_arr.append(1)
        p.append(1.0 / n_first)
        frontier.append(len(parent) - 1)
    for d in range(2, depth + 1):
        nxt = []
        for node in frontier:
            used = set()
            for b in range(branching):
                lab = int((label[node] + 1 + b * 3 + d) % n_labels)
                if lab in used:
                    continue
                used.add(lab)
                parent.append(node)
                label.append(lab)
                depth_arr.append(d)
                p.append(p[node] * (0.5 if branching > 1 else 0.9) * 0.9)
                nxt.append(len(parent) - 1)
        frontier = nxt
    N = len(parent)
    parent = np.asarray(parent, np.int32)
    label = np.asarray(label, np.int32)
    depth_arr = np.asarray(depth_arr, np.int32)
    p = np.asarray(p, np.float32)
    child_index = np.full((N, n_labels), -1, np.int32)
    for i in range(1, N):
        child_index[parent[i], label[i]] = i
    is_leaf = (child_index < 0).all(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        cond_p = np.where(parent >= 0,
                          p / np.maximum(p[np.maximum(parent, 0)], 1e-30),
                          0.0).astype(np.float32)
    return TrieArrays(parent=parent, label=label, depth=depth_arr, p=p,
                      cond_p=cond_p, child_index=child_index,
                      is_leaf=is_leaf, n_labels=n_labels)


@dataclass(frozen=True)
class TrieArrays:
    """Array form of the TPSTry.  Topology arrays are numpy (static — they key
    the jit cache); probabilities (`p`, `cond_p`) are runtime inputs."""

    parent: np.ndarray       # (N,) int32, -1 for root
    label: np.ndarray        # (N,) int32 label id, -1 for root
    depth: np.ndarray        # (N,) int32
    p: np.ndarray            # (N,) float32
    cond_p: np.ndarray       # (N,) float32  p(n)/p(parent(n))
    child_index: np.ndarray  # (N, L) int32, -1 = no child
    is_leaf: np.ndarray      # (N,) bool
    n_labels: int

    @property
    def n_nodes(self) -> int:
        return int(self.parent.shape[0])

    @property
    def max_depth(self) -> int:
        return int(self.depth.max()) if self.n_nodes else 0

    def topology_signature(self) -> Tuple:
        """Hashable topology key (probabilities excluded) for jit caching."""
        return (
            self.parent.tobytes(),
            self.label.tobytes(),
            self.is_leaf.tobytes(),
            self.n_labels,
        )
