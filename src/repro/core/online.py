"""Online TAPER driver: continuous partition enhancement under combined
workload *and* topology drift (paper §1: "incrementally adjust the
partitioning in reaction to changes in the graph topology, the query
workload, or both").

:class:`OnlineTaper` owns a mutable :class:`~repro.graphs.graph.LabelledGraph`,
a partition vector, a :class:`~repro.workload.sketch.FrequencySketch` of the
observed query stream and an accumulated *dirty frontier* of mutated
vertices.  Each tick the caller feeds it query observations
(:meth:`observe`) and topology deltas (:meth:`apply_mutations`); the
:class:`OnlinePolicy` then decides *when* a TAPER invocation is worth its
cost — not a fixed cadence but triggers on

* **topology**: the dirty frontier exceeding a fraction of the graph —
  served by a *mutation-local* invocation whose swap candidate queue is
  seeded from the frontier only (``Taper.invoke(frontier=...)``);
* **workload**: L1 drift of the sketched frequencies since the last
  invocation;
* **ipt regression**: a caller-measured ipt exceeding the post-invocation
  baseline by a configured ratio — additionally gated (when
  ``OnlinePolicy.min_ipt_gain_per_mb`` > 0) on the projected ipt saving
  beating the degree-proportional vertex-state bytes the invocation's
  expected moves would ship between partitions;
* **cadence**: a hard upper bound on ticks between invocations.

Brand-new vertices are placed greedily on arrival: each picks the partition
holding the most intra-partition traversal probability over its already-
placed neighbours (weighted by the last extroversion field's per-vertex
traversal probability ``Pr(v)`` when available), subject to the balance
cap — so the partitioning never degenerates between invocations.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Dict, Iterable, Optional

import numpy as np

from repro.core.taper import Taper, TaperConfig, TaperReport
from repro.graphs.graph import AppliedMutation, LabelledGraph, MutationBatch
from repro.graphs.partition import hash_partition
from repro.utils import get_logger

if TYPE_CHECKING:  # import cycle guard: workload.sketch imports repro.core.rpq
    from repro.workload.sketch import FrequencySketch

log = get_logger("core.online")


@dataclass
class OnlinePolicy:
    """When to spend a TAPER invocation (see module docstring)."""

    cadence: int = 8            # invoke at least every N ticks (fallback)
    min_interval: int = 1       # never invoke more often than this
    dirty_fraction: float = 0.02   # topology trigger: |dirty| >= frac * n
    drift_l1: float = 0.5       # workload trigger: L1(freqs, freqs@invoke)
    ipt_regression: float = 1.2  # ipt trigger: measured / measured@invoke
    frontier_only: bool = True  # topology-triggered invocations are local
    min_freq: float = 1e-4      # sketch noise floor for the workload
    #: estimated bytes of vertex state shipped per incident edge when a
    #: vertex migrates between partitions (degree-proportional model: a
    #: vertex's serialized adjacency + per-edge payload dominates its
    #: transfer cost on a real store)
    migration_bytes_per_edge: float = 64.0
    #: ipt-regression gate: invoke only when the projected per-tick ipt
    #: saving (measured - post-invocation baseline) per megabyte of
    #: projected migration traffic clears this threshold.  0 disables the
    #: gate (regression ratio alone decides, the pre-PR-3 behaviour).
    min_ipt_gain_per_mb: float = 0.0
    #: bootstrap trigger: with no invocation yet and a non-empty observed
    #: workload, invoke once ``tick >= bootstrap_after_ticks``.  ``None``
    #: disables it (the cadence/topology triggers decide, the historic
    #: behaviour); serving engines set 0 so the first fit happens as soon
    #: as traffic exists — together with the serving layer's request-based
    #: ``first_invocation_after`` gate this replaces the legacy
    #: ``GraphQueryEngine`` "huge counter" first-invocation sentinel.
    #: (Deliberately tick-based and named differently from the serving
    #: config's request-based knob.)
    bootstrap_after_ticks: Optional[int] = None
    #: serve-pressure coupling (``serve.control``): when the caller passes
    #: a [0, 1] pressure signal to :meth:`OnlineTaper.poll`, an invocation
    #: is *deferred* (trigger suppressed, counted in
    #: ``pressure_deferrals``) at pressure >= ``defer_above_pressure`` —
    #: an overloaded loop cannot afford the enhancement's wall cost — and
    #: the ipt-regression threshold is *relaxed* toward 1 by
    #: ``accel_factor`` at pressure <= ``accelerate_below_pressure`` (idle
    #: capacity is the cheapest time to repartition).  ``None`` (default)
    #: disables each coupling; with no pressure passed behaviour is
    #: exactly the historic policy.
    defer_above_pressure: Optional[float] = None
    accelerate_below_pressure: Optional[float] = None
    #: relaxed regression threshold = 1 + (ipt_regression - 1) * accel_factor
    accel_factor: float = 0.5


@dataclass
class OnlineStepReport:
    """Outcome of one :meth:`OnlineTaper.step` tick."""

    tick: int
    invoked: bool
    reason: str = ""
    dirty_before: int = 0
    report: Optional[TaperReport] = None


@dataclass
class PendingInvocation:
    """An invocation split into its observe/commit halves.

    :meth:`OnlineTaper.begin_invocation` snapshots everything the TAPER run
    needs (partition vector, workload, frontier, dirty mask) on the driver
    thread; :meth:`OnlineTaper.run_invocation` may then execute on a
    different thread — overlapping with query serving — while the driver
    keeps serving against the *old* partition vector.  The graph must not
    mutate while :meth:`~OnlineTaper.run_invocation` executes (serving
    loops defer ingest while a run is in flight); mutations landing after
    the run but before the commit are safe:
    :meth:`OnlineTaper.commit_invocation` swaps the partition atomically,
    grafting the enhanced snapshot-length prefix onto whatever the live
    vector has grown to and clearing only the dirty bits the invocation
    actually consumed — mid-flight dirt survives for the next one.
    """

    reason: str
    tick: int
    n_snapshot: int
    part_snapshot: np.ndarray
    workload: list
    frontier: Optional[np.ndarray]
    dirty_snapshot: np.ndarray
    report: Optional[TaperReport] = None


class OnlineTaper:
    """Serving-loop driver combining workload sketching, topology deltas and
    policy-gated TAPER invocations over one mutable graph."""

    def __init__(
        self,
        g: LabelledGraph,
        k: int,
        part: Optional[np.ndarray] = None,
        config: Optional[TaperConfig] = None,
        policy: Optional[OnlinePolicy] = None,
        sketch: Optional["FrequencySketch"] = None,
    ):
        from repro.workload.sketch import FrequencySketch

        self.g = g
        self.k = k
        self.policy = policy or OnlinePolicy()
        self.taper = Taper(g, k, config)
        self.sketch = sketch or FrequencySketch(half_life=4.0)
        self.part = (
            np.asarray(part, dtype=np.int32).copy()
            if part is not None else hash_partition(g.n, k)
        )
        if self.part.shape[0] != g.n:
            raise ValueError("part length != g.n")
        self._dirty = np.zeros(g.n, dtype=bool)
        self.tick = 0
        self.invocations = 0
        self._last_invoke_tick = 0
        self._freqs_at_invoke: Dict[str, float] = {}
        self._ipt_at_invoke: Optional[float] = None
        self._last_total_moves: Optional[int] = None
        #: invocations the policy wanted but serve pressure deferred
        self.pressure_deferrals = 0
        #: snapshot-restored traversal prior for arrival placement: a fresh
        #: process has no field memo yet, but bitwise recovery parity needs
        #: replayed placements to see the same ``Pr`` the crashed node used
        self._restored_pr: Optional[np.ndarray] = None

    # -- inputs ---------------------------------------------------------------
    def observe(self, queries: Iterable) -> None:
        """Feed one batch of observed query instances (one sketch tick)."""
        self.sketch.observe_batch(queries)

    def apply_mutations(self, batch: MutationBatch) -> AppliedMutation:
        """Apply a topology delta: mutate the graph in place, greedily place
        brand-new vertices, and fold the changed endpoints into the dirty
        frontier for the next mutation-local invocation."""
        return self.ingest(self.g.apply_mutations(batch))

    def ingest(self, applied: AppliedMutation) -> AppliedMutation:
        """Absorb a mutation already applied to ``self.g`` (placement +
        dirty-frontier bookkeeping only) — for callers that apply the graph
        delta themselves, e.g. to account maintenance cost separately.

        The record must be the graph's *latest* mutation and contiguous
        with this driver's state — a skipped or replayed record would
        desync the partition vector, so it fails fast instead."""
        if applied.version != self.g.version:
            raise ValueError(
                f"stale AppliedMutation: record version {applied.version} "
                f"!= graph version {self.g.version} (ingest immediately "
                "after each apply_mutations)")
        if self.part.shape[0] != applied.n_before:
            raise ValueError(
                f"non-contiguous AppliedMutation: tracked part has "
                f"{self.part.shape[0]} vertices, record expects "
                f"{applied.n_before}")
        grow = applied.n_after - applied.n_before
        if grow:
            self.part = np.concatenate(
                [self.part, np.full(grow, -1, np.int32)])
            self._dirty = np.concatenate(
                [self._dirty, np.ones(grow, dtype=bool)])
            self._place_new(np.arange(applied.n_before, applied.n_after))
        if not applied.is_noop:
            dirty = applied.dirty_vertices()
            self._dirty[dirty[dirty < self.g.n]] = True
        return applied

    def _last_field(self):
        memo = self.taper._field_memo
        return memo[1] if memo is not None else None

    def placement_pr(self) -> Optional[np.ndarray]:
        """The traversal-probability prior arrival placement runs against:
        the last evaluated field's ``Pr`` when one exists, else the prior a
        snapshot restore carried over (``restore_placement_prior``)."""
        fld = self._last_field()
        if fld is not None:
            return fld.pr
        return self._restored_pr

    def restore_placement_prior(self, pr: Optional[np.ndarray]) -> None:
        """Install a snapshot-restored ``Pr`` prior for arrival placement.
        Superseded by the first real field evaluation (the memo wins in
        :meth:`placement_pr`)."""
        self._restored_pr = (
            None if pr is None else np.asarray(pr, dtype=np.float64))

    def _place_new(self, vs: np.ndarray) -> None:
        """Greedy arrival placement: argmax over partitions of the placed
        neighbours' traversal-probability mass (paper's intra-partition
        traversal probability, approximated by the last field's ``Pr``),
        subject to the configured balance cap."""
        g, k = self.g, self.k
        sizes = np.bincount(self.part[self.part >= 0], minlength=k).astype(np.int64)
        max_size = int(np.floor(
            (1.0 + self.taper.config.balance_eps) * g.n / k))
        pr = self.placement_pr()
        for v in vs.tolist():
            nbrs = g.neighbors(v).astype(np.int64)
            nbrs = nbrs[self.part[nbrs] >= 0]
            dest = None
            if nbrs.size:
                if pr is not None:
                    w = np.where(nbrs < pr.shape[0], pr[np.minimum(
                        nbrs, pr.shape[0] - 1)], 0.0).astype(np.float64)
                    # unknown-probability neighbours still count a little,
                    # so a vertex wholly attached to new vertices is not
                    # placed blind
                    w = np.maximum(w, 1e-12)
                else:
                    w = np.ones(nbrs.size, dtype=np.float64)
                score = np.bincount(self.part[nbrs], weights=w, minlength=k)
                for p in np.argsort(-score):
                    if sizes[p] < max_size:
                        dest = int(p)
                        break
            if dest is None:
                dest = int(np.argmin(sizes))
            self.part[v] = dest
            sizes[dest] += 1

    def workload_drift(self, freqs: Optional[Dict[str, float]] = None) -> float:
        """L1 distance between the sketched frequencies now and at the last
        invocation (1.0-ish before any invocation: everything is new).
        ``freqs`` lets a caller that already computed the sketch snapshot
        (the per-tick policy loop) avoid recomputing it."""
        if freqs is None:
            freqs = self.sketch.frequencies(self.policy.min_freq)
        keys = set(freqs) | set(self._freqs_at_invoke)
        return sum(
            abs(freqs.get(h, 0.0) - self._freqs_at_invoke.get(h, 0.0))
            for h in keys)

    # -- the policy loop ------------------------------------------------------
    def _decide(self, measured_ipt: Optional[float],
                pressure: Optional[float] = None) -> Optional[str]:
        pol = self.policy
        since = self.tick - self._last_invoke_tick
        if since < pol.min_interval:
            return None
        reason = self._trigger(measured_ipt, pressure)
        if (reason is not None and pressure is not None
                and pol.defer_above_pressure is not None
                and pressure >= pol.defer_above_pressure):
            # overload: the loop cannot afford the enhancement's wall cost
            # right now; the trigger condition persists, so the invocation
            # fires as soon as pressure drops back below the gate
            self.pressure_deferrals += 1
            log.info("invocation (%s) deferred: serve pressure %.2f >= %.2f",
                     reason, pressure, pol.defer_above_pressure)
            return None
        return reason

    def _trigger(self, measured_ipt: Optional[float],
                 pressure: Optional[float]) -> Optional[str]:
        pol = self.policy
        since = self.tick - self._last_invoke_tick
        if (self.invocations == 0 and pol.bootstrap_after_ticks is not None
                and self.tick >= pol.bootstrap_after_ticks):
            return "bootstrap"
        if int(self._dirty.sum()) >= max(1, int(pol.dirty_fraction * self.g.n)):
            return "topology"
        # drift is only defined against a post-invocation baseline — before
        # the first invocation the bootstrap/cadence/topology triggers
        # decide (an empty baseline would read as ~1.0 drift on a
        # stationary workload)
        freqs = self.sketch.frequencies(pol.min_freq) if self.invocations else {}
        if freqs and self.workload_drift(freqs) >= pol.drift_l1:
            return "workload"
        ipt_threshold = pol.ipt_regression
        if (pressure is not None and pol.accelerate_below_pressure is not None
                and pressure <= pol.accelerate_below_pressure):
            # idle capacity: relax the regression threshold toward 1 so a
            # smaller ipt regression justifies spending the invocation now
            ipt_threshold = 1.0 + (pol.ipt_regression - 1.0) * pol.accel_factor
        if (measured_ipt is not None and self._ipt_at_invoke is not None
                and self._ipt_at_invoke > 0
                and measured_ipt / self._ipt_at_invoke >= ipt_threshold
                and self._migration_worthwhile(measured_ipt)):
            return "ipt"
        if since >= pol.cadence:
            return "cadence"
        return None

    def estimated_migration_bytes(self) -> float:
        """Projected vertex-state transfer cost of the next invocation.

        Moves are estimated from the last invocation's actual move count
        (falling back to the topology trigger's dirty threshold before any
        history exists) and each move ships degree-proportional state:
        ``avg_degree * migration_bytes_per_edge`` bytes per vertex."""
        g = self.g
        est_moves = (self._last_total_moves
                     if self._last_total_moves is not None
                     else max(1, int(self.policy.dirty_fraction * g.n)))
        avg_deg = g.m / max(g.n, 1)
        return est_moves * avg_deg * self.policy.migration_bytes_per_edge

    def _migration_worthwhile(self, measured_ipt: float) -> bool:
        """Gate the ipt-regression trigger on projected savings beating the
        migration cost (ROADMAP: invoke only when the enhancement pays for
        the bytes it moves)."""
        threshold = self.policy.min_ipt_gain_per_mb
        if threshold <= 0:
            return True
        baseline = self._ipt_at_invoke
        if baseline is None:
            return True
        projected_gain = measured_ipt - baseline
        mb = self.estimated_migration_bytes() / 2**20
        if mb <= 0:
            return True
        return projected_gain / mb >= threshold

    def poll(self, measured_ipt: Optional[float] = None,
             pressure: Optional[float] = None) -> Optional[str]:
        """Advance one tick and return the policy's trigger reason *without*
        invoking — the decide-only half of :meth:`step`, for serving loops
        that run the invocation themselves (overlapped on another thread
        via :meth:`begin_invocation` / :meth:`commit_invocation`).

        ``pressure`` is the serving loop's [0, 1] overload signal
        (``serve.control.serve_pressure``): high pressure defers the
        invocation, low pressure relaxes the ipt-regression threshold
        (see :class:`OnlinePolicy`)."""
        self.tick += 1
        if (measured_ipt is not None and self._ipt_at_invoke is None
                and self.invocations):
            # first measurement after an invocation becomes the regression
            # baseline (the pre-invocation measure would never trigger)
            self._ipt_at_invoke = measured_ipt
        return self._decide(measured_ipt, pressure)

    def step(self, measured_ipt: Optional[float] = None) -> OnlineStepReport:
        """Advance one tick and invoke TAPER if the policy says so.

        ``measured_ipt`` (optional) is the caller's current ipt measurement
        for the live partitioning — it feeds the regression trigger and is
        recorded as the post-invocation baseline."""
        dirty_before = int(self._dirty.sum())
        reason = self.poll(measured_ipt)
        if reason is None:
            return OnlineStepReport(self.tick, False, "", dirty_before)
        report = self.invoke(reason=reason)
        return OnlineStepReport(
            self.tick, report is not None, reason, dirty_before, report)

    # -- invocation lifecycle (observe -> run -> commit) ----------------------
    def begin_invocation(
        self, reason: str = "manual"
    ) -> Optional[PendingInvocation]:
        """Snapshot the inputs of one TAPER invocation (driver thread).

        Returns ``None`` when there is no observed workload to fit yet.
        Topology-triggered invocations are mutation-local (frontier-seeded)
        when ``policy.frontier_only``; other reasons use the full queue."""
        workload = self.sketch.workload(self.policy.min_freq)
        if not workload:
            log.info("online invoke skipped: no observed workload yet")
            return None
        frontier = None
        if reason == "topology" and self.policy.frontier_only:
            frontier = np.nonzero(self._dirty)[0]
        return PendingInvocation(
            reason=reason,
            tick=self.tick,
            n_snapshot=self.g.n,
            part_snapshot=self.part.copy(),
            workload=workload,
            frontier=frontier,
            dirty_snapshot=self._dirty.copy(),
        )

    def run_invocation(self, pending: PendingInvocation,
                       should_abort=None) -> TaperReport:
        """Execute the snapshotted invocation — safe on a worker thread as
        long as the graph does not mutate until the run returns (serving
        loops defer ingest while a run is in flight).  ``should_abort`` is
        forwarded to :meth:`Taper.invoke` (watchdog cancellation)."""
        pending.report = self.taper.invoke(
            pending.part_snapshot, pending.workload,
            frontier=pending.frontier, should_abort=should_abort)
        return pending.report

    def commit_invocation(self, pending: PendingInvocation) -> TaperReport:
        """Atomically publish a finished invocation (driver thread).

        The live partition vector may have grown since the snapshot (greedy
        arrival placements committed after the run finished); the enhanced
        part covers the snapshot prefix and is grafted onto the live tail
        in one rebind — concurrent readers see either the old vector or the
        new one, never a torn mix.  Only the dirty bits captured at
        :meth:`begin_invocation` are cleared: topology dirt accumulated
        mid-flight stays for the next invocation."""
        report = pending.report
        if report is None:
            raise ValueError("commit_invocation before run_invocation")
        new_part = self.part.copy()
        n_snap = min(pending.n_snapshot, new_part.shape[0])
        new_part[:n_snap] = report.final_part.astype(np.int32)[:n_snap]
        self.part = new_part  # atomic rebind: serve threads read old or new
        # off the critical path (the swap is already published): re-deal the
        # sharded field's vertex layout along the just-committed enhanced
        # partition, so the next invocation's halo exchange follows it —
        # no-op unless shard_map_source="partition" and enough vertices
        # changed shard (Taper.maybe_redeal_shards)
        self.taper.maybe_redeal_shards(new_part)
        ds = pending.dirty_snapshot
        self._dirty[:ds.shape[0]] &= ~ds
        self._last_total_moves = report.total_moves
        self.invocations += 1
        self._last_invoke_tick = self.tick
        self._freqs_at_invoke = self.sketch.frequencies(self.policy.min_freq)
        self._ipt_at_invoke = None  # re-baselined by the next measured step
        log.info(
            "online invoke #%d (reason=%s): %d moves, objective %.4f",
            self.invocations, pending.reason, report.total_moves,
            report.objective[-1] if report.objective else float("nan"))
        return report

    def invoke(self, reason: str = "manual") -> Optional[TaperReport]:
        """Run one TAPER invocation now, synchronously (policy bypassed):
        :meth:`begin_invocation` -> :meth:`run_invocation` ->
        :meth:`commit_invocation` on the calling thread."""
        pending = self.begin_invocation(reason)
        if pending is None:
            return None
        self.run_invocation(pending)
        return self.commit_invocation(pending)
