"""TAPER core: the paper's primary contribution.

RPQ workload encoding (rpq), the TPSTry summary trie (tpstry), the
vectorised Visitor-Matrix extroversion field (visitor), vertex swapping
(swap) and the invocation driver (taper).
"""
from repro.core.rpq import RPQ, parse_rpq, label, concat, union, star
from repro.core.tpstry import TPSTry, TrieArrays
from repro.core.visitor import ExtroversionResult, extroversion_field, vm_cell
from repro.core.taper import Taper, TaperConfig, TaperReport
from repro.core.online import OnlinePolicy, OnlineStepReport, OnlineTaper

__all__ = [
    "OnlinePolicy",
    "OnlineStepReport",
    "OnlineTaper",
    "RPQ",
    "parse_rpq",
    "label",
    "concat",
    "union",
    "star",
    "TPSTry",
    "TrieArrays",
    "ExtroversionResult",
    "extroversion_field",
    "vm_cell",
    "Taper",
    "TaperConfig",
    "TaperReport",
]
