"""Vertex swapping (paper §3.1, §5.5) — frontier-batched numpy engine.

Per TAPER internal iteration:

1. take each partition's most extroverted vertices, in descending
   extroversion order (NOT random boundary vertices — paper §3.1);
2. for each candidate, compute its preferred destination partitions (by
   external mass, descending) and its *family* — the flood-fill closure of
   local vertices whose traversal probability toward a member is "more
   likely than not" (paper §5.5);
3. cooperative offer/receive: the receiving partition accepts only when its
   introversion gain exceeds the sender's loss; otherwise try the next
   destination (paper §5.5, Fig. 6);
4. a vertex moves at most once per iteration; a 5% balance constraint is
   enforced (paper §6.2.1).

All probability masses come precomputed from the extroversion field (the jit
DP).  The seed implementation walked each family one neighbour at a time with
an ``np.searchsorted`` reverse-edge lookup per neighbour pair; this version
keeps the offer/receive semantics and balance constraint bit-identical (see
``repro.core.swap_ref`` + tests/test_swap_parity.py) but does all per-family
work as whole-frontier array operations:

* family expansion expands an entire BFS frontier per step — one
  concatenated CSR slice, one gather of the cached
  ``LabelledGraph.reverse_edge_index``, one first-occurrence dedup;
* family gain/loss is a single masked segment-sum (``np.bincount``) over the
  family's incident edge set, yielding the gains toward *all* ``k``
  destinations at once (the seed recomputed a Python loop per destination).

Internal iterations are therefore "inexpensive" in the paper's sense (§5):
per-candidate cost is a handful of O(family-degree) vector ops.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.visitor import ExtroversionResult
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("core.swap")


@dataclass
class SwapConfig:
    candidates_per_part: Optional[int] = None  # None = drain the whole queue (§5.5)
    family_threshold: float = 0.5     # "more likely than not"
    family_max_size: int = 12
    balance_eps: float = 0.05         # paper: max 5% imbalance
    min_gain: float = 0.0
    safe_introversion: float = 0.95   # §5.2.1 safe-vertex threshold
    max_scan_neighbors: int = 512     # hub guard in family flood fill
    rank_by: str = "extroversion"     # "extroversion" (paper §3.2 ratio) or
                                      # "mass" (absolute external mass; beyond-paper)


@dataclass
class SwapStats:
    moves: int
    accepted_offers: int
    rejected_offers: int
    candidates: int


def _concat_csr_edges(
    g: LabelledGraph, vs: np.ndarray
) -> Tuple[np.ndarray, np.ndarray]:
    """``LabelledGraph.edge_indices_of`` plus the per-vertex edge counts."""
    cnts = g.row_ptr[vs + 1] - g.row_ptr[vs]
    return g.edge_indices_of(vs), cnts


def _frontier_edge_indices(
    g: LabelledGraph, frontier: np.ndarray, rel_mass_out: np.ndarray, cap: int
) -> np.ndarray:
    """Concatenated CSR edge indices of every frontier vertex, in frontier
    order (each vertex's edges in CSR order).  Vertices whose degree exceeds
    ``cap`` keep only their ``cap`` highest-``rel_mass_out`` edges — the same
    hub guard (and the same tie-breaking ``argsort`` call) as the seed."""
    starts = g.row_ptr[frontier]
    cnts = g.row_ptr[frontier + 1] - starts
    if not (cnts > cap).any():
        return _concat_csr_edges(g, frontier)[0]
    chunks: List[np.ndarray] = []
    for lo, c in zip(starts, cnts):
        eidx = np.arange(lo, lo + c, dtype=np.int64)
        if c > cap:
            keep = np.argsort(-rel_mass_out[eidx])[:cap]
            eidx = eidx[keep]
        chunks.append(eidx)
    return np.concatenate(chunks)


def _family_of(
    g: LabelledGraph,
    v: int,
    part: np.ndarray,
    moved: np.ndarray,
    rel_mass_out: np.ndarray,
    rev: np.ndarray,
    cfg: SwapConfig,
) -> np.ndarray:
    """Flood-fill family: local vertices likely (> threshold) to traverse
    *to* a current member (paper §5.5).  rel_mass_out[e] = edge_mass[e] /
    Pr(src[e]) — the probability that a traversal out of src follows e.

    Whole frontiers expand at once: for frontier edges ``e = (w, u)`` the
    membership test reads the reverse edge ``(u, w)`` through the cached
    ``reverse_edge_index`` gather, and candidates join in first-occurrence
    order (identical to the seed's sequential scan) up to
    ``family_max_size``."""
    home = part[v]
    fam = np.array([v], dtype=np.int64)
    frontier = fam
    while frontier.size and fam.size < cfg.family_max_size:
        eidx = _frontier_edge_indices(g, frontier, rel_mass_out,
                                      cfg.max_scan_neighbors)
        if eidx.size == 0:
            break
        nbrs = g.dst[eidx].astype(np.int64)
        # traversal from u to w is the reverse edge (u, w) of e = (w, u)
        r = rev[eidx]
        ok = (
            (part[nbrs] == home)
            & ~moved[nbrs]
            & (r >= 0)
            & ~np.isin(nbrs, fam)
            # r == -1 rows are already masked; the clamped gather is harmless
            & (rel_mass_out[np.maximum(r, 0)] > cfg.family_threshold)
        )
        cand = nbrs[ok]
        if cand.size == 0:
            break
        # first-occurrence dedup preserves the seed's sequential join order
        _, first = np.unique(cand, return_index=True)
        cand = cand[np.sort(first)]
        room = cfg.family_max_size - fam.size
        cand = cand[:room]
        fam = np.concatenate([fam, cand])
        frontier = cand
    return fam


def _family_gains(
    g: LabelledGraph,
    fam: np.ndarray,
    part: np.ndarray,
    edge_mass: np.ndarray,
    rev: np.ndarray,
    k: int,
) -> np.ndarray:
    """``(k,)`` float64 — traversal mass between the family and *each*
    partition (both edge directions), as one masked segment-sum over the
    family's incident edges.

    ``gains[dest]`` is the receiver gain of moving the family to ``dest``;
    ``gains[home]`` is the sender loss.  Family-internal edges move with the
    family and edges to third partitions stay cut, so neither affects the
    decision.  (The seed recomputed this with Python loops once per
    destination attempt.)"""
    eidx, _ = _concat_csr_edges(g, fam)
    if eidx.size == 0:
        return np.zeros(k, dtype=np.float64)
    nbrs = g.dst[eidx].astype(np.int64)
    ext = ~np.isin(nbrs, fam)
    eidx, nbrs = eidx[ext], nbrs[ext]
    r = rev[eidx]
    m = edge_mass[eidx].astype(np.float64) + np.where(
        r >= 0, edge_mass[np.maximum(r, 0)].astype(np.float64), 0.0)
    return np.bincount(part[nbrs], weights=m, minlength=k)


def _candidate_queue(
    part: np.ndarray,
    field: ExtroversionResult,
    k: int,
    cfg: SwapConfig,
    candidate_mask: Optional[np.ndarray] = None,
) -> np.ndarray:
    """Most extroverted vertices per partition (safe ones skipped, §5.2.1),
    merged into one globally descending queue (paper §3.1).

    ``candidate_mask`` restricts the queue to a vertex subset — the dirty
    frontier of mutated vertices for mutation-local online invocations
    (paper §5.5's queue pruning generalised to topology deltas)."""
    ext = field.extroversion if cfg.rank_by == "extroversion" else field.extro_mass
    per_part: List[np.ndarray] = []
    for p in range(k):
        members = np.nonzero(part == p)[0]
        if candidate_mask is not None and members.size:
            members = members[candidate_mask[members]]
        if members.size == 0:
            continue
        # §5.2.1: vertices with introversion above the safe threshold are
        # discarded (they cannot be good swap candidates)
        unsafe = field.extroversion[members] > (1.0 - cfg.safe_introversion)
        members = members[unsafe]
        if members.size == 0:
            continue
        top = members[np.argsort(-ext[members])]
        if cfg.candidates_per_part is not None:
            top = top[: cfg.candidates_per_part]
        per_part.append(top.astype(np.int64))
    if not per_part:
        return np.empty(0, dtype=np.int64)
    candidates = np.concatenate(per_part)
    # stable sort keeps the per-partition order on ties, like the seed's
    # Python list.sort(key=-ext)
    return candidates[np.argsort(-ext[candidates], kind="stable")]


def _lazy_prefs(
    g: LabelledGraph, v: int, home: int, part: np.ndarray,
    field: ExtroversionResult, k: int
) -> np.ndarray:
    """Two-phase path (§Perf-T2): per-destination preference computed lazily
    from the candidate's own cut edges.  The ``bincount`` accumulates the
    float32 masses into float64 in edge order — the same arithmetic as the
    seed's ``np.add.at``."""
    lo, hi = int(g.row_ptr[v]), int(g.row_ptr[v + 1])
    pn = part[g.dst[lo:hi]]
    cut = pn != home
    # .astype: bincount of an empty input yields int64 zeros
    return np.bincount(
        pn[cut],
        weights=field.edge_mass[lo:hi][cut].astype(np.float64),
        minlength=k).astype(np.float64)


def swap_iteration(
    g: LabelledGraph,
    part: np.ndarray,
    field: ExtroversionResult,
    k: int,
    cfg: SwapConfig,
    rng: np.random.Generator,
    candidate_mask: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, SwapStats]:
    """One internal TAPER iteration of offer/receive vertex swapping.

    ``candidate_mask`` (optional ``(n,)`` bool) seeds the candidate queue
    from a vertex subset only — used by ``OnlineTaper`` to run
    mutation-local invocations over the dirty frontier; ``None`` keeps the
    full paper §3.1 queue.

    Produces bit-identical partitions and stats to the seed implementation
    (``repro.core.swap_ref.swap_iteration_reference``), but amortises almost
    all per-candidate work into whole-array precomputes:

    * the *joinable* relation (which neighbour can ever enter a family) only
      shrinks during an iteration — vertices leave it by being moved, and
      ``part`` changes only for moved vertices — so a candidate whose family
      is a singleton at iteration start stays a singleton.  Singleton
      candidates (the vast majority under the 0.5 "more likely than not"
      threshold) get their k-destination gain rows and preference rows from
      two batched ``bincount``/``argsort`` passes over the whole candidate
      set;
    * the sequential offer/receive walk then runs in plain Python over those
      precomputed rows; a batched row is re-derived per candidate only when
      a vertex in its 1-hop neighbourhood has moved since the batch (the
      gains/prefs of v depend only on ``part``/``moved`` over N(v) ∪ {v});
    * candidates with multi-member families take the frontier-batched
      ``_family_of`` / ``_family_gains`` path against live state.
    """
    part = part.astype(np.int32).copy()
    n = g.n
    sizes = np.bincount(part, minlength=k).astype(np.int64)
    ideal = n / k
    max_size = int(np.floor((1.0 + cfg.balance_eps) * ideal))
    min_size = int(np.ceil((1.0 - cfg.balance_eps) * ideal))

    pr_src = np.maximum(field.pr[g.src], 1e-30)
    rel_mass_out = field.edge_mass / pr_src
    rev = g.reverse_edge_index
    rev_ok = rev >= 0
    rev_c = np.maximum(rev, 0)

    candidates = _candidate_queue(part, field, k, cfg, candidate_mask)
    moved = np.zeros(n, dtype=bool)
    stats = SwapStats(0, 0, 0, int(candidates.size))
    if candidates.size == 0:
        return part, stats

    # ---- whole-iteration precomputes --------------------------------------
    # symmetric edge mass m_out + m_in, in the seed's float64 arithmetic
    sym_mass = field.edge_mass.astype(np.float64) + np.where(
        rev_ok, field.edge_mass[rev_c].astype(np.float64), 0.0)
    # rel_mass_out of the reverse edge (u -> w traversal for edge e=(w, u))
    rel_rev = np.where(rev_ok, rel_mass_out[rev_c], -np.inf)
    # an edge can recruit its dst into src's family ("joinable"); this set
    # only shrinks as vertices move, so it is computed once per iteration
    join_e = (part[g.src] == part[g.dst]) & (rel_rev > cfg.family_threshold)
    has_join = np.zeros(n, dtype=bool)
    has_join[g.src[join_e]] = True
    is_single = ~has_join[candidates]

    # ---- batched gain/pref rows for singleton-family candidates -----------
    S = candidates[is_single]
    row_of = np.full(candidates.size, -1, dtype=np.int64)
    row_of[is_single] = np.arange(S.size)
    dense = field.ext_to is not None
    if S.size:
        eidx, s_cnts = _concat_csr_edges(g, S)
        cid = np.repeat(np.arange(S.size, dtype=np.int64), s_cnts)
        nbr = g.dst[eidx].astype(np.int64)
        notself = nbr != np.repeat(S, s_cnts)
        e_i, c_i, n_i = eidx[notself], cid[notself], nbr[notself]
        # .astype guards: bincount of an empty input yields int64 zeros
        gains_mat = np.bincount(
            c_i * k + part[n_i], weights=sym_mass[e_i], minlength=S.size * k
        ).astype(np.float64).reshape(S.size, k)
        if dense:
            prefs_mat = field.ext_to[S].copy()
        else:
            cut = part[n_i] != part[S][c_i]
            prefs_mat = np.bincount(
                c_i[cut] * k + part[n_i[cut]],
                weights=field.edge_mass[e_i[cut]].astype(np.float64),
                minlength=S.size * k,
            ).astype(np.float64).reshape(S.size, k)
        prefs_mat[np.arange(S.size), part[S]] = -np.inf
        order_mat = np.argsort(-prefs_mat, axis=1)
        gains_rows = gains_mat.tolist()
        prefs_rows = prefs_mat.tolist()
        order_rows = order_mat.tolist()
    else:
        gains_rows = prefs_rows = order_rows = []

    # ---- sequential offer/receive walk (pure Python on cached rows) -------
    rp = g.row_ptr.tolist()
    dl = g.dst.tolist()
    cand_list = candidates.tolist()
    row_list = row_of.tolist()
    single_list = is_single.tolist()
    dirty = bytearray(n)  # vertices whose part/moved changed since the batch
    sizes_l = sizes.tolist()
    min_gain = cfg.min_gain

    for ci, v in enumerate(cand_list):
        if moved[v]:
            continue
        home = int(part[v])
        if single_list[ci]:
            fresh = not dirty[v]
            if fresh:
                for j in range(rp[v], rp[v + 1]):
                    if dirty[dl[j]]:
                        fresh = False
                        break
            row = row_list[ci]
            if fresh:
                gains = gains_rows[row]
                prefs = prefs_rows[row]
                order = order_rows[row]
            else:
                # 1-hop state changed: re-derive from live part[] (same
                # arithmetic as the batch).  Preference rows built from
                # ext_to are static — only the two-phase lazy prefs depend
                # on neighbours' partitions; gains re-derive lazily below,
                # only once a destination passes the balance check.
                if dense:
                    prefs = prefs_rows[row]
                    order = order_rows[row]
                else:
                    prefs_a = _lazy_prefs(g, v, home, part, field, k)
                    prefs_a[home] = -np.inf
                    order = np.argsort(-prefs_a)
                    prefs = prefs_a
                gains = None
            for dest in order:
                if prefs[dest] <= 0.0:
                    break  # no external mass toward remaining partitions
                if (sizes_l[dest] + 1 > max_size
                        or sizes_l[home] - 1 < min_size):
                    stats.rejected_offers += 1
                    continue
                if gains is None:
                    lo, hi = rp[v], rp[v + 1]
                    nbrs = g.dst[lo:hi]
                    ns = nbrs != v
                    gains = np.bincount(part[nbrs[ns]],
                                        weights=sym_mass[lo:hi][ns],
                                        minlength=k)
                if gains[dest] > gains[home] + min_gain:
                    part[v] = dest
                    moved[v] = True
                    dirty[v] = 1
                    sizes_l[home] -= 1
                    sizes_l[dest] += 1
                    stats.moves += 1
                    stats.accepted_offers += 1
                    break
                stats.rejected_offers += 1
            continue

        # ---- multi-member family: frontier-batched path on live state ----
        if dense:
            prefs_a = field.ext_to[v].copy()
        else:
            prefs_a = _lazy_prefs(g, v, home, part, field, k)
        prefs_a[home] = -np.inf
        order_a = np.argsort(-prefs_a)
        fam = _family_of(g, v, part, moved, rel_mass_out, rev, cfg)
        fs = int(fam.size)
        gains_a = None  # computed on the first destination passing balance
        for dest in order_a:
            dest = int(dest)
            if prefs_a[dest] <= 0.0:
                break
            if sizes_l[dest] + fs > max_size or sizes_l[home] - fs < min_size:
                stats.rejected_offers += 1
                continue
            if gains_a is None:
                gains_a = _family_gains(g, fam, part, field.edge_mass, rev, k)
            if float(gains_a[dest]) > float(gains_a[home]) + min_gain:
                part[fam] = dest
                moved[fam] = True
                for u in fam.tolist():
                    dirty[u] = 1
                sizes_l[home] -= fs
                sizes_l[dest] += fs
                stats.moves += fs
                stats.accepted_offers += 1
                break
            stats.rejected_offers += 1
    return part, stats
