"""Seed (pre-vectorisation) swap implementation — kept as the parity oracle.

This is the original per-vertex Python implementation of ``swap_iteration``:
flood-fill families via per-neighbour ``np.searchsorted`` reverse-edge
lookups and per-destination gain loops.  ``repro.core.swap`` re-implements
the same semantics with frontier-batched numpy; the parity suite
(tests/test_swap_parity.py) and ``benchmarks/swap_scale.py`` hold the two
bit-identical on random labelled graphs.

Do not optimise this module — its value is being the unchanged oracle.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.core.swap import SwapConfig, SwapStats
from repro.core.visitor import ExtroversionResult
from repro.graphs.graph import LabelledGraph


def _edge_indices_from(g: LabelledGraph, u: int) -> Tuple[np.ndarray, np.ndarray]:
    lo, hi = g.row_ptr[u], g.row_ptr[u + 1]
    return np.arange(lo, hi, dtype=np.int64), g.dst[lo:hi]


def _edge_index(g: LabelledGraph, u: int, w: int) -> Optional[int]:
    """Index of directed edge (u, w) in the CSR-sorted edge list, or None."""
    lo, hi = g.row_ptr[u], g.row_ptr[u + 1]
    j = np.searchsorted(g.dst[lo:hi], w)
    if j < hi - lo and g.dst[lo + j] == w:
        return int(lo + j)
    return None


def _family_of(
    g: LabelledGraph,
    v: int,
    part: np.ndarray,
    moved: np.ndarray,
    rel_mass_out: np.ndarray,
    cfg: SwapConfig,
) -> List[int]:
    """Flood-fill family: local vertices likely (> threshold) to traverse
    *to* a current member (paper §5.5)."""
    home = part[v]
    fam = [v]
    in_fam = {v}
    frontier = [v]
    while frontier and len(fam) < cfg.family_max_size:
        nxt: List[int] = []
        for w in frontier:
            eidx, nbrs = _edge_indices_from(g, w)
            if nbrs.size > cfg.max_scan_neighbors:
                keep = np.argsort(-rel_mass_out[eidx])[: cfg.max_scan_neighbors]
                eidx, nbrs = eidx[keep], nbrs[keep]
            for u in nbrs:
                u = int(u)
                if u in in_fam or part[u] != home or moved[u]:
                    continue
                rev = _edge_index(g, u, w)
                if rev is None:
                    continue
                if rel_mass_out[rev] > cfg.family_threshold:
                    fam.append(u)
                    in_fam.add(u)
                    nxt.append(u)
                    if len(fam) >= cfg.family_max_size:
                        break
            if len(fam) >= cfg.family_max_size:
                break
        frontier = nxt
    return fam


def _family_gain(
    g: LabelledGraph,
    fam: List[int],
    dest: int,
    part: np.ndarray,
    edge_mass: np.ndarray,
) -> Tuple[float, float]:
    """(receiver_gain, sender_loss) in traversal-probability mass."""
    in_fam = set(fam)
    home = part[fam[0]]
    gain = loss = 0.0
    for w in fam:
        eidx, nbrs = _edge_indices_from(g, w)
        for e, u in zip(eidx, nbrs):
            u = int(u)
            if u in in_fam:
                continue
            m_out = float(edge_mass[e])
            rev = _edge_index(g, u, w)
            m_in = float(edge_mass[rev]) if rev is not None else 0.0
            if part[u] == dest:
                gain += m_out + m_in
            elif part[u] == home:
                loss += m_out + m_in
    return gain, loss


def swap_iteration_reference(
    g: LabelledGraph,
    part: np.ndarray,
    field: ExtroversionResult,
    k: int,
    cfg: SwapConfig,
    rng: np.random.Generator,
) -> Tuple[np.ndarray, SwapStats]:
    """One internal TAPER iteration of offer/receive vertex swapping (seed)."""
    part = part.astype(np.int32).copy()
    n = g.n
    sizes = np.bincount(part, minlength=k).astype(np.int64)
    ideal = n / k
    max_size = int(np.floor((1.0 + cfg.balance_eps) * ideal))
    min_size = int(np.ceil((1.0 - cfg.balance_eps) * ideal))

    pr_src = np.maximum(field.pr[g.src], 1e-30)
    rel_mass_out = field.edge_mass / pr_src

    ext = field.extroversion if cfg.rank_by == "extroversion" else field.extro_mass
    candidates: List[int] = []
    for p in range(k):
        members = np.nonzero(part == p)[0]
        if members.size == 0:
            continue
        unsafe = field.extroversion[members] > (1.0 - cfg.safe_introversion)
        members = members[unsafe]
        if members.size == 0:
            continue
        top = members[np.argsort(-ext[members])]
        if cfg.candidates_per_part is not None:
            top = top[: cfg.candidates_per_part]
        candidates.extend(int(v) for v in top)
    candidates.sort(key=lambda v: -ext[v])

    moved = np.zeros(n, dtype=bool)
    stats = SwapStats(0, 0, 0, len(candidates))

    for v in candidates:
        if moved[v]:
            continue
        home = part[v]
        if field.ext_to is not None:
            prefs = field.ext_to[v].copy()
        else:
            prefs = np.zeros(k)
            eidx, nbrs = _edge_indices_from(g, v)
            is_cut = part[nbrs] != home
            np.add.at(prefs, part[nbrs[is_cut]], field.edge_mass[eidx[is_cut]])
        prefs[home] = -np.inf
        order = np.argsort(-prefs)
        fam = _family_of(g, v, part, moved, rel_mass_out, cfg)
        fs = len(fam)
        for dest in order:
            dest = int(dest)
            if prefs[dest] <= 0.0:
                break
            if sizes[dest] + fs > max_size or sizes[home] - fs < min_size:
                stats.rejected_offers += 1
                continue
            gain, loss = _family_gain(g, fam, dest, part, field.edge_mass)
            if gain > loss + cfg.min_gain:
                part[list(fam)] = dest
                moved[list(fam)] = True
                sizes[home] -= fs
                sizes[dest] += fs
                stats.moves += fs
                stats.accepted_offers += 1
                break
            stats.rejected_offers += 1
    return part, stats
