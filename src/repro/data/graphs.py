"""Graph data pipeline: synthetic graph builders for every GNN shape cell
and a real fanout neighbor sampler (GraphSAGE-style) for ``minibatch_lg``.

The sampler is part of the system (assignment: "``minibatch_lg`` needs a real
neighbor sampler"): it samples ``fanout`` neighbors per hop from a CSR
adjacency (with replacement when the degree exceeds the fanout, GraphSAGE
semantics), compacts the union of sampled vertices, and emits fixed-shape
padded arrays suitable for jit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import GNNConfig, ShapeSpec
from repro.models.gnn import api as gnn_api


# ---------------------------------------------------------------------------
# synthetic graphs per shape cell
# ---------------------------------------------------------------------------


def random_graph_batch(
    cfg: GNNConfig, shape: ShapeSpec, seed: int = 0, scale: float = 1.0
) -> Dict[str, np.ndarray]:
    """Concrete (host) arrays for one training batch of the given cell.

    ``scale`` < 1 shrinks node/edge counts for CPU smoke tests while keeping
    every structural property (padding, masks, graph ids).
    """
    rng = np.random.default_rng(seed)
    d_feat = gnn_api.feature_dim(cfg, shape)

    if shape.name == "molecule":
        G = shape.dim("batch")
        npg, epg = shape.dim("n_nodes"), shape.dim("n_edges")
        if scale < 1.0:
            G = max(2, int(G * scale))
        N, E = G * npg, G * epg
        node_feat = np.zeros((N, d_feat), np.float32)
        species = rng.integers(0, d_feat, N)
        node_feat[np.arange(N), species] = 1.0
        # random bonds within each molecule
        src = rng.integers(0, npg, E) + np.repeat(np.arange(G), epg) * npg
        dst = rng.integers(0, npg, E) + np.repeat(np.arange(G), epg) * npg
        batch = {
            "node_feat": node_feat,
            "edge_src": src.astype(np.int32),
            "edge_dst": dst.astype(np.int32),
            "node_mask": np.ones(N, bool),
            "edge_mask": (src != dst),
            "graph_id": np.repeat(np.arange(G), npg).astype(np.int32),
            "positions": rng.normal(size=(N, 3)).astype(np.float32),
        }
        tshape, tdtype = gnn_api.target_spec(cfg, shape, N)
        graph_level = tshape == (gnn_api.n_graphs_of(shape),)
        # graph-level target count must follow the (possibly scaled) G
        batch["targets"] = _targets(rng, (G,) if graph_level else (N,), tdtype, cfg)
        return batch

    if shape.name == "minibatch_lg":
        # the sampler produces this cell; here we build a scaled base graph
        base_n = max(2000, int(shape.dim("n_nodes") * scale))
        avg_deg = 16
        g = build_csr(base_n, base_n * avg_deg, seed)
        sampler = NeighborSampler(g, (shape.dim("fanout1"), shape.dim("fanout2")))
        seeds = rng.integers(0, base_n, max(32, int(shape.dim("batch_nodes") * scale)))
        sub = sampler.sample(seeds, rng)
        return subgraph_to_batch(sub, cfg, shape, d_feat, rng)

    # full-graph cells
    N = shape.dim("n_nodes")
    E = shape.dim("n_edges")
    if scale < 1.0:
        N, E = max(64, int(N * scale)), max(256, int(E * scale))
    src = rng.integers(0, N, E).astype(np.int32)
    dst = rng.integers(0, N, E).astype(np.int32)
    if cfg.kind in ("nequip", "equiformer_v2"):
        node_feat = np.zeros((N, d_feat), np.float32)
        node_feat[np.arange(N), rng.integers(0, d_feat, N)] = 1.0
    else:
        node_feat = rng.normal(size=(N, d_feat)).astype(np.float32) * 0.1
    batch = {
        "node_feat": node_feat,
        "edge_src": src,
        "edge_dst": dst,
        "node_mask": np.ones(N, bool),
        "edge_mask": src != dst,
    }
    if gnn_api.needs_positions(cfg):
        batch["positions"] = rng.normal(size=(N, 3)).astype(np.float32)
    tshape, tdtype = gnn_api.target_spec(cfg, shape, N)
    batch["targets"] = _targets(rng, (N,), tdtype, cfg)
    return batch


def _targets(rng, shape, dtype, cfg: GNNConfig):
    if dtype == np.int32 or str(dtype).endswith("int32"):
        return rng.integers(0, cfg.n_classes, shape).astype(np.int32)
    return rng.normal(size=shape).astype(np.float32)


# ---------------------------------------------------------------------------
# CSR + neighbor sampler
# ---------------------------------------------------------------------------


@dataclass
class CSRGraph:
    n: int
    row_ptr: np.ndarray
    col: np.ndarray


def build_csr(n: int, m: int, seed: int = 0, skew: float = 1.0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    u = rng.random(m)
    src = np.minimum((n * u ** (1 + skew)).astype(np.int64), n - 1)
    dst = rng.integers(0, n, m)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    counts = np.bincount(src, minlength=n)
    row_ptr = np.concatenate([[0], np.cumsum(counts)])
    return CSRGraph(n, row_ptr.astype(np.int64), dst.astype(np.int32))


@dataclass
class SampledSubgraph:
    """Fixed-shape 2-hop sampled subgraph (padded)."""

    nodes: np.ndarray        # (N_sub,) original vertex ids (padded -1)
    edge_src: np.ndarray     # (E_sub,) local indices
    edge_dst: np.ndarray
    node_mask: np.ndarray
    edge_mask: np.ndarray
    n_seeds: int


class NeighborSampler:
    """GraphSAGE fanout sampler over CSR adjacency (with replacement)."""

    def __init__(self, g: CSRGraph, fanouts: Sequence[int]):
        self.g = g
        self.fanouts = tuple(fanouts)

    def max_nodes(self, n_seeds: int) -> int:
        total, cur = n_seeds, n_seeds
        for f in self.fanouts:
            cur = cur * f
            total += cur
        return total

    def sample(self, seeds: np.ndarray, rng: np.random.Generator) -> SampledSubgraph:
        g = self.g
        seeds = np.asarray(seeds, dtype=np.int64)
        frontier = seeds
        all_src, all_dst = [], []     # edges in ORIGINAL vertex ids (src=nbr, dst=center)
        layers = [seeds]
        for f in self.fanouts:
            deg = g.row_ptr[frontier + 1] - g.row_ptr[frontier]
            # with-replacement sampling: offsets uniform in [0, deg)
            offs = (rng.random((frontier.size, f)) * np.maximum(deg, 1)[:, None]).astype(np.int64)
            nbrs = g.col[np.minimum(g.row_ptr[frontier][:, None] + offs,
                                    len(g.col) - 1)]
            valid = (deg > 0)[:, None] & np.ones((1, f), bool)
            src = nbrs.reshape(-1)
            dst = np.repeat(frontier, f)
            mask = valid.reshape(-1)
            all_src.append(np.where(mask, src, -1))
            all_dst.append(np.where(mask, dst, -1))
            frontier = np.where(mask, src, 0).astype(np.int64)
            layers.append(frontier)
        src = np.concatenate(all_src)
        dst = np.concatenate(all_dst)

        # compact: union of vertices -> local ids (padded to max_nodes)
        uniq = np.unique(np.concatenate([l.reshape(-1) for l in layers]))
        uniq = uniq[uniq >= 0]
        cap = self.max_nodes(len(seeds))
        nodes = np.full(cap, -1, np.int64)
        nodes[: len(uniq)] = uniq
        remap = {int(v): i for i, v in enumerate(uniq)}
        emask = (src >= 0) & (dst >= 0)
        lsrc = np.array([remap.get(int(v), 0) for v in src], np.int32)
        ldst = np.array([remap.get(int(v), 0) for v in dst], np.int32)
        return SampledSubgraph(
            nodes=nodes,
            edge_src=np.where(emask, lsrc, 0).astype(np.int32),
            edge_dst=np.where(emask, ldst, 0).astype(np.int32),
            node_mask=nodes >= 0,
            edge_mask=emask,
            n_seeds=len(seeds),
        )


def subgraph_to_batch(sub: SampledSubgraph, cfg: GNNConfig, shape: ShapeSpec,
                      d_feat: int, rng) -> Dict[str, np.ndarray]:
    N = len(sub.nodes)
    if cfg.kind in ("nequip", "equiformer_v2"):
        node_feat = np.zeros((N, d_feat), np.float32)
        node_feat[np.arange(N), rng.integers(0, d_feat, N)] = 1.0
    else:
        node_feat = rng.normal(size=(N, d_feat)).astype(np.float32) * 0.1
    batch = {
        "node_feat": node_feat,
        "edge_src": sub.edge_src,
        "edge_dst": sub.edge_dst,
        "node_mask": sub.node_mask,
        "edge_mask": sub.edge_mask,
    }
    if gnn_api.needs_positions(cfg):
        batch["positions"] = rng.normal(size=(N, 3)).astype(np.float32)
    tshape, tdtype = gnn_api.target_spec(cfg, shape, N)
    batch["targets"] = _targets(rng, (N,), tdtype, cfg)
    return batch
