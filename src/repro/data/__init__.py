"""Deterministic synthetic data pipelines (tokens, graphs, click logs)."""
