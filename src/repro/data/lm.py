"""Synthetic LM token pipeline: seeded, zipf-distributed tokens with a
learnable bigram structure (so loss decreases measurably during the
end-to-end example runs)."""
from __future__ import annotations

from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0):
        self.vocab = vocab
        self.batch = batch
        self.seq_len = seq_len
        self._rng = np.random.default_rng(seed)
        # hidden bigram table: next-token bias (gives the model signal)
        self._shift = self._rng.integers(1, vocab, size=64)

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        rng = self._rng
        # zipf-ish marginal
        u = rng.random((self.batch, self.seq_len + 1))
        toks = np.floor(self.vocab * u ** 2.2).astype(np.int64) % self.vocab
        # deterministic bigram continuation half the time
        follow = rng.random((self.batch, self.seq_len)) < 0.5
        nxt = (toks[:, :-1] + self._shift[toks[:, :-1] % 64]) % self.vocab
        toks[:, 1:] = np.where(follow, nxt, toks[:, 1:])
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
