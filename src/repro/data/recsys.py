"""Synthetic click-log pipeline for DLRM.

Sparse ids are drawn zipf-per-field with *correlated co-access groups*
(user-segment latent variable) so that workload-aware row placement has
something to exploit — mirroring real CTR logs where feature values
co-occur by audience segment."""
from __future__ import annotations

from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import DLRMConfig
from repro.models.dlrm import table_offsets


class ClickLogPipeline:
    def __init__(self, cfg: DLRMConfig, batch: int, seed: int = 0,
                 n_segments: int = 64, p_segment: float = 0.8):
        self.cfg = cfg
        self.batch = batch
        self._rng = np.random.default_rng(seed)
        self.offsets = table_offsets(cfg)
        self.n_segments = n_segments
        self.p_segment = p_segment

    def _field_ids(self, field: int, segment: np.ndarray) -> np.ndarray:
        """Zipf within segment-specific slices of the vocab."""
        rng = self._rng
        V = self.cfg.vocab_sizes[field]
        B = segment.shape[0]
        u = rng.random(B)
        local = np.minimum((V * u ** 3.0).astype(np.int64), V - 1)
        # map into the segment's stripe with prob p_segment
        use_seg = rng.random(B) < self.p_segment
        stripe = V // self.n_segments
        if stripe > 0:
            seg_local = segment * stripe + (local % stripe)
            local = np.where(use_seg, seg_local, local)
        return local

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg, rng = self.cfg, self._rng
        B = self.batch
        segment = rng.integers(0, self.n_segments, B)
        dense = rng.normal(size=(B, cfg.n_dense)).astype(np.float32)
        cols = []
        for f in range(cfg.n_sparse):
            ids = self._field_ids(f, segment) + self.offsets[f]
            cols.append(ids)
        sparse = np.stack(cols, axis=1).astype(np.int64)
        if cfg.multi_hot > 1:
            sparse = np.repeat(sparse[:, :, None], cfg.multi_hot, axis=2)
        # clicks correlated with dense[0] + segment parity
        logit = dense[:, 0] + (segment % 2) - 0.5
        labels = (rng.random(B) < 1 / (1 + np.exp(-logit))).astype(np.float32)
        return {
            "dense": dense,
            "sparse": sparse.astype(np.int32),
            "labels": labels,
        }
