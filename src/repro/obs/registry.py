"""Unified metrics registry: counters, gauges, histograms, exporters.

One :class:`Registry` replaces the repo's scattered metric surfaces —
``serve/metrics.ServeMetrics``'s flat dict, ``utils/timing.Timer``'s
bespoke totals, and the ``pre["_halo_stats"]`` / ``pre["_shard_uploads"]``
side channels — behind three typed instruments plus a pull-based
**collector protocol**:

* :class:`Counter` — monotonic float (``inc``);
* :class:`Gauge` — last-write-wins float (``set``);
* :class:`Histogram` — fixed cumulative buckets + sum/count
  (``observe``), the shape Prometheus expects; per-SLO-class latency
  histograms are one metric name with a ``cls`` label per class;
* collectors — any component with a ``collect() -> dict`` method (the
  serving loop, executor, replication hub, followers, router,
  coordinator all implement it) registers under a prefix; the registry
  pulls them at export time, so components keep their cheap native
  counters and pay nothing per event.

Exporters: :meth:`Registry.to_prometheus_text` (the Prometheus text
exposition format — :func:`parse_prometheus_text` round-trips it, which
the test suite gates) and :meth:`Registry.export_jsonl` / ``snapshot()``
for dashboards that want one flat dict.

Instruments are lock-free on the hot path (float add / bucket increment
under the GIL); creation is locked and get-or-create, keyed on
``(name, sorted labels)``.
"""
from __future__ import annotations

import bisect
import json
import re
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Registry",
    "flatten_numeric", "parse_prometheus_text",
]

#: default latency buckets (seconds): micro-batch serving spans ~0.1ms-5s
DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
                   0.1, 0.25, 0.5, 1.0, 2.5, 5.0)

LabelsKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, str]) -> LabelsKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _sanitize(name: str) -> str:
    """Fold an arbitrary metric key into a legal Prometheus name."""
    name = _NAME_RE.sub("_", str(name))
    if not name or name[0].isdigit():
        name = "_" + name
    return name


class Counter:
    """Monotonic counter."""

    __slots__ = ("name", "labels", "value")
    kind = "counter"

    def __init__(self, name: str, labels: LabelsKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    """Last-write-wins value."""

    __slots__ = ("name", "labels", "value")
    kind = "gauge"

    def __init__(self, name: str, labels: LabelsKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Histogram:
    """Fixed-bucket histogram (cumulative on export, like Prometheus)."""

    __slots__ = ("name", "labels", "bounds", "counts", "sum", "count")
    kind = "histogram"

    def __init__(self, name: str, labels: LabelsKey,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.bounds = tuple(float(b) for b in buckets)
        #: per-bucket (non-cumulative) counts; index len(bounds) = +Inf
        self.counts = [0] * (len(self.bounds) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float) -> None:
        x = float(x)
        self.counts[bisect.bisect_left(self.bounds, x)] += 1
        self.sum += x
        self.count += 1

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (0 <= q <= 1)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        acc = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            if acc + c >= target and c:
                hi = (self.bounds[i] if i < len(self.bounds)
                      else max(self.bounds[-1], self.sum / self.count))
                frac = (target - acc) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            acc += c
            if i < len(self.bounds):
                lo = self.bounds[i]
        return lo


class Registry:
    """Named, labelled instruments + pull collectors (module doc)."""

    def __init__(self, namespace: str = "repro"):
        self.namespace = str(namespace)
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelsKey], Any] = {}
        #: prefix -> zero-arg callable returning a (possibly nested) dict;
        #: re-registering a prefix replaces the old collector (a promoted
        #: loop takes over its predecessor's slot)
        self._collectors: Dict[str, Callable[[], Dict[str, Any]]] = {}

    # -- instruments ----------------------------------------------------------
    def _get(self, cls, name: str, labels: Dict[str, str], **kw):
        key = (str(name), _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(key[0], key[1], **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r}{dict(labels)!r} already registered as "
                f"{type(m).__name__}")
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  buckets: Tuple[float, ...] = DEFAULT_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets)

    def metrics(self) -> List[Any]:
        with self._lock:
            return sorted(self._metrics.values(),
                          key=lambda m: (m.name, m.labels))

    # -- collectors -----------------------------------------------------------
    def register_collector(self, prefix: str,
                           fn: Callable[[], Dict[str, Any]]) -> None:
        """Register (or replace) the component collector at ``prefix``."""
        with self._lock:
            self._collectors[str(prefix)] = fn

    def unregister_collector(self, prefix: str) -> None:
        with self._lock:
            self._collectors.pop(str(prefix), None)

    def collected(self) -> Dict[str, float]:
        """Pull every collector; nested dicts flatten with ``_`` joins and
        non-numeric values are dropped (they belong in span attributes or
        the flight recorder, not in a numeric metrics plane)."""
        with self._lock:
            items = list(self._collectors.items())
        out: Dict[str, float] = {}
        for prefix, fn in items:
            try:
                d = fn()
            except Exception:  # a dying component must not kill export
                continue
            out.update(flatten_numeric(d, prefix=prefix))
        return out

    # -- export ---------------------------------------------------------------
    def snapshot(self) -> Dict[str, float]:
        """One flat dict: every instrument (histograms as
        ``_count``/``_sum``/``_p50``/``_p99``) plus every collected value."""
        out: Dict[str, float] = {}
        for m in self.metrics():
            suffix = "".join(f"_{k}_{v}" for k, v in m.labels)
            base = _sanitize(m.name + suffix)
            if m.kind == "histogram":
                out[base + "_count"] = m.count
                out[base + "_sum"] = m.sum
                out[base + "_p50"] = m.quantile(0.50)
                out[base + "_p99"] = m.quantile(0.99)
            else:
                out[base] = m.value
        out.update(self.collected())
        return out

    def to_prometheus_text(self, include_collected: bool = True) -> str:
        """The Prometheus text exposition format.  Instruments render with
        ``# TYPE`` headers; collected values render as untyped gauges."""
        lines: List[str] = []
        typed: Dict[str, str] = {}
        for m in self.metrics():
            name = _sanitize(m.name)
            if typed.get(name) is None:
                lines.append(f"# TYPE {name} {m.kind}")
                typed[name] = m.kind
            lab = _fmt_labels(m.labels)
            if m.kind == "histogram":
                cum = m.cumulative()
                for b, c in zip(m.bounds, cum):
                    lines.append(
                        f"{name}_bucket{_fmt_labels(m.labels, le=b)} {c}")
                lines.append(
                    f'{name}_bucket{_fmt_labels(m.labels, le="+Inf")} '
                    f"{cum[-1]}")
                lines.append(f"{name}_sum{lab} {_fmt_value(m.sum)}")
                lines.append(f"{name}_count{lab} {m.count}")
            else:
                lines.append(f"{name}{lab} {_fmt_value(m.value)}")
        if include_collected:
            for k, v in sorted(self.collected().items()):
                name = _sanitize(k)
                if typed.get(name) is None:
                    lines.append(f"# TYPE {name} gauge")
                    typed[name] = "gauge"
                lines.append(f"{name} {_fmt_value(v)}")
        return "\n".join(lines) + "\n"

    def export_jsonl(self, path) -> int:
        """Write ``snapshot()`` one ``{"metric":..., "value":...}`` JSON
        object per line; returns the number of lines."""
        from repro.utils.logging import json_default

        snap = self.snapshot()
        with open(path, "w") as fh:
            for k in sorted(snap):
                fh.write(json.dumps({"metric": k, "value": snap[k]},
                                    default=json_default) + "\n")
        return len(snap)


# ---------------------------------------------------------------------------
# helpers + the parse side of the Prometheus round trip
# ---------------------------------------------------------------------------


def flatten_numeric(d: Dict[str, Any], prefix: str = "") -> Dict[str, float]:
    """Flatten a (possibly nested) stats dict to ``prefix_key`` -> float,
    keeping only int/float/bool values (bools export as 0/1)."""
    out: Dict[str, float] = {}
    for k, v in d.items():
        key = f"{prefix}_{k}" if prefix else str(k)
        if isinstance(v, dict):
            out.update(flatten_numeric(v, prefix=key))
        elif isinstance(v, bool):
            out[_sanitize(key)] = float(v)
        elif isinstance(v, (int, float)):
            out[_sanitize(key)] = v
    return out


def _fmt_value(v: float) -> str:
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def _fmt_labels(labels: LabelsKey, le: Optional[Any] = None) -> str:
    parts = [f'{k}="{v}"' for k, v in labels]
    if le is not None:
        le_s = le if isinstance(le, str) else _fmt_value(le)
        parts.append(f'le="{le_s}"')
    return "{" + ",".join(parts) + "}" if parts else ""


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'(?P<k>[a-zA-Z_][a-zA-Z0-9_]*)="(?P<v>[^"]*)"')


def parse_prometheus_text(text: str) -> "Registry":
    """Parse Prometheus text exposition back into a fresh :class:`Registry`
    (typed instruments reconstructed from ``# TYPE`` headers; histogram
    buckets de-cumulated).  ``to_prometheus_text`` of the result is
    byte-identical to the input for registry-rendered text — the
    round-trip property the test suite gates."""
    reg = Registry()
    types: Dict[str, str] = {}
    hist: Dict[Tuple[str, LabelsKey], Dict[str, Any]] = {}
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split()
            if len(parts) >= 4 and parts[1] == "TYPE":
                types[parts[2]] = parts[3]
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"unparseable sample line: {line!r}")
        name, labels_s, value = m.group("name", "labels", "value")
        labels = {lm.group("k"): lm.group("v")
                  for lm in _LABEL_RE.finditer(labels_s or "")}
        base, suffix = name, ""
        for sfx in ("_bucket", "_sum", "_count"):
            if name.endswith(sfx) and types.get(name[:-len(sfx)]) \
                    == "histogram":
                base, suffix = name[:-len(sfx)], sfx
                break
        kind = types.get(base, "gauge")
        if kind == "histogram":
            le = labels.pop("le", None)
            key = (base, _labels_key(labels))
            h = hist.setdefault(key, {"buckets": {}, "sum": 0.0, "count": 0})
            if suffix == "_bucket":
                h["buckets"][le] = float(value)
            elif suffix == "_sum":
                h["sum"] = float(value)
            elif suffix == "_count":
                h["count"] = int(float(value))
        elif kind == "counter":
            reg.counter(base, **labels).value = float(value)
        else:
            reg.gauge(base, **labels).set(float(value))
    for (base, lkey), h in hist.items():
        bounds = sorted(float(b) for b in h["buckets"] if b != "+Inf")
        hm = reg.histogram(base, buckets=tuple(bounds), **dict(lkey))
        prev = 0.0
        for i, b in enumerate(bounds):
            cum = h["buckets"][_fmt_value(b)]
            hm.counts[i] = int(cum - prev)
            prev = cum
        inf = h["buckets"].get("+Inf", prev)
        hm.counts[len(bounds)] = int(inf - prev)
        hm.sum = h["sum"]
        hm.count = h["count"]
    return reg
