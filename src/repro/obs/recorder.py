"""Flight recorder: a fixed-size ring of structured events, JSONL dumps.

The serving cluster's "what just happened?" black box.  Components call
:meth:`FlightRecorder.record` with a *kind* (``admission_reject``,
``watchdog_abort``, ``backend_fallback``, ``fence_rejection``,
``heartbeat_lapse``, ``promotion``, ``tail_resync``, ``fault_fired``, …)
plus free-form fields; events land in a bounded ring stamped with a
monotonic sequence number, a monotonic-clock time and a wall-clock time,
so the retained window is always a causally ordered, replayable timeline.

:meth:`trigger` is the auto-dump hook: the fault injector fires it at
every armed fault site, the coordinator on failover, and the serving loop
on degradation transitions (watchdog abort, backend-ladder move).  When a
``dump_dir`` is configured — explicitly or via the ``REPRO_FLIGHT_DIR``
environment variable (CI sets it so the 8-device matrix can upload dumps
as failure artifacts) — each trigger writes the full ring as a JSONL file
``flight-<node>-<n>.jsonl``; without one, the trigger is just another
ring event and tests read :meth:`events` / call :meth:`dump` directly.

Recording is lock-cheap: one ``itertools.count`` tick plus a
``deque.append`` (both atomic under the GIL), and a disabled recorder
(``enabled=False``) returns after a single attribute check.
"""
from __future__ import annotations

import itertools
import json
import os
import time
from collections import deque
from pathlib import Path
from typing import Any, Dict, List, Optional

__all__ = ["FlightRecorder"]

#: environment variable naming a default dump directory (CI artifacts)
FLIGHT_DIR_ENV = "REPRO_FLIGHT_DIR"


class FlightRecorder:
    """Bounded ring of structured events with triggered JSONL dumps."""

    def __init__(self, capacity: int = 2048, dump_dir=None, node: str = "n0",
                 enabled: bool = True):
        self.enabled = bool(enabled)
        self.node = str(node)
        self._events: "deque[Dict[str, Any]]" = deque(maxlen=int(capacity))
        self._seq = itertools.count(1)
        self._dump_seq = itertools.count(1)
        if dump_dir is None:
            dump_dir = os.environ.get(FLIGHT_DIR_ENV) or None
        self.dump_dir: Optional[Path] = (
            None if dump_dir is None else Path(dump_dir))
        #: paths of every dump written (tests assert on these)
        self.dumps: List[Path] = []
        self.recorded = 0
        self.triggers = 0

    # -- recording ------------------------------------------------------------
    def record(self, kind: str, **fields) -> None:
        """Append one event to the ring (cheap; safe from any thread)."""
        if not self.enabled:
            return
        ev = {"seq": next(self._seq), "t": time.monotonic(),
              "wall": time.time(), "node": self.node, "kind": kind}
        ev.update(fields)
        self._events.append(ev)
        self.recorded += 1

    def trigger(self, reason: str, **fields) -> Optional[Path]:
        """Record a ``dump_trigger`` event and — when a dump directory is
        configured — persist the whole ring as JSONL.  Returns the dump
        path (None when no directory is set or the recorder is off)."""
        if not self.enabled:
            return None
        self.triggers += 1
        self.record("dump_trigger", reason=reason, **fields)
        if self.dump_dir is None:
            return None
        try:
            return self.dump()
        except OSError:  # a full/readonly disk must never fault the loop
            return None

    # -- export ---------------------------------------------------------------
    def events(self, kind: Optional[str] = None) -> List[Dict[str, Any]]:
        """Retained events in causal (seq) order, optionally one kind."""
        evs = sorted(list(self._events), key=lambda e: e["seq"])
        if kind is not None:
            evs = [e for e in evs if e["kind"] == kind]
        return evs

    def dump(self, path=None) -> Path:
        """Write the retained ring as JSONL.  Default path:
        ``<dump_dir>/flight-<node>-<n>.jsonl``."""
        from repro.utils.logging import json_default

        if path is None:
            if self.dump_dir is None:
                raise ValueError("no dump path and no dump_dir configured")
            self.dump_dir.mkdir(parents=True, exist_ok=True)
            path = self.dump_dir / (
                f"flight-{self.node}-{next(self._dump_seq):04d}.jsonl")
        path = Path(path)
        with open(path, "w") as fh:
            for ev in self.events():
                fh.write(json.dumps(ev, default=json_default) + "\n")
        self.dumps.append(path)
        return path

    @staticmethod
    def load_jsonl(path) -> List[Dict[str, Any]]:
        """Read a dump back (tests / offline analysis)."""
        with open(path) as fh:
            return [json.loads(line) for line in fh if line.strip()]
