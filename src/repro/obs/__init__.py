"""Observability: tracing, flight recorder, metrics registry.

One :class:`Observability` bundle carries the three pillars the serving
cluster shares:

* :class:`~repro.obs.trace.Tracer` — request/invocation traces
  (sampled, cross-node via ticket/frame trace ids);
* :class:`~repro.obs.recorder.FlightRecorder` — bounded ring of
  structured events with triggered JSONL dumps;
* :class:`~repro.obs.registry.Registry` — named counters / gauges /
  histograms + the per-component ``collect()`` protocol, exported as
  Prometheus text or JSONL.

A loop, coordinator, or test creates one bundle and threads it through
``ServeLoopConfig.obs`` / ``ClusterConfig.obs``; everything downstream
(queue, fault injector, taper, hub, followers, router) borrows the same
tracer/recorder/registry so spans and events from every component land
in one causally ordered place.  :meth:`Observability.disabled` returns a
shared all-off bundle whose members short-circuit after one attribute
check — the default when no one asked for observability, keeping the
hot path free.
"""
from __future__ import annotations

from typing import Optional

from .recorder import FLIGHT_DIR_ENV, FlightRecorder
from .registry import (DEFAULT_BUCKETS, Counter, Gauge, Histogram, Registry,
                       flatten_numeric, parse_prometheus_text)
from .trace import NOOP_SPAN, NOOP_TRACE, Span, TraceContext, Tracer

__all__ = [
    "Observability",
    "Tracer", "Span", "TraceContext", "NOOP_SPAN", "NOOP_TRACE",
    "FlightRecorder", "FLIGHT_DIR_ENV",
    "Registry", "Counter", "Gauge", "Histogram", "DEFAULT_BUCKETS",
    "flatten_numeric", "parse_prometheus_text",
]


class Observability:
    """The tracer + flight recorder + registry bundle (module doc)."""

    def __init__(self, enabled: bool = True, trace_sample_rate: float = 1.0,
                 node: str = "n0", dump_dir=None,
                 trace_capacity: int = 8192, recorder_capacity: int = 2048,
                 registry: Optional[Registry] = None):
        self.enabled = bool(enabled)
        self.node = str(node)
        self.tracer = Tracer(enabled=self.enabled,
                             sample_rate=trace_sample_rate,
                             capacity=trace_capacity, node=self.node)
        self.recorder = FlightRecorder(capacity=recorder_capacity,
                                       dump_dir=dump_dir, node=self.node,
                                       enabled=self.enabled)
        self.registry = registry if registry is not None else Registry()

    _DISABLED: Optional["Observability"] = None

    @classmethod
    def disabled(cls) -> "Observability":
        """The shared all-off bundle (no sampling, no ring writes)."""
        if cls._DISABLED is None:
            cls._DISABLED = cls(enabled=False, trace_sample_rate=0.0)
        return cls._DISABLED
