"""Request/invocation tracing: trace ids, spans, sampling.

A **trace** is the causal story of one unit of work — a served request
(queue admission → micro-batch drain → enum sweeps → reply), a TAPER
invocation (input snapshot → field depth steps → swap iterations → commit
→ shard re-deal), an ingest group (journal append → apply → ship →
follower apply), or a failover (crash → fence → promotion → first
answer).  A trace is identified by a ``trace_id`` string; its **spans**
are named intervals on the monotonic clock, each carrying a
``span_id``/``parent_id`` pair and free-form key/value attributes.  Trace
ids travel across nodes on ``ServeTicket``s and piggybacked inside
replication-frame payloads, so a follower's apply or a router's
first-answer-after-failover *joins* the originating trace
(:meth:`Tracer.join`) instead of starting a disconnected one.

The hot-path contract is *pay nothing when off*:

* ``Tracer(enabled=False)`` (the compile-out-style fast path) makes
  :meth:`new_trace` return the shared :data:`NOOP_TRACE` and
  :meth:`start` the shared :data:`NOOP_SPAN` after a single attribute
  check — no allocation, no lock;
* ``sample_rate`` < 1 makes the *sampling decision once per trace* at
  :meth:`new_trace` (deterministic 1-in-``round(1/rate)`` counting, so
  runs are reproducible); every span of an unsampled trace is the no-op
  singleton.

Finished spans land in a bounded ring (oldest evicted) and export as
dicts (:meth:`Tracer.spans`) or JSONL (:meth:`Tracer.export_jsonl`).
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["NOOP_SPAN", "NOOP_TRACE", "Span", "TraceContext", "Tracer"]


class TraceContext:
    """Immutable (trace id, current parent span id, sampled) triple.

    Carried on tickets and frame payloads; ``sampled=False`` contexts
    (including :data:`NOOP_TRACE`) produce only no-op spans."""

    __slots__ = ("trace_id", "span_id", "sampled")

    def __init__(self, trace_id: str = "", span_id: int = 0,
                 sampled: bool = False):
        self.trace_id = trace_id
        self.span_id = span_id
        self.sampled = sampled

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"TraceContext({self.trace_id!r}, span={self.span_id}, "
                f"sampled={self.sampled})")


NOOP_TRACE = TraceContext()


class Span:
    """One named interval of a sampled trace.  Usable as a context manager
    (``with tracer.start(...) as sp:``) or via explicit :meth:`end`."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "t1",
                 "attrs", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 span_id: int, parent_id: int, attrs: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = time.monotonic()
        self.t1: Optional[float] = None
        self.attrs = attrs

    @property
    def t_wall(self) -> float:
        """Wall-clock start, derived from the tracer's clock anchor (no
        per-span ``time.time()`` syscall on the hot path)."""
        return self._tracer._wall0 + self.t0

    def set(self, **attrs) -> "Span":
        """Attach key/value attributes; returns self for chaining."""
        self.attrs.update(attrs)
        return self

    def context(self) -> TraceContext:
        """A child context: same trace, this span as the parent."""
        return TraceContext(self.trace_id, self.span_id, True)

    def end(self, **attrs) -> None:
        """Close the span (idempotent) and hand it to the tracer's ring."""
        if self.t1 is not None:
            return
        if attrs:
            self.attrs.update(attrs)
        self.t1 = time.monotonic()
        self._tracer._record(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.end()
        return False

    def to_dict(self) -> Dict[str, Any]:
        return {
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "t0": self.t0,
            "t1": self.t1,
            "duration_s": (None if self.t1 is None else self.t1 - self.t0),
            "wall": self.t_wall,
            "attrs": dict(self.attrs),
        }


class _NoopSpan:
    """Shared do-nothing span for disabled tracers / unsampled traces."""

    __slots__ = ()

    def set(self, **attrs) -> "_NoopSpan":
        return self

    def context(self) -> TraceContext:
        return NOOP_TRACE

    def end(self, **attrs) -> None:
        pass

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


NOOP_SPAN = _NoopSpan()


class Tracer:
    """Span factory + bounded ring of finished spans (module doc)."""

    def __init__(self, enabled: bool = True, sample_rate: float = 1.0,
                 capacity: int = 8192, node: str = "n0"):
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self.node = str(node)
        self._spans: "deque[Span]" = deque(maxlen=int(capacity))
        #: wall = monotonic + anchor: one syscall pair here, none per span
        self._wall0 = time.time() - time.monotonic()
        self._trace_seq = itertools.count(1)
        self._span_seq = itertools.count(1)
        self.sampled_traces = 0
        self.unsampled_traces = 0
        #: sampling period resolved once: every Nth trace is sampled
        self._period = (1 if self.sample_rate >= 1.0
                        else (0 if self.sample_rate <= 0.0
                              else max(1, round(1.0 / self.sample_rate))))

    # -- trace lifecycle ------------------------------------------------------
    def new_trace(self, force: bool = False) -> TraceContext:
        """Open a new trace; the sampling decision is made here, once.
        ``force=True`` bypasses sampling (rare, load-bearing traces:
        invocations, failovers) but still honours ``enabled=False``."""
        if not self.enabled:
            return NOOP_TRACE
        n = next(self._trace_seq)
        if not force:
            if self._period == 0 or (n - 1) % self._period:
                self.unsampled_traces += 1
                return NOOP_TRACE
        self.sampled_traces += 1
        return TraceContext(f"t-{self.node}-{n:08d}", 0, True)

    def join(self, trace_id: Optional[str]) -> TraceContext:
        """Adopt a trace id that arrived from another node (ticket, frame
        payload).  The originating tracer already made the sampling
        decision — an id is only ever shipped for sampled traces."""
        if not self.enabled or not trace_id:
            return NOOP_TRACE
        return TraceContext(str(trace_id), 0, True)

    # -- spans ----------------------------------------------------------------
    def start(self, name: str, ctx: TraceContext, **attrs):
        """Open a span under ``ctx`` (its ``span_id`` is the parent)."""
        if not self.enabled or not ctx.sampled:
            return NOOP_SPAN
        return Span(self, name, ctx.trace_id, next(self._span_seq),
                    ctx.span_id, attrs)

    def event(self, name: str, ctx: TraceContext, **attrs) -> None:
        """Record an instant (zero-duration) span — a point-in-time marker
        such as a per-depth halo accounting step or a fence advancing."""
        if not self.enabled or not ctx.sampled:
            return
        sp = Span(self, name, ctx.trace_id, next(self._span_seq),
                  ctx.span_id, attrs)
        sp.t1 = sp.t0
        self._record(sp)

    def _record(self, span: Span) -> None:
        # deque.append is atomic under the GIL; eviction at maxlen is the
        # ring semantics we want
        self._spans.append(span)

    # -- export ---------------------------------------------------------------
    def spans(self, trace_id: Optional[str] = None,
              name: Optional[str] = None) -> List[Dict[str, Any]]:
        """Finished spans (oldest-evicted ring), sorted by start time;
        optionally filtered by trace id and/or span name."""
        out = [s for s in list(self._spans)
               if (trace_id is None or s.trace_id == trace_id)
               and (name is None or s.name == name)]
        out.sort(key=lambda s: (s.t0, s.span_id))
        return [s.to_dict() for s in out]

    def trace_ids(self) -> List[str]:
        seen: Dict[str, None] = {}
        for s in list(self._spans):
            seen.setdefault(s.trace_id)
        return list(seen)

    def export_jsonl(self, path) -> int:
        """Write every retained span as one JSON object per line; returns
        the number of spans written."""
        from repro.utils.logging import json_default

        rows = self.spans()
        with open(path, "w") as fh:
            for r in rows:
                fh.write(json.dumps(r, default=json_default) + "\n")
        return len(rows)
