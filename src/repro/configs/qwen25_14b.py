"""qwen2.5-14b — dense LM with GQA and QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="qwen2.5-14b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=13824,
    vocab=152064,
    attn_bias=True,
    rope_theta=1_000_000.0,
)
