"""taper_paper — the paper's own technique as a distributed workload:
one extroversion-field refine step over a MusicBrainz-scale graph
(10M vertices, 12 labels) partitioned over the mesh.
"""
from repro.configs.base import TaperSystemConfig

CONFIG = TaperSystemConfig(
    name="taper_paper",
    n_vertices=10_000_000,
    avg_degree=6.0,
    n_labels=12,
    n_trie_nodes=24,
    trie_depth=4,
    k_partitions=512,
)
