"""gemma3-4b — dense LM, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.  Local layers use a
1024-token sliding window; every 6th layer is global.  The hybrid
local:global stack gives it the sub-quadratic path required to run the
``long_500k`` cell (DESIGN.md §Shape-cell skips).
"""
from repro.configs.base import LMConfig

CONFIG = LMConfig(
    name="gemma3-4b",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_head=256,
    d_ff=10240,
    vocab=262144,
    sliding_window=1024,
    global_every=6,           # 5 local : 1 global
    qk_norm=True,
    rope_theta=1_000_000.0,
    supports_long_context=True,
)
