"""gcn-cora — 2-layer GCN [arXiv:1609.02907; paper].

n_layers=2 d_hidden=16 aggregator=mean norm=sym (Cora: 2708 nodes, 7 classes).
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gcn-cora",
    kind="gcn",
    n_layers=2,
    d_hidden=16,
    aggregator="mean",
    norm="sym",
    n_classes=7,
)
