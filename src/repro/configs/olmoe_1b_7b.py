"""olmoe-1b-7b — 64-expert top-8 MoE LM [arXiv:2409.02060; hf].

16L d_model=2048 16H (GQA kv=16) expert d_ff=1024 vocab=50304, MoE 64e top-8.
"""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=1024,
    vocab=50304,
    moe=MoEConfig(n_experts=64, top_k=8, d_expert_ff=1024),
    qk_norm=True,  # OLMoE uses QK-norm
    rope_theta=10000.0,
)
