"""dlrm-rm2 — deep learning recommendation model [arXiv:1906.00091; paper].

n_dense=13 n_sparse=26 embed_dim=64 bot 13-512-256-64 top 512-512-256-1
dot interaction.  Table sizes follow the Criteo-Kaggle cardinalities
(~40M rows total).
"""
from repro.configs.base import DLRMConfig

# Criteo Kaggle per-field cardinalities (C1..C26)
CRITEO_VOCABS = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572,
)

CONFIG = DLRMConfig(
    name="dlrm-rm2",
    n_dense=13,
    n_sparse=26,
    embed_dim=64,
    bot_mlp=(512, 256, 64),
    top_mlp=(512, 512, 256, 1),
    interaction="dot",
    vocab_sizes=CRITEO_VOCABS,
)
