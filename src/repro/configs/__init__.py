from repro.configs.base import (
    ArchConfig,
    DLRMConfig,
    GNNConfig,
    LMConfig,
    MoEConfig,
    ShapeSpec,
    TaperSystemConfig,
)
from repro.configs.registry import get_config, list_archs, shapes_for

__all__ = [
    "ArchConfig",
    "DLRMConfig",
    "GNNConfig",
    "LMConfig",
    "MoEConfig",
    "ShapeSpec",
    "TaperSystemConfig",
    "get_config",
    "list_archs",
    "shapes_for",
]
