"""nequip — O(3)-equivariant interatomic potential [arXiv:2101.03164; paper].

n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5, E(3) tensor-product messages.
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="nequip",
    kind="nequip",
    n_layers=5,
    d_hidden=32,
    l_max=2,
    n_rbf=8,
    cutoff=5.0,
    n_classes=1,   # energy regression
)
