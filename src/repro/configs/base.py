"""Architecture / shape configuration dataclasses.

Every assigned architecture is a frozen config under ``repro/configs/<id>.py``
with the exact dimensions from the assignment, plus a ``reduced()`` variant
used by CPU smoke tests.  Shape cells (``train_4k``, ``prefill_32k``, ...)
are ``ShapeSpec`` entries resolved by the launch layer into
ShapeDtypeStructs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell for an architecture."""

    name: str
    kind: str                 # "train" | "prefill" | "decode" | "serve" | ...
    dims: Tuple[Tuple[str, int], ...] = ()

    def dim(self, key: str) -> int:
        for k, v in self.dims:
            if k == key:
                return v
        raise KeyError(key)

    def get(self, key: str, default=None):
        for k, v in self.dims:
            if k == key:
                return v
        return default


def _dims(**kwargs) -> Tuple[Tuple[str, int], ...]:
    return tuple(kwargs.items())


LM_SHAPES = (
    ShapeSpec("train_4k", "train", _dims(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", _dims(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", _dims(seq_len=32768, global_batch=128)),
    ShapeSpec("long_500k", "decode", _dims(seq_len=524288, global_batch=1)),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "train",
              _dims(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeSpec("minibatch_lg", "train",
              _dims(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                    fanout1=15, fanout2=10, d_feat=602)),
    ShapeSpec("ogb_products", "train",
              _dims(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeSpec("molecule", "train",
              _dims(n_nodes=30, n_edges=64, batch=128)),
)

DLRM_SHAPES = (
    ShapeSpec("train_batch", "train", _dims(batch=65536)),
    ShapeSpec("serve_p99", "serve", _dims(batch=512)),
    ShapeSpec("serve_bulk", "serve", _dims(batch=262144)),
    ShapeSpec("retrieval_cand", "retrieval", _dims(batch=1, n_candidates=1000000)),
)


# ---------------------------------------------------------------------------
# Architectures
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert_ff: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    aux_coef: float = 1e-2


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    moe: Optional[MoEConfig] = None
    qk_norm: bool = False
    attn_bias: bool = False                 # qwen2.5-style QKV bias
    sliding_window: Optional[int] = None    # local-attention window
    global_every: int = 0                   # gemma3: every Nth layer is global
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    family: str = "lm"
    # which shape cells apply; long_500k only for archs with a sub-quadratic
    # local-attention path (DESIGN.md §Shape-cell skips)
    supports_long_context: bool = False
    attention_chunk: int = 1024             # blocked-softmax KV chunk

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        """Total parameter count (embeddings included)."""
        d, h, kv, dh, ff, V, L = (self.d_model, self.n_heads, self.n_kv_heads,
                                  self.d_head, self.d_ff, self.vocab, self.n_layers)
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.attn_bias:
            attn += (h + 2 * kv) * dh
        if self.moe:
            ffp = self.moe.n_experts * 3 * d * self.moe.d_expert_ff
            ffp += self.moe.n_shared * 3 * d * self.moe.d_expert_ff
            ffp += d * self.moe.n_experts  # router
        else:
            ffp = 3 * d * ff
        norms = 2 * d * L + d
        emb = V * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffp) + norms + emb

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        dense = self.n_params() - L * (
            self.moe.n_experts * 3 * d * self.moe.d_expert_ff
        )
        active_ff = L * (self.moe.top_k * 3 * d * self.moe.d_expert_ff)
        return dense + active_ff

    def reduced(self) -> "LMConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dataclasses.asdict(self)
        moe = None
        if self.moe:
            moe = MoEConfig(
                n_experts=min(self.moe.n_experts, 8),
                top_k=min(self.moe.top_k, 2),
                d_expert_ff=32,
                n_shared=min(self.moe.n_shared, 1),
            )
        kw.update(
            n_layers=2, d_model=64,
            n_heads=4, n_kv_heads=max(1, 4 // max(self.q_per_kv, 1)),
            d_head=16, d_ff=128, vocab=256,
            sliding_window=16 if self.sliding_window else None,
            dtype="float32",
            attention_chunk=32,
        )
        kw["moe"] = moe
        return LMConfig(**kw)

    shapes = property(lambda self: LM_SHAPES)


@dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str                  # "gcn" | "gin" | "nequip" | "equiformer_v2"
    n_layers: int
    d_hidden: int
    # gcn/gin
    aggregator: str = "mean"
    norm: str = "sym"
    eps_learnable: bool = False
    # equivariant
    l_max: int = 0
    m_max: int = 0
    n_heads: int = 0
    n_rbf: int = 0
    cutoff: float = 5.0
    n_classes: int = 16
    dtype: str = "float32"
    family: str = "gnn"

    def reduced(self) -> "GNNConfig":
        kw = dataclasses.asdict(self)
        kw.update(
            n_layers=2, d_hidden=16,
            l_max=min(self.l_max, 2), m_max=min(self.m_max, 1) if self.m_max else 0,
            n_heads=min(self.n_heads, 2) if self.n_heads else 0,
            n_rbf=min(self.n_rbf, 4) if self.n_rbf else 0,
        )
        return GNNConfig(**kw)

    shapes = property(lambda self: GNN_SHAPES)


@dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int
    n_sparse: int
    embed_dim: int
    bot_mlp: Tuple[int, ...]
    top_mlp: Tuple[int, ...]
    interaction: str = "dot"
    # per-table vocab sizes (criteo-like skew); len == n_sparse
    vocab_sizes: Tuple[int, ...] = ()
    multi_hot: int = 1          # ids per field (embedding-bag when > 1)
    dtype: str = "float32"
    family: str = "recsys"

    def total_rows(self) -> int:
        return sum(self.vocab_sizes)

    def n_params(self) -> int:
        p = self.total_rows() * self.embed_dim
        dims = (self.n_dense,) + self.bot_mlp
        p += sum(a * b + b for a, b in zip(dims, dims[1:]))
        n_feat = self.n_sparse + 1
        inter = n_feat * (n_feat - 1) // 2 if self.interaction == "dot" else 0
        dims = (inter + self.bot_mlp[-1],) + self.top_mlp
        p += sum(a * b + b for a, b in zip(dims, dims[1:]))
        return p

    def reduced(self) -> "DLRMConfig":
        kw = dataclasses.asdict(self)
        kw.update(
            embed_dim=8,
            bot_mlp=(16, 8),
            top_mlp=(16, 8, 1),
            vocab_sizes=tuple(min(v, 100) for v in self.vocab_sizes),
        )
        kw["bot_mlp"] = tuple(kw["bot_mlp"])
        kw["top_mlp"] = tuple(kw["top_mlp"])
        kw["vocab_sizes"] = tuple(kw["vocab_sizes"])
        return DLRMConfig(**kw)

    shapes = property(lambda self: DLRM_SHAPES)


@dataclass(frozen=True)
class TaperSystemConfig:
    """The paper's own technique as a dry-run cell: one extroversion-field
    refine step over a partitioned graph."""

    name: str = "taper_paper"
    n_vertices: int = 10_000_000
    avg_degree: float = 6.0
    n_labels: int = 12
    n_trie_nodes: int = 24
    trie_depth: int = 4
    k_partitions: int = 512
    family: str = "taper"

    def reduced(self) -> "TaperSystemConfig":
        return dataclasses.replace(self, n_vertices=2000, k_partitions=8)

    shapes = property(
        lambda self: (
            ShapeSpec("refine_step", "taper",
                      _dims(n_vertices=self.n_vertices,
                            n_edges=int(self.n_vertices * self.avg_degree))),
        )
    )


ArchConfig = (LMConfig, GNNConfig, DLRMConfig, TaperSystemConfig)
