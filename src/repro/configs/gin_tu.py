"""gin-tu — Graph Isomorphism Network [arXiv:1810.00826; paper].

n_layers=5 d_hidden=64 aggregator=sum eps=learnable (TU graph classification).
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu",
    kind="gin",
    n_layers=5,
    d_hidden=64,
    aggregator="sum",
    eps_learnable=True,
    n_classes=2,
)
