"""kimi-k2-1t-a32b — trillion-param MoE (paper-table) [arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, MoE 384e top-8
plus 1 shared expert.
"""
from repro.configs.base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    vocab=163840,
    moe=MoEConfig(n_experts=384, top_k=8, d_expert_ff=2048, n_shared=1),
    rope_theta=50000.0,
)
