"""equiformer-v2 — equivariant graph attention via eSCN convolutions
[arXiv:2306.12059; unverified].

n_layers=12 d_hidden=128 l_max=6 m_max=2 n_heads=8, SO(2)-eSCN equivariance.
"""
from repro.configs.base import GNNConfig

CONFIG = GNNConfig(
    name="equiformer-v2",
    kind="equiformer_v2",
    n_layers=12,
    d_hidden=128,
    l_max=6,
    m_max=2,
    n_heads=8,
    n_rbf=8,
    cutoff=5.0,
    n_classes=1,   # energy regression
)
