"""Architecture registry: ``--arch <id>`` resolution."""
from __future__ import annotations

from importlib import import_module
from typing import Dict, List

_ARCH_MODULES = {
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "qwen2.5-14b": "repro.configs.qwen25_14b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "gcn-cora": "repro.configs.gcn_cora",
    "equiformer-v2": "repro.configs.equiformer_v2",
    "gin-tu": "repro.configs.gin_tu",
    "nequip": "repro.configs.nequip",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "taper_paper": "repro.configs.taper_paper",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; available: {list_archs()}")
    return import_module(_ARCH_MODULES[arch]).CONFIG


def shapes_for(arch: str):
    cfg = get_config(arch)
    shapes = list(cfg.shapes)
    if cfg.family == "lm" and not cfg.supports_long_context:
        # long_500k needs a sub-quadratic attention path
        # (DESIGN.md §Shape-cell skips)
        shapes = [s for s in shapes if s.name != "long_500k"]
    return shapes
