from repro.distributed.sharding import (
    LogicalAxisRules,
    SINGLE_POD_RULES,
    MULTI_POD_RULES,
    activation_sharding,
    constrain,
    logical_to_sharding,
    tree_shardings,
)

__all__ = [
    "LogicalAxisRules",
    "SINGLE_POD_RULES",
    "MULTI_POD_RULES",
    "activation_sharding",
    "constrain",
    "logical_to_sharding",
    "tree_shardings",
]
