"""Gradient compression: int8 quantisation with error feedback.

Before the (all-reduced) gradients hit the optimizer, each leaf is
quantised to int8 with a per-tensor scale; the quantisation error is kept
as residual state and added back next step (error feedback, Seide et al. /
1-bit SGD lineage), which preserves convergence.  On a real deployment the
int8 tensors are what crosses the DP axis — an 4x wire-byte reduction on
the gradient all-reduce (recorded in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def init_residuals(params) -> Dict:
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, residuals):
    """Returns (compressed-then-decompressed grads, new residuals).

    The int8 representation is materialised (it is what the DP all-reduce
    would carry); the error is fed back into the next step's residual.
    """
    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize(g32)
        deq = _dequantize(q, scale)
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_r = treedef.unflatten([o[1] for o in out])
    return new_g, new_r


def wire_bytes_saved(params) -> int:
    """fp32 -> int8 gradient bytes saved per DP all-reduce."""
    total = sum(x.size for x in jax.tree.leaves(params))
    return total * (4 - 1)
