"""Logical-axis sharding rules (MaxText/t5x style).

Model code annotates every tensor dimension with a *logical* name; the launch
layer resolves names to mesh axes per deployment.  Parameters use the
``fsdp`` name on their largest dim (ZeRO-3: parameters and optimizer state
fully sharded over the data axis) and ``model`` on the tensor-parallel dim.

Defaults:

  single pod  (16, 16)   -> ("data", "model")
  multi-pod   (2, 16, 16) -> ("pod", "data", "model");
    batch over (pod, data); parameters replicated across pods (DCN is slow;
    intra-pod ICI carries the FSDP all-gathers), unless ``fsdp_over_pod``.
"""
from __future__ import annotations

import contextlib
import contextvars
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

# ---------------------------------------------------------------------------
# activation sharding constraints
#
# Model code calls ``constrain(x, "batch", None, ...)`` on intermediates.
# Outside a launch context this is a no-op (CPU tests see plain arrays);
# the launch layer activates it so GSPMD cannot drift into pathological
# layouts (the dry-run §Perf log shows why this matters: without constraints
# XLA materialised full-batch fp32 logits on every device).
# ---------------------------------------------------------------------------

_ACT_CTX: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Optional["LogicalAxisRules"] = None):
    token = _ACT_CTX.set((mesh, rules or rules_for(mesh)))
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def constrain(x, *logical_axes):
    """with_sharding_constraint by logical names; no-op without context."""
    ctx = _ACT_CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.spec(logical_axes, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


@dataclass(frozen=True)
class LogicalAxisRules:
    rules: Tuple[Tuple[str, Axis], ...]

    def lookup(self, name: Optional[str]) -> Axis:
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None,
             mesh: Optional[Mesh] = None) -> P:
        """Resolve logical names to a PartitionSpec.

        When ``shape`` and ``mesh`` are given, mesh axes that do not divide
        the dimension are dropped (trailing-first), falling back to
        replication — the standard divisibility guard."""
        seen = []
        out = []
        for i, name in enumerate(logical_axes):
            ax = self.lookup(name)
            if ax is None:
                out.append(None)
                continue
            flat = (ax,) if isinstance(ax, str) else tuple(ax)
            # a mesh axis may appear only once in a PartitionSpec
            flat = tuple(a for a in flat if a not in seen)
            if shape is not None and mesh is not None:
                dim = shape[i]
                while flat:
                    prod = 1
                    for a in flat:
                        prod *= mesh.shape[a]
                    if dim % prod == 0:
                        break
                    flat = flat[:-1]
            seen.extend(flat)
            if not flat:
                out.append(None)
            elif len(flat) == 1:
                out.append(flat[0])
            else:
                out.append(flat)
        while out and out[-1] is None:
            out.pop()
        return P(*out)


SINGLE_POD_RULES = LogicalAxisRules((
    ("batch", ("data",)),
    ("fsdp", ("data",)),
    ("model", ("model",)),
    ("experts", ("model",)),
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("ffn", ("model",)),
    # KV-cache sequence: takes whatever axes the array hasn't used yet
    # (batched decode -> model only; batch-1 long decode -> data+model)
    ("kv_seq", ("data", "model")),
    ("nodes", ("data",)),       # GNN node dim
    ("edges", ("data",)),
    ("rows", ("model",)),       # embedding-table rows
    ("candidates", ("model",)),
    ("feat_model", ("model",)),
))

MULTI_POD_RULES = LogicalAxisRules((
    ("batch", ("pod", "data")),
    ("fsdp", ("data",)),
    ("model", ("model",)),
    ("experts", ("model",)),
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("ffn", ("model",)),
    ("kv_seq", ("pod", "data", "model")),
    ("nodes", ("pod", "data")),
    ("edges", ("pod", "data")),
    ("rows", ("model",)),
    ("candidates", ("model",)),
    ("feat_model", ("model",)),
))


def rules_for(mesh: Mesh) -> LogicalAxisRules:
    return MULTI_POD_RULES if "pod" in mesh.axis_names else SINGLE_POD_RULES


def logical_to_sharding(
    mesh: Mesh,
    logical_axes: Sequence[Optional[str]],
    rules: Optional[LogicalAxisRules] = None,
    shape: Optional[Sequence[int]] = None,
) -> NamedSharding:
    rules = rules or rules_for(mesh)
    return NamedSharding(mesh, rules.spec(logical_axes, shape, mesh))


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def tree_shardings(mesh: Mesh, logical_tree, shapes_tree=None,
                   rules: Optional[LogicalAxisRules] = None):
    """Map a pytree of logical-axis tuples to NamedShardings.  With
    ``shapes_tree`` (matching pytree of ShapeDtypeStructs), applies the
    divisibility fallback."""
    rules = rules or rules_for(mesh)
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: logical_to_sharding(mesh, axes, rules),
            logical_tree, is_leaf=_is_axes,
        )
    flat_axes, treedef = jax.tree.flatten(logical_tree, is_leaf=_is_axes)
    flat_shapes = jax.tree.leaves(shapes_tree)
    out = [
        logical_to_sharding(mesh, axes, rules, s.shape)
        for axes, s in zip(flat_axes, flat_shapes)
    ]
    return jax.tree.unflatten(treedef, out)
