"""Shard-aware edge packing for the multi-device extroversion field.

Partitions the per-graph ``vm_packing`` destination blocks across a device
mesh's ``model`` axis so the ``vm_step`` Pallas kernel can run one shard per
device over its *local* edge blocks.

**Index spaces.**  The packing separates a vertex's *id* from its *position*
in the shard layout: a pluggable **shard map** (a vertex permutation
``pos_of``/``vtx_at``) decides where each vertex lives.  Shard ``s`` owns the
contiguous *position* range ``[s * n_local_pad, (s+1) * n_local_pad)``; which
vertices occupy those positions is the shard map's choice:

* ``"stripe"`` — identity (contiguous vertex-id ranges; the PR-3 layout);
* ``"partition"`` — positions dealt along the live TAPER partition vector
  (k -> S folding via greedy largest-partition-first when k != n_shards), so
  co-partitioned — i.e. co-traversed — vertices co-locate on a shard;
* ``"bfs"`` — breadth-first visitation order from high-degree seeds, a
  community/locality ordering for graphs with no partition yet.

Kernel output rows are positions (a shard's destination blocks never cross
shards); what crosses shards is the *source* side of an edge: a shard's edge
blocks may read ``beta`` rows of vertices positioned elsewhere (the shard's
**halo**).  A topology-aware shard map makes halos small — TAPER's own
thesis (query-aware placement minimises cross-partition traversals) applied
to the compute layout.

**Halo exchange tables.**  The packing precomputes both exchange backends:

* ``frontier`` — the union of all shards' halo *positions* (append-only;
  first ``n_frontier`` live).  The ``"psum"`` backend moves these
  ``(H_pad, N_trie)`` rows per depth step — one ``psum`` over the ``model``
  axis completes the union because each frontier row has exactly one owner
  (``fr_local_idx`` / ``fr_owned``).
* ``send_local`` / ``src_map_sliced`` — the ``"sliced"`` backend's
  per-shard-pair slice tables: ``send_local[o, j]`` lists the local rows
  shard ``o`` must ship to shard ``j`` (only what ``j`` actually reads).
  The ragged all-to-all is decomposed into ``S - 1`` ring rounds (round
  ``r``: every shard ships its slice to the shard ``r`` hops ahead, one
  ``ppermute``), each padded only to *that round's* largest pair
  (``round_cap[r]``) — so per-depth bytes are ``sum(round_cap)`` rows per
  shard, scaling with what each shard actually *reads* instead of the
  global union, and one heavy pair inflates one round, not every pair.
  Slot assignment (``fr_slot``) is append-only: a frontier row's slot in a
  pair list is fixed when the reader first gathers it, so mutations never
  shuffle previously-uploaded tables.

  The sliced backend is **two-tier**: skewed graphs have hub rows read by
  most shards, and a row read by ``r`` readers costs ``r`` pair slots (and
  inflates the max pairwise halo every pair list is padded to) but only
  one row in a broadcast union.  Build time therefore splits the frontier
  by read-degree — rows read by at least ``t`` shards form the **hot**
  union (``hot_local_idx`` / ``hot_owned``: a small psum'd buffer, one
  copy per depth) and the cold tail flows through the pair slices — with
  ``t`` chosen per packing by exact cost scan over the read-degree
  histogram (``hot_pad + sum(round_cap)`` minimised; the scan includes the
  no-hot-tier extreme, so the hybrid never loses to pure slicing).
  Mutation-appended rows always join the cold tier (their read degree is
  unknown); a scratch rebuild re-tiers.

* ``src_map`` — per-shard source indices remapped into the concatenated
  ``[local rows | exchanged rows]`` index space, so the kernel gathers
  from one contiguous ``beta`` buffer without runtime translation.  For
  psum the exchanged segment is the union frontier (offset ``n_local_pad
  + frontier index``); for sliced it is ``[hot union | round 1 slice |
  ... | round S-1 slice]`` (offset ``n_local_pad + hot_pad +
  round_base[(reader - owner) % S] + fr_slot``, each round padded to its
  own ``round_cap``).
* ``slot_raw`` — packed slot -> raw edge id, so per-slot edge masses scatter
  back into the graph's raw edge order on the host.

Like :meth:`LabelledGraph.vm_packing`, the packing is partition-independent
*given a shard map* and version-keyed.  After
:meth:`LabelledGraph.apply_mutations` the cached packing is **patched per
dirty shard** (:func:`patch_sharded_vm_packing`): only shards whose
destination blocks contain a mutated endpoint are refilled, new halo
positions are *appended* to the frontier and to the pair lists (existing
slots stay valid, so unaffected shards' maps survive untouched; owners whose
send tables grew bump their epoch), brand-new vertices extend the shard map
with an identity tail, and per-shard ``shard_epoch`` counters tell
device-buffer caches exactly which shard slices to re-upload.  Capacity
headroom (``EB_SLACK`` spare edge blocks per shard, ``FR_SLACK`` spare
frontier rows, ``PAIR_SLACK`` spare pair-list slots) absorbs modest growth
without a shape change; overflowing it evicts the entry for a scratch
rebuild.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

#: spare edge blocks per shard so mutations can grow a shard in place
EB_SLACK = 2
#: spare frontier rows so mutations can append halo vertices in place
FR_SLACK = 64
#: spare per-shard-pair slice slots so mutations can append reads in place
PAIR_SLACK = 16


# ---------------------------------------------------------------------------
# shard maps (vertex -> position permutations)
# ---------------------------------------------------------------------------


def _normalize_order(order: Optional[np.ndarray], n: int,
                     validate: bool = True) -> Tuple[np.ndarray, np.ndarray, bool]:
    """``(pos_of, vtx_at, is_identity)`` for a caller-supplied shard map.

    ``order=None`` is the identity (stripe).  A map shorter than ``n`` is
    extended with an identity tail — vertices born after the map was drawn
    keep position == id, exactly how :func:`patch_sharded_vm_packing` grows
    a live packing."""
    if order is None:
        ar = np.arange(n, dtype=np.int64)
        return ar, ar, True
    pos_of = np.asarray(order, dtype=np.int64).reshape(-1)
    if pos_of.shape[0] > n:
        raise ValueError("shard map longer than the vertex range")
    if validate and pos_of.shape[0] and (
            pos_of.min() < 0 or pos_of.max() >= pos_of.shape[0]
            or np.bincount(pos_of, minlength=pos_of.shape[0]).max() != 1):
        raise ValueError("shard map must be a permutation of its range")
    if pos_of.shape[0] < n:
        pos_of = np.concatenate(
            [pos_of, np.arange(pos_of.shape[0], n, dtype=np.int64)])
    vtx_at = np.empty(n, dtype=np.int64)
    vtx_at[pos_of] = np.arange(n, dtype=np.int64)
    identity = bool((pos_of == np.arange(n, dtype=np.int64)).all())
    return pos_of, vtx_at, identity


def partition_shard_order(part: np.ndarray, n_shards: int) -> np.ndarray:
    """Vertex positions dealt along a partition vector (``pos_of``).

    Partitions are folded into ``n_shards`` groups by greedy
    largest-partition-first bin packing (exact when k == n_shards: one
    partition per shard, sizes permitting), then vertices are laid out
    group-major, partition-minor, id-minor — so each shard's contiguous
    position range covers whole partitions wherever the fold allows."""
    part = np.asarray(part, dtype=np.int64).reshape(-1)
    if part.size == 0:
        return np.empty(0, dtype=np.int64)
    k = int(part.max()) + 1
    sizes = np.bincount(np.maximum(part, 0), minlength=k)
    group = np.zeros(k, dtype=np.int64)
    load = np.zeros(max(int(n_shards), 1), dtype=np.int64)
    for p in np.argsort(-sizes):
        g_ = int(np.argmin(load))
        group[p] = g_
        load[g_] += sizes[p]
    key = group[np.maximum(part, 0)] * (k + 1) + np.maximum(part, 0)
    vtx_at = np.argsort(key, kind="stable")
    pos_of = np.empty(part.size, dtype=np.int64)
    pos_of[vtx_at] = np.arange(part.size, dtype=np.int64)
    return pos_of


def shard_assignment(part: np.ndarray, n_shards: int,
                     block_n: int = 128) -> np.ndarray:
    """Per-vertex shard id under the partition-dealt fold.

    Applies :func:`partition_shard_order` and divides positions by the
    block-padded per-shard span (the same span arithmetic the packing and
    ``Taper.maybe_redeal_shards`` use) — the movement-aware k→S fold's
    answer to "which shard hosts vertex v at S shards", which elastic
    restore uses to budget how many vertices change shard when a snapshot
    is brought up at a different S."""
    part = np.asarray(part, dtype=np.int64).reshape(-1)
    if part.size == 0:
        return np.empty(0, dtype=np.int32)
    pos_of = partition_shard_order(part, n_shards)
    nb = max(1, -(-part.size // block_n))
    span = -(-nb // max(int(n_shards), 1)) * block_n
    return (pos_of // span).astype(np.int32)


def majority_owner(owner_of: np.ndarray, vertices: np.ndarray) -> int:
    """Majority vote of ``owner_of`` over ``vertices`` (ties break to the
    lowest owner id; no vertices → owner 0).

    The cluster router's query→replica fold: with ``owner_of =``
    :func:`shard_assignment` ``(part, n_replicas)`` — the same
    partition-dealt span arithmetic ``ShardedVMPacking.owner_of`` uses on
    device — a query routes to the replica owning most of its start
    vertices, so most of its first-hop frontier is owner-local and the
    cross-replica ipt the router accounts stays the partition-quality
    signal the paper's serving metric wants."""
    v = np.asarray(vertices, dtype=np.int64).reshape(-1)
    if v.size == 0:
        return 0
    counts = np.bincount(np.asarray(owner_of, dtype=np.int64)[v])
    return int(np.argmax(counts))


def bfs_shard_order(g) -> np.ndarray:
    """BFS visitation order from high-degree seeds (``pos_of``).

    A cheap community/locality ordering for graphs with no partition yet:
    neighbours are discovered together, so contiguous position ranges land
    on densely-connected vertex groups."""
    n = g.n
    pos_of = np.full(n, -1, dtype=np.int64)
    visited = np.zeros(n, dtype=bool)
    seeds = np.argsort(-g.degrees, kind="stable")
    seed_i = 0
    nxt = 0
    while nxt < n:
        while seed_i < n and visited[seeds[seed_i]]:
            seed_i += 1
        if seed_i >= n:
            break
        frontier = np.asarray([seeds[seed_i]], dtype=np.int64)
        visited[frontier] = True
        while frontier.size:
            pos_of[frontier] = np.arange(nxt, nxt + frontier.size)
            nxt += int(frontier.size)
            nbrs = g.dst[g.edge_indices_of(frontier)].astype(np.int64)
            nbrs = np.unique(nbrs[~visited[nbrs]])
            visited[nbrs] = True
            frontier = nbrs
    rest = np.nonzero(pos_of < 0)[0]
    pos_of[rest] = np.arange(nxt, nxt + rest.size)
    return pos_of


def compute_shard_order(g, source: str, n_shards: int,
                        part: Optional[np.ndarray] = None) -> Optional[np.ndarray]:
    """Resolve a ``shard_map_source`` name into a ``pos_of`` permutation."""
    if source == "stripe":
        return None
    if source == "partition":
        if part is None:
            raise ValueError('shard_map_source="partition" needs a partition')
        return partition_shard_order(part, n_shards)
    if source == "bfs":
        return bfs_shard_order(g)
    raise ValueError(f"unknown shard_map_source {source!r}")


# ---------------------------------------------------------------------------
# the packing
# ---------------------------------------------------------------------------


@dataclass
class ShardedVMPacking:
    """Stacked per-shard ``vm_step`` inputs (leading axis = shard)."""

    n_shards: int
    block_n: int
    block_e: int
    blocks_per_shard: int          # destination blocks per shard (capacity)
    n_local_pad: int               # blocks_per_shard * block_n
    eb_cap: int                    # edge blocks per shard (incl. slack)
    meta: np.ndarray               # (S, eb_cap, 2) [local dst block, is_first]
    src_map: np.ndarray            # (S, e_pad) int32 into [local | frontier]
    src_global: np.ndarray         # (S, e_pad) int32 global source vertex id
    dst_local: np.ndarray          # (S, e_pad) int32 within-block dst position
    dst_global: np.ndarray         # (S, e_pad) int32 global destination id
    dst_label: np.ndarray          # (S, e_pad) int32 label of destination
    inv_cnt: np.ndarray            # (S, e_pad) f32 1/cnt[src, l(dst)], 0 pad
    slot_raw: np.ndarray           # (S, e_pad) int64 raw edge id, -1 pad
    vlabels: np.ndarray            # (S, n_local_pad) int32 owned labels, -1 pad
    frontier: np.ndarray           # (H_pad,) int64 positions; n_frontier live
    n_frontier: int
    fr_local_idx: np.ndarray       # (S, H_pad) int32 owner-local row
    fr_owned: np.ndarray           # (S, H_pad) f32 1.0 iff shard owns entry
    version: int                   # graph version the arrays reflect
    # -- shard map (vertex id <-> position permutation) --------------------
    pos_of: np.ndarray = field(default=None)   # (n,) int64 vertex -> position
    vtx_at: np.ndarray = field(default=None)   # (n,) int64 position -> vertex
    order_token: str = "stripe"    # identity of the shard map (cache key)
    identity: bool = True          # fast path: position == vertex id
    # -- sliced (two-tier: hot union + per-shard-pair) exchange tables -----
    pair_cap: int = 8              # send_local slot width: max(round_cap)
    round_cap: np.ndarray = field(default=None)  # (S,) padded slots per ring
                                                 # round; [0] unused (self)
    fr_reads: np.ndarray = field(default=None)   # (S, H_pad) bool reader map
    fr_slot: np.ndarray = field(default=None)    # (S, H_pad) int32 pair slot
    pair_cnt: np.ndarray = field(default=None)   # (S, S) int32 live slots
    send_local: np.ndarray = field(default=None)  # (S, S, pair_cap) int32
    src_map_sliced: np.ndarray = field(default=None)  # (S, e_pad) int32
    n_hot: int = 0                 # hot-tier rows (read-degree >= threshold)
    fr_hot_pos: np.ndarray = field(default=None)  # (H_pad,) int32, -1 = cold
    hot_local_idx: np.ndarray = field(default=None)  # (S, hot_pad) int32
    hot_owned: np.ndarray = field(default=None)      # (S, hot_pad) f32
    shard_epoch: np.ndarray = field(default=None)  # (S,) int64 change counters
    fr_epoch: int = 0

    def __post_init__(self):
        if self.shard_epoch is None:
            self.shard_epoch = np.zeros(self.n_shards, dtype=np.int64)

    @property
    def e_pad(self) -> int:
        return self.eb_cap * self.block_e

    @property
    def h_pad(self) -> int:
        return int(self.frontier.shape[0])

    def owner_of(self, v) -> np.ndarray:
        """Shard owning vertex id ``v`` (through the shard map)."""
        return self.pos_of[np.asarray(v)] // self.n_local_pad

    @property
    def hot_pad(self) -> int:
        return int(self.hot_local_idx.shape[1])

    @property
    def round_base(self) -> np.ndarray:
        """(S,) receive-buffer row offset of ring round ``r``'s slice
        (``round_base[r] = sum(round_cap[1:r])``; entry 0 unused)."""
        base = np.zeros(self.n_shards, dtype=np.int64)
        if self.n_shards > 1:
            base[1:] = np.concatenate(
                [[0], np.cumsum(self.round_cap[1:-1])])
        return base

    def halo_bytes_per_depth(self, n_trie: int, itemsize: int = 4,
                             exchange: str = "psum") -> int:
        """Bytes each shard receives per depth step under ``exchange``:
        the psum'd union frontier, or the sliced hot union plus the
        per-round-padded ring slices."""
        if exchange == "sliced":
            rows = self.hot_pad + int(self.round_cap[1:].sum())
            return rows * n_trie * itemsize
        return self.h_pad * n_trie * itemsize

    def full_field_bytes_per_depth(self, n: int, n_trie: int,
                                   itemsize: int = 4) -> int:
        """Bytes an all-gather of the full field would move instead."""
        return n * n_trie * itemsize

    def exchange_metrics(self, n_trie: int, n: int,
                         itemsize: int = 4) -> Dict[str, float]:
        """Numeric exchange-footprint summary for the metrics registry's
        ``collect()`` protocol: per-depth bytes under both exchange modes,
        the full-field baseline, and the live packing geometry."""
        full = self.full_field_bytes_per_depth(n, n_trie, itemsize)
        return {
            "n_shards": self.n_shards,
            "n_local_pad": self.n_local_pad,
            "n_frontier": self.n_frontier,
            "hot_rows": self.hot_pad,
            "sliced_rows": self.hot_pad + int(self.round_cap[1:].sum()),
            "halo_bytes_psum": self.halo_bytes_per_depth(
                n_trie, itemsize, exchange="psum"),
            "halo_bytes_sliced": self.halo_bytes_per_depth(
                n_trie, itemsize, exchange="sliced"),
            "full_field_bytes": full,
            "shard_epoch_max": int(self.shard_epoch.max())
            if self.n_shards else 0,
        }

    def scatter_slot_values(self, values: np.ndarray, m: int,
                            dtype=np.float32) -> np.ndarray:
        """Scatter per-slot values (flattened ``(S * e_pad,)`` or
        ``(S, e_pad)``) back into raw edge order."""
        flat = np.asarray(values).reshape(-1)
        raw = self.slot_raw.reshape(-1)
        ok = raw >= 0
        out = np.zeros(m, dtype=dtype)
        out[raw[ok]] = flat[ok]
        return out


def _dst_sorted_view(
        g, sp: Optional[ShardedVMPacking] = None,
        pos_of: Optional[np.ndarray] = None, identity: bool = True,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """``(e_src, e_dst, e_dpos, e_raw)`` — the edge list sorted by
    destination *position*, with ``e_dpos`` the destination positions and
    ``e_raw`` the raw (``(src, dst)``-sorted) index of each edge.

    Under the identity shard map, symmetric graphs get this for free: the
    dst-sorted view is the raw arrays with roles swapped, and the sort
    permutation is the reverse-edge involution (the identity ``vm_packing``
    patching already exploits)."""
    if sp is not None:
        pos_of, identity = sp.pos_of, sp.identity
    if identity:
        if g.is_symmetric():
            return g.dst, g.src, g.src, g.reverse_edge_index
        order = np.lexsort((g.src, g.dst))
        d = g.dst[order]
        return g.src[order], d, d, order
    dpos = pos_of[g.dst]
    order = np.lexsort((g.src, dpos))
    return g.src[order], g.dst[order], dpos[order], order


def _fill_shard(sp: ShardedVMPacking, s: int, g, cnt,
                e_src: np.ndarray, e_dst: np.ndarray, e_dpos: np.ndarray,
                e_raw: np.ndarray) -> Optional[np.ndarray]:
    """Refill shard ``s``'s packed rows from the current graph.

    Returns the shard's halo *position* array (sorted unique), or ``None``
    when the shard's real edges no longer fit ``eb_cap`` (caller must
    rebuild).  Does not touch the source maps — the caller remaps after
    frontier updates."""
    bn, be, bps = sp.block_n, sp.block_e, sp.blocks_per_shard
    blocks = np.arange(s * bps, (s + 1) * bps, dtype=np.int64)
    vlo_all = np.minimum(blocks * bn, g.n)
    vhi_all = np.minimum((blocks + 1) * bn, g.n)
    lo_all = np.searchsorted(e_dpos, vlo_all)
    hi_all = np.searchsorted(e_dpos, vhi_all)
    cnt_b = hi_all - lo_all
    eb_need = np.maximum(1, -(-cnt_b // be))
    if int(eb_need.sum()) > sp.eb_cap:
        return None

    sp.meta[s] = 0                      # pad rows: block 0, is_first=0
    sp.src_global[s] = 0
    sp.dst_local[s] = 0
    sp.dst_global[s] = 0
    sp.dst_label[s] = 0
    sp.inv_cnt[s] = 0.0
    sp.slot_raw[s] = -1

    eb_off = np.concatenate([[0], np.cumsum(eb_need)])
    labels = g.labels
    for i, b in enumerate(blocks.tolist()):
        lo, hi = int(lo_all[i]), int(hi_all[i])
        c = hi - lo
        o = int(eb_off[i]) * be
        if c:
            es = e_src[lo:hi]
            ed = e_dst[lo:hi]
            sp.src_global[s, o:o + c] = es
            sp.dst_local[s, o:o + c] = e_dpos[lo:hi] - b * bn
            sp.dst_global[s, o:o + c] = ed
            dl = labels[ed]
            sp.dst_label[s, o:o + c] = dl
            sp.inv_cnt[s, o:o + c] = 1.0 / np.maximum(
                cnt[es, dl].astype(np.float32), 1.0)
            sp.slot_raw[s, o:o + c] = e_raw[lo:hi]
        blk_meta = sp.meta[s, eb_off[i]:eb_off[i + 1]]
        blk_meta[:, 0] = i              # local destination block id
        blk_meta[0, 1] = 1              # first edge block zero-inits output

    # owned labels (pad rows beyond n get -1, which never matches a prior)
    plo, phi = s * sp.n_local_pad, min((s + 1) * sp.n_local_pad, g.n)
    sp.vlabels[s] = -1
    if phi > plo:
        sp.vlabels[s, : phi - plo] = labels[sp.vtx_at[plo:phi]]

    real = sp.slot_raw[s] >= 0
    srcs = np.unique(sp.src_global[s][real])
    spos = sp.pos_of[srcs]
    lo_own, hi_own = s * sp.n_local_pad, (s + 1) * sp.n_local_pad
    halo = spos[(spos < lo_own) | (spos >= hi_own)]
    halo.sort()
    return halo


def _mark_reads(sp: ShardedVMPacking, s: int, fidx: np.ndarray):
    """Record that shard ``s`` reads the frontier rows at ``fidx``.

    New *cold* reads are assigned append-only slots in their owner's pair
    list (``fr_slot``) and written into ``send_local``; hot-tier rows are
    broadcast to every shard anyway, so a fresh reader costs nothing.
    Returns the array of owner shards whose send tables changed (callers
    bump their epochs), or ``None`` when a pair list would overflow its
    ring round's capacity (caller evicts and rebuilds).  Reads are
    monotone: a refilled shard that stops reading a row keeps its
    (harmless, stale) slot — exactly like stale frontier entries — which
    is what keeps every previously-issued slot valid."""
    fidx = np.asarray(fidx, dtype=np.int64)
    fidx = fidx[~sp.fr_reads[s, fidx]]
    if fidx.size == 0:
        return np.empty(0, dtype=np.int64)
    hot = sp.fr_hot_pos[fidx] >= 0
    sp.fr_reads[s, fidx[hot]] = True
    fidx = fidx[~hot]
    if fidx.size == 0:
        return np.empty(0, dtype=np.int64)
    owners = sp.frontier[fidx] // sp.n_local_pad
    order = np.argsort(owners, kind="stable")
    fidx, owners = fidx[order], owners[order]
    uo, starts, counts = np.unique(
        owners, return_index=True, return_counts=True)
    cap = sp.round_cap[(s - uo) % sp.n_shards]
    if (sp.pair_cnt[uo, s] + counts > cap).any():
        return None
    ranks = np.arange(fidx.size, dtype=np.int64) - np.repeat(starts, counts)
    slots = sp.pair_cnt[owners, s].astype(np.int64) + ranks
    sp.fr_slot[s, fidx] = slots.astype(np.int32)
    sp.fr_reads[s, fidx] = True
    sp.send_local[owners, s, slots] = (
        sp.frontier[fidx] - owners * sp.n_local_pad).astype(np.int32)
    sp.pair_cnt[uo, s] += counts.astype(np.int32)
    return uo


def _remap_shard_src(sp: ShardedVMPacking, s: int) -> None:
    """Rewrite shard ``s``'s source maps against the current frontier.

    ``src_map`` indexes ``[local | union frontier]`` (psum exchange);
    ``src_map_sliced`` indexes ``[local | hot union | (owner, pair slot)
    receive buffer]`` (two-tier all_to_all exchange).  Every halo source
    must already be marked in ``fr_reads[s]`` (:func:`_mark_reads`)."""
    fr = sp.frontier[: sp.n_frontier]
    order = np.argsort(fr, kind="stable")
    fr_sorted = fr[order]
    sg = sp.src_global[s].astype(np.int64)
    spos = sp.pos_of[sg]
    owned = (spos >= s * sp.n_local_pad) & (spos < (s + 1) * sp.n_local_pad)
    real = sp.slot_raw[s] >= 0
    pos = np.searchsorted(fr_sorted, spos)
    pos = np.minimum(pos, max(sp.n_frontier - 1, 0))
    fr_idx = order[pos] if sp.n_frontier else np.zeros_like(pos)
    local = spos - s * sp.n_local_pad
    remapped = np.where(owned, local, sp.n_local_pad + fr_idx)
    sp.src_map[s] = np.where(real, remapped, 0).astype(np.int32)
    fr_owner = sp.frontier[fr_idx] // sp.n_local_pad
    hot_pos = sp.fr_hot_pos[fr_idx]
    rnd = (s - fr_owner) % sp.n_shards
    cold = (sp.n_local_pad + sp.hot_pad
            + sp.round_base[rnd] + sp.fr_slot[s, fr_idx])
    exchanged = np.where(hot_pos >= 0, sp.n_local_pad + hot_pos, cold)
    remapped_sl = np.where(owned, local, exchanged)
    sp.src_map_sliced[s] = np.where(real, remapped_sl, 0).astype(np.int32)


def build_sharded_vm_packing(g, n_shards: int, cnt: np.ndarray,
                             block_n: int = 128,
                             block_e: int = 256,
                             order: Optional[np.ndarray] = None,
                             order_token: str = "stripe") -> ShardedVMPacking:
    """Build the stacked per-shard packing from scratch (see module doc).

    ``order`` is the shard map (``pos_of``: vertex id -> position), ``None``
    for the identity stripe; ``order_token`` names it for cache keying."""
    S = int(n_shards)
    if S < 1:
        raise ValueError("n_shards must be >= 1")
    pos_of, vtx_at, identity = _normalize_order(order, g.n)
    nb = max(1, -(-g.n // block_n))
    bps = -(-nb // S)
    n_local_pad = bps * block_n

    e_src, e_dst, e_dpos, e_raw = _dst_sorted_view(
        g, pos_of=pos_of, identity=identity)

    # capacity pass: per-shard edge-block need (every block gets >= 1)
    blocks = np.arange(S * bps, dtype=np.int64)
    lo = np.searchsorted(e_dpos, np.minimum(blocks * block_n, g.n))
    hi = np.searchsorted(e_dpos, np.minimum((blocks + 1) * block_n, g.n))
    eb_need = np.maximum(1, -(-(hi - lo) // block_e)).reshape(S, bps)
    eb_cap = int(eb_need.sum(axis=1).max()) + EB_SLACK
    e_pad = eb_cap * block_e

    sp = ShardedVMPacking(
        n_shards=S, block_n=block_n, block_e=block_e,
        blocks_per_shard=bps, n_local_pad=n_local_pad, eb_cap=eb_cap,
        meta=np.zeros((S, eb_cap, 2), np.int32),
        src_map=np.zeros((S, e_pad), np.int32),
        src_global=np.zeros((S, e_pad), np.int32),
        dst_local=np.zeros((S, e_pad), np.int32),
        dst_global=np.zeros((S, e_pad), np.int32),
        dst_label=np.zeros((S, e_pad), np.int32),
        inv_cnt=np.zeros((S, e_pad), np.float32),
        slot_raw=np.full((S, e_pad), -1, np.int64),
        vlabels=np.full((S, n_local_pad), -1, np.int32),
        frontier=np.empty(0, np.int64),   # placeholder until halos known
        n_frontier=0,
        fr_local_idx=np.empty((S, 0), np.int32),
        fr_owned=np.empty((S, 0), np.float32),
        version=g.version,
        pos_of=pos_of, vtx_at=vtx_at,
        order_token=order_token, identity=identity,
    )

    halos = []
    for s in range(S):
        halo = _fill_shard(sp, s, g, cnt, e_src, e_dst, e_dpos, e_raw)
        assert halo is not None  # capacity was sized for exactly this graph
        halos.append(halo)
    frontier = (np.unique(np.concatenate(halos)) if halos
                else np.empty(0, np.int64))
    H = int(frontier.size)
    h_pad = -(-(H + FR_SLACK) // 8) * 8
    sp.frontier = np.zeros(h_pad, np.int64)
    sp.frontier[:H] = frontier
    sp.n_frontier = H
    sp.fr_local_idx = np.zeros((S, h_pad), np.int32)
    sp.fr_owned = np.zeros((S, h_pad), np.float32)
    _refresh_frontier_rows(sp, np.arange(H))

    # sliced exchange tables: split the frontier into a hot broadcast tier
    # and cold pair slices at the cost-optimal read-degree threshold, size
    # pair_cap from the cold pairwise maxima, then assign slots through the
    # same append-only path mutations use
    owners_all = frontier // n_local_pad if H else np.empty(0, np.int64)
    fidx_of = {s: np.searchsorted(frontier, halos[s]) for s in range(S)}
    _build_tiers(sp, fidx_of, owners_all, H)
    sp.fr_reads = np.zeros((S, h_pad), dtype=bool)
    sp.fr_slot = np.zeros((S, h_pad), np.int32)
    sp.pair_cnt = np.zeros((S, S), np.int32)
    sp.send_local = np.zeros((S, S, sp.pair_cap), np.int32)
    sp.src_map_sliced = np.zeros((S, e_pad), np.int32)
    for s in range(S):
        changed = _mark_reads(sp, s, fidx_of[s])
        assert changed is not None      # pair_cap was sized for these reads
    for s in range(S):
        _remap_shard_src(sp, s)
    return sp


def _build_tiers(sp: ShardedVMPacking, fidx_of, owners_all: np.ndarray,
                 H: int) -> None:
    """Split the frontier into hot/cold exchange tiers (module doc).

    A frontier row read by ``r`` shards costs ``r`` cold pair slots (and
    pushes its ring round's padding) but exactly one hot-union row, so the
    per-depth receive footprint ``hot_pad + sum(round_cap)`` is minimised
    by an exact scan over read-degree thresholds ``t``: rows with
    ``r >= t`` go hot.  ``t = S + 1`` (everything cold) is in the scan, so
    the two-tier layout never costs more than pure pair slicing."""
    S = sp.n_shards

    def _pad8(x, slack=0):
        return max(8, -(-(int(x) + slack) // 8) * 8)

    if H == 0 or S == 1:
        sp.n_hot = 0
        sp.fr_hot_pos = np.full(sp.h_pad, -1, np.int32)
        sp.hot_local_idx = np.zeros((S, 8), np.int32)
        sp.hot_owned = np.zeros((S, 8), np.float32)
        sp.round_cap = np.full(S, 8, np.int64)
        sp.round_cap[0] = 0
        sp.pair_cap = 8
        return
    r_deg = np.zeros(H, dtype=np.int64)
    for s in range(S):
        r_deg[fidx_of[s]] += 1
    # hist[(owner, reader), r]: cold pair-list sizes per candidate threshold
    hist = np.zeros((S * S, S + 1), dtype=np.int64)
    for s in range(S):
        fidx = fidx_of[s]
        if fidx.size:
            np.add.at(hist, (owners_all[fidx] * S + s, r_deg[fidx]), 1)
    cold_prefix = np.cumsum(hist, axis=1)      # reads with r <= t per pair
    hh_suffix = np.cumsum(np.bincount(r_deg, minlength=S + 2)[::-1])[::-1]
    # ring round of pair (owner o, reader j): j receives from o at round
    # (j - o) mod S; each round is padded to its own largest pair
    pair_round = (np.arange(S * S) % S
                  - np.arange(S * S) // S) % S   # (o * S + j) -> round

    def _round_caps(col: np.ndarray) -> np.ndarray:
        caps = np.zeros(S, dtype=np.int64)
        np.maximum.at(caps, pair_round, col)
        return caps

    best_t, best_cost, best_caps = None, None, None
    for t in range(2, S + 2):
        hh = int(hh_suffix[t])                       # rows with r >= t
        caps = _round_caps(cold_prefix[:, t - 1])    # per-round cold maxima
        cost = _pad8(hh) + sum(
            _pad8(c, PAIR_SLACK) for c in caps[1:])
        if best_cost is None or cost < best_cost:
            best_t, best_cost, best_caps = t, cost, caps
    hot_rows = np.nonzero(r_deg >= best_t)[0]
    sp.n_hot = int(hot_rows.size)
    hot_pad = _pad8(sp.n_hot)
    sp.fr_hot_pos = np.full(sp.h_pad, -1, np.int32)
    sp.fr_hot_pos[hot_rows] = np.arange(sp.n_hot, dtype=np.int32)
    sp.hot_local_idx = np.zeros((S, hot_pad), np.int32)
    sp.hot_owned = np.zeros((S, hot_pad), np.float32)
    if sp.n_hot:
        vs = sp.frontier[hot_rows]
        owners = vs // sp.n_local_pad
        cols = np.arange(sp.n_hot)
        sp.hot_local_idx[owners, cols] = (
            vs - owners * sp.n_local_pad).astype(np.int32)
        sp.hot_owned[owners, cols] = 1.0
    sp.round_cap = np.asarray(
        [0] + [_pad8(c, PAIR_SLACK) for c in best_caps[1:]], np.int64)
    sp.pair_cap = int(sp.round_cap.max()) if S > 1 else 8


def _refresh_frontier_rows(sp: ShardedVMPacking, positions: np.ndarray) -> None:
    """(Re)write the owner maps for the given frontier positions."""
    if positions.size == 0:
        return
    vs = sp.frontier[positions]
    owners = (vs // sp.n_local_pad).astype(np.int64)
    owners = np.minimum(owners, sp.n_shards - 1)
    sp.fr_local_idx[:, positions] = 0
    sp.fr_owned[:, positions] = 0.0
    sp.fr_local_idx[owners, positions] = (
        vs - owners * sp.n_local_pad).astype(np.int32)
    sp.fr_owned[owners, positions] = 1.0


def patch_sharded_vm_packing(sp: ShardedVMPacking, g, cnt: np.ndarray,
                             changed_dsts: np.ndarray,
                             changed_pairs: np.ndarray,
                             n_old: int, old2new: np.ndarray) -> bool:
    """Patch ``sp`` in place across one applied mutation.

    ``changed_dsts`` are the destination endpoints of every added/removed
    directed edge; ``changed_pairs`` the ``src * L + label(dst)`` keys whose
    neighbour-label count changed; ``old2new`` the mutation's edge position
    map (all as computed by ``apply_mutations``).  Only shards whose
    destination blocks contain a changed endpoint (plus shards gaining
    vertices) are refilled; fresh halo positions are appended to the
    frontier and to the pair slice tables so every other shard's maps stay
    valid; brand-new vertices extend the shard map with an identity tail
    (position == id).  Returns ``False`` when capacity is exceeded (caller
    evicts and rebuilds)."""
    if not g.is_symmetric():
        return False
    bn, bps, S = sp.block_n, sp.blocks_per_shard, sp.n_shards
    nb_new = max(1, -(-g.n // bn))
    if nb_new > S * bps:
        return False                       # vertex growth exceeded capacity
    nb_old = max(1, -(-n_old // bn))
    if g.n > sp.pos_of.shape[0]:
        # new vertices take identity-tail positions (old2new composes with
        # the permutation because existing positions never move)
        tail = np.arange(sp.pos_of.shape[0], g.n, dtype=np.int64)
        sp.pos_of = np.concatenate([sp.pos_of, tail])
        sp.vtx_at = np.concatenate([sp.vtx_at, tail])

    # every shard's slot -> raw-edge map must follow the global edge
    # renumbering (host-side only — device buffers never hold slot_raw,
    # so this re-indexing does not dirty any shard's upload epoch)
    ok = sp.slot_raw >= 0
    sp.slot_raw[ok] = old2new[sp.slot_raw[ok]]
    aff_blocks = np.unique(np.concatenate([
        sp.pos_of[np.asarray(changed_dsts, dtype=np.int64)] // bn,
        np.arange(nb_old, nb_new, dtype=np.int64),
    ]))
    # vertex growth changes vlabels rows even without edges
    grow_shards = (np.arange(n_old // sp.n_local_pad,
                             -(-g.n // sp.n_local_pad), dtype=np.int64)
                   if g.n > n_old else np.empty(0, np.int64))
    aff_shards = np.unique(np.concatenate([
        aff_blocks // bps, grow_shards]))
    aff_shards = aff_shards[(aff_shards >= 0) & (aff_shards < S)]

    e_src, e_dst, e_dpos, e_raw = _dst_sorted_view(g, sp=sp)
    live = set(sp.frontier[: sp.n_frontier].tolist())
    appends = set()
    halos = {}
    for s in aff_shards.tolist():
        halo = _fill_shard(sp, s, g, cnt, e_src, e_dst, e_dpos, e_raw)
        if halo is None:
            return False                   # edge growth exceeded capacity
        halos[s] = halo
        for v in halo.tolist():
            if v not in live:
                appends.add(v)
    if appends:
        new = np.fromiter(sorted(appends), dtype=np.int64)
        if sp.n_frontier + new.size > sp.h_pad:
            return False                   # frontier slack exhausted
        pos = np.arange(sp.n_frontier, sp.n_frontier + new.size)
        sp.frontier[pos] = new
        sp.n_frontier += int(new.size)
        _refresh_frontier_rows(sp, pos)
        sp.fr_epoch += 1

    # sliced tables: append-only slot assignment for fresh reads; owners
    # whose send tables grew must re-upload their shard slice
    fr_order = np.argsort(sp.frontier[: sp.n_frontier], kind="stable")
    fr_sorted = sp.frontier[: sp.n_frontier][fr_order]
    dirty_owners = set()
    for s, halo in halos.items():
        fidx = fr_order[np.searchsorted(fr_sorted, halo)]
        changed = _mark_reads(sp, s, fidx)
        if changed is None:
            return False                   # pair-slot slack exhausted
        dirty_owners.update(changed.tolist())

    for s in aff_shards.tolist():
        _remap_shard_src(sp, s)
        sp.shard_epoch[s] += 1
    for o in sorted(dirty_owners - set(aff_shards.tolist())):
        sp.shard_epoch[o] += 1

    # refresh 1/cnt on slots of *unaffected* shards whose (src, dst-label)
    # count changed (their packed structure is untouched)
    changed_pairs = np.asarray(changed_pairs, dtype=np.int64)
    if changed_pairs.size:
        L = g.n_labels
        untouched = np.setdiff1d(np.arange(S, dtype=np.int64), aff_shards)
        for s in untouched.tolist():
            real = sp.slot_raw[s] >= 0
            keys = sp.src_global[s].astype(np.int64) * L + sp.dst_label[s]
            upd = real & np.isin(keys, changed_pairs)
            if upd.any():
                sp.inv_cnt[s][upd] = 1.0 / np.maximum(
                    cnt[sp.src_global[s][upd],
                        sp.dst_label[s][upd]].astype(np.float32), 1.0)
                sp.shard_epoch[s] += 1

    sp.version = g.version
    return True
