"""Shard-aware edge packing for the multi-device extroversion field.

Partitions the per-graph ``vm_packing`` destination blocks across a device
mesh's ``model`` axis so the ``vm_step`` Pallas kernel can run one shard per
device over its *local* edge blocks.  Each shard owns a contiguous vertex
range (``blocks_per_shard * block_n`` ids) and therefore a contiguous range
of destination blocks — the kernel's output rows never cross shards.  What
does cross shards is the *source* side of an edge: a shard's edge blocks may
read ``beta`` columns of vertices owned elsewhere (the shard's **halo**).

The packing precomputes everything the halo exchange needs:

* ``frontier`` — the union of all shards' halo vertices.  Per depth step the
  exchange moves only these ``(H_pad, N_trie)`` columns (one ``psum`` over
  the ``model`` axis), not the full ``(n, N_trie)`` field.
* ``src_map`` — per-shard source indices remapped into the concatenated
  ``[local rows | frontier rows]`` index space, so the kernel gathers from
  one contiguous ``beta`` buffer without runtime translation.
* ``fr_local_idx`` / ``fr_owned`` — each shard's contribution map into the
  frontier buffer (its owned frontier rows; ``psum`` completes the union
  because every frontier vertex is owned by exactly one shard).
* ``slot_raw`` — packed slot -> raw edge id, so per-slot edge masses scatter
  back into the graph's raw edge order on the host.

Like :meth:`LabelledGraph.vm_packing`, the packing is partition-independent
(the TAPER ``part`` vector never appears here) and version-keyed.  After
:meth:`LabelledGraph.apply_mutations` the cached packing is **patched per
dirty shard** (:func:`patch_sharded_vm_packing`): only shards whose
destination blocks contain a mutated endpoint are refilled, new halo
vertices are *appended* to the frontier (existing positions stay valid, so
unaffected shards' ``src_map`` rows survive untouched), and per-shard
``shard_epoch`` counters tell device-buffer caches exactly which shard
slices to re-upload.  Capacity headroom (``EB_SLACK`` spare edge blocks per
shard, ``FR_SLACK`` spare frontier rows) absorbs modest growth without a
shape change; overflowing it evicts the entry for a scratch rebuild.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

#: spare edge blocks per shard so mutations can grow a shard in place
EB_SLACK = 2
#: spare frontier rows so mutations can append halo vertices in place
FR_SLACK = 64


def _dst_sorted_view(g) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """``(e_src, e_dst, e_raw)`` — the edge list sorted by ``(dst, src)``
    with ``e_raw`` the raw (``(src, dst)``-sorted) position of each edge.

    Symmetric graphs get this for free: the dst-sorted view is the raw
    arrays with roles swapped, and the sort permutation is the reverse-edge
    involution (the identity ``vm_packing`` patching already exploits).
    """
    if g.is_symmetric():
        return g.dst, g.src, g.reverse_edge_index
    order = np.lexsort((g.src, g.dst))
    return g.src[order], g.dst[order], order


@dataclass
class ShardedVMPacking:
    """Stacked per-shard ``vm_step`` inputs (leading axis = shard)."""

    n_shards: int
    block_n: int
    block_e: int
    blocks_per_shard: int          # destination blocks per shard (capacity)
    n_local_pad: int               # blocks_per_shard * block_n
    eb_cap: int                    # edge blocks per shard (incl. slack)
    meta: np.ndarray               # (S, eb_cap, 2) [local dst block, is_first]
    src_map: np.ndarray            # (S, e_pad) int32 into [local | frontier]
    src_global: np.ndarray         # (S, e_pad) int32 global source vertex
    dst_local: np.ndarray          # (S, e_pad) int32 within-block destination
    dst_global: np.ndarray         # (S, e_pad) int32 global destination vertex
    dst_label: np.ndarray          # (S, e_pad) int32 label of destination
    inv_cnt: np.ndarray            # (S, e_pad) f32 1/cnt[src, l(dst)], 0 pad
    slot_raw: np.ndarray           # (S, e_pad) int64 raw edge id, -1 pad
    vlabels: np.ndarray            # (S, n_local_pad) int32 owned labels, -1 pad
    frontier: np.ndarray           # (H_pad,) int64; first n_frontier live
    n_frontier: int
    fr_local_idx: np.ndarray       # (S, H_pad) int32 owner-local row
    fr_owned: np.ndarray           # (S, H_pad) f32 1.0 iff shard owns entry
    version: int                   # graph version the arrays reflect
    shard_epoch: np.ndarray = field(default=None)  # (S,) int64 change counters
    fr_epoch: int = 0

    def __post_init__(self):
        if self.shard_epoch is None:
            self.shard_epoch = np.zeros(self.n_shards, dtype=np.int64)

    @property
    def e_pad(self) -> int:
        return self.eb_cap * self.block_e

    @property
    def h_pad(self) -> int:
        return int(self.frontier.shape[0])

    def owner_of(self, v) -> np.ndarray:
        return np.asarray(v) // self.n_local_pad

    def halo_bytes_per_depth(self, n_trie: int, itemsize: int = 4) -> int:
        """Bytes each shard receives per depth step (the psum'd frontier)."""
        return self.h_pad * n_trie * itemsize

    def full_field_bytes_per_depth(self, n: int, n_trie: int,
                                   itemsize: int = 4) -> int:
        """Bytes an all-gather of the full field would move instead."""
        return n * n_trie * itemsize

    def scatter_slot_values(self, values: np.ndarray, m: int,
                            dtype=np.float32) -> np.ndarray:
        """Scatter per-slot values (flattened ``(S * e_pad,)`` or
        ``(S, e_pad)``) back into raw edge order."""
        flat = np.asarray(values).reshape(-1)
        raw = self.slot_raw.reshape(-1)
        ok = raw >= 0
        out = np.zeros(m, dtype=dtype)
        out[raw[ok]] = flat[ok]
        return out


def _fill_shard(sp: ShardedVMPacking, s: int, g, cnt,
                e_src: np.ndarray, e_dst: np.ndarray,
                e_raw: np.ndarray) -> Optional[np.ndarray]:
    """Refill shard ``s``'s packed rows from the current graph.

    Returns the shard's halo vertex array (sorted unique), or ``None`` when
    the shard's real edges no longer fit ``eb_cap`` (caller must rebuild).
    Does not touch ``src_map`` — the caller remaps after frontier updates.
    """
    bn, be, bps = sp.block_n, sp.block_e, sp.blocks_per_shard
    blocks = np.arange(s * bps, (s + 1) * bps, dtype=np.int64)
    vlo_all = np.minimum(blocks * bn, g.n)
    vhi_all = np.minimum((blocks + 1) * bn, g.n)
    lo_all = np.searchsorted(e_dst, vlo_all)
    hi_all = np.searchsorted(e_dst, vhi_all)
    cnt_b = hi_all - lo_all
    eb_need = np.maximum(1, -(-cnt_b // be))
    if int(eb_need.sum()) > sp.eb_cap:
        return None

    sp.meta[s] = 0                      # pad rows: block 0, is_first=0
    sp.src_global[s] = 0
    sp.dst_local[s] = 0
    sp.dst_global[s] = 0
    sp.dst_label[s] = 0
    sp.inv_cnt[s] = 0.0
    sp.slot_raw[s] = -1

    eb_off = np.concatenate([[0], np.cumsum(eb_need)])
    labels = g.labels
    for i, b in enumerate(blocks.tolist()):
        lo, hi = int(lo_all[i]), int(hi_all[i])
        c = hi - lo
        o = int(eb_off[i]) * be
        if c:
            es = e_src[lo:hi]
            ed = e_dst[lo:hi]
            sp.src_global[s, o:o + c] = es
            sp.dst_local[s, o:o + c] = ed - b * bn
            sp.dst_global[s, o:o + c] = ed
            dl = labels[ed]
            sp.dst_label[s, o:o + c] = dl
            sp.inv_cnt[s, o:o + c] = 1.0 / np.maximum(
                cnt[es, dl].astype(np.float32), 1.0)
            sp.slot_raw[s, o:o + c] = e_raw[lo:hi]
        blk_meta = sp.meta[s, eb_off[i]:eb_off[i + 1]]
        blk_meta[:, 0] = i              # local destination block id
        blk_meta[0, 1] = 1              # first edge block zero-inits output

    # owned labels (pad rows beyond n get -1, which never matches a prior)
    vlo, vhi = s * sp.n_local_pad, min((s + 1) * sp.n_local_pad, g.n)
    sp.vlabels[s] = -1
    if vhi > vlo:
        sp.vlabels[s, : vhi - vlo] = labels[vlo:vhi]

    real = sp.slot_raw[s] >= 0
    srcs = np.unique(sp.src_global[s][real])
    lo_own, hi_own = s * sp.n_local_pad, (s + 1) * sp.n_local_pad
    return srcs[(srcs < lo_own) | (srcs >= hi_own)]


def _remap_shard_src(sp: ShardedVMPacking, s: int) -> None:
    """Rewrite shard ``s``'s ``src_map`` against the current frontier."""
    fr = sp.frontier[: sp.n_frontier]
    order = np.argsort(fr, kind="stable")
    fr_sorted = fr[order]
    sg = sp.src_global[s].astype(np.int64)
    owned = (sg >= s * sp.n_local_pad) & (sg < (s + 1) * sp.n_local_pad)
    real = sp.slot_raw[s] >= 0
    pos = np.searchsorted(fr_sorted, sg)
    pos = np.minimum(pos, max(sp.n_frontier - 1, 0))
    fr_idx = order[pos] if sp.n_frontier else np.zeros_like(pos)
    remapped = np.where(owned, sg - s * sp.n_local_pad,
                        sp.n_local_pad + fr_idx)
    sp.src_map[s] = np.where(real, remapped, 0).astype(np.int32)


def build_sharded_vm_packing(g, n_shards: int, cnt: np.ndarray,
                             block_n: int = 128,
                             block_e: int = 256) -> ShardedVMPacking:
    """Build the stacked per-shard packing from scratch (see module doc)."""
    S = int(n_shards)
    if S < 1:
        raise ValueError("n_shards must be >= 1")
    nb = max(1, -(-g.n // block_n))
    bps = -(-nb // S)
    n_local_pad = bps * block_n

    e_src, e_dst, e_raw = _dst_sorted_view(g)

    # capacity pass: per-shard edge-block need (every block gets >= 1)
    blocks = np.arange(S * bps, dtype=np.int64)
    lo = np.searchsorted(e_dst, np.minimum(blocks * block_n, g.n))
    hi = np.searchsorted(e_dst, np.minimum((blocks + 1) * block_n, g.n))
    eb_need = np.maximum(1, -(-(hi - lo) // block_e)).reshape(S, bps)
    eb_cap = int(eb_need.sum(axis=1).max()) + EB_SLACK
    e_pad = eb_cap * block_e

    sp = ShardedVMPacking(
        n_shards=S, block_n=block_n, block_e=block_e,
        blocks_per_shard=bps, n_local_pad=n_local_pad, eb_cap=eb_cap,
        meta=np.zeros((S, eb_cap, 2), np.int32),
        src_map=np.zeros((S, e_pad), np.int32),
        src_global=np.zeros((S, e_pad), np.int32),
        dst_local=np.zeros((S, e_pad), np.int32),
        dst_global=np.zeros((S, e_pad), np.int32),
        dst_label=np.zeros((S, e_pad), np.int32),
        inv_cnt=np.zeros((S, e_pad), np.float32),
        slot_raw=np.full((S, e_pad), -1, np.int64),
        vlabels=np.full((S, n_local_pad), -1, np.int32),
        frontier=np.empty(0, np.int64),   # placeholder until halos known
        n_frontier=0,
        fr_local_idx=np.empty((S, 0), np.int32),
        fr_owned=np.empty((S, 0), np.float32),
        version=g.version,
    )

    halos = []
    for s in range(S):
        halo = _fill_shard(sp, s, g, cnt, e_src, e_dst, e_raw)
        assert halo is not None  # capacity was sized for exactly this graph
        halos.append(halo)
    frontier = (np.unique(np.concatenate(halos)) if halos
                else np.empty(0, np.int64))
    H = int(frontier.size)
    h_pad = -(-(H + FR_SLACK) // 8) * 8
    sp.frontier = np.zeros(h_pad, np.int64)
    sp.frontier[:H] = frontier
    sp.n_frontier = H
    sp.fr_local_idx = np.zeros((S, h_pad), np.int32)
    sp.fr_owned = np.zeros((S, h_pad), np.float32)
    _refresh_frontier_rows(sp, np.arange(H))
    for s in range(S):
        _remap_shard_src(sp, s)
    return sp


def _refresh_frontier_rows(sp: ShardedVMPacking, positions: np.ndarray) -> None:
    """(Re)write the owner maps for the given frontier positions."""
    if positions.size == 0:
        return
    vs = sp.frontier[positions]
    owners = (vs // sp.n_local_pad).astype(np.int64)
    owners = np.minimum(owners, sp.n_shards - 1)
    sp.fr_local_idx[:, positions] = 0
    sp.fr_owned[:, positions] = 0.0
    sp.fr_local_idx[owners, positions] = (
        vs - owners * sp.n_local_pad).astype(np.int32)
    sp.fr_owned[owners, positions] = 1.0


def patch_sharded_vm_packing(sp: ShardedVMPacking, g, cnt: np.ndarray,
                             changed_dsts: np.ndarray,
                             changed_pairs: np.ndarray,
                             n_old: int, old2new: np.ndarray) -> bool:
    """Patch ``sp`` in place across one applied mutation.

    ``changed_dsts`` are the destination endpoints of every added/removed
    directed edge; ``changed_pairs`` the ``src * L + label(dst)`` keys whose
    neighbour-label count changed; ``old2new`` the mutation's edge position
    map (all as computed by ``apply_mutations``).  Only shards whose
    destination blocks contain a changed endpoint (plus shards gaining
    vertices) are refilled; fresh halo vertices are appended to the frontier
    so every other shard's ``src_map`` stays valid.  Returns ``False`` when
    capacity is exceeded (caller evicts and rebuilds).
    """
    if not g.is_symmetric():
        return False
    bn, bps, S = sp.block_n, sp.blocks_per_shard, sp.n_shards
    nb_new = max(1, -(-g.n // bn))
    if nb_new > S * bps:
        return False                       # vertex growth exceeded capacity
    nb_old = max(1, -(-n_old // bn))

    # every shard's slot -> raw-edge map must follow the global edge
    # renumbering (host-side only — device buffers never hold slot_raw,
    # so this re-indexing does not dirty any shard's upload epoch)
    ok = sp.slot_raw >= 0
    sp.slot_raw[ok] = old2new[sp.slot_raw[ok]]
    aff_blocks = np.unique(np.concatenate([
        np.asarray(changed_dsts, dtype=np.int64) // bn,
        np.arange(nb_old, nb_new, dtype=np.int64),
    ]))
    # vertex growth changes vlabels rows even without edges
    grow_shards = (np.arange(n_old // sp.n_local_pad,
                             -(-g.n // sp.n_local_pad), dtype=np.int64)
                   if g.n > n_old else np.empty(0, np.int64))
    aff_shards = np.unique(np.concatenate([
        aff_blocks // bps, grow_shards]))
    aff_shards = aff_shards[(aff_shards >= 0) & (aff_shards < S)]

    e_src, e_dst, e_raw = _dst_sorted_view(g)
    live = set(sp.frontier[: sp.n_frontier].tolist())
    appends = set()
    for s in aff_shards.tolist():
        halo = _fill_shard(sp, s, g, cnt, e_src, e_dst, e_raw)
        if halo is None:
            return False                   # edge growth exceeded capacity
        for v in halo.tolist():
            if v not in live:
                appends.add(v)
    if appends:
        new = np.fromiter(sorted(appends), dtype=np.int64)
        if sp.n_frontier + new.size > sp.h_pad:
            return False                   # frontier slack exhausted
        pos = np.arange(sp.n_frontier, sp.n_frontier + new.size)
        sp.frontier[pos] = new
        sp.n_frontier += int(new.size)
        _refresh_frontier_rows(sp, pos)
        sp.fr_epoch += 1

    for s in aff_shards.tolist():
        _remap_shard_src(sp, s)
        sp.shard_epoch[s] += 1

    # refresh 1/cnt on slots of *unaffected* shards whose (src, dst-label)
    # count changed (their packed structure is untouched)
    changed_pairs = np.asarray(changed_pairs, dtype=np.int64)
    if changed_pairs.size:
        L = g.n_labels
        untouched = np.setdiff1d(np.arange(S, dtype=np.int64), aff_shards)
        for s in untouched.tolist():
            real = sp.slot_raw[s] >= 0
            keys = sp.src_global[s].astype(np.int64) * L + sp.dst_label[s]
            upd = real & np.isin(keys, changed_pairs)
            if upd.any():
                sp.inv_cnt[s][upd] = 1.0 / np.maximum(
                    cnt[sp.src_global[s][upd],
                        sp.dst_label[s][upd]].astype(np.float32), 1.0)
                sp.shard_epoch[s] += 1

    sp.version = g.version
    return True
