from repro.graphs.graph import AppliedMutation, LabelledGraph, MutationBatch
from repro.graphs.partition import (
    hash_partition,
    metis_like_partition,
    fennel_stream_partition,
)
from repro.graphs.metrics import edge_cut, partition_balance, partition_sizes

__all__ = [
    "AppliedMutation",
    "LabelledGraph",
    "MutationBatch",
    "hash_partition",
    "metis_like_partition",
    "fennel_stream_partition",
    "edge_cut",
    "partition_balance",
    "partition_sizes",
]
