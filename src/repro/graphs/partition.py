"""Initial graph partitioners.

TAPER *enhances* an existing partitioning (paper §1.1); it never computes one
from scratch.  We provide the two starting points the paper evaluates —
hash and (unweighted) Metis — plus a streaming partitioner:

* ``hash_partition`` — the cheap baseline (paper §1: "grouping vertices by
  some hash of their ids").
* ``metis_like_partition`` — an in-repo multilevel min-edge-cut partitioner
  (heavy-edge-matching coarsening, greedy region-growing initialisation,
  boundary FM refinement at every level).  Stands in for the Metis binary;
  same objective, no external dependency.
* ``fennel_stream_partition`` — single-pass streaming partitioner (Fennel,
  paper [24]) as a third baseline.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger

log = get_logger("graphs.partition")


# ---------------------------------------------------------------------------
# Hash
# ---------------------------------------------------------------------------


def hash_partition(n: int, k: int, seed: int = 0) -> np.ndarray:
    """Pseudo-random balanced assignment by a mixed hash of the vertex id."""
    ids = np.arange(n, dtype=np.uint64)
    mix = (seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    x = ids + np.uint64(mix)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return (x % np.uint64(k)).astype(np.int32)


# ---------------------------------------------------------------------------
# Fennel streaming
# ---------------------------------------------------------------------------


def fennel_stream_partition(
    g: LabelledGraph, k: int, seed: int = 0, gamma: float = 1.5
) -> np.ndarray:
    """One-pass Fennel: argmax_p |N(v) ∩ P_p| - alpha*gamma/2*|P_p|^(gamma-1)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(g.n)
    m = g.undirected_edge_count()
    alpha = m * (k ** (gamma - 1.0)) / max(g.n, 1) ** gamma
    part = -np.ones(g.n, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    cap = int(1.1 * g.n / k) + 1
    for v in order:
        nbrs = g.neighbors(v)
        scores = np.zeros(k, dtype=np.float64)
        pn = part[nbrs]
        pn = pn[pn >= 0]
        if pn.size:
            np.add.at(scores, pn, 1.0)
        scores -= alpha * gamma / 2.0 * np.power(sizes.astype(np.float64), gamma - 1.0)
        scores[sizes >= cap] = -np.inf
        p = int(np.argmax(scores))
        part[v] = p
        sizes[p] += 1
    return part


# ---------------------------------------------------------------------------
# Multilevel min edge-cut ("metis-like")
# ---------------------------------------------------------------------------


@dataclass
class _CoarseGraph:
    n: int
    src: np.ndarray       # directed symmetric
    dst: np.ndarray
    ewgt: np.ndarray      # per directed edge
    vwgt: np.ndarray      # per vertex
    row_ptr: np.ndarray
    fine_to_coarse: Optional[np.ndarray] = None  # mapping from the finer level


def _build_csr(n: int, src: np.ndarray, dst: np.ndarray, ewgt: np.ndarray) -> _CoarseGraph:
    order = np.lexsort((dst, src))
    src, dst, ewgt = src[order], dst[order], ewgt[order]
    # merge parallel edges
    if len(src):
        key = src.astype(np.int64) * n + dst
        uniq, inv = np.unique(key, return_inverse=True)
        w = np.zeros(len(uniq), dtype=np.float64)
        np.add.at(w, inv, ewgt)
        src = (uniq // n).astype(np.int32)
        dst = (uniq % n).astype(np.int32)
        ewgt = w
    counts = np.bincount(src, minlength=n)
    row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
    return _CoarseGraph(n, src, dst, ewgt, np.ones(n), row_ptr)


def _heavy_edge_matching(cg: _CoarseGraph, rng: np.random.Generator) -> Tuple[_CoarseGraph, np.ndarray]:
    """One coarsening level; returns (coarser graph, fine->coarse map)."""
    match = -np.ones(cg.n, dtype=np.int64)
    order = rng.permutation(cg.n)
    for v in order:
        if match[v] >= 0:
            continue
        lo, hi = cg.row_ptr[v], cg.row_ptr[v + 1]
        nbrs, w = cg.dst[lo:hi], cg.ewgt[lo:hi]
        free = match[nbrs] < 0
        cand, cw = nbrs[free], w[free]
        cand_mask = cand != v
        cand, cw = cand[cand_mask], cw[cand_mask]
        if cand.size:
            u = int(cand[np.argmax(cw)])
            match[v], match[u] = u, v
        else:
            match[v] = v
    # assign coarse ids
    coarse_id = -np.ones(cg.n, dtype=np.int64)
    nxt = 0
    for v in range(cg.n):
        if coarse_id[v] < 0:
            coarse_id[v] = nxt
            u = match[v]
            if u != v and coarse_id[u] < 0:
                coarse_id[u] = nxt
            nxt += 1
    csrc = coarse_id[cg.src].astype(np.int32)
    cdst = coarse_id[cg.dst].astype(np.int32)
    keep = csrc != cdst
    out = _build_csr(nxt, csrc[keep], cdst[keep], cg.ewgt[keep])
    vwgt = np.zeros(nxt)
    np.add.at(vwgt, coarse_id, cg.vwgt)
    out.vwgt = vwgt
    out.fine_to_coarse = coarse_id
    return out, coarse_id


def _region_grow_init(cg: _CoarseGraph, k: int, rng: np.random.Generator) -> np.ndarray:
    """BFS-order chunking: balanced by construction, locality from BFS."""
    visited = np.zeros(cg.n, dtype=bool)
    order: list = []
    perm = rng.permutation(cg.n)
    for s in perm:
        if visited[s]:
            continue
        queue = [int(s)]
        visited[s] = True
        while queue:
            v = queue.pop(0)
            order.append(v)
            lo, hi = cg.row_ptr[v], cg.row_ptr[v + 1]
            for u in cg.dst[lo:hi]:
                if not visited[u]:
                    visited[u] = True
                    queue.append(int(u))
    order = np.asarray(order)
    cum = np.cumsum(cg.vwgt[order])
    total = cum[-1]
    part = np.empty(cg.n, dtype=np.int32)
    part[order] = np.minimum((cum * k / (total + 1e-9)).astype(np.int32), k - 1)
    return part


def _fm_refine(
    cg: _CoarseGraph,
    part: np.ndarray,
    k: int,
    epsilon: float,
    passes: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Boundary FM-style greedy refinement on weighted edge-cut."""
    part = part.copy()
    sizes = np.zeros(k)
    np.add.at(sizes, part, cg.vwgt)
    max_size = (1.0 + epsilon) * cg.vwgt.sum() / k

    def _rebalance():
        """Force oversized partitions under max_size (min-loss moves)."""
        for p in np.argsort(-sizes):
            while sizes[p] > max_size:
                members = np.nonzero(part == p)[0]
                w_to = np.zeros((members.size, k))
                for i, v in enumerate(members):
                    lo, hi = cg.row_ptr[v], cg.row_ptr[v + 1]
                    np.add.at(w_to[i], part[cg.dst[lo:hi]], cg.ewgt[lo:hi])
                loss = w_to[:, p] - w_to.max(axis=1)
                for i in np.argsort(loss):
                    v = members[i]
                    dests = np.argsort(-w_to[i])
                    dests = [d for d in dests if d != p and sizes[d] + cg.vwgt[v] <= max_size]
                    if not dests:
                        continue
                    d = int(dests[0])
                    sizes[p] -= cg.vwgt[v]
                    sizes[d] += cg.vwgt[v]
                    part[v] = d
                    if sizes[p] <= max_size:
                        break
                else:
                    return  # cannot rebalance further

    _rebalance()
    for _ in range(passes):
        moved = 0
        # external/internal weighted degrees per vertex (recomputed per pass)
        w_to = np.zeros((cg.n, k))
        np.add.at(w_to, (cg.src, part[cg.dst]), cg.ewgt)
        internal = w_to[np.arange(cg.n), part]
        best_gain = w_to.max(axis=1) - internal
        boundary = np.nonzero(best_gain > 0)[0]
        order = boundary[np.argsort(-best_gain[boundary])]
        for v in order:
            p_old = part[v]
            gains = w_to[v] - w_to[v, p_old]
            gains[p_old] = -np.inf
            cand = np.argsort(-gains)
            for p_new in cand:
                if gains[p_new] <= 0:
                    break
                if sizes[p_new] + cg.vwgt[v] <= max_size:
                    # apply and update neighbour tallies
                    lo, hi = cg.row_ptr[v], cg.row_ptr[v + 1]
                    nbrs, w = cg.dst[lo:hi], cg.ewgt[lo:hi]
                    np.subtract.at(w_to, (nbrs, np.full(nbrs.size, p_old)), w)
                    np.add.at(w_to, (nbrs, np.full(nbrs.size, int(p_new))), w)
                    sizes[p_old] -= cg.vwgt[v]
                    sizes[p_new] += cg.vwgt[v]
                    part[v] = int(p_new)
                    moved += 1
                    break
        if moved == 0:
            break
    return part


def metis_like_partition(
    g: LabelledGraph,
    k: int,
    seed: int = 0,
    epsilon: float = 0.05,
    coarsen_to: Optional[int] = None,
    refine_passes: int = 4,
    restarts: int = 2,
) -> np.ndarray:
    """Multilevel k-way min-edge-cut partitioning (unweighted input edges).

    Matches the paper's use of Metis "without edge weights" (§1.2) as the
    workload-agnostic gold-standard starting point.
    """
    rng = np.random.default_rng(seed)
    base = _build_csr(g.n, g.src.copy(), g.dst.copy(), np.ones(g.m, dtype=np.float64))
    coarsen_to = coarsen_to or max(256, 32 * k)

    levels = [base]
    cg = base
    while cg.n > coarsen_to:
        nxt, _ = _heavy_edge_matching(cg, rng)
        if nxt.n >= cg.n * 0.95:  # matching stalled
            break
        levels.append(nxt)
        cg = nxt

    best_part, best_cut = None, np.inf
    for r in range(restarts):
        part = _region_grow_init(levels[-1], k, rng)
        part = _fm_refine(levels[-1], part, k, epsilon, refine_passes, rng)
        cut = _cut_of(levels[-1], part)
        if cut < best_cut:
            best_part, best_cut = part, cut
    part = best_part

    # uncoarsen with refinement at each level
    for lvl in range(len(levels) - 1, 0, -1):
        f2c = levels[lvl].fine_to_coarse
        part = part[f2c]
        part = _fm_refine(levels[lvl - 1], part, k, epsilon, refine_passes, rng)
    log.debug("metis_like: levels=%d final cut=%.0f", len(levels), _cut_of(base, part))
    return part.astype(np.int32)


def _cut_of(cg: _CoarseGraph, part: np.ndarray) -> float:
    cut = part[cg.src] != part[cg.dst]
    return float(cg.ewgt[cut].sum() / 2.0)
