"""Labelled graph container.

The graph is stored once on the host as numpy arrays (CSR + symmetric edge
list) and exposed to JAX as plain int32/float32 arrays.  All TAPER
computations are expressed over the *directed, symmetrised* edge list
``(src[i], dst[i])`` — an undirected edge appears in both directions, which
matches the paper's traversal semantics (Gremlin ``both()`` steps).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np


@dataclass
class LabelledGraph:
    """A vertex-labelled graph ``G = (V, E, L_V, l)``.

    Attributes:
      n: number of vertices.
      labels: ``(n,)`` int32 — label id per vertex.
      label_names: label id -> human readable name.
      src, dst: ``(m,)`` int32 symmetric directed edge list, sorted by
        ``(src, dst)``.
      row_ptr: ``(n+1,)`` int64 CSR offsets into ``dst`` for each ``src``.
    """

    n: int
    labels: np.ndarray
    label_names: List[str]
    src: np.ndarray
    dst: np.ndarray
    row_ptr: np.ndarray = field(repr=False, default=None)
    _rev_index: Optional[np.ndarray] = field(repr=False, default=None, compare=False)
    _vm_pack_cache: Dict = field(repr=False, default_factory=dict, compare=False)

    def __post_init__(self):
        self.labels = np.asarray(self.labels, dtype=np.int32)
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.row_ptr is None:
            order = np.lexsort((self.dst, self.src))
            self.src = self.src[order]
            self.dst = self.dst[order]
            counts = np.bincount(self.src, minlength=self.n)
            self.row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_undirected_edges(
        n: int,
        labels: Sequence[int],
        edges: np.ndarray,
        label_names: Optional[List[str]] = None,
        dedup: bool = True,
    ) -> "LabelledGraph":
        """Build from an ``(e, 2)`` array of undirected edges (no self loops)."""
        edges = np.asarray(edges, dtype=np.int64)
        if edges.size == 0:
            edges = edges.reshape(0, 2)
        keep = edges[:, 0] != edges[:, 1]  # paper fn.6: no self loops
        edges = edges[keep]
        sym = np.concatenate([edges, edges[:, ::-1]], axis=0)
        if dedup and len(sym):
            key = sym[:, 0] * np.int64(n) + sym[:, 1]
            _, idx = np.unique(key, return_index=True)
            sym = sym[idx]
        labels = np.asarray(labels, dtype=np.int32)
        if label_names is None:
            label_names = [f"L{i}" for i in range(int(labels.max(initial=-1)) + 1)]
        return LabelledGraph(
            n=n,
            labels=labels,
            label_names=list(label_names),
            src=sym[:, 0].astype(np.int32),
            dst=sym[:, 1].astype(np.int32),
        )

    # -- properties --------------------------------------------------------
    @property
    def m(self) -> int:
        """Number of *directed* edges (2x undirected count)."""
        return int(self.src.shape[0])

    @property
    def n_labels(self) -> int:
        return len(self.label_names)

    @property
    def degrees(self) -> np.ndarray:
        return (self.row_ptr[1:] - self.row_ptr[:-1]).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.dst[self.row_ptr[v] : self.row_ptr[v + 1]]

    @property
    def reverse_edge_index(self) -> np.ndarray:
        """``(m,)`` int64 — index of the reverse edge ``(w, u)`` for each
        directed edge ``i = (u, w)``, or ``-1`` if absent.

        The edge list is sorted by ``(src, dst)``, so the flat keys
        ``src * n + dst`` are ascending and every reverse edge is found with
        one vectorised ``searchsorted`` — no per-edge host loops.  Cached on
        first use (the graph is immutable after construction); symmetric
        graphs built via :meth:`from_undirected_edges` always yield a total
        (no ``-1``) mapping with ``rev[rev] == arange(m)``.
        """
        if self._rev_index is None:
            keys = self.src.astype(np.int64) * self.n + self.dst
            rkeys = self.dst.astype(np.int64) * self.n + self.src
            pos = np.searchsorted(keys, rkeys)
            pos = np.minimum(pos, max(self.m - 1, 0))
            found = (keys[pos] == rkeys) if self.m else np.zeros(0, bool)
            self._rev_index = np.where(found, pos, -1).astype(np.int64)
        return self._rev_index

    def vm_packing(self, cnt: Optional[np.ndarray] = None,
                   block_n: int = 128, block_e: int = 256):
        """Cached edge packing for the ``vm_step`` Pallas kernel.

        Returns ``(packed, dst_label, inv_cnt, dst_global)`` where the first
        three follow :func:`repro.kernels.vm_step.ops.pack_vm_inputs` and
        ``dst_global`` is the ``(E_pad,)`` global destination id per packed
        slot.  Padding slots alias the first vertex of their block
        (``dst_local == 0``, i.e. ``block_id * block_n``) — use
        ``packed.pad_mask``, not ``dst_global``, to identify padding; the
        zeroed ``inv_cnt`` channel is what neutralises padded slots in the
        kernel.  The packing depends only on the graph (not on
        any partitioning), so it is computed once and reused across every
        extroversion-field evaluation/iteration.  A non-default ``cnt`` is
        checked against the cached one — a mismatch rebuilds rather than
        silently returning channels derived from a different count matrix.
        """
        # normalise first so a cnt=None call never aliases an entry built
        # from a custom count matrix (the graph's own counts are cached too)
        if cnt is None:
            if "_default_cnt" not in self._vm_pack_cache:
                self._vm_pack_cache["_default_cnt"] = self.neighbor_label_counts()
            cnt = self._vm_pack_cache["_default_cnt"]
        key = (int(block_n), int(block_e))
        hit = self._vm_pack_cache.get(key)
        if hit is not None:
            cached_cnt, entry = hit
            if cached_cnt is cnt or np.array_equal(cnt, cached_cnt):
                return entry
        from repro.kernels.vm_step.ops import pack_vm_inputs

        packed, dst_label, inv_cnt = pack_vm_inputs(
            self.src, self.dst, self.labels, cnt, self.n,
            block_n=block_n, block_e=block_e)
        dst_global = (np.repeat(packed.meta[:, 0], packed.block_e)
                      * packed.block_n) + packed.dst_local
        entry = (packed, dst_label, inv_cnt, dst_global.astype(np.int32))
        self._vm_pack_cache[key] = (np.asarray(cnt), entry)
        return entry

    def label_counts(self) -> np.ndarray:
        """(n_labels,) number of vertices per label."""
        return np.bincount(self.labels, minlength=self.n_labels)

    def neighbor_label_counts(self) -> np.ndarray:
        """(n, n_labels) int32 — ``cnt[u, l]`` neighbours of u with label l."""
        flat = self.src.astype(np.int64) * self.n_labels + self.labels[self.dst]
        cnt = np.bincount(flat, minlength=self.n * self.n_labels)
        return cnt.reshape(self.n, self.n_labels).astype(np.int32)

    def undirected_edge_count(self) -> int:
        return self.m // 2

    def subgraph_mask(self, vmask: np.ndarray) -> "LabelledGraph":
        """Induced subgraph on the vertices where ``vmask`` is True.

        Vertex ids are compacted; returns the subgraph (labels preserved).
        """
        idx = np.nonzero(vmask)[0]
        remap = -np.ones(self.n, dtype=np.int64)
        remap[idx] = np.arange(idx.size)
        emask = vmask[self.src] & vmask[self.dst]
        s, d = remap[self.src[emask]], remap[self.dst[emask]]
        return LabelledGraph(
            n=int(idx.size),
            labels=self.labels[idx],
            label_names=self.label_names,
            src=s.astype(np.int32),
            dst=d.astype(np.int32),
        )

    def validate(self) -> None:
        assert self.labels.shape == (self.n,)
        assert self.src.shape == self.dst.shape
        assert self.row_ptr.shape == (self.n + 1,)
        assert self.row_ptr[-1] == self.m
        if self.m:
            assert self.src.min() >= 0 and self.src.max() < self.n
            assert self.dst.min() >= 0 and self.dst.max() < self.n
        assert self.labels.min(initial=0) >= 0
        assert self.labels.max(initial=0) < self.n_labels

    def stats(self) -> Dict[str, float]:
        deg = self.degrees
        return {
            "n": self.n,
            "m_undirected": self.undirected_edge_count(),
            "n_labels": self.n_labels,
            "avg_degree": float(deg.mean()) if self.n else 0.0,
            "max_degree": int(deg.max()) if self.n else 0,
        }
