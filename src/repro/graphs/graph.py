"""Labelled graph container with a versioned mutation/delta model.

The graph is stored once on the host as numpy arrays (CSR + symmetric edge
list) and exposed to JAX as plain int32/float32 arrays.  All TAPER
computations are expressed over the *directed, symmetrised* edge list
``(src[i], dst[i])`` — an undirected edge appears in both directions, which
matches the paper's traversal semantics (Gremlin ``both()`` steps).

Dynamic graphs (online TAPER): :meth:`LabelledGraph.apply_mutations` applies
a batched :class:`MutationBatch` of edge/vertex insertions and deletions
*in place*, incrementally patching the sorted edge arrays, ``row_ptr``, the
cached ``reverse_edge_index``, the cached neighbour-label count matrix and
any cached ``vm_packing`` entries (merge-patch, not rebuild).  Every
successful batch bumps :attr:`LabelledGraph.version`; consumers holding
graph-derived state (device-resident buffers in ``repro.core.visitor``, the
executor's per-query traversal-count cache, ...) compare their recorded
version against the graph's to detect staleness instead of silently reusing
stale buffers.  A bounded :attr:`mutation_log` of :class:`AppliedMutation`
records lets those consumers patch their own state incrementally.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class MutationBatch:
    """A batch of topology mutations, expressed over *undirected* edges.

    Attributes:
      add_vertex_labels: label ids of brand-new vertices; they receive the
        next ``len(add_vertex_labels)`` vertex ids (``n .. n+v-1``) and may
        be referenced by ``add_edges`` in the same batch.
      add_edges: ``(e, 2)`` undirected edges to insert.  Self loops,
        already-present edges and edges touching a vertex removed in the
        same batch are dropped; an endpoint beyond the post-batch vertex
        range raises ``ValueError``.
      remove_edges: ``(e, 2)`` undirected edges to delete (absent edges are
        ignored).
      remove_vertices: vertex ids to delete.  Deletion *isolates* the vertex
        — all incident edges are dropped but the id slot and its label
        remain (a tombstone), so existing vertex ids, partition vectors and
        per-vertex caches never need renumbering.
      relabel: ``(v, new_label)`` pairs re-labelling existing vertices (same-
        batch additions included).  A vertex listed twice keeps the last
        entry.  Relabels are applied *after* the structural changes, against
        the post-batch adjacency.

    Removals are applied before additions: an edge listed in both ends up
    present.
    """

    add_vertex_labels: Sequence[int] = ()
    add_edges: Sequence = ()
    remove_edges: Sequence = ()
    remove_vertices: Sequence[int] = ()
    relabel: Sequence = ()

    @property
    def is_empty(self) -> bool:
        return not (
            len(self.add_vertex_labels)
            or len(self.add_edges)
            or len(self.remove_edges)
            or len(self.remove_vertices)
            or len(self.relabel)
        )


@dataclass
class AppliedMutation:
    """Normalised record of one applied :class:`MutationBatch`.

    All edge arrays are *directed* (symmetrised) and describe what actually
    changed.  ``old2new`` maps every pre-mutation edge position to its
    post-mutation position (``-1`` if the edge was removed) and
    ``new_edge_pos`` lists the post-mutation positions of inserted edges —
    together they let downstream per-edge state (e.g. the executor's
    traversal counts) be re-indexed without re-deriving the merge.
    """

    version: int            # graph version after applying (a no-op batch
                            # leaves it at the pre-call version; see is_noop)
    n_before: int
    n_after: int
    added_src: np.ndarray   # (a,) int32 directed
    added_dst: np.ndarray   # (a,) int32
    removed_src: np.ndarray  # (r,) int32 directed
    removed_dst: np.ndarray  # (r,) int32
    old2new: np.ndarray     # (m_before,) int64, -1 where removed
    new_edge_pos: np.ndarray  # (a,) int64 positions of added edges (new order)
    #: graph version the record's *pre* state corresponds to.  A freshly
    #: applied batch spans one version (``version - 1 -> version``); log
    #: compaction composes adjacent records into wider spans.
    version_base: int = -1
    #: effective vertex re-labellings: ``relabel_v[i]`` changed from
    #: ``relabel_old[i]`` to ``relabel_new[i]`` (old != new by construction)
    relabel_v: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))
    relabel_old: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int32))
    relabel_new: np.ndarray = field(
        default_factory=lambda: np.empty(0, np.int32))

    def __post_init__(self):
        if self.version_base < 0:
            self.version_base = self.version - 1

    @property
    def is_noop(self) -> bool:
        return (
            self.n_before == self.n_after
            and self.added_src.size == 0
            and self.removed_src.size == 0
            and self.relabel_v.size == 0
        )

    def dirty_vertices(self) -> np.ndarray:
        """Unique vertex ids whose incident edge set or label changed (plus
        brand-new vertices) — the seed frontier for mutation-local TAPER
        invocations."""
        parts = [
            self.added_src.astype(np.int64),
            self.added_dst.astype(np.int64),
            self.removed_src.astype(np.int64),
            self.removed_dst.astype(np.int64),
            self.relabel_v.astype(np.int64),
            np.arange(self.n_before, self.n_after, dtype=np.int64),
        ]
        return np.unique(np.concatenate(parts))


def compose_mutations(a: AppliedMutation, b: AppliedMutation) -> AppliedMutation:
    """Compose two *adjacent* records into one spanning both mutations.

    Requires ``b.version_base == a.version`` (b applies directly on top of
    a).  The composed ``old2new`` and ``new_edge_pos`` are exact.  The
    added/removed endpoint lists stay *bounded*: edges that are transient
    within the span (added by ``a`` then removed by ``b``) are pruned from
    both sides, so repeated churn over the same edge never accumulates —
    list sizes are bounded by the distinct edge universe, not by lifetime
    batch count.  An edge removed by ``a`` and re-added by ``b`` keeps both
    entries (a conservative dirty-seed superset; consumers re-derive
    against the final arrays, so extra seeds cost time, never correctness).
    """
    if b.version_base != a.version:
        raise ValueError(
            f"cannot compose: records not adjacent "
            f"({a.version_base}->{a.version} then {b.version_base}->{b.version})")
    valid = a.old2new >= 0
    old2new = np.full(a.old2new.shape[0], -1, dtype=np.int64)
    old2new[valid] = b.old2new[a.old2new[valid]]
    # a's added edges that survive b, re-indexed into b's final order
    a_pos_new = (b.old2new[a.new_edge_pos]
                 if a.new_edge_pos.size else a.new_edge_pos)
    surv = a_pos_new >= 0
    added_src = np.concatenate([a.added_src[surv], b.added_src])
    added_dst = np.concatenate([a.added_dst[surv], b.added_dst])
    new_edge_pos = np.concatenate([a_pos_new[surv], b.new_edge_pos])
    order = np.argsort(new_edge_pos, kind="stable")
    # prune b-removals of edges a itself added (transient within the span:
    # absent at the base, absent at the end — they are not removals w.r.t.
    # the composed pre-state, and dropping them is what keeps compacted
    # records from growing with every churn cycle over the same edge)
    span = np.int64(max(b.n_after, 1))
    b_rem_keys = b.removed_src.astype(np.int64) * span + b.removed_dst
    a_add_keys = np.unique(
        a.added_src.astype(np.int64) * span + a.added_dst)
    genuine = ~np.isin(b_rem_keys, a_add_keys)
    # relabels compose pointwise: earliest old, latest new; a net no-change
    # flip (a: x->y then b: y->x) is pruned — consumers re-derive against
    # the final labels, so the intermediate value never matters
    rl: Dict[int, Tuple[int, int]] = {}
    for rec in (a, b):
        for v, o, nw in zip(rec.relabel_v.tolist(),
                            rec.relabel_old.tolist(),
                            rec.relabel_new.tolist()):
            rl[v] = (rl[v][0], nw) if v in rl else (o, nw)
    rl_items = sorted((v, o, nw) for v, (o, nw) in rl.items() if o != nw)
    return AppliedMutation(
        version=b.version,
        n_before=a.n_before,
        n_after=b.n_after,
        added_src=added_src[order].astype(np.int32),
        added_dst=added_dst[order].astype(np.int32),
        removed_src=np.concatenate([a.removed_src, b.removed_src[genuine]]),
        removed_dst=np.concatenate([a.removed_dst, b.removed_dst[genuine]]),
        old2new=old2new,
        new_edge_pos=new_edge_pos[order],
        version_base=a.version_base,
        relabel_v=np.asarray([v for v, _, _ in rl_items], np.int64),
        relabel_old=np.asarray([o for _, o, _ in rl_items], np.int32),
        relabel_new=np.asarray([nw for _, _, nw in rl_items], np.int32),
    )


#: AppliedMutation array fields persisted by the mutation-log serializers,
#: with their storage dtypes (scalar fields travel in the manifest instead)
_MUTATION_ARRAY_FIELDS = (
    ("added_src", np.int32), ("added_dst", np.int32),
    ("removed_src", np.int32), ("removed_dst", np.int32),
    ("old2new", np.int64), ("new_edge_pos", np.int64),
    ("relabel_v", np.int64), ("relabel_old", np.int32),
    ("relabel_new", np.int32),
)


def mutation_log_state(log: Sequence[AppliedMutation]):
    """Flatten a mutation log for persistence: ``(arrays, meta)`` where
    ``arrays`` maps ``mlog{i}_{field}`` to the i-th record's edge/relabel
    arrays (npz-friendly) and ``meta`` holds each record's scalar version
    span — so a restored graph keeps the compacted log and its version
    spans, and slow consumers (executor DP patching) span-walk across the
    restart exactly as they would across any other gap."""
    arrays: Dict[str, np.ndarray] = {}
    meta = []
    for i, rec in enumerate(log):
        for name, dt in _MUTATION_ARRAY_FIELDS:
            arrays[f"mlog{i}_{name}"] = np.asarray(getattr(rec, name), dt)
        meta.append({
            "version": int(rec.version),
            "version_base": int(rec.version_base),
            "n_before": int(rec.n_before),
            "n_after": int(rec.n_after),
        })
    return arrays, meta


def mutation_log_from_state(arrays, meta) -> List[AppliedMutation]:
    """Inverse of :func:`mutation_log_state`."""
    out: List[AppliedMutation] = []
    for i, m in enumerate(meta):
        fields = {
            name: np.asarray(arrays[f"mlog{i}_{name}"], dt)
            for name, dt in _MUTATION_ARRAY_FIELDS
        }
        out.append(AppliedMutation(
            version=int(m["version"]),
            n_before=int(m["n_before"]),
            n_after=int(m["n_after"]),
            version_base=int(m["version_base"]),
            **fields,
        ))
    return out


@dataclass
class LabelledGraph:
    """A vertex-labelled graph ``G = (V, E, L_V, l)``.

    Attributes:
      n: number of vertices.
      labels: ``(n,)`` int32 — label id per vertex.
      label_names: label id -> human readable name.
      src, dst: ``(m,)`` int32 symmetric directed edge list, sorted by
        ``(src, dst)``.
      row_ptr: ``(n+1,)`` int64 CSR offsets into ``dst`` for each ``src``.
      version: mutation counter — bumped by every effective
        :meth:`apply_mutations`; lets derived caches detect staleness.
    """

    #: ring size of the mutation log.  When a new record would overflow it,
    #: the two oldest records are *composed* (``compose_mutations``) rather
    #: than dropped, so the log always reaches back to its earliest base
    #: version and slow consumers patch across arbitrarily long gaps —
    #: falling back to rebuild only when their snapshot predates that base
    #: or falls strictly inside a compacted span.
    MUTATION_LOG_LIMIT = 16

    n: int
    labels: np.ndarray
    label_names: List[str]
    src: np.ndarray
    dst: np.ndarray
    row_ptr: np.ndarray = field(repr=False, default=None)
    version: int = 0
    _rev_index: Optional[np.ndarray] = field(repr=False, default=None, compare=False)
    _vm_pack_cache: Dict = field(repr=False, default_factory=dict, compare=False)
    _mutation_log: List[AppliedMutation] = field(
        repr=False, default_factory=list, compare=False)

    def __post_init__(self):
        self.labels = np.asarray(self.labels, dtype=np.int32)
        self.src = np.asarray(self.src, dtype=np.int32)
        self.dst = np.asarray(self.dst, dtype=np.int32)
        if self.row_ptr is None:
            order = np.lexsort((self.dst, self.src))
            self.src = self.src[order]
            self.dst = self.dst[order]
            counts = np.bincount(self.src, minlength=self.n)
            self.row_ptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)

    # -- construction -----------------------------------------------------
    @staticmethod
    def from_undirected_edges(
        n: int,
        labels: Sequence[int],
        edges: np.ndarray,
        label_names: Optional[List[str]] = None,
        dedup: bool = True,
    ) -> "LabelledGraph":
        """Build from an ``(e, 2)`` array of undirected edges (no self loops)."""
        edges = np.asarray(edges, dtype=np.int64)
        if edges.size == 0:
            edges = edges.reshape(0, 2)
        keep = edges[:, 0] != edges[:, 1]  # paper fn.6: no self loops
        edges = edges[keep]
        sym = np.concatenate([edges, edges[:, ::-1]], axis=0)
        if dedup and len(sym):
            key = sym[:, 0] * np.int64(n) + sym[:, 1]
            _, idx = np.unique(key, return_index=True)
            sym = sym[idx]
        labels = np.asarray(labels, dtype=np.int32)
        if label_names is None:
            label_names = [f"L{i}" for i in range(int(labels.max(initial=-1)) + 1)]
        return LabelledGraph(
            n=n,
            labels=labels,
            label_names=list(label_names),
            src=sym[:, 0].astype(np.int32),
            dst=sym[:, 1].astype(np.int32),
        )

    def copy(self) -> "LabelledGraph":
        """Independent copy with fresh (empty) caches and version 0."""
        return LabelledGraph(
            n=self.n,
            labels=self.labels.copy(),
            label_names=list(self.label_names),
            src=self.src.copy(),
            dst=self.dst.copy(),
            row_ptr=self.row_ptr.copy(),
        )

    # -- properties --------------------------------------------------------
    @property
    def m(self) -> int:
        """Number of *directed* edges (2x undirected count)."""
        return int(self.src.shape[0])

    @property
    def n_labels(self) -> int:
        return len(self.label_names)

    @property
    def degrees(self) -> np.ndarray:
        return (self.row_ptr[1:] - self.row_ptr[:-1]).astype(np.int64)

    @property
    def mutation_log(self) -> List[AppliedMutation]:
        return self._mutation_log

    def neighbors(self, v: int) -> np.ndarray:
        return self.dst[self.row_ptr[v] : self.row_ptr[v + 1]]

    def edge_indices_of(self, vs: np.ndarray) -> np.ndarray:
        """Concatenated CSR edge indices of ``vs`` — each vertex's out-edges
        in CSR order, vertices in the given order."""
        vs = np.asarray(vs, dtype=np.int64)
        starts = self.row_ptr[vs]
        cnts = self.row_ptr[vs + 1] - starts
        total = int(cnts.sum())
        if total == 0:
            return np.empty(0, dtype=np.int64)
        offs = np.repeat(starts - (np.cumsum(cnts) - cnts), cnts)
        return offs + np.arange(total, dtype=np.int64)

    @property
    def reverse_edge_index(self) -> np.ndarray:
        """``(m,)`` int64 — index of the reverse edge ``(w, u)`` for each
        directed edge ``i = (u, w)``, or ``-1`` if absent.

        The edge list is sorted by ``(src, dst)``, so the flat keys
        ``src * n + dst`` are ascending and every reverse edge is found with
        one vectorised ``searchsorted`` — no per-edge host loops.  Cached on
        first use and *incrementally patched* by :meth:`apply_mutations`;
        symmetric graphs built via :meth:`from_undirected_edges` always
        yield a total (no ``-1``) mapping with ``rev[rev] == arange(m)``.
        """
        if self._rev_index is None:
            keys = self.src.astype(np.int64) * self.n + self.dst
            rkeys = self.dst.astype(np.int64) * self.n + self.src
            pos = np.searchsorted(keys, rkeys)
            pos = np.minimum(pos, max(self.m - 1, 0))
            found = (keys[pos] == rkeys) if self.m else np.zeros(0, bool)
            self._rev_index = np.where(found, pos, -1).astype(np.int64)
        return self._rev_index

    def is_symmetric(self) -> bool:
        """True when every directed edge has its reverse present."""
        return bool((self.reverse_edge_index >= 0).all()) if self.m else True

    def vm_packing(self, cnt: Optional[np.ndarray] = None,
                   block_n: int = 128, block_e: int = 256):
        """Cached edge packing for the ``vm_step`` Pallas kernel.

        Returns ``(packed, dst_label, inv_cnt, dst_global)`` where the first
        three follow :func:`repro.kernels.vm_step.ops.pack_vm_inputs` and
        ``dst_global`` is the ``(E_pad,)`` global destination id per packed
        slot.  Padding slots alias the first vertex of their block
        (``dst_local == 0``, i.e. ``block_id * block_n``) — use
        ``packed.pad_mask``, not ``dst_global``, to identify padding; the
        zeroed ``inv_cnt`` channel is what neutralises padded slots in the
        kernel.  The packing depends only on the graph (not on
        any partitioning), so it is computed once and reused across every
        extroversion-field evaluation/iteration; :meth:`apply_mutations`
        merge-patches cached entries block-by-block instead of re-packing.
        A non-default ``cnt`` is checked against the cached one — a mismatch
        rebuilds rather than silently returning channels derived from a
        different count matrix.
        """
        # normalise first so a cnt=None call never aliases an entry built
        # from a custom count matrix (the graph's own counts are cached too)
        if cnt is None:
            cnt = self.cached_neighbor_label_counts()
        key = (int(block_n), int(block_e))
        hit = self._vm_pack_cache.get(key)
        if hit is not None:
            cached_cnt, entry = hit
            if cached_cnt is cnt or np.array_equal(cnt, cached_cnt):
                return entry
        from repro.kernels.vm_step.ops import pack_vm_inputs

        packed, dst_label, inv_cnt = pack_vm_inputs(
            self.src, self.dst, self.labels, cnt, self.n,
            block_n=block_n, block_e=block_e)
        dst_global = (np.repeat(packed.meta[:, 0], packed.block_e)
                      * packed.block_n) + packed.dst_local
        entry = (packed, dst_label, inv_cnt, dst_global.astype(np.int32))
        self._vm_pack_cache[key] = (np.asarray(cnt), entry)
        return entry

    def vm_packing_sharded(self, n_shards: int,
                           cnt: Optional[np.ndarray] = None,
                           block_n: int = 128, block_e: int = 256,
                           order: Optional[np.ndarray] = None,
                           order_token: str = "stripe"):
        """Cached shard-aware edge packing for the multi-device field.

        Returns a :class:`repro.graphs.sharded_packing.ShardedVMPacking`:
        the ``vm_packing`` destination blocks dealt across ``n_shards``
        shards along the ``order`` shard map (a vertex -> position
        permutation; ``None`` = contiguous id stripes), with per-shard
        local/halo source index maps and both halo-exchange table sets (see
        that module's docstring).  Cached per ``(n_shards, block_n,
        block_e)`` and version-keyed like :meth:`vm_packing`; a call with a
        different ``order_token`` re-deals (rebuilds) the cached entry.
        :meth:`apply_mutations` patches cached entries per dirty shard
        (bumping their ``shard_epoch`` counters so device caches re-upload
        only changed shard slices), evicting only when the mutation
        outgrows the packing's capacity slack.
        """
        if cnt is None:
            cnt = self.cached_neighbor_label_counts()
        key = ("sharded", int(n_shards), int(block_n), int(block_e))
        hit = self._vm_pack_cache.get(key)
        if hit is not None:
            cached_cnt, entry = hit
            if (entry.version == self.version
                    and entry.order_token == order_token
                    and (cached_cnt is cnt or np.array_equal(cnt, cached_cnt))):
                return entry
        from repro.graphs.sharded_packing import build_sharded_vm_packing

        entry = build_sharded_vm_packing(
            self, n_shards, cnt, block_n=block_n, block_e=block_e,
            order=order, order_token=order_token)
        self._vm_pack_cache[key] = (np.asarray(cnt), entry)
        return entry

    def label_counts(self) -> np.ndarray:
        """(n_labels,) number of vertices per label."""
        return np.bincount(self.labels, minlength=self.n_labels)

    def neighbor_label_counts(self) -> np.ndarray:
        """(n, n_labels) int32 — ``cnt[u, l]`` neighbours of u with label l."""
        flat = self.src.astype(np.int64) * self.n_labels + self.labels[self.dst]
        cnt = np.bincount(flat, minlength=self.n * self.n_labels)
        return cnt.reshape(self.n, self.n_labels).astype(np.int32)

    def cached_neighbor_label_counts(self) -> np.ndarray:
        """The graph's own neighbour-label count matrix, built lazily and
        incrementally patched across mutations (treat as read-only)."""
        cnt = self._vm_pack_cache.get("_default_cnt")
        if cnt is None:
            cnt = self.neighbor_label_counts()
            self._vm_pack_cache["_default_cnt"] = cnt
        return cnt

    def undirected_edge_count(self) -> int:
        return self.m // 2

    # -- mutation ----------------------------------------------------------
    def apply_mutations(self, batch: MutationBatch) -> AppliedMutation:
        """Apply a :class:`MutationBatch` in place; return the normalised
        :class:`AppliedMutation` record.

        The sorted edge arrays are *merge-patched*: removals become a keep
        mask, additions are merged by one ``searchsorted`` pass — no
        re-sort.  ``row_ptr`` is rebuilt from patched degree counts (O(n)),
        and the cached ``reverse_edge_index``, neighbour-label counts and
        ``vm_packing`` entries are patched rather than recomputed.  Bumps
        :attr:`version` and appends to :attr:`mutation_log` unless the batch
        turns out to be a no-op.
        """
        n_old, m_old = self.n, self.m
        L = self.n_labels

        new_labels = np.asarray(
            batch.add_vertex_labels, dtype=np.int32).reshape(-1)
        if new_labels.size and (
                new_labels.min() < 0 or new_labels.max() >= L):
            raise ValueError("add_vertex_labels out of label range")
        n_new = n_old + int(new_labels.size)
        labels_new = (np.concatenate([self.labels, new_labels])
                      if new_labels.size else self.labels)

        # ---- relabels (validated now, applied after structural changes) --
        rl = np.asarray(batch.relabel, dtype=np.int64).reshape(-1, 2)
        if rl.size:
            if rl[:, 0].min() < 0 or rl[:, 0].max() >= n_new:
                raise ValueError("relabel vertex id out of range")
            if rl[:, 1].min() < 0 or rl[:, 1].max() >= L:
                raise ValueError("relabel label out of label range")
            # a vertex listed twice keeps its last entry
            _, last = np.unique(rl[::-1, 0], return_index=True)
            rl = rl[rl.shape[0] - 1 - last]
            eff = labels_new[rl[:, 0]] != rl[:, 1]
            rl = rl[eff]
        rl_v = rl[:, 0] if rl.size else np.empty(0, np.int64)
        rl_new_lab = rl[:, 1].astype(np.int32) if rl.size else \
            np.empty(0, np.int32)
        rl_old_lab = labels_new[rl_v].astype(np.int32) if rl.size else \
            np.empty(0, np.int32)

        keys_old = self.src.astype(np.int64) * n_new + self.dst
        if m_old > 1 and not (np.diff(keys_old) > 0).all():
            raise ValueError(
                "apply_mutations requires a deduplicated (src, dst)-sorted "
                "edge list")

        # ---- removals -> keep mask over old edge positions ---------------
        removed_vs = (np.unique(np.asarray(
            batch.remove_vertices, dtype=np.int64).reshape(-1))
            if len(batch.remove_vertices) else np.empty(0, np.int64))
        if removed_vs.size and (
                removed_vs.min() < 0 or removed_vs.max() >= n_new):
            raise ValueError("remove_vertices out of range")

        rem = np.asarray(batch.remove_edges, dtype=np.int64).reshape(-1, 2)
        rem_dir = (np.concatenate([rem, rem[:, ::-1]], axis=0)
                   if rem.size else rem.reshape(0, 2))
        old_removed_vs = removed_vs[removed_vs < n_old]
        if old_removed_vs.size:
            # collect out- AND in-arcs explicitly: on an asymmetric graph a
            # one-directional in-arc has no stored reverse, so mirroring the
            # out-edges would leave it dangling on the tombstone
            out_e = self.edge_indices_of(old_removed_vs)
            in_e = np.nonzero(np.isin(self.dst, old_removed_vs))[0]
            eidx = np.unique(np.concatenate([out_e, in_e]))
            inc = np.stack(
                [self.src[eidx], self.dst[eidx]], axis=1).astype(np.int64)
            rem_dir = np.concatenate([rem_dir, inc], axis=0)
        removed_pos = np.empty(0, np.int64)
        if rem_dir.size:
            ok = ((rem_dir >= 0) & (rem_dir < n_new)).all(axis=1)
            rem_dir = rem_dir[ok]
            rem_keys = np.unique(rem_dir[:, 0] * n_new + rem_dir[:, 1])
            if m_old:
                pos = np.minimum(
                    np.searchsorted(keys_old, rem_keys), m_old - 1)
                removed_pos = np.unique(pos[keys_old[pos] == rem_keys])
        keep = np.ones(m_old, dtype=bool)
        keep[removed_pos] = False
        kept_idx = np.nonzero(keep)[0]
        kept_keys = keys_old[kept_idx]

        # ---- additions -> sorted, deduped, not-already-present -----------
        add = np.asarray(batch.add_edges, dtype=np.int64).reshape(-1, 2)
        if add.size:
            if (add < 0).any() or (add >= n_new).any():
                raise ValueError(
                    "add_edges endpoint out of range (did the batch forget "
                    "matching add_vertex_labels?)")
            ok = add[:, 0] != add[:, 1]
            if removed_vs.size:
                ok &= ~(np.isin(add[:, 0], removed_vs)
                        | np.isin(add[:, 1], removed_vs))
            add = add[ok]
        add_dir = (np.concatenate([add, add[:, ::-1]], axis=0)
                   if add.size else add.reshape(0, 2))
        add_keys = (np.unique(add_dir[:, 0] * n_new + add_dir[:, 1])
                    if add_dir.size else np.empty(0, np.int64))
        if add_keys.size and kept_keys.size:
            p = np.minimum(
                np.searchsorted(kept_keys, add_keys), kept_keys.size - 1)
            add_keys = add_keys[kept_keys[p] != add_keys]
        add_s, add_d = np.divmod(add_keys, n_new)
        a = int(add_keys.size)

        if (a == 0 and removed_pos.size == 0 and n_new == n_old
                and rl_v.size == 0):
            # no effective change: no version bump, no log entry
            return AppliedMutation(
                version=self.version, n_before=n_old, n_after=n_old,
                added_src=np.empty(0, np.int32),
                added_dst=np.empty(0, np.int32),
                removed_src=np.empty(0, np.int32),
                removed_dst=np.empty(0, np.int32),
                old2new=np.arange(m_old, dtype=np.int64),
                new_edge_pos=np.empty(0, np.int64),
                version_base=self.version,
            )

        # ---- merge kept + added (one searchsorted, no re-sort) -----------
        m_new = kept_idx.size + a
        shift = np.searchsorted(add_keys, kept_keys)   # added keys before kept
        new_pos_kept = np.arange(kept_idx.size, dtype=np.int64) + shift
        new_pos_added = (np.searchsorted(kept_keys, add_keys)
                         + np.arange(a, dtype=np.int64))
        src_new = np.empty(m_new, dtype=np.int32)
        dst_new = np.empty(m_new, dtype=np.int32)
        src_new[new_pos_kept] = self.src[kept_idx]
        dst_new[new_pos_kept] = self.dst[kept_idx]
        src_new[new_pos_added] = add_s.astype(np.int32)
        dst_new[new_pos_added] = add_d.astype(np.int32)
        old2new = np.full(m_old, -1, dtype=np.int64)
        old2new[kept_idx] = new_pos_kept

        removed_src = self.src[removed_pos].copy()
        removed_dst = self.dst[removed_pos].copy()

        # ---- row_ptr from patched degrees (O(n) cumsum) ------------------
        deg = (self.row_ptr[1:] - self.row_ptr[:-1]).astype(np.int64)
        if n_new > n_old:
            deg = np.concatenate([deg, np.zeros(n_new - n_old, np.int64)])
        if removed_pos.size:
            deg -= np.bincount(removed_src, minlength=n_new)[:n_new]
        if a:
            deg += np.bincount(add_s, minlength=n_new)[:n_new]
        row_ptr_new = np.concatenate([[0], np.cumsum(deg)]).astype(np.int64)

        # ---- patch cached reverse_edge_index -----------------------------
        rev_new = None
        if self._rev_index is not None:
            rev_old = self._rev_index
            rev_new = np.full(m_new, -1, dtype=np.int64)
            r = rev_old[kept_idx]
            ok = (r >= 0) & keep[np.minimum(np.maximum(r, 0), max(m_old - 1, 0))]
            rev_new[new_pos_kept[ok]] = old2new[r[ok]]
            # kept edges whose reverse vanished/appeared + all added edges
            need = np.concatenate([new_pos_kept[~ok], new_pos_added])
            if need.size and m_new:
                keys_new = src_new.astype(np.int64) * n_new + dst_new
                rk = dst_new[need].astype(np.int64) * n_new + src_new[need]
                p = np.minimum(np.searchsorted(keys_new, rk), m_new - 1)
                rev_new[need] = np.where(keys_new[p] == rk, p, -1)

        # ---- patch cached neighbour-label counts -------------------------
        cnt_old = self._vm_pack_cache.get("_default_cnt")
        cnt_new = None
        if cnt_old is not None:
            if n_new > n_old:
                cnt_new = np.concatenate(
                    [cnt_old, np.zeros((n_new - n_old, L), cnt_old.dtype)])
            else:
                cnt_new = cnt_old.copy()
            if removed_pos.size:
                np.subtract.at(
                    cnt_new,
                    (removed_src.astype(np.int64),
                     labels_new[removed_dst.astype(np.int64)]), 1)
            if a:
                np.add.at(cnt_new, (add_s, labels_new[add_d]), 1)

        # ---- apply relabels against the post-batch adjacency -------------
        # structural count updates above used the pre-relabel labels; the
        # relabel delta now shifts each re-labelled vertex's final in-edge
        # contributions old->new, which composes exactly (a same-batch added
        # edge lands at the old column first, then shifts here)
        labels_final = labels_new
        rl_in_src = np.empty(0, np.int64)   # sources of final in-edges of rl_v
        rl_in_old = np.empty(0, np.int32)
        rl_in_new = np.empty(0, np.int32)
        if rl_v.size:
            labels_final = labels_new.copy()
            labels_final[rl_v] = rl_new_lab
            old_of = np.full(n_new, -1, np.int32)
            new_of = np.full(n_new, -1, np.int32)
            old_of[rl_v] = rl_old_lab
            new_of[rl_v] = rl_new_lab
            # in-edges of the re-labelled vertices: O(deg) through the
            # patched reverse index when the graph is symmetric (the
            # serving ingest hot path), O(m) dst scan otherwise
            sel = None
            if rev_new is not None and (
                    bool((rev_new >= 0).all()) if m_new else True):
                starts = row_ptr_new[rl_v]
                cnts = row_ptr_new[rl_v + 1] - starts
                total = int(cnts.sum())
                if total:
                    offs = np.repeat(
                        starts - (np.cumsum(cnts) - cnts), cnts)
                    sel = rev_new[offs + np.arange(total, dtype=np.int64)]
                else:
                    sel = np.empty(0, np.int64)
            if sel is None:
                sel = np.nonzero(np.isin(dst_new, rl_v))[0]
            rl_in_src = src_new[sel].astype(np.int64)
            rl_in_old = old_of[dst_new[sel]]
            rl_in_new = new_of[dst_new[sel]]
            if cnt_new is not None and sel.size:
                np.subtract.at(cnt_new, (rl_in_src, rl_in_old), 1)
                np.add.at(cnt_new, (rl_in_src, rl_in_new), 1)

        # ---- patch cached vm_packing entries (block merge-patch) ---------
        changed_dsts = np.unique(np.concatenate(
            [removed_dst.astype(np.int64), add_d, rl_v]))
        changed_pairs = np.unique(np.concatenate([
            removed_src.astype(np.int64) * L
            + labels_new[removed_dst.astype(np.int64)],
            add_s * L + labels_new[add_d],
            rl_in_src * L + rl_in_old,
            rl_in_src * L + rl_in_new,
        ]))
        patched_entries = {}
        sharded_items = []
        for key, hit in self._vm_pack_cache.items():
            if key == "_default_cnt":
                continue
            if isinstance(key, tuple) and key and key[0] == "sharded":
                sharded_items.append((key, hit))
                continue
            cached_cnt, entry = hit
            patchable = (
                cnt_new is not None
                and rev_new is not None
                and (rev_new >= 0 if m_new else np.ones(0, bool)).all()
                and (cached_cnt is cnt_old
                     or np.array_equal(cached_cnt, cnt_old))
            )
            if patchable:
                patched_entries[key] = (cnt_new, self._patch_vm_entry(
                    key, entry, src_new, dst_new, row_ptr_new, labels_final,
                    cnt_new, rev_new, n_new, changed_dsts, changed_pairs))
            # non-patchable entries (custom cnt, asymmetric graph) are
            # evicted and rebuilt lazily on next use

        # ---- commit ------------------------------------------------------
        self.n = n_new
        self.labels = labels_final
        self.src = src_new
        self.dst = dst_new
        self.row_ptr = row_ptr_new
        self._rev_index = rev_new
        self._vm_pack_cache = patched_entries
        if cnt_new is not None:
            self._vm_pack_cache["_default_cnt"] = cnt_new
        self.version += 1

        # ---- patch cached sharded packings (dirty shards only) -----------
        sharded_patchable = (
            cnt_new is not None
            and rev_new is not None
            and bool((rev_new >= 0).all() if m_new else True)
        )
        if sharded_items:
            from repro.graphs.sharded_packing import patch_sharded_vm_packing

            for key, (cached_cnt, entry) in sharded_items:
                ok = (
                    sharded_patchable
                    and (cached_cnt is cnt_old
                         or np.array_equal(cached_cnt, cnt_old))
                    and patch_sharded_vm_packing(
                        entry, self, cnt_new, changed_dsts, changed_pairs,
                        n_old, old2new)
                )
                if ok:
                    self._vm_pack_cache[key] = (cnt_new, entry)
                # capacity overflow / custom cnt: entry stays evicted and is
                # rebuilt from scratch on next vm_packing_sharded call

        applied = AppliedMutation(
            version=self.version,
            n_before=n_old,
            n_after=n_new,
            added_src=add_s.astype(np.int32),
            added_dst=add_d.astype(np.int32),
            removed_src=removed_src,
            removed_dst=removed_dst,
            old2new=old2new,
            new_edge_pos=new_pos_added,
            relabel_v=rl_v.copy(),
            relabel_old=rl_old_lab,
            relabel_new=rl_new_lab,
        )
        self._mutation_log.append(applied)
        while len(self._mutation_log) > self.MUTATION_LOG_LIMIT:
            # ring compaction: instead of dropping the oldest record (which
            # would strand slow consumers on a rebuild), compose the two
            # oldest into one wider-span record — old2new maps compose
            # eagerly, so a consumer at the span's base still patches
            self._mutation_log[:2] = [
                compose_mutations(self._mutation_log[0],
                                  self._mutation_log[1])]
        return applied

    def _patch_vm_entry(self, key, entry, src_new, dst_new, row_ptr_new,
                        labels_new, cnt_new, rev_new, n_new,
                        changed_dsts, changed_pairs):
        """Merge-patch one cached ``vm_packing`` entry.

        Exploits symmetry: the dst-sorted edge view that ``pack_edges``
        builds is exactly the swapped raw arrays (the j-th ``(dst, src)``
        pair in sorted order is the j-th raw ``(src, dst)`` pair with roles
        exchanged), and its sort permutation is the reverse-edge involution.
        Only dst-blocks containing a mutated endpoint are re-packed; the
        rest are copied slice-wise, with ``inv_cnt`` refreshed for slots
        whose ``(src, dst-label)`` count changed.
        """
        import jax.numpy as jnp

        bn, be = key
        packed_old, dst_label_old, inv_cnt_old, _ = entry
        nb_old = packed_old.n_blocks_out
        nb_new = (n_new + bn - 1) // bn

        aff = np.unique(np.concatenate([
            changed_dsts // bn, np.arange(nb_old, nb_new, dtype=np.int64)]))
        aff = aff[aff < nb_new]
        aff_mask = np.zeros(nb_new, dtype=bool)
        aff_mask[aff] = True

        old_eb = np.bincount(packed_old.meta[:, 0], minlength=nb_old)
        new_eb = np.zeros(nb_new, dtype=np.int64)
        new_eb[:min(nb_old, nb_new)] = old_eb[:min(nb_old, nb_new)]
        # per-block real edge counts from the new CSR (in-deg == out-deg)
        v_hi = np.minimum((aff + 1) * bn, n_new)
        blk_cnt = row_ptr_new[v_hi] - row_ptr_new[np.minimum(aff * bn, n_new)]
        new_eb[aff] = np.maximum(1, -(-blk_cnt // be))
        old_off = np.concatenate([[0], np.cumsum(old_eb)]) * be
        new_off = np.concatenate([[0], np.cumsum(new_eb)]) * be
        e_pad = int(new_off[-1])

        src_p = np.zeros(e_pad, dtype=np.int32)
        dloc_p = np.zeros(e_pad, dtype=np.int32)
        mask_p = np.zeros(e_pad, dtype=bool)
        dlab_p = np.zeros(e_pad, dtype=np.int32)
        inv_p = np.zeros(e_pad, dtype=np.float32)

        o_src = np.asarray(packed_old.src)
        o_dloc = np.asarray(packed_old.dst_local)
        o_mask = np.asarray(packed_old.pad_mask)
        o_dlab = np.asarray(dst_label_old)
        o_inv = np.asarray(inv_cnt_old)

        # copy runs of unaffected blocks wholesale
        b = 0
        while b < min(nb_old, nb_new):
            if aff_mask[b]:
                b += 1
                continue
            e = b
            while e < min(nb_old, nb_new) and not aff_mask[e]:
                e += 1
            slo, shi = int(old_off[b]), int(old_off[e])
            dlo = int(new_off[b])
            span = shi - slo
            src_p[dlo:dlo + span] = o_src[slo:shi]
            dloc_p[dlo:dlo + span] = o_dloc[slo:shi]
            mask_p[dlo:dlo + span] = o_mask[slo:shi]
            dlab_p[dlo:dlo + span] = o_dlab[slo:shi]
            inv_p[dlo:dlo + span] = o_inv[slo:shi]
            b = e

        # rebuild affected blocks from the swapped raw arrays
        for blk in aff.tolist():
            vlo, vhi_b = blk * bn, min((blk + 1) * bn, n_new)
            lo, hi = int(row_ptr_new[vlo]), int(row_ptr_new[vhi_b])
            c = hi - lo
            o = int(new_off[blk])
            if c:
                src_p[o:o + c] = dst_new[lo:hi]
                dloc_p[o:o + c] = src_new[lo:hi] - vlo
                mask_p[o:o + c] = True
                dlab_p[o:o + c] = labels_new[src_new[lo:hi]]
                inv_p[o:o + c] = 1.0 / np.maximum(
                    cnt_new[dst_new[lo:hi], labels_new[src_new[lo:hi]]], 1.0)

        # refresh inv_cnt where the (src, dst-label) count changed
        if changed_pairs.size:
            slot_keys = src_p.astype(np.int64) * self.n_labels + dlab_p
            upd = mask_p & np.isin(slot_keys, changed_pairs)
            if upd.any():
                inv_p[upd] = 1.0 / np.maximum(
                    cnt_new[src_p[upd], dlab_p[upd]], 1.0)

        meta = np.zeros((int(new_eb.sum()), 2), dtype=np.int32)
        meta[:, 0] = np.repeat(
            np.arange(nb_new, dtype=np.int64), new_eb).astype(np.int32)
        firsts = np.concatenate([[0], np.cumsum(new_eb)[:-1]])
        meta[firsts, 1] = 1

        from repro.kernels.segment_spmm.ops import PackedEdges

        packed_new = PackedEdges(
            src=src_p, dst_local=dloc_p, meta=meta, pad_mask=mask_p,
            order=rev_new, n_blocks_out=int(nb_new), block_n=bn, block_e=be)
        dst_global = (np.repeat(meta[:, 0], be) * bn + dloc_p).astype(np.int32)
        return (packed_new, jnp.asarray(dlab_p), jnp.asarray(inv_p),
                dst_global)

    def subgraph_mask(self, vmask: np.ndarray) -> "LabelledGraph":
        """Induced subgraph on the vertices where ``vmask`` is True.

        Vertex ids are compacted; returns the subgraph (labels preserved).
        """
        idx = np.nonzero(vmask)[0]
        remap = -np.ones(self.n, dtype=np.int64)
        remap[idx] = np.arange(idx.size)
        emask = vmask[self.src] & vmask[self.dst]
        s, d = remap[self.src[emask]], remap[self.dst[emask]]
        return LabelledGraph(
            n=int(idx.size),
            labels=self.labels[idx],
            label_names=self.label_names,
            src=s.astype(np.int32),
            dst=d.astype(np.int32),
        )

    def validate(self) -> None:
        assert self.labels.shape == (self.n,)
        assert self.src.shape == self.dst.shape
        assert self.row_ptr.shape == (self.n + 1,)
        assert self.row_ptr[-1] == self.m
        if self.m:
            assert self.src.min() >= 0 and self.src.max() < self.n
            assert self.dst.min() >= 0 and self.dst.max() < self.n
        assert self.labels.min(initial=0) >= 0
        assert self.labels.max(initial=0) < self.n_labels

    def stats(self) -> Dict[str, float]:
        deg = self.degrees
        return {
            "n": self.n,
            "m_undirected": self.undirected_edge_count(),
            "n_labels": self.n_labels,
            "avg_degree": float(deg.mean()) if self.n else 0.0,
            "max_degree": int(deg.max()) if self.n else 0,
        }
