"""Synthetic labelled-graph generators.

Two schema-constrained generators mirror the paper's test datasets:

* ``musicbrainz_like`` — 12 vertex labels, skewed sizes/degrees (paper §6.1.1
  uses a ~10M vertex MusicBrainz subset; we scale by parameter).
* ``provgen_like`` — PROV-DM graphs (Entity/Activity/Agent) following the
  ProvGen topological constraints (paper [6], §6.1.1).

Plus ``paper_example_graph`` — the exact 6-vertex graph of the paper's Fig. 1,
reconstructed from the worked examples in §4.2 and §5.4 (it reproduces every
number in those sections; see tests/test_visitor_oracle.py).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graphs.graph import LabelledGraph

# ---------------------------------------------------------------------------
# Paper Fig. 1 example
# ---------------------------------------------------------------------------

#: labels of vertices 1..6 (0-indexed as 0..5)
_PAPER_LABELS = ["a", "b", "c", "d"]


def paper_example_graph() -> LabelledGraph:
    """The graph of the paper's Fig. 1 (vertex ids shifted to 0-base).

    Vertices (paper id: label): 1:a 2:b 3:c 4:d 5:c 6:a.
    Undirected edges: 1-2, 2-3, 2-4, 2-5, 3-4, 3-5, 3-6, 4-5.

    Derivation from the text: query ``c.(b|d)`` evaluates to paths
    (3,2),(3,4),(5,2),(5,4) (§1); vertex 2 has neighbours {1,3,4,5} (§4.2);
    vertex 3 has local neighbours {5,6} and external {2,4} w.r.t. partition
    B = {3,5,6} (§5.4); vertices 5 and 6 each have exactly one c-labelled
    neighbour, vertex 3 (probabilities in §5.2.1/§5.4).
    """
    labels = [0, 1, 2, 3, 2, 0]  # a b c d c a
    edges = np.array(
        [(0, 1), (1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (2, 5), (3, 4)],
        dtype=np.int64,
    )
    return LabelledGraph.from_undirected_edges(6, labels, edges, list(_PAPER_LABELS))


def paper_example_partition() -> np.ndarray:
    """Partitioning used by §5.2.1/§5.4: A = {1,2,4}, B = {3,5,6} (1-based)."""
    return np.array([0, 0, 1, 0, 1, 1], dtype=np.int32)


# ---------------------------------------------------------------------------
# Schema-constrained generators
# ---------------------------------------------------------------------------


def _zipf_pick(rng: np.random.Generator, n: int, size: int, skew: float) -> np.ndarray:
    """Pick ``size`` vertex ranks in [0, n) with zipf-ish skew (0 = uniform)."""
    if n <= 0:
        raise ValueError("empty label class")
    u = rng.random(size)
    idx = np.floor(n * u ** (1.0 + skew)).astype(np.int64)
    return np.minimum(idx, n - 1)


def schema_graph(
    n: int,
    label_names: Sequence[str],
    label_props: Sequence[float],
    edge_schema: Sequence[Tuple[str, str, float]],
    avg_degree: float = 6.0,
    skew: float = 1.5,
    seed: int = 0,
    n_communities: Optional[int] = None,
    p_intra: float = 0.9,
) -> LabelledGraph:
    """Random heterogeneous graph over a label schema, with latent
    community structure.

    Real heterogeneous graphs (MusicBrainz, provenance) exhibit strong
    locality — an artist's credits/tracks/mediums cluster together, a
    provenance chain is a narrow DAG.  We model that with latent
    communities: each vertex belongs to one of ``n_communities`` blocks and
    an edge endpoint is drawn from the *same* block with probability
    ``p_intra`` (else globally).  Without this, the generator produces
    expander-like graphs that no partitioner (Metis included) can usefully
    split, which matches neither the paper's datasets nor its results.

    Args:
      n: vertex count.
      label_props: relative vertex proportions per label.
      edge_schema: (label_u, label_v, relative weight[, layer]) allowed edge
        types.  ``layer`` (default 0) selects which of two *independent*
        latent community assignments the edge type clusters by — relation
        groups in real data cluster along different axes (e.g. musical
        collaboration vs. web-link structure), which is exactly what gives a
        workload-aware partitioner headroom over min-edge-cut.
      avg_degree: target average (undirected) degree.
      skew: preferential-attachment skew (>0 = power-law-ish endpoints).
      n_communities: latent blocks (default: ~n/250, at least 8).
      p_intra: probability an edge stays within its block.
    """
    rng = np.random.default_rng(seed)
    props = np.asarray(label_props, dtype=np.float64)
    props = props / props.sum()
    counts = np.maximum(1, np.round(props * n).astype(np.int64))
    # adjust to sum exactly n
    counts[np.argmax(counts)] += n - counts.sum()
    name_to_id = {s: i for i, s in enumerate(label_names)}
    n_comm = n_communities or max(8, n // 250)
    n_layers = 1 + max((e[3] if len(e) > 3 else 0) for e in edge_schema)

    labels = np.repeat(np.arange(len(label_names), dtype=np.int32), counts)
    # vertex ids grouped by label; offsets per label
    offsets = np.concatenate([[0], np.cumsum(counts)])
    # latent communities per vertex and layer: within each label class,
    # vertices are striped over communities (layer 0) and independently
    # permuted per extra layer, so every (label, layer, community) cell is
    # non-empty and the layers are decorrelated
    comm = np.empty((n_layers, n), dtype=np.int64)
    for li in range(len(label_names)):
        lo, hi = offsets[li], offsets[li + 1]
        stripes = (np.arange(hi - lo) * n_comm) // max(hi - lo, 1)
        comm[0, lo:hi] = stripes
        for layer in range(1, n_layers):
            comm[layer, lo:hi] = stripes[rng.permutation(hi - lo)]
    # index vertices per (label, layer, community)
    cell_members = {}
    for li in range(len(label_names)):
        lo, hi = offsets[li], offsets[li + 1]
        for layer in range(n_layers):
            for c in range(n_comm):
                sel = lo + np.nonzero(comm[layer, lo:hi] == c)[0]
                if sel.size:
                    cell_members[(li, layer, c)] = sel

    target_edges = int(n * avg_degree / 2)
    weights = np.asarray([e[2] for e in edge_schema], dtype=np.float64)
    weights = weights / weights.sum()
    per_type = np.maximum(1, np.round(weights * target_edges).astype(np.int64))

    chunks = []
    for etype, cnt in zip(edge_schema, per_type):
        lu, lv = etype[0], etype[1]
        layer = etype[3] if len(etype) > 3 else 0
        iu, iv = name_to_id[lu], name_to_id[lv]
        cnt = int(cnt)
        us = offsets[iu] + _zipf_pick(rng, counts[iu], cnt, skew)
        # intra-community endpoints with probability p_intra (vectorised by
        # grouping the intra edges per source community)
        intra = rng.random(cnt) < p_intra
        vs = offsets[iv] + _zipf_pick(rng, counts[iv], cnt, skew)
        uc = comm[layer, us]
        intra_idx = np.nonzero(intra)[0]
        if intra_idx.size:
            order = np.argsort(uc[intra_idx], kind="stable")
            sorted_idx = intra_idx[order]
            sorted_comm = uc[sorted_idx]
            bounds = np.nonzero(np.diff(sorted_comm))[0] + 1
            for grp in np.split(sorted_idx, bounds):
                cell = cell_members.get((iv, layer, int(uc[grp[0]])))
                if cell is not None:
                    vs[grp] = cell[_zipf_pick(rng, cell.size, grp.size, skew)]
        chunks.append(np.stack([us, vs], axis=1))
    edges = np.concatenate(chunks, axis=0)
    g = LabelledGraph.from_undirected_edges(n, labels, edges, list(label_names))
    g.validate()
    return g


MUSICBRAINZ_LABELS = [
    "Area", "Artist", "Label", "Credit", "Track", "Recording",
    "Medium", "Release", "Work", "Place", "Genre", "Url",
]

_MB_PROPS = [0.01, 0.12, 0.02, 0.18, 0.28, 0.20, 0.05, 0.07, 0.04, 0.01, 0.005, 0.015]

_MB_SCHEMA = [
    # core music-collaboration relations (clustered by release group): layer 0
    ("Artist", "Area", 1.0, 0),
    ("Artist", "Credit", 4.0, 0),
    ("Credit", "Track", 5.0, 0),
    ("Credit", "Recording", 4.0, 0),
    ("Track", "Medium", 3.0, 0),
    ("Medium", "Release", 1.0, 0),
    ("Release", "Label", 0.8, 0),
    ("Label", "Area", 0.3, 0),
    ("Recording", "Work", 1.0, 0),
    # auxiliary relations clustered along an independent axis (web links,
    # taxonomies, geography): layer 1 — volume the unweighted min-cut
    # objective must serve, but MQ1-MQ3 never traverse
    ("Artist", "Url", 1.2, 1),
    ("Artist", "Genre", 1.5, 1),
    ("Place", "Area", 0.6, 1),
    ("Artist", "Place", 0.7, 1),
    ("Url", "Url", 1.0, 1),
    ("Genre", "Genre", 0.5, 1),
]


def musicbrainz_like(n: int = 20_000, avg_degree: float = 6.0, seed: int = 0) -> LabelledGraph:
    """Heterogeneous music-metadata graph (12 labels), paper §6.1.1 analogue."""
    return schema_graph(
        n, MUSICBRAINZ_LABELS, _MB_PROPS, _MB_SCHEMA,
        avg_degree=avg_degree, skew=1.5, seed=seed,
    )


PROV_LABELS = ["Entity", "Activity", "Agent"]

_PROV_SCHEMA = [
    # data-flow relations (clustered by workflow run): layer 0
    ("Entity", "Entity", 3.0, 0),      # wasDerivedFrom
    ("Entity", "Activity", 3.0, 0),    # wasGeneratedBy / used
    ("Activity", "Agent", 1.0, 0),     # wasAssociatedWith
    ("Entity", "Agent", 0.7, 0),       # wasAttributedTo
    # control-flow / organisational relations clustered independently
    # (scheduler batches, org charts): layer 1 — not traversed by PQ1-PQ4
    ("Activity", "Activity", 2.2, 1),  # wasInformedBy
    ("Agent", "Agent", 0.8, 1),        # actedOnBehalfOf
]


def provgen_like(n: int = 20_000, avg_degree: float = 6.0, seed: int = 0) -> LabelledGraph:
    """PROV-DM provenance graph (3 labels), ProvGen analogue (paper §6.1.1)."""
    return schema_graph(
        n, PROV_LABELS, [0.6, 0.3, 0.1], _PROV_SCHEMA,
        avg_degree=avg_degree, skew=1.2, seed=seed,
    )


def power_law_labelled(
    n: int, n_labels: int = 4, avg_degree: float = 8.0, skew: float = 1.0, seed: int = 0
) -> LabelledGraph:
    """Unstructured labelled graph (any label pair allowed) for property tests."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_labels, size=n).astype(np.int32)
    m = int(n * avg_degree / 2)
    us = _zipf_pick(rng, n, m, skew)
    vs = rng.integers(0, n, size=m)
    g = LabelledGraph.from_undirected_edges(
        n, labels, np.stack([us, vs], axis=1), [f"L{i}" for i in range(n_labels)]
    )
    g.validate()
    return g
