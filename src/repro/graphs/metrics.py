"""Partitioning quality metrics (workload-agnostic ones; ipt lives in
repro.workload.executor since it needs query execution)."""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import LabelledGraph


def edge_cut(g: LabelledGraph, part: np.ndarray, directed: bool = False) -> int:
    """Number of edges crossing partitions.

    ``directed=True`` counts cut *arcs* — every stored directed edge whose
    endpoints differ.  ``directed=False`` (default) counts each undirected
    pair once; arcs without a stored reverse still count once each (the old
    implementation's blanket ``// 2`` silently halved those).
    """
    cut = part[g.src] != part[g.dst]
    if directed:
        return int(cut.sum())
    # count each symmetric pair at its (src < dst) arc; one-directional
    # arcs (no stored reverse) are their own representative
    once = (g.src < g.dst) | (g.reverse_edge_index < 0)
    return int((cut & once).sum())


def partition_sizes(part: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(part, minlength=k)


def partition_balance(part: np.ndarray, k: int) -> float:
    """max partition size / ideal size; 1.0 = perfectly balanced."""
    sizes = partition_sizes(part, k)
    ideal = part.shape[0] / k
    return float(sizes.max() / ideal) if ideal > 0 else 1.0
