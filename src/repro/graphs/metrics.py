"""Partitioning quality metrics (workload-agnostic ones; ipt lives in
repro.workload.executor since it needs query execution)."""
from __future__ import annotations

import numpy as np

from repro.graphs.graph import LabelledGraph


def edge_cut(g: LabelledGraph, part: np.ndarray) -> int:
    """Number of undirected edges crossing partitions."""
    cut = part[g.src] != part[g.dst]
    return int(cut.sum() // 2)


def partition_sizes(part: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(part, minlength=k)


def partition_balance(part: np.ndarray, k: int) -> float:
    """max partition size / ideal size; 1.0 = perfectly balanced."""
    sizes = partition_sizes(part, k)
    ideal = part.shape[0] / k
    return float(sizes.max() / ideal) if ideal > 0 else 1.0
