"""FlashAttention TPU kernel (Dao et al. arXiv:2205.14135, TPU-adapted).

Blocked online softmax: grid = (B*H, n_q_blocks, n_kv_blocks) with the KV
axis innermost.  Running max / denominator / accumulator live in VMEM
scratch carried across KV grid steps; the output block is written on the
last KV step.  Causal + sliding-window masks are applied per block, and
blocks that are fully masked (above the causal diagonal or outside the
window) are skipped via pl.when.

BlockSpecs keep one (block_q, d) Q tile and one (block_k, d) KV tile in VMEM
per step — d is the full head dim (MXU-aligned when d in {64, 128, 256}).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, block_q: int, block_k: int, n_kv: int,
                 causal: bool, window: Optional[int], seq_kv: int):
    q_i = pl.program_id(1)
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = q_i * block_q
    kv_start = kv_i * block_k
    run = jnp.asarray(True)
    if causal:
        run = run & (kv_start <= q_start + block_q - 1)
    if window is not None:
        run = run & (kv_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (block_q, d)
        k = k_ref[0].astype(jnp.float32)            # (block_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (block_q, block_k)
        q_pos = q_start + jax.lax.iota(jnp.int32, block_q)
        kv_pos = kv_start + jax.lax.iota(jnp.int32, block_k)
        mask = kv_pos[None, :] < seq_kv
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        # fully-masked rows (m_new == NEG_INF) contribute nothing
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(kv_i == n_kv - 1)
    def _flush():
        o_ref[0, ...] = (acc_scr[...] /
                         jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jnp.ndarray,           # (BH, Sq, D)
    k: jnp.ndarray,           # (BH, Skv, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    n_q = math.ceil(Sq / block_q)
    n_kv = math.ceil(Skv / block_k)
    pad_q = n_q * block_q - Sq
    pad_k = n_kv * block_k - Skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=1.0 / math.sqrt(D), block_q=block_q,
        block_k=block_k, n_kv=n_kv, causal=causal, window=window, seq_kv=Skv)
    out = pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, n_q * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq, :]
