"""jit'd wrapper: GQA layout handling + XLA fallback.

``flash_attention(q, k, v)`` takes (B, S, H, D) / (B, S, KV, D) (the model's
layout), expands GQA groups, and dispatches to the Pallas kernel (TPU) or
the blocked-scan XLA path (CPU / fallback).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "window", "block_q", "block_k",
                                   "interpret", "use_pallas"))
def flash_attention(
    q: jnp.ndarray,            # (B, Sq, H, D)
    k: jnp.ndarray,            # (B, Skv, KV, D)
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
    use_pallas: bool = True,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    if not use_pallas:
        from repro.models.layers import attention

        return attention(q, k, v, causal=causal, window=window)
    # expand KV heads to full head count, flatten (B, H) into the grid axis
    k_full = jnp.repeat(k, G, axis=2)
    v_full = jnp.repeat(v, G, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k_full.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    vf = v_full.transpose(0, 2, 1, 3).reshape(B * H, Skv, D)
    out = flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
