"""Pure-jnp oracle for flash attention (naive full score matrix)."""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp
import jax


def attention_reference(
    q: jnp.ndarray,          # (B, Sq, H, D)
    k: jnp.ndarray,          # (B, Skv, H, D)  (same head count; GQA handled by caller)
    v: jnp.ndarray,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Skv = k.shape[1]
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    kv_pos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= kv_pos <= q_pos
    if window is not None:
        mask &= kv_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce 0 (matches the kernel's convention)
    any_valid = mask.any(axis=-1)
    p = p * any_valid[None, None, :, None]
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
