from repro.kernels.segment_spmm.ops import segment_spmm
from repro.kernels.segment_spmm.ref import segment_spmm_reference

__all__ = ["segment_spmm", "segment_spmm_reference"]
