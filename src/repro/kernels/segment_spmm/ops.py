"""Wrapper: CSR packing (host-side, cached) + pallas_call + XLA fallback.

``pack_edges`` sorts edges by destination and pads each destination block's
edge list to a multiple of ``block_e``, so every edge block belongs to
exactly one output block (the kernel's scalar-prefetch contract).  Padding
edges carry weight 0 and scatter to row 0 of their block (a no-op).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_spmm.kernel import segment_spmm_packed
from repro.kernels.segment_spmm.ref import segment_spmm_reference


@dataclass
class PackedEdges:
    src: np.ndarray          # (E_pad,)
    dst_local: np.ndarray    # (E_pad,)
    meta: np.ndarray         # (EB, 2) [dst_block_id, is_first]
    pad_mask: np.ndarray     # (E_pad,) True on real edges
    order: np.ndarray        # (E,) stable argsort of edge_dst: raw -> packed order
    n_blocks_out: int
    block_n: int
    block_e: int


def pack_edges(edge_src: np.ndarray, edge_dst: np.ndarray, n: int,
               block_n: int = 128, block_e: int = 256) -> PackedEdges:
    edge_src = np.asarray(edge_src)
    edge_dst = np.asarray(edge_dst)
    order = np.argsort(edge_dst, kind="stable")
    src_s, dst_s = edge_src[order], edge_dst[order]
    n_blocks_out = (n + block_n - 1) // block_n
    blk = dst_s // block_n

    src_chunks, dstloc_chunks, mask_chunks, meta = [], [], [], []
    for b in range(n_blocks_out):
        sel = blk == b
        cnt = int(sel.sum())
        n_eb = max(1, (cnt + block_e - 1) // block_e)
        pad = n_eb * block_e - cnt
        src_chunks.append(np.concatenate([src_s[sel], np.zeros(pad, src_s.dtype)]))
        dstloc_chunks.append(np.concatenate(
            [dst_s[sel] - b * block_n, np.zeros(pad, dst_s.dtype)]))
        mask_chunks.append(np.concatenate(
            [np.ones(cnt, bool), np.zeros(pad, bool)]))
        for j in range(n_eb):
            meta.append((b, 1 if j == 0 else 0))
    return PackedEdges(
        src=np.concatenate(src_chunks).astype(np.int32),
        dst_local=np.concatenate(dstloc_chunks).astype(np.int32),
        meta=np.asarray(meta, np.int32),
        pad_mask=np.concatenate(mask_chunks),
        order=order,
        n_blocks_out=n_blocks_out,
        block_n=block_n,
        block_e=block_e,
    )


def segment_spmm(
    x: jnp.ndarray,
    packed: PackedEdges,
    edge_w: jnp.ndarray,       # (E_pad,) weights aligned with packed order
    n_out: int,
    interpret: bool = True,
    use_pallas: bool = True,
    block_f: int = 0,
) -> jnp.ndarray:
    """Compute out[dst] += w_e * x[src] over packed edges; returns (n_out, F)."""
    if not use_pallas:
        # reconstruct global destinations from the packing
        dst_block = np.repeat(packed.meta[:, 0], packed.block_e)
        dst_global = jnp.asarray(dst_block * packed.block_n) + jnp.asarray(
            packed.dst_local)
        return segment_spmm_reference(
            x, jnp.asarray(packed.src), dst_global, edge_w, n_out)
    out = segment_spmm_packed(
        x,
        jnp.asarray(packed.src),
        jnp.asarray(packed.dst_local),
        edge_w,
        jnp.asarray(packed.meta),
        packed.n_blocks_out,
        packed.block_n,
        packed.block_e,
        block_f=block_f,
        interpret=interpret,
    )
    return out[:n_out]


def pack_weights(packed: PackedEdges, edge_w) -> jnp.ndarray:
    """Reorder raw per-edge weights into packed order (0 on padding).

    ``edge_w`` must align with the raw edge list the packing was built from;
    the dst-sort order recorded at pack time is applied directly.
    """
    w_sorted = np.asarray(edge_w)[packed.order]
    out = np.zeros(packed.src.shape[0], w_sorted.dtype)
    out[packed.pad_mask] = w_sorted
    return jnp.asarray(out)
