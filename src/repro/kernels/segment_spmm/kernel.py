"""Gather-scale-scatter SpMM TPU kernel (GNN message passing).

TPU adaptation of the GE-SpMM/FusedMM regime (taxonomy §B.3): edges are
pre-sorted by destination and padded so each edge block maps to exactly ONE
destination-node block (ops.py does the packing).  The grid runs over edge
blocks with a scalar-prefetched per-block destination-block index — the
output BlockSpec's index_map reads it, so consecutive edge blocks revisit
the same output VMEM tile and accumulate in place.

The scatter itself is a one-hot matmul: onehot(local_dst)^T @ msgs is a
(block_n x block_e) @ (block_e x F) MXU contraction — systolic-friendly,
no per-row scatter.  Gather of source rows uses in-VMEM dynamic indexing
(x tiles are resident; for graphs whose feature matrix exceeds VMEM the
feature dim F is tiled by the grid's second axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _spmm_kernel(meta_ref,  # scalar prefetch: (EB, 2) [dst_block, is_first]
                 src_ref, dstloc_ref, w_ref, x_ref, o_ref, *,
                 block_n: int, block_e: int):
    e_i = pl.program_id(0)
    is_first = meta_ref[e_i, 1]

    @pl.when(is_first == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    src = src_ref[...]                       # (block_e,)
    dst_loc = dstloc_ref[...]                # (block_e,) in [0, block_n)
    w = w_ref[...]                           # 0 on padded edges
    msgs = x_ref[src] * w[:, None].astype(x_ref.dtype)        # (block_e, F_t)
    onehot = (dst_loc[None, :] == jax.lax.iota(jnp.int32, block_n)[:, None])
    contrib = jax.lax.dot_general(
        onehot.astype(msgs.dtype), msgs,
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] += contrib.astype(o_ref.dtype)


def segment_spmm_packed(
    x: jnp.ndarray,            # (n, F)
    src: jnp.ndarray,          # (E_pad,) packed/sorted source ids
    dst_local: jnp.ndarray,    # (E_pad,) destination offset within its block
    w: jnp.ndarray,            # (E_pad,) weights, 0 on padding
    meta: jnp.ndarray,         # (EB, 2) int32 [dst_block_id, is_first]
    n_blocks_out: int,
    block_n: int,
    block_e: int,
    block_f: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    E_pad = src.shape[0]
    n, F = x.shape
    EB = E_pad // block_e
    block_f = block_f or F
    FB = F // block_f
    kernel = functools.partial(_spmm_kernel, block_n=block_n, block_e=block_e)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(EB, FB),
        in_specs=[
            pl.BlockSpec((block_e,), lambda e, f, meta: (e,)),
            pl.BlockSpec((block_e,), lambda e, f, meta: (e,)),
            pl.BlockSpec((block_e,), lambda e, f, meta: (e,)),
            pl.BlockSpec((n, block_f), lambda e, f, meta: (0, f)),
        ],
        out_specs=pl.BlockSpec(
            (block_n, block_f), lambda e, f, meta: (meta[e, 0], f)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks_out * block_n, F), x.dtype),
        interpret=interpret,
    )(meta, src, dst_local, w, x)
