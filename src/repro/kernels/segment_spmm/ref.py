"""Pure-jnp oracle: edge-weighted gather-scatter SpMM.

out[dst] += w_e * x[src]  — the GNN message-passing primitive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_spmm_reference(
    x: jnp.ndarray,          # (n, F) node features
    edge_src: jnp.ndarray,   # (E,) int32
    edge_dst: jnp.ndarray,   # (E,) int32
    edge_w: jnp.ndarray,     # (E,) float
    n_out: int,
) -> jnp.ndarray:
    msgs = x[edge_src] * edge_w[:, None].astype(x.dtype)
    return jax.ops.segment_sum(msgs, edge_dst, num_segments=n_out)
