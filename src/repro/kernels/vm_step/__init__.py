from repro.kernels.vm_step.ops import vm_step
from repro.kernels.vm_step.ref import vm_step_reference

__all__ = ["vm_step", "vm_step_reference"]
