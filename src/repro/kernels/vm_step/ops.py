"""Wrapper: reuses segment_spmm's edge packing; adds label/inv-cnt channels."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.segment_spmm.ops import PackedEdges, pack_edges
from repro.kernels.vm_step.kernel import vm_step_packed
from repro.kernels.vm_step.ref import vm_step_reference


def pack_vm_inputs(edge_src, edge_dst, labels, cnt, n: int,
                   block_n: int = 128, block_e: int = 256):
    """Pack edges (sorted by dst) and per-edge label / 1/cnt channels."""
    packed = pack_edges(edge_src, edge_dst, n, block_n, block_e)
    order = packed.order  # pack_edges already sorted by dst; reuse its order
    dst_lab_sorted = np.asarray(labels)[np.asarray(edge_dst)[order]]
    src_sorted = np.asarray(edge_src)[order]
    inv = 1.0 / np.maximum(
        np.asarray(cnt)[src_sorted, dst_lab_sorted], 1.0)
    E_pad = packed.src.shape[0]
    dst_label = np.zeros(E_pad, np.int32)
    inv_cnt = np.zeros(E_pad, np.float32)
    dst_label[packed.pad_mask] = dst_lab_sorted
    inv_cnt[packed.pad_mask] = inv
    return packed, jnp.asarray(dst_label), jnp.asarray(inv_cnt)


def vm_step(
    alpha: jnp.ndarray,
    T: jnp.ndarray,
    packed: PackedEdges,
    dst_label: jnp.ndarray,
    inv_cnt: jnp.ndarray,
    n: int,
    interpret: bool = True,
    use_pallas: bool = True,
) -> jnp.ndarray:
    if not use_pallas:
        dst_block = np.repeat(packed.meta[:, 0], packed.block_e)
        dst_global = jnp.asarray(dst_block * packed.block_n) + jnp.asarray(
            packed.dst_local)
        return vm_step_reference(
            alpha, T, jnp.asarray(packed.src), dst_global, inv_cnt,
            dst_label, n)
    out = vm_step_packed(
        alpha, T,
        jnp.asarray(packed.src), jnp.asarray(packed.dst_local),
        dst_label, inv_cnt, jnp.asarray(packed.meta),
        packed.n_blocks_out, packed.block_n, packed.block_e,
        interpret=interpret,
    )
    return out[:n]
