"""TAPER Visitor-Matrix DP edge-propagation TPU kernel.

The paper's Alg. 1 hot loop, reformulated (DESIGN.md §2) as a label-masked
SpMM.  Same packing contract as segment_spmm (edges sorted by destination,
one destination block per edge block, scalar-prefetched output index), with
the per-edge trie transition fused in:

    per edge block: A   = alpha[src]              gather   (block_e, N)
                    M   = A x T[label(dst)]       batched tiny matmul
                    out += onehot(dst_local)^T M  MXU scatter

The trie transition tensor T (L x N x N, ~ 12x24x24 floats) lives wholly in
VMEM — the intensional workload summary is small by construction (paper §4),
which is what makes this kernel VMEM-friendly at any graph size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vm_kernel(meta_ref, src_ref, dstloc_ref, dstlab_ref, invcnt_ref,
               alpha_ref, T_ref, o_ref, *, block_n: int, block_e: int):
    e_i = pl.program_id(0)
    is_first = meta_ref[e_i, 1]

    @pl.when(is_first == 1)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    src = src_ref[...]                        # (block_e,)
    dst_loc = dstloc_ref[...]
    dst_lab = dstlab_ref[...]
    inv_cnt = invcnt_ref[...]                 # 0 on padded edges

    A = alpha_ref[src]                        # (block_e, N)
    Tsel = T_ref[dst_lab]                     # (block_e, N, N)
    M = jnp.einsum("en,enm->em", A, Tsel,
                   preferred_element_type=jnp.float32)
    M = M * inv_cnt[:, None]
    onehot = (dst_loc[None, :] == jax.lax.iota(jnp.int32, block_n)[:, None])
    contrib = jax.lax.dot_general(
        onehot.astype(M.dtype), M, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[...] += contrib.astype(o_ref.dtype)


def vm_step_packed(
    alpha: jnp.ndarray,        # (n, N)
    T: jnp.ndarray,            # (L, N, N)
    src: jnp.ndarray,          # (E_pad,)
    dst_local: jnp.ndarray,    # (E_pad,)
    dst_label: jnp.ndarray,    # (E_pad,)
    inv_cnt: jnp.ndarray,      # (E_pad,) 0 on padding
    meta: jnp.ndarray,         # (EB, 2)
    n_blocks_out: int,
    block_n: int,
    block_e: int,
    interpret: bool = True,
) -> jnp.ndarray:
    E_pad = src.shape[0]
    n, N = alpha.shape
    L = T.shape[0]
    EB = E_pad // block_e
    kernel = functools.partial(_vm_kernel, block_n=block_n, block_e=block_e)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(EB,),
        in_specs=[
            pl.BlockSpec((block_e,), lambda e, meta: (e,)),
            pl.BlockSpec((block_e,), lambda e, meta: (e,)),
            pl.BlockSpec((block_e,), lambda e, meta: (e,)),
            pl.BlockSpec((block_e,), lambda e, meta: (e,)),
            pl.BlockSpec((n, N), lambda e, meta: (0, 0)),
            pl.BlockSpec((L, N, N), lambda e, meta: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, N), lambda e, meta: (meta[e, 0], 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks_out * block_n, N), alpha.dtype),
        interpret=interpret,
    )(meta, src, dst_local, dst_label, inv_cnt, alpha, T)
