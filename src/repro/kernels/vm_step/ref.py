"""Pure-jnp oracle for one Visitor-Matrix DP edge-propagation step.

Given alpha (n, N_trie) and the per-destination-label trie transition
matrices T (L, N, N) with T[l][p, c] = cond_p(c) iff child(p, l) == c:

    alpha_out[w, :] = sum over local edges (u, w):
        (alpha[u] @ T[label(w)]) / cnt[u, label(w)]

This is exactly the depth-advancing update inside
repro.core.visitor._build_field_fn, expressed for ALL depths at once (the
transition matrix is depth-stratified so one matmul advances every state by
one step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def build_transition(trie_parent, trie_label, trie_cond_p, n_labels: int):
    """(L, N, N) transition tensor from TrieArrays fields."""
    import numpy as np

    N = len(trie_parent)
    T = np.zeros((n_labels, N, N), np.float32)
    for c in range(N):
        p, l = int(trie_parent[c]), int(trie_label[c])
        if p >= 0:
            T[l, p, c] = float(trie_cond_p[c])
    return T


def vm_step_reference(
    alpha: jnp.ndarray,       # (n, N)
    T: jnp.ndarray,           # (L, N, N)
    edge_src: jnp.ndarray,    # (E,)
    edge_dst: jnp.ndarray,    # (E,)
    inv_cnt_e: jnp.ndarray,   # (E,) 1 / cnt[src, label(dst)]
    dst_label: jnp.ndarray,   # (E,)
    n: int,
) -> jnp.ndarray:
    msgs = jnp.einsum("en,enm->em", alpha[edge_src], T[dst_label])
    msgs = msgs * inv_cnt_e[:, None]
    return jax.ops.segment_sum(msgs, edge_dst, num_segments=n)
