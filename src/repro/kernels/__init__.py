"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships:
  kernel.py — pl.pallas_call body with explicit BlockSpec VMEM tiling
  ops.py    — jit'd wrapper (+ preprocessing, + XLA fallback used on CPU)
  ref.py    — pure-jnp oracle the kernel is validated against

Kernels (DESIGN.md §6):
  vm_step         — TAPER's Visitor-Matrix DP edge propagation (the paper's
                    Alg. 1 hot loop as a label-masked SpMM)
  segment_spmm    — GNN message passing (gather-scale-scatter)
  flash_attention — LM prefill blocked online softmax
  embedding_bag   — DLRM multi-hot lookup as vocab-tiled one-hot matmul

All kernels are TPU-targeted and validated with ``interpret=True`` on CPU
(tests/test_kernels.py sweeps shapes/dtypes via hypothesis).
"""
