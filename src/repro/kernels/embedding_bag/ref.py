"""Pure-jnp oracle: multi-hot embedding bag (sum/mean combiner)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_reference(
    table: jnp.ndarray,       # (V, d)
    ids: jnp.ndarray,         # (B, H) int32
    combiner: str = "sum",
) -> jnp.ndarray:
    B, H = ids.shape
    rows = jnp.take(table, ids.reshape(-1), axis=0)
    seg = jnp.repeat(jnp.arange(B), H)
    out = jax.ops.segment_sum(rows, seg, num_segments=B)
    if combiner == "mean":
        out = out / H
    return out
