from repro.kernels.embedding_bag.ops import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_reference

__all__ = ["embedding_bag_pallas", "embedding_bag_reference"]
