"""Embedding-bag TPU kernel: vocab-tiled one-hot matmul (taxonomy §B.6).

TPU adaptation of FBGEMM's table-batched embedding: rather than random HBM
row gathers (latency-bound on TPU), the vocab is streamed through VMEM in
tiles and each (batch block, vocab tile) contributes

    out_block += count_matrix @ table_tile

where count_matrix[b, r] = #slots of bag b hitting row (tile_start + r) —
an MXU contraction.  Grid = (batch_blocks, vocab_tiles) with the vocab axis
innermost; accumulation revisits the output block across vocab tiles.
Efficient when bags are dense in the vocab (DLRM's zipf-hot rows); the
gather-based path (ref) remains for cold tables.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(ids_ref, table_ref, o_ref, *, block_b: int, block_v: int,
                n_hot: int, n_vt: int):
    v_i = pl.program_id(1)

    @pl.when(v_i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                        # (block_b, H)
    start = v_i * block_v
    rows = jax.lax.iota(jnp.int32, block_v) + start
    # count matrix: how many slots of each bag hit each row of this tile
    counts = (ids[:, :, None] == rows[None, None, :]).sum(axis=1)  # (B, V_t)
    contrib = jax.lax.dot_general(
        counts.astype(table_ref.dtype), table_ref[...],
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] += contrib.astype(o_ref.dtype)


def embedding_bag_tiled(
    table: jnp.ndarray,        # (V, d)
    ids: jnp.ndarray,          # (B, H)
    block_b: int = 128,
    block_v: int = 512,
    interpret: bool = True,
) -> jnp.ndarray:
    V, d = table.shape
    B, H = ids.shape
    block_b = min(block_b, B)
    block_v = min(block_v, V)
    nb = (B + block_b - 1) // block_b
    nv = (V + block_v - 1) // block_v
    pad_b = nb * block_b - B
    pad_v = nv * block_v - V
    if pad_b:
        ids = jnp.pad(ids, ((0, pad_b), (0, 0)), constant_values=-1)
    if pad_v:
        table = jnp.pad(table, ((0, pad_v), (0, 0)))

    kernel = functools.partial(_bag_kernel, block_b=block_b, block_v=block_v,
                               n_hot=H, n_vt=nv)
    out = pl.pallas_call(
        kernel,
        grid=(nb, nv),
        in_specs=[
            pl.BlockSpec((block_b, H), lambda b, v: (b, 0)),
            pl.BlockSpec((block_v, d), lambda b, v: (v, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda b, v: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((nb * block_b, d), table.dtype),
        interpret=interpret,
    )(ids, table)
    return out[:B]
