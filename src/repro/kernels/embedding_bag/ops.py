"""jit wrapper with combiner handling + XLA fallback."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_tiled
from repro.kernels.embedding_bag.ref import embedding_bag_reference


@partial(jax.jit, static_argnames=("combiner", "block_b", "block_v",
                                   "interpret", "use_pallas"))
def embedding_bag_pallas(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    combiner: str = "sum",
    block_b: int = 128,
    block_v: int = 512,
    interpret: bool = True,
    use_pallas: bool = True,
) -> jnp.ndarray:
    if not use_pallas:
        return embedding_bag_reference(table, ids, combiner)
    out = embedding_bag_tiled(table, ids, block_b, block_v, interpret)
    if combiner == "mean":
        out = out / ids.shape[1]
    return out
