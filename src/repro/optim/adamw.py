"""AdamW with global-norm clipping and optional low-precision state.

Optimizer state follows parameter sharding (ZeRO-3: the ``fsdp`` logical axis
on every parameter shards m/v too).  ``state_dtype="bfloat16"`` halves the
m/v footprint — at kimi-k2 scale (1T params) this is the difference between
fitting and not fitting a 512-chip pod slice (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

AdamWState = Dict  # {"m": tree, "v": tree, "step": scalar}


@dataclass(frozen=True)
class AdamW:
    learning_rate: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"

    def _lr(self, step):
        if callable(self.learning_rate):
            return self.learning_rate(step)
        return self.learning_rate

    def init(self, params) -> AdamWState:
        dt = jnp.bfloat16 if self.state_dtype == "bfloat16" else jnp.float32
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def state_logical_axes(self, param_logical) -> Dict:
        return {
            "m": param_logical,
            "v": param_logical,
            "step": (),
        }

    def update(self, params, grads, state: AdamWState):
        step = state["step"] + 1
        if self.clip_norm is not None:
            gnorm = jnp.sqrt(
                sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                    for g in jax.tree.leaves(grads))
            )
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

        lr = self._lr(step)
        bc1 = 1.0 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * self.b1 + g32 * (1 - self.b1)
            v32 = v.astype(jnp.float32) * self.b2 + g32 * g32 * (1 - self.b2)
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return p_new.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}
