from repro.optim.adamw import AdamW, AdamWState
from repro.optim.schedule import cosine_schedule

__all__ = ["AdamW", "AdamWState", "cosine_schedule"]
