"""DLRM (Naumov et al., arXiv:1906.00091) — recsys kernel regime.

Bottom MLP over dense features, 26 embedding tables (the hot path: JAX has
no native EmbeddingBag, so it is built here from ``jnp.take`` +
``jax.ops.segment_sum`` per the assignment), dot-product feature
interaction, top MLP -> click logit.

Sharding: tables are concatenated row-wise into one (total_rows, d) matrix
sharded over the ``model`` axis ("rows"); lookups under pjit become
all-gather/all-to-all of the requested rows.  ``retrieval_cand`` scores one
query against 10^6 candidates as a single sharded matmul + top-k.

TAPER integration (DESIGN.md §4.2): ``plan_row_placement`` builds the
co-access graph of embedding rows from a click log and runs TAPER on it;
``query_span`` measures the shards-touched-per-request metric the placement
optimises.  benchmarks/dlrm_span.py reports the reduction.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import DLRMConfig
from repro.models.gnn.common import mlp_apply, mlp_init


# ---------------------------------------------------------------------------
# embedding bag (jnp.take + segment_sum — built in-repo, per the assignment)
# ---------------------------------------------------------------------------


def embedding_bag(
    table: jnp.ndarray,         # (rows, d)
    ids: jnp.ndarray,           # (B, n_per_bag) int32 — global row ids
    weights: Optional[jnp.ndarray] = None,
    combiner: str = "sum",
) -> jnp.ndarray:
    """Multi-hot gather-reduce; the Pallas kernel in
    repro.kernels.embedding_bag is the TPU-optimised twin of this oracle."""
    B, n = ids.shape
    rows = jnp.take(table, ids.reshape(-1), axis=0)          # (B*n, d)
    if weights is not None:
        rows = rows * weights.reshape(-1, 1)
    seg = jnp.repeat(jnp.arange(B), n)
    out = jax.ops.segment_sum(rows, seg, num_segments=B)
    if combiner == "mean":
        out = out / n
    return out


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def table_offsets(cfg: DLRMConfig) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(cfg.vocab_sizes)]).astype(np.int64)


def init(rng, cfg: DLRMConfig) -> Tuple[Dict, Dict]:
    keys = jax.random.split(rng, 4)
    total = cfg.total_rows()
    emb = jax.random.normal(keys[0], (total, cfg.embed_dim), jnp.float32)
    emb = emb / math.sqrt(cfg.embed_dim)
    bot, bot_log = mlp_init(keys[1], (cfg.n_dense,) + cfg.bot_mlp)
    n_feat = cfg.n_sparse + 1
    inter = n_feat * (n_feat - 1) // 2 if cfg.interaction == "dot" else 0
    top, top_log = mlp_init(keys[2], (inter + cfg.bot_mlp[-1],) + cfg.top_mlp)
    params = {"embedding": emb, "bot": bot, "top": top}
    logical = {"embedding": ("rows", None), "bot": bot_log, "top": top_log}
    return params, logical


def forward(params, batch: Dict, cfg: DLRMConfig) -> jnp.ndarray:
    """batch: dense (B, n_dense) float; sparse (B, n_sparse[, multi_hot])
    int32 with *global* row ids (offsets already applied)."""
    dense, sparse = batch["dense"], batch["sparse"]
    B = dense.shape[0]
    x_bot = mlp_apply(params["bot"], dense, final_act=True)  # (B, d)
    if sparse.ndim == 2:
        emb = jnp.take(params["embedding"], sparse.reshape(-1), axis=0)
        emb = emb.reshape(B, cfg.n_sparse, cfg.embed_dim)
    else:  # multi-hot: embedding bag per field
        B_, F, H = sparse.shape
        emb = embedding_bag(params["embedding"], sparse.reshape(B_ * F, H))
        emb = emb.reshape(B, cfg.n_sparse, cfg.embed_dim)

    feats = jnp.concatenate([x_bot[:, None, :], emb], axis=1)  # (B, F+1, d)
    if cfg.interaction == "dot":
        gram = jnp.einsum("bfd,bgd->bfg", feats, feats)
        iu = jnp.triu_indices(feats.shape[1], k=1)
        inter = gram[:, iu[0], iu[1]]                          # (B, F(F+1)/2)
        z = jnp.concatenate([x_bot, inter], axis=-1)
    else:
        z = feats.reshape(B, -1)
    return mlp_apply(params["top"], z)[:, 0]                   # logits (B,)


def loss_fn(params, batch: Dict, cfg: DLRMConfig):
    logits = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )
    auc_proxy = jnp.mean((jax.nn.sigmoid(logits) > 0.5) == (y > 0.5))
    return loss, {"loss": loss, "acc": auc_proxy}


def make_train_step(cfg: DLRMConfig, optimizer):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True
        )(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, metrics

    return train_step


def serve_step(params, batch: Dict, cfg: DLRMConfig) -> jnp.ndarray:
    return jax.nn.sigmoid(forward(params, batch, cfg))


def retrieval_step(params, query: Dict, candidates: jnp.ndarray, top_k: int = 100):
    """Score one user against (n_cand, d) candidate embeddings: sharded
    matmul + top-k (no loop; the assignment's batched-dot requirement)."""
    dense = query["dense"]
    user = mlp_apply(params["bot"], dense, final_act=True)     # (1, d)
    scores = (candidates @ user[0]).astype(jnp.float32)        # (n_cand,)
    return jax.lax.top_k(scores, top_k)


# ---------------------------------------------------------------------------
# TAPER integration: workload-aware row placement
# ---------------------------------------------------------------------------


def coaccess_graph(cfg: DLRMConfig, sparse_batches: Sequence[np.ndarray],
                   max_rows_per_field: int = 512, min_count: int = 2):
    """Build the row co-access graph from click-log batches.

    Vertices = (field, row) pairs (hot rows only, capped per field); labels =
    field ids; edges connect rows co-accessed by one request.  A request is a
    2-hop label path, so TAPER's trie sees the field-pair traversal pattern —
    the direct analogue of the paper's query workload."""
    from repro.graphs.graph import LabelledGraph

    offsets = table_offsets(cfg)
    # hot rows per field
    hot: Dict[int, np.ndarray] = {}
    for f in range(cfg.n_sparse):
        vals = np.concatenate([b[:, f].reshape(-1) for b in sparse_batches])
        uniq, cnt = np.unique(vals, return_counts=True)
        hot[f] = uniq[np.argsort(-cnt)][:max_rows_per_field]
    remap: Dict[int, int] = {}
    labels = []
    for f in range(cfg.n_sparse):
        for r in hot[f]:
            remap[int(r)] = len(labels)
            labels.append(f)
    edges = []
    for b in sparse_batches:
        ids = b if b.ndim == 2 else b.reshape(b.shape[0], -1)
        for row in ids[: 512]:
            present = [remap[int(v)] for v in row if int(v) in remap]
            edges.extend(
                (present[i], present[j])
                for i in range(len(present))
                for j in range(i + 1, len(present))
            )
    edges = np.asarray(edges, np.int64).reshape(-1, 2)
    # keep only systematically co-accessed pairs: a pair seen once is zipf
    # noise, a pair seen repeatedly is workload structure (the signal the
    # paper's traversal frequencies carry)
    n_v = len(labels)
    if len(edges):
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n_v + hi
        uniq, counts = np.unique(key, return_counts=True)
        keep = uniq[counts >= min_count]
        edges = np.stack([keep // n_v, keep % n_v], axis=1)
    g = LabelledGraph.from_undirected_edges(
        n_v, np.asarray(labels, np.int32), edges,
        [f"F{f}" for f in range(cfg.n_sparse)],
    )
    inverse = np.full(len(labels), -1, np.int64)
    for orig, local in remap.items():
        inverse[local] = orig
    return g, inverse


def query_span(part_of_row: np.ndarray, sparse: np.ndarray, k: int) -> float:
    """Average number of shards touched per request (SWORD's 'query span')."""
    B = sparse.shape[0]
    ids = sparse.reshape(B, -1)
    parts = part_of_row[ids]
    span = np.array([len(np.unique(p)) for p in parts])
    return float(span.mean())
