"""GNN model family: SpMM regime (GCN, GIN), irrep tensor-product regime
(NequIP), and SO(2)/eSCN regime (EquiformerV2)."""
