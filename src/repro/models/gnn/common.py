"""Shared GNN substrate: batch container, segment message passing, RBF.

JAX has no native sparse message passing — per the assignment, SpMM-regime
aggregation is built on ``jax.ops.segment_sum`` over an edge index (the
scatter path), with the Pallas kernel in repro.kernels.segment_spmm as the
TPU-optimised twin.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class GraphBatch:
    """Padded (possibly batched) graph.

    node_feat: (N, F) float; positions: (N, 3) or None; edge_src/dst: (E,)
    int32 (padded entries masked); graph_id: (N,) int32 for pooled readout;
    targets: (N,) or (G,) — node labels / graph labels / energies.
    """

    node_feat: jnp.ndarray
    edge_src: jnp.ndarray
    edge_dst: jnp.ndarray
    node_mask: jnp.ndarray
    edge_mask: jnp.ndarray
    targets: jnp.ndarray
    positions: Optional[jnp.ndarray] = None
    graph_id: Optional[jnp.ndarray] = None
    n_graphs: int = 1

    def as_dict(self) -> Dict:
        out = {
            "node_feat": self.node_feat,
            "edge_src": self.edge_src,
            "edge_dst": self.edge_dst,
            "node_mask": self.node_mask,
            "edge_mask": self.edge_mask,
            "targets": self.targets,
        }
        if self.positions is not None:
            out["positions"] = self.positions
        if self.graph_id is not None:
            out["graph_id"] = self.graph_id
        return out


def scatter_sum(values: jnp.ndarray, index: jnp.ndarray, n: int,
                mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """segment_sum with optional edge mask; values (E, ...), index (E,)."""
    if mask is not None:
        values = values * mask.reshape((-1,) + (1,) * (values.ndim - 1))
        index = jnp.where(mask, index, n)  # park masked edges in a waste bin
        return jax.ops.segment_sum(values, index, num_segments=n + 1)[:n]
    return jax.ops.segment_sum(values, index, num_segments=n)


def degrees(edge_dst: jnp.ndarray, n: int,
            edge_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    ones = jnp.ones_like(edge_dst, dtype=jnp.float32)
    return scatter_sum(ones, edge_dst, n, edge_mask)


def gather(x: jnp.ndarray, index: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(x, index, axis=0)


def segment_softmax(logits: jnp.ndarray, index: jnp.ndarray, n: int,
                    mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Per-destination softmax over edges; logits (E, ...), index (E,)."""
    big_neg = -1e30
    if mask is not None:
        logits = jnp.where(mask.reshape((-1,) + (1,) * (logits.ndim - 1)),
                           logits, big_neg)
    seg_max = jax.ops.segment_max(logits, index, num_segments=n)
    seg_max = jnp.where(jnp.isfinite(seg_max), seg_max, 0.0)
    ex = jnp.exp(logits - seg_max[index])
    if mask is not None:
        ex = ex * mask.reshape((-1,) + (1,) * (ex.ndim - 1))
    denom = jax.ops.segment_sum(ex, index, num_segments=n)
    return ex / jnp.maximum(denom[index], 1e-30)


def bessel_rbf(dist: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """Bessel radial basis with polynomial cutoff envelope (NequIP-style)."""
    d = jnp.maximum(dist, 1e-6)[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d
    x = jnp.clip(dist / cutoff, 0.0, 1.0)[..., None]
    env = 1.0 - 10.0 * x ** 3 + 15.0 * x ** 4 - 6.0 * x ** 5
    return basis * env


def mlp_init(rng, dims, dtype=jnp.float32):
    params = []
    logical = []
    keys = jax.random.split(rng, len(dims) - 1)
    for k, (a, b) in zip(keys, zip(dims, dims[1:])):
        w = jax.random.normal(k, (a, b), jnp.float32) / np.sqrt(a)
        params.append({"w": w.astype(dtype), "b": jnp.zeros((b,), dtype)})
        logical.append({"w": (None, None), "b": (None,)})
    return params, logical


def mlp_apply(params, x, act=jax.nn.silu, final_act=False):
    for i, p in enumerate(params):
        x = x @ p["w"] + p["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def layer_norm(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)
