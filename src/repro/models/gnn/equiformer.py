"""EquiformerV2 (Liao et al., arXiv:2306.12059) — equivariant graph
attention with eSCN SO(2) convolutions.

The eSCN trick (Passaro & Zitnick): rotating each edge's SH-coefficient
features into a frame where the edge points at +z makes the tensor-product
convolution block-diagonal in m — an O(L^6) CG contraction becomes O(L^3)
per-m channel mixing.  Per edge:

  1. rotate source features into the edge frame:  x~ = D(R_e) x_src
  2. SO(2) conv for |m| <= m_max (distance-conditioned gates g_m(rbf) and
     learned channel mixes W_m pairing the (+m, -m) coefficient vectors):
        y_{+m} = g (W1 x_{+m} - W2 x_{-m});  y_{-m} = g (W2 x_{+m} + W1 x_{-m})
  3. attention: per-head logits from the rotated scalar (m=0) channel,
     softmax over incoming edges (segment softmax), alpha-weighted messages
  4. rotate back: msg = D(R_e)^T y, aggregate into the destination.

Followed by an equivariant RMS norm and a gated FFN on the scalar block.
m truncation (m_max=2 at l_max=6) is the assigned configuration.
Equivariance is property-tested.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.gnn import so3
from repro.models.gnn.common import (
    bessel_rbf,
    gather,
    mlp_apply,
    mlp_init,
    scatter_sum,
    segment_softmax,
)


def _m_indices(l_max: int, m: int) -> List[int]:
    """Flat SH indices of coefficient m for every l >= |m|."""
    return [so3.sh_index(l, m) for l in range(abs(m), l_max + 1)]


def init(rng, cfg: GNNConfig, n_species: int) -> Tuple[Dict, Dict]:
    C, L, M = cfg.d_hidden, cfg.l_max, cfg.m_max
    keys = jax.random.split(rng, 4 + cfg.n_layers)
    params: Dict = {
        "embed": jax.random.normal(keys[0], (n_species, C), jnp.float32) / np.sqrt(n_species),
    }
    logical: Dict = {"embed": (None, None)}
    layers, layers_log = [], []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 8)
        layer = {
            # per-m channel mixes (W1, W2); m=0 needs only W1
            "w0": jax.random.normal(ks[0], (C, C), jnp.float32) / np.sqrt(C),
            "radial": mlp_init(ks[1], (cfg.n_rbf, 32, (2 * M + 1)))[0],
            "attn": mlp_init(ks[2], (C, 32, cfg.n_heads))[0],
            "ffn1": jax.random.normal(ks[3], (C, 2 * C), jnp.float32) / np.sqrt(C),
            "ffn2": jax.random.normal(ks[4], (2 * C, C), jnp.float32) / np.sqrt(2 * C),
            "ffn_gate": jax.random.normal(ks[5], (C, L * C), jnp.float32) / np.sqrt(C),
            "out": jax.random.normal(ks[6], (C, C), jnp.float32) / np.sqrt(C),
        }
        layer_log = {
            "w0": (None, None),
            "radial": [{"w": (None, None), "b": (None,)} for _ in range(2)],
            "attn": [{"w": (None, None), "b": (None,)} for _ in range(2)],
            "ffn1": (None, None), "ffn2": (None, None),
            "ffn_gate": (None, None), "out": (None, None),
        }
        for m in range(1, M + 1):
            km = jax.random.split(ks[7], 2 * M)
            layer[f"w{m}_1"] = jax.random.normal(km[2 * m - 2], (C, C), jnp.float32) / np.sqrt(C)
            layer[f"w{m}_2"] = jax.random.normal(km[2 * m - 1], (C, C), jnp.float32) / np.sqrt(C)
            layer_log[f"w{m}_1"] = (None, None)
            layer_log[f"w{m}_2"] = (None, None)
        layers.append(layer)
        layers_log.append(layer_log)
    params["layers"] = layers
    logical["layers"] = layers_log
    params["readout"] = mlp_init(keys[1], (C, 32, 1))[0]
    logical["readout"] = [{"w": (None, None), "b": (None,)} for _ in range(2)]
    return params, logical


def _so2_conv(lp, x_rot, rbf_gates, cfg: GNNConfig):
    """Blockwise-in-m channel mixing in the edge frame.

    x_rot: (E, C, S); rbf_gates: (E, 2*m_max+1).  Coefficients with |m| >
    m_max are dropped (the eSCN truncation).
    """
    E, C, S = x_rot.shape
    L, M = cfg.l_max, cfg.m_max
    y = jnp.zeros_like(x_rot)
    # m = 0
    idx0 = jnp.asarray(_m_indices(L, 0))
    g0 = rbf_gates[:, M][:, None, None]
    y = y.at[:, :, idx0].set(
        g0 * jnp.einsum("cd,eds->ecs", lp["w0"], x_rot[:, :, idx0]))
    for m in range(1, M + 1):
        ip = jnp.asarray(_m_indices(L, m))
        im = jnp.asarray(_m_indices(L, -m))
        gp = rbf_gates[:, M + m][:, None, None]
        gm = rbf_gates[:, M - m][:, None, None]
        xp, xm = x_rot[:, :, ip], x_rot[:, :, im]
        W1, W2 = lp[f"w{m}_1"], lp[f"w{m}_2"]
        yp = jnp.einsum("cd,eds->ecs", W1, xp) - jnp.einsum("cd,eds->ecs", W2, xm)
        ym = jnp.einsum("cd,eds->ecs", W2, xp) + jnp.einsum("cd,eds->ecs", W1, xm)
        y = y.at[:, :, ip].set(gp * yp)
        y = y.at[:, :, im].set(gm * ym)
    return y


def _equiv_norm(x, l_max: int, eps: float = 1e-6):
    """RMS norm per l-block over (channel, m)."""
    outs = []
    for l in range(l_max + 1):
        lo, hi = l * l, (l + 1) ** 2
        blk = x[:, :, lo:hi]
        rms = jnp.sqrt(jnp.mean(blk ** 2, axis=(1, 2), keepdims=True) + eps)
        outs.append(blk / rms)
    return jnp.concatenate(outs, axis=-1)


def forward(params, batch: Dict, cfg: GNNConfig, n_graphs: int) -> jnp.ndarray:
    species = batch["node_feat"]
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask, nmask = batch["edge_mask"], batch["node_mask"]
    n = species.shape[0]
    C, L = cfg.d_hidden, cfg.l_max

    h = jnp.zeros((n, C, so3.n_sph(L)), jnp.float32)
    h = h.at[:, :, 0].set(species @ params["embed"])

    r = gather(pos, src) - gather(pos, dst)
    dist = jnp.linalg.norm(r + 1e-9, axis=-1)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)
    emask = emask & (dist < cfg.cutoff)
    a, b, g = so3.align_to_z_angles(r)
    Ds = so3.rotation_block_diag(a, b, g, L)

    n_heads = cfg.n_heads
    for lp in params["layers"]:
        # -- eSCN attention block --
        x_src = gather(h, src)
        x_rot = so3.rotate_coeffs(x_src, Ds, L)            # into edge frame
        gates = mlp_apply(lp["radial"], rbf)               # (E, 2M+1)
        y = _so2_conv(lp, x_rot, gates, cfg)
        # attention logits from the rotated scalar block + destination scalars
        inv = y[:, :, 0] + gather(h, dst)[:, :, 0]
        logits = mlp_apply(lp["attn"], inv)                # (E, H)
        alpha = segment_softmax(logits, dst, n, emask)     # (E, H)
        # heads gate channel groups
        y = y * jnp.repeat(alpha, C // n_heads, axis=1)[:, :, None]
        msg = so3.rotate_coeffs(y, Ds, L, transpose=True)  # back to global
        agg = scatter_sum(msg, dst, n, emask)
        agg = jnp.einsum("cd,nds->ncs", lp["out"], agg)
        h = h + agg
        h = _equiv_norm(h, L) * nmask[:, None, None]

        # -- gated FFN on the scalar block --
        s = h[:, :, 0]
        f = jax.nn.silu(s @ lp["ffn1"]) @ lp["ffn2"]
        h = h.at[:, :, 0].add(f)
        gates_l = jax.nn.sigmoid(s @ lp["ffn_gate"]).reshape(n, L, C)
        for l in range(1, L + 1):
            lo, hi = l * l, (l + 1) ** 2
            h = h.at[:, :, lo:hi].multiply(gates_l[:, l - 1, :, None])
        h = h * nmask[:, None, None]

    atom_e = mlp_apply(params["readout"], h[:, :, 0])[:, 0] * nmask
    gid = batch.get("graph_id")
    if gid is not None:
        return jax.ops.segment_sum(atom_e, gid, num_segments=n_graphs)
    return atom_e


def loss_fn(params, batch: Dict, cfg: GNNConfig, n_graphs: int):
    pred = forward(params, batch, cfg, n_graphs)
    target = batch["targets"].astype(jnp.float32)
    loss = jnp.mean((pred - target) ** 2)
    return loss, {"loss": loss, "mae": jnp.mean(jnp.abs(pred - target))}
