"""Partition-aware distributed GNN execution: halo exchange accounting.

Integration point 1 of DESIGN.md §4: when graph nodes are sharded over
devices, every message-passing layer must fetch the features of *remote*
neighbours ("halo" rows) — the distributed-GNN incarnation of the paper's
inter-partition traversals.  Halo volume per layer is exactly the number of
(partition, remote-neighbour) pairs, so a TAPER-refined placement directly
reduces the all-to-all bytes.

``halo_stats`` computes the exchange plan; ``partitioned_gcn_forward`` runs
a GCN with explicit per-partition halo gathers (the execution semantics a
shard_map deployment uses, validated against the monolithic forward in
tests/test_gnn_halo.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.graphs.graph import LabelledGraph


@dataclass
class HaloPlan:
    k: int
    halo_rows: List[np.ndarray]        # per partition: remote node ids needed
    total_halo_rows: int
    bytes_per_layer: int               # at d_hidden fp32

    @staticmethod
    def build(g: LabelledGraph, part: np.ndarray, d_hidden: int,
              k: int) -> "HaloPlan":
        halo_rows = []
        total = 0
        for p in range(k):
            mask = part[g.dst] == p
            remote = part[g.src] != p
            rows = np.unique(g.src[mask & remote])
            halo_rows.append(rows)
            total += rows.size
        return HaloPlan(k, halo_rows, total, total * d_hidden * 4)


def partitioned_gcn_forward(params, g: LabelledGraph, part: np.ndarray,
                            x: np.ndarray, cfg: GNNConfig, k: int):
    """GCN forward executed partition-by-partition with explicit halo
    gathers — the reference semantics for the shard_map deployment.

    Returns (logits, halo_bytes_total).
    """
    from repro.models.gnn.common import scatter_sum

    n = g.n
    deg = np.zeros(n)
    np.add.at(deg, g.dst, 1.0)
    deg += 1.0
    inv_sqrt = 1.0 / np.sqrt(deg)

    halo_bytes = 0
    h = jnp.asarray(x)
    for li, p_layer in enumerate(params["layers"]):
        plan = HaloPlan.build(g, part, h.shape[1], k)
        halo_bytes += plan.total_halo_rows * h.shape[1] * 4
        agg = jnp.zeros_like(h)
        for p in range(k):
            emask = part[g.dst] == p
            src, dst = g.src[emask], g.dst[emask]
            # local + halo rows are materialised per partition ("the exchange")
            coeff = jnp.asarray((inv_sqrt[src] * inv_sqrt[dst]).astype(np.float32))
            msgs = h[jnp.asarray(src)] * coeff[:, None]
            agg = agg + scatter_sum(msgs, jnp.asarray(dst), n)
        agg = agg + h * jnp.asarray((1.0 / deg).astype(np.float32))[:, None]
        h = agg @ p_layer["w"] + p_layer["b"]
        if li < len(params["layers"]) - 1:
            h = jax.nn.relu(h)
    return h, halo_bytes


def halo_bytes_per_step(g: LabelledGraph, part: np.ndarray, cfg: GNNConfig,
                        d_feat: int, k: int) -> int:
    """Total halo bytes for one forward pass (layer dims vary)."""
    dims = [d_feat] + [cfg.d_hidden] * (cfg.n_layers - 1)
    total = 0
    for d in dims:
        total += HaloPlan.build(g, part, d, k).total_halo_rows * d * 4
    return total
