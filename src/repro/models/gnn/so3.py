"""SO(3) representation machinery for equivariant GNNs.

Real spherical harmonics, Wigner-D rotation matrices in the real basis, and
real-basis Clebsch-Gordan coefficients — everything NequIP's tensor-product
messages and EquiformerV2's eSCN rotation trick need, with no external
dependency (e3nn is not available offline).

Conventions: real SH index ``(l, m)`` flattened as ``l*l + (m + l)``;
normalised so that Y transforms as ``Y(R r) = D(R) Y(r)`` with the D built
here (this identity is property-tested in tests/test_so3.py).

Wigner-D path: complex Wigner-d(β) via Wigner's factorial formula
(precomputed numpy coefficient tables per l), z-y-z Euler composition, and a
fixed unitary change of basis U_l between complex and real SH.  All per-edge
math is jnp (vectorised over edges); the l-indexed tables are baked numpy
constants.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def n_sph(l_max: int) -> int:
    return (l_max + 1) ** 2


def sh_index(l: int, m: int) -> int:
    return l * l + m + l


# ---------------------------------------------------------------------------
# real spherical harmonics
# ---------------------------------------------------------------------------


def sph_harm(vec: jnp.ndarray, l_max: int, eps: float = 1e-12) -> jnp.ndarray:
    """Real spherical harmonics of unit(ised) vectors.

    vec: (..., 3) -> (..., (l_max+1)^2), ordered l*l + m + l.
    """
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    ct = z / r                                   # cos(theta)
    st = jnp.sqrt(jnp.maximum(1.0 - ct * ct, 0.0))
    phi = jnp.arctan2(y, x + eps * (x == 0))

    # associated Legendre P_l^m(ct) with Condon-Shortley, upward recursion
    P: Dict[Tuple[int, int], jnp.ndarray] = {}
    P[(0, 0)] = jnp.ones_like(ct)
    for m in range(1, l_max + 1):
        # P_m^m = (-1)^m (2m-1)!! st^m
        P[(m, m)] = (-1.0) ** m * _dfact(2 * m - 1) * st ** m
    for m in range(0, l_max):
        P[(m + 1, m)] = ct * (2 * m + 1) * P[(m, m)]
    for m in range(0, l_max + 1):
        for l in range(m + 2, l_max + 1):
            P[(l, m)] = ((2 * l - 1) * ct * P[(l - 1, m)]
                         - (l + m - 1) * P[(l - 2, m)]) / (l - m)

    cos_m = [jnp.ones_like(phi)]
    sin_m = [jnp.zeros_like(phi)]
    for m in range(1, l_max + 1):
        cos_m.append(jnp.cos(m * phi))
        sin_m.append(jnp.sin(m * phi))

    out = []
    for l in range(l_max + 1):
        row = [None] * (2 * l + 1)
        for m in range(0, l + 1):
            N = math.sqrt((2 * l + 1) / (4 * math.pi)
                          * math.factorial(l - m) / math.factorial(l + m))
            # cancel Condon-Shortley so the real SH is CS-free
            base = N * ((-1.0) ** m) * P[(l, m)]
            if m == 0:
                row[sh_index(l, 0) - l * l] = base
            else:
                row[sh_index(l, m) - l * l] = math.sqrt(2.0) * base * cos_m[m]
                row[sh_index(l, -m) - l * l] = math.sqrt(2.0) * base * sin_m[m]
        out.extend(row)
    return jnp.stack(out, axis=-1)


def _dfact(n: int) -> float:
    out = 1.0
    while n > 1:
        out *= n
        n -= 2
    return out


# ---------------------------------------------------------------------------
# Wigner-d / Wigner-D
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _wigner_d_tables(l: int):
    """Precompute Wigner-d(β) expansion tables for one l.

    d^l_{m',m}(β) = sum_k c_k * cos(β/2)^(2l+m-m'-2k) * sin(β/2)^(m'-m+2k)

    Returns (coef, cos_pow, sin_pow) arrays of shape (2l+1, 2l+1, K).
    """
    dim = 2 * l + 1
    kmax = 2 * l + 1
    coef = np.zeros((dim, dim, kmax))
    cpow = np.zeros((dim, dim, kmax), dtype=np.int64)
    spow = np.zeros((dim, dim, kmax), dtype=np.int64)
    f = math.factorial
    for im1, m1 in enumerate(range(-l, l + 1)):     # m'
        for im2, m2 in enumerate(range(-l, l + 1)):  # m
            pref = math.sqrt(f(l + m1) * f(l - m1) * f(l + m2) * f(l - m2))
            for k in range(max(0, m2 - m1), min(l - m1, l + m2) + 1):
                denom = f(l - m1 - k) * f(l + m2 - k) * f(k + m1 - m2) * f(k)
                coef[im1, im2, k] = ((-1.0) ** (k + m1 - m2)) * pref / denom
                cpow[im1, im2, k] = 2 * l + m2 - m1 - 2 * k
                spow[im1, im2, k] = m1 - m2 + 2 * k
    return coef, cpow, spow


@lru_cache(maxsize=None)
def _complex_to_real_basis(l: int) -> np.ndarray:
    """Unitary U with Y_real = U @ Y_complex (complex SH with CS phase).

    Real index order: m = -l..l (sin|m| ... Y_l0 ... cos m).
    """
    dim = 2 * l + 1
    U = np.zeros((dim, dim), dtype=np.complex128)
    s2 = 1.0 / math.sqrt(2.0)
    for m in range(1, l + 1):
        # Y_{l,-m}^real (sin) = i/sqrt2 (Y_{l,-m} - (-1)^m Y_{l,m})
        U[l - m, l - m] = 1j * s2
        U[l - m, l + m] = -1j * s2 * ((-1.0) ** m)
        # Y_{l,m}^real (cos) = 1/sqrt2 (Y_{l,-m} + (-1)^m Y_{l,m})
        U[l + m, l - m] = s2
        U[l + m, l + m] = s2 * ((-1.0) ** m)
    U[l, l] = 1.0
    return U


def wigner_d_real(alpha, beta, gamma, l: int) -> jnp.ndarray:
    """Real-basis Wigner D^l for z-y-z Euler angles (vectorised over leading
    dims).  Satisfies Y(R r) = D(R) Y(r) for the real SH above, where
    R = Rz(alpha) Ry(beta) Rz(gamma)."""
    coef, cpow, spow = _wigner_d_tables(l)
    cb = jnp.cos(beta / 2.0)[..., None, None, None]
    sb = jnp.sin(beta / 2.0)[..., None, None, None]
    d = jnp.sum(coef * cb ** cpow * sb ** spow, axis=-1)  # (..., dim, dim)

    m = jnp.arange(-l, l + 1)
    # Y(R r) = M Y(r) holds for M = conj(D) in the standard convention
    # D^l_{m',m} = e^{-i m' a} d^l(b) e^{-i m g}; we build conj(D) directly
    # (d is real, so only the phases flip sign)
    ea = jnp.exp(1j * m * alpha[..., None])
    eg = jnp.exp(1j * m * gamma[..., None])
    Dc = ea[..., :, None] * d.astype(jnp.complex64) * eg[..., None, :]
    U = jnp.asarray(_complex_to_real_basis(l))
    Dr = jnp.einsum("ij,...jk,lk->...il", U, Dc, U.conj())
    return jnp.real(Dr).astype(jnp.float32)


def align_to_z_angles(vec: jnp.ndarray, eps: float = 1e-12):
    """Euler angles (alpha, beta, gamma) of a rotation R taking ``vec`` to
    +z: R = Rz(0) Ry(-theta) Rz(-phi)."""
    x, y, z = vec[..., 0], vec[..., 1], vec[..., 2]
    r = jnp.sqrt(x * x + y * y + z * z + eps)
    theta = jnp.arccos(jnp.clip(z / r, -1.0, 1.0))
    phi = jnp.arctan2(y, x + eps * (x == 0))
    zeros = jnp.zeros_like(theta)
    return zeros, -theta, -phi


def rotation_block_diag(alpha, beta, gamma, l_max: int) -> List[jnp.ndarray]:
    """List of per-l real D matrices (one entry per l in 0..l_max)."""
    out = [jnp.ones(alpha.shape + (1, 1), dtype=jnp.float32)]
    for l in range(1, l_max + 1):
        out.append(wigner_d_real(alpha, beta, gamma, l))
    return out


def rotate_coeffs(coeffs: jnp.ndarray, Ds: List[jnp.ndarray], l_max: int,
                  transpose: bool = False) -> jnp.ndarray:
    """Apply block-diagonal rotation to (..., C, (l_max+1)^2) coefficients."""
    outs = []
    for l in range(l_max + 1):
        lo, hi = l * l, (l + 1) * (l + 1)
        blk = coeffs[..., lo:hi]
        D = Ds[l]
        eq = "...ij,...cj->...ci" if not transpose else "...ji,...cj->...ci"
        outs.append(jnp.einsum(eq, D, blk))
    return jnp.concatenate(outs, axis=-1)


# ---------------------------------------------------------------------------
# Clebsch-Gordan (real basis)
# ---------------------------------------------------------------------------


@lru_cache(maxsize=None)
def _cg_complex(l1: int, l2: int, l3: int) -> np.ndarray:
    """Complex-basis CG coefficients <l1 m1 l2 m2 | l3 m3> via Racah."""
    f = math.factorial

    def cg(j1, m1, j2, m2, j3, m3):
        if m3 != m1 + m2:
            return 0.0
        pref = math.sqrt(
            (2 * j3 + 1)
            * f(j3 + j1 - j2) * f(j3 - j1 + j2) * f(j1 + j2 - j3)
            / f(j1 + j2 + j3 + 1)
        )
        pref *= math.sqrt(
            f(j3 + m3) * f(j3 - m3) * f(j1 - m1) * f(j1 + m1)
            * f(j2 - m2) * f(j2 + m2)
        )
        s = 0.0
        for k in range(0, j1 + j2 - j3 + 1):
            denom_args = [
                k, j1 + j2 - j3 - k, j1 - m1 - k, j2 + m2 - k,
                j3 - j2 + m1 + k, j3 - j1 - m2 + k,
            ]
            if any(a < 0 for a in denom_args):
                continue
            denom = 1.0
            for a in denom_args:
                denom *= f(a)
            s += ((-1.0) ** k) / denom
        return pref * s

    out = np.zeros((2 * l1 + 1, 2 * l2 + 1, 2 * l3 + 1))
    for i1, m1 in enumerate(range(-l1, l1 + 1)):
        for i2, m2 in enumerate(range(-l2, l2 + 1)):
            for i3, m3 in enumerate(range(-l3, l3 + 1)):
                out[i1, i2, i3] = cg(l1, m1, l2, m2, l3, m3)
    return out


@lru_cache(maxsize=None)
def clebsch_gordan_real(l1: int, l2: int, l3: int) -> np.ndarray:
    """Real-basis CG tensor C with the equivariance property
    (D1 a) x (D2 b) -> contraction transforms with D3 (property-tested).

    Built as U1* U2* C_complex U3^T with phase fixed so the result is real.
    """
    C = _cg_complex(l1, l2, l3).astype(np.complex128)
    U1 = _complex_to_real_basis(l1)
    U2 = _complex_to_real_basis(l2)
    U3 = _complex_to_real_basis(l3)
    # real-basis tensor: C_real[i,j,k] = sum U1[i,m1] U2[j,m2] C[m1,m2,m3] U3*[k,m3]
    out = np.einsum("im,jn,mnp,kp->ijk", U1, U2, C, U3.conj())
    # the result is either purely real or purely imaginary; normalise phase
    if np.abs(out.imag).max() > np.abs(out.real).max():
        out = out.imag
    else:
        out = out.real
    norm = np.abs(out).max()
    return np.ascontiguousarray(out)


def tensor_product_paths(l_max_in: int, l_max_out: int):
    """All (l1, l2, l3) with |l1-l2| <= l3 <= l1+l2 within the budgets."""
    paths = []
    for l1 in range(l_max_in + 1):
        for l2 in range(l_max_in + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max_out) + 1):
                paths.append((l1, l2, l3))
    return paths
