"""GCN (Kipf & Welling, arXiv:1609.02907) — SpMM kernel regime.

X' = act( norm(A + I) X W + b ); sym norm D^-1/2 (A+I) D^-1/2 or mean D^-1 A.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.gnn.common import degrees, gather, scatter_sum


def init(rng, cfg: GNNConfig, d_in: int) -> Tuple[Dict, Dict]:
    dims = [d_in] + [cfg.d_hidden] * (cfg.n_layers - 1) + [cfg.n_classes]
    keys = jax.random.split(rng, len(dims))
    params, logical = [], []
    for k, (a, b) in zip(keys, zip(dims, dims[1:])):
        w = (jax.random.normal(k, (a, b), jnp.float32) / np.sqrt(a))
        params.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
        logical.append({"w": (None, "feat_model"), "b": ("feat_model",)})
    return {"layers": params}, {"layers": logical}


def forward(params, batch: Dict, cfg: GNNConfig) -> jnp.ndarray:
    x = batch["node_feat"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask, nmask = batch["edge_mask"], batch["node_mask"]
    n = x.shape[0]
    deg = degrees(dst, n, emask) + 1.0  # +1: self loop
    if cfg.norm == "sym":
        inv_sqrt = jax.lax.rsqrt(deg)
        coeff = inv_sqrt[src] * inv_sqrt[dst]
        self_coeff = 1.0 / deg
    else:  # mean aggregator
        coeff = 1.0 / deg[dst]
        self_coeff = 1.0 / deg
    for i, p in enumerate(params["layers"]):
        msgs = gather(x, src) * coeff[:, None]
        agg = scatter_sum(msgs, dst, n, emask) + x * self_coeff[:, None]
        x = agg @ p["w"] + p["b"]
        if i < len(params["layers"]) - 1:
            x = jax.nn.relu(x)
    return x * nmask[:, None]


def loss_fn(params, batch: Dict, cfg: GNNConfig):
    logits = forward(params, batch, cfg)
    labels = batch["targets"]
    logz = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels[:, None], axis=-1)[:, 0]
    mask = batch["node_mask"].astype(jnp.float32)
    loss = jnp.sum((logz - gold) * mask) / jnp.maximum(mask.sum(), 1.0)
    acc = jnp.sum((logits.argmax(-1) == labels) * mask) / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss, "accuracy": acc}
