"""Unified GNN entry points keyed by ``GNNConfig.kind`` and shape cell.

The four assigned GNNs fall in three kernel regimes (taxonomy §B.3):
SpMM (gcn, gin), CG tensor product (nequip), SO(2)/eSCN (equiformer_v2).
Non-molecular shape cells feed the equivariant models synthetic 3-D
positions (DESIGN.md §Shape-cell skips).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import GNNConfig, ShapeSpec
from repro.models.gnn import equiformer, gcn, gin, nequip

N_SPECIES = 16  # synthetic atomic-species vocabulary for equivariant models


def feature_dim(cfg: GNNConfig, shape: ShapeSpec) -> int:
    if cfg.kind in ("nequip", "equiformer_v2"):
        return N_SPECIES
    return shape.get("d_feat", N_SPECIES)


def is_graph_level(cfg: GNNConfig, shape: ShapeSpec) -> bool:
    return shape.name == "molecule"


def n_graphs_of(shape: ShapeSpec) -> int:
    return shape.get("batch", 1)


def init(rng, cfg: GNNConfig, shape: ShapeSpec):
    d_in = feature_dim(cfg, shape)
    if cfg.kind == "gcn":
        return gcn.init(rng, cfg, d_in)
    if cfg.kind == "gin":
        return gin.init(rng, cfg, d_in)
    if cfg.kind == "nequip":
        return nequip.init(rng, cfg, d_in)
    if cfg.kind == "equiformer_v2":
        return equiformer.init(rng, cfg, d_in)
    raise ValueError(cfg.kind)


def loss_fn(params, batch: Dict, cfg: GNNConfig, shape: ShapeSpec):
    graph_level = is_graph_level(cfg, shape)
    # derive the pooled-graph count from the batch (supports scaled smoke
    # batches); static at trace time
    G = batch["targets"].shape[0] if graph_level else n_graphs_of(shape)
    if cfg.kind == "gcn":
        if graph_level:
            # GCN as graph classifier: mean-pool via gin-style readout is out
            # of scope; use node-level loss against per-node targets
            return gcn.loss_fn(params, batch, cfg)
        return gcn.loss_fn(params, batch, cfg)
    if cfg.kind == "gin":
        return gin.loss_fn(params, batch, cfg, G, node_level=not graph_level)
    if cfg.kind == "nequip":
        return nequip.loss_fn(params, batch, cfg, G if graph_level else 1)
    if cfg.kind == "equiformer_v2":
        return equiformer.loss_fn(params, batch, cfg, G if graph_level else 1)
    raise ValueError(cfg.kind)


def make_train_step(cfg: GNNConfig, shape: ShapeSpec, optimizer):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, shape), has_aux=True
        )(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        return params, opt_state, metrics

    return train_step


def needs_positions(cfg: GNNConfig) -> bool:
    return cfg.kind in ("nequip", "equiformer_v2")


def target_spec(cfg: GNNConfig, shape: ShapeSpec, n_nodes: int):
    """(shape, dtype) of the targets array for this cell.

    GCN has no pooled readout, so it always trains node-level; GIN pools on
    molecule batches; equivariant models regress per-graph energies on
    molecule batches and per-node scalars elsewhere."""
    if cfg.kind in ("nequip", "equiformer_v2"):
        if is_graph_level(cfg, shape):
            return (n_graphs_of(shape),), jnp.float32
        return (n_nodes,), jnp.float32
    if cfg.kind == "gin" and is_graph_level(cfg, shape):
        return (n_graphs_of(shape),), jnp.int32
    return (n_nodes,), jnp.int32
