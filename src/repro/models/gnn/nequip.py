"""NequIP (Batzner et al., arXiv:2101.03164) — E(3) tensor-product messages.

Features are (N, C, (l_max+1)^2) real-SH coefficient stacks (C channels per
l).  An interaction layer computes, per edge:

    m^(l3) += w_path(rbf(|r|)) * CG^{l1 l2 l3} ( h_src^(l1) x Y^(l2)(r̂) )

over all allowed paths, aggregates by destination, applies a per-l linear
self-interaction and a gate nonlinearity (scalars: SiLU; l>0 blocks scaled by
a sigmoid gate from dedicated scalar channels).  Readout: per-atom linear on
the scalar block -> per-graph energy sum.  Equivariance is property-tested
(tests/test_gnn_models.py::test_nequip_equivariance).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.gnn import so3
from repro.models.gnn.common import bessel_rbf, gather, mlp_apply, mlp_init, scatter_sum


def _paths(l_max: int) -> List[Tuple[int, int, int]]:
    out = []
    for l1 in range(l_max + 1):
        for l2 in range(l_max + 1):
            for l3 in range(abs(l1 - l2), min(l1 + l2, l_max) + 1):
                if np.abs(so3.clebsch_gordan_real(l1, l2, l3)).max() > 1e-12:
                    out.append((l1, l2, l3))
    return out


def init(rng, cfg: GNNConfig, n_species: int) -> Tuple[Dict, Dict]:
    C, L = cfg.d_hidden, cfg.l_max
    paths = _paths(L)
    keys = jax.random.split(rng, 3 + cfg.n_layers)
    params: Dict = {
        "embed": jax.random.normal(keys[0], (n_species, C), jnp.float32) / np.sqrt(n_species),
    }
    logical: Dict = {"embed": (None, None)}
    layers, layers_log = [], []
    for i in range(cfg.n_layers):
        ks = jax.random.split(keys[2 + i], 4)
        radial, radial_log = mlp_init(ks[0], (cfg.n_rbf, 32, len(paths) * C))
        lin = {
            f"l{l}": jax.random.normal(ks[1], (C, C), jnp.float32) / np.sqrt(C)
            for l in range(L + 1)
        }
        gate = jax.random.normal(ks[2], (C, L * C), jnp.float32) / np.sqrt(C) if L else None
        layer = {"radial": radial, "lin": lin}
        layer_log = {"radial": radial_log,
                     "lin": {k: (None, None) for k in lin}}
        if gate is not None:
            layer["gate"] = gate
            layer_log["gate"] = (None, None)
        layers.append(layer)
        layers_log.append(layer_log)
    params["layers"] = layers
    logical["layers"] = layers_log
    readout, readout_log = mlp_init(keys[1], (C, 16, 1))
    params["readout"] = readout
    logical["readout"] = readout_log
    return params, logical


def _interaction(lp, h, Y, rbf_w, src, dst, emask, cfg: GNNConfig):
    """One tensor-product message-passing layer."""
    n, C, _ = h.shape
    L = cfg.l_max
    paths = _paths(L)
    w = mlp_apply(lp["radial"], rbf_w).reshape(-1, len(paths), C)  # (E, P, C)
    h_src = gather(h, src)                                          # (E, C, S)
    msg = jnp.zeros_like(h_src)
    for pi, (l1, l2, l3) in enumerate(paths):
        CG = jnp.asarray(so3.clebsch_gordan_real(l1, l2, l3), jnp.float32)
        a = h_src[:, :, l1 * l1:(l1 + 1) ** 2]                      # (E, C, 2l1+1)
        b = Y[:, l2 * l2:(l2 + 1) ** 2]                             # (E, 2l2+1)
        out = jnp.einsum("ijk,eci,ej->eck", CG, a, b)               # (E, C, 2l3+1)
        msg = msg.at[:, :, l3 * l3:(l3 + 1) ** 2].add(out * w[:, pi, :, None])
    agg = scatter_sum(msg, dst, n, emask)

    # self-interaction per l + gate nonlinearity
    new = jnp.zeros_like(h)
    for l in range(L + 1):
        lo, hi = l * l, (l + 1) ** 2
        mixed = jnp.einsum("cd,ncs->nds", lp["lin"][f"l{l}"], agg[:, :, lo:hi])
        new = new.at[:, :, lo:hi].set(mixed)
    scal = jax.nn.silu(new[:, :, 0])
    out = new.at[:, :, 0].set(scal)
    if L:
        gates = jax.nn.sigmoid(scal @ lp["gate"]).reshape(n, L, C)  # (N, L, C)
        for l in range(1, L + 1):
            lo, hi = l * l, (l + 1) ** 2
            out = out.at[:, :, lo:hi].multiply(gates[:, l - 1, :, None])
    return h + out  # residual


def forward(params, batch: Dict, cfg: GNNConfig, n_graphs: int) -> jnp.ndarray:
    """Per-graph energy prediction (or per-node when graph_id is absent)."""
    species = batch["node_feat"]                 # (N, n_species) one-hot-ish
    pos = batch["positions"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask, nmask = batch["edge_mask"], batch["node_mask"]
    n = species.shape[0]
    C, L = cfg.d_hidden, cfg.l_max

    h = jnp.zeros((n, C, so3.n_sph(L)), jnp.float32)
    h = h.at[:, :, 0].set(species @ params["embed"])

    r = gather(pos, src) - gather(pos, dst)
    dist = jnp.linalg.norm(r + 1e-9, axis=-1)
    Y = so3.sph_harm(r, L)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)
    # zero out edges beyond the cutoff (masked edges too)
    emask = emask & (dist < cfg.cutoff)

    for lp in params["layers"]:
        h = _interaction(lp, h, Y, rbf, src, dst, emask, cfg)
        h = h * nmask[:, None, None]

    atom_e = mlp_apply(params["readout"], h[:, :, 0])[:, 0] * nmask
    gid = batch.get("graph_id")
    if gid is not None:
        return jax.ops.segment_sum(atom_e, gid, num_segments=n_graphs)
    return atom_e


def loss_fn(params, batch: Dict, cfg: GNNConfig, n_graphs: int):
    pred = forward(params, batch, cfg, n_graphs)
    target = batch["targets"].astype(jnp.float32)
    loss = jnp.mean((pred - target) ** 2)
    return loss, {"loss": loss, "mae": jnp.mean(jnp.abs(pred - target))}
