"""GIN (Xu et al., arXiv:1810.00826) — sum-aggregation SpMM + MLP.

h' = MLP( (1 + eps) h + sum_{u in N(v)} h_u ), eps learnable; graph-level
readout by per-layer sum pooling (jumping knowledge), linear classifier.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.models.gnn.common import gather, layer_norm, mlp_apply, mlp_init, scatter_sum


def init(rng, cfg: GNNConfig, d_in: int) -> Tuple[Dict, Dict]:
    keys = jax.random.split(rng, cfg.n_layers + 1)
    layers, logical_layers = [], []
    d_prev = d_in
    for i in range(cfg.n_layers):
        mlp, mlp_log = mlp_init(keys[i], (d_prev, cfg.d_hidden, cfg.d_hidden))
        layers.append({"mlp": mlp, "eps": jnp.zeros(())})
        logical_layers.append({"mlp": mlp_log, "eps": ()})
        d_prev = cfg.d_hidden
    w_out = jax.random.normal(keys[-1],
                              (cfg.n_layers * cfg.d_hidden, cfg.n_classes),
                              jnp.float32) / np.sqrt(cfg.n_layers * cfg.d_hidden)
    params = {"layers": layers, "readout": {"w": w_out,
                                            "b": jnp.zeros((cfg.n_classes,))}}
    logical = {"layers": logical_layers,
               "readout": {"w": (None, None), "b": (None,)}}
    return params, logical


def forward(params, batch: Dict, cfg: GNNConfig, n_graphs: int,
            node_level: bool = False) -> jnp.ndarray:
    x = batch["node_feat"]
    src, dst = batch["edge_src"], batch["edge_dst"]
    emask, nmask = batch["edge_mask"], batch["node_mask"]
    gid = batch.get("graph_id")
    n = x.shape[0]
    reps = []
    for lp in params["layers"]:
        agg = scatter_sum(gather(x, src), dst, n, emask)
        x = mlp_apply(lp["mlp"], (1.0 + lp["eps"]) * x + agg)
        x = layer_norm(x) * nmask[:, None]
        if node_level:
            reps.append(x)
        elif gid is not None:
            reps.append(jax.ops.segment_sum(x, gid, num_segments=n_graphs))
        else:
            reps.append(x.sum(axis=0, keepdims=True))
    h = jnp.concatenate(reps, axis=-1)
    return h @ params["readout"]["w"] + params["readout"]["b"]


def loss_fn(params, batch: Dict, cfg: GNNConfig, n_graphs: int,
            node_level: bool = False):
    logits = forward(params, batch, cfg, n_graphs, node_level).astype(jnp.float32)
    labels = batch["targets"]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = logz - gold
    correct = (logits.argmax(-1) == labels).astype(jnp.float32)
    if node_level:
        mask = batch["node_mask"].astype(jnp.float32)
        loss = jnp.sum(ce * mask) / jnp.maximum(mask.sum(), 1.0)
        acc = jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1.0)
    else:
        loss = jnp.mean(ce)
        acc = jnp.mean(correct)
    return loss, {"loss": loss, "accuracy": acc}
