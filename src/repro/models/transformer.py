"""Decoder-only LM transformer (dense + MoE), pure-function JAX.

Covers the assigned LM family: GQA (olmoe/kimi/gemma/qwen), QK-norm
(olmoe/gemma3/qwen3), QKV bias (qwen2.5), sliding-window + periodic-global
attention (gemma3), and MoE FFNs (olmoe, kimi-k2).

Layers are stacked along a leading ``L`` axis and driven by ``lax.scan`` —
keeps HLO size O(1) in depth (critical for 61-layer kimi at 512-device
dry-run compile) and makes remat policies uniform.

Entry points:
  init(rng, cfg)              -> (params, logical_axes)
  forward(params, tokens,...) -> logits (+ KV cache when requested)
  decode_step(params, cache, tokens) -> (logits, cache)
  make_train_step(cfg, optimizer)   -> jit-able train step
  init_cache(cfg, batch, max_len)   -> KV cache pytree
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.distributed.sharding import constrain
from repro.models import moe as moe_lib
from repro.models.layers import (
    apply_rope,
    attention,
    cross_entropy,
    dense_apply,
    rms_norm,
    rms_norm_nd,
    swiglu,
)

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}


def _dtype(cfg: LMConfig):
    return _DTYPES[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init(rng, cfg: LMConfig) -> Tuple[Dict, Dict]:
    dt = _dtype(cfg)
    d, H, KV, Dh, F, V, L = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.d_head, cfg.d_ff, cfg.vocab, cfg.n_layers)
    keys = jax.random.split(rng, 12)
    s_d = 1.0 / math.sqrt(d)

    def nrm(k, shape, scale):
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    attn = {
        "wq": nrm(keys[0], (L, d, H * Dh), s_d),
        "wk": nrm(keys[1], (L, d, KV * Dh), s_d),
        "wv": nrm(keys[2], (L, d, KV * Dh), s_d),
        "wo": nrm(keys[3], (L, H * Dh, d), 1.0 / math.sqrt(H * Dh)),
    }
    attn_logical = {
        "wq": (None, "fsdp", "model"),
        "wk": (None, "fsdp", "model"),
        "wv": (None, "fsdp", "model"),
        "wo": (None, "model", "fsdp"),
    }
    if cfg.attn_bias:
        attn["bq"] = jnp.zeros((L, H * Dh), dt)
        attn["bk"] = jnp.zeros((L, KV * Dh), dt)
        attn["bv"] = jnp.zeros((L, KV * Dh), dt)
        attn_logical.update(
            bq=(None, "model"), bk=(None, "model"), bv=(None, "model")
        )
    if cfg.qk_norm:
        attn["q_norm"] = jnp.ones((L, Dh), dt)
        attn["k_norm"] = jnp.ones((L, Dh), dt)
        attn_logical.update(q_norm=(None, None), k_norm=(None, None))

    if cfg.moe:
        E, Fe = cfg.moe.n_experts, cfg.moe.d_expert_ff
        ffn = {
            "router": {"w": nrm(keys[4], (L, d, E), s_d)},
            "gate": nrm(keys[5], (L, E, d, Fe), s_d),
            "up": nrm(keys[6], (L, E, d, Fe), s_d),
            "down": nrm(keys[7], (L, E, Fe, d), 1.0 / math.sqrt(Fe)),
        }
        ffn_logical = {
            "router": {"w": (None, "fsdp", None)},
            "gate": (None, "experts", "fsdp", None),
            "up": (None, "experts", "fsdp", None),
            "down": (None, "experts", None, "fsdp"),
        }
        if cfg.moe.n_shared:
            S = cfg.moe.n_shared
            ks = jax.random.split(keys[8], 3)
            ffn["shared"] = {
                "gate": nrm(ks[0], (L, S, d, Fe), s_d),
                "up": nrm(ks[1], (L, S, d, Fe), s_d),
                "down": nrm(ks[2], (L, S, Fe, d), 1.0 / math.sqrt(Fe)),
            }
            ffn_logical["shared"] = {
                "gate": (None, None, "fsdp", "model"),
                "up": (None, None, "fsdp", "model"),
                "down": (None, None, "model", "fsdp"),
            }
    else:
        ffn = {
            "gate": nrm(keys[4], (L, d, F), s_d),
            "up": nrm(keys[5], (L, d, F), s_d),
            "down": nrm(keys[6], (L, F, d), 1.0 / math.sqrt(F)),
        }
        ffn_logical = {
            "gate": (None, "fsdp", "ffn"),
            "up": (None, "fsdp", "ffn"),
            "down": (None, "ffn", "fsdp"),
        }

    params = {
        "embed": nrm(keys[9], (V, d), 1.0),
        "layers": {
            "attn": attn,
            "ffn": ffn,
            "ln1": jnp.ones((L, d), dt),
            "ln2": jnp.ones((L, d), dt),
        },
        "final_norm": {"scale": jnp.ones((d,), dt)},
    }
    logical = {
        "embed": ("vocab", "fsdp"),
        "layers": {
            "attn": attn_logical,
            "ffn": ffn_logical,
            "ln1": (None, None),
            "ln2": (None, None),
        },
        "final_norm": {"scale": (None,)},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = nrm(keys[10], (d, V), s_d)
        logical["lm_head"] = ("fsdp", "vocab")
    return params, logical


def is_global_layer(cfg: LMConfig) -> jnp.ndarray:
    """(L,) bool — True where the layer uses global (non-windowed) attention."""
    if cfg.sliding_window is None:
        return jnp.ones((cfg.n_layers,), dtype=bool)
    if cfg.global_every <= 0:
        return jnp.zeros((cfg.n_layers,), dtype=bool)
    idx = jnp.arange(cfg.n_layers)
    return (idx % cfg.global_every) == (cfg.global_every - 1)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer(cfg: LMConfig, x, lp, is_glob, q_offset=0, return_kv=False,
           unroll: bool = False):
    B, S, d = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ap = lp["attn"]

    h = rms_norm({"scale": lp["ln1"]}, x, cfg.norm_eps)
    q = h @ ap["wq"].astype(h.dtype)
    k = h @ ap["wk"].astype(h.dtype)
    v = h @ ap["wv"].astype(h.dtype)
    if cfg.attn_bias:
        q = q + ap["bq"].astype(h.dtype)
        k = k + ap["bk"].astype(h.dtype)
        v = v + ap["bv"].astype(h.dtype)
    q = constrain(q.reshape(B, S, H, Dh), "batch", None, "heads", None)
    k = constrain(k.reshape(B, S, KV, Dh), "batch", None, "kv_heads", None)
    v = constrain(v.reshape(B, S, KV, Dh), "batch", None, "kv_heads", None)
    if cfg.qk_norm:
        q = rms_norm_nd(ap["q_norm"], q, cfg.norm_eps)
        k = rms_norm_nd(ap["k_norm"], k, cfg.norm_eps)
    pos = q_offset + jnp.arange(S)
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)

    window_dyn = None
    if cfg.sliding_window is not None:
        big = jnp.asarray(1 << 30, dtype=jnp.int32)
        window_dyn = jnp.where(is_glob, big, cfg.sliding_window)
    o = attention(q, k, v, causal=True, window=None,
                  window_dynamic=window_dyn, chunk=cfg.attention_chunk,
                  unroll=unroll)
    x = x + o.reshape(B, S, H * Dh) @ ap["wo"].astype(x.dtype)
    x = constrain(x, "batch", None, None)

    h2 = rms_norm({"scale": lp["ln2"]}, x, cfg.norm_eps)
    fp = lp["ffn"]
    aux = {}
    if cfg.moe:
        flat = constrain(h2.reshape(B * S, d), "batch", None)
        y, aux = moe_lib.apply_auto(fp, flat, cfg.moe)
        y = y.reshape(B, S, d)
    else:
        h_ff = constrain(swiglu(h2 @ fp["gate"].astype(h2.dtype),
                                h2 @ fp["up"].astype(h2.dtype)),
                         "batch", None, "ffn")
        y = h_ff @ fp["down"].astype(h2.dtype)
    x = constrain(x + y, "batch", None, None)
    kv = (k, v) if return_kv else None
    return x, aux, kv


def forward(
    params: Dict,
    tokens: jnp.ndarray,            # (B, S) int32
    cfg: LMConfig,
    return_cache: bool = False,
    remat: bool = False,
    unroll: bool = False,           # full unroll (roofline analysis variant)
):
    dt = _dtype(cfg)
    x = constrain(params["embed"].astype(dt)[tokens], "batch", None, None)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    glob = is_global_layer(cfg)

    def body(carry, xs):
        lp, is_glob = xs
        x, aux_sum = carry
        x, aux, kv = _layer(cfg, x, lp, is_glob, return_kv=return_cache,
                            unroll=unroll)
        aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum} if aux else aux_sum
        return (x, aux_sum), kv

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )

    aux0 = {}
    if cfg.moe:
        aux0 = {"moe_aux_loss": 0.0, "moe_z_loss": 0.0, "moe_dropped_frac": 0.0}
    (x, aux_sum), kvs = jax.lax.scan(body, (x, aux0), (params["layers"], glob),
                                     unroll=cfg.n_layers if unroll else 1)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(x @ head.astype(x.dtype), "batch", None, "vocab")
    aux_mean = {k: v / cfg.n_layers for k, v in aux_sum.items()}
    if return_cache:
        k_stack, v_stack = kvs
        cache = {"k": k_stack, "v": v_stack,
                 "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
        return logits, aux_mean, cache
    return logits, aux_mean


# ---------------------------------------------------------------------------
# decode (one token against a KV cache)
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int) -> Dict:
    dt = _dtype(cfg)
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dt),
        "v": jnp.zeros(shape, dt),
        "pos": jnp.asarray(0, jnp.int32),
    }


def cache_logical_axes(cfg: LMConfig, long_context: bool = False) -> Dict:
    """KV-cache sharding: batch over data; sequence over whatever mesh axes
    remain (the rules dedupe per-array mesh-axis reuse, so batched decode's
    seq dim picks up only ``model`` while batch-1 long-context decode takes
    the full mesh).  kv_heads rarely divides the model axis (4-8 heads vs 16
    shards) — the divisibility fallback then drops it."""
    batch_axis = None if long_context else "batch"
    return {
        "k": (None, batch_axis, "kv_seq", "kv_heads", None),
        "v": (None, batch_axis, "kv_seq", "kv_heads", None),
        "pos": (),
    }


def decode_step(params: Dict, cache: Dict, tokens: jnp.ndarray, cfg: LMConfig,
                unroll: bool = False):
    """One decode step: tokens (B, 1) -> (logits (B, 1, V), new cache)."""
    dt = _dtype(cfg)
    B = tokens.shape[0]
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    x = params["embed"].astype(dt)[tokens]          # (B, 1, d)
    if cfg.tie_embeddings:
        x = x * math.sqrt(cfg.d_model)
    pos = cache["pos"]
    glob = is_global_layer(cfg)

    def body(x, xs):
        lp, k_cache, v_cache, is_glob = xs
        ap = lp["attn"]
        h = rms_norm({"scale": lp["ln1"]}, x, cfg.norm_eps)
        q = h @ ap["wq"].astype(h.dtype)
        k = h @ ap["wk"].astype(h.dtype)
        v = h @ ap["wv"].astype(h.dtype)
        if cfg.attn_bias:
            q = q + ap["bq"].astype(h.dtype)
            k = k + ap["bk"].astype(h.dtype)
            v = v + ap["bv"].astype(h.dtype)
        q = q.reshape(B, 1, H, Dh)
        k = k.reshape(B, 1, KV, Dh)
        v = v.reshape(B, 1, KV, Dh)
        if cfg.qk_norm:
            q = rms_norm_nd(ap["q_norm"], q, cfg.norm_eps)
            k = rms_norm_nd(ap["k_norm"], k, cfg.norm_eps)
        q = apply_rope(q, pos[None] + jnp.zeros((1,), jnp.int32), cfg.rope_theta)
        k = apply_rope(k, pos[None] + jnp.zeros((1,), jnp.int32), cfg.rope_theta)
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
        window_dyn = None
        if cfg.sliding_window is not None:
            big = jnp.asarray(1 << 30, dtype=jnp.int32)
            window_dyn = jnp.where(is_glob, big, cfg.sliding_window)
        o = attention(
            q, k_cache, v_cache, causal=True, q_offset=pos,
            window_dynamic=window_dyn, chunk=cfg.attention_chunk,
            kv_len=jnp.full((B,), pos + 1, jnp.int32), unroll=unroll,
        )
        x = x + o.reshape(B, 1, H * Dh) @ ap["wo"].astype(x.dtype)
        h2 = rms_norm({"scale": lp["ln2"]}, x, cfg.norm_eps)
        fp = lp["ffn"]
        if cfg.moe:
            y, _ = moe_lib.apply_auto(fp, h2.reshape(B, -1), cfg.moe)
            y = y.reshape(B, 1, -1)
        else:
            y = swiglu(h2 @ fp["gate"].astype(h2.dtype),
                       h2 @ fp["up"].astype(h2.dtype)) @ fp["down"].astype(h2.dtype)
        return x + y, (k_cache, v_cache)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], glob),
        unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = constrain(x @ head.astype(x.dtype), "batch", None, "vocab")
    return logits, {"k": k_new, "v": v_new, "pos": pos + 1}


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg: LMConfig, remat: bool = False,
            unroll: bool = False):
    logits, aux = forward(params, batch["tokens"], cfg, remat=remat,
                          unroll=unroll)
    loss = cross_entropy(logits, batch["labels"])
    total = loss
    for k in ("moe_aux_loss", "moe_z_loss"):
        if k in aux:
            total = total + aux[k]
    metrics = {"loss": loss, **aux}
    return total, metrics


def make_train_step(cfg: LMConfig, optimizer, remat: bool = True,
                    unroll: bool = False):
    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg, remat=remat, unroll=unroll),
            has_aux=True
        )(params)
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics["total_loss"] = loss
        return params, opt_state, metrics

    return train_step
