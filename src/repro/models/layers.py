"""Shared neural-net layers (pure functions over param pytrees).

Params are dicts of jnp arrays; each initializer returns ``(params,
logical_axes)`` where logical_axes mirrors the param tree with per-dim
logical names consumed by repro.distributed.sharding.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


def _split(rng, n):
    return jax.random.split(rng, n)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(rng, d_in: int, d_out: int, dtype, axes=("fsdp", "model"),
               bias: bool = False, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32) * scale
    params = {"w": w.astype(dtype)}
    logical = {"w": axes}
    if bias:
        params["b"] = jnp.zeros((d_out,), dtype=dtype)
        logical["b"] = (axes[1],)
    return params, logical


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def norm_init(d: int, dtype) -> Tuple[Dict, Dict]:
    return {"scale": jnp.ones((d,), dtype=dtype)}, {"scale": (None,)}


def rms_norm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def rms_norm_nd(scale, x, eps: float = 1e-6):
    """RMS norm with an explicit scale array (e.g. per-head QK-norm)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, d_head); positions: broadcastable to (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)              # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# blocked-softmax attention (memory-safe reference; the Pallas kernel in
# repro.kernels.flash_attention is the TPU-optimised twin)
# ---------------------------------------------------------------------------


NEG_INF = -1e30


def attention(
    q: jnp.ndarray,            # (B, S_q, H, D)
    k: jnp.ndarray,            # (B, S_kv, KV, D)
    v: jnp.ndarray,            # (B, S_kv, KV, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    chunk: int = 1024,
    kv_len: Optional[jnp.ndarray] = None,   # (B,) valid KV length (decode)
    window_dynamic: Optional[jnp.ndarray] = None,  # scalar overriding window
    unroll: bool = False,                    # unroll the KV-chunk scan
) -> jnp.ndarray:
    """Grouped-query attention with online-softmax over KV chunks.

    Memory per step is O(S_q * chunk) instead of O(S_q * S_kv) — this is what
    keeps 32k-token prefill lowerable without materialising the score matrix.
    """
    B, Sq, H, D = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    scale = 1.0 / math.sqrt(D)

    n_chunks = max(1, math.ceil(Skv / chunk))
    pad = n_chunks * chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, D)
    vc = v.reshape(B, n_chunks, chunk, KV, D)

    q_pos = q_offset + jnp.arange(Sq)

    def step(carry, inputs):
        m, l, acc = carry
        kb, vb, start = inputs
        kv_pos = start + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        mask = kv_pos[None, :] <= (q_pos[:, None] if causal else jnp.inf)
        if not causal:
            mask = jnp.ones((Sq, chunk), dtype=bool)
        w = window if window_dynamic is None else None
        if w is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - w)
        if window_dynamic is not None:
            mask = mask & (kv_pos[None, :] > q_pos[:, None] - window_dynamic)
        valid = kv_pos < Skv
        if kv_len is not None:
            validb = kv_pos[None, :] < kv_len[:, None]        # (B, chunk)
            maskb = mask[None, :, :] & validb[:, None, :]     # (B, Sq, chunk)
            s = jnp.where(maskb[:, :, None, None, :], s, NEG_INF)
        else:
            maskb = mask & valid[None, :]
            s = jnp.where(maskb[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, KV, G), NEG_INF, dtype=jnp.float32)
    l0 = jnp.zeros((B, Sq, KV, G), dtype=jnp.float32)
    acc0 = jnp.zeros((B, Sq, KV, G, D), dtype=jnp.float32)
    starts = jnp.arange(n_chunks) * chunk
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), starts),
        unroll=n_chunks if unroll else 1,
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def swiglu(gate: jnp.ndarray, up: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.silu(gate) * up


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean token cross-entropy in fp32 (logits (..., V), labels (...))."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
