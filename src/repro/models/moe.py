"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Switch/GShard-style (taxonomy §B.2): tokens are routed to their top-k
experts, laid out into an ``(experts, capacity, d)`` buffer via a sort by
expert id (O(T k log) — no (T, E) one-hot materialisation, which matters at
384 experts x 1M tokens), processed by per-expert SwiGLU FFNs, and combined
with router weights.  Tokens beyond an expert's capacity are dropped (their
residual stream passes through unchanged).

Two expert-parallel execution paths:

* ``apply``          — single-program scatter/gather.  Correct everywhere,
  but under GSPMD the global (T*K)-indexed scatter/gather cannot be
  partitioned: its gradient materialises full (T*K, d) fp32 tensors and
  all-reduces them (§Perf-K1 measured ~970 GB/step wire on kimi-k2 train).
* ``apply_sharded``  — shard_map expert parallelism (§Perf-K1 fix): experts
  live on their model shard, activations are already replicated across
  ``model``, each shard routes/dispatches purely locally and the combine is
  ONE psum of the (T_local, d) partial output — the same wire cost as any
  tensor-parallel layer.

``apply_auto`` picks the sharded path whenever a launch-layer mesh context
with a ``model`` axis is active (CPU unit tests see the plain path).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.distributed.sharding import _ACT_CTX, constrain
from repro.models.layers import dense_init, swiglu


def init(rng, d_model: int, cfg: MoEConfig, dtype) -> Tuple[Dict, Dict]:
    ks = jax.random.split(rng, 5)
    E, F = cfg.n_experts, cfg.d_expert_ff
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(F)
    params = {
        "router": {"w": (jax.random.normal(ks[0], (d_model, E), jnp.float32) * s_in)},
        "gate": jax.random.normal(ks[1], (E, d_model, F), jnp.float32) * s_in,
        "up": jax.random.normal(ks[2], (E, d_model, F), jnp.float32) * s_in,
        "down": jax.random.normal(ks[3], (E, F, d_model), jnp.float32) * s_out,
    }
    logical = {
        "router": {"w": ("fsdp", None)},
        "gate": ("experts", "fsdp", None),
        "up": ("experts", "fsdp", None),
        "down": ("experts", None, "fsdp"),
    }
    params = jax.tree.map(lambda x: x.astype(dtype), params)
    if cfg.n_shared:
        ks2 = jax.random.split(ks[4], 3)
        params["shared"] = {
            "gate": (jax.random.normal(ks2[0], (cfg.n_shared, d_model, F), jnp.float32) * s_in).astype(dtype),
            "up": (jax.random.normal(ks2[1], (cfg.n_shared, d_model, F), jnp.float32) * s_in).astype(dtype),
            "down": (jax.random.normal(ks2[2], (cfg.n_shared, F, d_model), jnp.float32) * s_out).astype(dtype),
        }
        logical["shared"] = {
            "gate": (None, "fsdp", "model"),
            "up": (None, "fsdp", "model"),
            "down": (None, "model", "fsdp"),
        }
    return params, logical


def apply(params, x: jnp.ndarray, cfg: MoEConfig,
          capacity: Optional[int] = None) -> Tuple[jnp.ndarray, Dict]:
    """x: (T, d) token-major. Returns (out (T, d), aux metrics/losses)."""
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = capacity or max(1, int(math.ceil(T * K / E * cfg.capacity_factor)))

    logits = (x @ params["router"]["w"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_p, topk_e = jax.lax.top_k(probs, K)                   # (T, K)
    topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # ---- sort-based dispatch ----
    flat_e = topk_e.reshape(-1)                                # (T*K,)
    order = jnp.argsort(flat_e)                                # stable
    sorted_e = flat_e[order]
    # rank within expert: position in sorted array minus expert start
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))         # (E,)
    rank = jnp.arange(T * K) - starts[sorted_e]
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, E * C)         # E*C = drop bin
    token_of = order // K

    buf = jnp.zeros((E * C + 1, d), dtype=x.dtype)
    buf = buf.at[slot].add(x[token_of])                        # scatter tokens
    buf = constrain(buf[: E * C].reshape(E, C, d), "experts", None, None)

    # ---- expert FFNs (grouped einsum over the expert dim) ----
    h = jnp.einsum("ecd,edf->ecf", buf, params["gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["up"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", swiglu(h, u), params["down"].astype(x.dtype))
    y = constrain(y, "experts", None, None)

    # ---- combine ----
    y_flat = y.reshape(E * C, d)
    w_sorted = topk_p.reshape(-1)[order].astype(x.dtype)
    gathered = jnp.where(
        keep[:, None], y_flat[jnp.minimum(slot, E * C - 1)], 0.0
    ) * w_sorted[:, None]
    out = constrain(
        jnp.zeros((T, d), dtype=x.dtype).at[token_of].add(gathered),
        "batch", None)

    if "shared" in params:
        sp = params["shared"]
        for i in range(sp["gate"].shape[0]):
            h = x @ sp["gate"][i].astype(x.dtype)
            u = x @ sp["up"][i].astype(x.dtype)
            out = out + swiglu(h, u) @ sp["down"][i].astype(x.dtype)

    # ---- router losses (Switch aux load-balance + z-loss) ----
    me = probs.mean(axis=0)                                    # (E,)
    ce = jnp.zeros((E,)).at[flat_e].add(1.0) / (T * K)
    aux_loss = cfg.aux_coef * E * jnp.sum(me * ce)
    z_loss = cfg.router_z_coef * jnp.mean(
        jax.scipy.special.logsumexp(logits, axis=-1) ** 2
    )
    dropped = 1.0 - keep.mean()
    aux = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": dropped,
    }
    return out, aux


# ---------------------------------------------------------------------------
# shard_map expert parallelism (§Perf-K1)
# ---------------------------------------------------------------------------


def apply_sharded(params, x: jnp.ndarray, cfg: MoEConfig, mesh, rules,
                  capacity: Optional[int] = None) -> Tuple[jnp.ndarray, Dict]:
    """Expert-parallel MoE via shard_map.

    x: (T, d), sharded over the batch axes and replicated over ``model``.
    Expert weights (E, d, F) are sharded over ``model``.  Each model shard
    routes its (replicated) tokens against the global router, keeps only
    the assignments that hit its local experts, runs the local expert FFNs,
    and contributes a partial (T_local, d) output; psum over ``model``
    completes the combine.  No global scatter/gather ever crosses shards.
    """
    T, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    batch_axes = rules.lookup("batch")
    batch_axes = (batch_axes,) if isinstance(batch_axes, str) else tuple(batch_axes)
    model_axis = "model"
    n_model = mesh.shape[model_axis]
    E_loc = E // n_model
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    T_loc = T // n_batch
    C = capacity or max(1, int(math.ceil(T_loc * K / E * cfg.capacity_factor)))

    x_spec = P(batch_axes, None)
    router_spec = P(None, None)
    ew_spec = P(model_axis, None, None)
    ew_spec_out = P(model_axis, None, None)

    def local_moe(xb, router_w, gate, up, down):
        # xb: (T_loc, d) — identical on every model shard of this data row
        my_rank = jax.lax.axis_index(model_axis)
        logits = (xb @ router_w.astype(xb.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topk_p, topk_e = jax.lax.top_k(probs, K)
        topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

        flat_e = topk_e.reshape(-1)
        flat_p = topk_p.reshape(-1)
        local_id = flat_e - my_rank * E_loc
        mine = (local_id >= 0) & (local_id < E_loc)

        order = jnp.argsort(jnp.where(mine, local_id, E_loc))
        sorted_lid = jnp.where(mine, local_id, E_loc)[order]
        starts = jnp.searchsorted(sorted_lid, jnp.arange(E_loc))
        rank = jnp.arange(T_loc * K) - starts[jnp.minimum(sorted_lid, E_loc - 1)]
        keep = (sorted_lid < E_loc) & (rank < C)
        slot = jnp.where(keep, sorted_lid * C + rank, E_loc * C)
        token_of = order // K

        buf = jnp.zeros((E_loc * C + 1, d), dtype=xb.dtype)
        buf = buf.at[slot].add(xb[token_of])
        buf = buf[: E_loc * C].reshape(E_loc, C, d)

        h = jnp.einsum("ecd,edf->ecf", buf, gate.astype(xb.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, up.astype(xb.dtype))
        y = jnp.einsum("ecf,efd->ecd", swiglu(h, u), down.astype(xb.dtype))

        y_flat = y.reshape(E_loc * C, d)
        w_sorted = flat_p[order].astype(xb.dtype)
        gathered = jnp.where(
            keep[:, None], y_flat[jnp.minimum(slot, E_loc * C - 1)], 0.0
        ) * w_sorted[:, None]
        partial = jnp.zeros((T_loc, d), dtype=xb.dtype).at[token_of].add(gathered)
        out = jax.lax.psum(partial, model_axis)

        # router losses (identical on all model shards; psum the kept count)
        me = probs.mean(axis=0)
        ce = jnp.zeros((E,)).at[flat_e].add(1.0) / (T_loc * K)
        aux_loss = jnp.asarray(cfg.aux_coef * E * jnp.sum(me * ce))
        z_loss = jnp.asarray(cfg.router_z_coef * jnp.mean(
            jax.scipy.special.logsumexp(logits, axis=-1) ** 2))
        kept = jax.lax.psum(keep.sum(), model_axis)
        dropped = 1.0 - kept.astype(jnp.float32) / (T_loc * K)
        return out, aux_loss[None], z_loss[None], dropped[None]

    shard_spec = P(batch_axes)
    out, aux_loss, z_loss, dropped = shard_map(
        local_moe, mesh=mesh,
        in_specs=(x_spec, router_spec, ew_spec, ew_spec, ew_spec_out),
        out_specs=(x_spec, shard_spec, shard_spec, shard_spec),
        check_rep=False,
    )(x, params["router"]["w"], params["gate"], params["up"], params["down"])
    aux_loss, z_loss, dropped = (aux_loss.mean(), z_loss.mean(), dropped.mean())

    if "shared" in params:
        sp = params["shared"]
        for i in range(sp["gate"].shape[0]):
            h = x @ sp["gate"][i].astype(x.dtype)
            u = x @ sp["up"][i].astype(x.dtype)
            out = out + swiglu(h, u) @ sp["down"][i].astype(x.dtype)

    aux = {
        "moe_aux_loss": aux_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": dropped,
    }
    return out, aux


def apply_auto(params, x: jnp.ndarray, cfg: MoEConfig) -> Tuple[jnp.ndarray, Dict]:
    """Sharded path when a mesh context with a model axis is active."""
    ctx = _ACT_CTX.get()
    if ctx is not None:
        mesh, rules = ctx
        if "model" in mesh.axis_names and cfg.n_experts % mesh.shape["model"] == 0:
            return apply_sharded(params, x, cfg, mesh, rules)
    return apply(params, x, cfg)
