"""Model substrate: LM transformers (dense + MoE), GNNs, DLRM."""
