"""Durable serving-state snapshots + mutation write-ahead journal.

Crash-safety for the serving subsystem is two complementary pieces:

* **Snapshots** (:class:`ServingSnapshotter`) — the full serving state,
  atomically published by generalising ``CheckpointManager``'s temp-dir +
  ``os.replace`` pattern (:func:`repro.train.checkpoint.atomic_dir_publish`):
  graph arrays, partition vector, frequency sketch, shard-map permutation,
  online-policy counters, the arrival-placement ``Pr`` prior, the swap
  engine's RNG state and the compacted mutation log with its version spans.
  :func:`capture_serving_state` copies everything on the worker thread
  (between micro-batches, when nothing is mutating); the write itself runs
  on a background thread, off the serving critical path — the same
  split-capture/async-write shape PR 4's ``begin_invocation`` /
  ``run_invocation`` overlap uses.  Each snapshot's ``arrays.npz`` carries a
  sha256 in the manifest, so a corrupted snapshot is *detected* at restore
  and the loader falls back to the next older one.

* **WAL** (:class:`MutationJournal`) — mutations are journaled on ingest,
  *before* they are applied: each drained coalesced group writes its member
  batches to an append-only, CRC-framed log, applies, then records the
  apply *outcome* (merged fold vs per-member fallback, per-member fates).
  A torn tail (crash mid-append) is truncated on re-open; replay stops at
  the first corrupt frame.  Restore = latest-readable snapshot + replay of
  the journal groups past the snapshot's ``journal_seq`` through
  ``OnlineTaper.apply_mutations`` — bitwise parity with a node that never
  crashed, because the exact apply stream (fold boundaries, version bumps,
  validation drops) and the arrival-placement inputs (partition prefix +
  restored ``Pr`` prior + swap-RNG state) are all reproduced.  Records
  covered by every *retained* snapshot are compacted away after each
  successful save.

* **Elastic restore** — ``restore_serving_state(..., n_shards=S)`` brings a
  snapshot up on a different shard count by re-folding the partition-dealt
  shard map with the existing movement-aware k→S fold
  (:func:`repro.graphs.sharded_packing.partition_shard_order`);
  :func:`plan_elastic_restore` budgets the byte movement with
  ``train.elastic``'s reshard-plan schema.
"""
from __future__ import annotations

import io
import json
import hashlib
import os
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graphs.graph import (
    LabelledGraph,
    MutationBatch,
    mutation_log_from_state,
    mutation_log_state,
)
from repro.train.checkpoint import atomic_dir_publish
from repro.train.elastic import movement_plan
from repro.utils import get_logger

log = get_logger("serve.snapshot")

SNAP_PREFIX = "snap_"
WAL_NAME = "wal.log"
_REC_MAGIC = b"TPR1"
_REC_HEADER = struct.Struct("<cQQ")  # kind, seq, payload length
_REC_CRC = struct.Struct("<I")
_KIND_GROUP = b"G"
_KIND_OUTCOME = b"O"


# ---------------------------------------------------------------------------
# mutation WAL
# ---------------------------------------------------------------------------


def _members_payload(members: Sequence[MutationBatch]) -> bytes:
    arrays: Dict[str, np.ndarray] = {"n": np.int64(len(members))}
    for i, b in enumerate(members):
        arrays[f"avl{i}"] = np.asarray(list(b.add_vertex_labels), np.int64)
        arrays[f"ae{i}"] = np.asarray(b.add_edges, np.int64).reshape(-1, 2)
        arrays[f"rme{i}"] = np.asarray(b.remove_edges, np.int64).reshape(-1, 2)
        arrays[f"rmv{i}"] = np.asarray(list(b.remove_vertices), np.int64)
        arrays[f"rl{i}"] = np.asarray(b.relabel, np.int64).reshape(-1, 2)
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    return buf.getvalue()


def _members_from_payload(payload: bytes) -> List[MutationBatch]:
    with np.load(io.BytesIO(payload)) as d:
        return [
            MutationBatch(
                add_vertex_labels=d[f"avl{i}"].copy(),
                add_edges=d[f"ae{i}"].copy(),
                remove_edges=d[f"rme{i}"].copy(),
                remove_vertices=d[f"rmv{i}"].copy(),
                relabel=d[f"rl{i}"].copy(),
            )
            for i in range(int(d["n"]))
        ]


class MutationJournal:
    """Append-only, CRC-framed write-ahead log of the serving loop's
    mutation *apply stream*.

    The journaling boundary is the ingest drain: right before the loop
    applies a coalesced group, the group's member batches are journaled
    (:meth:`append_group`, a ``G`` record); right after the apply, the
    *outcome* is journaled (:meth:`append_outcome`, an ``O`` record) —
    whether the merged fold applied in one shot or fell back to per-member
    application, and which members survived validation.  Replay reproduces
    the apply stream exactly — same coalesced folds, same per-batch version
    bumps, same validation drops — which is what bitwise recovery parity
    (graph version, mutation-log spans, packing caches) rests on.  A group
    with no outcome record (crash mid-apply) replays through the standard
    try-merged-then-members path, which is deterministic for everything but
    an injected fault — and a crashed apply has no live outcome to match.

    Frame: ``magic | kind | seq u64 | len u64 | payload | crc32(payload)``.
    Thread-safe; ``sync=True`` fsyncs every append (durability against
    power loss, not just process death).  Re-opening a journal with a torn
    tail truncates the partial frame so later appends stay readable."""

    def __init__(self, path, sync: bool = False):
        self.path = Path(path)
        self.sync = bool(sync)
        self._lock = threading.RLock()
        self._fh = None
        self._last_seq = 0
        self.appended = 0
        #: replication retention floor: records with ``seq > retain_floor``
        #: are still needed by a registered follower's tail replay, so
        #: :meth:`compact` never drops past it even when every retained
        #: snapshot already covers them (``None`` = no followers registered)
        self.retain_floor: Optional[int] = None
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if self.path.exists():
            records, end = self._scan()
            if records:
                self._last_seq = max(seq for _, seq, _ in records)
            if end < self.path.stat().st_size:
                log.warning(
                    "journal %s has a torn tail (%d of %d bytes valid); "
                    "truncating", self.path, end, self.path.stat().st_size)
                with open(self.path, "r+b") as fh:
                    fh.truncate(end)

    @property
    def last_seq(self) -> int:
        with self._lock:
            return self._last_seq

    def _scan(self) -> Tuple[List[Tuple[bytes, int, bytes]], int]:
        """All valid ``(kind, seq, payload)`` frames and the offset where
        validity ends (start of the torn/corrupt tail, or EOF)."""
        out: List[Tuple[bytes, int, bytes]] = []
        data = self.path.read_bytes() if self.path.exists() else b""
        off = 0
        frame = len(_REC_MAGIC) + _REC_HEADER.size
        while off + frame <= len(data):
            if data[off:off + len(_REC_MAGIC)] != _REC_MAGIC:
                break
            kind, seq, plen = _REC_HEADER.unpack_from(
                data, off + len(_REC_MAGIC))
            body = off + frame
            end = body + plen + _REC_CRC.size
            if end > len(data):
                break
            payload = data[body:body + plen]
            (crc,) = _REC_CRC.unpack_from(data, body + plen)
            if zlib.crc32(payload) != crc:
                break
            out.append((kind, int(seq), payload))
            off = end
        return out, off

    def _write(self, kind: bytes, seq: int, payload: bytes) -> None:
        if self._fh is None:
            self._fh = open(self.path, "ab")
        self._fh.write(_REC_MAGIC + _REC_HEADER.pack(kind, seq, len(payload))
                       + payload + _REC_CRC.pack(zlib.crc32(payload)))
        self._fh.flush()
        if self.sync:
            os.fsync(self._fh.fileno())
        self.appended += 1

    def append_group(self, members: Sequence[MutationBatch]) -> int:
        """Journal one coalesced group's member batches *before* they are
        applied; returns the group's sequence number (1-based)."""
        payload = _members_payload(members)
        with self._lock:
            seq = self._last_seq + 1
            self._write(_KIND_GROUP, seq, payload)
            self._last_seq = seq
            return seq

    def append_outcome(self, group_seq: int, mode: str,
                       applied: Sequence[bool]) -> None:
        """Journal how group ``group_seq`` actually applied: ``mode`` is
        ``"merged"`` (the fold applied in one shot) or ``"members"``
        (per-member fallback), ``applied`` flags each member's fate."""
        payload = json.dumps(
            {"mode": mode, "applied": [bool(a) for a in applied]}
        ).encode()
        with self._lock:
            self._write(_KIND_OUTCOME, int(group_seq), payload)

    def replay(self, after_seq: int = 0
               ) -> List[Tuple[int, List[MutationBatch],
                               Optional[Dict[str, Any]]]]:
        """Every journaled group with ``seq > after_seq``, in order, as
        ``(seq, members, outcome-or-None)``.  Stops (silently, by
        construction) at a torn/corrupt tail."""
        with self._lock:
            records, _ = self._scan()
        outcomes: Dict[int, Dict[str, Any]] = {}
        groups: List[Tuple[int, bytes]] = []
        for kind, seq, payload in records:
            if kind == _KIND_GROUP:
                groups.append((seq, payload))
            elif kind == _KIND_OUTCOME:
                outcomes[seq] = json.loads(payload.decode())
        return [(seq, _members_from_payload(p), outcomes.get(seq))
                for seq, p in groups if seq > int(after_seq)]

    def set_retain_floor(self, seq: Optional[int]) -> None:
        """Install the replication retention floor: ``min(acked seq)``
        across registered followers (the hub updates it every pump round).
        A lagging replica keeps its tail-replay window alive this way
        instead of being forced into a full snapshot re-fetch."""
        with self._lock:
            self.retain_floor = None if seq is None else int(seq)

    def compact(self, upto_seq: int) -> int:
        """Drop records with ``seq <= upto_seq`` (covered by every retained
        durable snapshot), rewriting the file atomically.  Returns how many
        records were dropped.  The replication retention floor
        (:meth:`set_retain_floor`) clamps the cut: records a registered
        follower has not acknowledged survive snapshot-driven pruning."""
        with self._lock:
            if self.retain_floor is not None:
                upto_seq = min(int(upto_seq), self.retain_floor)
            records, _ = self._scan()
            keep = [r for r in records if r[1] > int(upto_seq)]
            if len(keep) == len(records):
                return 0
            if self._fh is not None:
                self._fh.close()
                self._fh = None
            tmp = self.path.with_name(self.path.name + ".tmp")
            with open(tmp, "wb") as fh:
                for kind, seq, payload in keep:
                    fh.write(_REC_MAGIC
                             + _REC_HEADER.pack(kind, seq, len(payload))
                             + payload + _REC_CRC.pack(zlib.crc32(payload)))
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, self.path)
            return len(records) - len(keep)

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


# ---------------------------------------------------------------------------
# state capture
# ---------------------------------------------------------------------------


@dataclass
class ServingState:
    """One captured (host-side, already copied) serving state, ready to be
    written by :class:`ServingSnapshotter` on any thread."""

    arrays: Dict[str, np.ndarray]
    manifest: Dict[str, Any] = field(default_factory=dict)


def capture_serving_state(ot, journal_seq: int,
                          extra: Optional[Dict[str, Any]] = None
                          ) -> ServingState:
    """Copy the full serving state of an ``OnlineTaper`` (module doc).

    Must run where the graph and partition are quiescent — the serving
    worker between micro-batches, or any thread while the loop is stopped.
    ``journal_seq`` is the WAL sequence number of the last *applied*
    mutation batch: restore replays everything after it."""
    t0 = time.perf_counter()
    g = ot.g
    arrays: Dict[str, np.ndarray] = {
        "labels": g.labels.copy(),
        "src": g.src.copy(),
        "dst": g.dst.copy(),
        "row_ptr": g.row_ptr.copy(),
        "part": np.asarray(ot.part, np.int32).copy(),
        "dirty": ot._dirty.copy(),
    }
    mlog_arrays, mlog_meta = mutation_log_state(g.mutation_log)
    arrays.update(mlog_arrays)
    pr = ot.placement_pr()
    if pr is not None:
        arrays["placement_pr"] = np.asarray(pr, np.float64).copy()
    shard = ot.taper._pre.get("_shard_order")
    token = None
    n_shards = None
    if shard is not None and shard[1] is not None:
        token, pos = shard
        arrays["shard_pos"] = np.asarray(pos, np.int64).copy()
        n_shards = ot.taper._mesh_shards()
    manifest: Dict[str, Any] = {
        "format": 1,
        "kind": "serving_snapshot",
        # wall time is for humans reading the manifest; durations derived
        # from it would be skewed by NTP steps, so the capture cost is
        # measured separately on the monotonic clock and threaded into
        # ``ServingLoop.stats()`` as ``snapshot_capture_s``
        "time": time.time(),
        "wall_time_s": time.time(),
        "k": int(ot.k),
        "graph": {
            "n": int(g.n),
            "version": int(g.version),
            "label_names": list(g.label_names),
        },
        "journal_seq": int(journal_seq),
        "counters": {
            "tick": int(ot.tick),
            "invocations": int(ot.invocations),
            "last_invoke_tick": int(ot._last_invoke_tick),
            "freqs_at_invoke": dict(ot._freqs_at_invoke),
            "ipt_at_invoke": (None if ot._ipt_at_invoke is None
                              else float(ot._ipt_at_invoke)),
            "last_total_moves": (None if ot._last_total_moves is None
                                 else int(ot._last_total_moves)),
        },
        "sketch": ot.sketch.state_dict(),
        "rng_state": ot.taper._rng.bit_generator.state,
        "shard_order_token": token,
        "n_shards": n_shards,
        "field_backend": ot.taper.config.field_backend,
        "mutation_log": mlog_meta,
    }
    if extra:
        manifest["extra"] = dict(extra)
    manifest["capture_duration_s"] = time.perf_counter() - t0
    return ServingState(arrays=arrays, manifest=manifest)


# ---------------------------------------------------------------------------
# the snapshotter
# ---------------------------------------------------------------------------


class ServingSnapshotter:
    """Atomic, versioned serving snapshots with keep-N pruning, optional
    background writes (serialized, :class:`CheckpointManager`-style) and
    post-save WAL compaction."""

    def __init__(self, directory, keep: int = 3,
                 journal: Optional[MutationJournal] = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = int(keep)
        self.journal = journal
        self._save_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.saved = 0
        self.failures = 0
        self.last_wall_s = 0.0
        self.last_bytes = 0
        #: monotonic duration of the last state *capture* (host-side copy,
        #: from the manifest) vs ``last_wall_s``, the publish duration —
        #: the two halves of the snapshot cost surfaced in ``stats()``
        self.last_capture_s = 0.0

    # -- inventory -----------------------------------------------------------
    def all_ids(self) -> List[int]:
        out = []
        for p in self.dir.glob(SNAP_PREFIX + "*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_id(self) -> Optional[int]:
        ids = self.all_ids()
        return ids[-1] if ids else None

    # -- save ----------------------------------------------------------------
    def save(self, state: ServingState, sync: bool = True) -> None:
        """Persist one captured state.  ``sync=False`` writes on a
        background thread (one at a time — a second async save joins the
        first, like the fixed ``CheckpointManager``); the capture is already
        a copy, so the caller may keep mutating immediately."""
        with self._save_lock:
            self.last_capture_s = float(
                state.manifest.get("capture_duration_s", 0.0))
            if self._thread is not None:
                self._thread.join()
                self._thread = None
            if sync:
                self._write(state)
            else:
                self._thread = threading.Thread(
                    target=self._write_guarded, args=(state,),
                    name="serve-snapshot", daemon=True)
                self._thread.start()

    def wait(self) -> None:
        with self._save_lock:
            t, self._thread = self._thread, None
        if t is not None:
            t.join()

    def close(self) -> None:
        self.wait()

    def _write_guarded(self, state: ServingState) -> None:
        try:
            self._write(state)
        except BaseException:
            self.failures += 1
            log.exception("background serving snapshot failed")

    def _write(self, state: ServingState) -> None:
        t0 = time.perf_counter()
        ids = self.all_ids()
        snap_id = (ids[-1] + 1) if ids else 1

        def writer(tmp: Path) -> None:
            np.savez(tmp / "arrays.npz", **state.arrays)
            digest = hashlib.sha256(
                (tmp / "arrays.npz").read_bytes()).hexdigest()
            manifest = dict(state.manifest)
            manifest["snap_id"] = snap_id
            manifest["arrays_sha256"] = digest
            (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))

        final = atomic_dir_publish(self.dir, f"{SNAP_PREFIX}{snap_id:010d}",
                                   writer)
        self._gc()
        self._compact_journal()
        self.saved += 1
        self.last_wall_s = time.perf_counter() - t0
        self.last_bytes = sum(
            f.stat().st_size for f in final.iterdir() if f.is_file())
        log.info("serving snapshot %d saved in %.3fs (%d bytes)",
                 snap_id, self.last_wall_s, self.last_bytes)

    def _gc(self) -> None:
        import shutil

        for sid in self.all_ids()[: -self.keep]:
            shutil.rmtree(self.dir / f"{SNAP_PREFIX}{sid:010d}",
                          ignore_errors=True)

    def _compact_journal(self) -> None:
        """Drop WAL records every retained snapshot already covers.  Uses
        the *minimum* retained ``journal_seq`` so corruption fallback to an
        older snapshot still finds its replay tail intact."""
        if self.journal is None:
            return
        seqs = []
        for sid in self.all_ids():
            try:
                m = json.loads(
                    (self.dir / f"{SNAP_PREFIX}{sid:010d}" /
                     "manifest.json").read_text())
                seqs.append(int(m["journal_seq"]))
            except Exception:
                # unreadable manifest: assume it covers nothing (seq 0), so
                # compaction never outruns what fallback could need
                seqs.append(0)
        if seqs:
            self.journal.compact(min(seqs))


def load_serving_snapshot(directory, snap_id: Optional[int] = None
                          ) -> Tuple[Dict[str, Any], Dict[str, np.ndarray]]:
    """``(manifest, arrays)`` of the newest *readable* snapshot.

    Verifies the manifest's sha256 over ``arrays.npz``; a corrupt or
    unreadable snapshot (fault injection, partial disk failure) is skipped
    with a warning and the next older one is tried — recovery degrades to
    an older state plus a longer journal replay instead of failing."""
    directory = Path(directory)
    ids = ([int(snap_id)] if snap_id is not None else
           sorted((int(p.name.split("_")[1])
                   for p in directory.glob(SNAP_PREFIX + "*")
                   if (p / "manifest.json").exists()), reverse=True))
    last_err: Optional[BaseException] = None
    for sid in ids:
        path = directory / f"{SNAP_PREFIX}{sid:010d}"
        try:
            manifest = json.loads((path / "manifest.json").read_text())
            blob = (path / "arrays.npz").read_bytes()
            digest = hashlib.sha256(blob).hexdigest()
            if digest != manifest.get("arrays_sha256"):
                raise ValueError(
                    f"checksum mismatch in {path.name}/arrays.npz")
            with np.load(io.BytesIO(blob)) as data:
                arrays = {k: data[k].copy() for k in data.files}
            return manifest, arrays
        except BaseException as exc:
            last_err = exc
            log.warning("snapshot %s unreadable (%s); falling back",
                        path.name, exc)
    raise FileNotFoundError(
        f"no readable serving snapshot under {directory}"
        + (f" (last error: {last_err})" if last_err else ""))


# ---------------------------------------------------------------------------
# restore
# ---------------------------------------------------------------------------


@dataclass
class RestoreResult:
    """Outcome of :func:`restore_serving_state`."""

    ot: Any                       # the reconstructed OnlineTaper
    snap_id: int
    journal_seq: int              # last WAL seq applied (snapshot + replay)
    replayed: int                 # journal batches re-applied
    replay_failed: int            # journal batches dropped (failed live too)
    replay_wall_s: float
    manifest: Dict[str, Any]
    elastic_plan: Optional[Dict[str, Any]] = None


def plan_elastic_restore(g: LabelledGraph, part: np.ndarray,
                         old_shards: int, new_shards: int,
                         block_n: int = 128) -> Dict[str, Any]:
    """Byte-movement budget for restoring onto a different shard count S —
    ``train.elastic.plan_reshard``'s schema over the serving state.  The
    transfer estimate is movement-aware: only vertices whose shard changes
    under the k→S re-fold ship their degree-proportional state."""
    from repro.graphs.sharded_packing import shard_assignment

    old = shard_assignment(part, old_shards, block_n)
    new = shard_assignment(part, new_shards, block_n)
    moved = old != new
    deg = g.degrees
    total_bytes = (g.labels.nbytes + g.src.nbytes + g.dst.nbytes
                   + g.row_ptr.nbytes + np.asarray(part).nbytes)
    # per moved vertex: its CSR adjacency slice (src+dst int32 pairs) plus
    # its fixed row (label, partition id, row_ptr entry)
    est = int(np.sum(deg[moved]) * 8 + int(moved.sum()) * 16)
    plan = movement_plan(total_bytes, old_shards, new_shards, est)
    plan["moved_vertices"] = int(moved.sum())
    plan["moved_frac"] = float(moved.mean()) if moved.size else 0.0
    return plan


def apply_journal_group(ot, members: Sequence[MutationBatch],
                        outcome: Optional[Dict[str, Any]]) -> Tuple[int, int]:
    """Re-apply one journaled coalesced group to an ``OnlineTaper`` exactly
    as the live node applied it; returns ``(applied, failed)`` batch counts.

    This is the one replay fold shared by crash restore
    (:func:`restore_serving_state`) and WAL-shipping replication
    (``serve.replication.FollowerReplica``): a recorded ``"members"``
    outcome (poisoned fold) reproduces the per-member fates verbatim — an
    injected fault is not re-raised by replay, so the ``O`` record, not
    re-execution, is the authority — while a merged outcome (or a missing
    one, crash mid-apply) retraces the deterministic
    try-fold-then-members path."""
    from repro.serve.ingest import coalesce_groups

    applied = failed = 0
    if outcome is not None and outcome.get("mode") == "members":
        for m, ok in zip(members, outcome.get("applied", ())):
            if ok:
                ot.apply_mutations(m)
                applied += 1
            else:
                failed += 1
    else:
        for merged, mem in coalesce_groups(members):
            try:
                ot.apply_mutations(merged)
                applied += 1
            except ValueError:
                for m in mem:
                    try:
                        ot.apply_mutations(m)
                        applied += 1
                    except ValueError:
                        failed += 1
    return applied, failed


def restore_serving_state(
    directory,
    taper_config=None,
    policy=None,
    n_shards: Optional[int] = None,
    snap_id: Optional[int] = None,
    replay: bool = True,
) -> RestoreResult:
    """Rebuild an ``OnlineTaper`` from the latest readable snapshot plus a
    WAL replay (module doc).  ``n_shards`` re-folds the saved shard map onto
    a different S (elastic restore); device packings are *not* rebuilt here
    — callers rewarm via ``ServingLoop._warm_devices`` (or lazily on the
    first field evaluation)."""
    from repro.core.online import OnlineTaper
    from repro.workload.sketch import FrequencySketch

    directory = Path(directory)
    manifest, arrays = load_serving_snapshot(directory, snap_id)
    gm = manifest["graph"]
    g = LabelledGraph(
        n=int(gm["n"]),
        labels=arrays["labels"],
        label_names=list(gm["label_names"]),
        src=arrays["src"],
        dst=arrays["dst"],
        row_ptr=arrays["row_ptr"].astype(np.int64),
        version=int(gm["version"]),
    )
    g._mutation_log = mutation_log_from_state(
        arrays, manifest.get("mutation_log", []))
    ot = OnlineTaper(
        g, int(manifest["k"]),
        part=arrays["part"],
        config=taper_config,
        policy=policy,
        sketch=FrequencySketch.from_state(manifest["sketch"]),
    )
    c = manifest["counters"]
    ot.tick = int(c["tick"])
    ot.invocations = int(c["invocations"])
    ot._last_invoke_tick = int(c["last_invoke_tick"])
    ot._freqs_at_invoke = dict(c["freqs_at_invoke"])
    ot._ipt_at_invoke = (None if c["ipt_at_invoke"] is None
                         else float(c["ipt_at_invoke"]))
    ot._last_total_moves = (None if c["last_total_moves"] is None
                            else int(c["last_total_moves"]))
    ot._dirty = arrays["dirty"].astype(bool).copy()
    rng_state = manifest.get("rng_state")
    if rng_state is not None:
        ot.taper._rng.bit_generator.state = rng_state
    if "placement_pr" in arrays:
        ot.restore_placement_prior(arrays["placement_pr"])

    elastic_plan = None
    saved_shards = manifest.get("n_shards")
    token = manifest.get("shard_order_token")
    if "shard_pos" in arrays:
        pos = arrays["shard_pos"].astype(np.int64)
        if (n_shards is not None and saved_shards
                and int(n_shards) != int(saved_shards)):
            from repro.graphs.sharded_packing import partition_shard_order

            elastic_plan = plan_elastic_restore(
                g, ot.part, int(saved_shards), int(n_shards))
            pos = partition_shard_order(ot.part, int(n_shards))
            token = f"partition:restore{manifest['snap_id']}s{int(n_shards)}"
        ot.taper._pre["_shard_order"] = (token, pos)
    elif (n_shards is not None
          and ot.taper.config.shard_map_source == "partition"):
        from repro.graphs.sharded_packing import partition_shard_order

        ot.taper._pre["_shard_order"] = (
            f"partition:restore{manifest['snap_id']}s{int(n_shards)}",
            partition_shard_order(ot.part, int(n_shards)))

    replayed = replay_failed = 0
    replay_wall = 0.0
    journal_seq = int(manifest["journal_seq"])
    wal = directory / WAL_NAME
    if replay and wal.exists():
        t0 = time.perf_counter()
        for seq, members, outcome in MutationJournal(wal).replay(
                after_seq=journal_seq):
            ok, bad = apply_journal_group(ot, members, outcome)
            replayed += ok
            replay_failed += bad
            journal_seq = seq
        replay_wall = time.perf_counter() - t0
    log.info(
        "restored serving state: snapshot %d (graph v%d, n=%d), replayed "
        "%d journal batches (%d dropped) in %.3fs",
        manifest["snap_id"], g.version, g.n, replayed, replay_failed,
        replay_wall)
    return RestoreResult(
        ot=ot,
        snap_id=int(manifest["snap_id"]),
        journal_seq=journal_seq,
        replayed=replayed,
        replay_failed=replay_failed,
        replay_wall_s=replay_wall,
        manifest=manifest,
        elastic_plan=elastic_plan,
    )
