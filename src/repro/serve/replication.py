"""WAL-shipping replication: follower replicas, epoch fencing, retention.

The single-node serving loop is crash-safe (PR 6: snapshots + mutation
WAL), but a dead node still means downtime until replay finishes.  This
module turns the same durability artefacts into *replication*: follower
replicas that bootstrap exactly the way a restarted node does (snapshot
fetch + journal tail replay, :func:`repro.serve.snapshot.restore_serving_state`)
and then stay current by applying the primary's WAL stream as it is
written, shipped frame-by-frame over an injectable in-memory transport.

Three frame kinds flow primary → follower over a :class:`ShipChannel`:

* ``"group"`` — one journaled coalesced mutation group, members plus its
  ``O``-record outcome, stamped with its WAL ``seq``.  Followers apply it
  through :func:`repro.serve.snapshot.apply_journal_group`, the *same*
  fold crash restore uses, so a follower is bitwise-identical to the
  primary at every shipped seq (graph arrays, version, mutation-log
  spans, dirty bits, arrival placements).
* ``"commit"`` — an invocation commit's full volatile state (partition
  vector, RNG state, placement ``Pr`` prior, dirty bits, counters).
  Commits are *not* in the WAL (snapshot-on-commit covers single-node
  restore), so replication ships them explicitly; a follower adopts the
  payload only once its ``applied_seq`` reaches the frame's seq, keeping
  the partition vector and the graph in lock-step.  Commit frames carry a
  hub-assigned monotone ``commit_index``.
* ``"heartbeat"`` — primary liveness + applied seq/version/commit index;
  drives follower gap detection and the coordinator's failover timer.

**Loss recovery.**  The channel is deliberately unreliable (fault sites:
drop, delay, reorder, link partition — ``serve.faults``).  Followers
buffer out-of-order frames and apply strictly in order; a persistent gap
triggers a *tail resync*: group frames are re-read from the primary's
journal (:meth:`ReplicationHub.tail`) and commit frames from the hub's
retained list.  That is why WAL compaction must respect the replication
retention floor (``MutationJournal.set_retain_floor``, fed from
``min(acked seq)`` across followers): a lagging replica tail-replays
instead of re-fetching a snapshot.  Only when the journal has been
compacted past a follower's position (:class:`JournalGap` — e.g. the
follower was down across many snapshots) does it fall back to a full
re-bootstrap.

**Epoch fencing.**  The hub owns a monotone ``current_epoch`` (a
Raft-style term) and is the write-lease authority: the primary calls
:meth:`ReplicationHub.authorize` before every durable write — journaling
an ingest group, committing an invocation, publishing a snapshot.  A node
holding a stale epoch (a *zombie*: deposed but still running) gets
:class:`FencedWrite` and must drop the write; a partitioned primary is
refused the same way (lease semantics: a primary that cannot reach the
cluster stops accepting writes, so its state stays a consistent prefix
and it can later rejoin as a follower by pure catch-up replay).  On
failover the new primary publishes a *forced* commit frame (the epoch-
opening no-op) broadcasting its full commit-volatile state, which
re-converges every follower — including the demoted zombie, whose RNG may
have advanced inside an aborted invocation run — to bitwise parity.
"""
from __future__ import annotations

import copy
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serve.faults import (
    FaultInjector,
    InjectedFault,
    SITE_LINK_PARTITION,
    SITE_REPLICA_APPLY,
    SITE_REPLICA_SERVE,
    SITE_SHIP_DELAY,
    SITE_SHIP_DROP,
    SITE_SHIP_REORDER,
)
from repro.serve.snapshot import (
    MutationJournal,
    _members_from_payload,
    _members_payload,
    apply_journal_group,
    restore_serving_state,
)
from repro.utils import get_logger

log = get_logger("serve.replication")

KIND_GROUP = "group"
KIND_COMMIT = "commit"
KIND_HEARTBEAT = "heartbeat"


class FencedWrite(RuntimeError):
    """A durable write was rejected by the epoch fence (stale epoch, or a
    partitioned primary whose write lease lapsed)."""

    def __init__(self, stale_epoch: int, current_epoch: int, what: str = "",
                 partitioned: bool = False):
        self.stale_epoch = int(stale_epoch)
        self.current_epoch = int(current_epoch)
        self.what = what
        self.partitioned = bool(partitioned)
        if partitioned:
            msg = (f"write lease lost (link partitioned) at epoch "
                   f"{stale_epoch}: {what or 'write'} rejected")
        else:
            msg = (f"stale epoch {stale_epoch} (cluster at {current_epoch}): "
                   f"{what or 'write'} rejected")
        super().__init__(msg)


class JournalGap(RuntimeError):
    """Tail replay is impossible: the journal was compacted past the
    follower's position — a full snapshot re-bootstrap is required."""


@dataclass
class Frame:
    """One shipped replication frame (module doc for the three kinds)."""

    kind: str
    epoch: int
    #: WAL seq anchor: the group's own seq, or (commit/heartbeat) the
    #: primary's applied seq when the frame was emitted
    seq: int
    payload: Dict[str, Any] = field(default_factory=dict)
    #: hub-assigned monotone index (commit frames only)
    commit_index: int = 0
    #: epoch-opening commit emitted at promotion: applies by commit_index
    #: order like any other, but marks the re-convergence point
    force: bool = False


# ---------------------------------------------------------------------------
# commit-state shipping
# ---------------------------------------------------------------------------


def commit_payload(ot) -> Dict[str, Any]:
    """Copy everything an invocation commit touches that the WAL does not
    carry — the payload of a ``"commit"`` frame.  Captured on the primary
    right after ``commit_invocation`` (graph quiescent)."""
    pr = ot.placement_pr()
    return {
        "part": np.asarray(ot.part, np.int32).copy(),
        "dirty": np.asarray(ot._dirty, bool).copy(),
        "rng_state": copy.deepcopy(ot.taper._rng.bit_generator.state),
        "pr": None if pr is None else np.asarray(pr, np.float64).copy(),
        "invocations": int(ot.invocations),
        "tick": int(ot.tick),
        "last_invoke_tick": int(ot._last_invoke_tick),
        "freqs_at_invoke": dict(ot._freqs_at_invoke),
        "ipt_at_invoke": (None if ot._ipt_at_invoke is None
                          else float(ot._ipt_at_invoke)),
        "last_total_moves": (None if ot._last_total_moves is None
                             else int(ot._last_total_moves)),
        "version": int(ot.g.version),
        "n": int(ot.g.n),
    }


def adopt_commit_payload(ot, p: Dict[str, Any]) -> None:
    """Install a shipped commit payload on a replica's ``OnlineTaper``.
    Only valid at the commit's emission point in the stream — the replica's
    graph must match the payload's vertex count (the drain's total-order
    gating guarantees this; a covered stale commit is skipped there)."""
    if int(p["n"]) != int(ot.g.n):
        raise ValueError(
            f"commit payload for n={p['n']} vertices cannot apply to a "
            f"replica at n={ot.g.n} (apply the group stream first)")
    ot.part = np.asarray(p["part"], np.int32).copy()
    ot._dirty = np.asarray(p["dirty"], bool).copy()
    ot.taper._rng.bit_generator.state = copy.deepcopy(p["rng_state"])
    # the shipped Pr is the primary's post-commit placement prior; a stale
    # local field memo (a rejoining demoted primary has one) must not
    # shadow it, or arrival placements would diverge from the cluster
    ot.taper._field_memo = None
    ot.restore_placement_prior(p["pr"])
    ot.invocations = int(p["invocations"])
    ot.tick = int(p["tick"])
    ot._last_invoke_tick = int(p["last_invoke_tick"])
    ot._freqs_at_invoke = dict(p["freqs_at_invoke"])
    ot._ipt_at_invoke = (None if p["ipt_at_invoke"] is None
                         else float(p["ipt_at_invoke"]))
    ot._last_total_moves = (None if p["last_total_moves"] is None
                            else int(p["last_total_moves"]))


# ---------------------------------------------------------------------------
# transport
# ---------------------------------------------------------------------------


def _fire_site(faults: Optional[FaultInjector], site: str, name: str) -> None:
    """Fire a fault site both per-target (``site:name``) and bare."""
    if faults is None:
        return
    faults.fire(f"{site}:{name}")
    faults.fire(site)


class ShipChannel:
    """In-memory, deliberately unreliable primary→follower frame stream.

    Fault sites (armed bare or qualified ``"<site>:<name>"``) reinterpret
    the armed spec as network behaviour: ``ship_drop`` loses the frame,
    ``ship_delay`` holds it one extra poll round (late, out-of-order
    delivery), ``ship_reorder`` swaps it with the next frame.  A link
    partition (``set_partitioned`` or an armed ``link_partition`` site)
    blackholes the channel: sends are refused and frames in flight are
    lost, so healing requires the follower's tail-resync path."""

    def __init__(self, name: str, faults: Optional[FaultInjector] = None):
        self.name = name
        self._faults = faults
        self._lock = threading.Lock()
        self._inbox: List[Frame] = []
        #: (frame, polls until release): delayed frames surface *after*
        #: frames sent later, which is exactly the reorder the follower's
        #: sequence buffer must absorb
        self._delayed: List[List[Any]] = []
        self._swap: Optional[Frame] = None
        self.partitioned = False
        self.sent = 0
        self.dropped = 0
        self.delayed = 0
        self.reordered = 0
        self.blocked = 0
        #: highest group seq ever handed to send() (shipped, not acked)
        self.last_shipped_seq = 0
        #: optional circuit breaker (``serve.control.Breaker``, wired by
        #: the coordinator when control loops are on): an open link
        #: fast-fails the send instead of feeding a blackhole — the frame
        #: is still counted lost, and the follower's tail-resync path
        #: repairs the gap once the breaker's half-open probe succeeds
        self.breaker = None
        self.breaker_fastfail = 0

    def set_partitioned(self, flag: bool = True) -> None:
        self.partitioned = bool(flag)

    def _blackholed(self) -> bool:
        if self.partitioned:
            return True
        f = self._faults
        return f is not None and (
            f.armed(f"{SITE_LINK_PARTITION}:{self.name}")
            or f.armed(SITE_LINK_PARTITION))

    def send(self, frame: Frame) -> bool:
        """Ship one frame; returns False when the transport lost it."""
        if frame.kind == KIND_GROUP:
            self.last_shipped_seq = max(self.last_shipped_seq, int(frame.seq))
        if self.breaker is not None and not self.breaker.allow():
            # open link: don't even attempt the transport — the loss is
            # identical to a blackhole, but counted as a fast-fail and the
            # half-open probe (the first allowed send) re-tests the link
            self.breaker_fastfail += 1
            self.blocked += 1
            return False
        if self._blackholed():
            self.blocked += 1
            if self.breaker is not None:
                self.breaker.record_failure()
            return False
        try:
            _fire_site(self._faults, SITE_SHIP_DROP, self.name)
        except InjectedFault:
            self.dropped += 1
            if self.breaker is not None:
                self.breaker.record_failure()
            return False
        held = False
        try:
            _fire_site(self._faults, SITE_SHIP_DELAY, self.name)
        except InjectedFault:
            held = True
        reorder = False
        if not held:
            try:
                _fire_site(self._faults, SITE_SHIP_REORDER, self.name)
            except InjectedFault:
                reorder = True
        with self._lock:
            self.sent += 1
            if held:
                self.delayed += 1
                self._delayed.append([frame, 2])
            elif reorder:
                self.reordered += 1
                self._swap = frame
            else:
                self._inbox.append(frame)
                if self._swap is not None:
                    # the swapped-out frame lands *after* this newer one
                    self._inbox.append(self._swap)
                    self._swap = None
        if self.breaker is not None:
            self.breaker.record_success()
        return True

    def flush_in_flight(self) -> int:
        """Drop everything queued but undelivered (a crashed receiver's
        buffers die with its process).  Returns how many frames were lost."""
        with self._lock:
            lost = (len(self._inbox) + len(self._delayed)
                    + (1 if self._swap is not None else 0))
            self.dropped += lost
            self._inbox.clear()
            self._delayed.clear()
            self._swap = None
            return lost

    def receive(self) -> List[Frame]:
        """Drain deliverable frames (follower poll).  A blackholed link
        delivers nothing and loses whatever was in flight."""
        with self._lock:
            if self._blackholed():
                lost = (len(self._inbox) + len(self._delayed)
                        + (1 if self._swap is not None else 0))
                if lost:
                    self.dropped += lost
                    self._inbox.clear()
                    self._delayed.clear()
                    self._swap = None
                return []
            out = self._inbox
            self._inbox = []
            still: List[List[Any]] = []
            for item in self._delayed:
                item[1] -= 1
                if item[1] <= 0:
                    out.append(item[0])
                else:
                    still.append(item)
            self._delayed = still
            return out


# ---------------------------------------------------------------------------
# the hub
# ---------------------------------------------------------------------------


class ReplicationHub:
    """Cluster-side replication authority: epoch fence + write lease,
    per-follower ship channels, the retained commit-frame list, ack
    tracking, and journal-backed tail replay (module doc)."""

    def __init__(self, journal: Optional[MutationJournal] = None,
                 faults: Optional[FaultInjector] = None):
        self.journal = journal
        self._faults = faults
        self._lock = threading.RLock()
        self.current_epoch = 1
        self.channels: Dict[str, ShipChannel] = {}
        self._acked: Dict[str, int] = {}
        self._commits: List[Frame] = []
        self.primary_seq = int(journal.last_seq) if journal is not None else 0
        self.primary_version = 0
        self.last_heartbeat_mono = time.monotonic()
        self.heartbeats = 0
        self.stale_heartbeats = 0
        self.fencing_rejections = 0
        self.partition_rejections = 0
        self.last_stale_epoch: Optional[int] = None
        #: primary link state: True = the current primary cannot reach the
        #: cluster (its heartbeats are lost and its write lease lapses)
        self.primary_partitioned = False
        self.epochs_advanced = 0

    # -- membership ----------------------------------------------------------
    def register(self, name: str) -> ShipChannel:
        with self._lock:
            ch = ShipChannel(name, self._faults)
            self.channels[name] = ch
            self._acked.setdefault(name, 0)
            return ch

    def unregister(self, name: str) -> None:
        with self._lock:
            self.channels.pop(name, None)
            self._acked.pop(name, None)

    # -- fencing -------------------------------------------------------------
    def authorize(self, epoch: int, what: str = "") -> None:
        """Write-lease check: raises :class:`FencedWrite` for a stale epoch
        (zombie) or while the primary link is partitioned (lease lapsed)."""
        with self._lock:
            if int(epoch) != self.current_epoch:
                self.fencing_rejections += 1
                self.last_stale_epoch = int(epoch)
                raise FencedWrite(epoch, self.current_epoch, what)
            if self.primary_partitioned:
                self.partition_rejections += 1
                raise FencedWrite(epoch, self.current_epoch, what,
                                  partitioned=True)

    def advance_epoch(self) -> int:
        """Open a new epoch (failover).  Clears the partition flag — the
        promotee is on the cluster side of the partition by construction —
        and resets the heartbeat timer."""
        with self._lock:
            self.current_epoch += 1
            self.epochs_advanced += 1
            self.primary_partitioned = False
            self.last_heartbeat_mono = time.monotonic()
            return self.current_epoch

    def partition_primary(self, flag: bool = True) -> None:
        with self._lock:
            self.primary_partitioned = bool(flag)

    # -- primary-side publishing ---------------------------------------------
    def _broadcast(self, frame: Frame) -> None:
        with self._lock:
            channels = list(self.channels.values())
        for ch in channels:
            ch.send(frame)

    def heartbeat(self, epoch: int, applied_seq: int, version: int) -> bool:
        """Primary liveness beacon; ignored (counted) from a stale epoch or
        across a partitioned link, which is what starts the failover clock."""
        with self._lock:
            if int(epoch) != self.current_epoch or self.primary_partitioned:
                self.stale_heartbeats += 1
                return False
            self.heartbeats += 1
            self.last_heartbeat_mono = time.monotonic()
            self.primary_seq = max(self.primary_seq, int(applied_seq))
            self.primary_version = max(self.primary_version, int(version))
            frame = Frame(
                kind=KIND_HEARTBEAT, epoch=self.current_epoch,
                seq=int(applied_seq),
                payload={"version": int(version),
                         "commit_index": len(self._commits)})
        self._broadcast(frame)
        return True

    def publish_group(self, epoch: int, seq: int,
                      members: Sequence, mode: str,
                      applied: Sequence[bool], version_after: int,
                      trace_id: Optional[str] = None) -> Frame:
        """Ship one just-journaled-and-applied mutation group (the loop
        calls this right after writing the ``O`` record).  The frame
        carries the primary's commit index at publish time: a follower
        missing an earlier commit frame holds the group back (total-order
        gating) instead of applying past the commit's emission point.
        ``trace_id`` piggybacks the originating ingest trace on the frame
        so follower applies join it."""
        self.authorize(epoch, "group ship")
        with self._lock:
            frame = Frame(
                kind=KIND_GROUP, epoch=int(epoch), seq=int(seq),
                payload={
                    "members": _members_payload(members),
                    "mode": mode,
                    "applied": [bool(a) for a in applied],
                    "version_after": int(version_after),
                    "commit_index": len(self._commits),
                    **({"trace_id": str(trace_id)}
                       if trace_id is not None else {}),
                })
            self.primary_seq = max(self.primary_seq, int(seq))
            self.primary_version = max(self.primary_version,
                                       int(version_after))
        self._broadcast(frame)
        return frame

    def publish_commit(self, epoch: int, payload: Dict[str, Any],
                       seq: int, force: bool = False) -> Frame:
        """Ship one invocation commit's volatile state.  ``force=True`` is
        the promotion-time epoch-opening frame."""
        self.authorize(epoch, "invocation commit")
        with self._lock:
            frame = Frame(
                kind=KIND_COMMIT, epoch=int(epoch), seq=int(seq),
                payload=payload, commit_index=len(self._commits) + 1,
                force=force)
            self._commits.append(frame)
        self._broadcast(frame)
        return frame

    # -- follower-side acks / retention ---------------------------------------
    def ack(self, name: str, applied_seq: int) -> None:
        with self._lock:
            if name in self._acked:
                self._acked[name] = max(self._acked[name], int(applied_seq))

    def acked(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._acked)

    def update_retention(self,
                         include: Optional[Sequence[str]] = None) -> None:
        """Push ``min(acked seq)`` across (live) followers into the journal
        as its compaction floor.  ``include`` restricts the floor to the
        named followers — the coordinator passes only live ones, so a dead
        replica (which will re-bootstrap anyway) cannot pin the WAL."""
        if self.journal is None:
            return
        with self._lock:
            names = (list(self._acked) if include is None
                     else [n for n in include if n in self._acked])
            floor = min((self._acked[n] for n in names), default=None) \
                if names else None
        self.journal.set_retain_floor(floor)

    def tail(self, after_seq: int, after_commit_index: int) -> List[Frame]:
        """Everything a gapped follower needs, re-read from durable state:
        group frames from the journal past ``after_seq`` (outcome records
        authoritative), retained commit frames past ``after_commit_index``,
        plus a closing heartbeat.  Raises :class:`JournalGap` when the
        journal no longer reaches back to ``after_seq``."""
        with self._lock:
            epoch = self.current_epoch
            pseq = self.primary_seq
            pver = self.primary_version
            commits = [f for f in self._commits
                       if f.commit_index > int(after_commit_index)]
            n_commits = len(self._commits)
            commit_seqs = [int(f.seq) for f in self._commits]
        frames: List[Frame] = []
        if self.journal is not None:
            groups = self.journal.replay(after_seq=int(after_seq))
            if groups and groups[0][0] != int(after_seq) + 1:
                raise JournalGap(
                    f"journal starts at seq {groups[0][0]}, follower needs "
                    f"{int(after_seq) + 1} (compacted past it)")
            if not groups and pseq > int(after_seq):
                raise JournalGap(
                    f"journal empty but primary is at seq {pseq}, follower "
                    f"at {int(after_seq)}")
            for seq, members, outcome in groups:
                oc = outcome or {}
                frames.append(Frame(
                    kind=KIND_GROUP, epoch=epoch, seq=int(seq),
                    payload={
                        "members": _members_payload(members),
                        "mode": oc.get("mode", "merged"),
                        "applied": oc.get("applied",
                                          [True] * len(members)),
                        # journal-sourced frames carry no version stamp;
                        # the follower skips the integrity check for them
                        "version_after": None,
                        # reconstruct the publish-time gate: a commit at
                        # seq < s was emitted before this group
                        "commit_index": sum(
                            1 for cs in commit_seqs if cs < int(seq)),
                    }))
        frames.extend(commits)
        frames.append(Frame(
            kind=KIND_HEARTBEAT, epoch=epoch, seq=pseq,
            payload={"version": pver, "commit_index": n_commits}))
        return frames

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "epoch": self.current_epoch,
                "epochs_advanced": self.epochs_advanced,
                "fencing_rejections": self.fencing_rejections,
                "partition_rejections": self.partition_rejections,
                "last_stale_epoch": (-1 if self.last_stale_epoch is None
                                     else self.last_stale_epoch),
                "primary_seq": self.primary_seq,
                "primary_version": self.primary_version,
                "heartbeats": self.heartbeats,
                "stale_heartbeats": self.stale_heartbeats,
                "acked": dict(self._acked),
                "retained_commits": len(self._commits),
            }

    def collect(self) -> Dict[str, Any]:
        """Metrics-registry collector (``stats`` is already numeric apart
        from per-follower nesting, which the registry flattens)."""
        return self.stats()


# ---------------------------------------------------------------------------
# follower replica
# ---------------------------------------------------------------------------


class FollowerReplica:
    """One read-serving replica driven by the shipped WAL stream.

    Holds a full copy of the serving state (every replica can answer any
    query — that is what fallback and hedging lean on); stays current by
    applying ``group`` frames strictly in seq order and ``commit`` frames
    in commit-index order once their seq is reached, buffering whatever
    arrives early.  A gap that survives ``resync_after_polls`` polls (or
    a detected jump) triggers a tail resync from the hub; a journal gap
    triggers a full re-bootstrap from the latest snapshot."""

    def __init__(self, ot, hub: ReplicationHub, name: str,
                 directory=None, taper_config=None, policy=None,
                 applied_seq: int = 0, commit_index: int = 0,
                 faults: Optional[FaultInjector] = None,
                 resync_after_polls: int = 2):
        self.ot = ot
        self.hub = hub
        self.name = name
        self.directory = directory
        self._taper_config = taper_config
        self._policy = policy
        self._faults = faults if faults is not None else hub._faults
        self.resync_after_polls = int(resync_after_polls)
        self.channel = hub.register(name)
        from repro.workload.executor import QueryExecutor

        self.executor = QueryExecutor(ot.g)
        self.applied_seq = int(applied_seq)
        self.commit_index = int(commit_index)
        self.alive = True
        self.crash_error: Optional[BaseException] = None
        self._gbuf: Dict[int, Frame] = {}
        self._cbuf: Dict[int, Frame] = {}
        self.known_primary_seq = self.applied_seq
        self.known_primary_version = int(ot.g.version)
        self.known_commit_index = self.commit_index
        self.known_epoch = hub.current_epoch
        self.applied_groups = 0
        self.applied_commits = 0
        self.covered_commits = 0
        self.tail_resyncs = 0
        self.full_resyncs = 0
        self.serve_errors = 0
        self.served = 0
        self._gap_polls = 0
        self._desynced = False
        #: observability hooks (wired by the cluster coordinator): the
        #: tracer joins frame-borne trace ids so a follower's apply shows
        #: up inside the originating ingest/commit trace; the recorder
        #: captures resync/rebootstrap transitions
        self.tracer = None
        self.recorder = None

    def _join_span(self, name: str, trace_id, **attrs):
        """Span joined to a frame-borne trace id (None → no span)."""
        if self.tracer is None or not trace_id:
            return None
        ctx = self.tracer.join(trace_id)
        return self.tracer.start(name, ctx, replica=self.name, **attrs)

    # -- bootstrap -----------------------------------------------------------
    @classmethod
    def bootstrap(cls, hub: ReplicationHub, name: str, directory,
                  taper_config=None, policy=None,
                  faults: Optional[FaultInjector] = None,
                  resync_after_polls: int = 2) -> "FollowerReplica":
        """Join the cluster the way a restarted node recovers: latest
        readable snapshot + journal tail replay, then catch up through the
        hub to the live head."""
        res = restore_serving_state(directory, taper_config=taper_config,
                                    policy=policy)
        ci = cls._covered_commit_index(hub, res.ot.invocations,
                                       res.journal_seq)
        f = cls(res.ot, hub, name, directory=directory,
                taper_config=taper_config, policy=policy,
                applied_seq=res.journal_seq, commit_index=ci,
                faults=faults, resync_after_polls=resync_after_polls)
        f.catch_up()
        return f

    @staticmethod
    def _covered_commit_index(hub: ReplicationHub, invocations: int,
                              journal_seq: int) -> int:
        """Highest retained commit index a freshly restored snapshot
        already includes (its invocation counter and seq both cover the
        frame); later commits apply through the normal buffered path."""
        with hub._lock:
            idx = [f.commit_index for f in hub._commits
                   if int(f.payload.get("invocations", 0)) <= int(invocations)
                   and int(f.seq) <= int(journal_seq)]
        return max(idx, default=0)

    # -- state ---------------------------------------------------------------
    @property
    def g(self):
        return self.ot.g

    @property
    def seq_lag(self) -> int:
        return max(0, int(self.hub.primary_seq) - self.applied_seq)

    @property
    def version_lag(self) -> int:
        """Staleness bound in graph versions — the mutation log's version
        span between the primary's head and this replica (each applied
        batch bumps the version exactly once, so this is also the number
        of un-applied mutation batches)."""
        return max(0, int(self.hub.primary_version) - int(self.ot.g.version))

    # -- frame stream --------------------------------------------------------
    def poll(self) -> int:
        """Drain the channel and apply what is contiguous; escalate a
        persistent gap to a tail resync.  Returns frames applied.  An
        injected ``replica_apply`` raise crashes the replica (it stops
        applying, serving and acking until :meth:`rejoin`)."""
        if not self.alive:
            return 0
        try:
            self._ingest_frames(self.channel.receive())
            progress = self._drain()
            if self._desynced:
                self._rebootstrap()
                self.full_resyncs += 1
                progress += 1
            elif self._behind():
                self._gap_polls += 1
                if self._gap_polls >= self.resync_after_polls:
                    progress += self._resync()
            else:
                self._gap_polls = 0
        except InjectedFault as exc:
            self.alive = False
            self.crash_error = exc
            log.warning("replica %s crashed: %s", self.name, exc)
            return 0
        if not self.channel._blackholed():
            self.hub.ack(self.name, self.applied_seq)
        return progress

    def catch_up(self) -> int:
        """Poll, then force an immediate tail resync if still behind —
        promotion, rejoin and the router's staleness gate call this.
        Unlike a passive poll this reads the head position straight off
        the hub: a freshly (re)registered channel has received no frames
        yet, so a rejoining node would otherwise believe it is current."""
        if not self.alive:
            return 0
        if not self.channel._blackholed():
            with self.hub._lock:
                self.known_primary_seq = max(self.known_primary_seq,
                                             int(self.hub.primary_seq))
                self.known_primary_version = max(
                    self.known_primary_version,
                    int(self.hub.primary_version))
                self.known_commit_index = max(self.known_commit_index,
                                              len(self.hub._commits))
        n = self.poll()
        if self.alive and self._behind():
            try:
                n += self._resync()
            except InjectedFault as exc:
                self.alive = False
                self.crash_error = exc
                return n
            if not self.channel._blackholed():
                self.hub.ack(self.name, self.applied_seq)
        return n

    def _behind(self) -> bool:
        return (bool(self._gbuf)
                or self.known_primary_seq > self.applied_seq
                or self.known_commit_index > self.commit_index)

    def _ingest_frames(self, frames: List[Frame]) -> None:
        for f in frames:
            self.known_primary_seq = max(self.known_primary_seq, int(f.seq))
            self.known_epoch = max(self.known_epoch, int(f.epoch))
            if f.kind == KIND_GROUP:
                if f.seq > self.applied_seq:
                    self._gbuf[int(f.seq)] = f
                va = f.payload.get("version_after")
                if va is not None:
                    self.known_primary_version = max(
                        self.known_primary_version, int(va))
            elif f.kind == KIND_COMMIT:
                if f.commit_index > self.commit_index:
                    self._cbuf[int(f.commit_index)] = f
                self.known_commit_index = max(self.known_commit_index,
                                              int(f.commit_index))
            elif f.kind == KIND_HEARTBEAT:
                self.known_primary_version = max(
                    self.known_primary_version,
                    int(f.payload.get("version", 0)))
                self.known_commit_index = max(
                    self.known_commit_index,
                    int(f.payload.get("commit_index", 0)))

    def _drain(self) -> int:
        """Apply buffered frames in the primary's total order.  Commits are
        checked first: a commit emitted at seq ``s`` applies as soon as the
        replica has reached ``s``.  A group frame is held back while its
        publish-time ``commit_index`` exceeds the replica's — applying it
        would grow the graph past a missing commit's emission point; the
        gap registers as :meth:`_behind` and a tail resync delivers the
        commit.  A *covered* stale commit (payload ``n`` below the current
        graph — only the restore-from-older-snapshot edge produces one) is
        skipped by advancing ``commit_index`` without adopting."""
        n = 0
        while True:
            cf = self._cbuf.get(self.commit_index + 1)
            if cf is not None and int(cf.seq) <= self.applied_seq:
                self._cbuf.pop(self.commit_index + 1)
                if int(cf.payload.get("n", self.ot.g.n)) < int(self.ot.g.n):
                    self.commit_index = int(cf.commit_index)
                    self.covered_commits += 1
                else:
                    self._apply_commit(cf)
                n += 1
                continue
            gf = self._gbuf.get(self.applied_seq + 1)
            if gf is not None and int(
                    gf.payload.get("commit_index",
                                   self.commit_index)) <= self.commit_index:
                self._gbuf.pop(self.applied_seq + 1)
                self._apply_group(gf)
                n += 1
                continue
            break
        # a resync may have overtaken buffered duplicates
        for s in [s for s in self._gbuf if s <= self.applied_seq]:
            del self._gbuf[s]
        for ci in [ci for ci in self._cbuf if ci <= self.commit_index]:
            del self._cbuf[ci]
        return n

    def _apply_group(self, f: Frame) -> None:
        _fire_site(self._faults, SITE_REPLICA_APPLY, self.name)
        sp = self._join_span("replica.apply", f.payload.get("trace_id"),
                             seq=int(f.seq))
        members = _members_from_payload(f.payload["members"])
        outcome = {"mode": f.payload.get("mode", "merged"),
                   "applied": f.payload.get("applied",
                                            [True] * len(members))}
        apply_journal_group(self.ot, members, outcome)
        self.applied_seq = int(f.seq)
        self.applied_groups += 1
        if sp is not None:
            sp.end(members=len(members))
        va = f.payload.get("version_after")
        if va is not None and int(va) != int(self.ot.g.version):
            # bitwise-parity invariant broken (should be impossible): a
            # full re-bootstrap is the only safe recovery
            log.error(
                "replica %s desynced at seq %d: version %d != shipped %d",
                self.name, self.applied_seq, self.ot.g.version, int(va))
            self._desynced = True
        else:
            self.known_primary_version = max(
                self.known_primary_version, int(self.ot.g.version))

    def _apply_commit(self, f: Frame) -> None:
        _fire_site(self._faults, SITE_REPLICA_APPLY, self.name)
        sp = self._join_span("replica.commit", f.payload.get("trace_id"),
                             commit_index=int(f.commit_index),
                             epoch=int(f.epoch), force=bool(f.force))
        adopt_commit_payload(self.ot, f.payload)
        self.commit_index = int(f.commit_index)
        self.applied_commits += 1
        if sp is not None:
            sp.end()

    def _resync(self) -> int:
        """Tail resync: re-fetch the missing stream from durable state.
        Silently impossible across a partitioned link (the hub is on the
        other side); falls back to a full re-bootstrap on a journal gap."""
        self._gap_polls = 0
        if self.channel._blackholed():
            return 0
        try:
            frames = self.hub.tail(self.applied_seq, self.commit_index)
        except JournalGap:
            self._rebootstrap()
            self.full_resyncs += 1
            return 1
        self._ingest_frames(frames)
        n = self._drain()
        self.tail_resyncs += 1
        if self.recorder is not None:
            self.recorder.record("tail_resync", replica=self.name,
                                 applied_seq=self.applied_seq,
                                 frames=len(frames))
        return n

    def _rebootstrap(self) -> None:
        if self.directory is None:
            raise RuntimeError(
                f"replica {self.name} needs a full re-bootstrap but has no "
                "snapshot directory")
        if self.recorder is not None:
            self.recorder.record("full_resync", replica=self.name,
                                 applied_seq=self.applied_seq)
        res = restore_serving_state(self.directory,
                                    taper_config=self._taper_config,
                                    policy=self._policy)
        from repro.workload.executor import QueryExecutor

        self.ot = res.ot
        self.executor = QueryExecutor(res.ot.g)
        self.applied_seq = int(res.journal_seq)
        self.commit_index = self._covered_commit_index(
            self.hub, res.ot.invocations, res.journal_seq)
        self._gbuf.clear()
        self._cbuf.clear()
        self._desynced = False
        # the snapshot + its journal tail land us at the WAL head; pending
        # commit frames arrive from the hub's retained list
        try:
            self._ingest_frames(
                self.hub.tail(self.applied_seq, self.commit_index))
        except JournalGap:
            pass
        self._drain()

    # -- reads ---------------------------------------------------------------
    def serve(self, queries, max_results: int = 32):
        """Execute a read micro-batch against this replica's state (its own
        partition vector — at parity this is bitwise the primary's answer;
        behind it, a bounded-staleness answer)."""
        if not self.alive:
            raise RuntimeError(f"replica {self.name} is down")
        try:
            _fire_site(self._faults, SITE_REPLICA_SERVE, self.name)
        except InjectedFault:
            self.serve_errors += 1
            raise
        res = self.executor.enumerate_paths_many(
            queries, max_results=max_results, part=self.ot.part)
        self.served += len(queries)
        return res

    # -- lifecycle -----------------------------------------------------------
    def crash(self) -> None:
        """Test hook: kill the replica (stops applying/serving/acking).
        Frames in flight die with the process."""
        self.alive = False
        self.channel.flush_in_flight()

    def rejoin(self, reuse_state: bool = False) -> None:
        """Bring a crashed replica back.  ``reuse_state=False`` models a
        lost process: re-bootstrap from the latest snapshot + journal tail;
        ``True`` keeps the memory image (the fence/apply invariants make it
        a consistent prefix) and catches up.  Either way, nothing shipped
        during the outage survives in the transport — recovery must come
        from durable state (tail replay or snapshot), never from a
        conveniently-preserved network buffer."""
        self.channel.flush_in_flight()
        self.crash_error = None
        self.alive = True
        if not reuse_state:
            self._rebootstrap()
        self.catch_up()

    def stats(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "alive": int(self.alive),
            "applied_seq": self.applied_seq,
            "shipped_seq": self.channel.last_shipped_seq,
            "seq_lag": self.seq_lag,
            "version_lag": self.version_lag,
            "commit_index": self.commit_index,
            "applied_groups": self.applied_groups,
            "applied_commits": self.applied_commits,
            "covered_commits": self.covered_commits,
            "tail_resyncs": self.tail_resyncs,
            "full_resyncs": self.full_resyncs,
            "serve_errors": self.serve_errors,
            "served": self.served,
            "channel_dropped": self.channel.dropped,
            "channel_delayed": self.channel.delayed,
            "channel_reordered": self.channel.reordered,
            "channel_blocked": self.channel.blocked,
            "channel_breaker_fastfail": self.channel.breaker_fastfail,
        }

    def collect(self) -> Dict[str, Any]:
        """Metrics-registry collector (the non-numeric ``name`` field is
        dropped by the registry's flattening)."""
        return self.stats()
