"""Bounded admission queue with micro-batching and backpressure.

The request path of the serving subsystem (see ``serve/README.md``): callers
:meth:`RequestQueue.submit` individual RPQ requests; admission is O(1) and
either returns a :class:`ServeTicket` (a completion handle the caller can
wait on) or — when the queue is at ``max_depth`` — a :class:`Rejection`
carrying a *retry hint*: the estimated time for the current backlog to
drain, derived from an EWMA of recent per-request service time.  Rejecting
at admission instead of queueing unboundedly is what turns an overloaded
serving loop into backpressure the client can act on.

The serving loop drains requests in *micro-batches*
(:meth:`RequestQueue.take_batch`): up to ``max_batch`` requests leave
together so the executor can share per-query enumeration work across the
batch (``QueryExecutor.enumerate_paths_many``)."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

from repro.core.rpq import RPQ


@dataclass
class Rejection:
    """Admission refused: the queue is full.  ``retry_after_s`` estimates
    when the backlog will have drained enough to admit again."""

    retry_after_s: float
    queue_depth: int
    reason: str = "queue_full"

    @property
    def accepted(self) -> bool:
        return False


@dataclass
class ServeTicket:
    """Completion handle for one admitted request."""

    query: RPQ
    submitted_s: float
    done: threading.Event = field(default_factory=threading.Event)
    paths: Optional[List[Tuple[int, ...]]] = None
    ipt: int = 0
    latency_s: float = 0.0

    @property
    def accepted(self) -> bool:
        return True

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.done.wait(timeout)

    def complete(self, paths, ipt: int) -> None:
        self.paths = paths
        self.ipt = int(ipt)
        self.latency_s = time.perf_counter() - self.submitted_s
        self.done.set()


class RequestQueue:
    """Thread-safe bounded FIFO of :class:`ServeTicket` with micro-batch
    draining and a service-rate EWMA for retry hints."""

    def __init__(self, max_depth: int = 256, ewma_alpha: float = 0.2,
                 initial_service_s: float = 1e-3):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = int(max_depth)
        self._items: List[ServeTicket] = []
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._ewma_alpha = float(ewma_alpha)
        # seeded optimistic; the first completed batches correct it
        self._service_s = float(initial_service_s)
        self.submitted = 0
        self.rejected = 0

    # -- admission -----------------------------------------------------------
    def submit(self, query: RPQ) -> Union[ServeTicket, Rejection]:
        """Admit one request or reject with a backlog-drain retry hint."""
        with self._lock:
            depth = len(self._items)
            if depth >= self.max_depth:
                self.rejected += 1
                return Rejection(
                    retry_after_s=max(depth, 1) * self._service_s,
                    queue_depth=depth)
            ticket = ServeTicket(query=query, submitted_s=time.perf_counter())
            self._items.append(ticket)
            self.submitted += 1
            self._nonempty.notify()
            return ticket

    # -- draining ------------------------------------------------------------
    def take_batch(self, max_batch: int,
                   timeout: Optional[float] = 0.0) -> List[ServeTicket]:
        """Remove and return up to ``max_batch`` requests (FIFO order).

        ``timeout=0`` (the default) polls; ``timeout > 0`` blocks up to that
        many seconds for the queue to become non-empty; ``timeout=None``
        blocks until a request arrives.  Returns whatever is queued the
        moment it is non-empty — micro-batches fill from backlog, they do
        not wait to fill up, so an idle system serves single requests at
        low latency.
        """
        with self._nonempty:
            if not self._items:
                if timeout is None:
                    while not self._items:
                        self._nonempty.wait()
                elif timeout > 0:
                    self._nonempty.wait(timeout)
            batch = self._items[:max_batch]
            del self._items[:len(batch)]
            return batch

    def record_service_time(self, per_request_s: float) -> None:
        """Fold one batch's measured per-request service time into the EWMA
        that backs admission retry hints."""
        a = self._ewma_alpha
        with self._lock:
            self._service_s = (1 - a) * self._service_s + a * float(
                per_request_s)

    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def estimated_service_s(self) -> float:
        return self._service_s
