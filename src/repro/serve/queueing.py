"""Bounded admission queue with micro-batching and backpressure.

The request path of the serving subsystem (see ``serve/README.md``): callers
:meth:`RequestQueue.submit` individual RPQ requests; admission is O(1) and
either returns a :class:`ServeTicket` (a completion handle the caller can
wait on) or — when the queue is at ``max_depth`` — a :class:`Rejection`
carrying a *retry hint*: the estimated time for the current backlog to
drain, derived from an EWMA of recent per-request service time.  Rejecting
at admission instead of queueing unboundedly is what turns an overloaded
serving loop into backpressure the client can act on.

**Admission classes.**  An optional ``admission_weight`` hook (the serving
loop wires it to the ``FrequencySketch``'s per-query frequency) grades
backpressure by query heat — hot queries are cheap to serve (their
enumeration plan and traversal-count DP rows are warm), so under pressure
they are admitted ahead of cold ones: the top ``hot_reserve_frac`` of the
queue only admits queries at least as hot as the EWMA of recently admitted
weights (colder ones get a ``"cold_backpressure"`` rejection), and every
rejection's retry hint is scaled by relative heat — hot queries are told
to come back sooner, cold ones later, so the retry traffic itself arrives
pre-sorted by admission priority.  Without the hook behaviour is exactly
the unweighted PR-4 queue.

**Brownout shedding.**  The queue also carries a controller-driven *shed
level* (``serve.control.BrownoutController`` owns it; the queue itself
never changes it).  Each request declares an SLO class (``cls=``,
default ``"hot"``); at shed level ``L`` of ``max_shed_level``, classes in
``shed_classes`` see their admission zone shrink to the bottom
``1 - L/max_shed_level`` of the queue — and at the top level they are
rejected outright — with a ``"brownout"`` rejection whose retry hint is
stretched by ``1 + L``, so shed traffic backs off harder the deeper the
brownout.  Level 0 (the default) is byte-identical to the un-shed queue.

The serving loop drains requests in *micro-batches*
(:meth:`RequestQueue.take_batch`): up to ``max_batch`` requests leave
together so the executor can share per-query enumeration work across the
batch (``QueryExecutor.enumerate_paths_many``).  Draining is multi-worker
safe: ``take_batch`` removes its batch atomically under the queue lock, so
N executor workers (``ServeLoopConfig.n_workers``) pull disjoint batches
from the one shared queue with no further coordination — each ticket is
completed by exactly one worker."""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple, Union

from repro.core.rpq import RPQ
from repro.obs.trace import NOOP_SPAN, NOOP_TRACE, TraceContext


@dataclass
class Rejection:
    """Admission refused: the queue is full.  ``retry_after_s`` estimates
    when the backlog will have drained enough to admit again."""

    retry_after_s: float
    queue_depth: int
    reason: str = "queue_full"

    @property
    def accepted(self) -> bool:
        return False


@dataclass
class ServeTicket:
    """Completion handle for one admitted request."""

    query: RPQ
    submitted_s: float
    #: SLO class declared at submit (brownout shedding + per-class SLOs)
    cls: str = "hot"
    done: threading.Event = field(default_factory=threading.Event)
    paths: Optional[List[Tuple[int, ...]]] = None
    ipt: int = 0
    latency_s: float = 0.0
    #: trace context opened at admission; carried with the ticket so the
    #: drain/enumeration spans on another thread join the request's trace
    trace: TraceContext = NOOP_TRACE
    #: the root "request" span; ended (with latency/ipt attrs) at complete()
    span: Any = NOOP_SPAN

    @property
    def accepted(self) -> bool:
        return True

    def wait(self, timeout: Optional[float] = None) -> bool:
        return self.done.wait(timeout)

    def complete(self, paths, ipt: int) -> None:
        self.paths = paths
        self.ipt = int(ipt)
        self.latency_s = time.perf_counter() - self.submitted_s
        self.span.end(latency_s=self.latency_s, ipt=self.ipt,
                      n_paths=len(paths) if paths is not None else 0)
        self.done.set()


class RequestQueue:
    """Thread-safe bounded FIFO of :class:`ServeTicket` with micro-batch
    draining, a service-rate EWMA for retry hints, and optional
    frequency-weighted admission classes (module docstring)."""

    #: retry-hint scale clamp: a hint is never stretched/compressed by more
    #: than this factor relative to the unweighted backlog-drain estimate
    HINT_SCALE_MAX = 4.0

    def __init__(self, max_depth: int = 256, ewma_alpha: float = 0.2,
                 initial_service_s: float = 1e-3,
                 admission_weight: Optional[Callable[[RPQ], float]] = None,
                 hot_reserve_frac: float = 0.25):
        if max_depth < 1:
            raise ValueError("max_depth must be >= 1")
        self.max_depth = int(max_depth)
        self._items: List[ServeTicket] = []
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._ewma_alpha = float(ewma_alpha)
        # seeded optimistic; the first completed batches correct it
        self._service_s = float(initial_service_s)
        self.admission_weight = admission_weight
        self.hot_reserve_frac = float(hot_reserve_frac)
        # EWMA of admitted weights = the hot/cold watershed; starts at 0 so
        # an unwarmed sketch (every weight 0) treats all queries as hot
        self._weight_ewma = 0.0
        #: brownout ladder (owned by ``serve.control.BrownoutController``):
        #: at level L of max, ``shed_classes`` admission shrinks to the
        #: bottom 1 - L/max of the queue; the top level rejects outright
        self.shed_level = 0
        self.max_shed_level = 4
        self.shed_classes: Tuple[str, ...] = ("cold",)
        self.submitted = 0
        self.rejected = 0
        self.rejected_cold = 0
        self.rejected_brownout = 0
        #: observability hooks (wired by the serving loop when obs is on):
        #: tracer opens a trace per admitted request, recorder captures
        #: admission rejects as flight-recorder events
        self.tracer = None
        self.recorder = None

    def _hint_scale(self, weight: Optional[float]) -> float:
        """Retry-hint multiplier from relative heat: hot queries (above the
        admitted-weight EWMA) retry sooner, cold ones later."""
        if weight is None or self._weight_ewma <= 0.0:
            return 1.0
        ratio = self._weight_ewma / max(weight, 1e-9)
        return min(max(ratio, 1.0 / self.HINT_SCALE_MAX), self.HINT_SCALE_MAX)

    def set_shed_level(self, level: int) -> None:
        """Set the brownout shed level (clamped into [0, max_shed_level]).
        Called by the brownout controller, never by the queue itself."""
        with self._lock:
            self.shed_level = max(0, min(int(level), self.max_shed_level))

    # -- admission -----------------------------------------------------------
    def submit(self, query: RPQ,
               cls: str = "hot") -> Union[ServeTicket, Rejection]:
        """Admit one request or reject with a backlog-drain retry hint
        (weighted by the query's sketch frequency when the queue has an
        ``admission_weight`` hook; shed per-class under brownout)."""
        w = (self.admission_weight(query)
             if self.admission_weight is not None else None)
        with self._lock:
            depth = len(self._items)
            hint = max(depth, 1) * self._service_s * self._hint_scale(w)
            lvl = self.shed_level
            if lvl > 0 and cls in self.shed_classes:
                # brownout: shed classes admit only into the bottom
                # 1 - lvl/max of the queue; the top level sheds outright
                frac = lvl / max(self.max_shed_level, 1)
                if lvl >= self.max_shed_level or depth >= self.max_depth * (
                        1.0 - frac):
                    self.rejected += 1
                    self.rejected_brownout += 1
                    hint *= 1 + lvl
                    if self.recorder is not None:
                        self.recorder.record("admission_reject",
                                             reason="brownout", cls=cls,
                                             shed_level=lvl,
                                             queue_depth=depth,
                                             retry_after_s=hint)
                    return Rejection(retry_after_s=hint, queue_depth=depth,
                                     reason="brownout")
            if depth >= self.max_depth:
                self.rejected += 1
                if self.recorder is not None:
                    self.recorder.record("admission_reject",
                                         reason="queue_full",
                                         queue_depth=depth,
                                         retry_after_s=hint)
                return Rejection(retry_after_s=hint, queue_depth=depth)
            if (w is not None
                    and depth >= self.max_depth * (1 - self.hot_reserve_frac)
                    and w < self._weight_ewma):
                # the reserve zone only admits hot queries: their plans/DP
                # rows are warm, so they clear backlog fastest
                self.rejected += 1
                self.rejected_cold += 1
                if self.recorder is not None:
                    self.recorder.record("admission_reject",
                                         reason="cold_backpressure",
                                         queue_depth=depth,
                                         retry_after_s=hint)
                return Rejection(retry_after_s=hint, queue_depth=depth,
                                 reason="cold_backpressure")
            if w is not None:
                a = self._ewma_alpha
                self._weight_ewma = (1 - a) * self._weight_ewma + a * w
            ticket = ServeTicket(query=query, cls=cls,
                                 submitted_s=time.perf_counter())
            if self.tracer is not None:
                ctx = self.tracer.new_trace()
                if ctx.sampled:
                    # the raw query object: stringified only at export
                    # (to_text() per admission would tax the hot path)
                    span = self.tracer.start("request", ctx,
                                             query=query,
                                             queue_depth=depth)
                    ticket.trace = span.context()
                    ticket.span = span
            self._items.append(ticket)
            self.submitted += 1
            self._nonempty.notify()
            return ticket

    # -- draining ------------------------------------------------------------
    def take_batch(self, max_batch: int,
                   timeout: Optional[float] = 0.0) -> List[ServeTicket]:
        """Remove and return up to ``max_batch`` requests (FIFO order).

        ``timeout=0`` (the default) polls; ``timeout > 0`` blocks up to that
        many seconds for the queue to become non-empty; ``timeout=None``
        blocks until a request arrives.  Returns whatever is queued the
        moment it is non-empty — micro-batches fill from backlog, they do
        not wait to fill up, so an idle system serves single requests at
        low latency.  Atomic under the queue lock: concurrent workers get
        disjoint batches.
        """
        with self._nonempty:
            if not self._items:
                if timeout is None:
                    while not self._items:
                        self._nonempty.wait()
                elif timeout > 0:
                    self._nonempty.wait(timeout)
            batch = self._items[:max_batch]
            del self._items[:len(batch)]
            return batch

    def record_service_time(self, per_request_s: float) -> None:
        """Fold one batch's measured per-request service time into the EWMA
        that backs admission retry hints."""
        a = self._ewma_alpha
        with self._lock:
            self._service_s = (1 - a) * self._service_s + a * float(
                per_request_s)

    def depth(self) -> int:
        with self._lock:
            return len(self._items)

    @property
    def estimated_service_s(self) -> float:
        return self._service_s
