"""Online graph-query serving engine with TAPER partition maintenance.

The paper's deployment mode (§1.1 eqn. 2, §6.2.4): a partitioned graph
serves a stream of RPQ pattern-matching queries; the engine

  * executes micro-batches of requests, accounting the inter-partition
    traversals each incurs (the latency proxy);
  * feeds every request into the frequency sketch that backs the TPSTry;
  * monitors drift between the sketched workload and the workload the
    current partitioning was fitted to, and triggers a TAPER invocation
    when drift exceeds a threshold (improving on the paper's naive
    fixed-interval trigger, §6.2.4 "identifying effective trigger
    conditions is left as future work" — we use sketch L1 drift).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.rpq import RPQ
from repro.core.taper import Taper, TaperConfig
from repro.graphs.graph import LabelledGraph
from repro.utils import get_logger
from repro.workload.executor import QueryExecutor
from repro.workload.sketch import FrequencySketch

log = get_logger("serve.engine")


@dataclass
class ServeConfig:
    max_results_per_query: int = 32
    sketch_half_life: float = 500.0
    drift_threshold: float = 0.25       # L1 distance between workloads
    min_requests_between_invocations: int = 500
    taper: TaperConfig = field(default_factory=lambda: TaperConfig(max_iterations=4))


@dataclass
class RequestResult:
    query: str
    n_results: int
    ipt: int
    latency_s: float


class GraphQueryEngine:
    def __init__(self, g: LabelledGraph, part: np.ndarray, k: int,
                 config: Optional[ServeConfig] = None):
        self.g = g
        self.part = np.asarray(part, dtype=np.int32)
        self.k = k
        self.cfg = config or ServeConfig()
        self.executor = QueryExecutor(g)
        self.sketch = FrequencySketch(half_life=self.cfg.sketch_half_life)
        self.taper = Taper(g, k, self.cfg.taper)
        self._fitted_freqs: Dict[str, float] = {}
        self._since_invocation = 10 ** 9
        self.invocations = 0
        self.total_requests = 0
        self.total_ipt = 0.0

    # -- serving -----------------------------------------------------------
    def serve_batch(self, queries: Sequence[RPQ]) -> List[RequestResult]:
        out = []
        for q in queries:
            t0 = time.perf_counter()
            paths, crossings = self.executor.enumerate_paths(
                q, max_results=self.cfg.max_results_per_query, part=self.part)
            dt = time.perf_counter() - t0
            self.sketch.observe(q)
            self.total_requests += 1
            self.total_ipt += crossings
            out.append(RequestResult(q.to_text(), len(paths), crossings, dt))
        self._since_invocation += len(queries)
        self._maybe_repartition()
        return out

    # -- online maintenance --------------------------------------------------
    def workload_drift(self) -> float:
        cur = self.sketch.frequencies()
        keys = set(cur) | set(self._fitted_freqs)
        return sum(abs(cur.get(k, 0.0) - self._fitted_freqs.get(k, 0.0))
                   for k in keys)

    def _maybe_repartition(self) -> None:
        if self._since_invocation < self.cfg.min_requests_between_invocations:
            return
        drift = self.workload_drift()
        if drift < self.cfg.drift_threshold:
            return
        workload = self.sketch.workload()
        if not workload:
            return
        log.info("drift %.3f >= %.3f: invoking TAPER (%d queries)",
                 drift, self.cfg.drift_threshold, len(workload))
        report = self.taper.invoke(self.part, workload)
        self.part = report.final_part
        self._fitted_freqs = self.sketch.frequencies()
        self._since_invocation = 0
        self.invocations += 1

    # -- metrics -------------------------------------------------------------
    def stats(self) -> Dict:
        return {
            "requests": self.total_requests,
            "total_ipt": self.total_ipt,
            "ipt_per_request": self.total_ipt / max(self.total_requests, 1),
            "invocations": self.invocations,
            "drift": self.workload_drift(),
        }
