"""Synchronous facade over the async serving subsystem.

The original seed-era ``GraphQueryEngine`` — a private synchronous loop
with its own L1-drift repartition trigger — is gone; this module re-derives
the same call-and-response API as a thin shell over
:class:`repro.serve.loop.ServingLoop` driven inline (no threads): requests
are admitted through the bounded queue, served in micro-batches via the
batched executor, and repartitioning is decided by ``OnlinePolicy`` /
``OnlineTaper`` like every other consumer — the workload-drift trigger is
``OnlinePolicy.drift_l1`` and the first fit is the policy's explicit
``first_invocation_after`` bootstrap (replacing the old "huge counter"
sentinel).  Use :class:`~repro.serve.loop.ServingLoop` directly for the
threaded, invocation-overlapped deployment mode — including multi-worker
serving (``ServeLoopConfig.n_workers``); the facade always drives inline
on the calling thread, so worker count does not apply here.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.online import OnlinePolicy
from repro.core.rpq import RPQ
from repro.core.taper import TaperConfig
from repro.graphs.graph import LabelledGraph, MutationBatch
from repro.obs import Observability
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.workload.sketch import FrequencySketch


@dataclass
class ServeConfig:
    max_results_per_query: int = 32
    sketch_half_life: float = 500.0
    drift_threshold: float = 0.25       # L1 distance between workloads
    min_requests_between_invocations: int = 500
    #: completed requests before the first (bootstrap) invocation may fire
    first_invocation_after: int = 0
    micro_batch: int = 32
    #: directory for durable snapshots + mutation WAL (None = crash safety
    #: off); passed straight through to ``ServeLoopConfig.snapshot_dir``
    snapshot_dir: Optional[str] = None
    #: request-trace sampling rate (0 = tracing off); forwarded to
    #: ``ServeLoopConfig.trace_sample_rate``.  For full control (shared
    #: registry, flight-recorder dump dir) pass ``obs`` instead.
    trace_sample_rate: float = 0.0
    #: pre-built observability bundle; overrides ``trace_sample_rate``
    obs: Optional["Observability"] = None
    taper: TaperConfig = field(default_factory=lambda: TaperConfig(max_iterations=4))


@dataclass
class RequestResult:
    query: str
    n_results: int
    ipt: int
    latency_s: float


class GraphQueryEngine:
    """Blocking serve_batch API over the async engine (inline pump)."""

    def __init__(self, g: LabelledGraph, part: np.ndarray, k: int,
                 config: Optional[ServeConfig] = None):
        self.cfg = config or ServeConfig()
        policy = OnlinePolicy(
            # the drift trigger is the only workload-driven one the old
            # engine had; cadence/topology/ipt stay off in the facade
            cadence=10 ** 9,
            min_interval=0,
            dirty_fraction=2.0,
            drift_l1=self.cfg.drift_threshold,
            bootstrap_after_ticks=0,
        )
        self.loop = ServingLoop(
            g, k,
            part=np.asarray(part, dtype=np.int32),
            taper_config=self.cfg.taper,
            policy=policy,
            sketch=FrequencySketch(half_life=self.cfg.sketch_half_life),
            config=ServeLoopConfig(
                micro_batch=self.cfg.micro_batch,
                max_results_per_query=self.cfg.max_results_per_query,
                min_requests_between_invocations=(
                    self.cfg.min_requests_between_invocations),
                first_invocation_after=self.cfg.first_invocation_after,
                overlap_invocations=False,  # inline drive: synchronous
                snapshot_dir=self.cfg.snapshot_dir,
                trace_sample_rate=self.cfg.trace_sample_rate,
                obs=self.cfg.obs,
            ),
        )
        self.g = g
        self.k = k

    # -- compatibility surface ------------------------------------------------
    @property
    def part(self) -> np.ndarray:
        return self.loop.part

    @property
    def executor(self):
        return self.loop.executor

    @property
    def sketch(self):
        return self.loop.ot.sketch

    @property
    def obs(self):
        return self.loop.obs

    @property
    def invocations(self) -> int:
        return self.loop.ot.invocations

    @property
    def total_requests(self) -> int:
        return self.loop.metrics.completed

    @property
    def total_ipt(self) -> float:
        return self.loop.metrics.total_ipt

    # -- serving -----------------------------------------------------------
    def serve_batch(self, queries: Sequence[RPQ]) -> List[RequestResult]:
        """Admit, execute and account one batch of requests, blocking until
        every result is materialised (invocations run inline)."""
        tickets = []
        for q in queries:
            admission = self.loop.submit(q)
            while not admission.accepted:
                # inline mode: we ARE the worker, so drain and retry rather
                # than bouncing the rejection to the caller
                self.loop.pump()
                admission = self.loop.submit(q)
            tickets.append(admission)
        while not all(t.done.is_set() for t in tickets):
            self.loop.pump()
        return [
            RequestResult(t.query.to_text(), len(t.paths), t.ipt, t.latency_s)
            for t in tickets
        ]

    def apply_mutations(self, batch: MutationBatch) -> None:
        """Queue a topology delta; applied before the next micro-batch."""
        self.loop.submit_mutations(batch)

    def snapshot(self) -> None:
        """Persist the full serving state now (requires ``snapshot_dir``)."""
        self.loop.snapshot(sync=True)

    # -- online maintenance --------------------------------------------------
    def workload_drift(self) -> float:
        return self.loop.ot.workload_drift()

    # -- metrics -------------------------------------------------------------
    def stats(self) -> Dict:
        s = self.loop.stats()
        s.update({
            "requests": s["completed"],
            "invocations": self.loop.ot.invocations,
            "drift": self.workload_drift(),
        })
        return s
