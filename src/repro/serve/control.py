"""Closed-loop overload protection: the observe→decide→act layer.

PR 9 made the serving cluster observable — per-SLO-class latency
histograms, degradation events, a flight recorder — but every protective
mechanism still ran on *static* thresholds: fixed hedge deadlines, a fixed
backend strike count, heat-only admission.  This module closes the loop
(Loom / AWAPart's argument that online partitioning must feed measurement
back into serving decisions, PAPERS.md): the live registry signals drive
admission, hedging, degradation and invocation cadence.

Four control loops, composed by ``ServingLoop`` / ``ClusterCoordinator``:

* **SLO brownout admission** (:class:`BrownoutController`) — reads each
  class's live latency quantile from its registry histogram through a
  *windowed* bucket-quantile estimator (:class:`WindowedQuantile`: the
  delta of cumulative bucket counts between controller ticks, so the
  estimate reflects the current window, not the lifetime average).  A
  breach of the class budget raises the :class:`RequestQueue` shed level
  one step per controller window — progressively shrinking the admission
  zone for shed classes until they are rejected outright — and recovery
  lowers it hysteretically: the estimate must sit below
  ``clear_ratio * budget`` for ``clear_windows`` consecutive windows
  before each step back down.
* **adaptive hedging** (:class:`HedgeController`) — the router's hedge
  deadline becomes ``clamp(quantile * hedge_factor)`` of the same
  windowed estimate, bounded above by the static ``slo_budget_s`` (the
  old deadline is the worst case, never exceeded) and below by
  ``hedge_floor_s`` — so an uncongested class hedges early at its real
  tail, a congested one does not hedge-storm itself.
* **circuit breakers** (:class:`Breaker`) — one closed/open/half-open
  state machine wraps every unreliable dependency: follower serve paths
  (the router routes around an open replica), ship-channel sends (an
  open link fast-fails instead of queueing into a blackhole; the
  follower's tail resync repairs the gap) and the field-backend ladder
  (error-rate-over-window tripping replaces the bare consecutive-failure
  count).  Tripping needs ``min_failures`` in the window *and* the
  window's failure rate at ``error_rate`` — or ``min_failures``
  consecutive trailing failures, preserving the ladder's historic
  strike-count behaviour as the degenerate case.  Every transition is
  recorded to the flight recorder.
* **pressure-aware invocation cadence** — :func:`serve_pressure` folds
  queue depth, shed level and invocation wall cost into one [0, 1]
  signal the loop passes to ``OnlineTaper.poll``; the policy defers
  TAPER invocations above ``OnlinePolicy.defer_above_pressure`` and
  relaxes the ipt-regression threshold below
  ``accelerate_below_pressure`` (idle capacity is the cheapest time to
  repartition).

Every clock here is injectable (``clock=``) so ``serve.chaos`` can drive
the controllers on a deterministic virtual clock — the chaos scenarios'
bit-reproducibility depends on no control decision reading the wall.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.utils import get_logger

log = get_logger("serve.control")

__all__ = [
    "Breaker", "BrownoutController", "ControlConfig", "HedgeController",
    "WindowedQuantile", "serve_pressure",
]

#: breaker states
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class Breaker:
    """Closed/open/half-open circuit breaker (module doc).

    * **closed** — calls flow; outcomes fill a bounded window.  The
      breaker opens when the window holds ``min_failures`` failures at a
      failure rate of at least ``error_rate``, or when the last
      ``min_failures`` outcomes were all failures (the strike-count
      degenerate case).
    * **open** — :meth:`allow` refuses for ``cooldown_s`` (doubling per
      consecutive re-open up to ``cooldown_max_s``), then transitions to
      half-open.
    * **half-open** — probes are allowed through; ``probe_successes``
      consecutive successes close the breaker (window cleared, cooldown
      reset), one failure re-opens it with a doubled cooldown.

    Thread-compatible with the serving loop's single-mutator call sites;
    transitions are recorded to ``recorder`` as ``breaker_transition``
    events.  ``clock`` is injectable for deterministic chaos drills.
    """

    def __init__(self, name: str, window: int = 16, min_failures: int = 4,
                 error_rate: float = 0.5, cooldown_s: float = 0.5,
                 cooldown_max_s: float = 30.0, probe_successes: int = 1,
                 recorder=None,
                 clock: Callable[[], float] = time.monotonic):
        if window < 1 or min_failures < 1:
            raise ValueError("window and min_failures must be >= 1")
        self.name = str(name)
        self.window = int(window)
        self.min_failures = int(min_failures)
        self.error_rate = float(error_rate)
        self.base_cooldown_s = float(cooldown_s)
        self.cooldown_max_s = float(cooldown_max_s)
        self.probe_successes = int(probe_successes)
        self.recorder = recorder
        self.clock = clock
        self.state = CLOSED
        self._outcomes: List[bool] = []   # True = success
        self._opened_at = 0.0
        self._cooldown_s = float(cooldown_s)
        self._probe_ok = 0
        self.trips = 0
        self.closes = 0
        self.fast_failures = 0

    # -- state machine --------------------------------------------------------
    def _transition(self, to: str, **fields) -> None:
        frm, self.state = self.state, to
        if self.recorder is not None:
            self.recorder.record("breaker_transition", breaker=self.name,
                                 frm=frm, to=to, **fields)
        log.info("breaker %s: %s -> %s", self.name, frm, to)

    def _should_trip(self) -> bool:
        fails = sum(1 for ok in self._outcomes if not ok)
        if fails < self.min_failures:
            return False
        if fails / len(self._outcomes) >= self.error_rate:
            return True
        tail = 0
        for ok in reversed(self._outcomes):
            if ok:
                break
            tail += 1
        return tail >= self.min_failures

    def _open(self) -> None:
        self.trips += 1
        self._opened_at = self.clock()
        self._probe_ok = 0
        self._transition(OPEN, cooldown_s=self._cooldown_s)

    def allow(self) -> bool:
        """True when a call may proceed.  An open breaker whose cooldown
        has elapsed moves to half-open and lets the probe through."""
        if self.state == CLOSED:
            return True
        if self.state == OPEN:
            if self.clock() - self._opened_at < self._cooldown_s:
                self.fast_failures += 1
                return False
            self._transition(HALF_OPEN)
        return True  # half-open: probe traffic flows

    def record_success(self) -> None:
        if self.state == HALF_OPEN:
            self._probe_ok += 1
            if self._probe_ok >= self.probe_successes:
                self._outcomes.clear()
                self._cooldown_s = self.base_cooldown_s
                self.closes += 1
                self._transition(CLOSED)
            return
        if self.state == OPEN:
            return  # a straggler finishing after the trip
        self._outcomes.append(True)
        del self._outcomes[:-self.window]

    def record_failure(self) -> bool:
        """Record one failure; returns True when this call tripped the
        breaker closed→open (the ladder demotes on exactly that edge)."""
        if self.state == HALF_OPEN:
            # failed probe: back to open with a doubled cooldown, so a
            # flapping dependency converges onto long re-test intervals
            self._cooldown_s = min(self._cooldown_s * 2, self.cooldown_max_s)
            self._open()
            return False
        if self.state == OPEN:
            return False
        self._outcomes.append(False)
        del self._outcomes[:-self.window]
        if self._should_trip():
            self._open()
            return True
        return False

    def reset(self) -> None:
        """Forget history and close (a new ladder rung starts fresh)."""
        self._outcomes.clear()
        self._probe_ok = 0
        self._cooldown_s = self.base_cooldown_s
        if self.state != CLOSED:
            self._transition(CLOSED, reset=True)

    def stats(self) -> Dict[str, Any]:
        return {"state": self.state, "trips": self.trips,
                "closes": self.closes, "fast_failures": self.fast_failures}


class WindowedQuantile:
    """Bucket-quantile estimator over the *recent window* of a cumulative
    :class:`~repro.obs.registry.Histogram`.

    A registry histogram accumulates forever, so its lifetime quantile
    lags the live tail by however much history it holds.  This estimator
    snapshots the per-bucket counts at each :meth:`advance` (one
    controller window) and interpolates quantiles over the *delta* —
    exactly the samples observed since the last tick."""

    def __init__(self, hist):
        self.hist = hist
        self._base: List[int] = list(hist.counts)

    def advance(self) -> None:
        """Start a new window at the histogram's current position."""
        self._base = list(self.hist.counts)

    @property
    def count(self) -> int:
        """Samples observed in the current window."""
        return sum(self.hist.counts) - sum(self._base)

    def quantile(self, q: float) -> Optional[float]:
        """Interpolated quantile of the window, or None when empty."""
        counts = [c - b for c, b in zip(self.hist.counts, self._base)]
        total = sum(counts)
        if total <= 0:
            return None
        bounds = self.hist.bounds
        target = q * total
        acc = 0
        lo = 0.0
        for i, c in enumerate(counts):
            if acc + c >= target and c:
                hi = bounds[i] if i < len(bounds) else bounds[-1]
                frac = (target - acc) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            acc += c
            if i < len(bounds):
                lo = bounds[i]
        return lo


@dataclass
class ControlConfig:
    """Knobs for the serving stack's control loops (module doc)."""

    #: per-SLO-class latency budget (seconds) the brownout loop defends;
    #: serving loops default it, the cluster reuses ``slo_budget_s``
    slo_budget_s: Dict[str, float] = field(
        default_factory=lambda: {"hot": 0.05, "cold": 0.5})
    #: controller tick period (seconds of ``clock``)
    window_s: float = 0.25
    #: the quantile each class budget is enforced against
    breach_quantile: float = 0.99
    #: minimum window samples before a class's estimate is trusted
    min_window_samples: int = 8
    #: shed ladder height: level ``shed_levels`` rejects shed classes
    #: outright, intermediate levels shrink their admission zone
    shed_levels: int = 4
    #: classes the brownout loop may shed (never the hot class)
    shed_classes: Tuple[str, ...] = ("cold",)
    #: hysteresis: the estimate must sit below ``clear_ratio * budget``
    #: for ``clear_windows`` consecutive windows per step back down
    clear_ratio: float = 0.7
    clear_windows: int = 2
    # -- adaptive hedging ------------------------------------------------------
    hedge_quantile: float = 0.95
    #: deadline = clamp(quantile * hedge_factor, hedge_floor_s, budget)
    hedge_factor: float = 1.5
    hedge_floor_s: float = 1e-3
    # -- circuit breakers ------------------------------------------------------
    breaker_window: int = 16
    breaker_min_failures: int = 3
    breaker_error_rate: float = 0.5
    breaker_cooldown_s: float = 0.5
    # -- serve pressure --------------------------------------------------------
    #: weights folding queue depth / shed level / invocation wall cost
    #: into the [0, 1] pressure signal (see :func:`serve_pressure`)
    pressure_depth_weight: float = 0.5
    pressure_shed_weight: float = 0.5
    pressure_invocation_weight: float = 0.25
    #: deterministic drills replace the wall clock for every controller
    #: and breaker built from this config
    clock: Optional[Callable[[], float]] = None

    def resolved_clock(self) -> Callable[[], float]:
        return self.clock if self.clock is not None else time.monotonic


def serve_pressure(depth_frac: float, shed_frac: float,
                   invocation_frac: float,
                   cfg: Optional[ControlConfig] = None) -> float:
    """Fold the three overload signals into one [0, 1] pressure value:
    request-queue fullness, brownout shed depth, and the invocation wall
    cost relative to its watchdog budget."""
    c = cfg or ControlConfig()
    p = (c.pressure_depth_weight * max(0.0, min(1.0, depth_frac))
         + c.pressure_shed_weight * max(0.0, min(1.0, shed_frac))
         + c.pressure_invocation_weight
         * max(0.0, min(1.0, invocation_frac)))
    return max(0.0, min(1.0, p))


class _ClassWindows:
    """Shared per-class windowed estimators over registry histograms."""

    def __init__(self, registry, metric: str, cfg: ControlConfig):
        self.registry = registry
        self.metric = metric
        self.cfg = cfg
        self._windows: Dict[str, WindowedQuantile] = {}

    def window(self, cls: str) -> WindowedQuantile:
        w = self._windows.get(cls)
        if w is None:
            w = self._windows[cls] = WindowedQuantile(
                self.registry.histogram(self.metric, cls=cls))
        return w

    def advance(self) -> None:
        for w in self._windows.values():
            w.advance()


class BrownoutController:
    """SLO-aware brownout admission (module doc).

    Owns the :class:`RequestQueue`'s shed level: each controller window
    it estimates every budgeted class's ``breach_quantile`` latency over
    the window; any breach raises the shed level one step, and only
    ``clear_windows`` consecutive all-clear windows (every observed
    estimate below ``clear_ratio * budget``) lower it one step —
    admission re-opens hysteretically, never flaps."""

    def __init__(self, queue, registry, cfg: Optional[ControlConfig] = None,
                 metric: str = "request_latency_s", recorder=None):
        self.cfg = cfg or ControlConfig()
        self.queue = queue
        self.recorder = recorder
        self.clock = self.cfg.resolved_clock()
        self.budgets: Dict[str, float] = dict(self.cfg.slo_budget_s)
        self._cw = _ClassWindows(registry, metric, self.cfg)
        for cls in self.budgets:
            # open each class window now, not lazily at the first tick —
            # samples observed before then belong to the first window
            self._cw.window(cls)
        self._last_tick = self.clock()
        self._clear_streak = 0
        self.ticks = 0
        self.shed_raises = 0
        self.shed_drops = 0
        #: gauge mirror of the queue's shed level for dashboards
        self._gauge = registry.gauge("shed_level")
        queue.max_shed_level = self.cfg.shed_levels
        queue.shed_classes = tuple(self.cfg.shed_classes)

    @property
    def shed_level(self) -> int:
        return self.queue.shed_level

    def set_budget(self, cls: str, budget_s: float) -> None:
        """Reconfigure one class's budget live (chaos drills and dynamic
        SLO changes both go through here)."""
        self.budgets[cls] = float(budget_s)

    def maybe_tick(self) -> Optional[int]:
        """Run one controller window if ``window_s`` has elapsed; returns
        the new shed level when it changed, else None."""
        now = self.clock()
        if now - self._last_tick < self.cfg.window_s:
            return None
        self._last_tick = now
        return self.tick()

    def tick(self) -> Optional[int]:
        """Evaluate one window now (unconditionally).  Returns the new
        shed level when it changed, else None."""
        self.ticks += 1
        cfg = self.cfg
        breach = None
        all_clear = True
        observed = False
        for cls, budget in self.budgets.items():
            w = self._cw.window(cls)
            if w.count < cfg.min_window_samples:
                continue
            p = w.quantile(cfg.breach_quantile)
            if p is None:
                continue
            observed = True
            if p > budget:
                breach = (cls, p, budget)
            if p >= cfg.clear_ratio * budget:
                all_clear = False
        self._cw.advance()
        level = self.queue.shed_level
        if breach is not None:
            self._clear_streak = 0
            if level < cfg.shed_levels:
                return self._set_level(level + 1, raised=True,
                                       cls=breach[0], quantile_s=breach[1],
                                       budget_s=breach[2])
            return None
        if not observed or level == 0:
            # an idle window is not evidence of recovery
            return None
        if not all_clear:
            self._clear_streak = 0
            return None
        self._clear_streak += 1
        if self._clear_streak < cfg.clear_windows:
            return None
        self._clear_streak = 0
        return self._set_level(level - 1, raised=False)

    def _set_level(self, level: int, raised: bool, **fields) -> int:
        self.queue.set_shed_level(level)
        self._gauge.set(level)
        if raised:
            self.shed_raises += 1
        else:
            self.shed_drops += 1
        if self.recorder is not None:
            self.recorder.record("shed_level", level=level,
                                 raised=raised, **fields)
        log.info("brownout shed level -> %d (%s)", level,
                 "breach" if raised else "recovery")
        return level

    def stats(self) -> Dict[str, Any]:
        return {"shed_level": self.queue.shed_level, "ticks": self.ticks,
                "shed_raises": self.shed_raises,
                "shed_drops": self.shed_drops}


class HedgeController:
    """Adaptive hedge deadlines from live per-class latency quantiles
    (module doc).  Windows advance on their own ``window_s`` cadence so
    the deadline tracks the *recent* tail, clamped into
    ``[hedge_floor_s, budget]`` — the static budget stays the worst-case
    deadline, so adaptivity can only hedge earlier, never later."""

    def __init__(self, registry, cfg: Optional[ControlConfig] = None,
                 metric: str = "router_latency_s"):
        self.cfg = cfg or ControlConfig()
        self.clock = self.cfg.resolved_clock()
        self._cw = _ClassWindows(registry, metric, self.cfg)
        #: the previous full window's quantile per class (the live window
        #: is still filling, so decisions read the last complete one)
        self._latest: Dict[str, Optional[float]] = {}
        self._last_advance = self.clock()

    def _maybe_advance(self) -> None:
        now = self.clock()
        if now - self._last_advance < self.cfg.window_s:
            return
        self._last_advance = now
        for cls, w in self._cw._windows.items():
            if w.count >= self.cfg.min_window_samples:
                self._latest[cls] = w.quantile(self.cfg.hedge_quantile)
        self._cw.advance()

    def deadline(self, cls: str, budget: Optional[float]) -> Optional[float]:
        """The hedge deadline for ``cls``: the adaptive estimate when one
        exists, else the static budget (also the upper clamp)."""
        self._cw.window(cls)  # ensure the class is tracked
        self._maybe_advance()
        if budget is None:
            return None
        q = self._latest.get(cls)
        if q is None:
            return budget
        return min(budget, max(self.cfg.hedge_floor_s,
                               q * self.cfg.hedge_factor))
