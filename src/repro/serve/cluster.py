"""Replicated cluster serving: one primary loop, N WAL-shipped followers.

:class:`ClusterCoordinator` composes the pieces ``serve.replication``
provides into the deployment §6.2.4 of the paper assumes — a cluster that
keeps answering RPQ reads through replica crashes, shipping stalls and
network partitions:

* the **primary** is an ordinary :class:`~repro.serve.loop.ServingLoop`
  (mutations, TAPER invocations, snapshots/WAL) with
  ``attach_replication`` wired to a ``ReplicationHub``: every journaled
  ingest group and every invocation commit is fenced then shipped;
* **followers** bootstrap exactly like a restarted node (snapshot fetch +
  journal tail replay) and stay current by applying the shipped stream —
  bitwise parity with the primary at every shipped seq;
* the :class:`ClusterRouter` answers reads: each query routes to the
  replica *owning* most of its start vertices under the partition-dealt
  owner fold (:func:`repro.graphs.sharded_packing.shard_assignment` — the
  same span arithmetic ``ShardedVMPacking.owner_of`` uses on device), with
  per-class **bounded staleness** (a follower more than
  ``max_staleness_versions[cls]`` graph versions behind first catches up,
  then falls back to the primary) and per-class **deadline hedging** (a
  read exceeding ``slo_budget_s[cls]`` re-issues to an alternate replica
  and the faster answer wins — identical answers at parity, so hedging is
  pure tail-latency insurance).  Served paths are also accounted for
  **cross-replica ipt** — boundary crossings under the owner fold, the
  serving-level partition-quality metric — and folded into the primary's
  observation state so invocation triggers see the whole cluster's
  workload;
* **failover**: when primary heartbeats stop (crash or partition) past
  ``heartbeat_timeout_s``, the highest-applied-seq live follower promotes
  under a new epoch (:meth:`ClusterCoordinator.fail_over`): it catches up
  to the journal head, becomes a full ``ServingLoop`` over its replica
  state, publishes a *forced* epoch-opening commit frame (re-converging
  every replica, including the later-rejoining zombie) and a fresh
  snapshot.  The deposed node's late writes carry the stale epoch and are
  fenced; because the fence ran *before* every journal append, its state
  is a consistent stale prefix and :meth:`rejoin_demoted` turns it back
  into a follower by pure catch-up tail replay.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace as dc_replace
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.graphs.sharded_packing import majority_owner, shard_assignment
from repro.obs import Observability
from repro.obs.registry import Registry
from repro.obs.trace import NOOP_SPAN, NOOP_TRACE
from repro.serve.control import Breaker, ControlConfig, HedgeController
from repro.serve.faults import FaultInjector, InjectedFault
from repro.serve.loop import ServingLoop
from repro.serve.replication import FollowerReplica, ReplicationHub
from repro.utils import get_logger

log = get_logger("serve.cluster")


@dataclass
class ClusterConfig:
    n_followers: int = 2
    #: vertex block granularity of the owner fold (must match the packing's)
    block_n: int = 128
    max_results_per_query: int = 32
    #: missed-heartbeat window before a failover triggers
    heartbeat_timeout_s: float = 0.25
    #: per-class read staleness bound, in graph versions behind the primary
    #: (each applied mutation batch bumps the version once, so this is a
    #: mutation-log span); a staler follower catches up or the read falls
    #: back to the primary
    max_staleness_versions: Dict[str, int] = field(
        default_factory=lambda: {"hot": 4, "cold": 16})
    #: per-class deadline before a read hedges to a second replica
    slo_budget_s: Dict[str, float] = field(
        default_factory=lambda: {"hot": 0.05, "cold": 0.5})
    hedging: bool = True
    #: follower polls a gap may persist before a tail resync
    resync_after_polls: int = 2
    faults: Optional[FaultInjector] = None
    #: shared observability bundle (tracer / flight recorder / registry);
    #: defaults to the primary loop's bundle so cluster spans and the
    #: loop's invocation spans land in one place
    obs: Optional[Observability] = None
    # -- control loops (PR 10) -------------------------------------------------
    #: closed-loop serving protection (``serve.control``): per-follower
    #: serve breakers, breaker-gated ship channels, and adaptive hedge
    #: deadlines from the live ``router_latency_s`` quantiles (clamped to
    #: ``slo_budget_s``).  None keeps the static PR-8 behaviour exactly.
    control: Optional[ControlConfig] = None


class ClusterRouter:
    """Owner-routed, staleness-bounded, deadline-hedged read path."""

    def __init__(self, coord: "ClusterCoordinator"):
        self.coord = coord
        self._owner_key: Optional[Tuple[int, int]] = None
        self._owner_of: Optional[np.ndarray] = None
        self.routed = 0
        self.routed_by_slot: Dict[int, int] = {}
        self.hedged_requests = 0
        self.staleness_fallbacks = 0
        self.dead_redirects = 0
        self.read_failovers = 0
        self.cross_replica_ipt = 0.0
        #: per-SLO-class latency histograms, lazily bound to the registry
        self._lat_hists: Dict[str, Any] = {}
        # -- control loops (PR 10; all None/zero without a ControlConfig) ------
        ctl = coord.cfg.control
        #: histogram home: the shared registry when observability is on; a
        #: private one when only the control loops need the latencies (the
        #: shared disabled bundle's registry must never be written to)
        self._reg = (coord.obs.registry if coord.obs.enabled
                     else (Registry() if ctl is not None else None))
        #: adaptive hedge deadlines over the live per-class quantiles
        self._hedge = (HedgeController(self._reg, ctl)
                       if ctl is not None else None)
        #: per-follower-slot serve breakers (lazily bound)
        self._breakers: Dict[int, Breaker] = {}
        self.breaker_redirects = 0
        self.hedges_suppressed = 0

    def owners(self) -> np.ndarray:
        """Per-vertex owning replica slot under the current primary
        partition (cached until the partition vector is rebound)."""
        part = self.coord.primary.ot.part
        key = (id(part), len(part))
        if self._owner_key != key:
            self._owner_of = shard_assignment(
                part, self.coord.n_replicas, block_n=self.coord.cfg.block_n)
            self._owner_key = key
        return self._owner_of

    def route(self, query) -> int:
        """Preferred slot for ``query``: majority owner of its start
        vertices (liveness/staleness gating happens at serve time)."""
        ex = self.coord.primary.executor
        plan = ex._enum_plan(query)
        g = self.coord.primary.g
        starts = np.nonzero(np.isin(g.labels, plan.first_labels))[0]
        return majority_owner(self.owners(), starts)

    def _breaker_for(self, slot: int) -> Optional[Breaker]:
        """This follower slot's serve breaker (None without control)."""
        ctl = self.coord.cfg.control
        if ctl is None:
            return None
        b = self._breakers.get(slot)
        if b is None:
            coord = self.coord
            b = self._breakers[slot] = Breaker(
                f"follower-{slot}",
                window=ctl.breaker_window,
                min_failures=ctl.breaker_min_failures,
                error_rate=ctl.breaker_error_rate,
                cooldown_s=ctl.breaker_cooldown_s,
                recorder=(coord.obs.recorder if coord.obs.enabled else None),
                clock=ctl.resolved_clock())
        return b

    def _usable(self, slot: int, cls: str) -> int:
        """Gate the routed slot on liveness, its serve breaker and the
        class staleness bound; falls back to the primary when the owner
        cannot serve in-bound."""
        coord = self.coord
        if slot == coord.primary_slot:
            return slot
        f = coord.followers.get(slot)
        if f is None or not f.alive:
            self.dead_redirects += 1
            return coord.primary_slot
        b = self._breaker_for(slot)
        if b is not None and not b.allow():
            # open breaker: route around the failing replica entirely (no
            # staleness probe either — that would also touch it)
            self.breaker_redirects += 1
            return coord.primary_slot
        bound = coord.cfg.max_staleness_versions.get(
            cls, max(coord.cfg.max_staleness_versions.values(), default=0))
        if f.version_lag > bound:
            f.catch_up()
            if not f.alive or f.version_lag > bound:
                self.staleness_fallbacks += 1
                return coord.primary_slot
        return slot

    def _alternate(self, slot: int, cls: str) -> Optional[int]:
        """Hedge target: the primary when the slow read was on a follower,
        else the freshest in-bound follower whose breaker admits traffic —
        hedging into an open breaker would just double the failure."""
        coord = self.coord
        if slot != coord.primary_slot:
            return coord.primary_slot
        bound = coord.cfg.max_staleness_versions.get(
            cls, max(coord.cfg.max_staleness_versions.values(), default=0))
        best: Optional[int] = None
        breaker_skips = 0
        for s, f in coord.followers.items():
            if not f.alive or f.version_lag > bound:
                continue
            b = self._breaker_for(s)
            if b is not None and not b.allow():
                breaker_skips += 1
                continue
            if (best is None
                    or f.applied_seq > coord.followers[best].applied_seq):
                best = s
        if best is None and breaker_skips:
            self.hedges_suppressed += 1
        return best

    def _serve_slot(self, slot: int, queries: Sequence,
                    max_results: int) -> Tuple[List, float]:
        coord = self.coord
        t0 = time.perf_counter()
        if slot == coord.primary_slot:
            res = coord.primary.executor.enumerate_paths_many(
                queries, max_results=max_results, part=coord.primary.ot.part)
        else:
            res = coord.followers[slot].serve(queries,
                                              max_results=max_results)
        return res, time.perf_counter() - t0

    def serve(self, queries: Sequence, cls: str = "hot",
              max_results: Optional[int] = None) -> List:
        """Answer a read batch; returns ``[(paths, ipt), ...]`` in input
        order.  Replica-side failures (injected serve faults, a crash
        between gate and execute) fail the read over to the primary."""
        coord = self.coord
        cfg = coord.cfg
        if max_results is None:
            max_results = cfg.max_results_per_query
        # first read answered after a failover joins the failover trace:
        # the cross-node crash → fence → promotion → first-answer story
        fo_sp = NOOP_SPAN
        if coord._failover_ctx is not None:
            fo_sp = coord.obs.tracer.start(
                "failover.first-answer", coord._failover_ctx,
                cls=cls, n_queries=len(queries))
            coord._failover_ctx = None
        by_slot: Dict[int, List[int]] = {}
        for i, q in enumerate(queries):
            slot = self._usable(self.route(q), cls)
            by_slot.setdefault(slot, []).append(i)
            self.routed += 1
            self.routed_by_slot[slot] = self.routed_by_slot.get(slot, 0) + 1
        out: List = [None] * len(queries)
        lats: List[float] = [0.0] * len(queries)
        budget = cfg.slo_budget_s.get(cls)
        # adaptive hedging: the deadline tracks the class's live latency
        # quantile, clamped into [hedge_floor_s, budget] — without control
        # loops it is exactly the static budget
        deadline = (self._hedge.deadline(cls, budget)
                    if self._hedge is not None else budget)
        for slot, idxs in by_slot.items():
            qs = [queries[i] for i in idxs]
            b = (self._breaker_for(slot)
                 if slot != coord.primary_slot else None)
            try:
                res, dt = self._serve_slot(slot, qs, max_results)
                if b is not None:
                    b.record_success()
            except (InjectedFault, RuntimeError):
                if slot == coord.primary_slot:
                    raise
                if b is not None:
                    b.record_failure()
                self.read_failovers += 1
                res, dt = self._serve_slot(coord.primary_slot, qs,
                                           max_results)
            per = dt / max(len(qs), 1)
            if cfg.hedging and deadline is not None and per > deadline:
                alt = self._alternate(slot, cls)
                if alt is not None and alt != slot:
                    ab = (self._breaker_for(alt)
                          if alt != coord.primary_slot else None)
                    try:
                        res2, dt2 = self._serve_slot(alt, qs, max_results)
                        if ab is not None:
                            ab.record_success()
                        self.hedged_requests += len(qs)
                        if dt2 < dt:
                            res, per = res2, dt2 / max(len(qs), 1)
                    except (InjectedFault, RuntimeError):
                        if ab is not None:
                            ab.record_failure()
                        # the hedge failing leaves the first answer
            for i, r in zip(idxs, res):
                out[i] = r
                lats[i] = per
        owner = self.owners()
        for paths, _ in out:
            for p in paths:
                if len(p) > 1:
                    ov = owner[np.asarray(p, dtype=np.int64)]
                    self.cross_replica_ipt += float((ov[1:] != ov[:-1]).sum())
        coord.primary.observe_served(
            list(queries), [ipt for _, ipt in out], latencies=lats)
        if self._reg is not None:
            h = self._lat_hists.get(cls)
            if h is None:
                h = self._lat_hists[cls] = self._reg.histogram(
                    "router_latency_s", cls=cls)
            for lat in lats:
                h.observe(lat)
        fo_sp.end(n_served=len(out))
        return out

    def stats(self) -> Dict[str, Any]:
        return {
            "routed": self.routed,
            "routed_by_slot": dict(self.routed_by_slot),
            "hedged_requests": self.hedged_requests,
            "hedged_rate": self.hedged_requests / max(self.routed, 1),
            "staleness_fallbacks": self.staleness_fallbacks,
            "dead_redirects": self.dead_redirects,
            "read_failovers": self.read_failovers,
            "cross_replica_ipt": self.cross_replica_ipt,
            "breaker_redirects": self.breaker_redirects,
            "hedges_suppressed": self.hedges_suppressed,
            "breaker_trips": sum(b.trips for b in self._breakers.values()),
            "breakers_open": sum(1 for b in self._breakers.values()
                                 if b.state != "closed"),
        }

    def collect(self) -> Dict[str, Any]:
        """Registry-collector hook (flattened by ``flatten_numeric``)."""
        return self.stats()


class ClusterCoordinator:
    """One primary ``ServingLoop`` + N ``FollowerReplica``s + the router
    (module doc).  Slots ``0..n_followers`` index the replica set;
    ``primary_slot`` names the one currently holding the write lease, and
    moves on failover."""

    def __init__(self, primary: ServingLoop,
                 config: Optional[ClusterConfig] = None,
                 policy=None, taper_config=None):
        if primary._journal is None:
            raise ValueError(
                "cluster serving needs a durable primary "
                "(ServeLoopConfig.snapshot_dir)")
        self.cfg = config or ClusterConfig()
        self.primary = primary
        self.directory = Path(primary.cfg.snapshot_dir)
        self._taper_config = (taper_config if taper_config is not None
                              else primary.ot.taper.config)
        self._policy = policy if policy is not None else primary.ot.policy
        self.faults = (self.cfg.faults if self.cfg.faults is not None
                       else primary.cfg.faults)
        self.obs = (self.cfg.obs if self.cfg.obs is not None
                    else primary.obs)
        #: forced failover trace awaiting its first answered read
        self._failover_ctx = None
        self.hub = ReplicationHub(journal=primary._journal,
                                  faults=self.faults)
        self.hub.primary_version = int(primary.g.version)
        self.hub.primary_seq = int(primary._applied_seq)
        primary.attach_replication(self.hub)
        # seed snapshot: followers bootstrap the way a restarted node does
        primary.snapshot(sync=True)
        self.primary_slot = 0
        self.followers: Dict[int, FollowerReplica] = {}
        for slot in range(1, self.cfg.n_followers + 1):
            self.followers[slot] = FollowerReplica.bootstrap(
                self.hub, f"replica-{slot}", self.directory,
                taper_config=self._taper_config, policy=self._policy,
                resync_after_polls=self.cfg.resync_after_polls)
            self._wire_channel_breaker(self.followers[slot])
        self.router = ClusterRouter(self)
        self.failovers = 0
        self.rejoins = 0
        self._primary_down = False
        #: deposed primaries by their old slot, awaiting rejoin_demoted()
        self._demoted: Dict[int, ServingLoop] = {}
        if self.obs.enabled:
            for slot, f in self.followers.items():
                self._wire_obs(f, slot)
            if self.faults is not None and self.faults.recorder is None:
                self.faults.recorder = self.obs.recorder
            self.obs.registry.register_collector("cluster", self.collect)
            self.obs.registry.register_collector("router",
                                                 self.router.collect)
            self.obs.registry.register_collector("hub", self.hub.collect)

    def _wire_channel_breaker(self, follower: FollowerReplica) -> None:
        """Breaker-gate this follower's ship channel (control loops only):
        an open link fast-fails sends instead of feeding a blackhole; the
        follower's tail resync repairs the gap after the half-open probe
        succeeds."""
        ctl = self.cfg.control
        if ctl is None:
            return
        follower.channel.breaker = Breaker(
            f"ship-{follower.name}",
            window=ctl.breaker_window,
            min_failures=ctl.breaker_min_failures,
            error_rate=ctl.breaker_error_rate,
            cooldown_s=ctl.breaker_cooldown_s,
            recorder=(self.obs.recorder if self.obs.enabled else None),
            clock=ctl.resolved_clock())

    def _wire_obs(self, follower: FollowerReplica, slot: int) -> None:
        """Hand the shared tracer/recorder to a follower so its applies
        join shipped traces, and expose its stats as a collector."""
        follower.tracer = self.obs.tracer
        follower.recorder = self.obs.recorder
        self.obs.registry.register_collector(f"follower_{slot}",
                                             follower.collect)

    # -- shape ----------------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return 1 + self.cfg.n_followers

    def node_for(self, slot: int):
        if slot == self.primary_slot:
            return self.primary
        return self.followers.get(slot)

    # -- client API -----------------------------------------------------------
    def serve(self, queries: Sequence, cls: str = "hot",
              max_results: Optional[int] = None) -> List:
        """Owner-routed read batch (see :meth:`ClusterRouter.serve`)."""
        return self.router.serve(queries, cls=cls, max_results=max_results)

    def submit_mutations(self, batch):
        """Writes go to the primary (single-writer; applied at its next
        pump round, journaled + shipped to followers)."""
        return self.primary.submit_mutations(batch)

    def pump(self, wait_s: float = 0.0) -> int:
        """One cluster scheduling round: failover check, primary pump
        (ingest/invocations/snapshots + heartbeat + shipping), follower
        polls, retention-floor update."""
        self.check_failover()
        served = 0
        if not self._primary_down:
            served = self.primary.pump(wait_s)
        for f in list(self.followers.values()):
            f.poll()
        self.hub.update_retention(
            include=[f.name for f in self.followers.values() if f.alive])
        self.check_failover()
        return served

    # -- failure injection (tests / benchmark drive these) --------------------
    def crash_primary(self) -> None:
        """Model primary process death: it stops pumping (so heartbeats
        stop), and its durable-state file handles are dropped at the
        promotion that follows."""
        self._primary_down = True

    def partition_primary(self) -> None:
        """Cut the primary's link: heartbeats are lost in flight and the
        write lease lapses (its durable writes fence until failover; after
        failover its epoch is stale and they fence forever)."""
        self.hub.partition_primary(True)

    # -- failover -------------------------------------------------------------
    def check_failover(self) -> bool:
        """Promote when the primary is known-dead or silent (no accepted
        heartbeat) past ``heartbeat_timeout_s``."""
        if not (self._primary_down or self.hub.primary_partitioned):
            return False
        silent_s = time.monotonic() - self.hub.last_heartbeat_mono
        if silent_s < self.cfg.heartbeat_timeout_s:
            return False
        self.obs.recorder.record(
            "heartbeat_lapse", slot=self.primary_slot, silent_s=silent_s,
            timeout_s=self.cfg.heartbeat_timeout_s)
        self.fail_over()
        return True

    def fail_over(self) -> ServingLoop:
        """Promote the best live follower under a new epoch (module doc).
        Deterministic choice: highest applied seq, then highest commit
        index, then lowest slot."""
        # one forced cross-node trace tells the whole failover story:
        # primary-crash → fence → promotion → (router) first answer
        tracer = self.obs.tracer
        fo_ctx = tracer.new_trace(force=True)
        root = tracer.start("failover", fo_ctx, from_slot=self.primary_slot)
        ctx = root.context()
        tracer.event("failover.primary-crash", ctx, slot=self.primary_slot,
                     crashed=self._primary_down,
                     partitioned=self.hub.primary_partitioned)
        live = [(slot, f) for slot, f in self.followers.items() if f.alive]
        if not live:
            raise RuntimeError("no live follower to promote")
        # catch everyone up first: promotion must not lose anything the
        # durable journal or the retained commit frames still hold
        for _, f in live:
            f.catch_up()
        live = [(slot, f) for slot, f in live if f.alive]
        if not live:
            raise RuntimeError("every follower died during catch-up")
        slot, best = max(
            live, key=lambda it: (it[1].applied_seq, it[1].commit_index,
                                  -it[0]))
        old, old_slot = self.primary, self.primary_slot
        epoch = self.hub.advance_epoch()
        tracer.event("failover.fence", ctx, epoch=epoch)
        promo = tracer.start("failover.promotion", ctx, slot=slot,
                             epoch=epoch, applied_seq=best.applied_seq)
        self.followers.pop(slot)
        self.hub.unregister(best.name)
        if self.obs.enabled:
            self.obs.registry.unregister_collector(f"follower_{slot}")
        if self._primary_down:
            # the dead process takes its file handles with it
            try:
                if old._snapshotter is not None:
                    old._snapshotter.close()
                if old._journal is not None:
                    old._journal.close()
            except Exception:
                log.exception("closing dead primary handles failed")
        loop_cfg = (dc_replace(old.cfg, obs=self.obs) if self.obs.enabled
                    else dc_replace(old.cfg))
        promoted = ServingLoop(config=loop_cfg, ot=best.ot)
        promoted._applied_seq = best.applied_seq
        self.hub.journal = promoted._journal
        promoted.attach_replication(self.hub, epoch)
        self.primary = promoted
        self.primary_slot = slot
        self._demoted[old_slot] = old
        self._primary_down = False
        self.failovers += 1
        # epoch-opening commit (the term-opening no-op): broadcast the
        # promoted node's full commit-volatile state so every replica —
        # and the zombie when it rejoins — re-converges on it bitwise.
        # The frame carries the failover trace id, so follower
        # ``replica.commit`` spans join this trace cross-node.
        promoted._invocation_ctx = promo.context()
        promoted._publish_commit(force=True)
        promoted._clear_invocation_trace()
        promoted._warm_devices()
        # fresh snapshot under the new epoch: later bootstraps and full
        # resyncs start from promoted state
        promoted.snapshot(sync=True)
        for f in self.followers.values():
            f.poll()
        promo.end()
        self.obs.recorder.record("promotion", slot=slot, epoch=epoch,
                                 applied_seq=best.applied_seq,
                                 demoted_slot=old_slot)
        self.obs.recorder.trigger("failover")
        root.end(promoted_slot=slot, epoch=epoch)
        if fo_ctx.sampled:
            self._failover_ctx = ctx
        log.warning("failover: slot %d promoted at epoch %d (seq %d); "
                    "slot %d demoted", slot, epoch, best.applied_seq,
                    old_slot)
        return promoted

    def _rejoin_commit_index(self, old: ServingLoop) -> int:
        """Retained commit frames the demoted node already holds: anything
        it published itself (or adopted) under an epoch up to its own.  The
        promoted node's forced epoch-open frame carries a *newer* epoch, so
        it is never treated as covered — rejoin applies it, repairing the
        RNG/prior divergence from the zombie's aborted run."""
        with self.hub._lock:
            idx = [f.commit_index for f in self.hub._commits
                   if int(f.epoch) <= old._epoch
                   and int(f.payload.get("invocations", 0))
                   <= int(old.ot.invocations)
                   and int(f.seq) <= old._applied_seq]
        return max(idx, default=0)

    def rejoin_demoted(self, slot: Optional[int] = None,
                       reuse_state: bool = True) -> FollowerReplica:
        """Bring a deposed primary back as a follower.  ``reuse_state=True``
        (the partition-zombie case): the fence kept every divergent write
        out of durable state, so its memory is a consistent stale prefix —
        rejoin is registration + catch-up tail replay.  ``False`` (the
        crashed-process case): full bootstrap from the latest snapshot."""
        if slot is None:
            slot = sorted(self._demoted)[0]
        old = self._demoted.pop(slot)
        name = f"replica-{slot}"
        if reuse_state:
            try:
                if old._snapshotter is not None:
                    old._snapshotter.close()
                if old._journal is not None:
                    old._journal.close()
            except Exception:
                log.exception("closing demoted primary handles failed")
            f = FollowerReplica(
                old.ot, self.hub, name, directory=self.directory,
                taper_config=self._taper_config, policy=self._policy,
                applied_seq=old._applied_seq,
                commit_index=self._rejoin_commit_index(old),
                resync_after_polls=self.cfg.resync_after_polls)
            f.catch_up()
        else:
            f = FollowerReplica.bootstrap(
                self.hub, name, self.directory,
                taper_config=self._taper_config, policy=self._policy,
                resync_after_polls=self.cfg.resync_after_polls)
        self.followers[slot] = f
        self.rejoins += 1
        self._wire_channel_breaker(f)
        if self.obs.enabled:
            self._wire_obs(f, slot)
        self.obs.recorder.record("rejoin", slot=slot,
                                 reuse_state=bool(reuse_state),
                                 applied_seq=f.applied_seq)
        return f

    # -- lifecycle / stats ----------------------------------------------------
    def stop(self, drain: bool = True) -> Dict[str, Any]:
        stats = self.stats()
        if not self._primary_down:
            self.primary.stop(drain=drain)
        for f in self.followers.values():
            f.crash()
        return stats

    def stats(self) -> Dict[str, Any]:
        """The primary's flat stats dict extended with cluster health:
        per-follower ship/apply lag and staleness, router counters, epoch
        and failover/fencing accounting (satellite: replication health)."""
        s = dict(self.primary.stats())
        s.update(self.router.stats())
        hub = self.hub.stats()
        alive = [f for f in self.followers.values() if f.alive]
        s.update({
            "n_replicas": self.n_replicas,
            "primary_slot": self.primary_slot,
            "failovers": self.failovers,
            "rejoins": self.rejoins,
            "cluster_epoch": hub["epoch"],
            "fencing_rejections": (hub["fencing_rejections"]
                                   + hub["partition_rejections"]),
            "last_stale_epoch": hub["last_stale_epoch"],
            "stale_heartbeats": hub["stale_heartbeats"],
            "max_seq_lag": max((f.seq_lag for f in alive), default=0),
            "max_version_lag": max((f.version_lag for f in alive),
                                   default=0),
            "staleness_bound_versions": dict(self.cfg.max_staleness_versions),
            "full_resyncs": sum(f.full_resyncs
                                for f in self.followers.values()),
            "tail_resyncs": sum(f.tail_resyncs
                                for f in self.followers.values()),
            "followers": {f.name: f.stats()
                          for f in self.followers.values()},
        })
        return s

    def collect(self) -> Dict[str, Any]:
        """Registry-collector hook: cluster health only (the primary loop
        and each follower register their own collectors)."""
        hub = self.hub.stats()
        alive = [f for f in self.followers.values() if f.alive]
        return {
            "n_replicas": self.n_replicas,
            "primary_slot": self.primary_slot,
            "failovers": self.failovers,
            "rejoins": self.rejoins,
            "epoch": hub["epoch"],
            "fencing_rejections": (hub["fencing_rejections"]
                                   + hub["partition_rejections"]),
            "stale_heartbeats": hub["stale_heartbeats"],
            "max_seq_lag": max((f.seq_lag for f in alive), default=0),
            "max_version_lag": max((f.version_lag for f in alive),
                                   default=0),
            "followers_alive": len(alive),
        }
