"""Serving metrics / SLO accounting, exported as plain dicts.

Latency and per-request ipt are tracked in bounded sliding windows (the
most recent ``window`` samples) so p50/p99 reflect current behaviour, not
the lifetime average; counters (requests, rejections, invocations, stalls)
are monotonic.  ``ServeMetrics.snapshot()`` is the only export surface —
a flat dict of floats/ints that benchmarks and dashboards can consume
without importing anything from this package."""
from __future__ import annotations

import threading
from typing import Dict, List, Optional


class SlidingWindow:
    """Bounded ring of float samples with exact percentiles over the ring.

    The sorted view is computed lazily and cached until the next
    ``record`` — snapshot paths take 4 percentiles per window, and
    re-sorting the full ring for each was measurable at serving rates."""

    def __init__(self, window: int = 2048):
        self._buf: List[float] = []
        self._pos = 0
        self._window = int(window)
        self._sorted: Optional[List[float]] = None

    def record(self, x: float) -> None:
        if len(self._buf) < self._window:
            self._buf.append(float(x))
        else:
            self._buf[self._pos] = float(x)
            self._pos = (self._pos + 1) % self._window
        self._sorted = None

    def percentile(self, p: float) -> float:
        if not self._buf:
            return 0.0
        s = self._sorted
        if s is None:
            s = self._sorted = sorted(self._buf)
        idx = min(len(s) - 1, max(0, int(round(p / 100.0 * (len(s) - 1)))))
        return s[idx]

    def mean(self) -> float:
        return sum(self._buf) / len(self._buf) if self._buf else 0.0

    def __len__(self) -> int:
        return len(self._buf)


class ServeMetrics:
    """Counters + windows for the serving loop.  All mutators take the
    internal lock, so the worker, invocation and admission threads can
    report concurrently; ``snapshot`` returns a consistent copy."""

    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self.latency = SlidingWindow(window)
        self.request_ipt = SlidingWindow(window)
        self.completed = 0
        self.batches = 0
        self.total_ipt = 0.0
        self.invocations = 0
        #: wall seconds the worker was *blocked* in synchronous invocations
        #: (stop-the-world mode; 0 under full overlap)
        self.invocation_stall_s = 0.0
        #: wall seconds invocations spent in flight concurrently with serving
        self.invocation_overlap_s = 0.0
        #: requests completed while an invocation was in flight
        self.completed_during_invocation = 0
        self.partition_swaps = 0
        self.invocation_failures = 0
        # -- health / degradation (PR 6) --------------------------------------
        #: invocations cancelled by the watchdog after exceeding the timeout
        self.watchdog_aborts = 0
        #: times the loop fell one rung down the field-backend ladder
        self.backend_fallbacks = 0
        #: times a recovery probe climbed back up a rung
        self.backend_recoveries = 0
        #: failed device uploads of the sharded packing (_warm_devices)
        self.upload_failures = 0
        self.snapshots_taken = 0
        self.snapshot_failures = 0
        #: WAL batches re-applied at restore (set once by ServingLoop.restore)
        self.replayed_mutations = 0
        # -- batched enumeration (PR 7) ----------------------------------------
        #: depth expansions executed by the frontier-batched enumerator
        self.enum_sweeps = 0
        #: total live (query, state, tail-vertex) rows those sweeps advanced
        self.frontier_rows = 0
        #: per-executor-worker completed-request counts; the snapshot folds
        #: every worker's contribution into the one flat dict
        self.completed_by_worker: Dict[int, int] = {}

    def record_invocation_failure(self) -> None:
        with self._lock:
            self.invocation_failures += 1

    def record_watchdog_abort(self) -> None:
        with self._lock:
            self.watchdog_aborts += 1

    def record_backend_fallback(self) -> None:
        with self._lock:
            self.backend_fallbacks += 1

    def record_backend_recovery(self) -> None:
        with self._lock:
            self.backend_recoveries += 1

    def record_upload_failure(self) -> None:
        with self._lock:
            self.upload_failures += 1

    def record_snapshot(self, ok: bool = True) -> None:
        with self._lock:
            if ok:
                self.snapshots_taken += 1
            else:
                self.snapshot_failures += 1

    def record_batch(self, latencies, ipts, overlapped: bool,
                     enum_sweeps: int = 0, frontier_rows: int = 0,
                     worker_id: int = 0) -> None:
        with self._lock:
            self.batches += 1
            self.enum_sweeps += int(enum_sweeps)
            self.frontier_rows += int(frontier_rows)
            n = 0
            for lat, ipt in zip(latencies, ipts):
                self.latency.record(lat)
                self.request_ipt.record(float(ipt))
                self.completed += 1
                self.total_ipt += float(ipt)
                n += 1
                if overlapped:
                    self.completed_during_invocation += 1
            self.completed_by_worker[worker_id] = (
                self.completed_by_worker.get(worker_id, 0) + n)

    def record_invocation(self, wall_s: float, overlapped: bool) -> None:
        with self._lock:
            self.invocations += 1
            self.partition_swaps += 1
            if overlapped:
                self.invocation_overlap_s += float(wall_s)
            else:
                self.invocation_stall_s += float(wall_s)

    def snapshot(self, queue_depth: int = 0, ingest_depth: int = 0,
                 rejected_requests: int = 0, rejected_cold_requests: int = 0,
                 rejected_mutations: int = 0, failed_mutations: int = 0,
                 field_stats: Optional[Dict] = None, field_backend: str = "",
                 degraded: bool = False, worker_error: str = "",
                 invocation_error: str = "",
                 journal_seq: int = 0,
                 epoch: int = 0, cluster_epoch: int = 0,
                 fenced_writes: int = 0, fencing_rejections: int = 0,
                 last_stale_epoch: int = -1, fence_error: str = "",
                 snapshot_capture_s: float = 0.0,
                 snapshot_publish_s: float = 0.0,
                 extra: Optional[Dict] = None) -> Dict[str, float]:
        """Flat dict of the current SLO picture (plain python scalars).

        ``extra`` merges caller-provided scalars (the control loops' shed
        level, serve pressure, breaker states) into the flat dict last, so
        new control-plane keys never require a signature change here.

        ``field_stats`` is the sharded field's last measured exchange
        footprint (``pre["_halo_stats"]``): the halo bytes moved per depth
        step, their ratio to a full-field exchange, and which shard-map /
        exchange backend produced them — so dashboards see the serving
        loop's invocation bandwidth next to its latency percentiles."""
        fs = field_stats or {}
        with self._lock:
            c = max(self.completed, 1)
            # flat-dict contract: per-worker completions export as scalar
            # completed_by_worker_<i> keys, never as a nested dict
            by_worker = {f"completed_by_worker_{w}": n
                         for w, n in sorted(self.completed_by_worker.items())}
            return {
                "completed": self.completed,
                "batches": self.batches,
                "rejected_requests": rejected_requests,
                "rejected_cold_requests": rejected_cold_requests,
                "rejected_mutations": rejected_mutations,
                "failed_mutations": failed_mutations,
                "halo_bytes_per_depth": fs.get("halo_bytes_per_depth", 0),
                "halo_ratio": fs.get("halo_ratio", 0.0),
                "shard_map_source": fs.get("shard_map_source", ""),
                "halo_exchange": fs.get("halo_exchange", ""),
                "queue_depth": queue_depth,
                "ingest_depth": ingest_depth,
                "total_ipt": self.total_ipt,
                "ipt_per_request": self.total_ipt / c,
                "ipt_p50": self.request_ipt.percentile(50),
                "ipt_p99": self.request_ipt.percentile(99),
                "latency_p50_s": self.latency.percentile(50),
                "latency_p99_s": self.latency.percentile(99),
                "latency_mean_s": self.latency.mean(),
                "invocations": self.invocations,
                "invocation_failures": self.invocation_failures,
                "invocation_stall_s": self.invocation_stall_s,
                "invocation_overlap_s": self.invocation_overlap_s,
                "completed_during_invocation":
                    self.completed_during_invocation,
                "partition_swaps": self.partition_swaps,
                # -- batched enumeration ---------------------------------------
                "enum_sweeps": self.enum_sweeps,
                "frontier_rows": self.frontier_rows,
                "enum_sweeps_per_batch":
                    self.enum_sweeps / max(self.batches, 1),
                "frontier_rows_per_batch":
                    self.frontier_rows / max(self.batches, 1),
                "workers_reporting": len(self.completed_by_worker),
                **by_worker,
                # -- health / degradation -------------------------------------
                # "healthy" means: no unrecovered worker or invocation error
                # and the loop is serving at its configured (base) backend
                # rung; a watchdog abort or failed run clears only when a
                # later invocation starts clean
                "healthy": int(not degraded and not worker_error
                               and not invocation_error),
                "degraded": int(bool(degraded)),
                "field_backend": field_backend,
                "worker_error": worker_error,
                "invocation_error": invocation_error,
                "watchdog_aborts": self.watchdog_aborts,
                "backend_fallbacks": self.backend_fallbacks,
                "backend_recoveries": self.backend_recoveries,
                "upload_failures": self.upload_failures,
                "snapshots_taken": self.snapshots_taken,
                "snapshot_failures": self.snapshot_failures,
                "replayed_mutations": self.replayed_mutations,
                "journal_seq": journal_seq,
                # -- replication health (PR 8; zeros on unreplicated loops) ----
                # epoch = the fencing token this node believes it holds;
                # cluster_epoch = the hub's current term.  A node with
                # epoch < cluster_epoch is a fenced zombie: fenced_writes
                # counts its rejected durable writes and last_stale_epoch
                # surfaces the stale token the fence saw last
                "epoch": epoch,
                "cluster_epoch": cluster_epoch,
                "fenced": int(0 < epoch < cluster_epoch),
                "fenced_writes": fenced_writes,
                "fencing_rejections": fencing_rejections,
                "last_stale_epoch": last_stale_epoch,
                "fence_error": fence_error,
                # monotonic durations (satellite: manifest wall-time fix) —
                # capture = copying host state, publish = background write
                "snapshot_capture_s": snapshot_capture_s,
                "snapshot_publish_s": snapshot_publish_s,
                **(extra or {}),
            }
