from repro.serve.engine import GraphQueryEngine, RequestResult, ServeConfig
from repro.serve.ingest import IngestQueue, coalesce_mutations
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.serve.metrics import ServeMetrics
from repro.serve.queueing import Rejection, RequestQueue, ServeTicket

__all__ = [
    "GraphQueryEngine",
    "IngestQueue",
    "Rejection",
    "RequestQueue",
    "RequestResult",
    "ServeConfig",
    "ServeLoopConfig",
    "ServeMetrics",
    "ServeTicket",
    "ServingLoop",
    "coalesce_mutations",
]
