from repro.serve.engine import GraphQueryEngine, ServeConfig

__all__ = ["GraphQueryEngine", "ServeConfig"]
