from repro.serve.engine import GraphQueryEngine, RequestResult, ServeConfig
from repro.serve.faults import (
    FaultInjector,
    FaultSpec,
    InjectedFault,
    corrupt_latest_snapshot,
)
from repro.serve.ingest import IngestQueue, coalesce_mutations
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.serve.metrics import ServeMetrics
from repro.serve.queueing import Rejection, RequestQueue, ServeTicket
from repro.serve.snapshot import (
    MutationJournal,
    RestoreResult,
    ServingSnapshotter,
    capture_serving_state,
    plan_elastic_restore,
    restore_serving_state,
)

__all__ = [
    "FaultInjector",
    "FaultSpec",
    "GraphQueryEngine",
    "IngestQueue",
    "InjectedFault",
    "MutationJournal",
    "Rejection",
    "RequestQueue",
    "RequestResult",
    "RestoreResult",
    "ServeConfig",
    "ServeLoopConfig",
    "ServeMetrics",
    "ServeTicket",
    "ServingLoop",
    "ServingSnapshotter",
    "capture_serving_state",
    "coalesce_mutations",
    "corrupt_latest_snapshot",
    "plan_elastic_restore",
    "restore_serving_state",
]
