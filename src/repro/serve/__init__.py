from repro.obs import FlightRecorder, Observability, Registry, Tracer
from repro.serve.chaos import (
    ChaosHarness,
    ChaosReport,
    Scenario,
    run_scenario,
    scenario,
)
from repro.serve.cluster import ClusterConfig, ClusterCoordinator, ClusterRouter
from repro.serve.control import (
    Breaker,
    BrownoutController,
    ControlConfig,
    HedgeController,
    WindowedQuantile,
    serve_pressure,
)
from repro.serve.engine import GraphQueryEngine, RequestResult, ServeConfig
from repro.serve.faults import (
    FaultInjector,
    FaultSpec,
    InjectedFault,
    corrupt_latest_snapshot,
)
from repro.serve.ingest import IngestQueue, coalesce_mutations
from repro.serve.loop import ServeLoopConfig, ServingLoop
from repro.serve.metrics import ServeMetrics
from repro.serve.queueing import Rejection, RequestQueue, ServeTicket
from repro.serve.replication import (
    FencedWrite,
    FollowerReplica,
    Frame,
    JournalGap,
    ReplicationHub,
    ShipChannel,
)
from repro.serve.snapshot import (
    MutationJournal,
    RestoreResult,
    ServingSnapshotter,
    apply_journal_group,
    capture_serving_state,
    plan_elastic_restore,
    restore_serving_state,
)

__all__ = [
    "Breaker",
    "BrownoutController",
    "ChaosHarness",
    "ChaosReport",
    "ClusterConfig",
    "ClusterCoordinator",
    "ClusterRouter",
    "ControlConfig",
    "HedgeController",
    "Scenario",
    "WindowedQuantile",
    "run_scenario",
    "scenario",
    "serve_pressure",
    "FaultInjector",
    "FaultSpec",
    "FencedWrite",
    "FlightRecorder",
    "FollowerReplica",
    "Frame",
    "GraphQueryEngine",
    "Observability",
    "Registry",
    "Tracer",
    "IngestQueue",
    "InjectedFault",
    "JournalGap",
    "MutationJournal",
    "Rejection",
    "ReplicationHub",
    "RequestQueue",
    "RequestResult",
    "RestoreResult",
    "ServeConfig",
    "ServeLoopConfig",
    "ServeMetrics",
    "ServeTicket",
    "ServingLoop",
    "ServingSnapshotter",
    "ShipChannel",
    "apply_journal_group",
    "capture_serving_state",
    "coalesce_mutations",
    "corrupt_latest_snapshot",
    "plan_elastic_restore",
    "restore_serving_state",
]
